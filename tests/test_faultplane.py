"""The unified fault-injection plane, and what it proves.

Unit half: FaultPlane parsing (env + legacy spelling), selector
semantics (@n, @/n, @p...s...), action execution, determinism, counters,
and the shared StragglerDetector.

Crash half: a StreamWriter subprocess ingests micro-batches while a
fault point in the commit sequence SIGKILLs it; reopening must truncate
the torn tail and preserve exactly the at-least-once contract — every
ACKed batch present once, no phantom bytes, stream still appendable.
The default lane kills at each commit point once; the stress lane
repeats each point across several commit indices.

Plus corrupt-object detection: a content-addressed object flipped at
rest is dropped (never adopted) when verify-on-adopt is armed.
"""

import os
import subprocess
import sys
import time

import numpy as np
import pytest

from repro.core import (BufferStore, KernelZero, Sandbox, SipcReader,
                        StreamWriter, Table)
from repro.core import faultplane, zarquet
from repro.core.faultplane import (FaultInjected, FaultPlane,
                                   StragglerDetector, _parse_env,
                                   _parse_spec)
from repro.core.zarquet import STREAM_CRASH_POINTS

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


@pytest.fixture(autouse=True)
def _clean_plane(monkeypatch):
    monkeypatch.delenv("ZERROW_FAULTS", raising=False)
    monkeypatch.delenv("ZERROW_CRASH", raising=False)
    faultplane.PLANE.reset()
    yield
    faultplane.PLANE.reset()


# ---------------------------------------------------------------------------
# parsing
# ---------------------------------------------------------------------------

def test_parse_spec_forms():
    s = _parse_spec("pt=raise")
    assert (s.point, s.action, s.at) == ("pt", "raise", 1)
    s = _parse_spec("pt=delay:0.25@3")
    assert (s.action, s.arg, s.at) == ("delay", 0.25, 3)
    s = _parse_spec("pt=kill@/4")
    assert s.every == 4
    s = _parse_spec("pt=raise@p0.5s7")
    assert (s.p, s.seed) == (0.5, 7)
    # malformed tokens never take the runtime down
    for bad in ("", "pt", "pt=", "=kill", "pt=notanaction",
                "pt=delay:xyz", "pt=kill@bogus"):
        assert _parse_spec(bad) is None, bad


def test_parse_env_legacy_crash_spelling():
    specs = _parse_env("", "pre_journal:3")
    assert specs["pre_journal"].action == "kill"
    assert specs["pre_journal"].at == 3
    # a "torn" point maps to the torn action (call site tears its write)
    specs = _parse_env("", "torn_journal:2")
    assert specs["torn_journal"].action == "torn"
    # ZERROW_FAULTS wins over the legacy var for the same point
    specs = _parse_env("pre_journal=raise", "pre_journal:1")
    assert specs["pre_journal"].action == "raise"


# ---------------------------------------------------------------------------
# selector + action semantics
# ---------------------------------------------------------------------------

def test_at_selector_fires_nth_hit_and_later():
    pl = FaultPlane()
    pl.install("pt", "raise", at=3)
    assert pl.fire("pt") is None
    assert pl.fire("pt") is None
    with pytest.raises(FaultInjected):
        pl.fire("pt")
    with pytest.raises(FaultInjected):
        pl.fire("pt")                      # ...and every later hit
    assert pl.hits("pt") == 4 and pl.fired("pt") == 2


def test_every_selector_is_periodic():
    pl = FaultPlane()
    pl.install("pt", "torn", every=3)
    got = [pl.fire("pt") for _ in range(9)]
    assert got == [None, None, "torn"] * 3


def test_probabilistic_selector_is_seed_deterministic():
    a, b = FaultPlane(), FaultPlane()
    a.install("pt", "torn", p=0.4, seed=11)
    b.install("pt", "torn", p=0.4, seed=11)
    seq_a = [a.fire("pt") for _ in range(50)]
    seq_b = [b.fire("pt") for _ in range(50)]
    assert seq_a == seq_b                  # same seed, same hit order
    assert "torn" in seq_a and None in seq_a


def test_count_caps_total_fires():
    pl = FaultPlane()
    pl.install("pt", "torn", count=2)
    got = [pl.fire("pt") for _ in range(5)]
    assert got.count("torn") == 2


def test_delay_action_sleeps_then_continues():
    pl = FaultPlane()
    pl.install("pt", "delay", arg=0.05)
    t0 = time.monotonic()
    assert pl.fire("pt") == "delay"
    assert time.monotonic() - t0 >= 0.05


def test_env_specs_reparse_on_change(monkeypatch):
    pl = FaultPlane()
    assert pl.fire("pt") is None
    monkeypatch.setenv("ZERROW_FAULTS", "pt=raise")
    with pytest.raises(FaultInjected):
        pl.fire("pt")
    monkeypatch.setenv("ZERROW_FAULTS", "")
    assert pl.fire("pt") is None           # disarmed live
    # programmatic beats env
    monkeypatch.setenv("ZERROW_FAULTS", "pt=raise")
    pl.install("pt", "torn")
    assert pl.fire("pt") == "torn"


def test_corrupt_file_flips_in_place(tmp_path):
    p = str(tmp_path / "blob")
    with open(p, "wb") as fh:
        fh.write(b"abcdef")
    faultplane.corrupt_file(p, offset=2, nbytes=2)
    assert open(p, "rb").read() == b"ab\x9c\x9bef"
    faultplane.corrupt_file(p, offset=2, nbytes=2)   # XOR is an involution
    assert open(p, "rb").read() == b"abcdef"


# ---------------------------------------------------------------------------
# StragglerDetector
# ---------------------------------------------------------------------------

def test_straggler_detector_ewma_and_flagging():
    d = StragglerDetector(alpha=0.5, factor=1.7, min_peers=3)
    assert d.update("a", 1.0) == 1.0       # first sample seeds the EWMA
    assert d.update("a", 2.0) == pytest.approx(1.5)
    d.update("b", 1.0)
    # below min_peers: no verdicts, median 0
    assert d.flag() == ([], 0.0)
    d.update("c", 1.0)
    d.update("slow", 5.0)
    slow, median = d.flag()
    assert slow == ["slow"] and median > 0
    # restricting to a key set excludes the straggler from the population
    slow, _ = d.flag({"a", "b", "c"})
    assert slow == []
    d.drop("slow")
    assert d.ewma("slow") == 0.0


def test_fleet_monitor_uses_shared_detector():
    from repro.runtime.fault import FaultConfig, FleetMonitor
    m = FleetMonitor(4, FaultConfig(straggler_factor=1.5))
    for step in range(4):
        for w in range(4):
            m.heartbeat(w, step, 3.0 if w == 2 else 1.0)
    assert isinstance(m.health, StragglerDetector)
    assert m.detect_stragglers() == [2]
    assert m.workers[2].step_ewma == pytest.approx(m.health.ewma(2))


# ---------------------------------------------------------------------------
# StreamWriter crash matrix (subprocess: the faults SIGKILL for real)
# ---------------------------------------------------------------------------

ROWS = 40

_WRITER = r"""
import os, sys
import numpy as np
sys.path.insert(0, {src!r})
from repro.core import StreamWriter, Table

path, n = sys.argv[1], int(sys.argv[2])
w = StreamWriter(path, max_inflight=2,
                 on_ack=lambda seqs, v: [print(f"ACKED {{s}}", flush=True)
                                         for s in seqs])
for i in range(n):
    print(f"INGEST {{i}}", flush=True)
    w.ingest(Table.from_pydict(
        {{"seq": np.full({rows}, i, dtype=np.int64),
          "x": np.arange({rows}, dtype=np.int64) * (i + 1)}}))
w.close()
print("DONE", flush=True)
"""


def _run_stream_writer(path, n=6, faults=None, crash=None, timeout=120):
    env = dict(os.environ,
               PYTHONPATH=SRC + os.pathsep + os.environ.get("PYTHONPATH", ""))
    env.pop("ZERROW_FAULTS", None)
    env.pop("ZERROW_CRASH", None)
    if faults:
        env["ZERROW_FAULTS"] = faults
    if crash:
        env["ZERROW_CRASH"] = crash
    out = subprocess.run(
        [sys.executable, "-c",
         _WRITER.format(src=os.path.abspath(SRC), rows=ROWS),
         str(path), str(n)],
        capture_output=True, text=True, timeout=timeout, env=env)
    acked = [int(l.split()[1]) for l in out.stdout.splitlines()
             if l.startswith("ACKED ")]
    return out, acked


def _verify_stream(path, acked, n):
    """The at-least-once recovery contract, checked from a fresh reader."""
    # reopening for append truncates any torn (uncommitted) tail
    w = StreamWriter(str(path))
    w.close()
    assert os.path.getsize(path) == zarquet.committed_end(str(path))
    meta = zarquet.read_footer(str(path))
    if meta["nrows"] == 0:
        assert acked == []                 # nothing ACKed, nothing owed
        return set()
    t = zarquet.read_table(str(path))
    seqs = np.asarray(t.combine().batches[0].column("seq").values[:t.num_rows])
    present, counts = np.unique(seqs, return_counts=True)
    present = set(int(s) for s in present)
    # every ACKed batch survived; nothing appears twice; no phantoms
    assert set(acked) <= present, f"ACKed batch lost: {set(acked) - present}"
    assert all(c == ROWS for c in counts), "duplicate or torn row group"
    assert present <= set(range(n))
    # per-row content intact for every recovered group
    for g in present:
        rows = np.asarray(
            t.combine().batches[0].column("x").values[:t.num_rows])[
                seqs == g]
        assert np.array_equal(rows, np.arange(ROWS, dtype=np.int64) * (g + 1))
    return present


def _stream_crash_case(tmp_path, point, at, n=6):
    # commit-sequence points are hit once per commit (the v0 footer plus
    # one per flush of max_inflight=2 batches): n batches give 1 + n/2
    # hits, so n must scale with the target hit index
    n = max(n, (at + 1) * 2)
    p = tmp_path / "stream.zq"
    out, acked = _run_stream_writer(p, n=n,
                                    faults=f"{point}=torn@{at}"
                                    if "torn" in point
                                    else f"{point}=kill@{at}")
    assert out.returncode != 0, f"{point}@{at}: writer survived injection"
    present = _verify_stream(p, acked, n)
    # the stream must remain appendable after recovery
    w = StreamWriter(str(p))
    w.ingest(Table.from_pydict(
        {"seq": np.full(ROWS, 99, dtype=np.int64),
         "x": np.full(ROWS, 7, dtype=np.int64)}))
    w.close()
    t = zarquet.read_table(str(p))
    assert t.num_rows == (len(present) + 1) * ROWS


def test_stream_writer_clean_run_acks_everything(tmp_path):
    p = tmp_path / "stream.zq"
    out, acked = _run_stream_writer(p, n=6)
    assert out.returncode == 0, out.stderr[-2000:]
    assert sorted(acked) == list(range(6))
    assert _verify_stream(p, acked, 6) == set(range(6))


@pytest.mark.parametrize("point", STREAM_CRASH_POINTS)
def test_stream_writer_crash_at_each_point(tmp_path, point):
    _stream_crash_case(tmp_path, point, at=2)


@pytest.mark.stress
@pytest.mark.parametrize("point", STREAM_CRASH_POINTS)
@pytest.mark.parametrize("at", [1, 3, 5])
def test_stream_writer_crash_matrix(tmp_path, point, at):
    _stream_crash_case(tmp_path, point, at=at)


def test_stream_writer_legacy_crash_spelling_still_kills(tmp_path):
    p = tmp_path / "stream.zq"
    out, acked = _run_stream_writer(p, n=6, crash="stream_pre_sidecar:1")
    assert out.returncode != 0
    _verify_stream(p, acked, 6)


# ---------------------------------------------------------------------------
# corrupt-object detection (verify-on-adopt)
# ---------------------------------------------------------------------------

def _mk_table(i):
    rng = np.random.default_rng(2000 + i)
    return Table.from_pydict(
        {"a": rng.integers(0, 1 << 40, size=200).astype(np.int64)})


def test_corrupt_object_dropped_on_adopt(tmp_path, monkeypatch):
    root = str(tmp_path / "cache")
    store = BufferStore(backing="file", root=root)
    kz = KernelZero(store)
    for i in range(2):
        sb = Sandbox(store, kz, f"w{i}", mode="zero")
        msg = sb.write_output(_mk_table(i), label=f"t{i}")
        store.publish(f"fp{i}", msg, label=f"t{i}")
    store.close()

    # bit-rot one object at rest, between runs
    objdir = os.path.join(root, "objects")
    victim = sorted(os.listdir(objdir))[0]
    faultplane.corrupt_file(os.path.join(objdir, victim),
                            offset=16, nbytes=4)

    # without verification the rot goes unnoticed (size/existence pass)
    store = BufferStore.reopen(root)
    assert len(store.manifest.entries) == 2
    assert store.manifest.dropped_corrupt == 0
    store.close()

    # verify-on-adopt re-hashes and refuses the corrupt entry
    monkeypatch.setenv("ZERROW_VERIFY_OBJECTS", "1")
    store = BufferStore.reopen(root)
    try:
        man = store.manifest
        assert man.dropped_corrupt >= 1
        assert len(man.entries) == 1
        # the surviving entry still decodes to its exact content
        fp = next(iter(man.entries))
        msg = man.decode(fp, store, label=fp)
        got = SipcReader(store).read_table(msg)
        assert got.equals(_mk_table(int(fp[2:])))
    finally:
        store.close()

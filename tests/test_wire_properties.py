"""Property-based tests of the SIPC wire protocol.

Random Arrow-like tables — mixed dtypes, null runs, dictionary columns,
zero-row / zero-column / zero-length-string edge cases — must satisfy the
two structural claims the Flight data plane makes:

  * ``encode_message``/``decode_message`` roundtrip is the identity with
    ``copied_bytes == 0`` on both sides of the hop (references move, data
    does not);
  * the frame size depends only on the table's *structure* (schema,
    batch/buffer count), never on how many data bytes it describes.

The generator is a plain seeded-numpy implementation so the suite runs
everywhere; when ``hypothesis`` is installed (the CI stress lane) the
same properties also run under real strategies.  The default lane runs a
small example count; the ``stress`` marker runs >= 200 examples.
"""

import os

import numpy as np
import pytest

from repro.core import (BufferStore, KernelZero, Sandbox, SipcReader,
                        Table, decode_message, encode_message)
from repro.core.arrow import Column, Field, Schema, pack_validity
from repro.core.flight.wire import frame_refs

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

PRIM_DTYPES = ("int8", "int16", "int32", "int64", "uint8",
               "float32", "float64", "bool")
KINDS = ("prim", "utf8", "dict", "dict_prim")


# ---------------------------------------------------------------------------
# seeded random-table generator
# ---------------------------------------------------------------------------

def _null_runs(rng, n, forced=None):
    """Validity bitmap made of alternating valid/null runs (or None).
    ``forced`` pins presence so two builds of one spec share structure."""
    present = forced if forced is not None else bool(rng.random() < 0.6)
    if n == 0 or not present:
        return None
    mask = np.ones(n, dtype=bool)
    pos, valid = 0, bool(rng.integers(0, 2))
    while pos < n:
        run = int(rng.integers(1, max(n // 3, 1) + 1))
        mask[pos:pos + run] = valid
        valid = not valid
        pos += run
    return pack_validity(mask)


def _strings(rng, n, min_len=0):
    """n strings with zero-length and repeated runs mixed in."""
    out = []
    for _ in range(n):
        k = int(rng.integers(min_len, 13))  # 0: the empty-string edge
        out.append(bytes(rng.integers(97, 123, size=k, dtype=np.uint8)))
    return out


def _build_column(rng, kind, dtype, n, has_validity=None, card=None,
                  min_str=0):
    validity = _null_runs(rng, n, forced=has_validity)
    if kind == "prim":
        if dtype == "bool":
            vals = rng.integers(0, 2, size=n).astype(bool)
        elif dtype.startswith("float"):
            vals = rng.standard_normal(n).astype(dtype)
        else:
            info = np.iinfo(np.dtype(dtype))
            vals = rng.integers(info.min, int(info.max) + 1, size=n,
                                dtype=np.int64).astype(dtype)
        return Column.primitive(vals, validity)
    if kind == "utf8":
        return Column.from_strings(_strings(rng, n, min_str), validity)
    k = card or int(rng.integers(1, 7))     # dictionary cardinality
    codes = rng.integers(0, k, size=n).astype(np.int32)
    if kind == "dict":
        dic = Column.from_strings([bytes([97 + j]) * (j + 1)
                                   for j in range(k)])
    else:
        dic = Column.primitive(
            rng.integers(0, 1 << 20, size=k).astype(np.int64))
    return Column.dictionary_encoded(codes, dic, validity)


def random_spec(rng):
    """Per-column (kind, dtype, has_validity, dict_cardinality) — the
    *structure*, separate from the size, so the frame-size property can
    build two sizes of one spec with identical buffer layout."""
    n_cols = int(rng.integers(0, 5))        # 0: the zero-column edge
    return [(str(rng.choice(KINDS)), str(rng.choice(PRIM_DTYPES)),
             bool(rng.integers(0, 2)), int(rng.integers(1, 7)))
            for _ in range(n_cols)]


def build_table(rng, spec, n_rows, structural=False):
    """``structural=True`` pins everything that changes the frame layout
    (validity presence, empty-buffer edges) so only data sizes vary."""
    fields, cols = [], []
    for j, (kind, dtype, has_validity, card) in enumerate(spec):
        c = _build_column(rng, kind, dtype, n_rows,
                          has_validity=has_validity if structural else None,
                          card=card if structural else None,
                          min_str=1 if structural else 0)
        fields.append(Field(f"c{j}", c.type))
        cols.append(c)
    return Table.from_batch(Schema(fields), cols)


def random_table(rng):
    n = int(rng.choice([0, 1, 2, 7, 33, 128]))
    return build_table(rng, random_spec(rng), n)


# ---------------------------------------------------------------------------
# the properties
# ---------------------------------------------------------------------------

def _roundtrip_once(tmp_path, table, tag):
    store = BufferStore(backing="file",
                        data_dir=os.path.join(str(tmp_path), f"w{tag}"))
    reader_store = BufferStore(backing="file",
                               data_dir=os.path.join(str(tmp_path),
                                                     f"r{tag}"))
    try:
        sb = Sandbox(store, KernelZero(store), "w", mode="zero")
        msg = sb.write_output(table, label="t")
        copied_before = store.copied_bytes
        frame = encode_message(msg, store)
        got = SipcReader(reader_store).read_table(
            decode_message(frame, reader_store))
        assert got.equals(table)
        # references moved, data did not — on either side of the hop
        assert store.copied_bytes == copied_before
        assert reader_store.copied_bytes == 0
        # every exported reference names a real extent of a real file
        for path, offset, length in frame_refs(frame):
            assert os.path.getsize(path) >= offset + length
        return frame
    finally:
        store.close()
        reader_store.close()


def _check_roundtrips(tmp_path, n_examples):
    rng = np.random.default_rng(20260728)
    for i in range(n_examples):
        _roundtrip_once(tmp_path, random_table(rng), i)


def _check_frame_size_independence(tmp_path, n_examples):
    """Same structure, 64x the rows -> byte-identical frame length."""
    rng = np.random.default_rng(42)
    for i in range(n_examples):
        spec = random_spec(rng)
        small = build_table(rng, spec, 8, structural=True)
        big = build_table(rng, spec, 8 * 64, structural=True)
        f_small = _roundtrip_once(tmp_path, small, f"s{i}")
        f_big = _roundtrip_once(tmp_path, big, f"b{i}")
        assert len(f_small) == len(f_big), \
            f"frame grew with data: {len(f_small)} -> {len(f_big)} ({spec})"
        if big.nbytes:
            assert len(f_big) < max(big.nbytes, 1)


def test_wire_roundtrip_random_tables(tmp_path):
    _check_roundtrips(tmp_path, 40)


def test_wire_frame_size_independent_of_data(tmp_path):
    _check_frame_size_independence(tmp_path, 10)


@pytest.mark.stress
def test_wire_roundtrip_random_tables_stress(tmp_path):
    _check_roundtrips(tmp_path, 220)


@pytest.mark.stress
def test_wire_frame_size_independent_of_data_stress(tmp_path):
    _check_frame_size_independence(tmp_path, 40)


# ---------------------------------------------------------------------------
# hypothesis strategies (CI stress lane; skipped when not installed)
# ---------------------------------------------------------------------------

if HAVE_HYPOTHESIS:
    import shutil
    import tempfile

    @st.composite
    def arrow_tables(draw):
        seed = draw(st.integers(0, 2**32 - 1))
        rng = np.random.default_rng(seed)
        n = draw(st.sampled_from([0, 1, 3, 17, 64]))
        n_cols = draw(st.integers(0, 4))
        spec = [(draw(st.sampled_from(KINDS)),
                 draw(st.sampled_from(PRIM_DTYPES)),
                 draw(st.booleans()), draw(st.integers(1, 6)))
                for _ in range(n_cols)]
        return build_table(rng, spec, n)

    @pytest.mark.stress
    @settings(max_examples=200, deadline=None)
    @given(arrow_tables())
    def test_wire_roundtrip_hypothesis(table):
        d = tempfile.mkdtemp(prefix="zerrow-wireprop-")
        try:
            _roundtrip_once(d, table, "h")
        finally:
            shutil.rmtree(d, ignore_errors=True)

"""Unit tests for the Arrow computational format layer."""
import numpy as np
import pytest

from repro.core import arrow as A
from repro.core import ops


def test_primitive_roundtrip():
    v = np.arange(100, dtype=np.int64)
    c = A.Column.primitive(v)
    assert c.length == 100
    assert c.type is A.INT64
    assert np.array_equal(c.to_numpy(), v)


def test_utf8_from_strings():
    c = A.Column.from_strings(["ab", "", "cdef", "g"])
    assert c.length == 4
    assert c.get_bytes(0) == b"ab"
    assert c.get_bytes(1) == b""
    assert c.get_bytes(2) == b"cdef"
    assert c.get_bytes(3) == b"g"


def test_validity_bitmap_roundtrip():
    mask = np.array([True, False, True, True, False, True, True, True, False])
    bm = A.pack_validity(mask)
    assert bm.nbytes == 2
    assert np.array_equal(A.unpack_validity(bm, 9), mask)


def test_slice_is_view():
    v = np.arange(1000, dtype=np.float64)
    c = A.Column.primitive(v)
    s = c.slice(100, 200)
    assert s.length == 100
    # zero copy: the slice's values share memory with the parent
    assert s.values.base is v or s.values.base is c.values.base or \
        s.values.__array_interface__["data"][0] == \
        v.__array_interface__["data"][0] + 100 * 8


def test_utf8_slice_shares_values_buffer():
    c = A.Column.from_strings([f"s{i:03d}" for i in range(50)])
    s = c.slice(10, 20)
    assert s.length == 10
    assert s.get_bytes(0) == b"s010"
    # values buffer is the SAME array (non-zero-based offsets)
    assert s.values is c.values


def test_take_utf8():
    c = A.Column.from_strings(["aa", "bbb", "c", "dddd"])
    t = c.take(np.array([3, 0, 0, 2]))
    assert [t.get_bytes(i) for i in range(4)] == [b"dddd", b"aa", b"aa", b"c"]


def test_dictionary_roundtrip():
    dic = A.Column.from_strings(["x", "yy", "zzz"])
    codes = np.array([2, 0, 1, 1, 2], dtype=np.int32)
    c = A.Column.dictionary_encoded(codes, dic)
    dec = c.decode_dictionary()
    assert [dec.get_bytes(i) for i in range(5)] == \
        [b"zzz", b"x", b"yy", b"yy", b"zzz"]


def test_dict_take_shares_dictionary():
    dic = A.Column.from_strings(["x", "yy", "zzz"])
    c = A.Column.dictionary_encoded(np.array([0, 1, 2, 0], np.int32), dic)
    t = c.take(np.array([2, 0]))
    assert t.dictionary is dic  # dictionary sharing
    assert t._get_logical_bytes(0) == b"zzz"


def test_table_pydict_roundtrip():
    d = {"a": [1, 2, 3], "b": ["x", "y", "z"]}
    t = A.Table.from_pydict(d)
    assert t.num_rows == 3
    assert t.to_pydict() == {"a": [1, 2, 3], "b": ["x", "y", "z"]}


def test_table_equals():
    t1 = A.Table.from_pydict({"a": [1, 2, 3]})
    t2 = A.Table.from_pydict({"a": [1, 2, 3]})
    t3 = A.Table.from_pydict({"a": [1, 2, 4]})
    assert t1.equals(t2)
    assert not t1.equals(t3)


def test_chunked_combine():
    t1 = A.Table.from_pydict({"a": [1, 2], "s": ["p", "qq"]})
    t2 = A.Table.from_pydict({"a": [3], "s": ["rrr"]})
    t = ops.concat_tables([t1, t2])
    assert len(t.batches) == 2
    assert t.num_rows == 3
    c = t.combine()
    assert len(c.batches) == 1
    assert c.to_pydict() == {"a": [1, 2, 3], "s": ["p", "qq", "rrr"]}


def test_ranges_helper():
    lens = np.array([3, 0, 2, 1], dtype=np.int64)
    r = A._ranges(lens)
    assert np.array_equal(r, [0, 1, 2, 0, 1, 0])

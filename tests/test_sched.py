"""The layered scheduler subsystem (core/sched/): policies, admission,
eviction matrix, and the concurrent worker-pool executor."""
import functools

import numpy as np
import pytest

from repro.core import (BufferStore, DAG, Executor, InvalidTransition,
                        NodeSpec, POLICIES, ProcessWorkerExecutor, RMConfig,
                        ResourceManager, SCHEDULES, Table,
                        WorkerPoolExecutor)
from repro.core import ops, zarquet
from repro.core.dag import CACHED, DONE, EVICTED, RUNNING, WAITING
from repro.core.sched.eviction import (AdaptiveEviction, EvictionPolicy,
                                       register_eviction)


def add_cols_op(tables, out_name="n0"):
    """Module-level (picklable) chain op for process-mode stress."""
    return ops.add_columns_compute(tables[0], "i0", "i1", out_name)


@pytest.fixture()
def source(tmp_path):
    path = str(tmp_path / "t.zq")
    t = zarquet.gen_int_table(4, 1 << 14, seed=7)
    zarquet.write_table(path, t)
    return path, t


def make_env(tmp_path, workers=1, tag="", **cfg):
    store = BufferStore(swap_dir=str(tmp_path / f"swap{tag}"),
                        root=cfg.get("cache_root"))
    rm = ResourceManager(store, RMConfig(**cfg))
    ex = Executor(store, rm, workers=workers)
    return store, rm, ex


def chain_dag(path, depth, name="c", est=1 << 16):
    nodes = [NodeSpec("load", source=path, est_mem=est)]
    prev = "load"
    for i in range(depth):
        def fn(ts, i=i):
            return ops.add_columns_compute(ts[0], "i0", "i1", f"n{i}")
        nodes.append(NodeSpec(f"add{i}", fn=fn, deps=[prev],
                              est_mem=est // 2))
        prev = f"add{i}"
    return DAG(nodes, name=name)


# --------------------------------------------------------------------------
# registries
# --------------------------------------------------------------------------

def test_registries_contain_builtin_policies():
    assert {"none", "kswap", "rollback", "limitdrop",
            "adaptive"} <= set(POLICIES)
    assert {"depth", "breadth", "fair", "deadline"} <= set(SCHEDULES)


def test_custom_eviction_policy_registers_and_runs(tmp_path, source):
    path, _ = source
    calls = []

    @register_eviction
    class CountingRollback(EvictionPolicy):
        name = "counting-rollback-test"

        def __init__(self, rm):
            super().__init__(rm)
            self._inner = POLICIES["rollback"](rm)

        def evict(self, st):
            calls.append((st.dag.name, st.name))
            return self._inner.evict(st)

    try:
        store, rm, ex = make_env(tmp_path, memory_limit=3 << 15,
                                 policy="counting-rollback-test")
        dags = [chain_dag(path, 4, f"c{i}") for i in range(3)]
        ex.run(dags)
        assert all(d.all_done() for d in dags)
        assert calls, "custom policy was never consulted"
        store.close()
    finally:
        del POLICIES["counting-rollback-test"]


# --------------------------------------------------------------------------
# eviction-policy matrix
# --------------------------------------------------------------------------

@pytest.mark.parametrize("policy,counter", [
    ("rollback", "rollback"),
    ("limitdrop", "limitdrop"),
    ("adaptive", None),           # adaptive picks either mechanism
])
def test_eviction_matrix_completes_and_counts(tmp_path, source, policy,
                                              counter):
    path, _ = source
    store, rm, ex = make_env(tmp_path, memory_limit=3 << 15, policy=policy)
    dags = [chain_dag(path, 4, f"c{i}") for i in range(3)]
    ex.run(dags)
    assert all(d.all_done() for d in dags)
    if counter is not None:
        assert rm.evictions[counter] > 0
    else:
        assert rm.evictions["rollback"] + rm.evictions["limitdrop"] > 0
    store.close()


class _FakeMsg:
    released = False


def _fake_done(dag, names):
    for n in names:
        st = dag.nodes[n]
        st.status = DONE
        st.output = _FakeMsg()
        st.output_bytes = 1
    return [dag.nodes[n] for n in names]


def test_victim_order_least_progressed_then_id_then_depth(tmp_path, source):
    """Victim order: least-progressed DAG first, dag id DESCENDING on
    ties, deepest output first within a DAG."""
    path, _ = source
    store, rm, _ = make_env(tmp_path, policy="rollback", decache=False)
    d1 = chain_dag(path, 3, "d1")       # 4 nodes
    d2 = chain_dag(path, 3, "d2")
    # d1: 3/4 done (more progressed); d2: 2/4 done
    rm.completed_nodes = (_fake_done(d1, ["load", "add0", "add1"])
                          + _fake_done(d2, ["load", "add0"]))
    order = [(st.dag.name, st.name) for st in rm.eviction.victims()]
    # d2 (least progressed) is evicted first, deepest first within it
    assert order == [("d2", "add0"), ("d2", "load"),
                     ("d1", "add1"), ("d1", "add0"), ("d1", "load")]
    store.close()


def test_victim_order_dag_id_descending_on_progress_tie(tmp_path, source):
    path, _ = source
    store, rm, _ = make_env(tmp_path, policy="rollback", decache=False)
    d1 = chain_dag(path, 2, "d1")
    d2 = chain_dag(path, 2, "d2")      # d2.id > d1.id
    rm.completed_nodes = (_fake_done(d1, ["load", "add0"])
                          + _fake_done(d2, ["load", "add0"]))
    order = [(st.dag.name, st.name) for st in rm.eviction.victims()]
    # equal progress: highest dag id first (needed latest by the scheduler)
    assert order == [("d2", "add0"), ("d2", "load"),
                     ("d1", "add0"), ("d1", "load")]
    store.close()


def test_victims_protect_chosen_nodes_deps(tmp_path, source):
    path, _ = source
    store, rm, _ = make_env(tmp_path, policy="rollback", decache=False)
    d = chain_dag(path, 2, "d")
    rm.completed_nodes = _fake_done(d, ["load", "add0"])
    protected = [(st.dag.name, st.name)
                 for st in rm.eviction.victims(protect=d.nodes["add1"])]
    assert ("d", "add0") not in protected      # add1 depends on add0
    assert ("d", "load") in protected
    store.close()


def test_adaptive_mechanism_selection_by_latency_ratio(tmp_path, source):
    """Adaptive picks limitdrop for slow-to-recompute outputs (latency /
    bytes above threshold) and rollback for cheap ones."""
    path, _ = source
    store, rm, _ = make_env(tmp_path, policy="adaptive", decache=False)
    assert isinstance(rm.eviction, AdaptiveEviction)
    picked = []
    rm.eviction._rollback.evict = lambda st: picked.append("rollback") or 1
    rm.eviction._limitdrop.evict = lambda st: picked.append("limitdrop") or 1
    d = chain_dag(path, 2, "d")
    (cheap, expensive) = _fake_done(d, ["add0", "add1"])
    cheap.exec_latency, cheap.output_bytes = 0.0, 1 << 20
    expensive.exec_latency, expensive.output_bytes = 10.0, 1
    rm.eviction.evict(cheap)
    rm.eviction.evict(expensive)
    assert picked == ["rollback", "limitdrop"]
    store.close()


def test_refcount_safe_gc_under_rollback(tmp_path, source):
    """Eviction operates on virtual artifacts: shared files survive until
    refcounts hit zero, and everything is freed after the DAGs finish."""
    path, _ = source
    store, rm, ex = make_env(tmp_path, memory_limit=3 << 15,
                             policy="rollback", decache=False)
    dags = [chain_dag(path, 4, f"c{i}") for i in range(3)]
    ex.run(dags)
    assert all(d.all_done() for d in dags)
    assert rm.evictions["rollback"] > 0
    for f in store.files.values():          # no deleted-but-referenced file
        assert not f.deleted
        assert f.refcount >= 0
    assert store.global_charged == 0        # all intermediates GC'd
    store.close()


def test_keep_output_never_evicted_in_grouped_run(tmp_path, source):
    """A finished DAG's keep_output message is promised to an external
    consumer: later DAGs in the same grouped run must not roll it back."""
    path, _ = source
    store, rm, ex = make_env(tmp_path, memory_limit=3 << 15,
                             policy="rollback", decache=False)
    dags = []
    for i in range(3):
        nodes = [NodeSpec("load", source=path, est_mem=1 << 16)]
        prev = "load"
        for j in range(3):
            def fn(ts, j=j):
                return ops.add_columns_compute(ts[0], "i0", "i1", f"n{j}")
            nodes.append(NodeSpec(f"add{j}", fn=fn, deps=[prev],
                                  est_mem=1 << 15,
                                  keep_output=(j == 2)))
            prev = f"add{j}"
        dags.append(DAG(nodes, name=f"k{i}"))
    ex.run(dags)
    assert all(d.all_done() for d in dags)
    for d in dags:
        msg = d.nodes["add2"].output
        assert msg is not None and not msg.released
        msg.release()
    store.close()


def test_completed_nodes_pruned_after_dag_finish(tmp_path, source):
    """keep_output nodes must not leak in rm.completed_nodes across runs."""
    path, _ = source
    store, rm, ex = make_env(tmp_path, decache=False)
    for i in range(4):
        d = DAG([NodeSpec("load", source=path, est_mem=1 << 16),
                 NodeSpec("sink", fn=lambda ts: ts[0], deps=["load"],
                          est_mem=1 << 12, keep_output=True)],
                name=f"p{i}")
        ex.run([d])
        d.nodes["sink"].output.release()
    assert rm.completed_nodes == []
    store.close()


def test_admission_reserves_inflight_estimates(tmp_path, source):
    """Claimed in-flight nodes hold their est_mem, so concurrent workers
    cannot co-admit past the budget."""
    path, _ = source
    store, rm, _ = make_env(tmp_path, memory_limit=100)
    d = chain_dag(path, 1, "r", est=60)
    node = d.nodes["load"]
    assert rm.admit(node)                  # 60 <= 100
    rm.admission.reserve(node)
    assert rm.available() == 40
    assert not rm.admit(node)              # a second 60 no longer fits
    assert rm.admit(d.nodes["add0"])       # but est 30 does
    rm.admission.reserve(node)
    assert rm.available() == -20
    assert not rm.admit(d.nodes["add0"])
    rm.admission.unreserve(node)
    rm.admission.unreserve(node)
    assert rm.available() == 100
    store.close()


# --------------------------------------------------------------------------
# worker-pool executor
# --------------------------------------------------------------------------

def _multi_source(tmp_path, n):
    paths = []
    for i in range(n):
        p = str(tmp_path / f"s{i}.zq")
        zarquet.write_table(p, zarquet.gen_int_table(4, 1 << 14, seed=i))
        paths.append(p)
    return paths


def _run_counts(tmp_path, tag, force_threads=False, **cfg):
    path = str(tmp_path / f"src{tag}.zq")
    zarquet.write_table(path, zarquet.gen_int_table(4, 1 << 14, seed=7))
    store = BufferStore(swap_dir=str(tmp_path / f"swap{tag}"))
    rm = ResourceManager(store, RMConfig(memory_limit=3 << 15,
                                         policy="rollback", **cfg))
    ex = WorkerPoolExecutor(store, rm, workers=1,
                            force_threads=force_threads)
    dags = [chain_dag(path, 4, f"c{i}") for i in range(3)]
    ex.run(dags)
    assert all(d.all_done() for d in dags)
    counts = (ex.node_runs, ex.load_runs, dict(rm.evictions))
    store.close()
    return counts


def test_workers1_pool_equals_sequential(tmp_path):
    """workers=1 through the thread pool reproduces the inline sequential
    semantics exactly: same node_runs, load_runs and eviction counts on a
    fixed eviction-heavy workload."""
    seq = _run_counts(tmp_path, "seq", force_threads=False)
    pool = _run_counts(tmp_path, "pool", force_threads=True)
    assert seq == pool


def test_workers1_deterministic_across_runs(tmp_path):
    assert _run_counts(tmp_path, "a") == _run_counts(tmp_path, "b")


@pytest.mark.parametrize("workers", [2, 4])
def test_concurrent_run_correct(tmp_path, workers):
    paths = _multi_source(tmp_path, 5)
    store, rm, ex = make_env(tmp_path, workers=workers, tag=f"w{workers}",
                             decache=False)
    expected = []
    dags = []
    for i, p in enumerate(paths):
        t = zarquet.read_table(p)
        expected.append(int(sum(int(c.values.sum())
                                for c in t.batches[0].columns)))
        dags.append(DAG([
            NodeSpec("load", source=p, est_mem=1 << 16),
            NodeSpec("sum", fn=lambda ts: Table.from_pydict(
                {"total": np.array([ops.sum_all_ints(ts[0])],
                                   dtype=np.int64)}),
                deps=["load"], est_mem=1 << 12, keep_output=True),
        ], name=f"d{i}"))
    ex.run(dags)
    assert all(d.all_done() for d in dags)
    assert ex.node_runs == 10 and ex.load_runs == 5
    from repro.core import SipcReader
    for d, want in zip(dags, expected):
        msg = d.nodes["sum"].output
        got = SipcReader(store).read_table(msg).to_pydict()["total"][0]
        assert int(got) == want
        msg.release()
    store.close()


def test_concurrent_decache_single_flight(tmp_path, source):
    """Workers racing on the same (source, dict_columns) key must not
    duplicate the load: the DeCache load is single-flight."""
    path, _ = source
    store, rm, ex = make_env(tmp_path, workers=4, decache=True)
    dags = [DAG([
        NodeSpec("load", source=path, est_mem=1 << 16),
        NodeSpec("sum", fn=lambda ts: Table.from_pydict(
            {"total": np.array([ops.sum_all_ints(ts[0])],
                               dtype=np.int64)}),
            deps=["load"], est_mem=1 << 12),
    ], name=f"d{i}") for i in range(6)]
    ex.run(dags)
    assert all(d.all_done() for d in dags)
    assert ex.load_runs == 1
    assert rm.decache.hits >= 5
    store.close()


def test_concurrent_eviction_workload(tmp_path):
    paths = _multi_source(tmp_path, 4)
    store, rm, ex = make_env(tmp_path, workers=2, tag="ev",
                             memory_limit=3 << 15, policy="adaptive")
    dags = [chain_dag(p, 4, f"c{i}") for i, p in enumerate(paths)]
    ex.run(dags)
    assert all(d.all_done() for d in dags)
    assert sum(rm.evictions.values()) > 0
    store.close()


# --------------------------------------------------------------------------
# concurrency stress (the -m stress lane; see pytest.ini)
# --------------------------------------------------------------------------

def _drain_and_check_accounting(store, rm):
    """At drain: uncache everything, then every byte is uncharged, no
    deleted-but-referenced file, no negative refcount."""
    for f in store.files.values():
        assert not f.deleted
        assert f.refcount >= 0
    for e in list(rm.decache.uncache_candidates()):
        rm.decache.uncache(e)
    for fid in list(store.files):     # GC released keep_output files
        f = store.files[fid]
        if f.refcount == 0 and not f.decache_pinned:
            store.delete_file(fid)
    assert store.global_charged == 0, \
        f"accounting leak: {store.global_charged} bytes still charged"


@pytest.mark.stress
def test_stress_threads_decache_eviction(tmp_path):
    """N threads hammering DeCache + eviction under a tight budget: no
    double-execute (single-flight holds), no use-after-evict (any such
    read raises), accounting sums to zero at drain."""
    paths = _multi_source(tmp_path, 4)
    store, rm, ex = make_env(tmp_path, workers=4, tag="st",
                             memory_limit=3 << 15, policy="adaptive",
                             decache=True)
    dags = [chain_dag(paths[i % 4], 4, f"s{i}") for i in range(12)]
    ex.run(dags)
    assert all(d.all_done() for d in dags)
    assert sum(rm.evictions.values()) > 0      # the budget really bit
    # single-flight: any load beyond one per source must be explained by
    # an uncache eviction — nothing else may duplicate a load
    assert ex.load_runs <= 4 + rm.evictions["uncache"]
    _drain_and_check_accounting(store, rm)
    store.close()


@pytest.mark.stress
def test_stress_process_workers_durable_cache(tmp_path):
    """Scheduler threads x M worker processes x a durable manifest under
    a tight budget: publishes, adoptions, spills, and single-flight all
    race.  Round 2 re-runs the same DAGs warm and must come mostly from
    the cache."""
    paths = _multi_source(tmp_path, 3)
    root = str(tmp_path / "cache")

    def build_dags():
        dags = []
        for i, p in enumerate(paths):
            nodes = [NodeSpec("load", source=p, est_mem=1 << 16)]
            prev = "load"
            for j in range(3):
                nodes.append(NodeSpec(
                    f"add{j}",
                    fn=functools.partial(add_cols_op, out_name=f"n{j}"),
                    deps=[prev], est_mem=1 << 15,
                    keep_output=(j == 2)))
                prev = f"add{j}"
            dags.append(DAG(nodes, name=f"p{i}"))
        return dags

    hits = []
    for rnd in range(2):
        store = BufferStore(backing="file", root=root,
                            swap_dir=str(tmp_path / f"swp{rnd}"))
        rm = ResourceManager(store, RMConfig(
            memory_limit=3 << 15, policy="adaptive", workers=3,
            workers_mode="process", cache_root=root))
        ex = ProcessWorkerExecutor(store, rm, workers=3)
        dags = build_dags()
        ex.run(dags)
        assert all(d.all_done() for d in dags)
        assert ex.fallback_inline == 0         # everything crossed the hop
        hits.append(ex.cache_hits)
        for d in dags:
            msg = d.nodes["add2"].output
            assert msg is not None and not msg.released
            msg.release()
        _drain_and_check_accounting(store, rm)
        ex.close()
        store.close()
    assert hits[0] == 0                        # cold: everything executed
    assert hits[1] >= 3                        # warm: sinks adopted


@pytest.mark.stress
def test_stress_cached_outputs_spill_not_discard(tmp_path):
    """Under pressure, durable (published/adopted) outputs are spilled —
    mappings dropped, bytes kept in the content-addressed objects — never
    rolled back to a recompute, and reads after the spill still see the
    right data (remapped, not recomputed)."""
    paths = _multi_source(tmp_path, 3)
    root = str(tmp_path / "cache")

    def warm_dags(tag, with_tail):
        dags = []
        for i, p in enumerate(paths):
            d = chain_dag(p, 3, f"{tag}{i}")
            specs = [st.spec for st in d.nodes.values()]
            if with_tail:
                # an op the manifest has never seen: forces execution on
                # top of the adopted chain outputs
                specs.append(NodeSpec(
                    "tail", fn=lambda ts: ops.slice_rows(ts[0], 0, 8),
                    deps=["add2"], est_mem=1 << 15, keep_output=True))
            dags.append(DAG(specs, name=f"{tag}{i}"))
        return dags

    # cold run, roomy budget: publish everything
    store, rm, ex = make_env(tmp_path, tag="cold", cache_root=root)
    dags = warm_dags("c", with_tail=False)
    ex.run(dags)
    assert all(d.all_done() for d in dags)
    store.close()

    # warm run, tight budget: every chain is adopted (its tail executes),
    # and admission pressure must spill other DAGs' adopted outputs
    store2 = BufferStore(backing="file", root=root,
                         swap_dir=str(tmp_path / "swp2"))
    rm2 = ResourceManager(store2, RMConfig(memory_limit=3 << 15,
                                           policy="adaptive",
                                           cache_root=root))
    ex2 = Executor(store2, rm2)
    dags2 = warm_dags("w", with_tail=True)
    ex2.run(dags2)
    assert all(d.all_done() for d in dags2)
    assert ex2.cache_hits > 0
    assert rm2.evictions["spill"] > 0
    assert rm2.evictions["rollback"] == 0      # durable: spill, not discard
    for d in dags2:
        msg = d.nodes["tail"].output
        assert msg is not None and not msg.released
        from repro.core import SipcReader
        assert SipcReader(store2).read_table(msg).num_rows == 8
        msg.release()
    _drain_and_check_accounting(store2, rm2)
    store2.close()


# --------------------------------------------------------------------------
# schedule policies
# --------------------------------------------------------------------------

def _traced_chain(path, depth, name, trace):
    nodes = [NodeSpec("load", source=path, est_mem=1 << 16)]
    prev = "load"
    for i in range(depth):
        def fn(ts, i=i, name=name):
            trace.append((name, f"add{i}"))
            return ops.add_columns_compute(ts[0], "i0", "i1", f"n{i}")
        nodes.append(NodeSpec(f"add{i}", fn=fn, deps=[prev],
                              est_mem=1 << 15))
        prev = f"add{i}"
    return DAG(nodes, name=name)


def test_depth_first_finishes_dag_before_starting_next(tmp_path):
    paths = _multi_source(tmp_path, 2)
    store, rm, ex = make_env(tmp_path, tag="df", schedule="depth",
                             decache=False)
    trace = []
    dags = [_traced_chain(p, 3, f"d{i}", trace)
            for i, p in enumerate(paths)]
    ex.run(dags)
    # all of d0's compute nodes run before any of d1's
    assert trace == [("d0", f"add{i}") for i in range(3)] + \
                    [("d1", f"add{i}") for i in range(3)]
    store.close()


def test_breadth_first_interleaves_dags(tmp_path):
    paths = _multi_source(tmp_path, 2)
    store, rm, ex = make_env(tmp_path, tag="bf", schedule="breadth",
                             decache=False)
    trace = []
    dags = [_traced_chain(p, 3, f"d{i}", trace)
            for i, p in enumerate(paths)]
    ex.run(dags)
    # shallowest-first alternates between the two DAGs level by level
    assert trace == [("d0", "add0"), ("d1", "add0"),
                     ("d0", "add1"), ("d1", "add1"),
                     ("d0", "add2"), ("d1", "add2")]
    store.close()


def test_fair_share_round_robins_tenants(tmp_path):
    paths = _multi_source(tmp_path, 2)
    store, rm, ex = make_env(tmp_path, tag="fs", schedule="fair",
                             decache=False)
    trace = []
    dags = [_traced_chain(p, 3, f"d{i}", trace)
            for i, p in enumerate(paths)]
    ex.run(dags)
    # no tenant ever gets more than one completed node ahead
    counts = {"d0": 0, "d1": 0}
    for name, _ in trace:
        counts[name] += 1
        assert abs(counts["d0"] - counts["d1"]) <= 1
    store.close()


def test_deadline_aware_prioritizes_urgent_dag(tmp_path):
    paths = _multi_source(tmp_path, 2)
    store, rm, ex = make_env(tmp_path, tag="dl", schedule="deadline",
                             decache=False)
    trace = []
    d0 = _traced_chain(paths[0], 3, "d0", trace)       # no deadline
    d1 = _traced_chain(paths[1], 3, "d1", trace)
    d1.deadline = 1.0                                  # urgent
    ex.run([d0, d1])
    # d1 (earlier deadline) completes before d0 starts computing
    assert trace[:3] == [("d1", f"add{i}") for i in range(3)]
    store.close()


# --------------------------------------------------------------------------
# node lifecycle state machine
# --------------------------------------------------------------------------

def test_node_state_machine_rejects_illegal_transitions(tmp_path, source):
    path, _ = source
    d = chain_dag(path, 1, "sm")
    st = d.nodes["load"]
    assert st.status == WAITING
    with pytest.raises(InvalidTransition):
        st.transition(DONE)             # WAITING -> DONE skips RUNNING
    st.claim()
    assert st.status == RUNNING
    with pytest.raises(InvalidTransition):
        st.transition(EVICTED)          # only DONE outputs can be evicted
    st.transition(DONE)
    st.transition(EVICTED)
    st.claim()                          # re-execution after rollback
    assert st.status == RUNNING


def test_zarquet_codec_recorded_in_footer(tmp_path):
    path = str(tmp_path / "codec.zq")
    zarquet.write_table(path, zarquet.gen_int_table(2, 1 << 10))
    meta = zarquet.read_footer(path)
    assert meta["codec"] == zarquet.DEFAULT_CODEC
    assert zarquet.read_table(path).num_rows > 0

"""Property suite: every vectorized kernel is bit-identical to a naive
per-row reference on seeded random tables — mixed string lengths, null
runs, empty strings, non-ASCII ('ß' -> 'SS' changes byte length),
zero-row columns and dict columns."""
import os
import tempfile

import numpy as np
import pytest

from repro.core import arrow as A
from repro.core import ops, vkernels as vk, zarquet

MIXED = ["", "a", "zz", "abc", "ß", "日本", "Σσ", "a\x00b", "same", "same",
         "longer-string-row", "é"]


def rand_strings(rng, n, *, fixed_len=None, ascii_only=False):
    """Random strings with empty runs, repeats and non-ASCII chars."""
    alpha = list("abcxyz") if ascii_only else list("abcxyzß日éΣσ\x00 ")
    out = []
    for _ in range(n):
        if fixed_len is None:
            ln = int(rng.integers(0, 9))
            if rng.random() < 0.15:
                ln = 0                       # empty-string runs
        else:
            ln = fixed_len
        out.append("".join(rng.choice(alpha, size=ln)))
    return out


def utf8_col(rng, strs, null_frac=0.0):
    validity = None
    if null_frac > 0 and strs:
        mask = rng.random(len(strs)) >= null_frac
        validity = A.pack_validity(mask)
    return A.Column.from_strings(strs, validity=validity)


# -- naive per-row references (the loops the kernels replaced) --------------

def ref_dict_encode(strs_b):
    uniq = sorted(set(strs_b))
    index = {s: i for i, s in enumerate(uniq)}
    return [index[s] for s in strs_b], uniq


def ref_upper(strs):
    return [s.upper() for s in strs]


def ref_sort_order(strs_b):
    return np.argsort(np.array(strs_b, dtype=object), kind="stable")


def col_rows(col):
    """Per-row bytes via the naive accessor (the reference reader)."""
    return [col.get_bytes(i) for i in range(col.length)]


# ---------------------------------------------------------------------------
# gather / take
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", range(4))
def test_take_var_matches_per_row(seed):
    rng = np.random.default_rng(seed)
    strs = [s.encode() for s in rand_strings(rng, 60)]
    c = A.Column.from_strings(strs)
    idx = rng.integers(0, len(strs), size=40)
    new_off, out = vk.take_var(c.offsets, c.values, idx)
    got = [bytes(out[new_off[i]:new_off[i + 1]]) for i in range(len(idx))]
    assert got == [strs[i] for i in idx]


def test_take_var_zero_rows_and_empty():
    c = A.Column.from_strings([])
    new_off, out = vk.take_var(c.offsets, c.values, np.empty(0, np.int64))
    assert list(new_off) == [0] and out.size == 0
    c = A.Column.from_strings(["", "", ""])
    new_off, out = vk.take_var(c.offsets, c.values, np.array([2, 0]))
    assert list(new_off) == [0, 0, 0] and out.size == 0


def test_take_var_on_slice_offsets():
    """Non-zero-based offsets (row-slice views) gather correctly."""
    c = A.Column.from_strings([b"aa", b"bbb", b"c", b"dd"]).slice(1, 4)
    new_off, out = vk.take_var(c.offsets, c.values, np.array([2, 0]))
    assert [bytes(out[new_off[i]:new_off[i + 1]]) for i in range(2)] == \
        [b"dd", b"bbb"]


# ---------------------------------------------------------------------------
# dictionary encode
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", range(4))
@pytest.mark.parametrize("fixed_len", [None, 5])
def test_dict_encode_var_matches_reference(seed, fixed_len):
    rng = np.random.default_rng(seed)
    strs = [s.encode()
            for s in rand_strings(rng, 80, fixed_len=fixed_len)]
    c = A.Column.from_strings(strs)
    codes, uoff, uvals = vk.dict_encode_var(c.offsets, c.values)
    ref_codes, ref_uniq = ref_dict_encode(strs)
    got_uniq = [bytes(uvals[uoff[i]:uoff[i + 1]])
                for i in range(len(uoff) - 1)]
    assert got_uniq == ref_uniq
    assert codes.dtype == np.int32 and list(codes) == ref_codes


def test_dict_encode_var_trailing_nul_distinct():
    """Unlike numpy 'S'-dtype keys, trailing NUL bytes are significant."""
    strs = [b"ab", b"ab\x00", b"ab", b"ab\x00\x00"]
    c = A.Column.from_strings(strs)
    codes, uoff, uvals = vk.dict_encode_var(c.offsets, c.values)
    assert len(uoff) - 1 == 3
    assert list(codes) == [0, 1, 0, 2]


def test_dict_encode_var_edges():
    # zero rows
    c = A.Column.from_strings([])
    codes, uoff, uvals = vk.dict_encode_var(c.offsets, c.values)
    assert codes.size == 0 and list(uoff) == [0] and uvals.size == 0
    # all-empty rows: one dictionary entry
    c = A.Column.from_strings(["", "", ""])
    codes, uoff, uvals = vk.dict_encode_var(c.offsets, c.values)
    assert list(codes) == [0, 0, 0] and list(uoff) == [0, 0]


@pytest.mark.parametrize("seed", range(2))
def test_ops_dict_encode_table(seed):
    rng = np.random.default_rng(seed)
    strs = rand_strings(rng, 50)
    t = A.Table.from_pydict({"s": strs, "i": np.arange(50)})
    enc = ops.dict_encode(t, ["s"])
    c = enc.batches[0].column("s")
    assert c.type.is_dict
    assert col_rows(c.decode_dictionary()) == [s.encode() for s in strs]
    # dict column with nulls: codes computed for every slot, validity rides
    col = utf8_col(rng, strs, null_frac=0.3)
    t2 = A.Table.from_batch(A.Schema([A.Field("s", col.type)]), [col])
    c2 = ops.dict_encode(t2, ["s"]).batches[0].column("s")
    assert np.array_equal(c2.valid_mask(), col.valid_mask())
    assert col_rows(c2.decode_dictionary()) == [s.encode() for s in strs]


def test_dict_encode_skew_fallback_identical(monkeypatch):
    """Length-skewed columns take the per-row fallback (no padded-matrix
    blowup) with bit-identical results."""
    rng = np.random.default_rng(5)
    strs = [s.encode() for s in rand_strings(rng, 60)] + [b"x" * 500]
    c = A.Column.from_strings(strs)
    want = vk.dict_encode_var(c.offsets, c.values)
    want_order = vk.sort_order_var(c.offsets, c.values)
    monkeypatch.setattr(vk, "_SKEW_FLOOR", 0)
    monkeypatch.setattr(vk, "_SKEW_RATIO", 1)
    assert vk._skewed(len(strs), c.offsets[1:] - c.offsets[:-1])
    codes, uoff, uvals = vk.dict_encode_var(c.offsets, c.values)
    assert np.array_equal(codes, want[0]) and codes.dtype == np.int32
    assert np.array_equal(uoff, want[1])
    assert np.array_equal(uvals, want[2])
    assert list(vk.sort_order_var(c.offsets, c.values)) == list(want_order)


# ---------------------------------------------------------------------------
# sort keys
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", range(4))
def test_sort_keys_var_stable_order(seed):
    rng = np.random.default_rng(seed)
    strs = [s.encode() for s in rand_strings(rng, 120)]
    c = A.Column.from_strings(strs)
    keys = vk.sort_keys_var(c.offsets, c.values)
    order = np.argsort(keys, kind="stable")
    assert list(order) == list(ref_sort_order(strs))
    # the direct permutation kernel agrees with argsort-over-ranks
    assert list(vk.sort_order_var(c.offsets, c.values)) == list(order)


@pytest.mark.parametrize("fixed_len", [None, 6])
def test_sort_order_var_edges(fixed_len):
    rng = np.random.default_rng(9)
    strs = [s.encode() for s in rand_strings(rng, 40, fixed_len=fixed_len)]
    c = A.Column.from_strings(strs)
    order = vk.sort_order_var(c.offsets, c.values)
    assert list(order) == list(ref_sort_order(strs))
    c0 = A.Column.from_strings([])
    assert list(vk.sort_order_var(c0.offsets, c0.values)) == []
    ce = A.Column.from_strings(["", "", ""])
    assert list(vk.sort_order_var(ce.offsets, ce.values)) == [0, 1, 2]


@pytest.mark.parametrize("col_kind", ["utf8", "dict"])
def test_ops_sort_by(col_kind):
    rng = np.random.default_rng(7)
    strs = rand_strings(rng, 60)
    t = A.Table.from_pydict({"s": strs, "i": np.arange(60)})
    if col_kind == "dict":
        t = ops.dict_encode(t, ["s"])
    got = ops.sort_by(t, "s")
    want_i = sorted(range(60), key=lambda i: (strs[i].encode(), i))
    assert got.to_pydict()["i"] == want_i
    gd = ops.sort_by(t, "s", descending=True).to_pydict()["i"]
    assert gd == want_i[::-1]


# ---------------------------------------------------------------------------
# upper
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", range(4))
def test_upper_var_matches_per_row(seed):
    rng = np.random.default_rng(seed)
    strs = rand_strings(rng, 60).__add__(MIXED)
    strs = [s.replace("\x00", "n") for s in strs]    # valid printable rows
    c = A.Column.from_strings(strs)
    new_off, out = vk.upper_var(c.offsets, c.values)
    got = [bytes(out[new_off[i]:new_off[i + 1]]).decode()
           for i in range(len(strs))]
    assert got == ref_upper(strs)


def test_upper_var_eszett_changes_lengths():
    c = A.Column.from_strings(["straße", "ß", "", "aß"])
    new_off, out = vk.upper_var(c.offsets, c.values)
    got = [bytes(out[new_off[i]:new_off[i + 1]]).decode() for i in range(4)]
    assert got == ["STRASSE", "SS", "", "ASS"]


def test_upper_var_slice_offsets_and_zero_rows():
    c = A.Column.from_strings(["xx", "mixedß", "yy"]).slice(1, 2)
    new_off, out = vk.upper_var(c.offsets, c.values)
    assert new_off[0] == 0
    assert bytes(out[new_off[0]:new_off[1]]).decode() == "MIXEDSS"
    c0 = A.Column.from_strings([])
    new_off, out = vk.upper_var(c0.offsets, c0.values)
    assert list(new_off) == [0] and out.size == 0


def test_ops_upper_table_non_ascii_with_nulls():
    rng = np.random.default_rng(3)
    strs = ["straße", "ok", "", "Σσ", "ßß"]
    col = utf8_col(rng, strs, null_frac=0.0)
    mask = np.array([True, False, True, True, True])
    col = A.Column.from_strings(strs, validity=A.pack_validity(mask))
    t = A.Table.from_batch(A.Schema([A.Field("s", col.type)]), [col])
    up = ops.upper(t, "s").batches[0].column("s")
    assert np.array_equal(up.valid_mask(), mask)
    assert col_rows(up) == [s.upper().encode() for s in strs]


# ---------------------------------------------------------------------------
# decode_dictionary / equals
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", range(3))
def test_decode_dictionary_matches_per_row(seed):
    rng = np.random.default_rng(seed)
    uniq = sorted({s.encode() for s in rand_strings(rng, 30)})
    dic = A.Column.from_strings(uniq)
    codes = rng.integers(0, len(uniq), size=70).astype(np.int32)
    mask = rng.random(70) >= 0.2
    col = A.Column.dictionary_encoded(codes, dic,
                                      validity=A.pack_validity(mask))
    dec = col.decode_dictionary()
    assert col_rows(dec) == [uniq[c] for c in codes]
    assert np.array_equal(dec.valid_mask(), mask)


@pytest.mark.parametrize("seed", range(3))
def test_equals_utf8_vectorized(seed):
    rng = np.random.default_rng(seed)
    strs = rand_strings(rng, 50)
    a = utf8_col(rng, strs, null_frac=0.25)
    b = A.Column.from_strings(strs, validity=a.validity)
    assert a.equals(b)
    # null slots may hold different bytes and still compare equal
    mask = a.valid_mask()
    if not mask.all():
        j = int(np.nonzero(~mask)[0][0])
        strs2 = list(strs)
        strs2[j] = strs2[j] + "DIFFERENT"
        assert a.equals(A.Column.from_strings(strs2, validity=a.validity))
    # a single valid-row difference is detected
    j = int(np.nonzero(mask)[0][0])
    strs3 = list(strs)
    strs3[j] = strs3[j] + "x"
    assert not a.equals(A.Column.from_strings(strs3, validity=a.validity))


def test_equals_dict_vs_plain_utf8():
    """A dict column logically equals the plain column it encodes."""
    strs = ["b", "a", "b", "", "a"]
    t = ops.dict_encode(A.Table.from_pydict({"s": strs}), ["s"])
    dc = t.batches[0].column("s")
    pc = A.Column.from_strings(strs)
    assert dc.equals(pc) and pc.equals(dc)


# ---------------------------------------------------------------------------
# zarquet parallel copy-free decode
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("threads", [1, 4])
def test_zarquet_parallel_decode_identical(tmp_path, threads):
    rng = np.random.default_rng(11)
    strs = rand_strings(rng, 300)
    t = A.Table.from_pydict({
        "s0": strs,
        "s1": rand_strings(rng, 300, fixed_len=7),
        "i": rng.integers(0, 1 << 30, 300)})
    p = os.path.join(tmp_path, "t.zq")
    zarquet.write_table(p, t)
    r = zarquet.read_table(p, dict_columns=("s1",), reader_threads=threads)
    want = t.combine().batches[0]
    for name in ("s0", "s1", "i"):
        c = r.batches[0].column(name)
        if c.type.is_dict:
            assert name == "s1"
            c = c.decode_dictionary()
        assert c.equals(want.column(name))


def test_zarquet_decode_respects_allocator_contract(tmp_path):
    """Buffers land in allocator-provided memory; on_buffer sees each
    fresh buffer exactly once (decompress-into, no replacement array)."""
    t = zarquet.gen_str_table(2, 1 << 14, str_len=8, repeats=2)
    p = os.path.join(tmp_path, "t.zq")
    zarquet.write_table(p, t)
    handed, seen = [], []

    def allocator(n):
        a = np.zeros(n, dtype=np.uint8)
        handed.append(a)
        return a

    def on_buffer(a):
        seen.append(a)

    r = zarquet.read_table(p, allocator=allocator, on_buffer=on_buffer,
                           reader_threads=4)
    assert len(seen) == len(handed)
    for h, s in zip(handed, seen):
        assert s.base is h or s is h    # the very memory we allocated
    assert r.equals(t)


def test_zarquet_zstd_decomp_into(tmp_path):
    """zstd copy-free branch (runs in CI, where zstandard is installed):
    roundtrip through _decomp_into plus wrong-size detection."""
    zstandard = pytest.importorskip("zstandard")
    rng = np.random.default_rng(2)
    t = A.Table.from_pydict({
        "s": rand_strings(rng, 400), "i": rng.integers(0, 1 << 30, 400)})
    p = os.path.join(tmp_path, "t.zq")
    zarquet.write_table(p, t, codec="zstd")
    assert zarquet.read_footer(p)["codec"] == "zstd"
    r = zarquet.read_table(p, reader_threads=2)
    assert r.equals(t)
    blob = zstandard.ZstdCompressor().compress(b"x" * 4096)
    with pytest.raises(ValueError):
        zarquet._decomp_into(blob, np.empty(2048, np.uint8), "zstd")
    with pytest.raises(ValueError):
        zarquet._decomp_into(blob, np.empty(8192, np.uint8), "zstd")


def test_zarquet_truncated_buffer_detected(tmp_path):
    t = A.Table.from_pydict({"i": np.arange(1000, dtype=np.int64)})
    p = os.path.join(tmp_path, "t.zq")
    zarquet.write_table(p, t)
    meta = zarquet.read_footer(p)
    bm = meta["columns"][0]["buffers"][0]
    with open(p, "rb") as fh:
        raw = bytearray(fh.read())
    # corrupt the recorded uncompressed length: decode must notice
    blob = bytes(raw[bm["off"]:bm["off"] + bm["clen"]])
    dest = np.empty(bm["rlen"] // 2, dtype=np.uint8)
    with pytest.raises(ValueError):
        zarquet._decomp_into(blob, dest, meta["codec"])

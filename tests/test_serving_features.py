"""Serving-side features: int8 KV cache correctness, serve rules,
FSDP rules mapping."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, smoke_variant
from repro.models.api import ModelAPI
from repro.models.attention import dequant_kv, quant_kv


def test_quant_dequant_error_bound():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(2, 16, 4, 32)) * 3, jnp.float32)
    q, s = quant_kv(x)
    assert q.dtype == jnp.int8
    back = dequant_kv(q, s, jnp.float32)
    err = np.max(np.abs(np.asarray(back - x)))
    assert err <= np.max(np.abs(np.asarray(x))) / 127.0 + 1e-6


def test_int8_cache_decode_matches_prefill():
    cfg = dataclasses.replace(smoke_variant(ARCHS["granite-8b"]),
                              kv_cache_dtype="int8")
    api = ModelAPI(cfg)
    params = api.model.init(jax.random.key(5))
    rng = np.random.default_rng(1)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (2, 8)), jnp.int32)
    x = api.model.embed_inputs(params, toks)
    h, _, _ = api.model.backbone(params, x, "train", None,
                                 jnp.arange(8)[None, :])
    full = api.model.head(params, h)
    logits, caches = api.model.prefill(params, {"tokens": toks[:, :4]},
                                       cache_len=8)
    scale = float(jnp.max(jnp.abs(full)))
    assert float(jnp.max(jnp.abs(logits[:, 0] - full[:, 3]))) < 0.05 * scale
    for t in range(4, 7):
        logits, caches = api.model.decode_step(
            params, toks[:, t:t + 1], caches,
            jnp.full((2, 1), t, jnp.int32))
        assert float(jnp.max(jnp.abs(logits[:, 0] - full[:, t]))) \
            < 0.05 * scale


def test_int8_cache_axes_match_specs():
    cfg = dataclasses.replace(smoke_variant(ARCHS["granite-8b"]),
                              kv_cache_dtype="int8")
    api = ModelAPI(cfg)
    spec = jax.eval_shape(lambda: api.model.init_cache(2, 16))
    axes = api.cache_axes()
    flat_s = jax.tree.leaves(spec)
    from repro.sharding.partition import _is_axes_leaf
    flat_a = jax.tree.flatten(axes, is_leaf=_is_axes_leaf)[0]
    assert len(flat_s) == len(flat_a)


def test_rules_mapping_divisibility():
    """FSDP/serve rule sets yield valid specs for awkward shapes."""
    import os
    from repro.compat import make_mesh
    from repro.sharding.partition import (DEFAULT_RULES, FSDP_RULES,
                                          SERVE_RULES, logical_to_spec)
    mesh = make_mesh((1, 1), ("data", "model"))
    for rules in (DEFAULT_RULES, FSDP_RULES, SERVE_RULES):
        spec = logical_to_spec(("fsdp", "heads", None), mesh, rules,
                               shape=(576, 9, 64))
        assert spec is not None
    # 9 heads can't shard over a 16-wide axis: dropped
    spec = logical_to_spec(("fsdp", "heads"), mesh, DEFAULT_RULES,
                           shape=(576, 9))
    assert "model" not in str(spec.sharding_tuple) if hasattr(
        spec, "sharding_tuple") else True

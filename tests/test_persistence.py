"""Crash consistency of the persistent content-addressed store.

A writer subprocess publishes node outputs and is SIGKILLed mid-publish at
injected fault points (``ZERROW_CRASH=<point>:<n>``, see
``core/manifest.CRASH_POINTS``).  Invariant: ``BufferStore.reopen``
recovers *exactly* the journaled complete outputs —

  * every publish the writer acknowledged is present and decodes to the
    exact bytes it published (the journal fsync is the commit point);
  * at most the in-flight publish may additionally survive (crash after
    the journal write), and if it does it too must decode exactly;
  * a torn tail record is discarded, never surfaced as an entry, and the
    log stays appendable afterwards.

The default lane kills once per fault point; the ``stress`` lane repeats
each point at several publish indices (>= 20 kill iterations).
"""

import os
import signal
import subprocess
import sys

import numpy as np
import pytest

from repro.core import (BufferStore, KernelZero, Manifest, RMConfig,
                        ResourceManager, Sandbox, SipcReader, Table)
from repro.core.manifest import CRASH_POINTS

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def expected_table(i: int) -> Table:
    rng = np.random.default_rng(1000 + i)
    return Table.from_pydict({
        "a": rng.integers(0, 1 << 40, size=300).astype(np.int64),
        "s": [f"row-{i}-{j}" for j in range(300)],
    })


_WRITER = r"""
import os, sys
import numpy as np
sys.path.insert(0, {src!r})
from repro.core import BufferStore, KernelZero, Sandbox, Table

root, n_pub = sys.argv[1], int(sys.argv[2])

def expected_table(i):
    rng = np.random.default_rng(1000 + i)
    return Table.from_pydict({{
        "a": rng.integers(0, 1 << 40, size=300).astype(np.int64),
        "s": [f"row-{{i}}-{{j}}" for j in range(300)],
    }})

store = BufferStore(backing="file", root=root)
kz = KernelZero(store)
for i in range(n_pub):
    sb = Sandbox(store, kz, f"w{{i}}", mode="zero")
    msg = sb.write_output(expected_table(i), label=f"t{{i}}")
    print(f"PUBLISHING fp{{i:04d}}", flush=True)
    store.publish(f"fp{{i:04d}}", msg, label=f"t{{i}}")
    print(f"PUBLISHED fp{{i:04d}}", flush=True)
store.close()
print("DONE", flush=True)
"""


def _run_writer(root, n_pub=4, crash=None, timeout=120):
    env = dict(os.environ,
               PYTHONPATH=SRC + os.pathsep + os.environ.get("PYTHONPATH", ""))
    if crash is not None:
        env["ZERROW_CRASH"] = crash
    else:
        env.pop("ZERROW_CRASH", None)
    out = subprocess.run(
        [sys.executable, "-c", _WRITER.format(src=os.path.abspath(SRC)),
         str(root), str(n_pub)],
        capture_output=True, text=True, timeout=timeout, env=env)
    acked = [line.split()[1] for line in out.stdout.splitlines()
             if line.startswith("PUBLISHED ")]
    inflight = [line.split()[1] for line in out.stdout.splitlines()
                if line.startswith("PUBLISHING ")]
    inflight = [fp for fp in inflight if fp not in acked]
    return out, acked, inflight


def _verify_recovered(root, acked, inflight):
    """Reopen and check the crash-consistency contract."""
    store = BufferStore.reopen(str(root))
    try:
        man = store.manifest
        assert man.dropped_torn in (0, 1)
        recovered = set(man.entries)
        assert set(acked) <= recovered, \
            f"acknowledged publish lost: {set(acked) - recovered}"
        assert recovered <= set(acked) | set(inflight), \
            f"phantom entries: {recovered - set(acked) - set(inflight)}"
        for fp in sorted(recovered):
            i = int(fp[2:])
            msg = man.decode(fp, store, label=fp)
            assert msg is not None, f"journaled entry {fp} not decodable"
            got = SipcReader(store).read_table(msg)
            assert got.equals(expected_table(i)), f"{fp}: content mismatch"
        assert store.copied_bytes == 0     # recovery remaps, never copies
        return recovered
    finally:
        store.close()


# ---------------------------------------------------------------------------
# clean path
# ---------------------------------------------------------------------------

def test_publish_reopen_roundtrip(tmp_path):
    root = tmp_path / "cache"
    out, acked, _ = _run_writer(root, n_pub=3)
    assert out.returncode == 0, out.stderr[-2000:]
    assert len(acked) == 3
    recovered = _verify_recovered(root, acked, [])
    assert recovered == set(acked)


def test_publish_is_idempotent_and_content_addressed(tmp_path):
    root = str(tmp_path / "cache")
    store = BufferStore(backing="file", root=root)
    kz = KernelZero(store)
    sb = Sandbox(store, kz, "w", mode="zero")
    msg = sb.write_output(expected_table(0), label="t")
    e1 = store.publish("fp0", msg)
    e2 = store.publish("fp0", msg)          # second publish: no-op
    assert e1 is e2
    assert store.manifest.published == 1
    # identical content published under a second fingerprint dedupes the
    # object files (content addressing): no new objects appear
    objs = set(os.listdir(store.manifest.objects_dir))
    sb2 = Sandbox(store, kz, "w2", mode="zero")
    msg2 = sb2.write_output(expected_table(0), label="t2")
    store.publish("fp1", msg2)
    assert set(os.listdir(store.manifest.objects_dir)) == objs
    store.close()


def test_reopen_drops_entry_with_missing_object(tmp_path):
    root = tmp_path / "cache"
    out, acked, _ = _run_writer(root, n_pub=2)
    assert out.returncode == 0, out.stderr[-2000:]
    man = Manifest(str(root))
    victim = sorted(man.entries)[0]
    from repro.core import frame_refs
    path = man.resolve(frame_refs(man.entries[victim].frame)[0][0])
    man.close()
    os.unlink(path)
    store = BufferStore.reopen(str(root))
    assert victim not in store.manifest.entries
    assert store.manifest.dropped_incomplete >= 1
    # the other entry is unaffected
    survivor = [fp for fp in acked if fp != victim]
    for fp in survivor:
        assert fp in store.manifest.entries
    store.close()


def test_torn_tail_is_truncated_and_log_stays_appendable(tmp_path):
    root = str(tmp_path / "cache")
    out, acked, _ = _run_writer(root, n_pub=2)
    assert out.returncode == 0, out.stderr[-2000:]
    log = os.path.join(root, "MANIFEST.log")
    good = os.path.getsize(log)
    with open(log, "ab") as fh:
        fh.write(b"ZMF1\xff\xff")            # torn tail garbage
    store = BufferStore(backing="file", root=root)   # writer-mode reopen
    assert store.manifest.dropped_torn == 1
    assert set(store.manifest.entries) == set(acked)
    assert os.path.getsize(log) == good      # tail truncated
    kz = KernelZero(store)
    sb = Sandbox(store, kz, "w", mode="zero")
    store.publish("fp9999", sb.write_output(expected_table(9), label="t"))
    store.close()
    store2 = BufferStore.reopen(root)        # append after recovery parses
    assert set(store2.manifest.entries) == set(acked) | {"fp9999"}
    assert store2.manifest.dropped_torn == 0
    store2.close()


# ---------------------------------------------------------------------------
# the kill matrix
# ---------------------------------------------------------------------------

def _kill_matrix(tmp_path, hits):
    iterations = 0
    for point in CRASH_POINTS:
        for hit in hits:
            root = tmp_path / f"cache-{point}-{hit}"
            out, acked, inflight = _run_writer(
                root, n_pub=4, crash=f"{point}:{hit}")
            assert out.returncode == -signal.SIGKILL, \
                (point, hit, out.returncode, out.stderr[-1000:])
            iterations += 1
            _verify_recovered(root, acked, inflight)
            # the crash left the store recoverable AND writable: a restart
            # completes the remaining publishes on the same root
            out2, acked2, _ = _run_writer(root, n_pub=4)
            assert out2.returncode == 0, out2.stderr[-2000:]
            _verify_recovered(root, [f"fp{i:04d}" for i in range(4)], [])
    return iterations


def test_sigkill_mid_publish_recovers_journaled_files(tmp_path):
    assert _kill_matrix(tmp_path, hits=(2,)) == len(CRASH_POINTS)


@pytest.mark.stress
def test_sigkill_mid_publish_recovers_journaled_files_stress(tmp_path):
    # every fault point x several publish indices: >= 20 kill iterations
    assert _kill_matrix(tmp_path, hits=(1, 2, 3, 4)) >= 20

"""SIPC: reference-passing IPC, IPC inspection, resharing, dict sharing."""
import numpy as np
import pytest

from repro.core import (AddressMap, BufferStore, Column, KernelZero,
                        SipcReader, SipcWriter, Table, alloc_aligned)
from repro.core import ops
from repro.core.zarquet import gen_int_table, gen_str_table


@pytest.fixture()
def env(tmp_path):
    store = BufferStore(swap_dir=str(tmp_path / "swap"))
    kz = KernelZero(store)
    cg = store.new_cgroup("sb")
    yield store, kz, cg
    store.close()


def roundtrip(store, kz, cg, table, mode="zero"):
    w = SipcWriter(store, kz, cg, mode=mode)
    msg = w.write_table(table)
    r = SipcReader(store, mode=mode)
    return msg, r.read_table(msg), r


def test_roundtrip_zero(env):
    store, kz, cg = env
    t = Table.from_pydict({"a": np.arange(1000, dtype=np.int64),
                           "s": [f"v{i}" for i in range(1000)]})
    msg, t2, _ = roundtrip(store, kz, cg, t)
    assert t.equals(t2)
    # schema copied, data referenced: wire size is tiny
    assert msg.wire_nbytes < 1000
    assert msg.new_bytes > 0 and msg.reshared_bytes == 0


def test_roundtrip_modes_equal(env):
    store, kz, cg = env
    t = Table.from_pydict({"a": np.arange(257, dtype=np.float64),
                           "s": [f"x{i}" for i in range(257)]})
    for mode in ("full_copy", "writer_copy", "zero", "zero_noreshare"):
        _, t2, _ = roundtrip(store, kz, cg, t, mode)
        assert t.equals(t2), mode


def test_zero_mode_reader_views_share_memory(env):
    store, kz, cg = env
    v = alloc_aligned(8 * 4096).view(np.int64)
    v[:] = np.arange(v.size)
    t = Table.from_pydict({"a": v})
    msg, t2, _ = roundtrip(store, kz, cg, t)
    a1 = v.view(np.uint8).__array_interface__["data"][0]
    a2 = t2.batches[0].columns[0].values.view(np.uint8) \
        .__array_interface__["data"][0]
    assert a1 == a2  # true zero copy end to end


def test_writer_copy_mode_copies(env):
    store, kz, cg = env
    t = gen_int_table(2, 1 << 16)
    before = store.stats.bytes_copied
    roundtrip(store, kz, cg, t, mode="writer_copy")
    assert store.stats.bytes_copied - before >= 2 * (1 << 16)


# ---------------------------------------------------------------------------
# resharing (IPC inspection)
# ---------------------------------------------------------------------------

def run_node(store, kz, in_msg, fn, mode="zero"):
    """Simulate a downstream node: read inputs, apply fn, SIPC-write."""
    cg = store.new_cgroup("node")
    reader = SipcReader(store, mode=mode)
    t = reader.read_table(in_msg)
    out = fn(t)
    w = SipcWriter(store, kz, cg, mode=mode, input_map=reader.map)
    return w.write_table(out)


def test_reshare_drop_columns(env):
    store, kz, cg = env
    t = gen_int_table(10, 1 << 14)
    msg, _, _ = roundtrip(store, kz, cg, t)
    out = run_node(store, kz, msg, lambda x: ops.drop_columns(x, ["i0", "i1"]))
    assert out.new_bytes == 0                    # zero new physical data
    assert out.reshared_bytes == 8 * (1 << 14)
    r = SipcReader(store)
    t2 = r.read_table(out)
    assert t2.num_columns == 8
    assert t2.equals(ops.drop_columns(t, ["i0", "i1"]))


def test_reshare_slice_rows(env):
    store, kz, cg = env
    t = gen_int_table(4, 1 << 14)
    msg, _, _ = roundtrip(store, kz, cg, t)
    out = run_node(store, kz, msg, lambda x: ops.slice_rows(x, 10, 500))
    assert out.new_bytes == 0
    t2 = SipcReader(store).read_table(out)
    assert t2.equals(ops.slice_rows(t, 10, 500))


def test_reshare_slice_strings(env):
    store, kz, cg = env
    t = gen_str_table(2, 1 << 14, str_len=20)
    msg, _, _ = roundtrip(store, kz, cg, t)
    out = run_node(store, kz, msg, lambda x: ops.slice_rows(x, 5, 100))
    assert out.new_bytes == 0                    # offsets view + values ref
    t2 = SipcReader(store).read_table(out)
    assert t2.equals(ops.slice_rows(t, 5, 100))


def test_reshare_add_column_costs_only_new(env):
    store, kz, cg = env
    t = gen_int_table(4, 1 << 14)
    msg, _, _ = roundtrip(store, kz, cg, t)
    ncol = (1 << 14) // 8
    out = run_node(store, kz, msg,
                   lambda x: ops.add_column(
                       x, "new", np.arange(ncol, dtype=np.int64)))
    assert out.new_bytes == 1 << 14              # just the added column
    assert out.reshared_bytes == 4 * (1 << 14)


def test_reshare_concat(env):
    store, kz, cg = env
    t = gen_int_table(3, 1 << 13, seed=1)
    msg, _, _ = roundtrip(store, kz, cg, t)

    def concat_self(x):
        return ops.concat_tables([x, x])
    out = run_node(store, kz, msg, concat_self)
    # both halves reference the same input buffers -> zero new data
    assert out.new_bytes == 0
    t2 = SipcReader(store).read_table(out)
    assert t2.num_rows == 2 * t.num_rows


def test_filter_copies_but_dict_shares(env):
    store, kz, cg = env
    t = gen_str_table(2, 1 << 14, str_len=16, seed=3)
    t = ops.dict_encode(t, ["s0", "s1"])
    msg, _, _ = roundtrip(store, kz, cg, t)
    nrows = t.num_rows

    def filt(x):
        mask = np.zeros(x.num_rows, dtype=bool)
        mask[::2] = True
        return ops.filter_rows(x, mask)
    out = run_node(store, kz, msg, filt)
    # codes copied (4B * kept rows * 2 cols), dictionaries reshared
    assert out.new_bytes == 2 * 4 * ((nrows + 1) // 2)
    assert out.reshared_bytes > 0
    t2 = SipcReader(store).read_table(out)
    mask = np.zeros(nrows, dtype=bool)
    mask[::2] = True
    assert t2.equals(ops.filter_rows(t, mask))


def test_upper_ascii_reshares_offsets(env):
    store, kz, cg = env
    t = gen_str_table(1, 1 << 13, str_len=32, seed=5)
    msg, _, _ = roundtrip(store, kz, cg, t)
    out = run_node(store, kz, msg, lambda x: ops.upper(x, "s0"))
    # ASCII fast path: values new, offsets reshared
    nrows = t.num_rows
    assert out.reshared_bytes == (nrows + 1) * 8   # offsets buffer
    t2 = SipcReader(store).read_table(out)
    assert t2.batches[0].columns[0].get_bytes(0).isupper()


def test_upper_utf8_general_no_reshare(env):
    store, kz, cg = env
    t = Table.from_pydict({"s": ["straße", "ŉdif", "plain"]})
    msg, _, _ = roundtrip(store, kz, cg, t)
    out = run_node(store, kz, msg, lambda x: ops.upper(x, "s"))
    assert out.reshared_bytes == 0
    t2 = SipcReader(store).read_table(out)
    assert t2.to_pydict()["s"] == ["STRASSE", "ŉdif".upper(), "PLAIN"]


def test_files_referenced_exposed_for_rm(env):
    store, kz, cg = env
    t = gen_int_table(2, 1 << 12)
    msg, _, _ = roundtrip(store, kz, cg, t)
    info = msg.files_referenced()
    assert len(info) == 2                          # one file per column
    assert all(v == 1 << 12 for v in info.values())
    # refcounts pinned
    for fid in info:
        assert store.get(fid).refcount == 1
    msg.release()
    for fid in info:
        assert store.get(fid).refcount == 0


def test_reshared_refcount_protects_from_delete(env):
    store, kz, cg = env
    t = gen_int_table(2, 1 << 12)
    msg, _, _ = roundtrip(store, kz, cg, t)
    out = run_node(store, kz, msg, lambda x: ops.drop_columns(x, ["i0"]))
    fid = out.all_refs()[0].file_id
    # input msg released, but downstream still references one file
    msg.release()
    f = store.get(fid)
    assert f.refcount == 1
    t2 = SipcReader(store).read_table(out)        # still readable
    assert t2.num_columns == 1

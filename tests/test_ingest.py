"""Streaming zarquet ingest + continuous differential recompute.

The load-bearing properties:

* ``StreamWriter`` commits micro-batches durably (ACK after the commit
  pointer advances), honors the bounded in-flight window, survives
  reopen-for-append and torn tails (at-least-once), and never disturbs
  committed row-group extents;
* per-row-group source fingerprints are append-stable: adding groups
  changes the whole-file hash but leaves every existing group's loader
  fingerprint intact, which is what makes
  ``IncrementalRecompute.refresh()`` execute exactly the new tail's
  cone (plus the reduce) while old cones stay CACHED — and the
  incremental result is bit-identical to a from-scratch run;
* serving snapshots are refcounted: a reader pinned to version v keeps
  reading v while newer refreshes land and release it.
"""

import os
import threading

import numpy as np
import pytest

from repro.core import (BufferStore, IncrementalRecompute, RMConfig,
                        ResourceManager, StreamWriter, make_executor)
from repro.core import fingerprint, ops, zarquet
from repro.core.arrow import Table


def _batch(i: int, n: int = 200) -> Table:
    return Table.from_pydict({
        "k": (np.arange(n, dtype=np.int64) + i) % 7,
        "v": np.arange(n, dtype=np.float64) * (i + 1),
        "s": [f"t{(j + i) % 5}" for j in range(n)]})


def _sum_v(tables):
    """Per-group map stage (module-level: picklable + fingerprintable)."""
    t = tables[0].combine()
    b = t.batches[0]
    return Table.from_pydict({
        "k": b.column("k").to_numpy(),
        "v2": b.column("v").to_numpy() * 2.0})


# ---------------------------------------------------------------------------
# StreamWriter: commit/ACK lifecycle, window, recovery
# ---------------------------------------------------------------------------

def test_stream_writer_commit_ack_roundtrip(tmp_path):
    p = str(tmp_path / "s.zq")
    acks = []
    w = StreamWriter(p, max_inflight=3,
                     on_ack=lambda seqs, v: acks.append((tuple(seqs), v)))
    # v0 footer: readable before any data
    meta = zarquet.read_footer(p)
    assert meta["version"] == 0 and meta["groups"] == []
    seqs = [w.ingest(_batch(i)) for i in range(5)]
    assert seqs == [0, 1, 2, 3, 4]
    w.flush()
    # window of 3 auto-committed once; flush committed the rest
    assert acks == [((0, 1, 2), 1), ((3, 4), 2)]
    assert w.poll_acks() == [0, 1, 2, 3, 4] and w.poll_acks() == []
    meta = zarquet.read_footer(p)
    assert meta["version"] == 2
    assert len(meta["groups"]) == 5
    assert meta["nrows"] == 5 * 200
    # per-group content hashes: distinct data -> distinct, same -> same
    hashes = [g["hash"] for g in meta["groups"]]
    assert len(set(hashes)) == 5
    w.ingest(_batch(0))                  # identical content to group 0
    w.flush()
    meta = zarquet.read_footer(p)
    assert meta["groups"][5]["hash"] == hashes[0]
    w.close()
    # whole read == concat of the micro-batches, one RecordBatch/group
    t = zarquet.read_table(p)
    assert len(t.batches) == 6
    ref = ops.concat_tables([_batch(i) for i in range(5)] + [_batch(0)])
    assert t.to_pydict() == ref.to_pydict()


def test_stream_bounded_window_backpressure(tmp_path):
    p = str(tmp_path / "s.zq")
    w = StreamWriter(p, max_inflight=2)
    w.ingest(_batch(0))
    assert w.inflight == 1 and w.version == 0      # buffered, not durable
    w.ingest(_batch(1))                            # window full -> commit
    assert w.inflight == 0 and w.version == 1
    w.close()


def test_stream_reopen_append_and_torn_tail(tmp_path):
    p = str(tmp_path / "s.zq")
    with StreamWriter(p) as w:
        for i in range(3):
            w.ingest(_batch(i))
            w.flush()
    # crash simulation: garbage appended past the committed pointer
    with open(p, "ab") as fh:
        fh.write(b"\xde\xad" * 37)
    meta = zarquet.read_footer(p)                  # readers: unaffected
    assert meta["version"] == 3 and len(meta["groups"]) == 3
    w2 = StreamWriter(p)                           # reopen truncates tail
    assert os.path.getsize(p) == zarquet.committed_end(p)
    assert w2.version == 3
    w2.ingest(_batch(9))
    w2.close()
    t = zarquet.read_table(p)
    assert len(t.batches) == 4 and t.num_rows == 4 * 200
    # schema mismatch is rejected at ingest
    w3 = StreamWriter(p)
    with pytest.raises(ValueError, match="schema"):
        w3.ingest(Table.from_pydict({"other": np.arange(3)}))
    w3.close()


def test_stream_row_group_selection(tmp_path):
    p = str(tmp_path / "s.zq")
    with StreamWriter(p) as w:
        for i in range(4):
            w.ingest(_batch(i, n=50))
    t = zarquet.read_table(p, row_groups=(2, 0), columns=["v"])
    assert len(t.batches) == 2                     # selection order kept
    ref = (list(_batch(2, 50).to_pydict()["v"])
           + list(_batch(0, 50).to_pydict()["v"]))
    assert t.to_pydict()["v"] == ref
    with pytest.raises(IndexError, match="row group"):
        zarquet.read_table(p, row_groups=(9,))
    # an empty stream has nothing to read (but its footer is valid)
    p2 = str(tmp_path / "empty.zq")
    StreamWriter(p2).close()
    with pytest.raises(ValueError, match="no committed row groups"):
        zarquet.read_table(p2)


def test_batch_file_cannot_be_appended(tmp_path):
    p = str(tmp_path / "b.zq")
    zarquet.write_table(p, _batch(0))
    with pytest.raises(ValueError, match="batch file"):
        StreamWriter(p)
    # batch files read unchanged (single implicit group 0)
    t = zarquet.read_table(p, row_groups=(0,))
    assert t.to_pydict() == _batch(0).to_pydict()


# ---------------------------------------------------------------------------
# fingerprints: append-stability of the stable prefix
# ---------------------------------------------------------------------------

def test_group_fingerprints_stable_across_append(tmp_path):
    p = str(tmp_path / "s.zq")
    w = StreamWriter(p)
    w.ingest(_batch(0))
    w.flush()
    fp_g0 = fingerprint.source_fingerprint(p, (0,))
    fp_file = fingerprint.file_fingerprint(p)
    w.ingest(_batch(1))
    w.flush()
    w.close()
    fingerprint.reset_caches()          # force footer re-read
    assert fingerprint.source_fingerprint(p, (0,)) == fp_g0
    assert fingerprint.source_fingerprint(p, (1,)) != fp_g0
    assert fingerprint.file_fingerprint(p) != fp_file
    # order matters: (0,1) and (1,0) read different tables
    assert fingerprint.source_fingerprint(p, (0, 1)) != \
        fingerprint.source_fingerprint(p, (1, 0))


# ---------------------------------------------------------------------------
# incremental recompute: the differential cone
# ---------------------------------------------------------------------------

def _env(root, workers=1, **kw):
    store = BufferStore(backing="file", root=root)
    rm = ResourceManager(store, RMConfig(cache_root=root, workers=workers,
                                         **kw))
    return store, rm, make_executor(store, rm, workers=workers)


def test_incremental_recompute_executes_only_the_tail(tmp_path):
    p = str(tmp_path / "s.zq")
    root = str(tmp_path / "cache")
    w = StreamWriter(p)
    for i in range(3):
        w.ingest(_batch(i))
        w.flush()
    store, rm, ex = _env(root)
    drv = IncrementalRecompute(p, store=store, rm=rm, executor=ex,
                               map_fn=_sum_v)
    s = drv.refresh()
    # cold: every node executes (3 x load+map, 1 reduce)
    assert (s.groups, s.nodes_total, s.nodes_executed) == (3, 7, 7)
    for i in range(3, 6):
        w.ingest(_batch(i))
        w.flush()
        s = drv.refresh()
        # exactly the new tail's cone: load_gN + map_gN + reduce
        assert s.nodes_executed == 3, s
        assert s.cache_hits == s.nodes_total - 3, s
    with drv.snapshot() as (t, version):
        incr = t.to_pydict()
        assert version == w.version
        assert t.num_rows == 6 * 200
    w.close()
    drv.close()
    ex.close()
    store.close()
    # bit-identity: a from-scratch run in a fresh cache env
    fingerprint.reset_caches()
    store2, rm2, ex2 = _env(str(tmp_path / "cache2"))
    drv2 = IncrementalRecompute(p, store=store2, rm=rm2, executor=ex2,
                                map_fn=_sum_v)
    s2 = drv2.refresh()
    assert s2.nodes_executed == s2.nodes_total == 13
    with drv2.snapshot() as (t2, _):
        assert t2.to_pydict() == incr
    drv2.close()
    ex2.close()
    store2.close()


def test_incremental_requires_manifest(tmp_path):
    p = str(tmp_path / "s.zq")
    StreamWriter(p).close()
    store = BufferStore()
    rm = ResourceManager(store, RMConfig())     # no cache_root
    ex = make_executor(store, rm)
    with pytest.raises(ValueError, match="cache_root"):
        IncrementalRecompute(p, store=store, rm=rm, executor=ex)
    store.close()


def test_snapshot_pins_version_across_refresh(tmp_path):
    p = str(tmp_path / "s.zq")
    w = StreamWriter(p)
    w.ingest(_batch(0))
    w.flush()
    store, rm, ex = _env(str(tmp_path / "cache"))
    drv = IncrementalRecompute(p, store=store, rm=rm, executor=ex)
    drv.refresh()
    with drv.snapshot() as (t, v1):
        before = t.to_pydict()
        w.ingest(_batch(1))
        w.flush()
        drv.refresh()                   # supersedes v1 while it is pinned
        assert drv.version > v1
        # the pinned view is still fully readable and unchanged
        assert t.to_pydict() == before
    with drv.snapshot() as (t2, v2):
        assert v2 == drv.version and t2.num_rows == 2 * 200
    w.close()
    drv.close()
    ex.close()
    store.close()


def test_ingest_while_serving_concurrently(tmp_path):
    """Queries serve from snapshots WHILE micro-batches land and
    refreshes run: no torn reads, versions monotonic."""
    p = str(tmp_path / "s.zq")
    root = str(tmp_path / "cache")
    w = StreamWriter(p)
    w.ingest(_batch(0))
    w.flush()
    store, rm, ex = _env(root, workers=2)
    drv = IncrementalRecompute(p, store=store, rm=rm, executor=ex)
    drv.refresh()
    stop = threading.Event()
    errors = []
    seen_versions = []

    def serve():
        try:
            while not stop.is_set():
                with drv.snapshot() as (t, v):
                    # aggregate over the pinned view: row count must be
                    # exactly v * 200 (each version == one more group)
                    assert t.num_rows == v * 200, (t.num_rows, v)
                    seen_versions.append(v)
        except BaseException as e:       # pragma: no cover - failure path
            errors.append(e)

    threads = [threading.Thread(target=serve) for _ in range(2)]
    for t in threads:
        t.start()
    for i in range(1, 8):
        w.ingest(_batch(i))
        w.flush()
        s = drv.refresh()
        assert s.nodes_executed == 2     # new loader + reduce
    stop.set()
    for t in threads:
        t.join()
    assert not errors, errors
    assert seen_versions and all(1 <= v <= 8 for v in seen_versions)
    w.close()
    drv.close()
    ex.close()
    store.close()


def test_incremental_recompute_process_mode(tmp_path):
    """row_groups plumb through the Flight worker protocol: process-mode
    refresh is differential and bit-identical to thread mode."""
    p = str(tmp_path / "s.zq")
    w = StreamWriter(p)
    for i in range(2):
        w.ingest(_batch(i))
        w.flush()
    store, rm, ex = _env(str(tmp_path / "cache"), workers=2,
                         workers_mode="process")
    drv = IncrementalRecompute(p, store=store, rm=rm, executor=ex)
    drv.refresh()
    w.ingest(_batch(2))
    w.flush()
    s = drv.refresh()
    assert s.nodes_executed == 2
    with drv.snapshot() as (t, _):
        got = t.to_pydict()
    assert got == ops.concat_tables(
        [_batch(i) for i in range(3)]).to_pydict()
    w.close()
    drv.close()
    ex.close()
    store.close()


# ---------------------------------------------------------------------------
# data pipeline over stream shards
# ---------------------------------------------------------------------------

def test_pipeline_stream_shard_incremental(tmp_path):
    from repro.data.pipeline import (PipelineConfig, ZerrowDataPipeline,
                                     _gen_text_table, make_text_stream)
    root = str(tmp_path / "cache")
    p = make_text_stream(str(tmp_path), n_batches=3, rows_per_batch=120,
                         seed=0)
    pipe = ZerrowDataPipeline(
        [p], PipelineConfig(batch=2, seq_len=64, cache_root=root))
    n1 = sum(1 for _ in pipe.batches())
    assert n1 > 0
    loads1 = pipe.ex.load_runs
    # append one micro-batch: exactly one load+pack cone recomputes
    with zarquet.StreamWriter(p) as w:
        w.ingest(_gen_text_table(np.random.default_rng(99), 360, 120))
    runs0 = pipe.ex.node_runs
    n2 = sum(1 for _ in pipe.batches())
    assert n2 > n1
    assert pipe.ex.node_runs - runs0 == 2        # load_g3 + its pack
    assert pipe.ex.load_runs == loads1 + 1
    pipe.close()

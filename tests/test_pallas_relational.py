"""Eligibility harness for the Pallas relational kernels.

Admission to the ``ZERROW_KERNEL_BACKEND=pallas`` path is decided here:
every kernel in ``repro.kernels.relational`` must be **bit-identical**
(values AND dtypes) to its ``core/vkernels.py`` numpy reference, which
must itself match a per-row naive Python reference — across dtype mixes
(int32/int64/uint64/float64 including -0.0 and NaN), null fractions,
duplicate-heavy keys, zero-row and empty(all-null)-group inputs.
Kernels the registry marks ineligible (the order-sensitive float
reductions) are asserted to *stay* on the numpy path even with the
pallas backend active, and the demotion machinery is proven to pull a
kernel whose bits regress.  The fingerprint section pins the cache
contract: flipping the backend or editing a Pallas kernel invalidates
exactly the join/group-by cones (manifest adoption counts asserted,
mirroring the PR 5 ``__fp_includes__`` tests).

Everything here runs interpret-mode on accelerator-less CI runners; the
seeded-numpy sweeps always run, and a hypothesis property (stress lane)
widens the input space when hypothesis is installed.
"""
import functools
import os

import numpy as np
import pytest

pytest.importorskip("jax", reason="pallas kernels need jax")

from repro.core import (BufferStore, DAG, Executor, NodeSpec, RMConfig,
                        ResourceManager, SipcReader, code_fingerprint)
from repro.core import fingerprint, kdispatch as kd, ops, vkernels, zarquet
from repro.core.arrow import Column, Table, pack_validity
from repro.kernels import relational as rel

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

_M64 = (1 << 64) - 1


@pytest.fixture
def pallas_backend(monkeypatch):
    monkeypatch.setenv("ZERROW_KERNEL_BACKEND", "pallas")
    yield
    kd.reset_demotions()


# ---------------------------------------------------------------------------
# per-row naive references (pure Python ints — no numpy accumulation)
# ---------------------------------------------------------------------------

def _py_mix64(h: int) -> int:
    h ^= h >> 30
    h = (h * 0xBF58476D1CE4E5B9) & _M64
    h ^= h >> 27
    h = (h * 0x94D049BB133111EB) & _M64
    return h ^ (h >> 31)


def _py_bits(v, dtype) -> int:
    if np.issubdtype(dtype, np.floating):
        if v == 0:
            v = abs(v) * 0            # -0.0 canonicalizes to +0.0
        return int(np.asarray(v, dtype).view(f"u{np.dtype(dtype).itemsize}"))
    return int(np.asarray(v, dtype).view(f"u{np.dtype(dtype).itemsize}"))


def py_hash_fixed(values: np.ndarray) -> np.ndarray:
    g = 0x9E3779B97F4A7C15
    return np.array([_py_mix64(_py_bits(v, values.dtype) ^ g)
                     for v in values], dtype=np.uint64)


def py_combine(col_hashes, n) -> np.ndarray:
    g = 0x9E3779B97F4A7C15
    out = []
    for i in range(n):
        h = g
        for col in col_hashes:
            h = _py_mix64((h * g ^ int(col[i])) & _M64)
        out.append(h)
    return np.array(out, dtype=np.uint64) if n else np.empty(0, np.uint64)


def py_segreduce(op, values, order, starts, valid):
    """Per-group loop reference for the integer reducers; returns
    (vals, counts) in the reducer's output dtype contract."""
    n = len(order)
    ends = list(starts[1:]) + [n]
    vals, counts = [], []
    v = values.astype(np.uint8) if values.dtype == np.bool_ else values
    acc_t = np.uint64 if v.dtype == np.uint64 else np.int64
    for s, e in zip(starts, ends):
        rows = [order[i] for i in range(s, e)
                if valid is None or valid[order[i]]]
        counts.append(len(rows))
        if op == "count":
            vals.append(len(rows))
        elif op == "sum":
            t = 0
            for r in rows:
                t = int(np.asarray(t, acc_t) + np.asarray(v[r], acc_t)
                        .astype(acc_t))  # wrap like the kernel
            vals.append(np.asarray(t % (1 << 64) if acc_t == np.uint64
                                   else t, acc_t))
        elif op == "min":
            vals.append(min((v[r] for r in rows),
                            default=vkernels._dtype_max(v.dtype)))
        else:
            vals.append(max((v[r] for r in rows),
                            default=vkernels._dtype_min(v.dtype)))
    out_t = np.int64 if op == "count" else (
        acc_t if op == "sum" else v.dtype)
    return (np.array(vals, dtype=out_t) if vals else np.empty(0, out_t),
            np.array(counts, dtype=np.int64))


def assert_bits(got, want, msg=""):
    gv, gc = got if isinstance(got, tuple) else (got, None)
    wv, wc = want if isinstance(want, tuple) else (want, None)
    assert gv.dtype == wv.dtype, f"{msg}: dtype {gv.dtype} != {wv.dtype}"
    assert np.array_equal(gv, wv, equal_nan=gv.dtype.kind == "f"), \
        f"{msg}: values diverge"
    if gc is not None:
        assert gc.dtype == wc.dtype and np.array_equal(gc, wc), \
            f"{msg}: counts diverge"


# ---------------------------------------------------------------------------
# hashing: pallas-interpret == numpy == per-row python
# ---------------------------------------------------------------------------

_HASH_ARRAYS = {
    "int32": np.array([0, -1, 5, 2**31 - 1, -2**31], np.int32),
    "int64": np.array([0, -1, 2**62, -2**62, 7], np.int64),
    "uint64": np.array([0, 1, 2**63, 2**64 - 1], np.uint64),
    "float64": np.array([0.0, -0.0, 1.5, -1.5, np.nan, np.inf,
                         -np.inf, 1e-300], np.float64),
    "float32": np.array([0.0, -0.0, 2.5, np.nan], np.float32),
    "empty": np.empty(0, np.int64),
}


@pytest.mark.parametrize("name", sorted(_HASH_ARRAYS))
def test_hash_fixed_triple_identical(name):
    v = _HASH_ARRAYS[name]
    ref = vkernels.hash_fixed(v)
    assert_bits(rel.hash_fixed(v), ref, f"pallas hash_fixed[{name}]")
    assert_bits(ref, py_hash_fixed(v), f"numpy hash_fixed[{name}]")


def test_hash_fixed_large_blocked():
    # spans multiple kernel blocks, with -0.0/NaN sprinkled in
    rng = np.random.default_rng(0)
    v = rng.standard_normal(5001)
    v[rng.random(5001) < 0.1] = -0.0
    v[rng.random(5001) < 0.05] = np.nan
    assert_bits(rel.hash_fixed(v), vkernels.hash_fixed(v))


def test_combine_hashes_triple_identical():
    rng = np.random.default_rng(1)
    n = 700
    hs = [rng.integers(0, 1 << 64, n, dtype=np.uint64) for _ in range(3)]
    ref = vkernels.combine_hashes(hs, n)
    assert_bits(rel.combine_hashes(hs, n), ref)
    assert_bits(ref, py_combine(hs, n))
    # order-sensitive: reversing the columns must change the bits
    assert not np.array_equal(ref, rel.combine_hashes(hs[::-1], n))
    # zero-column and zero-row edges match numpy exactly
    assert_bits(rel.combine_hashes([], 4), vkernels.combine_hashes([], 4))
    zero = [np.empty(0, np.uint64)] * 3
    assert_bits(rel.combine_hashes(zero, 0),
                vkernels.combine_hashes(zero, 0))


def test_hash_keys_fused_matches_composition():
    rng = np.random.default_rng(2)
    n = 3000
    keys = [rng.integers(-9, 9, n).astype(np.int64),
            (rng.integers(0, 5, n).astype(np.float64) / 2),
            rng.integers(0, 1 << 64, n, dtype=np.uint64)]
    keys[1][rng.random(n) < 0.2] = -0.0
    assert_bits(rel.hash_keys(keys, n), vkernels.hash_keys(keys, n))
    # var-length keys are structurally numpy-only: the fused kernel
    # refuses them, the dispatcher routes them
    c = Column.from_strings(["a", "bb", ""])
    with pytest.raises(TypeError):
        rel.hash_keys([(c.offsets, c.values)], 3)


def test_dispatch_hash_keys_var_length_routes_to_numpy(pallas_backend):
    c = Column.from_strings(["a", "bb", "", "a"])
    want = vkernels.hash_keys([(c.offsets, c.values)], 4)
    assert_bits(kd.hash_keys([(c.offsets, c.values)], 4), want)


# ---------------------------------------------------------------------------
# gathers
# ---------------------------------------------------------------------------

def test_filter_join_gather_triple_identical():
    rng = np.random.default_rng(3)
    sel = np.nonzero(rng.random(400) < 0.5)[0]
    idx = rng.integers(-1, len(sel), 900).astype(np.int64)
    ref = vkernels.filter_join_gather(sel, idx)
    assert_bits(rel.filter_join_gather(sel, idx), ref)
    naive = np.array([-1 if i < 0 else sel[i] for i in idx], np.int64)
    assert_bits(ref, naive)
    # no-sentinel fast path and empty edges
    pos = np.abs(idx[idx >= 0])
    assert_bits(rel.filter_join_gather(sel, pos),
                vkernels.filter_join_gather(sel, pos))
    empty = np.empty(0, np.int64)
    assert_bits(rel.filter_join_gather(sel, empty),
                vkernels.filter_join_gather(sel, empty))
    all_miss = np.full(5, -1, np.int64)
    assert_bits(rel.filter_join_gather(empty, all_miss),
                vkernels.filter_join_gather(empty, all_miss))


@pytest.mark.parametrize("dtype", [np.int32, np.int64, np.float64])
def test_gather_payload_matches_naive(dtype):
    rng = np.random.default_rng(4)
    src = rng.integers(-99, 99, 300).astype(dtype)
    idx = rng.integers(-1, 300, 700).astype(np.int64)
    got = rel.gather_payload(src, idx, 0)
    naive = np.array([src[i] if i >= 0 else 0 for i in idx], dtype)
    assert_bits(got, naive)
    assert_bits(kd.gather_payload(src, idx, 0), naive)  # numpy arm
    assert rel.gather_payload(src, np.empty(0, np.int64)).shape == (0,)
    assert_bits(rel.gather_payload(np.empty(0, dtype),
                                   np.full(3, -1, np.int64), 0),
                np.zeros(3, dtype))


# ---------------------------------------------------------------------------
# segment reducers: dtype sweep x null fraction x group shape
# ---------------------------------------------------------------------------

def _values(rng, n, dtype):
    if dtype == "bool":
        return rng.random(n) < 0.5
    if dtype == "uint64":
        return rng.integers(0, 1 << 64, n, dtype=np.uint64)
    return rng.integers(-100, 100, n).astype(dtype)


@pytest.mark.parametrize("dtype", ["int32", "int64", "uint64", "bool"])
@pytest.mark.parametrize("null_frac", [0.0, 0.3, 1.0])
@pytest.mark.parametrize("shape", ["spread", "dup_heavy", "empty"])
def test_grouped_reducers_triple_identical(dtype, null_frac, shape):
    rng = np.random.default_rng(hash((dtype, null_frac, shape)) % 2**32)
    n = 0 if shape == "empty" else 1500
    card = 3 if shape == "dup_heavy" else 200
    codes = rng.integers(0, card, n)
    order, starts = vkernels.group_ranges([codes])
    v = _values(rng, n, dtype)
    valid = None if null_frac == 0.0 else rng.random(n) >= null_frac
    for op, pf, nf in (("count", rel.grouped_count, vkernels.grouped_count),
                       ("sum", rel.grouped_sum, vkernels.grouped_sum),
                       ("min", rel.grouped_min, vkernels.grouped_min),
                       ("max", rel.grouped_max, vkernels.grouped_max)):
        ref = nf(v, order, starts, valid)
        assert_bits(pf(v, order, starts, valid), ref,
                    f"pallas grouped_{op}[{dtype},{null_frac},{shape}]")
        assert_bits(ref, py_segreduce(op, v, order, starts, valid),
                    f"numpy grouped_{op}[{dtype},{null_frac},{shape}]")


def test_grouped_sum_uint64_wraps_like_numpy():
    # a group total past 2**64 wraps mod 2**64 on both backends
    v = np.array([2**63, 2**63, 5], np.uint64)
    order, starts = vkernels.group_ranges([np.zeros(3, np.int64)])
    assert_bits(rel.grouped_sum(v, order, starts),
                vkernels.grouped_sum(v, order, starts))


def test_grouped_reducers_many_groups_blocked():
    # group count spans multiple output blocks of the kernel grid
    rng = np.random.default_rng(11)
    n, card = 4000, 1300
    codes = rng.integers(0, card, n)
    order, starts = vkernels.group_ranges([codes])
    v = rng.integers(-1000, 1000, n).astype(np.int64)
    valid = rng.random(n) >= 0.2
    for pf, nf in ((rel.grouped_sum, vkernels.grouped_sum),
                   (rel.grouped_min, vkernels.grouped_min),
                   (rel.grouped_max, vkernels.grouped_max),
                   (rel.grouped_count, vkernels.grouped_count)):
        assert_bits(pf(v, order, starts, valid),
                    nf(v, order, starts, valid))


# ---------------------------------------------------------------------------
# ineligible kernels stay on numpy; the registry documents why
# ---------------------------------------------------------------------------

def test_float_reducers_are_registry_ineligible():
    for key in ("grouped_sum:float", "grouped_min:float",
                "grouped_max:float", "grouped_mean"):
        e = kd.REGISTRY[key]
        assert not e.eligible and e.reason
    assert "order" in kd.REGISTRY["grouped_sum:float"].reason
    assert not kd.eligible("grouped_sum", np.float64)
    assert kd.eligible("grouped_sum", np.int64)
    assert not kd.eligible("no_such_kernel")    # unknown => numpy


def test_float_sum_dispatch_stays_numpy(pallas_backend):
    """With the pallas backend active, a float grouped_sum must serve
    the numpy bits — the sequential np.bincount accumulation — and the
    pallas port must refuse float input outright (it cannot honor the
    order contract)."""
    rng = np.random.default_rng(5)
    n = 2000
    # adversarial magnitudes: parallel reordering WOULD change the bits
    v = rng.standard_normal(n) * 10.0 ** rng.integers(-12, 12, n)
    codes = rng.integers(0, 7, n)
    order, starts = vkernels.group_ranges([codes])
    valid = rng.random(n) >= 0.2
    want = vkernels.grouped_sum(v, order, starts, valid)
    assert_bits(kd.GROUPED_REDUCERS["sum"](v, order, starts, valid), want)
    assert_bits(kd.GROUPED_REDUCERS["mean"](v, order, starts, valid),
                vkernels.grouped_mean(v, order, starts, valid))
    with pytest.raises(TypeError):
        rel.grouped_sum(v, order, starts, valid)
    with pytest.raises(TypeError):
        rel.grouped_min(v, order, starts, valid)
    with pytest.raises(TypeError):
        rel.grouped_max(v, order, starts, valid)


def test_self_check_admits_current_kernels(pallas_backend):
    res = kd.self_check()
    demoted = {k: v for k, v in res.items() if v.startswith("demoted")}
    assert not demoted, demoted
    assert not kd.demoted()
    assert res["hash_fixed"] == "ok"
    assert res["grouped_sum:int"] == "ok"
    assert res["grouped_sum:float"].startswith("ineligible")


def test_self_check_demotes_bit_regressions(pallas_backend, monkeypatch):
    """The registry rejects a kernel whose differential fails: corrupt
    the pallas grouped_sum, prove self_check demotes it, dispatch falls
    back to numpy bits, and the demotion changes the group_by cone
    fingerprint (a demoted backend must not serve stale cones)."""
    fp_before = code_fingerprint(ops.group_by)

    def bad_grouped_sum(values, order, starts, valid=None, **kw):
        vals, counts = vkernels.grouped_sum(values, order, starts, valid)
        return vals + vals.dtype.type(1), counts       # off by one

    monkeypatch.setattr(rel, "grouped_sum", bad_grouped_sum)
    fp_patched = code_fingerprint(ops.group_by)
    assert fp_patched != fp_before, "kernel edit must change the cone"
    res = kd.self_check()
    assert res["grouped_sum:int"].startswith("demoted")
    assert "grouped_sum:int" in kd.demoted()
    assert not kd.eligible("grouped_sum", np.int64)
    # dispatch now serves numpy bits despite the corrupted kernel
    rng = np.random.default_rng(6)
    v = rng.integers(-50, 50, 500).astype(np.int64)
    order, starts = vkernels.group_ranges([rng.integers(0, 9, 500)])
    assert_bits(kd.GROUPED_REDUCERS["sum"](v, order, starts),
                vkernels.grouped_sum(v, order, starts))
    assert code_fingerprint(ops.group_by) != fp_patched, \
        "a demotion must change the cone (numpy now computes it)"
    kd.reset_demotions()
    assert kd.eligible("grouped_sum", np.int64)
    assert code_fingerprint(ops.group_by) == fp_patched


def test_unavailable_pallas_warns_once_and_serves_numpy(monkeypatch):
    monkeypatch.setenv("ZERROW_KERNEL_BACKEND", "pallas")
    monkeypatch.setattr(kd, "_pallas_mod", None)
    monkeypatch.setattr(kd, "_pallas_error",
                        ImportError("jax not installed"))
    monkeypatch.setattr(kd, "_warned_fallback", False)
    v = np.arange(10, dtype=np.int64)
    with pytest.warns(RuntimeWarning, match="ZERROW_KERNEL_BACKEND"):
        assert kd.active_backend() == "numpy"
    assert_bits(kd.hash_fixed(v), vkernels.hash_fixed(v))
    # loud once, not per call
    import warnings as w
    with w.catch_warnings():
        w.simplefilter("error")
        assert kd.active_backend() == "numpy"


def test_unknown_backend_name_raises(monkeypatch):
    monkeypatch.setenv("ZERROW_KERNEL_BACKEND", "cuda")
    with pytest.raises(ValueError, match="ZERROW_KERNEL_BACKEND"):
        kd.requested_backend()


# ---------------------------------------------------------------------------
# fingerprint pinning: backend flips + kernel edits invalidate the cones
# ---------------------------------------------------------------------------

def test_backend_flip_changes_only_relational_fingerprints(monkeypatch):
    relational_ops = (ops.join, ops.group_by, ops.filter_join,
                      ops.join_node, ops.group_by_node,
                      ops.filter_join_node)
    unrelated = (ops.filter_rows, ops.select_columns, ops.sort_by,
                 ops.add_column)
    monkeypatch.setenv("ZERROW_KERNEL_BACKEND", "numpy")
    fp_np = [code_fingerprint(f) for f in relational_ops + unrelated]
    assert all(fp_np)
    monkeypatch.setenv("ZERROW_KERNEL_BACKEND", "pallas")
    fp_pl = [code_fingerprint(f) for f in relational_ops + unrelated]
    assert all(fp_pl)
    k = len(relational_ops)
    assert all(a != b for a, b in zip(fp_np[:k], fp_pl[:k])), \
        "backend flip did not invalidate a relational op"
    assert fp_np[k:] == fp_pl[k:], \
        "backend flip leaked into non-relational ops"


def test_pallas_kernel_edit_invalidates_join_cone(monkeypatch):
    monkeypatch.setenv("ZERROW_KERNEL_BACKEND", "pallas")
    fp1 = code_fingerprint(ops.join)
    gb1 = code_fingerprint(ops.group_by)

    def other_hash_fixed(values, *, interpret=None):
        return vkernels.hash_fixed(values)

    monkeypatch.setattr(rel, "hash_fixed", other_hash_fixed)
    assert code_fingerprint(ops.join) != fp1, \
        "editing a pallas hash kernel must invalidate join cones"
    assert code_fingerprint(ops.group_by) == gb1, \
        "the hash kernel is not a group_by dependency"


def _select_amount(tables):
    return ops.select_columns(tables[0], ["amount"])


def test_backend_flip_recomputes_exactly_join_groupby_cone(
        tmp_path, monkeypatch):
    """Manifest adoption across a backend flip: sources and the
    non-relational node adopt from the cache, the join + group_by cone
    re-executes — and both backends' outputs are bit-identical, so the
    recompute converges back to the same aggregates."""
    data = str(tmp_path / "data")
    cache_root = str(tmp_path / "cache")
    os.makedirs(data)
    rng = np.random.default_rng(7)
    zarquet.write_table(os.path.join(data, "cust.zq"), Table.from_pydict({
        "cust": np.arange(64, dtype=np.int64),
        "country": [f"c{i % 5}" for i in range(64)]}))
    zarquet.write_table(os.path.join(data, "orders.zq"),
                        Table.from_pydict({
        "cust": rng.integers(0, 72, 400).astype(np.int64),
        "amount": rng.integers(0, 10_000, 400).astype(np.int64)}))

    def dag():
        est = 1 << 22
        return DAG([
            NodeSpec("orders", source=os.path.join(data, "orders.zq"),
                     est_mem=est),
            NodeSpec("cust", source=os.path.join(data, "cust.zq"),
                     est_mem=est, dict_columns=("country",)),
            NodeSpec("amounts", fn=_select_amount, deps=["orders"],
                     est_mem=est, keep_output=True),
            NodeSpec("join", fn=functools.partial(
                ops.join_node, on="cust", how="left"),
                deps=["orders", "cust"], est_mem=est),
            NodeSpec("agg", fn=functools.partial(
                ops.group_by_node, keys="country",
                aggs={"total": ("amount", "sum"),
                      "hi": ("amount", "max"),
                      "n": ("amount", "count")}),
                deps=["join"], est_mem=est, keep_output=True),
        ], name="star")

    def run(backend):
        monkeypatch.setenv("ZERROW_KERNEL_BACKEND", backend)
        fingerprint.reset_caches()
        store = BufferStore(backing="file", root=cache_root,
                            swap_dir=os.path.join(data, "swap"))
        rm = ResourceManager(store, RMConfig(policy="adaptive",
                                             cache_root=cache_root))
        ex = Executor(store, rm)
        d = dag()
        ex.run([d])
        out = SipcReader(store).read_table(
            d.nodes["agg"].output).to_pydict()
        counts = (ex.node_runs, ex.cache_hits,
                  {n: s.status for n, s in d.nodes.items()})
        store.close()
        return out, counts

    out1, (runs1, hits1, _) = run("numpy")
    assert (runs1, hits1) == (5, 0)
    out2, (runs2, hits2, _) = run("numpy")         # warm, same backend
    assert out2 == out1
    assert (runs2, hits2) == (0, 5)
    out3, (runs3, hits3, states) = run("pallas")   # flip backend
    assert out3 == out1, "pallas cone diverged from the numpy bits"
    assert (runs3, hits3) == (2, 3), \
        f"backend flip should re-run exactly join+agg, got {states}"
    assert states["orders"] == states["cust"] == states["amounts"] \
        == "cached"
    out4, (runs4, hits4, _) = run("numpy")         # old manifests live on
    assert out4 == out1
    assert (runs4, hits4) == (0, 5)


# ---------------------------------------------------------------------------
# end-to-end: join+group_by tables bit-identical across backends
# ---------------------------------------------------------------------------

def test_join_group_by_tables_bit_identical_across_backends(monkeypatch):
    rng = np.random.default_rng(8)
    n, m = 3000, 150
    lvalid = pack_validity(rng.random(n) >= 0.1)
    left = Table.from_pydict({
        "cust": Column.primitive(
            rng.integers(0, m + 20, n).astype(np.int64), lvalid),
        "amount": rng.integers(-500, 500, n).astype(np.int64),
        "f": np.where(rng.random(n) < 0.1, -0.0, rng.standard_normal(n)),
        "flag": rng.random(n) < 0.5})
    right = Table.from_pydict({
        "cust": np.arange(m, dtype=np.int64),
        "country": [f"c{i % 7}" for i in range(m)]})
    aggs = {"total": ("amount", "sum"), "lo": ("amount", "min"),
            "hi": ("amount", "max"), "n": ("amount", "count"),
            "fmean": ("f", "mean"), "fsum": ("f", "sum"),
            "any": ("flag", "max")}

    outs, raw = {}, {}
    for backend in ("numpy", "pallas"):
        monkeypatch.setenv("ZERROW_KERNEL_BACKEND", backend)
        j = ops.join(left, right, on="cust", how="left")
        g = ops.group_by(j, "country", aggs)
        outs[backend] = g.to_pydict()
        raw[backend] = {
            f.name: c._logical()
            for f, c in zip(g.batches[0].schema.fields,
                            g.batches[0].columns)
            if c.type.is_primitive}
    assert outs["numpy"] == outs["pallas"]
    for name, va in raw["numpy"].items():        # raw buffer bits too
        vb = raw["pallas"][name]
        assert va.dtype == vb.dtype, name
        assert np.array_equal(va, vb, equal_nan=va.dtype.kind == "f"), \
            name


# ---------------------------------------------------------------------------
# hypothesis stress lane: widen the differential's input space
# ---------------------------------------------------------------------------

if HAVE_HYPOTHESIS:
    @pytest.mark.stress
    @settings(max_examples=int(os.environ.get("ZERROW_HYP_EXAMPLES",
                                              "25")), deadline=None)
    @given(st.integers(0, 2**31 - 1),
           st.sampled_from(["int32", "int64", "uint64", "bool"]),
           st.integers(0, 60), st.floats(0.0, 1.0), st.integers(1, 9))
    def test_grouped_reducers_match_hypothesis(seed, dtype, n, null_frac,
                                               card):
        rng = np.random.default_rng(seed)
        codes = rng.integers(0, card, n)
        order, starts = vkernels.group_ranges([codes]) if n else \
            (np.empty(0, np.int64), np.empty(0, np.int64))
        v = _values(rng, n, dtype)
        valid = None if null_frac == 0 else rng.random(n) >= null_frac
        for pf, nf in ((rel.grouped_count, vkernels.grouped_count),
                       (rel.grouped_sum, vkernels.grouped_sum),
                       (rel.grouped_min, vkernels.grouped_min),
                       (rel.grouped_max, vkernels.grouped_max)):
            assert_bits(pf(v, order, starts, valid),
                        nf(v, order, starts, valid))

    @pytest.mark.stress
    @settings(max_examples=int(os.environ.get("ZERROW_HYP_EXAMPLES",
                                              "25")), deadline=None)
    @given(st.integers(0, 2**31 - 1),
           st.sampled_from(["int32", "int64", "uint64", "float64"]),
           st.integers(0, 80))
    def test_hash_and_gather_match_hypothesis(seed, dtype, n):
        rng = np.random.default_rng(seed)
        v = _values(rng, n, dtype) if dtype != "float64" else \
            np.where(rng.random(n) < 0.2, -0.0, rng.standard_normal(n))
        assert_bits(rel.hash_fixed(v), vkernels.hash_fixed(v))
        sel = np.nonzero(rng.random(n) < 0.5)[0]
        idx = rng.integers(-1, max(len(sel), 1), n).astype(np.int64) \
            if len(sel) else np.full(n, -1, np.int64)
        assert_bits(rel.filter_join_gather(sel, idx),
                    vkernels.filter_join_gather(sel, idx))

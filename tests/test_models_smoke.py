"""Per-architecture smoke tests: reduced same-family config, one forward /
train step + one prefill/decode step on CPU; asserts shapes and no NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, ShapeConfig, smoke_variant
from repro.models.api import ModelAPI
from repro.train.trainstep import init_state, make_train_step

SMOKE_TRAIN = ShapeConfig("smoke_train", "train", 32, 2)
SMOKE_PREFILL = ShapeConfig("smoke_prefill", "prefill", 32, 2)
SMOKE_DECODE = ShapeConfig("smoke_decode", "decode", 32, 2)

ARCH_NAMES = sorted(ARCHS)


def make_batch(api, shape, rng):
    out = {}
    for k, s in api.input_specs(shape).items():
        if s.dtype == jnp.int32:
            if k == "positions":
                out[k] = jnp.full(s.shape, shape.seq_len, jnp.int32)
            else:
                out[k] = jnp.asarray(
                    rng.integers(0, api.cfg.vocab, s.shape), jnp.int32)
        else:
            out[k] = jnp.asarray(rng.normal(size=s.shape), s.dtype)
    return out


@pytest.fixture(scope="module")
def rng():
    return np.random.default_rng(0)


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_train_step(name, rng):
    cfg = smoke_variant(ARCHS[name])
    api = ModelAPI(cfg)
    state = init_state(api, jax.random.key(0))
    step = jax.jit(make_train_step(api, total_steps=10))
    batch = make_batch(api, SMOKE_TRAIN, rng)
    state, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"])), name
    assert float(metrics["loss"]) > 0
    assert int(state["step"]) == 1
    # params updated and finite
    for leaf in jax.tree.leaves(state["params"]):
        assert np.all(np.isfinite(np.asarray(leaf, dtype=np.float32)))


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_prefill_decode(name, rng):
    cfg = smoke_variant(ARCHS[name])
    api = ModelAPI(cfg)
    params = api.model.init(jax.random.key(1))
    batch = make_batch(api, SMOKE_PREFILL, rng)
    logits, caches = jax.jit(
        lambda p, b: api.prefill(p, b, SMOKE_PREFILL))(params, batch)
    B = SMOKE_PREFILL.global_batch
    assert logits.shape == (B, 1, cfg.vocab)
    assert np.all(np.isfinite(np.asarray(logits, np.float32)))
    dec = {"tokens": jnp.zeros((B, 1), jnp.int32),
           "positions": jnp.full((B, 1), SMOKE_PREFILL.seq_len, jnp.int32)}
    logits2, caches2 = jax.jit(api.serve_step)(params, dec, caches)
    assert logits2.shape == (B, 1, cfg.vocab)
    assert np.all(np.isfinite(np.asarray(logits2, np.float32)))


def test_decode_matches_prefill_dense(rng):
    """Teacher-forced prefill logits == step-by-step decode logits."""
    cfg = smoke_variant(ARCHS["smollm-135m"])
    api = ModelAPI(cfg)
    params = api.model.init(jax.random.key(2))
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (1, 8)), jnp.int32)

    # full forward logits
    x = api.model.embed_inputs(params, toks)
    pos = jnp.arange(8)[None, :]
    h, _, _ = api.model.backbone(params, x, "train", None, pos)
    full_logits = api.model.head(params, h)

    # prefill on the first 4, then decode 4 steps
    shape = ShapeConfig("s", "prefill", 8, 1)
    logits, caches = api.model.prefill(params, {"tokens": toks[:, :4]},
                                       cache_len=8)
    np.testing.assert_allclose(np.asarray(logits[0, 0]),
                               np.asarray(full_logits[0, 3]), rtol=2e-4,
                               atol=2e-4)
    for t in range(4, 8):
        step_logits, caches = api.model.decode_step(
            params, toks[:, t:t + 1], caches,
            jnp.full((1, 1), t, jnp.int32))
        if t < 7:
            np.testing.assert_allclose(np.asarray(step_logits[0, 0]),
                                       np.asarray(full_logits[0, t]),
                                       rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("name", ["rwkv6-3b", "recurrentgemma-9b"])
def test_decode_matches_prefill_recurrent(name, rng):
    cfg = smoke_variant(ARCHS[name])
    api = ModelAPI(cfg)
    params = api.model.init(jax.random.key(3))
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (1, 8)), jnp.int32)
    x = api.model.embed_inputs(params, toks)
    h, _, _ = api.model.backbone(params, x, "train", None,
                                 jnp.arange(8)[None, :])
    full_logits = api.model.head(params, h)
    logits, caches = api.model.prefill(params, {"tokens": toks[:, :4]},
                                       cache_len=8)
    np.testing.assert_allclose(np.asarray(logits[0, 0]),
                               np.asarray(full_logits[0, 3]), rtol=5e-3,
                               atol=5e-3)
    for t in range(4, 7):
        step_logits, caches = api.model.decode_step(
            params, toks[:, t:t + 1], caches,
            jnp.full((1, 1), t, jnp.int32))
        np.testing.assert_allclose(np.asarray(step_logits[0, 0]),
                                   np.asarray(full_logits[0, t]),
                                   rtol=5e-3, atol=5e-3)

"""Hypothesis property tests on the system's invariants."""
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import (BufferStore, Column, KernelZero, PAGE, SipcReader,
                        SipcWriter, Table, alloc_aligned)
from repro.core import ops
from repro.core.arrow import pack_validity, unpack_validity

settings.register_profile("ci", max_examples=40, deadline=None)
settings.load_profile("ci")


@st.composite
def small_tables(draw):
    n = draw(st.integers(1, 64))
    cols = {}
    n_int = draw(st.integers(0, 3))
    n_str = draw(st.integers(0, 2))
    if n_int + n_str == 0:
        n_int = 1
    for j in range(n_int):
        cols[f"i{j}"] = np.asarray(
            draw(st.lists(st.integers(-2**40, 2**40),
                          min_size=n, max_size=n)), np.int64)
    for j in range(n_str):
        cols[f"s{j}"] = draw(st.lists(
            st.text(min_size=0, max_size=12), min_size=n, max_size=n))
    return Table.from_pydict(cols)


# --------------------------------------------------------------------------
# invariant: deanon roundtrip preserves bytes, at any alignment
# --------------------------------------------------------------------------

@given(st.integers(1, 4 * PAGE), st.integers(0, 257))
def test_deanon_roundtrip_bytes(nbytes, offset):
    store = BufferStore()
    try:
        kz = KernelZero(store)
        cg = store.new_cgroup("p")
        base = alloc_aligned(nbytes + offset + 8)
        src = base[offset:offset + nbytes]
        rng = np.random.default_rng(nbytes)
        base_w = base
        base_w[:] = rng.integers(0, 255, base.nbytes, dtype=np.uint8)
        want = src.copy()
        f = kz.new_file(cg)
        off, n = kz.deanon(f, src)
        assert n == nbytes
        got = f.read(off, n)
        assert np.array_equal(got, want)
    finally:
        store.close()


# --------------------------------------------------------------------------
# invariant: SIPC roundtrip is identity, in every mode
# --------------------------------------------------------------------------

@given(small_tables(), st.sampled_from(
    ["full_copy", "writer_copy", "zero", "zero_noreshare"]))
def test_sipc_roundtrip_identity(table, mode):
    store = BufferStore()
    try:
        kz = KernelZero(store)
        cg = store.new_cgroup("p")
        msg = SipcWriter(store, kz, cg, mode=mode).write_table(table)
        out = SipcReader(store, mode=mode).read_table(msg)
        assert table.equals(out)
    finally:
        store.close()


# --------------------------------------------------------------------------
# invariant: resharing never changes the logical value of an op
# --------------------------------------------------------------------------

@given(small_tables(), st.data())
def test_reshared_op_equals_direct_op(table, data):
    n = table.num_rows
    op_name = data.draw(st.sampled_from(
        ["slice", "drop", "filter", "concat", "sort"]))
    if op_name == "slice":
        a = data.draw(st.integers(0, n))
        b = data.draw(st.integers(a, n))
        op = lambda t: ops.slice_rows(t, a, b)
    elif op_name == "drop":
        name = data.draw(st.sampled_from(table.schema.names()))
        if len(table.schema) == 1:
            return
        op = lambda t: ops.drop_columns(t, [name])
    elif op_name == "filter":
        mask = np.asarray(data.draw(st.lists(
            st.booleans(), min_size=n, max_size=n)), bool)
        op = lambda t: ops.filter_rows(t, mask)
    elif op_name == "concat":
        op = lambda t: ops.concat_tables([t, t])
    else:
        name = table.schema.names()[0]
        op = lambda t: ops.sort_by(t, name)
    direct = op(table)

    store = BufferStore()
    try:
        kz = KernelZero(store)
        cg = store.new_cgroup("p")
        msg = SipcWriter(store, kz, cg, mode="zero").write_table(table)
        reader = SipcReader(store, mode="zero")
        t2 = reader.read_table(msg)
        out = op(t2)
        w2 = SipcWriter(store, kz, store.new_cgroup("c"), mode="zero",
                        input_map=reader.map)
        msg2 = w2.write_table(out)
        back = SipcReader(store, mode="zero").read_table(msg2)
        assert direct.equals(back)
    finally:
        store.close()


# --------------------------------------------------------------------------
# invariant: refcounts never go negative; GC only frees refcount-0 files
# --------------------------------------------------------------------------

@given(small_tables())
def test_refcount_protects_reshared_files(table):
    store = BufferStore()
    try:
        kz = KernelZero(store)
        msg = SipcWriter(store, kz, store.new_cgroup("p"),
                         mode="zero").write_table(table)
        reader = SipcReader(store, mode="zero")
        t2 = reader.read_table(msg)
        out = ops.concat_tables([t2, t2])
        msg2 = SipcWriter(store, kz, store.new_cgroup("c"), mode="zero",
                          input_map=reader.map).write_table(out)
        msg.release()
        for fid in msg.files_referenced():
            f = store.files.get(fid)
            if f is not None:
                assert f.refcount >= 0
        # downstream must still be readable after upstream release
        back = SipcReader(store, mode="zero").read_table(msg2)
        assert back.num_rows == 2 * table.num_rows
    finally:
        store.close()


# --------------------------------------------------------------------------
# invariant: validity bitmaps roundtrip
# --------------------------------------------------------------------------

@given(st.lists(st.booleans(), min_size=1, max_size=200))
def test_validity_roundtrip(bits):
    mask = np.asarray(bits, bool)
    assert np.array_equal(unpack_validity(pack_validity(mask), len(mask)),
                          mask)


# --------------------------------------------------------------------------
# invariant: swap-out/in preserves content (eviction correctness)
# --------------------------------------------------------------------------

@given(small_tables())
def test_swap_preserves_table(table):
    store = BufferStore()
    try:
        kz = KernelZero(store)
        msg = SipcWriter(store, kz, store.new_cgroup("p"),
                         mode="zero").write_table(table)
        for fid in list(msg.files_referenced()):
            store.swap_out_file(fid)
        out = SipcReader(store, mode="zero").read_table(msg)
        assert table.equals(out)
    finally:
        store.close()

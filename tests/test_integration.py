"""End-to-end integration: serving engine, train-checkpoint-resume,
dry-run lowering machinery on a small in-process mesh."""
import os
import subprocess
import sys

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, ShapeConfig, smoke_variant
from repro.models.api import ModelAPI


def test_serve_engine_matches_forward():
    """Engine greedy decode == argmax over the full teacher-forced logits."""
    from repro.serve.engine import Request, ServeEngine
    cfg = smoke_variant(ARCHS["smollm-135m"])
    api = ModelAPI(cfg)
    params = api.model.init(jax.random.key(7))
    engine = ServeEngine(api, params, batch=2, max_seq=16)
    rng = np.random.default_rng(3)
    prompts = [rng.integers(1, cfg.vocab, size=16).astype(np.int32),
               rng.integers(1, cfg.vocab, size=16).astype(np.int32)]
    outs = engine.run_batch([Request(p, max_new=3) for p in prompts])

    # manual: forward the prompt, take argmax, append, repeat
    for i, p in enumerate(prompts):
        toks = list(p)
        for t in range(3):
            x = api.model.embed_inputs(params, jnp.asarray([toks]))
            h, _, _ = api.model.backbone(
                params, x, "train", None,
                jnp.arange(len(toks))[None, :])
            logits = api.model.head(params, h)
            nxt = int(jnp.argmax(logits[0, -1]))
            assert outs[i][t] == nxt, (i, t)
            toks.append(nxt)


def test_train_checkpoint_resume_exact(tmp_path):
    """Stop/restore mid-training resumes to identical parameters."""
    from repro.runtime.checkpoint import CheckpointStore
    from repro.train.trainstep import init_state, make_train_step
    cfg = smoke_variant(ARCHS["smollm-135m"])
    api = ModelAPI(cfg)
    rng = np.random.default_rng(0)
    batches = [{"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (2, 32)),
                                      jnp.int32),
                "labels": jnp.asarray(rng.integers(0, cfg.vocab, (2, 32)),
                                      jnp.int32)} for _ in range(6)]
    step = jax.jit(make_train_step(api, total_steps=6))

    state = init_state(api, jax.random.key(0))
    for b in batches[:3]:
        state, _ = step(state, b)
    store = CheckpointStore(str(tmp_path / "c"), async_io=False)
    store.save(3, jax.tree.map(np.asarray, state))
    for b in batches[3:]:
        state, m_direct = step(state, b)

    restored, _ = store.restore(3, like=jax.tree.map(np.asarray, state))
    state2 = jax.tree.map(jnp.asarray, restored)
    for b in batches[3:]:
        state2, m_resumed = step(state2, b)
    np.testing.assert_allclose(float(m_direct["loss"]),
                               float(m_resumed["loss"]), rtol=1e-6)
    for a, b_ in zip(jax.tree.leaves(state["params"]),
                     jax.tree.leaves(state2["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b_))
    store.close()


DRYRUN_SNIPPET = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
from repro.configs import ARCHS, ShapeConfig, smoke_variant
from repro.launch.dryrun import _lower_train, _lower_decode
from repro.models.api import ModelAPI
from repro.sharding.partition import DEFAULT_RULES, SERVE_RULES, use_mesh

from repro.compat import make_mesh
mesh = make_mesh((4, 2), ("data", "model"))
cfg = smoke_variant(ARCHS[%r])
api = ModelAPI(cfg)
shape = ShapeConfig("t", "train", 64, 8)
with use_mesh(mesh, DEFAULT_RULES):
    c = _lower_train(api, shape, mesh, DEFAULT_RULES, False, 2).compile()
    assert c.memory_analysis().temp_size_in_bytes >= 0
dshape = ShapeConfig("d", "decode", 64, 8)
with use_mesh(mesh, SERVE_RULES):
    c = _lower_decode(api, dshape, mesh, SERVE_RULES).compile()
    from repro.compat import cost_analysis
    assert cost_analysis(c).get("flops", 0) > 0
print("OK")
"""


@pytest.mark.parametrize("arch", ["smollm-135m", "qwen3-moe-30b-a3b",
                                  "recurrentgemma-9b", "rwkv6-3b"])
def test_dryrun_machinery_subprocess(arch):
    """The full lower+compile path works on a small SPMD mesh (fresh
    process so the 8-device override doesn't leak into this one)."""
    env = dict(os.environ,
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    out = subprocess.run(
        [sys.executable, "-c", DRYRUN_SNIPPET % arch],
        capture_output=True, text=True, timeout=420, env=env)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "OK" in out.stdout

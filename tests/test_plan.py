"""Query frontend + logical optimizer (core/plan/).

The load-bearing property: for ANY plan, the optimized compile and the
naive compile produce bit-identical tables, in thread and process modes,
and both match a per-row pure-Python reference — the optimizer may only
change HOW (fewer nodes, fewer bytes), never WHAT.

Amounts are integer-valued floats throughout so sums are exact and
``to_pydict`` equality really is bit-identity, not approximation.
"""

import functools
import os
import pickle

import numpy as np
import pytest

from repro.core import (BufferStore, Executor, RMConfig, ResourceManager,
                        code_fingerprint, fingerprint_dag, make_executor,
                        zarquet)
from repro.core.arrow import Column, Table, pack_validity
from repro.core import fingerprint, ops
from repro.core.plan import (Filter, FilterJoin, Join, Plan, Scan, col,
                             compile_plans, explain_plans, lit, scan)
from repro.core.plan import compiler as plan_compiler
from repro.core.plan import rules as plan_rules
from repro.core.plan.expr import eval_predicate, split_conjuncts

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


# ---------------------------------------------------------------------------
# dataset + per-row reference
# ---------------------------------------------------------------------------

def _keys(rng, n, kind, card, null_frac=0.0):
    """A join-key column of the given logical kind."""
    validity = None
    if null_frac > 0 and n:
        validity = pack_validity(rng.random(n) >= null_frac)
    raw = rng.integers(0, card, size=n)
    if kind == "int":
        return Column.primitive(raw.astype(np.int64), validity)
    # "dict" keys are written plain (zarquet stores decoded) and re-
    # encoded at load via the scan's dict_columns — see _marts_plans
    return Column.from_strings([f"k{int(v):02d}" for v in raw],
                               validity=validity)


def _star(tmp, rng, n_orders, n_cust, key_kind, null_frac, tag=""):
    """Write an (orders, customers) pair; returns (po, pc, o_pd, c_pd)."""
    orders = Table.from_pydict({
        "cust": _keys(rng, n_orders, key_kind, max(n_cust, 1),
                      null_frac),
        "amount": rng.integers(-5, 15, size=n_orders).astype(np.float64),
        "pad": rng.random(n_orders),
    })
    customers = Table.from_pydict({
        "cust": _keys(rng, n_cust, key_kind, max(n_cust, 1)),
        "country": Column.from_strings(
            [f"c{int(v)}" for v in rng.integers(0, 4, size=n_cust)]),
        "segment": Column.from_strings(
            [f"s{int(v)}" for v in rng.integers(0, 3, size=n_cust)]),
        "extra": rng.random(n_cust),
    })
    po = os.path.join(tmp, f"orders{tag}.zq")
    pc = os.path.join(tmp, f"customers{tag}.zq")
    zarquet.write_table(po, orders)
    zarquet.write_table(pc, customers)
    return po, pc, orders.to_pydict(), customers.to_pydict()


def _marts_plans(po, pc, key_dict=False):
    """The bench_query marts pair.  ``key_dict`` dict-encodes the join
    key itself at load (dict-utf8 key mix)."""
    odict = ("cust",) if key_dict else ()
    cdict = ("country", "cust") if key_dict else ("country",)
    staging = (scan(po, dict_columns=odict).filter(col("amount") > 0)
               .join(scan(pc, dict_columns=cdict), on="cust"))
    return {
        "fct_country": staging.group_by(
            "country", {"revenue": ("amount", "sum"),
                        "n": ("amount", "count")}),
        "fct_segment": staging.group_by(
            "segment", {"revenue": ("amount", "sum")}),
    }


def _ref_marts(o_pd, c_pd):
    """Per-row reference: SQL null semantics (null keys never match,
    null comparisons are False), groups sorted ascending by key."""
    cmap = {}
    for j, k in enumerate(c_pd["cust"]):
        if k is not None:
            cmap.setdefault(k, []).append(j)
    by_country, by_segment = {}, {}
    for k, amt in zip(o_pd["cust"], o_pd["amount"]):
        if amt is None or not amt > 0 or k is None:
            continue
        for j in cmap.get(k, ()):
            gc = by_country.setdefault(c_pd["country"][j], [0.0, 0])
            gc[0] += amt
            gc[1] += 1
            by_segment[c_pd["segment"][j]] = \
                by_segment.get(c_pd["segment"][j], 0.0) + amt
    ck = sorted(by_country)
    sk = sorted(by_segment)
    return ({"country": ck,
             "revenue": [by_country[k][0] for k in ck],
             "n": [by_country[k][1] for k in ck]},
            {"segment": sk, "revenue": [by_segment[k] for k in sk]})


def _run_plans(env_store, ex, plans, optimize):
    cp = compile_plans(plans, optimize=optimize)
    ex.run([cp.dag])
    return {s: cp.read(env_store, s).to_pydict() for s in cp.sinks}


def _thread_env(tmp_path, sub):
    store = BufferStore(swap_dir=str(tmp_path / f"swap-{sub}"))
    rm = ResourceManager(store, RMConfig())
    return store, Executor(store, rm)


# ---------------------------------------------------------------------------
# expression trees
# ---------------------------------------------------------------------------

def test_expr_repr_stable_and_picklable():
    e = ((col("amount") > 0) & (col("country") == "c1")) | \
        ~(col("qty") <= lit(np.int64(3)))
    # numpy literals canonicalize to Python scalars -> address-free repr
    assert repr(e) == ("(((col('amount') > lit(0)) & "
                       "(col('country') == lit('c1'))) | "
                       "(~(col('qty') <= lit(3))))")
    e2 = pickle.loads(pickle.dumps(e))
    assert repr(e2) == repr(e)
    assert e.columns() == {"amount", "country", "qty"}
    # the compiled mask partial must pickle (Flight boundary) and
    # fingerprint deterministically across rebuilds
    p1 = functools.partial(eval_predicate, expr=e)
    p2 = functools.partial(eval_predicate, expr=pickle.loads(
        pickle.dumps(e)))
    assert code_fingerprint(p1) is not None
    assert code_fingerprint(p1) == code_fingerprint(p2)


def test_expr_eval_null_and_utf8_semantics():
    validity = pack_validity(np.array([True, False, True, True]))
    t = Table.from_pydict({
        "a": Column.primitive(np.array([1.0, 2.0, -1.0, 5.0]), validity),
        "s": Column.from_strings(["x", "y", "x", "z"]),
    })
    b = t.combine().batches[0]
    # comparisons with a null row are False for ==, !=, and >
    assert list(eval_predicate(b, col("a") > 0)) == [True, False, False,
                                                     True]
    assert list(eval_predicate(b, col("a") != 2)) == [True, False, True,
                                                      True]
    assert list(eval_predicate(b, col("s") == "x")) == [True, False,
                                                        True, False]
    # utf8 != is also null-safe plain negation of ==
    m_eq = eval_predicate(b, col("s") == "x")
    m_ne = eval_predicate(b, col("s") != "x")
    assert not np.any(m_eq & m_ne)
    # conjunct split == combined mask
    pred = (col("a") > 0) & (col("s") != "x") & (col("a") < 4)
    combined = eval_predicate(b, pred)
    split = np.ones(4, dtype=bool)
    for c in split_conjuncts(pred):
        split &= eval_predicate(b, c)
    assert list(combined) == list(split)


# ---------------------------------------------------------------------------
# optimizer structure
# ---------------------------------------------------------------------------

def test_pushdown_routes_conjuncts_by_side(tmp_path):
    rng = np.random.default_rng(0)
    po, pc, _, _ = _star(str(tmp_path), rng, 100, 10, "int", 0.0)
    base = scan(po).join(scan(pc), on="cust")
    pred = ((col("amount") > 0) & (col("extra") < 1)
            & (col("amount") < col("extra")))
    opt, _ = plan_rules.optimize_plans({"q": base.filter(pred).root})
    root = opt["q"]
    # cross-side conjunct stays above; the others fused into the join
    assert isinstance(root, Filter)
    assert root.predicate.columns() == {"amount", "extra"}
    fj = root.children[0]
    assert isinstance(fj, FilterJoin)
    assert fj.left_pred is not None and \
        fj.left_pred.columns() == {"amount"}
    assert fj.right_pred is not None and \
        fj.right_pred.columns() == {"extra"}

    # under a LEFT join the right-side conjunct must NOT push (it would
    # resurrect null-padded rows the original plan filtered out)
    left = scan(po).join(scan(pc), on="cust", how="left").filter(
        (col("amount") > 0) & (col("extra") < 1))
    opt, _ = plan_rules.optimize_plans({"q": left.root})
    root = opt["q"]
    assert isinstance(root, Filter)
    assert root.predicate.columns() == {"extra"}
    fj = root.children[0]
    assert isinstance(fj, FilterJoin)
    assert fj.left_pred is not None and fj.right_pred is None


def test_pruning_preserves_suffix_collision(tmp_path):
    """orders and customers share a non-key column name; a plan that
    reads the suffixed right copy must keep the LEFT copy loaded too so
    the collision (and therefore the suffix) survives pruning."""
    rng = np.random.default_rng(1)
    tmp = str(tmp_path)
    n = 64
    o = Table.from_pydict({
        "cust": rng.integers(0, 8, n).astype(np.int64),
        "x": rng.integers(0, 100, n).astype(np.int64),
        "pad": rng.random(n)})
    c = Table.from_pydict({
        "cust": np.arange(8, dtype=np.int64),
        "x": rng.integers(0, 100, 8).astype(np.int64),
        "extra": rng.random(8)})
    po, pc = os.path.join(tmp, "o.zq"), os.path.join(tmp, "c.zq")
    zarquet.write_table(po, o)
    zarquet.write_table(pc, c)
    for sel in (("cust", "x_right"), ("cust", "x")):
        p = scan(po).join(scan(pc), on="cust").select(*sel)
        outs = {}
        for optimize in (False, True):
            store, ex = _thread_env(tmp_path, f"{sel[-1]}-{optimize}")
            outs[optimize] = _run_plans(
                store, ex, {"q": p}, optimize)["q"]
            store.close()
        assert outs[False] == outs[True], f"select {sel} differs"
    # structure: selecting x_right keeps 'x' on BOTH scans
    opt, _ = plan_rules.optimize_plans(
        {"q": scan(po).join(scan(pc), on="cust")
         .select("cust", "x_right").root})
    proj = opt["q"]
    join = proj.children[0]
    lscan, rscan = join.children
    assert "x" in lscan.schema() and "x" in rscan.schema()
    assert "pad" not in lscan.schema() and "extra" not in rscan.schema()


def test_dedup_shares_staging_cone(tmp_path):
    rng = np.random.default_rng(2)
    po, pc, _, _ = _star(str(tmp_path), rng, 200, 20, "int", 0.0)
    plans = _marts_plans(po, pc)
    naive = compile_plans(plans, optimize=False)
    opt = compile_plans(plans, optimize=True)
    assert len(naive.dag.nodes) == 10
    assert len(opt.dag.nodes) == 5      # 2 scans + filter_join + 2 marts
    # the two sinks share their staging dependency
    deps = {opt.dag.nodes[opt.sinks[s]].spec.deps[0]
            for s in opt.sinks}
    assert len(deps) == 1
    # pruned loaders narrowed to the referenced columns
    scans = {st.spec.source: st.spec.columns
             for st in opt.dag.nodes.values() if st.is_loader}
    assert set(scans[po]) == {"cust", "amount"}
    assert set(scans[pc]) == {"cust", "country", "segment"}
    # everything fingerprints (cacheable), deterministically per recompile
    fp1 = fingerprint_dag(opt.dag)
    assert all(v is not None for v in fp1.values())
    fp2 = fingerprint_dag(compile_plans(plans, optimize=True).dag)
    assert fp1 == fp2


def test_explain_shows_passes_and_sharing(tmp_path):
    rng = np.random.default_rng(3)
    po, pc, _, _ = _star(str(tmp_path), rng, 100, 10, "int", 0.0)
    text = explain_plans(_marts_plans(po, pc))
    assert "== logical plan (pre-optimization) ==" in text
    assert "== optimizer passes ==" in text
    assert "== optimized plan ==" in text
    assert "[fuse_filter_join]" in text
    assert "[prune_projection]" in text
    assert "[dedup_subplan]" in text
    assert "[shared]" in text
    assert "filter_join" in text
    # single-plan sugar
    assert "scan" in scan(po).filter(col("amount") > 0).explain()


# ---------------------------------------------------------------------------
# equivalence: optimized == naive == reference, thread AND process
# ---------------------------------------------------------------------------

_MATRIX = [("int", 0.0, 300, 24), ("int", 0.3, 300, 24),
           ("utf8", 0.0, 200, 16), ("utf8", 0.25, 200, 16),
           ("dict", 0.2, 200, 16), ("int", 0.0, 0, 8)]


@pytest.mark.parametrize("mode", ["thread", "process"])
def test_marts_equivalence_matrix(tmp_path, mode):
    """Key-type mixes, null keys, and empty inputs: optimized output is
    bit-identical to naive output and to the per-row reference, in both
    executor modes.  One warm env per mode (process spawn is paid once)."""
    root = str(tmp_path / mode)
    os.makedirs(root, exist_ok=True)
    backing = "file" if mode == "process" else "ram"
    store = BufferStore(swap_dir=os.path.join(root, "swap"),
                        backing=backing,
                        data_dir=os.path.join(root, "store")
                        if backing == "file" else None)
    rm = ResourceManager(store, RMConfig(workers=2, workers_mode=mode))
    ex = make_executor(store, rm, workers=2)
    try:
        for i, (kind, null_frac, n_orders, n_cust) in enumerate(_MATRIX):
            rng = np.random.default_rng(10 + i)
            po, pc, o_pd, c_pd = _star(root, rng, n_orders, n_cust,
                                       kind, null_frac, tag=str(i))
            plans = _marts_plans(po, pc, key_dict=(kind == "dict"))
            naive = _run_plans(store, ex, plans, optimize=False)
            opt = _run_plans(store, ex, plans, optimize=True)
            ref_c, ref_s = _ref_marts(o_pd, c_pd)
            case = f"{kind}/null={null_frac}/n={n_orders}/{mode}"
            assert opt == naive, f"optimized != naive [{case}]"
            assert opt["fct_country"] == ref_c, \
                f"fct_country != reference [{case}]"
            assert opt["fct_segment"] == ref_s, \
                f"fct_segment != reference [{case}]"
        if mode == "process":
            assert ex.fallback_inline == 0, \
                "plan ops fell back to inline (not picklable?)"
    finally:
        ex.close()
        store.close()


def test_filter_null_semantics_end_to_end(tmp_path):
    """Nulls in the filtered column: rows with null amount drop (mask
    False), matching the reference and the naive plan."""
    rng = np.random.default_rng(42)
    n = 200
    validity = rng.random(n) >= 0.3
    amounts = rng.integers(-5, 15, n).astype(np.float64)
    t = Table.from_pydict({
        "amount": Column.primitive(amounts, pack_validity(validity)),
        "tag": rng.integers(0, 5, n).astype(np.int64)})
    po = os.path.join(str(tmp_path), "t.zq")
    zarquet.write_table(po, t)
    p = scan(po).filter(col("amount") > 0)
    outs = {}
    for optimize in (False, True):
        store, ex = _thread_env(tmp_path, str(optimize))
        outs[optimize] = _run_plans(store, ex, {"q": p}, optimize)["q"]
        store.close()
    assert outs[False] == outs[True]
    keep = [i for i in range(n) if validity[i] and amounts[i] > 0]
    assert outs[True]["amount"] == [amounts[i] for i in keep]
    assert outs[True]["tag"] == [int(t.to_pydict()["tag"][i])
                                 for i in keep]


if HAVE_HYPOTHESIS:
    @settings(max_examples=12, deadline=None)
    @given(seed=st.integers(0, 10_000),
           kind=st.sampled_from(["int", "utf8", "dict"]),
           null_frac=st.sampled_from([0.0, 0.2, 0.5]),
           n_orders=st.sampled_from([0, 1, 37, 150]))
    def test_property_optimized_equals_naive(tmp_path_factory, seed, kind,
                                             null_frac, n_orders):
        tmp = str(tmp_path_factory.mktemp("plan-prop"))
        rng = np.random.default_rng(seed)
        n_cust = max(n_orders // 6, 4)
        po, pc, o_pd, c_pd = _star(tmp, rng, n_orders, n_cust, kind,
                                   null_frac)
        plans = _marts_plans(po, pc)
        store = BufferStore(swap_dir=os.path.join(tmp, "swap"))
        rm = ResourceManager(store, RMConfig())
        ex = Executor(store, rm)
        try:
            naive = _run_plans(store, ex, plans, optimize=False)
            opt = _run_plans(store, ex, plans, optimize=True)
        finally:
            store.close()
        ref_c, ref_s = _ref_marts(o_pd, c_pd)
        assert opt == naive
        assert opt["fct_country"] == ref_c
        assert opt["fct_segment"] == ref_s


# ---------------------------------------------------------------------------
# fingerprint pinning + DAG plumbing + loader projection
# ---------------------------------------------------------------------------

def test_fused_plan_op_fingerprint_pinned():
    """filter_join_exec folds in the fusion kernels AND the rewrite
    rules that emit it; dropping any pin changes the op identity (the
    PR 5 join pinning contract, extended to optimizer rules)."""
    fn = functools.partial(
        plan_compiler.filter_join_exec, on=["cust"], how="inner",
        suffix="_right", left_pred=col("amount") > 0, right_pred=None)
    fp1 = code_fingerprint(fn)
    assert fp1 is not None
    saved = plan_compiler.filter_join_exec.__fp_includes__
    assert plan_rules.fuse_filter_join in saved
    assert plan_rules.pushdown_filters in saved
    assert ops.filter_join in saved
    try:
        plan_compiler.filter_join_exec.__fp_includes__ = tuple(
            d for d in saved if d is not plan_rules.fuse_filter_join)
        assert code_fingerprint(fn) != fp1, \
            "dropping the fusion-rule pin did not change the identity"
    finally:
        plan_compiler.filter_join_exec.__fp_includes__ = saved
    assert code_fingerprint(fn) == fp1
    assert plan_rules.pushdown_filters in \
        plan_compiler.filter_exec.__fp_includes__
    assert plan_rules.prune_projections in \
        plan_compiler.project_exec.__fp_includes__


def test_compile_plumbs_deadline_and_tenant(tmp_path):
    rng = np.random.default_rng(5)
    po, pc, _, _ = _star(str(tmp_path), rng, 50, 8, "int", 0.0)
    cp = compile_plans(_marts_plans(po, pc), name="marts",
                       deadline=123.5, tenant="team-a")
    assert cp.dag.name == "marts"
    assert cp.dag.deadline == 123.5
    assert cp.dag.tenant == "team-a"
    # defaults: tenant falls back to the DAG name (fair-share key)
    cp2 = compile_plans(_marts_plans(po, pc), name="marts2")
    assert cp2.dag.deadline is None
    assert cp2.dag.tenant == "marts2"


def test_zarquet_column_projection(tmp_path):
    rng = np.random.default_rng(6)
    n = 100
    t = Table.from_pydict({
        "a": rng.integers(0, 9, n).astype(np.int64),
        "b": rng.random(n),
        "s": Column.from_strings([f"v{i % 7}" for i in range(n)])})
    p = os.path.join(str(tmp_path), "t.zq")
    zarquet.write_table(p, t)
    sub = zarquet.read_table(p, columns=["s", "a"])
    # output order follows the footer, not the request
    assert sub.schema.names() == ["a", "s"]
    full = zarquet.read_table(p)
    assert sub.to_pydict() == {"a": full.to_pydict()["a"],
                               "s": full.to_pydict()["s"]}
    with pytest.raises(KeyError):
        zarquet.read_table(p, columns=["a", "nope"])
    # a pruned loader fingerprints differently from the full load, and
    # the full load's payload is unchanged (old manifests keep hitting)
    from repro.core import NodeSpec, node_fingerprint
    fp_full = node_fingerprint(NodeSpec("n", source=p), [])
    fp_sub = node_fingerprint(
        NodeSpec("n", source=p, columns=("a", "s")), [])
    assert fp_full is not None and fp_sub is not None
    assert fp_full != fp_sub


def test_diff_rerun_recomputes_only_affected_cone(tmp_path):
    """Differential cache over compiled plans: rewriting the customers
    source re-executes only its cone (scan_customers, the shared
    filter_join, both marts); the orders scan adopts from the manifest."""
    tmp = str(tmp_path)
    root = os.path.join(tmp, "cache")
    rng = np.random.default_rng(7)
    po, pc, _, _ = _star(tmp, rng, 200, 16, "int", 0.0)
    plans = _marts_plans(po, pc)

    def run():
        fingerprint.reset_caches()
        store = BufferStore(backing="file", root=root)
        rm = ResourceManager(store, RMConfig(cache_root=root))
        ex = make_executor(store, rm)
        cp = compile_plans(plans, optimize=True)
        ex.run([cp.dag])
        for s in cp.sinks:
            cp.dag.nodes[cp.sinks[s]].output.release()
        counts = (ex.node_runs, ex.cache_hits)
        ex.close()
        store.close()
        return counts

    runs_cold, hits_cold = run()
    assert runs_cold == 5 and hits_cold == 0
    # rewrite customers ONLY (different country mapping, same schema)
    rng2 = np.random.default_rng(8)
    zarquet.write_table(pc, Table.from_pydict({
        "cust": np.arange(16, dtype=np.int64),
        "country": Column.from_strings(
            [f"c{int(v)}" for v in rng2.integers(0, 4, 16)]),
        "segment": Column.from_strings(
            [f"s{int(v)}" for v in rng2.integers(0, 3, 16)]),
        "extra": rng2.random(16)}))
    runs_diff, hits_diff = run()
    assert (runs_diff, hits_diff) == (4, 1), \
        f"expected (4 runs, 1 hit), got ({runs_diff}, {hits_diff})"


# ---------------------------------------------------------------------------
# plan-layer correctness regressions (streaming-ingest PR)
# ---------------------------------------------------------------------------

def test_scan_key_ignores_column_spelling_order(tmp_path):
    """A scan's identity is WHICH columns it loads, never the order the
    user spelled them (read_table assembles in footer order regardless):
    reordered spellings must be one loader, not double loaded bytes."""
    rng = np.random.default_rng(11)
    po, _, o_pd, _ = _star(str(tmp_path), rng, 80, 8, "int", 0.0)
    s1 = Scan(po, ("pad", "amount"))
    s2 = Scan(po, ("amount", "pad"))
    assert s1.key() == s2.key()
    # schema() is footer order (cust, amount, pad) restricted to the set
    assert s1.schema() == s2.schema() == ["amount", "pad"]
    # a different SUBSET is still a different loader
    assert Scan(po, ("amount",)).key() != s1.key()
    plans = {
        "hi": scan(po, columns=["pad", "amount"]).filter(col("amount") > 3),
        "lo": scan(po, columns=["amount", "pad"]).filter(col("amount") < 0),
    }
    for optimize in (False, True):
        cp = compile_plans(plans, optimize=optimize)
        loaders = [n for n in cp.dag.nodes.values() if n.spec.source]
        # naive lowers per-occurrence (the hand-wired baseline); the
        # optimizer's key()-memo collapses the reordered spellings to ONE
        assert len(loaders) == (1 if optimize else 2), \
            f"reordered scans compiled to {len(loaders)} loaders"
        store, ex = _thread_env(tmp_path, f"s1-{optimize}")
        out = _run_plans(store, ex, plans, optimize)
        store.close()
        assert out["hi"]["amount"] == \
            [v for v in o_pd["amount"] if v > 3]
        assert out["lo"]["amount"] == \
            [v for v in o_pd["amount"] if v < 0]


def test_filter_over_project_fails_in_both_modes(tmp_path):
    """A predicate over a column the child does not produce is an
    invalid plan.  It must fail identically whether or not the optimizer
    runs — pushdown must never 'repair' it by commuting the filter below
    the Project that dropped the column.  The check fires at plan build
    (before either mode is even chosen)."""
    rng = np.random.default_rng(12)
    po, _, o_pd, _ = _star(str(tmp_path), rng, 60, 8, "int", 0.0)
    base = scan(po).select("amount")
    with pytest.raises(KeyError, match=r"no such column\(s\) \['pad'\]"):
        base.filter(col("pad") > lit(1.0))
    # the same shape over a produced column runs in both modes
    good = base.filter(col("amount") > lit(1.0))
    outs = {}
    for optimize in (False, True):
        store, ex = _thread_env(tmp_path, f"s2-{optimize}")
        outs[optimize] = _run_plans(store, ex, {"q": good}, optimize)["q"]
        store.close()
    assert outs[False] == outs[True]
    assert outs[True]["amount"] == [v for v in o_pd["amount"] if v > 1.0]


def test_utf8_numeric_comparison_raises_named_typeerror():
    """Kind-mismatched comparisons die with a TypeError naming both
    sides and their kinds (the ops.hash_keys contract), never a bare
    AssertionError from the byte-compare internals."""
    b = Table.from_pydict({
        "s": Column.from_strings(["x", "y", "z"]),
        "a": np.arange(3, dtype=np.int64)}).combine().batches[0]
    with pytest.raises(TypeError, match=r"'s' vs 'a': utf8 vs prim"):
        eval_predicate(b, col("s") == col("a"))
    with pytest.raises(TypeError, match=r"column 's' is utf8 but lit\(3\)"):
        eval_predicate(b, col("s") == lit(3))
    with pytest.raises(TypeError,
                       match=r"column 'a' is prim but lit\('x'\)"):
        eval_predicate(b, col("a") == lit("x"))
    # matched kinds still work
    assert eval_predicate(b, col("s") == lit("y")).tolist() == \
        [False, True, False]


def test_div_by_zero_semantics_and_null_tests(tmp_path):
    """Expression eval is warning-clean under -W error: x/0 follows
    IEEE (±inf, 0/0 = NaN) and NaN fails every comparison.  lit(None)
    is rejected with a pointer to the null tests, and
    is_null()/is_not_null() work end-to-end on prim AND utf8 columns."""
    import warnings
    b = Table.from_pydict({
        "a": np.array([-2.0, 0.0, 3.0]),
        "d": np.zeros(3)}).combine().batches[0]
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        m = eval_predicate(b, (col("a") / col("d")) > lit(1.0))
    # -2/0 -> -inf (False), 0/0 -> NaN (False), 3/0 -> +inf (True)
    assert m.tolist() == [False, False, True]
    with pytest.raises(TypeError, match="is_null"):
        lit(None)
    with pytest.raises(TypeError, match="is_null"):
        col("a") == None                        # noqa: E711 - the trap
    assert repr(col("a").is_null()) == "col('a').is_null()"
    assert repr(col("a").is_not_null()) == "col('a').is_not_null()"
    rng = np.random.default_rng(13)
    n = 60
    av = rng.random(n) >= 0.3
    sv = rng.random(n) >= 0.4
    t = Table.from_pydict({
        "a": Column.primitive(rng.integers(0, 9, n).astype(np.float64),
                              pack_validity(av)),
        "s": Column.from_strings([f"v{i % 5}" for i in range(n)],
                                 validity=pack_validity(sv))})
    po = os.path.join(str(tmp_path), "nulls.zq")
    zarquet.write_table(po, t)
    t_pd = t.to_pydict()
    cases = [(col("a").is_null(), [i for i in range(n) if not av[i]]),
             (col("s").is_not_null(), [i for i in range(n) if sv[i]])]
    for ci, (pred, keep) in enumerate(cases):
        outs = {}
        for optimize in (False, True):
            store, ex = _thread_env(tmp_path, f"s4-{ci}-{optimize}")
            outs[optimize] = _run_plans(
                store, ex, {"q": scan(po).filter(pred)}, optimize)["q"]
            store.close()
        assert outs[False] == outs[True]
        assert outs[True]["s"] == [t_pd["s"][i] for i in keep]
        assert outs[True]["a"] == [t_pd["a"][i] for i in keep]


if HAVE_HYPOTHESIS:
    @settings(max_examples=12, deadline=None)
    @given(seed=st.integers(0, 10_000),
           kind=st.sampled_from(["int", "utf8"]),
           perm=st.permutations(["cust", "amount", "pad"]),
           pred_i=st.integers(0, 3))
    def test_property_plan_fixes_optimized_equals_naive(
            tmp_path_factory, seed, kind, perm, pred_i):
        """optimized == naive == per-row reference on plans mixing the
        regression surfaces: reordered scan columns, filter over a
        project, utf8/numeric predicates, div-by-zero and null tests."""
        tmp = str(tmp_path_factory.mktemp("plan-fix-prop"))
        rng = np.random.default_rng(seed)
        po, _, o_pd, _ = _star(tmp, rng, 90, 8, kind, 0.1)
        preds = [
            col("amount") > lit(0),
            (col("amount") / lit(0)) > lit(1),      # +inf/NaN/-inf path
            col("cust").is_not_null(),
            (col("cust") == lit("k03")) if kind == "utf8"
            else (col("cust") > lit(2)),
        ]
        plan = (scan(po, columns=list(perm))
                .select("cust", "amount").filter(preds[pred_i]))
        store = BufferStore(swap_dir=os.path.join(tmp, "swap"))
        rm = ResourceManager(store, RMConfig())
        ex = Executor(store, rm)
        try:
            naive = _run_plans(store, ex, {"q": plan}, optimize=False)["q"]
            opt = _run_plans(store, ex, {"q": plan}, optimize=True)["q"]
        finally:
            store.close()
        assert opt == naive

        def keep(i):
            amt, cust = o_pd["amount"][i], o_pd["cust"][i]
            if pred_i in (0, 1):
                # amount is never null; amt/0 > 1 iff +inf iff amt > 0
                return amt > 0
            if pred_i == 2:
                return cust is not None
            if kind == "utf8":
                return cust == "k03"
            return cust is not None and cust > 2

        rows = [i for i in range(len(o_pd["amount"])) if keep(i)]
        assert opt["cust"] == [o_pd["cust"][i] for i in rows]
        assert opt["amount"] == [o_pd["amount"][i] for i in rows]

"""Overload resilience: backpressure, shedding, deadline enforcement,
tenant budgets, and the submit/Ticket serving plane.

Every shed/miss is a *typed outcome* on the DAG (never an exception in a
request thread), and the serve_stats ledger must balance exactly:
``offered == admitted + shed`` and
``admitted == completed + deadline_misses + poisoned + failed``.
"""

import threading
import time

import numpy as np
import pytest

from repro.core import (BufferStore, DAG, Executor, NodeSpec, RMConfig,
                        ResourceManager, SipcReader, Table, make_executor)
from repro.core import ops, zarquet
from repro.core.dag import COMPLETE


def slow_op(tables):
    time.sleep(0.4)
    return tables[0]


def quick_op(tables):
    return ops.add_columns_compute(tables[0], "i0", "i1", "n0")


def evil_op(tables):
    raise RuntimeError("should never have been admitted")


@pytest.fixture()
def source(tmp_path):
    path = str(tmp_path / "t.zq")
    zarquet.write_table(path, zarquet.gen_int_table(4, 1 << 14, seed=7))
    return path


def make_env(tmp_path, workers=1, tag="", backing="ram", **cfg):
    store = BufferStore(backing=backing,
                        swap_dir=str(tmp_path / f"swap{tag}"))
    rm = ResourceManager(store, RMConfig(workers=workers, **cfg))
    ex = make_executor(store, rm, workers=workers)
    return store, rm, ex


def one_dag(path, name="d", est=1 << 16, fn=quick_op, tenant=None,
            deadline=None):
    return DAG([
        NodeSpec("load", source=path, est_mem=est),
        NodeSpec("op", fn=fn, deps=["load"], est_mem=est // 2),
    ], name=name, tenant=tenant, deadline=deadline)


def check_ledger(rm):
    s = rm.serve_stats
    assert s["offered"] == s["admitted"] + s["shed"], s
    assert s["shed"] == (s["shed_overloaded"] + s["shed_deadline"] +
                         s["shed_tenant_budget"] + s["shed_quarantined"]), s
    assert s["admitted"] == (s["completed"] + s["deadline_misses"] +
                             s["poisoned"] + s["failed"]), s


# ---------------------------------------------------------------------------
# reservation invariants
# ---------------------------------------------------------------------------

def test_unbalanced_unreserve_raises(tmp_path, source):
    store, rm, ex = make_env(tmp_path)
    dag = one_dag(source)
    st = dag.nodes["load"]
    rm.admission.reserve(st)
    rm.admission.unreserve(st)               # balanced: fine
    assert rm.admission.reserved == 0
    with pytest.raises(RuntimeError, match="unbalanced"):
        rm.admission.unreserve(st)           # negative balance: loud
    store.close()


def test_tenant_reservations_tracked_per_tenant(tmp_path, source):
    store, rm, ex = make_env(tmp_path)
    a = one_dag(source, "a", tenant="alpha")
    b = one_dag(source, "b", tenant="beta")
    rm.admission.reserve(a.nodes["load"])
    rm.admission.reserve(b.nodes["load"])
    assert rm.admission.tenant_reserved == {"alpha": 1 << 16,
                                            "beta": 1 << 16}
    rm.admission.unreserve(a.nodes["load"])
    assert "alpha" not in rm.admission.tenant_reserved
    # releasing beta's reservation against alpha's books is unbalanced
    with pytest.raises(RuntimeError, match="unbalanced"):
        rm.admission.unreserve(a.nodes["load"])
    store.close()


# ---------------------------------------------------------------------------
# shedding decision table (offer() directly — no executor needed)
# ---------------------------------------------------------------------------

def test_offer_sheds_when_queue_full(tmp_path, source):
    store, rm, ex = make_env(tmp_path, max_queue_depth=2)
    assert rm.admission.offer(one_dag(source, "a")) is None
    assert rm.admission.offer(one_dag(source, "b")) is None
    d = one_dag(source, "c")
    assert rm.admission.offer(d) == "overloaded"
    assert d.outcome == "shed:overloaded" and d.cancelled
    assert d.runnable() == [] and d.all_done()   # cancelled: nothing runs
    assert rm.serve_stats["shed_overloaded"] == 1
    assert rm.serve_stats["offered"] == 3
    store.close()


def test_offer_sheds_impossible_tenant_budget(tmp_path, source):
    store, rm, ex = make_env(tmp_path,
                             tenant_budgets={"small": 1 << 10})
    d = one_dag(source, "d", est=1 << 20, tenant="small")
    assert rm.admission.offer(d) == "tenant_budget"
    assert d.outcome == "shed:tenant_budget"
    # an unbudgeted tenant sails through
    assert rm.admission.offer(one_dag(source, "e", est=1 << 20,
                                      tenant="other")) is None
    store.close()


def test_offer_sheds_expired_deadline_on_arrival(tmp_path, source):
    store, rm, ex = make_env(tmp_path, enforce_deadlines=True)
    d = one_dag(source, "late", deadline=time.monotonic() - 1.0)
    assert rm.admission.offer(d) == "deadline"
    assert d.outcome == "shed:deadline"
    # without enforcement a deadline stays an ordering hint: admitted
    store2 = BufferStore(swap_dir=str(tmp_path / "swap2"))
    rm2 = ResourceManager(store2, RMConfig())
    assert rm2.admission.offer(
        one_dag(source, "hint", deadline=time.monotonic() - 1.0)) is None
    store.close()
    store2.close()


def test_offer_sheds_hopeless_deadline_under_overload(tmp_path, source):
    # threshold 0 makes any queue+memory state count as overload, so the
    # ETA test is exercised deterministically
    store, rm, ex = make_env(tmp_path, max_queue_depth=10,
                             memory_limit=1 << 30,
                             enforce_deadlines=True,
                             overload_threshold=0.0)
    rm.admission.note_latency(1.0)           # 1s/node EWMA
    assert rm.admission.offer(one_dag(source, "backlog")) is None
    d = one_dag(source, "hopeless", deadline=time.monotonic() + 0.5)
    # eta ~ now + 1.0s * (2 backlog + 2 own) / 1 worker >> deadline
    assert rm.admission.offer(d) == "deadline"
    # a comfortable deadline is admitted under the same overload
    assert rm.admission.offer(
        one_dag(source, "fine", deadline=time.monotonic() + 60)) is None
    store.close()


def test_offer_sheds_quarantined_op(tmp_path, source):
    store, rm, ex = make_env(tmp_path)
    rm.quarantined.add(ResourceManager.poison_key(evil_op))
    d = one_dag(source, "q", fn=evil_op)
    assert rm.admission.offer(d) == "quarantined"
    assert d.outcome == "shed:quarantined"
    # loaders are never quarantined (poison_key(None) is None)
    assert ResourceManager.poison_key(None) is None
    assert rm.admission.offer(one_dag(source, "ok")) is None
    store.close()


# ---------------------------------------------------------------------------
# submit / Ticket serving plane
# ---------------------------------------------------------------------------

def test_submit_runs_and_resolves_tickets(tmp_path, source):
    store, rm, ex = make_env(tmp_path, workers=2)
    tickets = [ex.submit(one_dag(source, f"d{i}")) for i in range(4)]
    outcomes = [t.wait(timeout=60) for t in tickets]
    assert outcomes == ["completed"] * 4
    assert all(t.done() and t.latency is not None for t in tickets)
    ex.drain(timeout=10)
    check_ledger(rm)
    assert rm.serve_stats["completed"] == 4
    assert rm.admission.reserved == 0 and rm.admission.queued == {}
    store.close()


def test_submit_shed_resolves_immediately(tmp_path, source):
    store, rm, ex = make_env(tmp_path, enforce_deadlines=True)
    t = ex.submit(one_dag(source, "late",
                          deadline=time.monotonic() - 1.0))
    assert t.done()                          # resolved on the spot
    assert t.outcome == "shed:deadline"
    assert t.wait(timeout=0) == "shed:deadline"
    ex.drain(timeout=10)
    check_ledger(rm)
    store.close()


@pytest.mark.parametrize("mode", ["thread", "process"])
def test_deadline_enforced_mid_run(tmp_path, source, mode):
    """A DAG whose deadline passes while a node runs is cancelled
    cooperatively: the in-flight node drains, downstream nodes never
    start, reservations release, outcome is 'deadline_miss'."""
    backing = "file" if mode == "process" else "ram"
    store, rm, ex = make_env(tmp_path, workers=2, backing=backing,
                             workers_mode=mode, enforce_deadlines=True)
    dag = DAG([
        NodeSpec("load", source=source, est_mem=1 << 20),
        NodeSpec("slow", fn=slow_op, deps=["load"], est_mem=1 << 20),
        NodeSpec("post", fn=quick_op, deps=["slow"], est_mem=1 << 20),
    ], name="misses", deadline=time.monotonic() + 0.15)
    ok = one_dag(source, "ok", est=1 << 20)
    try:
        ex.run([dag, ok])
        assert dag.outcome == "deadline_miss" and dag.cancelled
        assert dag.nodes["post"].status not in COMPLETE
        assert ok.all_done() and not ok.cancelled   # bystander unharmed
        assert rm.serve_stats["deadline_misses"] == 1
        assert rm.admission.reserved == 0
        assert ex._inflight == {}
    finally:
        ex.close()
        store.close()


def test_deadline_expires_while_queued(tmp_path, source):
    """submit -> admitted -> the deadline lapses before its wave runs:
    counted as a miss (it was admitted), not a shed."""
    store, rm, ex = make_env(tmp_path, workers=1, enforce_deadlines=True)
    gate = threading.Event()

    def block_op(tables):
        gate.wait(5.0)
        return tables[0]

    first = ex.submit(one_dag(source, "blocker", fn=block_op))
    time.sleep(0.05)                         # dispatcher picks up wave 1
    doomed = ex.submit(one_dag(source, "doomed",
                               deadline=time.monotonic() + 0.1))
    time.sleep(0.2)                          # deadline lapses in queue
    gate.set()
    assert first.wait(timeout=60) == "completed"
    assert doomed.wait(timeout=60) == "deadline_miss"
    ex.drain(timeout=10)
    check_ledger(rm)
    assert rm.serve_stats["deadline_misses"] == 1
    store.close()


def test_tenant_budget_isolation_under_concurrency(tmp_path, source):
    """A burst tenant whose nodes cannot fit its budget is shed; the
    well-behaved tenant's requests all complete — from concurrent
    submitter threads, like a real frontend."""
    store, rm, ex = make_env(
        tmp_path, workers=2,
        tenant_budgets={"burst": 1 << 12, "steady": 1 << 26})
    results = {}

    def client(tenant, i, est):
        t = ex.submit(one_dag(source, f"{tenant}{i}", est=est,
                              tenant=tenant))
        results[(tenant, i)] = t.wait(timeout=60)

    threads = [threading.Thread(target=client, args=("steady", i, 1 << 16))
               for i in range(3)]
    threads += [threading.Thread(target=client, args=("burst", i, 1 << 20))
                for i in range(3)]
    for th in threads:
        th.start()
    for th in threads:
        th.join()
    ex.drain(timeout=10)
    assert all(results[("steady", i)] == "completed" for i in range(3))
    assert all(results[("burst", i)] == "shed:tenant_budget"
               for i in range(3))
    check_ledger(rm)
    assert rm.admission.reserved == 0
    store.close()


def test_fair_share_no_starvation_under_burst(tmp_path, source):
    """Fair scheduling + submit: a single-DAG tenant is not starved
    behind a burst tenant's pile — everything completes and the ledger
    balances (regression guard for the tenant plumbing under the
    dispatcher's wave batching)."""
    store, rm, ex = make_env(tmp_path, workers=2, schedule="fair")
    tickets = [ex.submit(one_dag(source, f"burst{i}", tenant="burst"))
               for i in range(5)]
    tickets.append(ex.submit(one_dag(source, "solo", tenant="solo")))
    outcomes = [t.wait(timeout=60) for t in tickets]
    assert outcomes == ["completed"] * 6
    ex.drain(timeout=10)
    check_ledger(rm)
    store.close()


def test_mixed_overload_ledger_balances(tmp_path, source):
    """Concurrent mixed offers — completions, queue sheds, deadline
    sheds — always leave serve_stats internally consistent."""
    store, rm, ex = make_env(tmp_path, workers=2, max_queue_depth=3,
                             enforce_deadlines=True)
    tickets = []
    lock = threading.Lock()

    def client(i):
        if i % 3 == 2:
            d = one_dag(source, f"late{i}",
                        deadline=time.monotonic() - 1.0)
        else:
            d = one_dag(source, f"d{i}")
        t = ex.submit(d)
        with lock:
            tickets.append(t)

    threads = [threading.Thread(target=client, args=(i,))
               for i in range(12)]
    for th in threads:
        th.start()
    for th in threads:
        th.join()
    outcomes = [t.wait(timeout=60) for t in tickets]
    assert len(outcomes) == 12 and None not in outcomes
    assert all(o == "completed" or o.startswith("shed:")
               or o == "deadline_miss" for o in outcomes)
    ex.drain(timeout=10)
    check_ledger(rm)
    assert rm.admission.reserved == 0 and rm.admission.queued == {}
    store.close()


# ---------------------------------------------------------------------------
# eviction-storm observability
# ---------------------------------------------------------------------------

def test_eviction_storm_bound_is_counted(tmp_path, source):
    store, rm, ex = make_env(tmp_path, memory_limit=3 << 15,
                             policy="rollback")
    dags = [one_dag(source, f"c{i}", est=1 << 15) for i in range(8)]
    ex.run(dags)
    assert all(d.all_done() for d in dags)
    # storm_breaks is a legal-zero counter, but it must exist and never
    # go negative — the bench asserts on it under real pressure
    assert rm.evictions["storm_breaks"] >= 0
    store.close()

"""Unit tests for BufferStore / KernelZero (de-anonymization, swap)."""
import os

import numpy as np
import pytest

from repro.core import (AnonRegion, BufferStore, KernelZero, OOMError, PAGE,
                        alloc_aligned)


@pytest.fixture()
def store(tmp_path):
    s = BufferStore(swap_dir=str(tmp_path / "swap"))
    yield s
    s.close()


def test_alloc_aligned():
    a = alloc_aligned(10000)
    assert a.__array_interface__["data"][0] % PAGE == 0
    assert a.nbytes == 10000


def test_deanon_zero_copy_aligned(store):
    kz = KernelZero(store)
    cg = store.new_cgroup("sb")
    f = kz.new_file(cg)
    src = alloc_aligned(8 * PAGE).view(np.int64)
    src[:] = np.arange(src.size)
    before = store.stats.bytes_copied
    off, n = kz.deanon(f, src)
    assert n == 8 * PAGE
    # aligned input: no partial pages -> zero bytes copied
    assert store.stats.bytes_copied == before
    assert store.stats.bytes_deanon >= 8 * PAGE
    # the file view IS the source memory
    view = f.read(off, n)
    assert view.__array_interface__["data"][0] == \
        src.view(np.uint8).__array_interface__["data"][0]
    assert np.array_equal(view.view(np.int64), np.arange(src.size))
    # transferred memory is immutable now
    with pytest.raises(ValueError):
        src[0] = 1


def test_deanon_partial_pages_accounted(store):
    kz = KernelZero(store)
    cg = store.new_cgroup("sb")
    f = kz.new_file(cg)
    base = alloc_aligned(4 * PAGE)
    src = base[100:100 + 2 * PAGE]  # unaligned head/tail
    off, n = kz.deanon(f, src)
    assert store.stats.partial_page_bytes > 0
    assert np.array_equal(f.read(off, n), src)


def test_writer_copy_is_a_real_copy(store):
    kz = KernelZero(store)
    cg = store.new_cgroup("sb")
    f = kz.new_file(cg)
    src = np.arange(1024, dtype=np.int64)
    off, n = kz.writer_copy(f, src)
    assert store.stats.bytes_copied >= n
    view = f.read(off, n)
    assert view.__array_interface__["data"][0] != \
        src.view(np.uint8).__array_interface__["data"][0]
    assert np.array_equal(view.view(np.int64), src)


def test_charge_accounting_transfer(store):
    kz = KernelZero(store)
    sandbox_cg = store.new_cgroup("sb")
    file_cg = store.new_cgroup("files")
    arr = alloc_aligned(PAGE * 4)
    region = AnonRegion(arr, sandbox_cg)
    assert sandbox_cg.charged == PAGE * 4
    f = kz.new_file(file_cg)
    kz.deanon(f, region)
    assert sandbox_cg.charged == 0            # charge moved
    assert file_cg.charged == PAGE * 4
    assert store.global_charged == PAGE * 4    # no double count


def test_swap_out_in_roundtrip(store):
    kz = KernelZero(store)
    cg = store.new_cgroup("sb")
    f = kz.new_file(cg)
    src = alloc_aligned(PAGE * 16)
    src_vals = np.random.default_rng(0).integers(0, 255, PAGE * 16,
                                                 dtype=np.uint8)
    src[:] = src_vals
    off, n = kz.deanon(f, src)
    assert store.swap_out_file(f.file_id) == n
    assert cg.charged == 0
    assert cg.swap_charged == n
    view = f.read(off, n)              # triggers foreground swapin
    assert store.stats.fg_swapin_pages == 16
    assert np.array_equal(view, src_vals)
    assert cg.charged == n


def test_direct_swap_no_io(store):
    kz = KernelZero(store)
    cg = store.new_cgroup("sb")
    arr = alloc_aligned(PAGE * 8)
    arr[:] = 7
    region = AnonRegion(arr, cg)
    region.swap_out(store)
    io_before = store.stats.swapin_bytes
    f = kz.new_file(cg)
    off, n = kz.deanon(f, region, direct_swap=True)
    # no swapin happened: the swap entry moved into the file
    assert store.stats.swapin_bytes == io_before
    assert store.stats.direct_swap_bytes == PAGE * 8
    # reading the file later swaps in and sees the data
    view = f.read(off, n)
    assert np.all(view == 7)


def test_indirect_swap_does_io(store):
    kz = KernelZero(store)
    cg = store.new_cgroup("sb")
    arr = alloc_aligned(PAGE * 8)
    arr[:] = 9
    region = AnonRegion(arr, cg)
    region.swap_out(store)
    f = kz.new_file(cg)
    io_before = store.stats.swapin_bytes
    kz.deanon(f, region, direct_swap=False)
    assert store.stats.swapin_bytes == io_before + PAGE * 8


def test_cgroup_limit_triggers_reclaim(tmp_path):
    store = BufferStore(swap_dir=str(tmp_path / "swap"))
    kz = KernelZero(store)
    cg = store.new_cgroup("sb", limit=PAGE * 8)
    f = kz.new_file(cg)
    for i in range(4):
        kz.deanon(f, alloc_aligned(PAGE * 4))
    # limit 8 pages, 16 appended -> at least half swapped out
    assert cg.charged <= PAGE * 8
    assert store.stats.swapout_bytes >= PAGE * 8
    store.close()


def test_system_oom_without_kswap(tmp_path):
    store = BufferStore(swap_dir=str(tmp_path / "swap"),
                        system_limit=PAGE * 8)
    store.kswap_enabled = False
    kz = KernelZero(store)
    cg = store.new_cgroup("sb")
    f = kz.new_file(cg)
    with pytest.raises(OOMError):
        for _ in range(4):
            kz.deanon(f, alloc_aligned(PAGE * 4))
    store.close()


def test_kswap_avoids_oom(tmp_path):
    store = BufferStore(swap_dir=str(tmp_path / "swap"),
                        system_limit=PAGE * 8)
    kz = KernelZero(store)
    cg = store.new_cgroup("sb")
    f = kz.new_file(cg)
    for _ in range(4):
        kz.deanon(f, alloc_aligned(PAGE * 4))
    assert store.stats.swapout_events > 0
    store.close()


def test_file_delete_frees_charge(store):
    kz = KernelZero(store)
    cg = store.new_cgroup("sb")
    f = kz.new_file(cg)
    kz.deanon(f, alloc_aligned(PAGE * 4))
    assert cg.charged == PAGE * 4
    store.delete_file(f.file_id)
    assert cg.charged == 0

"""Checkpoint store (content dedup, async, elastic restore), fault
tolerance policies, gradient compression, data pipeline."""
import os

import numpy as np
import pytest

from repro.runtime.checkpoint import CheckpointStore
from repro.runtime.fault import (FaultConfig, FleetMonitor, RestartPolicy)


@pytest.fixture()
def store(tmp_path):
    s = CheckpointStore(str(tmp_path / "ckpt"), async_io=True)
    yield s
    s.close()


def tree(step):
    return {"params": {"w": np.full((64, 64), float(step)),
                       "frozen": np.ones((128,))},
            "opt": {"mu": np.zeros((64, 64)), "count": np.array(step)}}


def test_checkpoint_roundtrip(store):
    t = tree(1)
    store.save(1, t)
    store.flush()
    out, mani = store.restore(1, like=t)
    for a, b in zip(np.asarray(out["params"]["w"]).ravel(),
                    t["params"]["w"].ravel()):
        assert a == b
    assert mani["step"] == 1


def test_checkpoint_content_dedup(store):
    store.save(1, tree(1))
    store.flush()
    w1 = store.stats["blobs_written"]
    store.save(2, tree(2))   # 'frozen' and 'mu' unchanged -> reused
    store.flush()
    assert store.stats["blobs_reused"] >= 2
    assert store.stats["blobs_written"] - w1 <= 2


def test_checkpoint_latest_and_restore_like(store):
    store.save(5, tree(5))
    store.save(9, tree(9))
    store.flush()
    assert store.latest_step() == 9
    out, mani = store.restore(like=tree(0))
    assert float(np.asarray(out["opt"]["count"])) == 9


def test_fleet_failure_detection():
    m = FleetMonitor(4, FaultConfig(grace_steps=2))
    for step in range(6):
        for w in range(4):
            if w == 3 and step > 1:
                continue          # worker 3 dies at step 2
            m.heartbeat(w, step, 0.1)
    failed = m.detect_failures()
    assert failed == [3]
    assert m.healthy() == 3


def test_straggler_detection():
    m = FleetMonitor(5, FaultConfig())
    for step in range(10):
        for w in range(5):
            m.heartbeat(w, step, 1.0 if w != 2 else 3.0)
    assert m.detect_stragglers() == [2]


def test_restart_policy(tmp_path):
    cs = CheckpointStore(str(tmp_path / "c"), async_io=False)
    cs.save(42, {"w": np.ones(4)})
    m = FleetMonitor(4, FaultConfig(grace_steps=1))
    for s in range(4):
        for w in range(4):
            if w == 0 and s > 0:
                continue
            m.heartbeat(w, s, 0.1)
    pol = RestartPolicy(cs, m, min_workers=3)
    plan = pol.plan()
    assert plan["action"] == "restart"
    assert plan["from_step"] == 42
    # lose two more -> elastic shrink
    m.workers[1].failed = True
    m.workers[2].failed = True
    plan = RestartPolicy(cs, m, min_workers=3).plan()
    assert plan["action"] == "elastic_shrink"
    assert plan["new_size"] == 1
    cs.close()


def test_grad_compression_unbiased_over_time():
    """int8 + error feedback: accumulated error stays bounded."""
    import jax
    import jax.numpy as jnp
    from repro.runtime.compression import compress_grads_psum, init_residual

    grads = {"w": jnp.asarray(np.random.default_rng(0)
                              .normal(size=(256,)) * 1e-3, jnp.float32)}
    residual = init_residual(grads)

    from repro.compat import shard_map

    def step(g, r):
        f = shard_map(
            lambda gg, rr: compress_grads_psum(gg, rr, "pod", n_pods=1),
            mesh=jax.make_mesh((1,), ("pod",)),
            in_specs=(jax.sharding.PartitionSpec(),) * 2,
            out_specs=(jax.sharding.PartitionSpec(),) * 2,
            check_vma=False)
        return f(g, r)

    total_true = np.zeros(256)
    total_sync = np.zeros(256)
    for i in range(20):
        synced, residual = step(grads, residual)
        total_true += np.asarray(grads["w"])
        total_sync += np.asarray(synced["w"])
    # error feedback keeps the cumulative sum close
    err = np.abs(total_true - total_sync).max()
    assert err < 2 * float(np.abs(np.asarray(grads["w"])).max())


def test_data_pipeline(tmp_path):
    from repro.data.pipeline import (PipelineConfig, ZerrowDataPipeline,
                                     make_text_shards)
    shards = make_text_shards(str(tmp_path / "c"), 2, 500, seed=1)
    pipe = ZerrowDataPipeline(shards, PipelineConfig(batch=2, seq_len=64))
    seen = 0
    first = None
    for b in pipe.batches(epochs=2):
        assert b["tokens"].shape == (2, 64)
        assert b["labels"].shape == (2, 64)
        # next-token alignment
        np.testing.assert_array_equal(b["tokens"][:, 1:],
                                      b["labels"][:, :-1])
        if first is None:
            first = b["tokens"].copy()
        seen += 1
    assert seen >= 4
    stats = pipe.stats()
    assert stats["loads"] == 2          # epoch 2 hit the DeCache
    assert stats["decache_hits"] >= 2
    pipe.close()

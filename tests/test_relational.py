"""Property suite: ``ops.join`` / ``ops.group_by`` bit-identical to naive
per-row references on seeded random tables — mixed dtypes, null keys,
duplicate keys, empty build/probe sides, dict-encoded keys — plus the
zero-copy invariants the relational engine claims:

  * join payload dictionaries ride through as SIPC *reshares* (every
    dictionary BufRef on the join output has ``reshared=True``; the only
    copied bytes anywhere are the page-edge de-anonymization tax on
    genuinely-new buffers — never a data copy);
  * thread and Flight process workers produce bit-identical results,
    with worker-side reshare stats propagated to the parent;
  * differential reruns over a join recompute only the affected side.

References are plain Python loops over ``to_pydict`` rows.  Seeded-numpy
generation runs everywhere; when ``hypothesis`` is installed the join
property also runs under real strategies.
"""
import functools
import os
import tempfile

import numpy as np
import pytest

from repro.core import (BufferStore, DAG, Executor, NodeSpec, RMConfig,
                        ResourceManager, SipcReader, make_executor)
from repro.core import ops, zarquet
from repro.core.arrow import Column, Table, pack_validity

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


# ---------------------------------------------------------------------------
# random-table generation
# ---------------------------------------------------------------------------

def _key_column(rng, n, kind, card=4, null_frac=0.0):
    """A key column of small cardinality (duplicates on purpose)."""
    validity = None
    if null_frac > 0 and n:
        validity = pack_validity(rng.random(n) >= null_frac)
    if kind == "int":
        return Column.primitive(
            rng.integers(0, card, size=n).astype(np.int64), validity)
    if kind == "float":
        vals = rng.integers(0, card, size=n).astype(np.float64) / 2
        return Column(Column.primitive(vals).type, n, vals,
                      validity=validity)
    strs = [f"k{int(v)}" for v in rng.integers(0, card, size=n)]
    c = Column.from_strings(strs, validity=validity)
    if kind == "dict":
        from repro.core import vkernels
        codes, uoff, uvals = vkernels.dict_encode_var(c.offsets, c.values)
        return Column.dictionary_encoded(codes, Column.utf8(uoff, uvals),
                                         validity=validity)
    return c


def _payload_column(rng, n, kind, null_frac=0.2):
    validity = None
    if null_frac > 0 and n:
        validity = pack_validity(rng.random(n) >= null_frac)
    if kind == "int":
        return Column.primitive(
            rng.integers(-50, 50, size=n).astype(np.int64), validity)
    if kind == "float":
        vals = np.round(rng.random(n), 3)          # no NaN, exact halves
        return Column(Column.primitive(vals).type, n, vals,
                      validity=validity)
    if kind == "bool":
        return Column.primitive(rng.random(n) < 0.5, validity)
    return Column.from_strings(
        ["v%d" % v for v in rng.integers(0, 9, size=n)], validity)


def _rand_table(rng, n, key_kinds, payload_kinds, prefix,
                key_nulls=0.15):
    cols = {}
    for i, kk in enumerate(key_kinds):
        cols[f"k{i}"] = _key_column(rng, n, kk, null_frac=key_nulls)
    for i, pk in enumerate(payload_kinds):
        cols[f"{prefix}{i}"] = _payload_column(rng, n, pk)
    return Table.from_pydict(cols)


# ---------------------------------------------------------------------------
# naive per-row references
# ---------------------------------------------------------------------------

def ref_join(ld, rd, keys, how, suffix="_right"):
    """Nested-loop join over to_pydict row dicts.  Null keys never
    match; output is left-major with right matches in right-row order."""
    lcols, rcols = list(ld), list(rd)
    rpay = [c for c in rcols if c not in keys]
    names = {c: (c + suffix if c in lcols else c) for c in rpay}
    out = {c: [] for c in lcols}
    out.update({names[c]: [] for c in rpay})
    n_l = len(ld[lcols[0]]) if lcols else 0
    n_r = len(rd[rcols[0]]) if rcols else 0
    for i in range(n_l):
        key = tuple(ld[k][i] for k in keys)
        matches = []
        if all(v is not None for v in key):
            for j in range(n_r):
                rkey = tuple(rd[k][j] for k in keys)
                if all(v is not None for v in rkey) and rkey == key:
                    matches.append(j)
        if not matches:
            if how == "left":
                for c in lcols:
                    out[c].append(ld[c][i])
                for c in rpay:
                    out[names[c]].append(None)
            continue
        for j in matches:
            for c in lcols:
                out[c].append(ld[c][i])
            for c in rpay:
                out[names[c]].append(rd[c][j])
    return out


def _ref_agg(rows, how):
    """One aggregate over a group's rows (None = null), reproducing the
    kernels bit-for-bit: float sums accumulate left-to-right in row
    order as float64 (the ``np.bincount`` contract), integer sums widen
    to int64 (exact in any order)."""
    vals = [v for v in rows if v is not None]
    if how == "count":
        return len(vals)
    if not vals:
        return None
    if how == "min":
        return min(vals)
    if how == "max":
        return max(vals)
    if isinstance(vals[0], bool) or isinstance(vals[0], (int, np.integer)):
        s = np.int64(0)
    else:
        s = np.float64(0.0)
    for v in vals:
        s = s + v
    if how == "mean":
        return (np.float64(s) / len(vals)).item()
    return s.item()


def ref_group_by(d, keys, aggs):
    """Dict-accumulation group-by; groups sorted by key values ascending
    (utf8 in bytes order), the null group last per column."""
    n = len(d[keys[0]])
    groups = {}
    for i in range(n):
        groups.setdefault(tuple(d[k][i] for k in keys), []).append(i)

    def sortkey(kt):
        return [(1, b"") if v is None else
                (0, v.encode() if isinstance(v, str) else v) for v in kt]

    out = {k: [] for k in keys}
    out.update({name: [] for name in aggs})
    for kt in sorted(groups, key=sortkey):
        idxs = groups[kt]
        for k, v in zip(keys, kt):
            out[k].append(v)
        for name, (col, how) in aggs.items():
            out[name].append(_ref_agg([d[col][i] for i in idxs], how))
    return out


ALL_AGGS = {"n": ("p0", "count"), "tot": ("p0", "sum"),
            "lo": ("p0", "min"), "hi": ("p0", "max"),
            "avg": ("p0", "mean")}


# ---------------------------------------------------------------------------
# join vs reference
# ---------------------------------------------------------------------------

KEY_MIXES = [("int",), ("utf8",), ("dict",), ("float",),
             ("int", "utf8"), ("dict", "int")]


@pytest.mark.parametrize("how", ["inner", "left"])
@pytest.mark.parametrize("seed", range(3))
@pytest.mark.parametrize("key_kinds", KEY_MIXES,
                         ids=["-".join(k) for k in KEY_MIXES])
def test_join_matches_reference(seed, how, key_kinds):
    rng = np.random.default_rng(seed * 101 + len(key_kinds))
    keys = [f"k{i}" for i in range(len(key_kinds))]
    l = _rand_table(rng, int(rng.integers(1, 40)), key_kinds,
                    ("int", "float", "utf8"), "l")
    r = _rand_table(rng, int(rng.integers(1, 40)), key_kinds,
                    ("float", "bool"), "r")
    got = ops.join(l, r, on=keys, how=how).to_pydict()
    want = ref_join(l.to_pydict(), r.to_pydict(), keys, how)
    assert got == want


@pytest.mark.parametrize("how", ["inner", "left"])
def test_join_empty_sides(how):
    rng = np.random.default_rng(0)
    l = _rand_table(rng, 12, ("int",), ("float",), "l")
    r = _rand_table(rng, 9, ("int",), ("utf8",), "r")
    l0, r0 = ops.slice_rows(l, 0, 0), ops.slice_rows(r, 0, 0)
    for a, b in ((l0, r), (l, r0), (l0, r0)):
        got = ops.join(a, b, on="k0", how=how).to_pydict()
        assert got == ref_join(a.to_pydict(), b.to_pydict(), ["k0"], how)


def test_join_dict_keys_match_plain_utf8():
    """A dict-encoded key column joins against a plain utf8 key column:
    equality is logical, not representational."""
    rng = np.random.default_rng(7)
    l = _rand_table(rng, 30, ("dict",), ("int",), "l")
    r = _rand_table(rng, 20, ("utf8",), ("float",), "r")
    got = ops.join(l, r, on="k0", how="left").to_pydict()
    assert got == ref_join(l.to_pydict(), r.to_pydict(), ["k0"], "left")


def test_join_utf8_keys_across_column_widths():
    """The row hash is a pure function of row bytes: a short key must
    match itself even when the other side's column holds a much longer
    row (different padded-chunk widths)."""
    l = Table.from_pydict({"k0": ["a", "x"],
                           "lv": np.arange(2, dtype=np.int64)})
    r = Table.from_pydict({"k0": ["a", "longerstring12345-beyond-chunk"],
                           "rv": np.arange(2, dtype=np.int64)})
    got = ops.join(l, r, on="k0", how="left").to_pydict()
    assert got == ref_join(l.to_pydict(), r.to_pydict(), ["k0"], "left")


def test_hash_var_skew_fallback_identical(monkeypatch):
    """The length-skew per-row fallback computes the same hash as the
    vectorized chunk path."""
    from repro.core import vkernels as vk
    c = Column.from_strings(["hello", "", "world-long-string-beyond-8B",
                             "hello", "a\x00b"])
    vec = vk.hash_var(c.offsets, c.values)
    monkeypatch.setattr(vk, "_SKEW_FLOOR", 0)
    monkeypatch.setattr(vk, "_SKEW_RATIO", 0)
    assert np.array_equal(vk.hash_var(c.offsets, c.values), vec)


def test_hash_var_all_empty_column_matches_mixed():
    """Regression: the all-rows-empty early return must apply the same
    final mix as the main path, so an empty row hashes identically
    whether or not its column has non-empty siblings."""
    from repro.core import vkernels as vk
    all_empty = Column.from_strings(["", ""])
    mixed = Column.from_strings(["", "x"])
    h_empty = vk.hash_var(all_empty.offsets, all_empty.values)
    h_mixed = vk.hash_var(mixed.offsets, mixed.values)
    assert h_empty[0] == h_empty[1] == h_mixed[0]
    assert h_mixed[0] != h_mixed[1]
    zero = Column.from_strings([])
    assert len(vk.hash_var(zero.offsets, zero.values)) == 0


def test_join_all_empty_string_keys():
    """Regression: joining an all-empty-string key column against a
    mixed one must match on the empty rows (they used to hash through
    different code paths and silently drop)."""
    l = Table.from_pydict({"k0": ["", "x"],
                           "lv": np.arange(2, dtype=np.int64)})
    r = Table.from_pydict({"k0": ["", ""],
                           "rv": np.arange(2, dtype=np.int64)})
    got = ops.join(l, r, on="k0").to_pydict()
    assert got == ref_join(l.to_pydict(), r.to_pydict(), ["k0"], "inner")
    assert got["lv"] == [0, 0]
    # dict-encoded side whose dictionary is a single empty string
    rd = ops.dict_encode(r, ["k0"])
    assert ops.join(l, rd, on="k0").to_pydict() == got


def test_join_int64_uint64_keys_exact():
    """Mixed signed/unsigned 64-bit keys compare exactly: distinct
    integers beyond 2**53 that round to the same float64 must not
    match, and a negative int64 never equals a huge uint64."""
    big = 2 ** 60
    l = Table.from_pydict({"k0": np.array([big + 1, big, -1], np.int64),
                           "lv": np.arange(3, dtype=np.int64)})
    r = Table.from_pydict({"k0": np.array([big, 2 ** 64 - 1], np.uint64),
                           "rv": np.arange(2, dtype=np.int64)})
    got = ops.join(l, r, on="k0", how="left").to_pydict()
    assert got["rv"] == [None, 0, None]
    assert got == ref_join(l.to_pydict(), r.to_pydict(), ["k0"], "left")


def test_group_by_agg_name_collision_raises():
    t = Table.from_pydict({"k": np.array([1, 1, 2], np.int64),
                           "x": np.array([1.0, 2.0, 3.0])})
    with pytest.raises(ValueError):
        ops.group_by(t, "k", {"k": ("x", "sum")})


def test_group_by_uint64_sum_no_wrap():
    """uint64 payload sums accumulate as uint64: widening to int64
    would wrap values >= 2**63."""
    t = Table.from_pydict({"k": np.array([1, 1, 2], np.int64),
                           "p": np.array([2 ** 63, 5, 7], np.uint64)})
    got = ops.group_by(t, "k", {"s": ("p", "sum")}).to_pydict()
    assert got["s"] == [2 ** 63 + 5, 7]
    # mean accumulates 64-bit ints in float64: a group total past 2**64
    # must not wrap to garbage (sum itself is documented mod-2**64)
    t2 = Table.from_pydict({"k": np.array([1, 1], np.int64),
                            "p": np.array([2 ** 63, 2 ** 63 + 2],
                                          np.uint64)})
    m = ops.group_by(t2, "k", {"m": ("p", "mean")}).to_pydict()["m"][0]
    assert m == pytest.approx(2.0 ** 63, rel=1e-12)


def test_join_suffixed_name_collision_raises():
    """A suffixed right payload that still collides with an existing
    column raises instead of emitting a duplicate field name."""
    l = Table.from_pydict({"k": np.array([1], np.int64),
                           "v": np.array([7], np.int64),
                           "v_right": np.array([8], np.int64)})
    r = Table.from_pydict({"k": np.array([1], np.int64),
                           "v": np.array([9], np.int64)})
    with pytest.raises(ValueError):
        ops.join(l, r, on="k")


def test_left_join_miss_rows_gather_zero_utf8_bytes():
    """Null rows of a left-join utf8 payload contribute 0 bytes — a
    miss must not copy build row 0's payload."""
    doc = "x" * 4096
    l = Table.from_pydict({"k": np.array([1, 2, 3], np.int64),
                           "lv": np.arange(3, dtype=np.int64)})
    r = Table.from_pydict({"k": np.array([1], np.int64), "doc": [doc]})
    got = ops.join(l, r, on="k", how="left")
    col = got.batches[0].column("doc")
    assert col.values.nbytes == len(doc)          # one match, two misses
    assert got.to_pydict()["doc"] == [doc, None, None]


def test_hash_keys_matches_ops_key_hashes():
    """``vkernels.hash_keys`` over raw buffers must stay hash-identical
    to the composition ``ops._key_hashes`` performs on plain columns."""
    from repro.core import vkernels as vk
    t = Table.from_pydict({"a": np.array([3, -1, 3], np.int64),
                           "b": ["x", "", "x"]})
    b = t.combine().batches[0]
    cast = {"a": np.dtype(np.int64)}
    via_ops, _ = ops._key_hashes(b, ["a", "b"], cast)
    ca, cb = b.column("a"), b.column("b")
    via_kernel = vk.hash_keys([ca.values, (cb.offsets, cb.values)], 3)
    assert np.array_equal(via_ops, via_kernel)


def test_join_mixed_primitive_key_dtypes():
    """int64 vs int32 (negative values!) and float32 vs float64 keys
    hash through a common dtype, matching wherever ``==`` would."""
    l = Table.from_pydict({"k0": np.array([-1, 2, 7], np.int64),
                           "lv": np.arange(3, dtype=np.int64)})
    r = Table.from_pydict({"k0": np.array([-1, 2], np.int32),
                           "rv": np.arange(2, dtype=np.int64)})
    got = ops.join(l, r, on="k0", how="left").to_pydict()
    assert got["rv"] == [0, 1, None]
    lf = Table.from_pydict({"k0": np.array([1.5, -2.0], np.float32),
                            "lv": np.arange(2, dtype=np.int64)})
    rf = Table.from_pydict({"k0": np.array([1.5, -2.0, 9.0]),
                            "rv": np.arange(3, dtype=np.int64)})
    assert ops.join(lf, rf, on="k0").to_pydict()["rv"] == [0, 1]


def test_join_kind_mismatch_raises():
    l = Table.from_pydict({"k0": np.array([1], np.int64)})
    r = Table.from_pydict({"k0": ["1"]})
    with pytest.raises(TypeError):
        ops.join(l, r, on="k0")


def test_pipeline_join_stage_fingerprints_its_kernels():
    """join_filter_fn declares ops.join/filter_rows via __fp_includes__:
    a kernel edit must change its fingerprint (else cache_root reruns
    would serve stale joined tables)."""
    from repro.core import code_fingerprint
    from repro.data.pipeline import join_filter_fn
    fp = code_fingerprint(join_filter_fn)
    assert fp is not None
    saved = join_filter_fn.__fp_includes__
    try:
        join_filter_fn.__fp_includes__ = (ops.filter_rows,)
        assert code_fingerprint(join_filter_fn) != fp
    finally:
        join_filter_fn.__fp_includes__ = saved


def test_join_duplicate_heavy_keys():
    """Cardinality 1: every probe row matches every build row."""
    l = Table.from_pydict({"k0": np.zeros(5, np.int64),
                           "lv": np.arange(5, dtype=np.int64)})
    r = Table.from_pydict({"k0": np.zeros(3, np.int64),
                           "rv": np.arange(3, dtype=np.int64)})
    got = ops.join(l, r, on="k0").to_pydict()
    assert len(got["k0"]) == 15
    assert got == ref_join(l.to_pydict(), r.to_pydict(), ["k0"], "inner")


if HAVE_HYPOTHESIS:
    @settings(max_examples=int(os.environ.get("ZERROW_HYP_EXAMPLES", "25")),
              deadline=None)
    @given(st.integers(0, 2 ** 31 - 1), st.sampled_from(["inner", "left"]),
           st.sampled_from(KEY_MIXES))
    def test_join_matches_reference_hypothesis(seed, how, key_kinds):
        rng = np.random.default_rng(seed)
        keys = [f"k{i}" for i in range(len(key_kinds))]
        l = _rand_table(rng, int(rng.integers(0, 25)), key_kinds,
                        ("float",), "l")
        r = _rand_table(rng, int(rng.integers(0, 25)), key_kinds,
                        ("int",), "r")
        got = ops.join(l, r, on=keys, how=how).to_pydict()
        assert got == ref_join(l.to_pydict(), r.to_pydict(), keys, how)


# ---------------------------------------------------------------------------
# fused filter -> join
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("how", ["inner", "left"])
@pytest.mark.parametrize("seed", range(3))
@pytest.mark.parametrize("masked", ["left", "right", "both"],
                         ids=["lmask", "rmask", "both"])
def test_filter_join_matches_filter_then_join(seed, how, masked):
    """The fused gather must equal materializing the filters first:
    ops.filter_join(l, r, masks) == ops.join(filter_rows(l), filter_rows(r))
    row for row, for both join kinds and either/both mask sides."""
    rng = np.random.default_rng(900 + seed)
    l = _rand_table(rng, int(rng.integers(1, 40)), ("int", "utf8"),
                    ("int", "float"), "l")
    r = _rand_table(rng, int(rng.integers(1, 40)), ("int", "utf8"),
                    ("float", "utf8"), "r")
    lm = rng.random(l.num_rows) < 0.6 if masked in ("left", "both") else None
    rm = rng.random(r.num_rows) < 0.6 if masked in ("right", "both") else None
    fused = ops.filter_join(l, r, on=["k0", "k1"], how=how,
                            left_mask=lm, right_mask=rm).to_pydict()
    lf = l if lm is None else ops.filter_rows(l, lm)
    rf = r if rm is None else ops.filter_rows(r, rm)
    unfused = ops.join(lf, rf, on=["k0", "k1"], how=how).to_pydict()
    assert fused == unfused


def test_filter_join_callable_masks():
    """Masks may be callables evaluated against each side's combined
    batch (what DAG node fns pass, so the mask fingerprints as code)."""
    rng = np.random.default_rng(31)
    l = _rand_table(rng, 25, ("int",), ("int",), "l", key_nulls=0.0)
    r = _rand_table(rng, 25, ("int",), ("float",), "r", key_nulls=0.0)

    def keep_pos(batch):
        return batch.column("r0").to_numpy() >= 0

    fused = ops.filter_join(l, r, on="k0", right_mask=keep_pos).to_pydict()
    mask = r.combine().batches[0].column("r0").to_numpy() >= 0
    unfused = ops.join(l, ops.filter_rows(r, mask), on="k0").to_pydict()
    assert fused == unfused


def test_filter_join_all_rows_masked_out():
    rng = np.random.default_rng(5)
    l = _rand_table(rng, 10, ("int",), ("int",), "l")
    r = _rand_table(rng, 10, ("int",), ("int",), "r")
    zeros = np.zeros(r.num_rows, dtype=bool)
    for how in ("inner", "left"):
        fused = ops.filter_join(l, r, on="k0", how=how,
                                right_mask=zeros).to_pydict()
        unfused = ops.join(l, ops.filter_rows(r, zeros), on="k0",
                           how=how).to_pydict()
        assert fused == unfused


def test_filter_join_fingerprints_distinct_from_join():
    """A DAG node switching join -> filter_join must change fingerprint
    (the fused gather is a different computation over the same inputs),
    while each stays stable across calls."""
    from repro.core import code_fingerprint
    assert code_fingerprint(ops.filter_join) is not None
    assert code_fingerprint(ops.join) is not None
    assert code_fingerprint(ops.filter_join) != code_fingerprint(ops.join)
    assert code_fingerprint(ops.filter_join) == \
        code_fingerprint(ops.filter_join)


# ---------------------------------------------------------------------------
# group_by vs reference
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", range(4))
@pytest.mark.parametrize("key_kinds", KEY_MIXES,
                         ids=["-".join(k) for k in KEY_MIXES])
def test_group_by_matches_reference(seed, key_kinds):
    rng = np.random.default_rng(seed * 37 + 11)
    keys = [f"k{i}" for i in range(len(key_kinds))]
    # null_frac high enough that some groups are all-null in the payload
    t = _rand_table(rng, int(rng.integers(1, 60)), key_kinds,
                    ("float", "int"), "p")
    got = ops.group_by(t, keys, ALL_AGGS).to_pydict()
    assert got == ref_group_by(t.to_pydict(), keys, ALL_AGGS)


def test_group_by_int_payload_and_bool():
    rng = np.random.default_rng(3)
    t = _rand_table(rng, 50, ("utf8",), ("int", "bool"), "p")
    aggs = {"n": ("p1", "count"), "s": ("p0", "sum"),
            "anyv": ("p1", "max"), "allv": ("p1", "min"),
            "m": ("p0", "mean")}
    got = ops.group_by(t, ["k0"], aggs).to_pydict()
    assert got == ref_group_by(t.to_pydict(), ["k0"], aggs)


def test_group_by_zero_rows_and_single_group():
    t = Table.from_pydict({"k0": np.array([5, 5, 5], np.int64),
                           "p0": np.array([1.5, 2.5, 3.0])})
    got = ops.group_by(t, "k0", ALL_AGGS).to_pydict()
    assert got == ref_group_by(t.to_pydict(), ["k0"], ALL_AGGS)
    empty = ops.slice_rows(t, 0, 0)
    got0 = ops.group_by(empty, "k0", ALL_AGGS).to_pydict()
    assert got0 == {"k0": [], "n": [], "tot": [], "lo": [], "hi": [],
                    "avg": []}


def test_group_by_all_null_payload_group_is_null():
    t = Table.from_batch(
        Table.from_pydict({"k0": np.array([1, 1, 2], np.int64),
                           "p0": np.array([9., 8., 7.])}).schema,
        [Column.primitive(np.array([1, 1, 2], np.int64)),
         Column.primitive(np.array([9., 8., 7.]),
                          validity=pack_validity(
                              np.array([False, False, True])))])
    got = ops.group_by(t, "k0", ALL_AGGS).to_pydict()
    assert got["n"] == [0, 1]
    assert got["tot"] == [None, 7.0] and got["avg"] == [None, 7.0]
    assert got["lo"] == [None, 7.0] and got["hi"] == [None, 7.0]


# ---------------------------------------------------------------------------
# zero-copy invariants through the executor
# ---------------------------------------------------------------------------

def _write_star(tmp, seed=0):
    rng = np.random.default_rng(seed)
    cust = Table.from_pydict({
        "cust": np.arange(64, dtype=np.int64),
        "country": [f"c{i % 5}" for i in range(64)]})
    orders = Table.from_pydict({
        "cust": rng.integers(0, 72, size=400).astype(np.int64),
        "amount": np.round(rng.random(400), 3)})
    pc, po = os.path.join(tmp, "cust.zq"), os.path.join(tmp, "orders.zq")
    zarquet.write_table(pc, cust)
    zarquet.write_table(po, orders)
    return po, pc


def _star_dag(po, pc, est=1 << 22, keep_join=False):
    return DAG([
        NodeSpec("orders", source=po, est_mem=est),
        NodeSpec("cust", source=pc, est_mem=est,
                 dict_columns=("country",)),
        NodeSpec("join", fn=functools.partial(ops.join_node, on="cust",
                                              how="left"),
                 deps=["orders", "cust"], est_mem=est,
                 keep_output=keep_join),
        NodeSpec("agg", fn=functools.partial(
            ops.group_by_node, keys="country",
            aggs={"total": ("amount", "sum"), "n": ("amount", "count")}),
            deps=["join"], est_mem=est, keep_output=True),
    ], name="star")


def _dict_refs(msg):
    out = []
    for b in msg.batches:
        for c in b.columns:
            if c.dictionary is not None:
                out.extend(c.dictionary.all_refs())
    return out


def test_join_payload_dictionary_reshares_not_copies(tmp_path):
    """The acceptance invariant: join outputs get AddressMap hits — the
    dimension dictionary is emitted as a reshared reference, never
    re-deanonymized, and zero data bytes are copied anywhere (the only
    ``bytes_copied`` are page-edge partial-page tax on new buffers)."""
    po, pc = _write_star(str(tmp_path))
    store = BufferStore(swap_dir=os.path.join(str(tmp_path), "swap"))
    rm = ResourceManager(store, RMConfig(policy="adaptive"))
    ex = Executor(store, rm)
    dag = _star_dag(po, pc, keep_join=True)
    ex.run([dag])
    jm = dag.nodes["join"].output
    drefs = _dict_refs(jm)
    assert drefs, "join output lost its dictionary column"
    assert all(r.reshared for r in drefs), \
        "join payload dictionary was re-deanonymized instead of reshared"
    # group_by keeps the dictionary by reference too
    arefs = _dict_refs(dag.nodes["agg"].output)
    assert arefs and all(r.reshared for r in arefs)
    s = store.stats
    assert s.reshare_hits > 0
    assert s.bytes_copied == s.partial_page_bytes, \
        "a full-buffer data copy happened on the relational path"
    store.close()


def test_join_groupby_thread_pool_equals_sequential(tmp_path):
    results = []
    for workers in (1, 4):
        os.makedirs(str(tmp_path / f"w{workers}"), exist_ok=True)
        po, pc = _write_star(str(tmp_path / f"w{workers}"))
        store = BufferStore(
            swap_dir=os.path.join(str(tmp_path), f"swap{workers}"))
        rm = ResourceManager(store, RMConfig(policy="adaptive",
                                             workers=workers))
        ex = Executor(store, rm, workers=workers)
        dag = _star_dag(po, pc)
        ex.run([dag])
        results.append(SipcReader(store).read_table(
            dag.nodes["agg"].output).to_pydict())
        store.close()
    assert results[0] == results[1]


def test_join_groupby_process_equals_thread(tmp_path):
    """Relational ops under Flight process workers: bit-identical output
    and worker-side reshare hits visible in the parent's stats."""
    outs = {}
    for mode in ("thread", "process"):
        root = str(tmp_path / mode)
        os.makedirs(root, exist_ok=True)
        po, pc = _write_star(root)
        backing = "file" if mode == "process" else "ram"
        store = BufferStore(
            swap_dir=os.path.join(root, "swap"), backing=backing,
            data_dir=os.path.join(root, "store")
            if backing == "file" else None)
        rm = ResourceManager(store, RMConfig(policy="adaptive", workers=2,
                                             workers_mode=mode))
        ex = make_executor(store, rm, workers=2)
        dag = _star_dag(po, pc)
        ex.run([dag])
        outs[mode] = SipcReader(store).read_table(
            dag.nodes["agg"].output).to_pydict()
        rs = ex.reshare_stats()
        assert rs["reshare_hits"] > 0, f"no reshare hits in {mode} mode"
        if mode == "process":
            assert ex.fallback_inline == 0, "relational ops must pickle"
            assert ex.worker_stats.get("reshare_hits", 0) > 0, \
                "worker-side reshare stats did not propagate"
        ex.close()
        store.close()
    assert outs["thread"] == outs["process"]


def test_differential_rerun_recomputes_only_affected_side(tmp_path):
    """Cross-run caching over a join DAG: a change to the fact source
    invalidates orders -> join -> agg but the dimension loader stays
    CACHED (adopted, not re-executed)."""
    cache_root = str(tmp_path / "cache")
    data = str(tmp_path / "data")
    os.makedirs(data)
    po, pc = _write_star(data, seed=0)

    def run():
        store = BufferStore(backing="file", root=cache_root,
                            swap_dir=os.path.join(data, "swap"))
        rm = ResourceManager(store, RMConfig(policy="adaptive",
                                             cache_root=cache_root))
        ex = Executor(store, rm)
        dag = _star_dag(po, pc)
        ex.run([dag])
        out = SipcReader(store).read_table(
            dag.nodes["agg"].output).to_pydict()
        counts = (ex.node_runs, ex.cache_hits,
                  {n: st.status for n, st in dag.nodes.items()})
        store.close()
        return out, counts

    out1, (runs1, hits1, _) = run()
    assert runs1 == 4 and hits1 == 0
    # warm rerun: everything adopted, nothing executed
    out2, (runs2, hits2, _) = run()
    assert out1 == out2
    assert runs2 == 0 and hits2 == 4
    # change the fact table only: the dimension side must stay cached
    rng_orders = Table.from_pydict({
        "cust": np.arange(300, dtype=np.int64) % 70,
        "amount": np.round(np.random.default_rng(9).random(300), 3)})
    zarquet.write_table(po, rng_orders)
    out3, (runs3, hits3, states) = run()
    assert runs3 == 3, f"expected orders+join+agg to re-run, got {states}"
    assert hits3 == 1 and states["cust"] == "cached"
    assert out3 != out1

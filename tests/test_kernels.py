"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps, interpret mode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


def rand(rng, shape, dtype):
    x = rng.normal(size=shape).astype(np.float32)
    return jnp.asarray(x, dtype)


@pytest.fixture(scope="module")
def rng():
    return np.random.default_rng(42)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,S,T,H,KV,hd", [
    (1, 128, 128, 2, 2, 32),
    (2, 256, 256, 4, 2, 64),     # GQA G=2
    (1, 128, 256, 4, 1, 32),     # MQA, cross-length
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_causal(rng, B, S, T, H, KV, hd, dtype):
    q = rand(rng, (B, S, H, hd), dtype)
    k = rand(rng, (B, T, KV, hd), dtype)
    v = rand(rng, (B, T, KV, hd), dtype)
    out = ops.flash_attention(q, k, v, causal=True, bq=64, bk=64)
    want = ref.attention_ref(q, k, v, causal=True)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_flash_non_causal(rng):
    q = rand(rng, (1, 128, 2, 32), jnp.float32)
    k = rand(rng, (1, 128, 2, 32), jnp.float32)
    v = rand(rng, (1, 128, 2, 32), jnp.float32)
    out = ops.flash_attention(q, k, v, causal=False, bq=64, bk=64)
    want = ref.attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("window", [32, 64, 128])
def test_flash_local_window(rng, window):
    q = rand(rng, (1, 256, 2, 32), jnp.float32)
    k = rand(rng, (1, 256, 2, 32), jnp.float32)
    v = rand(rng, (1, 256, 2, 32), jnp.float32)
    out = ops.flash_attention(q, k, v, causal=True, window=window,
                              bq=64, bk=64)
    want = ref.attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_flash_matches_model_chunked(rng):
    """Pallas kernel == the model's XLA chunked path (same math)."""
    from repro.models.attention import chunked_attention
    q = rand(rng, (2, 128, 4, 32), jnp.float32)
    k = rand(rng, (2, 128, 2, 32), jnp.float32)
    v = rand(rng, (2, 128, 2, 32), jnp.float32)
    out = ops.flash_attention(q, k, v, causal=True, bq=64, bk=64)
    want = chunked_attention(q, k, v, causal=True, chunk=64)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# RG-LRU scan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,S,W,chunk,bw", [
    (1, 64, 128, 16, 128),
    (2, 256, 256, 64, 128),
    (1, 128, 512, 128, 512),
])
def test_rglru(rng, B, S, W, chunk, bw):
    a = jnp.asarray(rng.uniform(0.8, 0.999, (B, S, W)), jnp.float32)
    b = rand(rng, (B, S, W), jnp.float32)
    h0 = rand(rng, (B, W), jnp.float32)
    h, hl = ops.rglru_scan(a, b, h0, chunk=chunk, bw=bw)
    want_h, want_hl = ref.rglru_ref(a, b, h0)
    np.testing.assert_allclose(np.asarray(h), np.asarray(want_h),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(hl), np.asarray(want_hl),
                               rtol=1e-5, atol=1e-5)


def test_rglru_no_h0(rng):
    a = jnp.asarray(rng.uniform(0.5, 0.99, (1, 32, 128)), jnp.float32)
    b = rand(rng, (1, 32, 128), jnp.float32)
    h, hl = ops.rglru_scan(a, b, chunk=16, bw=128)
    want_h, _ = ref.rglru_ref(a, b)
    np.testing.assert_allclose(np.asarray(h), np.asarray(want_h),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# WKV6
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,S,H,N,chunk", [
    (1, 32, 2, 16, 8),
    (2, 64, 4, 32, 16),
    (1, 48, 2, 64, 16),
])
def test_wkv6(rng, B, S, H, N, chunk):
    r = rand(rng, (B, S, H, N), jnp.float32) * 0.5
    k = rand(rng, (B, S, H, N), jnp.float32) * 0.5
    v = rand(rng, (B, S, H, N), jnp.float32)
    w = jnp.asarray(rng.uniform(0.05, 0.999, (B, S, H, N)), jnp.float32)
    u = jnp.asarray(rng.normal(size=(H, N)) * 0.1, jnp.float32)
    st = rand(rng, (B, H, N, N), jnp.float32) * 0.1
    out, s_out = ops.wkv6(r, k, v, w, u, st, chunk=chunk)
    want, want_s = ref.wkv6_ref(r, k, v, w, u, st)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(s_out), np.asarray(want_s),
                               rtol=1e-4, atol=1e-4)


def test_wkv6_matches_model_chunked(rng):
    """The model's jnp chunked path == the sequential oracle too."""
    from repro.models.rwkv6 import wkv6_chunked
    B, S, H, N = 1, 64, 2, 16
    r = rand(rng, (B, S, H, N), jnp.float32) * 0.5
    k = rand(rng, (B, S, H, N), jnp.float32) * 0.5
    v = rand(rng, (B, S, H, N), jnp.float32)
    w = jnp.asarray(rng.uniform(0.05, 0.999, (B, S, H, N)), jnp.float32)
    u = jnp.asarray(rng.normal(size=(H, N)) * 0.1, jnp.float32)
    out, s_out = wkv6_chunked(r, k, v, w, u, chunk=16)
    want, want_s = ref.wkv6_ref(r, k, v, w, u)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(s_out), np.asarray(want_s),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# take / dict gather
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("R,W,M", [(64, 128, 32), (128, 256, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.int32])
def test_take_rows(rng, R, W, M, dtype):
    vals = jnp.asarray(rng.integers(0, 100, (R, W)), dtype)
    idx = jnp.asarray(rng.integers(0, R, (M,)), jnp.int32)
    out = ops.take_rows(vals, idx)
    np.testing.assert_array_equal(np.asarray(out),
                                  np.asarray(ref.take_rows_ref(vals, idx)))


@pytest.mark.parametrize("R,W,M,bm", [(16, 128, 256, 64), (64, 128, 512, 256)])
def test_dict_decode(rng, R, W, M, bm):
    dic = jnp.asarray(rng.normal(size=(R, W)), jnp.float32)
    codes = jnp.asarray(rng.integers(0, R, (M,)), jnp.int32)
    out = ops.dict_decode(codes, dic, bm=bm)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(ref.dict_decode_ref(codes, dic)),
        rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# gather edge cases: empty inputs, odd widths, domain validation
# ---------------------------------------------------------------------------

def test_take_rows_zero_indices(rng):
    vals = jnp.asarray(rng.normal(size=(9, 7)), jnp.float32)
    out = ops.take_rows(vals, jnp.asarray([], jnp.int32))
    assert out.shape == (0, 7) and out.dtype == vals.dtype


def test_take_rows_out_of_range_raises(rng):
    vals = jnp.asarray(rng.normal(size=(9, 7)), jnp.float32)
    with pytest.raises(IndexError, match="out of range"):
        ops.take_rows(vals, jnp.asarray([0, 9], jnp.int32))
    with pytest.raises(IndexError, match="out of range"):
        ops.take_rows(vals, jnp.asarray([-1], jnp.int32))


def test_dict_decode_zero_codes(rng):
    dic = jnp.asarray(rng.normal(size=(5, 4)), jnp.float32)
    out = ops.dict_decode(jnp.asarray([], jnp.int32), dic)
    assert out.shape == (0, 4) and out.dtype == dic.dtype


@pytest.mark.parametrize("M", [1, 7, 103, 257])
def test_dict_decode_length_not_block_multiple(rng, M):
    """Any code count works: the wrapper pads M up to the block size and
    slices the pad rows back off."""
    dic = jnp.asarray(rng.normal(size=(6, 8)), jnp.float32)
    codes = jnp.asarray(rng.integers(0, 6, (M,)), jnp.int32)
    out = ops.dict_decode(codes, dic, bm=64)
    assert out.shape == (M, 8)
    np.testing.assert_array_equal(
        np.asarray(out), np.asarray(dic)[np.asarray(codes)])


def test_dict_decode_out_of_range_codes_raise(rng):
    """A code outside the dictionary raises instead of silently decoding
    garbage (the one-hot matmul would emit zero rows)."""
    dic = jnp.asarray(rng.normal(size=(5, 4)), jnp.float32)
    with pytest.raises(IndexError, match="out of range"):
        ops.dict_decode(jnp.asarray([0, 5], jnp.int32), dic)
    with pytest.raises(IndexError, match="out of range"):
        ops.dict_decode(jnp.asarray([-1], jnp.int32), dic)


# ---------------------------------------------------------------------------
# interpret-mode resolution: explicit env override beats device sniffing
# ---------------------------------------------------------------------------

def test_default_interpret_env_override(monkeypatch):
    monkeypatch.setenv("ZERROW_PALLAS_INTERPRET", "1")
    assert ops.default_interpret() is True
    monkeypatch.setenv("ZERROW_PALLAS_INTERPRET", "0")
    assert ops.default_interpret() is False
    monkeypatch.setenv("ZERROW_PALLAS_INTERPRET", "yes")
    with pytest.raises(ValueError, match="ZERROW_PALLAS_INTERPRET"):
        ops.default_interpret()
    # unset (and empty) fall back to device sniffing
    monkeypatch.setenv("ZERROW_PALLAS_INTERPRET", "")
    assert ops.default_interpret() == (not ops.on_tpu())
    monkeypatch.delenv("ZERROW_PALLAS_INTERPRET")
    assert ops.default_interpret() == (not ops.on_tpu())


def test_interpret_override_takes_effect_per_call(rng, monkeypatch):
    """The override is read at call time, not baked into a jit trace:
    flipping the env var between two calls of the *same* wrapper
    changes the lowering.  On a CPU backend, '0' (force compiled) must
    reach the kernel and fail with Pallas's interpret-only CPU error —
    a stale trace would silently keep interpreting."""
    if ops.on_tpu():
        pytest.skip("compiled mode is the normal path on TPU")
    vals = jnp.asarray(rng.integers(0, 100, (8, 16)), jnp.int32)
    idx = jnp.asarray(rng.integers(0, 8, (32,)), jnp.int32)
    monkeypatch.setenv("ZERROW_PALLAS_INTERPRET", "1")
    a = np.asarray(ops.take_rows(vals, idx))
    np.testing.assert_array_equal(a, np.asarray(vals)[np.asarray(idx)])
    monkeypatch.setenv("ZERROW_PALLAS_INTERPRET", "0")
    with pytest.raises(ValueError, match="[Ii]nterpret"):
        ops.take_rows(vals, idx)
    monkeypatch.setenv("ZERROW_PALLAS_INTERPRET", "1")
    np.testing.assert_array_equal(np.asarray(ops.take_rows(vals, idx)), a)


def test_on_tpu_matches_backend():
    assert ops.on_tpu() == (jax.default_backend() == "tpu")

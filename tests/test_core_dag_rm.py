"""DAG executor + DeCache + Resource Manager (admission & eviction)."""
import numpy as np
import pytest

from repro.core import (BufferStore, DAG, Executor, NodeSpec, OOMError,
                        RMConfig, ResourceManager, SipcReader, Table)
from repro.core import ops, zarquet


@pytest.fixture()
def source(tmp_path):
    path = str(tmp_path / "t.zq")
    t = zarquet.gen_int_table(4, 1 << 14, seed=7)
    zarquet.write_table(path, t)
    return path, t


def make_env(tmp_path, **cfg):
    store = BufferStore(swap_dir=str(tmp_path / "swap"))
    rm = ResourceManager(store, RMConfig(**cfg))
    ex = Executor(store, rm)
    return store, rm, ex


def two_node_dag(path, name="d"):
    return DAG([
        NodeSpec("load", source=path, est_mem=1 << 16),
        NodeSpec("sum", fn=lambda ts: Table.from_pydict(
            {"total": np.array([ops.sum_all_ints(ts[0])], dtype=np.int64)}),
            deps=["load"], est_mem=1 << 12),
    ], name=name)


def test_zarquet_roundtrip(tmp_path):
    path = str(tmp_path / "t.zq")
    t = Table.from_pydict({"a": np.arange(100, dtype=np.int64),
                           "s": [f"v{i}" for i in range(100)]})
    zarquet.write_table(path, t)
    t2 = zarquet.read_table(path)
    assert t.equals(t2)


def test_zarquet_dict_columns(tmp_path):
    path = str(tmp_path / "t.zq")
    t = Table.from_pydict({"s": ["aa", "bb", "aa", "cc", "bb"]})
    zarquet.write_table(path, t)
    t2 = zarquet.read_table(path, dict_columns=("s",))
    col = t2.batches[0].columns[0]
    assert col.type.is_dict
    assert col.dictionary.length == 3
    assert t2.to_pydict()["s"] == ["aa", "bb", "aa", "cc", "bb"]


def test_simple_dag_runs(tmp_path, source):
    path, t = source
    store, rm, ex = make_env(tmp_path)
    dag = two_node_dag(path)
    ex.run([dag])
    assert dag.all_done()
    # validate the computed sum
    out = dag.nodes["sum"]
    assert out.status == "done"


def test_decache_dedups_loads(tmp_path, source):
    path, _ = source
    store, rm, ex = make_env(tmp_path, decache=True)
    dags = [two_node_dag(path, f"d{i}") for i in range(5)]
    ex.run(dags)
    assert ex.load_runs == 1            # loaded once, shared 5 ways
    assert rm.decache.hits >= 4


def test_no_decache_loads_every_time(tmp_path, source):
    path, _ = source
    store, rm, ex = make_env(tmp_path, decache=False)
    dags = [two_node_dag(path, f"d{i}") for i in range(3)]
    ex.run(dags)
    assert ex.load_runs == 3


def chain_dag(path, depth, name="c", repeat=1):
    nodes = [NodeSpec("load", source=path, est_mem=1 << 16)]
    prev = "load"
    for i in range(depth):
        def fn(ts, i=i):
            return ops.add_columns_compute(ts[0], "i0", "i1", f"n{i}",
                                           repeat=repeat)
        nodes.append(NodeSpec(f"add{i}", fn=fn, deps=[prev],
                              est_mem=1 << 15))
        prev = f"add{i}"
    return DAG(nodes, name=name)


def test_chain_dag_correct(tmp_path, source):
    path, t = source
    store, rm, ex = make_env(tmp_path)
    dag = chain_dag(path, 3)
    ex.run([dag])
    assert dag.all_done()


def test_rollback_eviction_reexecutes(tmp_path, source):
    path, _ = source
    # tiny memory budget forces eviction of intermediate outputs
    store, rm, ex = make_env(tmp_path, memory_limit=3 << 15,
                             policy="rollback")
    dags = [chain_dag(path, 4, f"c{i}") for i in range(3)]
    ex.run(dags)
    assert all(d.all_done() for d in dags)
    assert rm.evictions["rollback"] + rm.evictions["uncache"] > 0
    # some nodes had to run more than once
    assert ex.node_runs > sum(len(d.nodes) for d in dags) - 3


def test_limitdrop_eviction_swaps(tmp_path, source):
    path, _ = source
    store, rm, ex = make_env(tmp_path, memory_limit=3 << 15,
                             policy="limitdrop")
    dags = [chain_dag(path, 4, f"c{i}") for i in range(3)]
    ex.run(dags)
    assert all(d.all_done() for d in dags)
    assert store.stats.swapout_bytes > 0
    assert rm.evictions["limitdrop"] > 0


def test_adaptive_eviction_completes(tmp_path, source):
    path, _ = source
    store, rm, ex = make_env(tmp_path, memory_limit=3 << 15,
                             policy="adaptive")
    dags = [chain_dag(path, 4, f"c{i}") for i in range(3)]
    ex.run(dags)
    assert all(d.all_done() for d in dags)
    assert rm.evictions["rollback"] + rm.evictions["limitdrop"] > 0


def test_fanout_dag(tmp_path, source):
    """Fanout-2, depth-3 (the Fig 10b shape): 1 load + branching adds."""
    path, _ = source
    store, rm, ex = make_env(tmp_path)
    nodes = [NodeSpec("load", source=path, est_mem=1 << 16)]
    frontier = ["load"]
    k = 0
    for d in range(3):
        nxt = []
        for p in frontier:
            for b in range(2):
                name = f"n{k}"
                k += 1
                nodes.append(NodeSpec(
                    name, fn=lambda ts, i=k: ops.add_columns_compute(
                        ts[0], "i0", "i1", f"c{i}"),
                    deps=[p], est_mem=1 << 15))
                nxt.append(name)
        frontier = nxt
    dag = DAG(nodes, "fan")
    ex.run([dag])
    assert dag.all_done()
    assert len(dag.nodes) == 1 + 2 + 4 + 8


def test_memory_is_freed_after_dags(tmp_path, source):
    path, _ = source
    store, rm, ex = make_env(tmp_path, decache=False)
    dags = [two_node_dag(path, f"d{i}") for i in range(3)]
    ex.run(dags)
    assert store.global_charged == 0   # all intermediates GC'd


def test_decache_pinned_survives_dag(tmp_path, source):
    path, _ = source
    store, rm, ex = make_env(tmp_path, decache=True)
    ex.run([two_node_dag(path, "d0")])
    assert store.global_charged > 0    # DeCache entry persists
    # uncache frees it
    for e in rm.decache.uncache_candidates():
        rm.decache.uncache(e)
    assert store.global_charged == 0

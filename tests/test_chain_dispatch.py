"""Chain shipping: linear DAG segments dispatched as one exec_chain
request (ISSUE 6).

Covers the dispatch-plane claims:
  * chain-shipped process runs are bit-identical to sequential thread
    runs, and strictly cheaper on the wire per node than per-node
    dispatch;
  * a chain crossing a CACHED boundary ships only its non-hit suffix
    (the PR 3 manifest cones stay intact);
  * a worker SIGKILLed mid-chain replays the whole segment on a
    survivor;
  * an unpicklable fn anywhere in a would-be chain degrades to
    node-by-node dispatch (and in-parent fallback), never to a wrong
    answer.
"""

import functools
import os
import signal

import numpy as np

from repro.core import (BufferStore, DAG, Executor, NodeSpec,
                        ProcessWorkerExecutor, RMConfig, ResourceManager,
                        SipcReader, zarquet)
from repro.core import ops


# ---------------------------------------------------------------------------
# module-level node fns: must be picklable for the process executor
# ---------------------------------------------------------------------------

def dict_encode_op(tables):
    return ops.dict_encode(tables[0], ["s0"])


def upper_op(tables):
    return ops.upper(tables[0], "s0")


def filter_even_op(tables):
    t = tables[0]
    return ops.filter_rows(t, np.arange(t.num_rows) % 2 == 0)


def drop_third_op(tables):
    t = tables[0]
    return ops.filter_rows(t, np.arange(t.num_rows) % 3 != 0)


def crash_once_op(tables, marker):
    """SIGKILL the hosting worker process the first time it runs; the
    marker file makes the replay succeed."""
    if not os.path.exists(marker):
        open(marker, "w").close()
        os.kill(os.getpid(), signal.SIGKILL)
    return tables[0]


def identity_op(tables):
    return tables[0]


def _write_shards(tmpdir, n=2):
    paths = []
    for i in range(n):
        t = zarquet.gen_str_table(1, 1 << 15, str_len=24, repeats=4, seed=i)
        p = os.path.join(tmpdir, f"s{i}.zq")
        zarquet.write_table(p, t)
        paths.append(p)
    return paths


def _file_store(tmp_path, name="store"):
    return BufferStore(backing="file",
                       data_dir=os.path.join(str(tmp_path), name))


def _linear_dags(paths, tail_fn=filter_even_op):
    return [DAG([
        NodeSpec("load", source=p, est_mem=1 << 22),
        NodeSpec("enc", fn=dict_encode_op, deps=["load"], est_mem=1 << 22),
        NodeSpec("filt", fn=tail_fn, deps=["enc"], est_mem=1 << 22,
                 keep_output=True),
    ], name=f"job{i}") for i, p in enumerate(paths)]


def _run_process(tmp_path, dags, name, workers=2, **cfg):
    fstore = _file_store(tmp_path, name)
    rm = ResourceManager(fstore, RMConfig(workers=workers,
                                          workers_mode="process", **cfg))
    ex = ProcessWorkerExecutor(fstore, rm, workers=workers)
    ex.run(dags)
    return fstore, rm, ex


def _sequential_reference(paths, out_node="filt", tail_fn=filter_even_op):
    ram = BufferStore()
    rm = ResourceManager(ram, RMConfig())
    dags = _linear_dags(paths, tail_fn=tail_fn)
    Executor(ram, rm).run(dags)
    refs = [SipcReader(ram).read_table(d.nodes[out_node].output)
            for d in dags]
    return ram, refs


# ---------------------------------------------------------------------------
# bit-identity + wire cost
# ---------------------------------------------------------------------------

def test_chain_shipped_run_matches_sequential(tmp_path):
    paths = _write_shards(str(tmp_path))
    ram, refs = _sequential_reference(paths)

    fstore, rm, ex = _run_process(tmp_path, dags := _linear_dags(paths),
                                  "chain")
    try:
        # every DAG is one linear picklable segment: all three nodes ship
        assert ex.chains_shipped == len(dags)
        assert ex.chain_nodes_shipped == 3 * len(dags)
        assert ex.fallback_inline == 0
        for d, want in zip(dags, refs):
            got = SipcReader(fstore).read_table(d.nodes["filt"].output)
            assert got.equals(want)
        # interior outputs stayed worker-local: never adopted or charged
        for d in dags:
            assert d.nodes["enc"].output is None
            assert d.nodes["enc"].output_bytes == 0
        assert rm.admission.reserved == 0
        assert ex._inflight == {}
        assert fstore.copied_bytes == 0
    finally:
        ex.close()
        fstore.close()
        ram.close()


def test_chain_dispatch_cuts_socket_bytes_per_node(tmp_path):
    paths = _write_shards(str(tmp_path))
    ram, refs = _sequential_reference(paths)
    per_node = {}
    try:
        for flag in (True, False):
            dags = _linear_dags(paths)
            fstore, _rm, ex = _run_process(tmp_path, dags, f"chain-{flag}",
                                           chain_dispatch=flag)
            try:
                assert ex.chains_shipped == (len(dags) if flag else 0)
                per_node[flag] = ex.socket_bytes / ex.node_runs
                for d, want in zip(dags, refs):
                    got = SipcReader(fstore).read_table(
                        d.nodes["filt"].output)
                    assert got.equals(want)
            finally:
                ex.close()
                fstore.close()
    finally:
        ram.close()
    # same answer either way, strictly fewer socket bytes per node chained
    assert per_node[True] < per_node[False]


# ---------------------------------------------------------------------------
# CACHED boundary
# ---------------------------------------------------------------------------

def test_chain_crossing_cached_boundary_ships_suffix_only(tmp_path):
    """Run 1 publishes the whole chain; run 2 swaps the dict-encode step
    for a different fn, so load+up hit the manifest (CACHED) and only
    the changed suffix [enc', filt] ships as a chain rooted at the
    cached output."""
    paths = _write_shards(str(tmp_path), n=1)
    cache_root = os.path.join(str(tmp_path), "cache")

    def build(mid_fn):
        return [DAG([
            NodeSpec("load", source=paths[0], est_mem=1 << 22),
            NodeSpec("up", fn=upper_op, deps=["load"], est_mem=1 << 22),
            NodeSpec("mid", fn=mid_fn, deps=["up"], est_mem=1 << 22),
            NodeSpec("filt", fn=filter_even_op, deps=["mid"],
                     est_mem=1 << 22, keep_output=True),
        ], name="cb")]

    store1 = BufferStore(backing="file", root=cache_root)
    rm1 = ResourceManager(store1, RMConfig(workers=2,
                                           workers_mode="process",
                                           cache_root=cache_root))
    ex1 = ProcessWorkerExecutor(store1, rm1, workers=2)
    dags1 = build(dict_encode_op)
    ex1.run(dags1)
    ex1.close()
    store1.close()

    # thread-mode reference for the run-2 DAG shape
    ram = BufferStore()
    dags_ref = build(drop_third_op)
    Executor(ram, ResourceManager(ram, RMConfig())).run(dags_ref)
    want = SipcReader(ram).read_table(dags_ref[0].nodes["filt"].output)

    store2 = BufferStore(backing="file", root=cache_root)
    rm2 = ResourceManager(store2, RMConfig(workers=2,
                                           workers_mode="process",
                                           cache_root=cache_root))
    ex2 = ProcessWorkerExecutor(store2, rm2, workers=2)
    dags2 = build(drop_third_op)
    ex2.run(dags2)
    try:
        d = dags2[0]
        # load+up were satisfied from the manifest, never executed
        assert ex2.cache_hits == 2
        assert ex2.load_runs == 0
        # the non-hit suffix shipped as ONE chain of two nodes
        assert ex2.chains_shipped == 1
        assert ex2.chain_nodes_shipped == 2
        got = SipcReader(store2).read_table(d.nodes["filt"].output)
        assert got.equals(want)
    finally:
        ex2.close()
        store2.close()
        ram.close()


# ---------------------------------------------------------------------------
# fault injection
# ---------------------------------------------------------------------------

def test_worker_killed_mid_chain_retries_on_survivor(tmp_path):
    """A SIGKILL inside a shipped chain loses nothing: the whole segment
    (references only, side-effect free) replays on a surviving worker
    and the RM reservations fully drain."""
    paths = _write_shards(str(tmp_path), n=1)

    # thread-mode reference with the same DAG shape (boom == identity)
    ram = BufferStore()
    ref_dag = DAG([
        NodeSpec("load", source=paths[0], est_mem=1 << 22),
        NodeSpec("boom", fn=identity_op, deps=["load"], est_mem=1 << 22),
        NodeSpec("filt", fn=filter_even_op, deps=["boom"], est_mem=1 << 22,
                 keep_output=True),
    ], name="crash-ref")
    Executor(ram, ResourceManager(ram, RMConfig())).run([ref_dag])
    want = SipcReader(ram).read_table(ref_dag.nodes["filt"].output)

    marker = os.path.join(str(tmp_path), "crashed-once")
    dag = DAG([
        NodeSpec("load", source=paths[0], est_mem=1 << 22),
        NodeSpec("boom", fn=functools.partial(crash_once_op, marker=marker),
                 deps=["load"], est_mem=1 << 22),
        NodeSpec("filt", fn=filter_even_op, deps=["boom"], est_mem=1 << 22,
                 keep_output=True),
    ], name="crash")
    fstore, rm, ex = _run_process(tmp_path, [dag], "crash", workers=2)
    try:
        assert os.path.exists(marker)          # it really died once
        assert ex.worker_retries == 1
        assert ex._pool.live_workers == 1      # the victim stayed retired
        assert ex.chains_shipped == 1
        assert ex.chain_nodes_shipped == 3
        assert dag.all_done()
        assert rm.admission.reserved == 0
        assert ex._inflight == {}
        got = SipcReader(fstore).read_table(dag.nodes["filt"].output)
        assert got.equals(want)
    finally:
        ex.close()
        fstore.close()
        ram.close()


# ---------------------------------------------------------------------------
# unpicklable fallback
# ---------------------------------------------------------------------------

def test_unpicklable_fn_chain_falls_back_node_by_node(tmp_path):
    paths = _write_shards(str(tmp_path), n=1)

    seen = []                          # closure -> unpicklable

    def local_mid(tables):
        seen.append(tables[0].num_rows)
        return ops.upper(tables[0], "s0")

    def build():
        return [DAG([
            NodeSpec("load", source=paths[0], est_mem=1 << 22),
            NodeSpec("mid", fn=local_mid, deps=["load"], est_mem=1 << 22),
            NodeSpec("filt", fn=filter_even_op, deps=["mid"],
                     est_mem=1 << 22, keep_output=True),
        ], name="ub")]

    ram = BufferStore()
    dags_ref = build()
    Executor(ram, ResourceManager(ram, RMConfig())).run(dags_ref)
    want = SipcReader(ram).read_table(dags_ref[0].nodes["filt"].output)

    fstore, rm, ex = _run_process(tmp_path, dags := build(), "unpick")
    try:
        # no link may include the closure, on either side: no chains
        assert ex.chains_shipped == 0
        assert ex.fallback_inline == 1
        assert seen and seen[-1] > 0
        got = SipcReader(fstore).read_table(dags[0].nodes["filt"].output)
        assert got.equals(want)
        assert rm.admission.reserved == 0
    finally:
        ex.close()
        fstore.close()
        ram.close()

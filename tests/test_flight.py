"""Flight data plane: file-backed store, SIPC wire protocol, process
workers.

Covers the three zero-copy claims the subsystem makes:
  * the wire roundtrip moves references, never data (copied_bytes == 0);
  * a *real second process* maps the same physical store file;
  * ``workers_mode='process'`` produces bit-identical DAG outputs to the
    sequential executor while only tiny control frames cross the socket.
"""

import functools
import os
import signal
import subprocess
import sys
import tempfile

import numpy as np
import pytest

from repro.core import (BufferStore, DAG, Executor, FlightClient,
                        FlightServer, FlightWorkerError, KernelZero,
                        NodeSpec, ProcessWorkerExecutor, RMConfig,
                        ResourceManager, Sandbox, SipcReader, Table,
                        decode_message, encode_message, make_executor)
from repro.core import ops, zarquet


# ---------------------------------------------------------------------------
# module-level node fns: must be picklable for the process executor
# ---------------------------------------------------------------------------

def dict_encode_op(tables):
    return ops.dict_encode(tables[0], ["s0"])


def filter_even_op(tables):
    t = tables[0]
    mask = np.arange(t.num_rows) % 2 == 0
    return ops.filter_rows(t, mask)


def upper_op(tables):
    return ops.upper(tables[0], "s0")


def crash_once_op(tables, marker):
    """SIGKILL the hosting worker process the first time it runs; the
    marker file makes the retry succeed."""
    if not os.path.exists(marker):
        open(marker, "w").close()
        os.kill(os.getpid(), signal.SIGKILL)
    return tables[0]


def crash_always_op(tables):
    os.kill(os.getpid(), signal.SIGKILL)


def _make_table(rows=1200):
    rng = np.random.default_rng(7)
    return Table.from_pydict({
        "a": rng.integers(0, 1 << 30, size=rows).astype(np.int64),
        "s": ["alpha", "beta", "gamma", "delta"] * (rows // 4),
    })


def _file_store(tmp_path, name="store"):
    return BufferStore(backing="file",
                       data_dir=os.path.join(str(tmp_path), name))


# ---------------------------------------------------------------------------
# wire roundtrip
# ---------------------------------------------------------------------------

def test_wire_roundtrip_zero_copy(tmp_path):
    store = _file_store(tmp_path)
    sb = Sandbox(store, KernelZero(store), "w", mode="zero")
    t = _make_table()
    msg = sb.write_output(t, label="t")

    c_before = store.copied_bytes
    frame = encode_message(msg, store)
    # the frame is tiny: references only, no data bytes
    assert len(frame) < 1024
    assert len(frame) < t.nbytes // 10

    reader_store = _file_store(tmp_path, "reader")
    msg2 = decode_message(frame, reader_store)
    t2 = SipcReader(reader_store).read_table(msg2)
    assert t2.equals(t)
    # zero data-byte copies on either side of the hop
    assert store.copied_bytes == c_before
    assert reader_store.copied_bytes == 0
    # all bytes were adopted (mapped), none reshared on first decode
    assert msg2.new_bytes > 0
    assert msg2.reshared_bytes == 0
    store.close()
    reader_store.close()


def test_wire_decode_into_producer_store_is_all_reshared(tmp_path):
    """Decoding a frame back into the store that owns the files must
    resolve every reference to the existing files: zero new bytes."""
    store = _file_store(tmp_path)
    sb = Sandbox(store, KernelZero(store), "w", mode="zero")
    msg = sb.write_output(_make_table(), label="t")
    frame = encode_message(msg, store)
    files_before = store.stats.files_created
    msg2 = decode_message(frame, store)
    assert msg2.new_bytes == 0
    assert msg2.reshared_bytes > 0
    assert store.stats.files_created == files_before
    store.close()


def test_wire_export_materializes_direct_swap_extents(tmp_path):
    """An anon region swapped out before deanon lands in the store as a
    direct-swap entry whose backing-file region is only reserved; the
    wire encoder must land the bytes in the backing file before naming
    the path, or readers would map a sparse hole of zeros."""
    from repro.core import AnonRegion

    store = _file_store(tmp_path)
    kz = KernelZero(store)
    cg = store.new_cgroup("sb")
    payload = (np.arange(4096 * 4) % 251).astype(np.uint8)
    region = AnonRegion(payload.copy(), cg)
    region.swap_out(store)                  # pre-deanon swap (the Fig 4
    f = kz.new_file(cg, "ds")               # direct-swap scenario)
    kz.deanon(f, region, direct_swap=True)
    ext = f.extents[0]
    assert not ext.resident and not ext.file_backed

    sb_msg_store = _file_store(tmp_path, "reader")
    # build a minimal message referencing the direct-swap file
    from repro.core import BufRef, SipcMessage
    from repro.core.arrow import UINT8
    from repro.core.sipc import BatchRefs, ColumnRefs
    msg = SipcMessage(b"[]", [BatchRefs(len(payload), [ColumnRefs(
        UINT8, len(payload), None, None,
        BufRef(f.file_id, 0, len(payload)))])])
    frame = encode_message(msg, store)
    got = decode_message(frame, sb_msg_store)
    ref = got.batches[0].columns[0].values
    arr = sb_msg_store.get(ref.file_id).read(ref.offset, ref.length)
    np.testing.assert_array_equal(np.asarray(arr), payload)
    store.close()
    sb_msg_store.close()


def test_wire_dictionary_column_roundtrip(tmp_path):
    store = _file_store(tmp_path)
    sb = Sandbox(store, KernelZero(store), "w", mode="zero")
    t = ops.dict_encode(_make_table(), ["s"])
    msg = sb.write_output(t, label="t")
    reader_store = _file_store(tmp_path, "reader")
    t2 = SipcReader(reader_store).read_table(
        decode_message(encode_message(msg, store), reader_store))
    assert t2.equals(t)
    assert reader_store.copied_bytes == 0
    store.close()
    reader_store.close()


# ---------------------------------------------------------------------------
# a real second process maps the same store file
# ---------------------------------------------------------------------------

_CHILD_SNIPPET = r"""
import sys
import numpy as np
from repro.core import BufferStore, SipcReader
from repro.core.flight import decode_message

frame = bytes.fromhex(sys.stdin.read().strip())
store = BufferStore(backing="file")
msg = decode_message(frame, store)
t = SipcReader(store).read_table(msg)
col = t.combine().batches[0].column("a")
print("SUM", int(col.values.sum()))
print("COPIED", store.copied_bytes)
store.close()
"""


def test_two_processes_map_same_store_file(tmp_path):
    store = _file_store(tmp_path)
    sb = Sandbox(store, KernelZero(store), "w", mode="zero")
    t = _make_table()
    msg = sb.write_output(t, label="t")
    frame = encode_message(msg, store)

    env = dict(os.environ, PYTHONPATH=os.path.join(
        os.path.dirname(__file__), "..", "src"))
    out = subprocess.run([sys.executable, "-c", _CHILD_SNIPPET],
                         input=frame.hex(), capture_output=True,
                         text=True, timeout=120, env=env)
    assert out.returncode == 0, out.stderr[-2000:]
    expect = int(t.combine().batches[0].column("a").values.sum())
    assert f"SUM {expect}" in out.stdout
    assert "COPIED 0" in out.stdout       # the child copied no data bytes
    store.close()


def test_child_sees_same_physical_bytes(tmp_path):
    """Byte-level check: a subprocess mmaps the backing file directly (no
    repro imports beyond numpy) and checksums the same extent."""
    store = _file_store(tmp_path)
    kz = KernelZero(store)
    cg = store.new_cgroup("t")
    f = kz.new_file(cg, "payload")
    payload = np.arange(4096 * 3, dtype=np.uint8)
    kz.deanon(f, payload)
    path = store.backing_path(f.file_id)
    code = ("import numpy as np, sys;"
            f"mm = np.memmap({path!r}, dtype=np.uint8, mode='r');"
            "print(int(mm.sum()))")
    out = subprocess.run([sys.executable, "-c", code],
                         capture_output=True, text=True, timeout=60)
    assert out.returncode == 0, out.stderr
    assert int(out.stdout.strip()) == int(payload.sum())
    store.close()


# ---------------------------------------------------------------------------
# process executor ≡ sequential executor
# ---------------------------------------------------------------------------

def _write_shards(tmpdir, n=3):
    paths = []
    for i in range(n):
        t = zarquet.gen_str_table(1, 1 << 16, str_len=32, repeats=4, seed=i)
        p = os.path.join(tmpdir, f"s{i}.zq")
        zarquet.write_table(p, t)
        paths.append(p)
    return paths


def _build_dags(paths):
    return [DAG([
        NodeSpec("load", source=p, est_mem=1 << 22),
        NodeSpec("enc", fn=dict_encode_op, deps=["load"], est_mem=1 << 22),
        NodeSpec("filt", fn=filter_even_op, deps=["enc"], est_mem=1 << 22,
                 keep_output=True),
    ], name=f"job{i}") for i, p in enumerate(paths)]


def test_process_mode_matches_sequential(tmp_path):
    paths = _write_shards(str(tmp_path))

    # sequential reference (seed semantics: RAM store, workers=1)
    ram = BufferStore()
    rm1 = ResourceManager(ram, RMConfig())
    dags1 = _build_dags(paths)
    Executor(ram, rm1).run(dags1)
    refs = [SipcReader(ram).read_table(d.nodes["filt"].output)
            for d in dags1]

    fstore = _file_store(tmp_path)
    rm2 = ResourceManager(fstore, RMConfig(workers=2,
                                           workers_mode="process"))
    ex = make_executor(fstore, rm2)
    assert isinstance(ex, ProcessWorkerExecutor)
    dags2 = _build_dags(paths)
    ex.run(dags2)
    try:
        outs = [SipcReader(fstore).read_table(d.nodes["filt"].output)
                for d in dags2]
        for got, want in zip(outs, refs):
            assert got.equals(want)
        # no node fell back to in-parent execution ...
        assert ex.fallback_inline == 0
        # ... and the socket carried references, not data: orders of
        # magnitude below the data the pipeline processed
        data_bytes = sum(t.nbytes for t in refs)
        assert ex.socket_bytes < max(data_bytes // 10, 1)
        assert fstore.copied_bytes == 0
    finally:
        ex.close()
        fstore.close()
        ram.close()


def test_process_mode_unpicklable_fn_falls_back_inline(tmp_path):
    paths = _write_shards(str(tmp_path), n=1)
    fstore = _file_store(tmp_path)
    rm = ResourceManager(fstore, RMConfig(workers=2,
                                          workers_mode="process"))
    ex = ProcessWorkerExecutor(fstore, rm, workers=2)

    captured = []                       # closure -> unpicklable

    def local_op(tables):
        captured.append(tables[0].num_rows)
        return tables[0]

    dag = DAG([
        NodeSpec("load", source=paths[0], est_mem=1 << 22),
        NodeSpec("op", fn=local_op, deps=["load"], est_mem=1 << 22,
                 keep_output=True),
    ], name="fb")
    ex.run([dag])
    try:
        assert ex.fallback_inline == 1
        assert captured and captured[0] > 0
        t = SipcReader(fstore).read_table(dag.nodes["op"].output)
        assert t.num_rows == captured[0]
    finally:
        ex.close()
        fstore.close()


def test_process_mode_decache_shares_loads(tmp_path):
    """Two DAGs over the same source: the load runs once, in a worker."""
    paths = _write_shards(str(tmp_path), n=1)
    fstore = _file_store(tmp_path)
    rm = ResourceManager(fstore, RMConfig(workers=2,
                                          workers_mode="process"))
    ex = ProcessWorkerExecutor(fstore, rm, workers=2)
    dags = [DAG([
        NodeSpec("load", source=paths[0], est_mem=1 << 22),
        NodeSpec("up", fn=upper_op, deps=["load"], est_mem=1 << 22,
                 keep_output=True),
    ], name=f"d{i}") for i in range(2)]
    ex.run(dags)
    try:
        assert ex.load_runs == 1
        t0 = SipcReader(fstore).read_table(dags[0].nodes["up"].output)
        t1 = SipcReader(fstore).read_table(dags[1].nodes["up"].output)
        assert t0.equals(t1)
    finally:
        ex.close()
        fstore.close()


# ---------------------------------------------------------------------------
# worker-crash fault injection
# ---------------------------------------------------------------------------

def test_worker_crash_mid_node_is_retried(tmp_path):
    """A worker SIGKILLed mid-node loses nothing: the request (references
    only, side-effect free) is replayed on a surviving worker, the node
    completes, and the RM's admission reservations fully drain."""
    paths = _write_shards(str(tmp_path), n=1)
    fstore = _file_store(tmp_path)
    rm = ResourceManager(fstore, RMConfig(workers=2,
                                          workers_mode="process"))
    ex = ProcessWorkerExecutor(fstore, rm, workers=2)
    marker = os.path.join(str(tmp_path), "crashed-once")
    dag = DAG([
        NodeSpec("load", source=paths[0], est_mem=1 << 22),
        NodeSpec("op", fn=functools.partial(crash_once_op, marker=marker),
                 deps=["load"], est_mem=1 << 22, keep_output=True),
    ], name="crash")
    try:
        ex.run([dag])
        assert os.path.exists(marker)          # it really died once
        assert ex.worker_retries == 1
        assert ex._pool.live_workers == 1      # the victim stayed retired
        assert dag.all_done()
        assert rm.admission.reserved == 0      # reservations released
        assert ex._inflight == {}
        t = SipcReader(fstore).read_table(dag.nodes["op"].output)
        assert t.num_rows > 0
    finally:
        ex.close()
        fstore.close()


def test_poison_op_quarantined_pool_survives(tmp_path):
    """An op that SIGKILLs its worker on every attempt exhausts its node
    retries and poisons *its own DAG* — run() returns (no raise), the
    DAG lands in the typed 'poisoned' outcome, every RM reservation
    drains, the offending fn is quarantined, and the pool heals back to
    at least one live worker so later DAGs still run."""
    paths = _write_shards(str(tmp_path), n=1)
    fstore = _file_store(tmp_path)
    rm = ResourceManager(fstore, RMConfig(workers=1, workers_mode="process",
                                          max_node_retries=2,
                                          retry_backoff_s=0.01))
    ex = ProcessWorkerExecutor(fstore, rm, workers=1)
    dag = DAG([
        NodeSpec("load", source=paths[0], est_mem=1 << 22),
        NodeSpec("op", fn=crash_always_op, deps=["load"], est_mem=1 << 22),
    ], name="doomed")
    try:
        ex.run([dag])                       # returns; does NOT raise
        assert dag.outcome == "poisoned"
        assert dag.cancelled
        assert rm.serve_stats["poisoned"] == 1
        assert rm.quarantined                # the killer fn is blacklisted
        assert rm.admission.reserved == 0
        assert ex._inflight == {}
        assert ex._pool.respawns >= 1        # replacements were spawned
        assert ex._pool.live_workers >= 1    # ...and the pool healed

        # the pool is still usable: an innocent DAG completes normally
        dag2 = DAG([
            NodeSpec("load", source=paths[0], est_mem=1 << 22),
            NodeSpec("op", fn=filter_even_op, deps=["load"], est_mem=1 << 22,
                     keep_output=True),
        ], name="innocent")
        ex.run([dag2])
        assert dag2.all_done() and dag2.outcome == "completed"
        t = SipcReader(fstore).read_table(dag2.nodes["op"].output)
        assert t.num_rows > 0
    finally:
        ex.close()
        fstore.close()


# ---------------------------------------------------------------------------
# pipeline integration
# ---------------------------------------------------------------------------

def test_pipeline_process_mode_equals_thread_mode(tmp_path):
    from repro.data.pipeline import (PipelineConfig, ZerrowDataPipeline,
                                     make_text_shards)
    shards = make_text_shards(os.path.join(str(tmp_path), "corpus"),
                              n_shards=2, rows_per_shard=400)
    batches = {}
    for mode in ("thread", "process"):
        pipe = ZerrowDataPipeline(shards, PipelineConfig(
            batch=4, seq_len=64, workers=2, workers_mode=mode))
        batches[mode] = [b["tokens"].copy()
                         for _, b in zip(range(4), pipe.batches(epochs=1))]
        pipe.close()
    assert len(batches["thread"]) == len(batches["process"])
    for a, b in zip(batches["thread"], batches["process"]):
        np.testing.assert_array_equal(a, b)


# ---------------------------------------------------------------------------
# flight server / client
# ---------------------------------------------------------------------------

def test_flight_server_client_roundtrip(tmp_path):
    server = FlightServer(store=_file_store(tmp_path, "server"))
    producer = _file_store(tmp_path, "producer")
    sb = Sandbox(producer, KernelZero(producer), "p", mode="zero")
    t = _make_table()
    pc = FlightClient(server.sock_path, store=producer)
    pc.put("tbl", sb.write_output(t, label="t"))

    consumer = FlightClient(server.sock_path,
                            store=_file_store(tmp_path, "consumer"))
    assert consumer.list() == ["tbl"]
    msg = consumer.get("tbl")
    t2 = SipcReader(consumer.store).read_table(msg)
    assert t2.equals(t)
    assert consumer.store.copied_bytes == 0
    # the exchange moved only control frames
    assert consumer.wire_bytes < t.nbytes
    stats = consumer.stats()
    assert stats["requests"] >= 3
    consumer.drop("tbl")
    assert consumer.list() == []
    consumer.store.close()
    consumer.close()
    pc.close()
    producer.close()
    server.close()
    server.store.close()

"""smollm-135m — HuggingFaceTB/SmolLM-135M [hf].

Dense llama-arch small: 30L, d_model 576, 9 heads (GQA kv=3), d_ff 1536,
vocab 49152.  Also the end-to-end training-example model.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="smollm-135m", family="dense",
    n_layers=30, d_model=576, n_heads=9, n_kv_heads=3,
    d_ff=1536, vocab=49152, mlp="swiglu", rope_theta=10000.0,
    tie_embeddings=True,
)

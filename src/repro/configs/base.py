"""Architecture + shape configuration system.

Every assigned architecture is an ``ArchConfig``; every workload shape is a
``ShapeConfig``.  ``(arch, shape)`` cells drive the smoke tests, the
multi-pod dry-run and the roofline table.
"""

from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Optional, Tuple


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                      # dense | moe | hybrid | vlm | ssm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0                # 0 -> d_model // n_heads
    mlp: str = "swiglu"              # swiglu | squared_relu | gelu
    # MoE
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    # hybrid (RG-LRU + local attention)
    block_pattern: Tuple[str, ...] = ()   # e.g. ("rec", "rec", "attn")
    local_window: int = 0
    lru_width: int = 0
    conv_width: int = 4
    # ssm (rwkv6)
    rwkv_head_dim: int = 64
    # vlm stub frontend
    vision_tokens: int = 0           # precomputed patch embeddings per sample
    # enc-dec (whisper): encoder stack + cross attention, frame-embed stub
    encoder_layers: int = 0
    # numerics / training
    dtype: str = "bfloat16"
    param_dtype: str = "float32"
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    optimizer: str = "adamw"         # adamw | adafactor
    remat: bool = True
    # attention backend: 'xla' (chunked online-softmax jnp; used for
    # lowering/roofline so FLOPs are visible in HLO) or 'pallas'
    attention_impl: str = "xla"
    attn_chunk: int = 1024
    # serving KV-cache dtype: 'model' (= cfg.dtype) or 'int8'
    # (per-(position, kv-head) symmetric quantization — halves cache HBM
    # and the decode memory roofline; beyond-paper serving optimization)
    kv_cache_dtype: str = "model"

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """Can this arch decode with a bounded cache at 500k context?"""
        return self.family in ("ssm", "hybrid")

    def param_count(self) -> int:
        """Analytic parameter count (for 6ND roofline cross-checks)."""
        d, f, V, L = self.d_model, self.d_ff, self.vocab, self.n_layers
        hd, H, KV = self.hd, self.n_heads, self.n_kv_heads
        def attn_p():
            return d * H * hd + 2 * d * KV * hd + H * hd * d
        def mlp_p(ff):
            n = 3 if self.mlp == "swiglu" else 2
            return n * d * ff
        total = V * d + (0 if self.tie_embeddings else V * d) + d
        if self.family == "ssm":                      # rwkv6
            per = (4 * d * d + d * d          # r,k,v,g + output
                   + 2 * d                    # decay/bonus etc (approx)
                   + 2 * d * f // 2 + d * f   # channel mix (approx)
                   + 8 * d)
            # channel-mix in rwkv6: wk (d,f) wv (f,d) wr (d,d)
            per = 5 * d * d + d * f + f * d + 10 * d
            return total + L * per
        if self.family == "hybrid":
            pat = self.block_pattern or ("rec",)
            n_attn = L * pat.count("attn") // len(pat)
            n_rec = L - n_attn
            w = self.lru_width or d
            rec = (d * w * 2                      # in/gate proj
                   + self.conv_width * w          # conv
                   + 2 * w * (w // 16 if False else 1) * 0
                   + 2 * w * w // max(1, 1)       # placeholder
                   + w * d)
            rec = 2 * d * w + self.conv_width * w + 3 * w + w * d \
                + 2 * (w * w) // 16               # block-diag gates (16 blocks)
            per_mlp = mlp_p(f)
            return total + n_attn * (attn_p() + per_mlp + 2 * d) \
                + n_rec * (rec + per_mlp + 2 * d)
        per = attn_p() + 2 * d
        if self.n_experts:
            per += d * self.n_experts \
                + self.n_experts * mlp_p(f) // 1
        else:
            per += mlp_p(f)
        total += L * per
        if self.encoder_layers:
            enc_per = attn_p() + mlp_p(f) + 2 * d
            dec_cross = attn_p() + d
            total += self.encoder_layers * enc_per + L * dec_cross
        if self.vision_tokens:
            total += self.vision_tokens * 0  # frontend is a stub
        return total

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top_k of n_experts)."""
        if not self.n_experts:
            return self.param_count()
        d, f, L = self.d_model, self.d_ff, self.n_layers
        n = 3 if self.mlp == "swiglu" else 2
        expert_p = n * d * f
        total = self.param_count() - L * self.n_experts * expert_p
        return total + L * self.top_k * expert_p


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: str            # train | prefill | decode
    seq_len: int
    global_batch: int

    @property
    def is_train(self) -> bool:
        return self.kind == "train"


TRAIN_4K = ShapeConfig("train_4k", "train", 4096, 256)
PREFILL_32K = ShapeConfig("prefill_32k", "prefill", 32768, 32)
DECODE_32K = ShapeConfig("decode_32k", "decode", 32768, 128)
LONG_500K = ShapeConfig("long_500k", "decode", 524288, 1)

SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
SHAPES_BY_NAME = {s.name: s for s in SHAPES}


def shape_applicable(arch: ArchConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Skip policy (DESIGN.md §4): long_500k only for sub-quadratic archs."""
    if shape.name == "long_500k" and not arch.sub_quadratic:
        return False, "skip(full-attn)"
    return True, ""


def smoke_variant(arch: ArchConfig) -> ArchConfig:
    """Reduced same-family config for CPU smoke tests."""
    kw = dict(
        n_layers=max(2, len(arch.block_pattern) or 2),
        d_model=64, n_heads=4,
        n_kv_heads=min(arch.n_kv_heads, 2),
        d_ff=128, vocab=256, head_dim=16,
        dtype="float32", param_dtype="float32", remat=False,
        attn_chunk=32,
    )
    if arch.n_experts:
        kw.update(n_experts=8, top_k=2, d_ff=32)
    if arch.family == "hybrid":
        kw.update(lru_width=64, local_window=32,
                  n_layers=len(arch.block_pattern))
    if arch.family == "ssm":
        kw.update(rwkv_head_dim=16, d_ff=128)
    if arch.encoder_layers:
        kw.update(encoder_layers=2)
    if arch.vision_tokens:
        kw.update(vision_tokens=8)
    return replace(arch, **kw)

"""internvl2-26b — InternVL2 26B [arXiv:2404.16821; hf].

VLM: InternViT frontend (STUB: input_specs provides 256 precomputed patch
embeddings per image) + InternLM2-20B-style backbone: 48L, d_model 6144,
48 heads (GQA kv=8), d_ff 16384, vocab 92553.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-26b", family="vlm",
    n_layers=48, d_model=6144, n_heads=48, n_kv_heads=8,
    d_ff=16384, vocab=92553, mlp="swiglu", rope_theta=1000000.0,
    vision_tokens=256,
)

"""whisper-medium — OpenAI Whisper medium [arXiv:2212.04356; unverified].

Enc-dec audio: 24 encoder + 24 decoder layers, d_model 1024, 16 heads
(kv=16, i.e. MHA), d_ff 4096, vocab 51865.  Conv frontend is a STUB:
input_specs provides precomputed frame embeddings (seq_len frames).
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-medium", family="audio",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16,
    d_ff=4096, vocab=51865, mlp="gelu", encoder_layers=24,
    rope_theta=0.0,   # whisper uses sinusoid/learned positions, not RoPE
)

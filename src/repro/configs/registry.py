"""Registry of the 10 assigned architectures."""

from .base import ArchConfig
from .granite_8b import CONFIG as GRANITE_8B
from .smollm_135m import CONFIG as SMOLLM_135M
from .nemotron_4_340b import CONFIG as NEMOTRON_4_340B
from .deepseek_67b import CONFIG as DEEPSEEK_67B
from .qwen3_moe_30b_a3b import CONFIG as QWEN3_MOE_30B
from .qwen3_moe_235b_a22b import CONFIG as QWEN3_MOE_235B
from .recurrentgemma_9b import CONFIG as RECURRENTGEMMA_9B
from .internvl2_26b import CONFIG as INTERNVL2_26B
from .rwkv6_3b import CONFIG as RWKV6_3B
from .whisper_medium import CONFIG as WHISPER_MEDIUM

ARCHS = {c.name: c for c in [
    GRANITE_8B, SMOLLM_135M, NEMOTRON_4_340B, DEEPSEEK_67B,
    QWEN3_MOE_30B, QWEN3_MOE_235B, RECURRENTGEMMA_9B, INTERNVL2_26B,
    RWKV6_3B, WHISPER_MEDIUM,
]}


def get_arch(name: str) -> ArchConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; available: {sorted(ARCHS)}")
    return ARCHS[name]

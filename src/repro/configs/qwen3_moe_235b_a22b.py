"""qwen3-moe-235b-a22b — Qwen3-235B-A22B-style [hf:Qwen/Qwen3-30B-A3B].

MoE: 94L, d_model 4096, 64 heads (GQA kv=4), per-expert d_ff 1536,
vocab 151936, 128 experts top-8.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-moe-235b-a22b", family="moe",
    n_layers=94, d_model=4096, n_heads=64, n_kv_heads=4,
    d_ff=1536, vocab=151936, mlp="swiglu", rope_theta=1000000.0,
    n_experts=128, top_k=8, head_dim=128,
)

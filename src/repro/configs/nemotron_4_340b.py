"""nemotron-4-340b — NVIDIA Nemotron-4 340B [arXiv:2402.16819; unverified].

Dense GQA with squared-ReLU MLP: 96L, d_model 18432, 96 heads (kv=8),
d_ff 73728, vocab 256000.  At this size Adam fp32 state cannot fit a
single 256-chip v5e pod; the config selects adafactor (documented in
EXPERIMENTS.md §Dry-run).
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="nemotron-4-340b", family="dense",
    n_layers=96, d_model=18432, n_heads=96, n_kv_heads=8,
    d_ff=73728, vocab=256000, mlp="squared_relu", rope_theta=10000.0,
    optimizer="adafactor",
)

"""recurrentgemma-9b — Griffin-style hybrid [arXiv:2402.19427; unverified].

38L, d_model 4096, 16 heads (MQA kv=1), d_ff 12288, vocab 256000.
RG-LRU + local attention, pattern 1 local-attn per 2 recurrent blocks
(rec, rec, attn).  Sub-quadratic: runs long_500k.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="recurrentgemma-9b", family="hybrid",
    n_layers=38, d_model=4096, n_heads=16, n_kv_heads=1,
    d_ff=12288, vocab=256000, mlp="swiglu",
    block_pattern=("rec", "rec", "attn"), local_window=2048,
    lru_width=4096, conv_width=4, head_dim=256,
)

"""granite-8b — IBM Granite Code 8B [arXiv:2405.04324; hf].

Dense llama-arch: 36L, d_model 4096, 32 heads (GQA kv=8), d_ff 14336,
vocab 49152, swiglu MLP.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="granite-8b", family="dense",
    n_layers=36, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=14336, vocab=49152, mlp="swiglu", rope_theta=10000.0,
)

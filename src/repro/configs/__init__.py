"""Assigned architecture configs (public-literature exact settings)."""

from .base import (ArchConfig, ShapeConfig, SHAPES, SHAPES_BY_NAME,
                   TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K,
                   shape_applicable, smoke_variant)
from .registry import ARCHS, get_arch

__all__ = ["ArchConfig", "ShapeConfig", "SHAPES", "SHAPES_BY_NAME",
           "TRAIN_4K", "PREFILL_32K", "DECODE_32K", "LONG_500K",
           "shape_applicable", "smoke_variant", "ARCHS", "get_arch"]

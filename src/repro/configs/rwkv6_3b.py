"""rwkv6-3b — RWKV-6 'Finch' 3B [arXiv:2404.05892; hf].

Attention-free SSM with data-dependent decay: 32L, d_model 2560,
d_ff 8960, vocab 65536.  Head dim 64 (40 heads).  Sub-quadratic:
runs long_500k with O(1) recurrent state.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="rwkv6-3b", family="ssm",
    n_layers=32, d_model=2560, n_heads=40, n_kv_heads=40,
    d_ff=8960, vocab=65536, mlp="rwkv", rwkv_head_dim=64,
)

"""qwen3-moe-30b-a3b — Qwen/Qwen3-30B-A3B [hf].

MoE: 48L, d_model 2048, 32 heads (GQA kv=4), per-expert d_ff 768,
vocab 151936, 128 experts top-8.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-moe-30b-a3b", family="moe",
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=4,
    d_ff=768, vocab=151936, mlp="swiglu", rope_theta=1000000.0,
    n_experts=128, top_k=8, head_dim=128,
)

"""Training input pipeline built ON Zerrow — the integration point between
the paper's system and the LM framework.

Per epoch, per shard:   loader node (zarquet -> Arrow, DeCache-shared)
                    [-> join+filter node against the corpus metadata
                        table when ``meta_path`` is set (the metadata
                        loader deserializes once for every shard DAG)]
                     -> pack node (tokenize + pack to a flat id column)
and per step a *zero-copy row-slice* of the packed column is reshared out
of the pipeline (paper Fig 6 'slice': no new bytes) and handed to
``device_put``.  Multiple trainers / epochs / eval jobs reading the same
shard share one physical copy through the DeCache (paper Fig 5), and
intermediate memory is governed by the RM's admission + eviction.

Shards may also be *streams* (``zarquet.StreamWriter`` output, see
``make_text_stream``): each committed row group gets its own
load→[joinf]→pack DAG pinned with ``row_groups=(g,)``, so a shard that
grew since the last epoch recomputes only its new micro-batches' cones
while every old group fingerprint-hits the manifest.
"""

from __future__ import annotations

import functools
import os
from dataclasses import dataclass
from typing import Dict, Iterator, List, Optional

import numpy as np

from ..core import (BufferStore, DAG, NodeSpec, RMConfig,
                    ResourceManager, SipcReader, Table, Column,
                    make_executor)
from ..core import ops, zarquet

PAD = 0


def byte_tokenize(text_col: Column) -> np.ndarray:
    """Byte-level tokenizer: utf8 column -> flat int32 ids (+1 offset so 0
    stays PAD)."""
    lo, hi = int(text_col.offsets[0]), int(text_col.offsets[-1])
    return text_col.values[lo:hi].astype(np.int32) + 1


def pack_fn(tables: List[Table], batch: int, seq_len: int) -> Table:
    """Tokenize + pack one shard to a flat id column, truncated to whole
    (batch, seq_len+1) spans.  Module-level (not a bound method) so a
    ``functools.partial`` of it pickles across the Flight process
    boundary in ``workers_mode='process'``."""
    ids = byte_tokenize(tables[0].combine().batches[0].column("text"))
    span = batch * (seq_len + 1)
    n = (len(ids) // span) * span
    return Table.from_pydict({"ids": ids[:n]})


def _keep_mask(batch, col: str) -> np.ndarray:
    """Right-side mask for the fused corpus filter: metadata rows whose
    keep flag is nonzero.  Module-level so the partial pickles."""
    return batch.column(col).to_numpy() != 0


def join_filter_fn(tables: List[Table], on: str = "doc",
                   keep_col: str = "keep") -> Table:
    """The pipeline's join-shaped stage: inner-join a text shard
    (``tables[0]``) with the corpus metadata table (``tables[1]``) on the
    document id and keep only rows whose ``keep_col`` is nonzero — the
    metadata-driven corpus filter every curated training set runs.
    Runs as the *fused* ``ops.filter_join``: the keep mask composes into
    the join's build-side selection, so the filtered intermediate table
    is never materialized (one gather per payload column instead of
    two).  Metadata payloads (e.g. the dict-encoded ``lang``) ride
    through the join with their dictionaries reshared by reference.
    Module-level so a partial of it crosses the Flight process
    boundary."""
    return ops.filter_join(
        tables[0], tables[1], on=on, how="inner",
        right_mask=functools.partial(_keep_mask, col=keep_col))


#: ops.filter_join is reached through the ``ops`` module attribute, which
#: node fingerprints do not chase — declare it (it chains to its
#: relational vkernels, incl. filter_join_gather) so a join/kernel edit
#: invalidates cached 'joinf' outputs instead of serving stale tables
join_filter_fn.__fp_includes__ = (ops.filter_join, _keep_mask)


def _gen_text_table(rng, base: int, rows: int) -> Table:
    words = ["the", "quick", "brown", "fox", "jumps", "over", "lazy",
             "dog", "zero", "copy", "arrow", "pipeline", "kernel",
             "memory", "shared", "data"]
    texts = [" ".join(rng.choice(words, size=rng.integers(8, 24)))
             for _ in range(rows)]
    return Table.from_pydict({
        "doc": np.arange(base, base + rows, dtype=np.int64),
        "text": texts})


def make_text_shards(root: str, n_shards: int, rows_per_shard: int,
                     seed: int = 0) -> List[str]:
    """Synthetic corpus shards (zarquet files with 'doc' id + 'text'
    columns; doc ids are globally unique across shards so a metadata
    table written by ``make_doc_meta`` joins against every shard)."""
    rng = np.random.default_rng(seed)
    paths = []
    os.makedirs(root, exist_ok=True)
    for s in range(n_shards):
        t = _gen_text_table(rng, s * rows_per_shard, rows_per_shard)
        p = os.path.join(root, f"shard-{s:04d}.zq")
        zarquet.write_table(p, t)
        paths.append(p)
    return paths


def make_text_stream(root: str, n_batches: int, rows_per_batch: int,
                     seed: int = 0, base_doc: int = 0) -> str:
    """Synthetic *streaming* corpus shard: the same 'doc'+'text' schema
    as ``make_text_shards``, but committed as ``n_batches`` row groups
    through ``zarquet.StreamWriter`` — the shape an ingest frontier
    produces.  Append more micro-batches later by reopening the path
    with ``StreamWriter``; the pipeline recomputes only the new groups'
    cones."""
    rng = np.random.default_rng(seed)
    os.makedirs(root, exist_ok=True)
    p = os.path.join(root, "stream-shard.zq")
    with zarquet.StreamWriter(p) as w:
        for b in range(n_batches):
            w.ingest(_gen_text_table(rng, base_doc + b * rows_per_batch,
                                     rows_per_batch))
            w.flush()
    return p


def make_doc_meta(root: str, n_docs: int, keep_frac: float = 0.75,
                  seed: int = 1) -> str:
    """Write the corpus metadata table (doc id, keep flag, language tag)
    the join-shaped pipeline stage filters against.  ``lang`` is low-
    cardinality on purpose: loaded with ``dict_columns=('lang',)`` its
    dictionary reshares through every per-shard join."""
    rng = np.random.default_rng(seed)
    langs = np.array(["en", "de", "fr", "ja"])
    t = Table.from_pydict({
        "doc": np.arange(n_docs, dtype=np.int64),
        "keep": (rng.random(n_docs) < keep_frac).astype(np.int64),
        "lang": list(langs[rng.integers(0, len(langs), size=n_docs)]),
    })
    os.makedirs(root, exist_ok=True)
    p = os.path.join(root, "meta.zq")
    zarquet.write_table(p, t)
    return p


@dataclass
class PipelineConfig:
    batch: int = 8
    seq_len: int = 256
    memory_limit: Optional[int] = None
    vocab: int = 257            # bytes + PAD
    workers: int = 1            # sched worker-pool size: >1 overlaps shard
    #                           # decompression across loader nodes
    reader_threads: Optional[int] = None   # per-shard zarquet reader pool:
    #                                      # column-chunk decompression
    #                                      # fan-out inside one load (None =
    #                                      # auto; 1 = serial)
    workers_mode: str = "thread"   # 'process': loader + pack run in
    #                              # spawned OS processes over the Flight
    #                              # data plane (compute scales past the
    #                              # GIL; store becomes file-backed)
    cache_root: Optional[str] = None   # persistent content-addressed cache
    #                                  # dir: re-runs adopt unchanged
    #                                  # shards' load/pack outputs from the
    #                                  # manifest instead of recomputing
    #                                  # (store becomes durable/file-backed)
    meta_path: Optional[str] = None    # corpus metadata table (see
    #                                  # make_doc_meta): adds a join-shaped
    #                                  # stage per shard — inner-join on
    #                                  # 'doc', keep rows with keep != 0 —
    #                                  # before packing; the metadata
    #                                  # loader is DeCache-shared across
    #                                  # every shard DAG


class ZerrowDataPipeline:
    """Iterator of {tokens, labels} numpy batches, Zerrow underneath."""

    def __init__(self, shard_paths: List[str], cfg: PipelineConfig,
                 store: Optional[BufferStore] = None,
                 rm: Optional[ResourceManager] = None):
        self.paths = list(shard_paths)
        self.cfg = cfg
        backing = ("file" if cfg.workers_mode == "process" or cfg.cache_root
                   else "ram")
        self.store = store or BufferStore(backing=backing,
                                          root=cfg.cache_root)
        self.rm = rm or ResourceManager(
            self.store, RMConfig(memory_limit=cfg.memory_limit,
                                 policy="adaptive",
                                 workers=cfg.workers,
                                 workers_mode=cfg.workers_mode,
                                 reader_threads=cfg.reader_threads,
                                 cache_root=cfg.cache_root))
        self.ex = make_executor(self.store, self.rm, workers=cfg.workers)
        self._owned_msgs: List = []

    # -- one shard -> packed ids message -----------------------------------
    def _pack_fn(self) -> "functools.partial[Table]":
        return functools.partial(pack_fn, batch=self.cfg.batch,
                                 seq_len=self.cfg.seq_len)

    def _shard_groups(self, path: str) -> Optional[int]:
        """Committed row-group count of a *stream* shard, None for batch
        shards (``write_table`` output)."""
        groups = zarquet.read_footer(path).get("groups")
        return None if groups is None else len(groups)

    def _run_shards(self, paths: List[str]) -> List:
        """One DAG per shard, submitted together: with ``workers > 1`` the
        loader decompressions overlap in the executor's worker pool.
        With ``meta_path`` each DAG grows a metadata loader (one DeCache-
        shared deserialization for all shards) and a join+filter stage
        between load and pack.

        A *stream* shard (``StreamWriter`` output) becomes one DAG per
        committed row group, each loader pinned with ``row_groups=(g,)``:
        packing is per-micro-batch, and because committed groups'
        content hashes never change, re-running after an append
        fingerprint-hits every old group's load→[joinf]→pack cone and
        recomputes only the new tail (with ``cache_root`` set)."""
        dags = []
        fn = self._pack_fn()
        meta = self.cfg.meta_path
        for path in paths:
            n_groups = self._shard_groups(path)
            size = os.path.getsize(path)
            est = max(size * 8 // (n_groups or 1), 1 << 20)
            # projection pruning (same loader knob core/plan's optimizer
            # targets): without the metadata join only 'text' is ever
            # read, so the 'doc' id column is never decoded; the join
            # needs the id, so the meta path loads the full shard
            cols = None if meta else ("text",)
            units = [None] if n_groups is None else \
                [(g,) for g in range(n_groups)]
            for rg in units:
                nodes = [NodeSpec("load", source=path, est_mem=est,
                                  columns=cols, row_groups=rg)]
                pack_dep = "load"
                if meta:
                    nodes.append(NodeSpec(
                        "meta", source=meta, dict_columns=("lang",),
                        est_mem=max(os.path.getsize(meta) * 8, 1 << 20)))
                    nodes.append(NodeSpec(
                        "joinf", fn=join_filter_fn,
                        deps=["load", "meta"], est_mem=est))
                    pack_dep = "joinf"
                nodes.append(NodeSpec("pack", fn=fn, deps=[pack_dep],
                                      est_mem=est // 2, keep_output=True))
                tag = "" if rg is None else f"@g{rg[0]}"
                dags.append(DAG(nodes,
                                name=f"pipe-{os.path.basename(path)}{tag}"))
        self.ex.run(dags)
        # keep_output=True: the packed messages survive DAG completion;
        # we own their release
        msgs = [d.nodes["pack"].output for d in dags]
        self._owned_msgs.extend(msgs)
        return msgs

    # -- batches ---------------------------------------------------------------
    def batches(self, epochs: int = 1) -> Iterator[Dict[str, np.ndarray]]:
        B, S = self.cfg.batch, self.cfg.seq_len
        span = B * (S + 1)
        group = max(1, self.cfg.workers)
        for _ in range(epochs):
            for g in range(0, len(self.paths), group):
                # NOTE: loader output is DeCache-shared; epoch 2+ and any
                # concurrent consumer reuse the same physical Arrow data
                for msg in self._run_shards(self.paths[g:g + group]):
                    reader = SipcReader(self.store)
                    packed = reader.read_table(msg)
                    col = packed.combine().batches[0].column("ids")
                    n = col.length
                    for i in range(n // span):
                        # zero-copy slice (reshared view of the packed
                        # buffer)
                        window = col.slice(i * span, (i + 1) * span)
                        arr = window.values.reshape(B, S + 1)
                        yield {"tokens": np.ascontiguousarray(arr[:, :-1]),
                               "labels": np.ascontiguousarray(arr[:, 1:])}
                    msg.release()
                    self._owned_msgs.remove(msg)
                    for fid in list(msg.files_referenced()):
                        f = self.store.files.get(fid)
                        if f is not None and f.refcount == 0 \
                                and not f.decache_pinned:
                            self.store.delete_file(fid)

    def stats(self) -> dict:
        return {"decache_hits": self.rm.decache.hits,
                "loads": self.ex.load_runs,
                "cache_hits": self.ex.cache_hits,
                **self.rm.cache_stats,
                **self.store.stats.snapshot()}

    def close(self) -> None:
        self.ex.close()
        self.store.close()

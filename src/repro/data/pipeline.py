"""Training input pipeline built ON Zerrow — the integration point between
the paper's system and the LM framework.

Per epoch, per shard:   loader node (zarquet -> Arrow, DeCache-shared)
                     -> pack node (tokenize + pack to a flat id column)
and per step a *zero-copy row-slice* of the packed column is reshared out
of the pipeline (paper Fig 6 'slice': no new bytes) and handed to
``device_put``.  Multiple trainers / epochs / eval jobs reading the same
shard share one physical copy through the DeCache (paper Fig 5), and
intermediate memory is governed by the RM's admission + eviction.
"""

from __future__ import annotations

import functools
import os
from dataclasses import dataclass
from typing import Dict, Iterator, List, Optional

import numpy as np

from ..core import (BufferStore, DAG, NodeSpec, RMConfig,
                    ResourceManager, SipcReader, Table, Column,
                    make_executor)
from ..core import ops, zarquet

PAD = 0


def byte_tokenize(text_col: Column) -> np.ndarray:
    """Byte-level tokenizer: utf8 column -> flat int32 ids (+1 offset so 0
    stays PAD)."""
    lo, hi = int(text_col.offsets[0]), int(text_col.offsets[-1])
    return text_col.values[lo:hi].astype(np.int32) + 1


def pack_fn(tables: List[Table], batch: int, seq_len: int) -> Table:
    """Tokenize + pack one shard to a flat id column, truncated to whole
    (batch, seq_len+1) spans.  Module-level (not a bound method) so a
    ``functools.partial`` of it pickles across the Flight process
    boundary in ``workers_mode='process'``."""
    ids = byte_tokenize(tables[0].combine().batches[0].column("text"))
    span = batch * (seq_len + 1)
    n = (len(ids) // span) * span
    return Table.from_pydict({"ids": ids[:n]})


def make_text_shards(root: str, n_shards: int, rows_per_shard: int,
                     seed: int = 0) -> List[str]:
    """Synthetic corpus shards (zarquet files with a 'text' column)."""
    rng = np.random.default_rng(seed)
    words = ["the", "quick", "brown", "fox", "jumps", "over", "lazy",
             "dog", "zero", "copy", "arrow", "pipeline", "kernel",
             "memory", "shared", "data"]
    paths = []
    os.makedirs(root, exist_ok=True)
    for s in range(n_shards):
        texts = [" ".join(rng.choice(words, size=rng.integers(8, 24)))
                 for _ in range(rows_per_shard)]
        t = Table.from_pydict({"text": texts})
        p = os.path.join(root, f"shard-{s:04d}.zq")
        zarquet.write_table(p, t)
        paths.append(p)
    return paths


@dataclass
class PipelineConfig:
    batch: int = 8
    seq_len: int = 256
    memory_limit: Optional[int] = None
    vocab: int = 257            # bytes + PAD
    workers: int = 1            # sched worker-pool size: >1 overlaps shard
    #                           # decompression across loader nodes
    reader_threads: Optional[int] = None   # per-shard zarquet reader pool:
    #                                      # column-chunk decompression
    #                                      # fan-out inside one load (None =
    #                                      # auto; 1 = serial)
    workers_mode: str = "thread"   # 'process': loader + pack run in
    #                              # spawned OS processes over the Flight
    #                              # data plane (compute scales past the
    #                              # GIL; store becomes file-backed)
    cache_root: Optional[str] = None   # persistent content-addressed cache
    #                                  # dir: re-runs adopt unchanged
    #                                  # shards' load/pack outputs from the
    #                                  # manifest instead of recomputing
    #                                  # (store becomes durable/file-backed)


class ZerrowDataPipeline:
    """Iterator of {tokens, labels} numpy batches, Zerrow underneath."""

    def __init__(self, shard_paths: List[str], cfg: PipelineConfig,
                 store: Optional[BufferStore] = None,
                 rm: Optional[ResourceManager] = None):
        self.paths = list(shard_paths)
        self.cfg = cfg
        backing = ("file" if cfg.workers_mode == "process" or cfg.cache_root
                   else "ram")
        self.store = store or BufferStore(backing=backing,
                                          root=cfg.cache_root)
        self.rm = rm or ResourceManager(
            self.store, RMConfig(memory_limit=cfg.memory_limit,
                                 policy="adaptive",
                                 workers=cfg.workers,
                                 workers_mode=cfg.workers_mode,
                                 reader_threads=cfg.reader_threads,
                                 cache_root=cfg.cache_root))
        self.ex = make_executor(self.store, self.rm, workers=cfg.workers)
        self._owned_msgs: List = []

    # -- one shard -> packed ids message -----------------------------------
    def _pack_fn(self) -> "functools.partial[Table]":
        return functools.partial(pack_fn, batch=self.cfg.batch,
                                 seq_len=self.cfg.seq_len)

    def _run_shards(self, paths: List[str]) -> List:
        """One DAG per shard, submitted together: with ``workers > 1`` the
        loader decompressions overlap in the executor's worker pool."""
        dags = []
        fn = self._pack_fn()
        for path in paths:
            est = max(os.path.getsize(path) * 8, 1 << 20)
            dags.append(DAG([
                NodeSpec("load", source=path, est_mem=est),
                NodeSpec("pack", fn=fn, deps=["load"],
                         est_mem=est // 2, keep_output=True),
            ], name=f"pipe-{os.path.basename(path)}"))
        self.ex.run(dags)
        # keep_output=True: the packed messages survive DAG completion;
        # we own their release
        msgs = [d.nodes["pack"].output for d in dags]
        self._owned_msgs.extend(msgs)
        return msgs

    # -- batches ---------------------------------------------------------------
    def batches(self, epochs: int = 1) -> Iterator[Dict[str, np.ndarray]]:
        B, S = self.cfg.batch, self.cfg.seq_len
        span = B * (S + 1)
        group = max(1, self.cfg.workers)
        for _ in range(epochs):
            for g in range(0, len(self.paths), group):
                # NOTE: loader output is DeCache-shared; epoch 2+ and any
                # concurrent consumer reuse the same physical Arrow data
                for msg in self._run_shards(self.paths[g:g + group]):
                    reader = SipcReader(self.store)
                    packed = reader.read_table(msg)
                    col = packed.combine().batches[0].column("ids")
                    n = col.length
                    for i in range(n // span):
                        # zero-copy slice (reshared view of the packed
                        # buffer)
                        window = col.slice(i * span, (i + 1) * span)
                        arr = window.values.reshape(B, S + 1)
                        yield {"tokens": np.ascontiguousarray(arr[:, :-1]),
                               "labels": np.ascontiguousarray(arr[:, 1:])}
                    msg.release()
                    self._owned_msgs.remove(msg)
                    for fid in list(msg.files_referenced()):
                        f = self.store.files.get(fid)
                        if f is not None and f.refcount == 0 \
                                and not f.decache_pinned:
                            self.store.delete_file(fid)

    def stats(self) -> dict:
        return {"decache_hits": self.rm.decache.hits,
                "loads": self.ex.load_runs,
                "cache_hits": self.ex.cache_hits,
                **self.rm.cache_stats,
                **self.store.stats.snapshot()}

    def close(self) -> None:
        self.ex.close()
        self.store.close()

"""Version-compatibility shims over the moving parts of the jax API.

The repo targets the jax baked into the container; upstream has moved
three symbols we rely on between releases:

  * ``shard_map``   — lived in ``jax.experimental.shard_map`` until it was
                      promoted to ``jax.shard_map``;
  * ``AxisType``    — ``jax.make_mesh(..., axis_types=...)`` only exists on
                      newer jax; older versions take no ``axis_types`` and
                      treat every axis as auto;
  * ``optimization_barrier`` — older jax ships the primitive without a
                      differentiation rule, so any model that barriers its
                      gathered params inside a scanned/checkpointed block
                      fails under ``jax.grad`` with NotImplementedError.

Import from here instead of from jax directly.
"""

from __future__ import annotations

import inspect

import jax

# -- shard_map ---------------------------------------------------------------
try:                                       # promoted top-level location
    _shard_map_impl = jax.shard_map
except AttributeError:                     # supported pre-promotion location
    from jax.experimental.shard_map import shard_map as _shard_map_impl

_SM_PARAMS = frozenset(
    inspect.signature(_shard_map_impl).parameters)


def shard_map(f, **kwargs):
    """``shard_map`` accepting the newest keyword spelling on any version.

    Newer jax renamed ``check_rep`` -> ``check_vma`` and replaced the
    ``auto`` axis set with its complement ``axis_names`` (the axes that are
    *manual*); translate to whatever the installed version understands.
    """
    if "check_vma" in kwargs and "check_vma" not in _SM_PARAMS:
        kwargs["check_rep"] = kwargs.pop("check_vma")
    if "axis_names" in kwargs and "axis_names" not in _SM_PARAMS:
        manual = set(kwargs.pop("axis_names"))
        mesh = kwargs.get("mesh")
        if mesh is not None and "auto" in _SM_PARAMS:
            auto = frozenset(mesh.axis_names) - manual
            if auto:
                kwargs["auto"] = auto
    return _shard_map_impl(f, **kwargs)


# -- make_mesh with axis_types ----------------------------------------------
def make_mesh(shape, axis_names):
    """``jax.make_mesh`` with explicit Auto axis types where supported.

    Newer jax distinguishes Auto/Explicit axis types; older jax has no
    ``AxisType`` and every axis is implicitly auto, so dropping the
    argument is semantically identical there.
    """
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return jax.make_mesh(shape, axis_names)
    return jax.make_mesh(shape, axis_names,
                         axis_types=(axis_type.Auto,) * len(axis_names))


# -- compiled-artifact cost analysis ----------------------------------------
def cost_analysis(compiled) -> dict:
    """``Compiled.cost_analysis()`` as a flat dict on every version.

    Older jax returns a list with one per-computation dict; newer jax
    returns the dict directly.
    """
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return dict(cost)


# -- differentiable optimization_barrier -------------------------------------
@jax.custom_vjp
def optimization_barrier(x):
    """``jax.lax.optimization_barrier`` that is differentiable on every
    supported jax version.

    The barrier is semantically the identity, so its VJP is the identity
    on cotangents; we barrier the cotangents too, matching the scheduling
    intent (keep per-iteration gathers inside the backward loop as well).
    """
    return jax.lax.optimization_barrier(x)


def _ob_fwd(x):
    return jax.lax.optimization_barrier(x), None


def _ob_bwd(_, g):
    return (jax.lax.optimization_barrier(g),)


optimization_barrier.defvjp(_ob_fwd, _ob_bwd)

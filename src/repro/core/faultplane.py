"""Unified fault-injection plane — one deterministic injector for every
crash/stall/corruption hook in the runtime.

The ad-hoc ``ZERROW_CRASH=<point>:<n>`` SIGKILL machinery that grew inside
``core/manifest.py`` is promoted here and generalized: the manifest publish
sequence, the ``zarquet.StreamWriter`` commit sequence, the flight worker
loop, the flight client, the executor's dispatch path and the ingest
refresh loop all report *fault points* to one :class:`FaultPlane`, which
decides — deterministically — whether anything fires.

Configuration is env-driven (inherited by spawned flight workers, which is
how cross-process injection works) or programmatic (current process only):

    ZERROW_FAULTS="<point>=<action>[:<arg>][@<sel>][,<point>=...]"

      action   kill           SIGKILL the process at the point
               raise          raise FaultInjected at the point
               delay:<s>      sleep s seconds, then continue (slow worker)
               stall:<s>      sleep s seconds, then continue — same effect,
                              separate name: used on socket paths where the
                              sleep is meant to push a peer past its reply
                              deadline (timeout-path testing)
               torn           returned to the call site, which performs its
                              own partial write and then calls :func:`kill`
                              (torn-tail crash points)
               corrupt        returned to the call site, which flips bytes
                              in the artifact it is about to produce
      sel      @n             fire on the n-th hit and every later one
                              (matches the legacy ZERROW_CRASH counting)
               @/n            fire on every n-th hit (periodic)
               @p<f>s<seed>   fire with probability f per hit, from a
                              dedicated random.Random(seed) — seedable, so
                              two processes given the same seed and hit
                              order inject identically
      default  @1 (every hit)

    ZERROW_CRASH="<point>:<n>"   legacy spelling, still honored:
      equivalent to "<point>=kill@n" — or "<point>=torn@n" when the point
      name contains "torn" (the call site tears its own write).

Programmatic use (tests): ``PLANE.install("stream_pre_sidecar", "raise")``,
``PLANE.reset()``.  Hit counters are per-process; ``fire`` is cheap when
nothing is installed (two env lookups + a dict probe), so production paths
keep their hooks permanently.

This module also hosts :class:`StragglerDetector` — the per-key EWMA +
median-factor straggler test shared by the flight worker pool's health
tracking and the training loop's ``runtime.fault.FleetMonitor``, so the
two cannot drift apart.
"""

from __future__ import annotations

import os
import random
import signal
import threading
import time
from dataclasses import dataclass
from typing import Dict, Iterable, List, Optional, Tuple

__all__ = ["FaultInjected", "FaultPlane", "FaultSpec", "PLANE",
           "StragglerDetector", "corrupt_file", "fire", "kill",
           "register_hook", "HOOKS"]


class FaultInjected(RuntimeError):
    """Raised at a fault point armed with the ``raise`` action."""


#: catalog of registered fault points -> description (the hook catalog
#: rendered in docs/ARCHITECTURE.md "Overload & fault model"); modules
#: register their points at import so the catalog is always current
HOOKS: Dict[str, str] = {}


def register_hook(point: str, description: str) -> str:
    HOOKS[point] = description
    return point


@dataclass
class FaultSpec:
    """One armed fault: what fires at a point, and when."""
    point: str
    action: str = "kill"          # kill|raise|delay|stall|torn|corrupt
    arg: float = 0.0              # seconds for delay/stall
    at: int = 1                   # fire when hits >= at ...
    every: Optional[int] = None   # ... or on every n-th hit instead
    p: Optional[float] = None     # ... or Bernoulli(p) per hit (seeded)
    seed: int = 0
    count: Optional[int] = None   # max total fires (None = unlimited)

    def __post_init__(self):
        self._rng = random.Random(self.seed) if self.p is not None else None
        self._fired = 0

    def armed(self, hits: int) -> bool:
        if self.count is not None and self._fired >= self.count:
            return False
        if self.p is not None:
            hot = self._rng.random() < self.p
        elif self.every is not None:
            hot = hits % self.every == 0
        else:
            hot = hits >= self.at
        if hot:
            self._fired += 1
        return hot


_ACTIONS = ("kill", "raise", "delay", "stall", "torn", "corrupt")


def _parse_spec(tok: str) -> Optional[FaultSpec]:
    """Parse one ``point=action[:arg][@sel]`` token; None when malformed
    (injection config errors must never take the runtime down)."""
    try:
        point, _, rest = tok.strip().partition("=")
        if not point or not rest:
            return None
        sel = ""
        if "@" in rest:
            rest, _, sel = rest.partition("@")
        action, _, arg = rest.partition(":")
        if action not in _ACTIONS:
            return None
        spec = FaultSpec(point, action, float(arg or 0.0))
        if sel.startswith("/"):
            spec.every = max(int(sel[1:]), 1)
        elif sel.startswith("p"):
            body = sel[1:]
            p, _, seed = body.partition("s")
            spec.p = min(max(float(p), 0.0), 1.0)
            spec.seed = int(seed or 0)
            spec.__post_init__()         # rebuild the seeded rng
        elif sel:
            spec.at = max(int(sel), 1)
        return spec
    except (ValueError, TypeError):
        return None


def _parse_env(faults: str, crash: str) -> Dict[str, FaultSpec]:
    specs: Dict[str, FaultSpec] = {}
    if crash:
        point, _, n = crash.partition(":")
        action = "torn" if "torn" in point else "kill"
        specs[point] = FaultSpec(point, action, at=int(n or 1))
    for tok in faults.split(","):
        if not tok.strip():
            continue
        spec = _parse_spec(tok)
        if spec is not None:
            specs[spec.point] = spec
    return specs


class FaultPlane:
    """Deterministic fault injector: per-point hit counters + armed specs.

    Programmatic specs (``install``) apply to this process; env specs
    (``ZERROW_FAULTS`` / legacy ``ZERROW_CRASH``) are re-read whenever the
    variables change, so a test can arm a point after import and a spawned
    worker inherits the parent's injection config through its environment.
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._specs: Dict[str, FaultSpec] = {}
        self._hits: Dict[str, int] = {}
        self._fired: Dict[str, int] = {}
        self._env_raw: Tuple[str, str] = ("", "")
        self._env_specs: Dict[str, FaultSpec] = {}

    # -- configuration -----------------------------------------------------
    def install(self, point: str, action: str = "kill", arg: float = 0.0,
                at: int = 1, every: Optional[int] = None,
                p: Optional[float] = None, seed: int = 0,
                count: Optional[int] = None) -> FaultSpec:
        """Arm one fault point programmatically (this process only)."""
        assert action in _ACTIONS, f"unknown fault action {action!r}"
        spec = FaultSpec(point, action, arg, at, every, p, seed, count)
        with self._lock:
            self._specs[point] = spec
        return spec

    def remove(self, point: str) -> None:
        with self._lock:
            self._specs.pop(point, None)

    def reset(self) -> None:
        """Drop every programmatic spec and all hit/fire counters."""
        with self._lock:
            self._specs.clear()
            self._hits.clear()
            self._fired.clear()
            self._env_raw = ("", "")
            self._env_specs = {}

    def hits(self, point: str) -> int:
        with self._lock:
            return self._hits.get(point, 0)

    def fired(self, point: str) -> int:
        with self._lock:
            return self._fired.get(point, 0)

    def snapshot(self) -> Dict[str, Dict[str, int]]:
        with self._lock:
            return {"hits": dict(self._hits), "fired": dict(self._fired)}

    # -- the hot path ------------------------------------------------------
    def _spec_for(self, point: str) -> Optional[FaultSpec]:
        spec = self._specs.get(point)
        if spec is not None:
            return spec
        raw = (os.environ.get("ZERROW_FAULTS", ""),
               os.environ.get("ZERROW_CRASH", ""))
        if raw != self._env_raw:
            self._env_specs = _parse_env(*raw)
            self._env_raw = raw
        return self._env_specs.get(point)

    def fire(self, point: str) -> Optional[str]:
        """Report one hit at ``point``.  Executes kill/raise/delay/stall
        in place; returns the action name for torn/corrupt (the call site
        applies those itself), the executed action's name for delay/stall,
        or None when nothing was armed."""
        with self._lock:
            spec = self._spec_for(point)
            if spec is None:
                return None       # unarmed: a dict probe + two env reads
            self._hits[point] = hits = self._hits.get(point, 0) + 1
            if not spec.armed(hits):
                return None
            self._fired[point] = self._fired.get(point, 0) + 1
            action, arg = spec.action, spec.arg
        if action == "kill":
            os.kill(os.getpid(), signal.SIGKILL)
        if action == "raise":
            raise FaultInjected(f"injected fault at {point!r}")
        if action in ("delay", "stall"):
            time.sleep(arg)
        return action


#: the process-wide plane (workers get their own via env inheritance)
PLANE = FaultPlane()


def fire(point: str) -> Optional[str]:
    """Module-level convenience: ``faultplane.fire("stream_pre_footer")``."""
    return PLANE.fire(point)


def kill() -> None:
    """SIGKILL this process — the second half of a torn-write fault (the
    call site writes its partial record first, then dies)."""
    os.kill(os.getpid(), signal.SIGKILL)


def corrupt_file(path: str, offset: int = 0, nbytes: int = 1) -> None:
    """Flip ``nbytes`` bytes of ``path`` in place (bit-rot simulation for
    corrupt-object detection tests).  Never used on live store files —
    content-addressed objects are hard links to them, so corrupting an
    object at rest means corrupting it *between* runs, after the writer
    process exited."""
    with open(path, "r+b") as fh:
        fh.seek(offset)
        chunk = fh.read(nbytes)
        fh.seek(offset)
        fh.write(bytes(b ^ 0xFF for b in chunk))


# --------------------------------------------------------------------------
# shared straggler detection
# --------------------------------------------------------------------------

class StragglerDetector:
    """Per-key service-time EWMA + median-factor straggler test.

    One implementation for both consumers so the two cannot drift:

      * ``runtime.fault.FleetMonitor`` — training-fleet heartbeats (keys
        are worker ids, samples are step times);
      * ``core.flight.worker.FlightWorkerPool.health`` — serving-plane
        request service times (keys are worker pids).

    ``update`` folds one sample into the key's EWMA; ``flag`` returns the
    keys whose EWMA exceeds ``factor`` x the population median (needing at
    least ``min_peers`` populated keys, since a median over fewer is
    noise).  Thread-safe: pool receiver threads update concurrently.
    """

    def __init__(self, alpha: float = 0.3, factor: float = 1.7,
                 min_peers: int = 3):
        self.alpha = alpha
        self.factor = factor
        self.min_peers = min_peers
        self._lock = threading.Lock()
        self._ewma: Dict[object, float] = {}

    def update(self, key, sample: float) -> float:
        with self._lock:
            prev = self._ewma.get(key, 0.0)
            cur = sample if prev == 0 \
                else self.alpha * sample + (1 - self.alpha) * prev
            self._ewma[key] = cur
            return cur

    def ewma(self, key) -> float:
        with self._lock:
            return self._ewma.get(key, 0.0)

    def drop(self, key) -> None:
        with self._lock:
            self._ewma.pop(key, None)

    def flag(self, keys: Optional[Iterable] = None
             ) -> Tuple[List, float]:
        """Returns ``(stragglers, median)`` over ``keys`` (default: every
        key with a populated EWMA)."""
        with self._lock:
            pop = {k: v for k, v in self._ewma.items()
                   if v > 0 and (keys is None or k in keys)} \
                if keys is None or not isinstance(keys, (set, frozenset)) \
                else {k: self._ewma[k] for k in keys
                      if self._ewma.get(k, 0.0) > 0}
        if len(pop) < self.min_peers:
            return [], 0.0
        times = sorted(pop.values())
        median = times[len(times) // 2]
        return ([k for k, v in pop.items() if v > self.factor * median],
                median)

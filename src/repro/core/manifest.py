"""Persistent content-addressed manifest — the cross-run half of DeCache.

The paper's DeCache (§3.1, §4.2.4) amortizes deserialization *within* a
process lifetime; Bauplan pipelines are FaaS jobs that restart constantly,
so the real wins come from differential caching across invocations ("FaaS
and Furious", Tagliabue et al. 2024).  This module makes node outputs
survive the process:

    <root>/objects/<sha256>     content-addressed store files (hard links
                                to — or, cross-device, copies of — the live
                                backing files; immutable once published)
    <root>/MANIFEST.log         append-only journal of publish records

A *publish* makes one node output durable:

  1. every store file the output references is materialized in its backing
     file (direct-swap extents are landed first), fsync'd, content-hashed,
     and linked into ``objects/`` under its hash — idempotent, so two nodes
     publishing reshared views of the same file share one object;
  2. the objects directory is fsync'd;
  3. one journal record — the node fingerprint plus the output's SIPC wire
     frame re-pointed at the object paths — is appended with a CRC and
     fsync'd.  The journal append is the commit point: a crash anywhere
     before it leaves at most unreferenced objects (garbage, never
     corruption); a crash during it leaves a torn tail record that recovery
     discards.

Recovery (``Manifest`` load, used by ``BufferStore.reopen``) scans the
journal, stops at the first torn record (truncating it in writer mode),
and drops entries whose object files are missing or short — so a reopened
manifest contains exactly the journaled complete outputs, each remappable
with zero bytes copied via ``decode_message``/``adopt_file``.

Fault injection goes through ``core.faultplane``: the legacy
``ZERROW_CRASH=<point>:<n>`` env spelling still SIGKILLs the process the
n-th time the named publish fault point is reached (``CRASH_POINTS``),
and the same points also accept any ``ZERROW_FAULTS`` / programmatic
action.  ``torn_journal`` writes half a record before dying — the
torn-tail case recovery must survive.
"""

from __future__ import annotations

import fcntl
import hashlib
import json
import os
import shutil
import struct
import threading
import zlib
from contextlib import contextmanager
from dataclasses import dataclass, field
from typing import Dict, Optional

from . import faultplane

REC_MAGIC = b"ZMF1"
_REC_HEAD = struct.Struct("<4sII")      # magic, payload_len, crc32(payload)

#: publish fault points, in execution order (see tests/test_persistence.py)
CRASH_POINTS = ("pre_link", "post_link", "pre_journal", "torn_journal",
                "pre_fsync", "post_fsync")

faultplane.register_hook("pre_link", "manifest publish: object hashed, "
                         "before the hard link into objects/")
faultplane.register_hook("post_link", "manifest publish: object linked, "
                         "before its fsync")
faultplane.register_hook("pre_journal", "manifest publish: objects durable, "
                         "before the journal append")
faultplane.register_hook("torn_journal", "manifest publish: half the "
                         "journal record written, then SIGKILL")
faultplane.register_hook("pre_fsync", "manifest publish: record appended, "
                         "before the journal fsync")
faultplane.register_hook("post_fsync", "manifest publish: journal fsync'd "
                         "(entry committed), before the in-memory update")


def _maybe_crash(point: str) -> None:
    """Report a publish fault point (kill/raise/delay per installed spec)."""
    faultplane.fire(point)


def _fsync_fd_of(path: str) -> None:
    fd = os.open(path, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


@contextmanager
def _flocked(fd: int):
    """Inter-process exclusive lock on the journal fd.  Serializes
    appends against recovery's torn-tail truncation when several
    processes share one cache root (the kernel releases it on process
    death, so a SIGKILL'd holder cannot wedge the root)."""
    fcntl.flock(fd, fcntl.LOCK_EX)
    try:
        yield
    finally:
        fcntl.flock(fd, fcntl.LOCK_UN)


# content hashing: one helper for the whole cache layer — source files
# and store files alike are hashed by content, cached by
# (path, size, mtime_ns) so an in-place rewrite never serves a stale hash
from .fingerprint import file_fingerprint as hash_file  # noqa: E402


@dataclass
class ManifestEntry:
    fingerprint: str
    frame: bytes                 # SIPC wire frame, object-relative paths
    nbytes: int                  # total object bytes referenced
    schema_fp: str
    label: str = ""
    meta: dict = field(default_factory=dict)


class Manifest:
    """The on-disk publish journal + content-addressed object directory."""

    def __init__(self, root: str):
        self.root = os.path.abspath(root)
        self.objects_dir = os.path.join(self.root, "objects")
        self.log_path = os.path.join(self.root, "MANIFEST.log")
        os.makedirs(self.objects_dir, exist_ok=True)
        self.entries: Dict[str, ManifestEntry] = {}
        self.dropped_torn = 0        # torn tail records discarded
        self.dropped_incomplete = 0  # journaled but objects missing/short
        self.dropped_corrupt = 0     # content hash mismatch (verify mode)
        self.published = 0
        self.object_copies = 0       # cross-device publishes (copied, not linked)
        # verify-on-adopt: when on, _objects_intact re-hashes every
        # objects/-relative ref against its content-addressed name, so
        # at-rest corruption (bit rot, a torn copy) is detected instead
        # of served.  Off by default — hashing costs a full read; the
        # size check alone already catches truncation.
        self.verify_objects = bool(os.environ.get("ZERROW_VERIFY_OBJECTS"))
        self._lock = threading.Lock()
        flags = os.O_WRONLY | os.O_CREAT | os.O_APPEND
        self._log_fd = os.open(self.log_path, flags, 0o644)
        with _flocked(self._log_fd):
            # load under the journal lock: a concurrent writer's half-
            # visible append must not be mistaken for a torn tail
            good_end = self._load()
            # recover: a real torn tail would make every later append
            # unreadable
            if os.path.getsize(self.log_path) > good_end:
                os.ftruncate(self._log_fd, good_end)

    # -- recovery ----------------------------------------------------------
    def _load(self) -> int:
        """Scan the journal; returns the offset past the last good record."""
        if not os.path.exists(self.log_path):
            return 0
        with open(self.log_path, "rb") as fh:
            data = fh.read()
        pos = 0
        while pos < len(data):
            head = data[pos:pos + _REC_HEAD.size]
            if len(head) < _REC_HEAD.size:
                self.dropped_torn += 1
                break
            magic, plen, crc = _REC_HEAD.unpack(head)
            payload = data[pos + _REC_HEAD.size:pos + _REC_HEAD.size + plen]
            if magic != REC_MAGIC or len(payload) < plen or \
                    zlib.crc32(payload) != crc:
                self.dropped_torn += 1
                break
            pos += _REC_HEAD.size + plen
            try:
                rec = json.loads(payload.decode())
                entry = ManifestEntry(rec["fp"], bytes.fromhex(rec["frame"]),
                                      rec["bytes"], rec["schema_fp"],
                                      rec.get("label", ""),
                                      rec.get("meta", {}))
            except (ValueError, KeyError):
                self.dropped_torn += 1
                break
            if self._objects_intact(entry):
                self.entries[entry.fingerprint] = entry
            else:
                self.dropped_incomplete += 1
        return pos

    def _objects_intact(self, entry: ManifestEntry) -> bool:
        from .flight.wire import WireError, frame_refs
        try:
            refs = frame_refs(entry.frame)
        except WireError:
            return False
        for path, offset, length in refs:
            p = self.resolve(path)
            try:
                if os.path.getsize(p) < offset + length:
                    return False
            except OSError:
                return False
            if self.verify_objects and not os.path.isabs(path):
                # content-addressed: the object's basename IS its hash
                # (hash_file caches by (path, size, mtime_ns), so the
                # re-read cost is paid once per changed file)
                if hash_file(p) != os.path.basename(path):
                    self.dropped_corrupt += 1
                    return False
        return True

    def resolve(self, path: str) -> str:
        return path if os.path.isabs(path) else os.path.join(self.root, path)

    # -- publish -----------------------------------------------------------
    def publish(self, store, fingerprint: str, msg, label: str = "",
                meta: Optional[dict] = None) -> ManifestEntry:
        """Make one SipcMessage durable under ``fingerprint``.  Idempotent:
        an already-published fingerprint returns the existing entry."""
        from .flight.wire import encode_message
        e = self.entries.get(fingerprint)
        if e is not None:
            return e
        # hashing + linking + fsync run OUTSIDE the manifest lock —
        # they are slow, per-file idempotent (content addressing), and
        # concurrent publishers of the same object simply race to the
        # same link.  Only the entries check and the journal append need
        # serializing.
        obj_rel: Dict[int, str] = {}
        total = 0
        for fid in msg.files_referenced():
            store.ensure_file_backed(fid)
            src = store.backing_path(fid)
            _fsync_fd_of(src)           # mmap'd writes -> durable first
            sha = hash_file(src)
            rel = os.path.join("objects", sha)
            obj = os.path.join(self.root, rel)
            _maybe_crash("pre_link")
            if not os.path.exists(obj):
                try:
                    os.link(src, obj)
                except FileExistsError:
                    pass                # racing publisher won
                except OSError:         # cross-device: copy + atomic move
                    tmp = f"{obj}.tmp-{os.getpid()}"
                    shutil.copyfile(src, tmp)
                    _fsync_fd_of(tmp)
                    os.replace(tmp, obj)
                    self.object_copies += 1
            _maybe_crash("post_link")
            _fsync_fd_of(obj)
            obj_rel[fid] = rel
            total += os.path.getsize(obj)
        _fsync_fd_of(self.objects_dir)
        frame = encode_message(msg, store, path_for=obj_rel.__getitem__)
        schema_fp = hashlib.sha256(msg.schema_bytes).hexdigest()
        payload = json.dumps({
            "fp": fingerprint, "frame": frame.hex(), "bytes": total,
            "schema_fp": schema_fp, "label": label,
            "meta": meta or {}}).encode()
        record = _REC_HEAD.pack(REC_MAGIC, len(payload),
                                zlib.crc32(payload)) + payload
        with self._lock:
            e = self.entries.get(fingerprint)
            if e is not None:
                return e                # racing thread journaled it first
            _maybe_crash("pre_journal")
            with _flocked(self._log_fd):
                if faultplane.fire("torn_journal") == "torn":
                    # write half the record, then die: recovery must
                    # discard this torn tail (flock dies with us)
                    os.write(self._log_fd,
                             record[:max(len(record) // 2, 1)])
                    faultplane.kill()
                # one append: the commit point
                os.write(self._log_fd, record)
                _maybe_crash("pre_fsync")
                os.fsync(self._log_fd)
            _maybe_crash("post_fsync")
            e = ManifestEntry(fingerprint, frame, total, schema_fp, label,
                              meta or {})
            self.entries[fingerprint] = e
            self.published += 1
            return e

    # -- lookup / adoption -------------------------------------------------
    def get(self, fingerprint: Optional[str]) -> Optional[ManifestEntry]:
        if fingerprint is None:
            return None
        return self.entries.get(fingerprint)

    def __contains__(self, fingerprint) -> bool:
        return fingerprint is not None and fingerprint in self.entries

    def __len__(self) -> int:
        return len(self.entries)

    def decode(self, fingerprint: str, store, owner=None, charge: bool = True,
               label: str = ""):
        """Adopt a published output into ``store`` (zero bytes copied —
        ``adopt_file`` mmaps the objects).  Returns None and drops the
        entry when its objects vanished underneath us.  Objects are
        re-validated *before* any adoption so a vanished file cannot
        leave earlier refs of the same frame adopted-and-charged (an
        accounting leak the store could never drain)."""
        from .flight.wire import WireError, decode_message
        e = self.entries.get(fingerprint)
        if e is None:
            return None
        if not self._objects_intact(e):
            with self._lock:
                self.entries.pop(fingerprint, None)
            return None
        try:
            return decode_message(e.frame, store, owner=owner, charge=charge,
                                  path_base=self.root,
                                  label=label or e.label or "cached")
        except (OSError, WireError):
            with self._lock:
                self.entries.pop(fingerprint, None)
            return None

    def close(self) -> None:
        if self._log_fd is not None:
            try:
                os.close(self._log_fd)
            except OSError:
                pass
            self._log_fd = None

"""SIPC — Shared Inter-Process Communication (paper §3.2, §4.2.2).

Extends the Arrow IPC protocol: Schema messages are copied to the sink as
usual (small), but RecordBatch and DictionaryBatch payloads are written as
``(file_id, offset, length)`` reference tuples into KernelZero-backed tmpfs
files.  A SIPC stream is therefore tiny: references, not data.

Implements the paper's three write-path techniques:

  * de-anonymization   — anonymous output buffers are transferred (not
                         copied) into per-column store files;
  * IPC inspection +
    resharing          — on write, each outgoing buffer's *physical address
                         range* is checked against the ranges mapped during
                         input reads; a hit emits a reference into the input
                         file instead of de-anonymizing again;
  * dictionary sharing — dictionaries ride through filter/sort by reference
                         even when the code buffers must be copied.

Degrees of copy avoidance (paper Fig 1) are selectable for benchmarking:
    mode='full_copy'    -> B: writer memcpy + reader memcpy
    mode='writer_copy'  -> C: writer memcpy, reader mmap (views)
    mode='zero'         -> D: de-anonymization + resharing (Zerrow)
    mode='zero_noreshare' -> ablation: deanon without IPC inspection

In zero mode every emitted output buffer counts toward
``StoreStats.reshare_hits`` (emitted as a reference — lazy pass-through
or AddressMap hit) or ``reshare_misses`` (de-anonymized); the hit-rate
is the per-buffer copy-avoidance score benchmarks report (e.g. the join
payload-dictionary path in ``benchmarks/bench_join.py``).
"""

from __future__ import annotations

import bisect
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .arrow import (ArrowType, Column, RecordBatch, Schema, Table)
from .buffers import BufferStore, Cgroup, LazyBuf, addr_range
from .deanon import KernelZero

MODES = ("full_copy", "writer_copy", "zero", "zero_noreshare")


# --------------------------------------------------------------------------
# physical address interval map (the 'IPC inspection' index)
# --------------------------------------------------------------------------

class AddressMap:
    """Maps virtual-address intervals -> (file_id, file_offset).

    Holds references to the mapped arrays so the address space cannot be
    recycled while the map is alive (prevents false positives)."""

    def __init__(self) -> None:
        self._starts: List[int] = []
        self._entries: List[Tuple[int, int, int, int]] = []  # (start, end, fid, foff)
        self._keep: List[np.ndarray] = []

    def add(self, arr: np.ndarray, file_id: int, file_off: int) -> None:
        if arr.nbytes == 0:
            return
        a, n = addr_range(arr.view(np.uint8).reshape(-1))
        i = bisect.bisect_left(self._starts, a)
        self._starts.insert(i, a)
        self._entries.insert(i, (a, a + n, file_id, file_off))
        self._keep.append(arr)

    def lookup(self, arr: np.ndarray) -> Optional[Tuple[int, int]]:
        """Return (file_id, file_offset) if arr's memory is fully contained
        in a mapped input range."""
        if arr.nbytes == 0 or not arr.flags["C_CONTIGUOUS"]:
            return None
        a, n = addr_range(arr.view(np.uint8).reshape(-1))
        i = bisect.bisect_right(self._starts, a) - 1
        if i < 0:
            return None
        s, e, fid, foff = self._entries[i]
        if a >= s and a + n <= e:
            return fid, foff + (a - s)
        return None

    def merge_from(self, other: "AddressMap") -> None:
        for (s, e, fid, foff), keep in zip(other._entries, other._keep):
            i = bisect.bisect_left(self._starts, s)
            self._starts.insert(i, s)
            self._entries.insert(i, (s, e, fid, foff))
        self._keep.extend(other._keep)


# --------------------------------------------------------------------------
# reference messages (what actually goes over the 'wire')
# --------------------------------------------------------------------------

@dataclass
class BufRef:
    file_id: int
    offset: int
    length: int
    reshared: bool = False


@dataclass
class ColumnRefs:
    type: ArrowType
    length: int
    validity: Optional[BufRef]
    offsets: Optional[BufRef]
    values: BufRef
    dictionary: Optional["ColumnRefs"] = None

    def all_refs(self) -> List[BufRef]:
        out = [r for r in (self.validity, self.offsets, self.values) if r]
        if self.dictionary:
            out += self.dictionary.all_refs()
        return out


@dataclass
class BatchRefs:
    num_rows: int
    columns: List[ColumnRefs]


@dataclass
class SipcMessage:
    """The SIPC 'file': schema bytes (copied) + reference tuples."""
    schema_bytes: bytes
    batches: List[BatchRefs]
    new_bytes: int = 0          # physically new data (deanon'd or copied)
    reshared_bytes: int = 0     # data referenced back to inputs
    _store: Optional[BufferStore] = None
    _pinned: List[int] = field(default_factory=list)
    released: bool = False

    def all_refs(self) -> List[BufRef]:
        return [r for b in self.batches for c in b.columns for r in c.all_refs()]

    def files_referenced(self) -> Dict[int, int]:
        """IPC-inspection product: {file_id: bytes referenced} — what the RM
        needs for share-aware refcounting/GC (paper Challenge 6)."""
        out: Dict[int, int] = {}
        for r in self.all_refs():
            if r.file_id == 0:
                continue                     # canonical empty buffer
            out[r.file_id] = out.get(r.file_id, 0) + r.length
        return out

    def pin(self, store: BufferStore) -> None:
        assert not self._pinned
        self._store = store
        for fid in self.files_referenced():
            store.get(fid).incref()
            self._pinned.append(fid)

    def release(self) -> None:
        if self.released:
            return
        self.released = True
        for fid in self._pinned:
            f = self._store.files.get(fid)
            if f is not None:
                f.decref()
        self._pinned = []

    @property
    def wire_nbytes(self) -> int:
        """Size of the SIPC stream itself: schema + 3 ints per buffer."""
        return len(self.schema_bytes) + 24 * len(self.all_refs())


# --------------------------------------------------------------------------
# writer
# --------------------------------------------------------------------------

class SipcWriter:
    def __init__(self, store: BufferStore, kz: KernelZero, cgroup: Cgroup,
                 mode: str = "zero", input_map: Optional[AddressMap] = None,
                 label: str = ""):
        assert mode in MODES, mode
        self.store = store
        self.kz = kz
        self.cgroup = cgroup
        self.mode = mode
        self.input_map = input_map
        self.label = label
        self._emitted = AddressMap()   # dedup within one stream (e.g. A ⊕ A)

    def write_table(self, table: Table) -> SipcMessage:
        schema_bytes = bytes(table.schema.to_json_bytes())  # schema is copied
        # one store file per column (+1 for all dictionaries): paper §4.2.2
        col_files = [self.kz.new_file(self.cgroup, f"{self.label}.c{j}")
                     for j in range(table.num_columns)]
        dict_file = None
        msg = SipcMessage(schema_bytes, [])
        for b in table.batches:
            cols = []
            for j, col in enumerate(b.columns):
                if col.type.is_dict and dict_file is None:
                    dict_file = self.kz.new_file(self.cgroup, f"{self.label}.dict")
                cols.append(self._write_column(col, col_files[j], dict_file, msg))
            msg.batches.append(BatchRefs(b.num_rows, cols))
        # empty files (fully reshared columns) are deleted eagerly
        for f in col_files + ([dict_file] if dict_file else []):
            if f is not None and f.length == 0:
                self.store.delete_file(f.file_id)
        msg.pin(self.store)
        return msg

    def _write_column(self, col: Column, file, dict_file, msg: SipcMessage
                      ) -> ColumnRefs:
        # raw (possibly unforced-lazy) buffers: pass-through columns are
        # reshared straight from provenance without faulting any data
        bufs = dict(col.buffers())
        validity = self._emit(bufs["validity"], file, msg) \
            if "validity" in bufs else None
        offsets = self._emit(bufs["offsets"], file, msg) \
            if "offsets" in bufs else None
        values = self._emit(bufs["values"], file, msg)
        dic = None
        if col.dictionary is not None:
            dic = self._write_column(col.dictionary, dict_file, dict_file, msg)
        return ColumnRefs(col.type, col.length, validity, offsets, values, dic)

    def _emit(self, arr, file, msg: SipcMessage) -> BufRef:
        if getattr(arr, "nbytes", None) == 0 or \
                (isinstance(arr, LazyBuf) and arr.length == 0):
            return BufRef(0, 0, 0)           # canonical empty buffer
        if isinstance(arr, LazyBuf):
            if self.mode == "zero" and not arr.forced:
                # pass-through of an unfaulted mapping: reshare straight from
                # provenance — no data is ever touched (true zero copy)
                self.store.stats.bytes_reshared += arr.length
                self.store.stats.reshare_hits += 1
                msg.reshared_bytes += arr.length
                return BufRef(arr.file_id, arr.offset, arr.length,
                              reshared=True)
            arr = arr.force()
        arr = arr if arr.flags["C_CONTIGUOUS"] else np.ascontiguousarray(arr)
        n = arr.nbytes
        if self.mode in ("zero", "zero_noreshare"):
            if self.mode == "zero":
                # IPC inspection: does this buffer's memory live in an input
                # file we mapped (or something we already emitted)?
                hit = (self.input_map.lookup(arr) if self.input_map else None) \
                    or self._emitted.lookup(arr)
                if hit is not None:
                    fid, foff = hit
                    self.store.stats.bytes_reshared += n
                    self.store.stats.reshare_hits += 1
                    msg.reshared_bytes += n
                    return BufRef(fid, foff, n, reshared=True)
                self.store.stats.reshare_misses += 1
            off, _ = self.kz.deanon(file, arr)
            self._emitted.add(arr, file.file_id, off)
            msg.new_bytes += n
            return BufRef(file.file_id, off, n)
        # baseline: writer-side memcpy into the shared file
        off, _ = self.kz.writer_copy(file, arr)
        msg.new_bytes += n
        return BufRef(file.file_id, off, n)


# --------------------------------------------------------------------------
# reader
# --------------------------------------------------------------------------

class SipcReader:
    def __init__(self, store: BufferStore, mode: str = "zero",
                 record_map: Optional[AddressMap] = None,
                 fault_lock=None):
        assert mode in MODES, mode
        self.store = store
        self.mode = mode
        self.map = record_map if record_map is not None else AddressMap()
        # lock that LazyBuf faults must hold (the executor critical
        # section) when user code runs outside it
        self.fault_lock = fault_lock

    def read_table(self, msg: SipcMessage) -> Table:
        schema = Schema.from_json_bytes(msg.schema_bytes)
        batches = []
        for b in msg.batches:
            cols = [self._read_column(c) for c in b.columns]
            batches.append(RecordBatch(schema, cols))
        return Table(batches)

    def _read_column(self, c: ColumnRefs) -> Column:
        validity = self._mmap(c.validity, "uint8") if c.validity else None
        offsets = self._mmap(c.offsets, "int64") if c.offsets else None
        if c.type.is_utf8:
            values = self._mmap(c.values, "uint8")
        elif c.type.is_dict:
            values = self._mmap(c.values, "int32")
        else:
            values = self._mmap(c.values, c.type.np_dtype)
        dic = self._read_column(c.dictionary) if c.dictionary else None
        return Column(c.type, c.length, values, offsets=offsets,
                      validity=validity, dictionary=dic)

    def _mmap(self, ref: BufRef, np_dtype: str):
        if ref.length == 0:
            return np.empty(0, dtype=np.dtype(np_dtype))
        if self.mode == "full_copy":
            view = self.store.get(ref.file_id).read(ref.offset, ref.length)
            out = view.copy()                     # the reader-side copy
            self.store.stats.bytes_copied += out.nbytes
            return out.view(np.dtype(np_dtype))
        # lazy mapping: data faults in only when compute touches it; on
        # fault, record the mapped range for later resharing by address
        return LazyBuf(self.store, ref.file_id, ref.offset, ref.length,
                       np_dtype, on_force=self._on_force,
                       fault_lock=self.fault_lock)

    def _on_force(self, raw: np.ndarray, file_id: int, offset: int) -> None:
        self.map.add(raw, file_id, offset)

"""SIPC wire encoding — reference-passing serialization of SipcMessages.

A SIPC stream between processes carries *references, never data*: the
schema bytes (small, copied — exactly as the in-process SIPC does) plus
one ``(file_path, offset, length)`` tuple per Arrow buffer.  Readers
reconstruct Tables by mmap'ing the referenced extents of the shared
store files; the frame itself is a few hundred bytes regardless of how
many gigabytes it describes (cf. "Benchmarking Apache Arrow Flight" —
reference-passing beats data-copying wire protocols by an order of
magnitude locally).

Frame layout (little-endian):

    magic   4s   b"SIP1"
    version u16
    schema  u32 len + bytes               (JSON schema, copied)
    paths   u16 count, each: u16 len + utf8 bytes   (backing-file table)
    batches u32 count, each:
        num_rows u64, n_cols u32, then per column (recursing into the
        dictionary column when flagged):
            type    u16 len + ArrowType JSON
            length  u64
            flags   u8   (1=validity, 2=offsets, 4=dictionary)
            refs    [validity?][offsets?][values], each:
                path_idx u32 (0xFFFFFFFF = canonical empty buffer)
                offset   u64
                length   u64
                reshared u8

Decoding adopts each referenced path into the local BufferStore —
``adopt_file`` recognises paths the store already owns, so a worker
output that reshares one of the parent's input files decodes into a
reference to the *original* file: resharing survives the process hop
with zero new bytes.
"""

from __future__ import annotations

import json
import os
import socket
import struct
from typing import Dict, List, Optional, Tuple

from ..arrow import ArrowType
from ..buffers import BufferStore, Cgroup
from ..sipc import BatchRefs, BufRef, ColumnRefs, SipcMessage

MAGIC = b"SIP1"
VERSION = 1
_EMPTY = 0xFFFFFFFF

_F_VALIDITY, _F_OFFSETS, _F_DICT = 1, 2, 4


class WireError(RuntimeError):
    """Malformed or version-incompatible SIPC frame."""


# --------------------------------------------------------------------------
# primitive packers
# --------------------------------------------------------------------------

def _pack_bytes(out: List[bytes], b: bytes, fmt: str = "<I") -> None:
    out.append(struct.pack(fmt, len(b)))
    out.append(b)


class _Cursor:
    __slots__ = ("data", "pos")

    def __init__(self, data: bytes):
        self.data = data
        self.pos = 0

    def take(self, fmt: str):
        size = struct.calcsize(fmt)
        vals = struct.unpack_from(fmt, self.data, self.pos)
        self.pos += size
        return vals if len(vals) > 1 else vals[0]

    def take_bytes(self, fmt: str = "<I") -> bytes:
        n = self.take(fmt)
        b = self.data[self.pos:self.pos + n]
        if len(b) != n:
            raise WireError("truncated SIPC frame")
        self.pos += n
        return b


# --------------------------------------------------------------------------
# encode
# --------------------------------------------------------------------------

def export_paths(msg: SipcMessage, store: BufferStore,
                 path_for=None) -> Dict[int, str]:
    """The store-mutating half of :func:`encode_message`: land every
    direct-swap extent in its backing file and resolve each referenced
    file's exported path.  The process executor runs this under its RM
    lock and then encodes the (now pure) frame outside it, so frame
    serialization overlaps with other scheduler threads instead of
    serializing on the critical section."""
    out: Dict[int, str] = {}
    for fid in msg.files_referenced():
        if fid == 0 or fid in out:
            continue
        # direct-swap extents live in a separate swap file until
        # faulted; land them in the backing file before exporting a
        # reference, or readers would map a sparse hole
        store.ensure_file_backed(fid)
        out[fid] = (path_for(fid) if path_for is not None
                    else store.backing_path(fid))
    return out


def encode_message(msg: SipcMessage, store: Optional[BufferStore] = None,
                   path_for=None,
                   fid_paths: Optional[Dict[int, str]] = None) -> bytes:
    """Serialize ``msg`` to a reference frame.  Requires a file-backed
    store (references must name real files other processes can map).

    ``path_for(file_id) -> str`` overrides the exported path per file —
    the manifest publishes frames whose references name the durable
    content-addressed objects (relative to the manifest root) instead of
    the live backing files.  ``fid_paths`` (from :func:`export_paths`)
    supplies the resolved paths up front, making this call pure — no
    store access, safe outside any lock.
    """
    paths: List[str] = []
    path_idx: Dict[int, int] = {}     # file_id -> index into `paths`

    def ref_idx(r: BufRef) -> int:
        if r.file_id == 0:
            return _EMPTY
        i = path_idx.get(r.file_id)
        if i is None:
            if fid_paths is not None:
                p = fid_paths[r.file_id]
            else:
                store.ensure_file_backed(r.file_id)
                p = (path_for(r.file_id) if path_for is not None
                     else store.backing_path(r.file_id))
            i = len(paths)
            paths.append(p)
            path_idx[r.file_id] = i
        return i

    body: List[bytes] = []

    def put_ref(r: Optional[BufRef]) -> None:
        if r is None:
            return
        body.append(struct.pack("<IQQB", ref_idx(r), r.offset, r.length,
                                1 if r.reshared else 0))

    def put_column(c: ColumnRefs) -> None:
        _pack_bytes(body, json.dumps(c.type.to_json()).encode(), "<H")
        flags = ((_F_VALIDITY if c.validity else 0) |
                 (_F_OFFSETS if c.offsets else 0) |
                 (_F_DICT if c.dictionary else 0))
        body.append(struct.pack("<QB", c.length, flags))
        put_ref(c.validity)
        put_ref(c.offsets)
        put_ref(c.values)
        if c.dictionary:
            put_column(c.dictionary)

    body.append(struct.pack("<I", len(msg.batches)))
    for b in msg.batches:
        body.append(struct.pack("<QI", b.num_rows, len(b.columns)))
        for c in b.columns:
            put_column(c)

    head: List[bytes] = [MAGIC, struct.pack("<H", VERSION)]
    _pack_bytes(head, msg.schema_bytes)
    head.append(struct.pack("<H", len(paths)))
    for p in paths:
        _pack_bytes(head, p.encode(), "<H")
    return b"".join(head + body)


# --------------------------------------------------------------------------
# decode
# --------------------------------------------------------------------------

class ParsedFrame:
    """A fully parsed SIPC frame that has not yet touched any store.

    Buffer references hold *path indices* (into :attr:`paths`), not file
    ids — :func:`materialize_message` adopts the paths and rewrites the
    indices into store file ids.  Splitting decode this way lets the
    process executor do all byte-level parsing (struct unpacks, JSON
    schema/type decoding) outside its RM lock and keep only the
    store-mutation half (adopt, charge, pin) inside it."""

    __slots__ = ("schema_bytes", "paths", "batches")

    def __init__(self, schema_bytes: bytes, paths: List[str],
                 batches: List[BatchRefs]):
        self.schema_bytes = schema_bytes
        self.paths = paths
        self.batches = batches


def parse_frame(data: bytes) -> ParsedFrame:
    """Parse a frame to a :class:`ParsedFrame` — pure, no store access."""
    cur = _Cursor(data)
    magic = cur.data[:4]
    cur.pos = 4
    if magic != MAGIC:
        raise WireError(f"bad SIPC magic {magic!r}")
    version = cur.take("<H")
    if version != VERSION:
        raise WireError(f"unsupported SIPC version {version}")
    schema_bytes = cur.take_bytes()
    n_paths = cur.take("<H")
    paths = [cur.take_bytes("<H").decode() for _ in range(n_paths)]

    def take_ref() -> BufRef:
        idx, off, length, resh = cur.take("<IQQB")
        if idx == _EMPTY:
            return BufRef(0, 0, 0)
        if idx >= len(paths):
            raise WireError("path index out of range")
        # file_id field transiently holds the path index + 1 (0 is the
        # canonical-empty sentinel); materialize_message rewrites it
        return BufRef(idx + 1, off, length, reshared=bool(resh))

    def take_column() -> ColumnRefs:
        t = ArrowType.from_json(json.loads(cur.take_bytes("<H").decode()))
        length, flags = cur.take("<QB")
        validity = take_ref() if flags & _F_VALIDITY else None
        offsets = take_ref() if flags & _F_OFFSETS else None
        values = take_ref()
        dic = take_column() if flags & _F_DICT else None
        return ColumnRefs(t, length, validity, offsets, values, dic)

    batches: List[BatchRefs] = []
    for _ in range(cur.take("<I")):
        num_rows, n_cols = cur.take("<QI")
        batches.append(
            BatchRefs(num_rows, [take_column() for _ in range(n_cols)]))
    return ParsedFrame(schema_bytes, paths, batches)


def materialize_message(parsed: ParsedFrame, store: BufferStore,
                        owner: Optional[Cgroup] = None,
                        charge: bool = True,
                        adopt_owned: bool = False,
                        label: str = "wire",
                        path_base: Optional[str] = None) -> SipcMessage:
    """The store-mutating half of :func:`decode_message`: adopt the
    parsed frame's paths, rewrite path indices to file ids, account
    new/reshared bytes and pin.  Call under the lock that guards
    ``store``.  Consumes ``parsed`` (its BufRefs are rewritten in
    place); materialize a parsed frame at most once."""
    fids: List[int] = []
    adopted_new: set = set()
    for path in parsed.paths:
        if path_base is not None and not os.path.isabs(path):
            path = os.path.join(path_base, path)
        pre = store.path_index.get(os.path.abspath(path))
        f = store.adopt_file(path, owner=owner, charge=charge,
                             owns_path=adopt_owned, label=label)
        fids.append(f.file_id)
        if pre is None:
            adopted_new.add(f.file_id)

    msg = SipcMessage(parsed.schema_bytes, parsed.batches)
    reshared = 0

    def fix_ref(r: Optional[BufRef]) -> None:
        nonlocal reshared
        if r is None or r.file_id == 0:
            return
        fid = fids[r.file_id - 1]
        r.file_id = fid
        if fid in adopted_new:
            msg.new_bytes += r.length
        else:
            reshared += r.length

    def fix_column(c: ColumnRefs) -> None:
        fix_ref(c.validity)
        fix_ref(c.offsets)
        fix_ref(c.values)
        if c.dictionary is not None:
            fix_column(c.dictionary)

    for b in parsed.batches:
        for c in b.columns:
            fix_column(c)

    msg.reshared_bytes = reshared
    store.stats.bytes_reshared += reshared
    msg.pin(store)
    return msg


def decode_message(data: bytes, store: BufferStore,
                   owner: Optional[Cgroup] = None,
                   charge: bool = True,
                   adopt_owned: bool = False,
                   label: str = "wire",
                   path_base: Optional[str] = None) -> SipcMessage:
    """Reconstruct a SipcMessage, adopting referenced backing files into
    ``store``.  Paths already registered resolve to the existing StoreFile
    (reshared — zero new bytes); fresh paths are mmap'd (adopted).

    ``adopt_owned=True`` transfers unlink responsibility for *newly*
    adopted files to this store (parent RM taking ownership of worker
    output); pre-existing files are untouched.  ``path_base`` resolves
    relative references (manifest frames name objects relative to the
    manifest root so a cache directory can be relocated).

    Equivalent to ``materialize_message(parse_frame(data), ...)`` — the
    split form exists so callers holding a hot lock can parse outside
    it."""
    return materialize_message(parse_frame(data), store, owner=owner,
                               charge=charge, adopt_owned=adopt_owned,
                               label=label, path_base=path_base)


def frame_refs(data: bytes) -> List[Tuple[str, int, int]]:
    """Parse a frame's buffer references — (path, offset, length) per
    non-empty buffer — without touching any store.  The manifest uses
    this to validate that a journaled entry's objects still exist."""
    cur = _Cursor(data)
    if cur.data[:4] != MAGIC:
        raise WireError(f"bad SIPC magic {cur.data[:4]!r}")
    cur.pos = 4
    if cur.take("<H") != VERSION:
        raise WireError("unsupported SIPC version")
    cur.take_bytes()                              # schema
    paths = [cur.take_bytes("<H").decode() for _ in range(cur.take("<H"))]
    out: List[Tuple[str, int, int]] = []

    def take_ref() -> None:
        idx, off, length, _ = cur.take("<IQQB")
        if idx != _EMPTY:
            if idx >= len(paths):
                raise WireError("path index out of range")
            out.append((paths[idx], off, length))

    def take_column() -> None:
        cur.take_bytes("<H")                      # type json
        _, flags = cur.take("<QB")
        if flags & _F_VALIDITY:
            take_ref()
        if flags & _F_OFFSETS:
            take_ref()
        take_ref()
        if flags & _F_DICT:
            take_column()

    for _ in range(cur.take("<I")):
        _, n_cols = cur.take("<QI")
        for _ in range(n_cols):
            take_column()
    return out


# --------------------------------------------------------------------------
# socket framing (length-prefixed frames over a Unix-domain socket)
# --------------------------------------------------------------------------

_LEN = struct.Struct("<Q")
MAX_FRAME = 1 << 30     # sanity bound: control frames are tiny by design


def send_frame(sock: socket.socket, payload: bytes) -> int:
    """Send one length-prefixed frame; returns bytes put on the wire."""
    sock.sendall(_LEN.pack(len(payload)) + payload)
    return _LEN.size + len(payload)


def recv_frame(sock: socket.socket) -> bytes:
    head = _recv_exact(sock, _LEN.size)
    (n,) = _LEN.unpack(head)
    if n > MAX_FRAME:
        raise WireError(f"frame of {n} bytes exceeds MAX_FRAME")
    return _recv_exact(sock, n)


def _recv_exact(sock: socket.socket, n: int) -> bytes:
    chunks = []
    while n:
        b = sock.recv(min(n, 1 << 20))
        if not b:
            raise ConnectionError("peer closed during SIPC frame")
        chunks.append(b)
        n -= len(b)
    return b"".join(chunks)

"""Flight — the cross-process zero-copy data plane.

Three layers on top of the file-backed BufferStore mode:

  wire    — SIPC wire protocol: a SipcMessage serializes to schema bytes
            plus ``(file_path, offset, length)`` reference tuples; data
            never crosses the socket (readers mmap the referenced extents)
  server/
  client  — FlightServer/FlightClient: a named-ticket exchange over a
            Unix-domain socket for long-lived cross-process sharing
  worker  — FlightWorkerPool: spawned worker processes that run DAG node
            ops, receiving inputs and returning outputs as wire
            references (driven by ``ProcessWorkerExecutor``)

See docs/ARCHITECTURE.md §"Flight data plane".
"""

from .client import FlightClient, FlightError
from .server import FlightServer
from .wire import (WireError, decode_message, encode_message, frame_refs,
                   recv_frame, send_frame)
from .worker import (FlightWorkerError, FlightWorkerLost, FlightWorkerPool,
                     worker_main)

__all__ = [
    "FlightClient", "FlightError", "FlightServer",
    "WireError", "decode_message", "encode_message", "frame_refs",
    "recv_frame", "send_frame",
    "FlightWorkerError", "FlightWorkerLost", "FlightWorkerPool",
    "worker_main",
]

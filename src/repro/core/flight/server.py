"""FlightServer — a named-ticket SIPC exchange over a Unix-domain socket.

The server owns a file-backed BufferStore and a registry of named
SipcMessages ("tickets").  Clients in other processes ``put`` and
``get`` messages by name; only schema bytes and file references cross
the socket — getting a 10 GB table costs a few hundred wire bytes, and
the client maps the server's store files directly.

Request/response are length-prefixed pickled dicts (trusted, same-host):

    {"op": "put", "ticket": str, "msg": <wire frame>}  -> {"ok": True}
    {"op": "get", "ticket": str}    -> {"ok": True, "msg": <wire frame>}
    {"op": "list"}                  -> {"ok": True, "tickets": [...]}
    {"op": "drop", "ticket": str}   -> {"ok": True}
    {"op": "stats"}                 -> {"ok": True, ...counters}

This is the long-lived service half of the Flight data plane (the
executor's worker pool is the ephemeral half): engine replicas or
separate pipeline processes exchange Arrow tables through it without
ever serializing data.
"""

from __future__ import annotations

import os
import pickle
import socket
import threading
from typing import Dict, Optional

from ..buffers import BufferStore
from ..sipc import SipcMessage
from .wire import decode_message, encode_message, recv_frame, send_frame


class FlightServer:
    def __init__(self, store: Optional[BufferStore] = None,
                 sock_path: Optional[str] = None):
        self.store = store or BufferStore(backing="file")
        if self.store.backing != "file":
            raise ValueError("FlightServer requires a file-backed store")
        self.sock_path = sock_path or os.path.join(
            self.store.data_dir, "flight.sock")
        self.tickets: Dict[str, SipcMessage] = {}
        self.requests = 0
        self.wire_bytes = 0          # bytes through the socket, both ways
        self._lock = threading.RLock()
        self._listener = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
        self._listener.bind(self.sock_path)
        self._listener.listen(16)
        self._closed = False
        self._thread = threading.Thread(target=self._serve_loop,
                                        name="flight-server", daemon=True)
        self._thread.start()

    # -- local (in-process) API --------------------------------------------
    def put(self, ticket: str, msg: SipcMessage) -> None:
        """Register a message already living in the server's store."""
        with self._lock:
            if not msg._pinned:
                msg.pin(self.store)
            self._store_ticket(ticket, msg)

    def _store_ticket(self, ticket: str, msg: SipcMessage) -> None:
        """Replace a ticket, releasing (and GC'ing) the previous message
        so publish-refresh cycles don't leak pinned store files.
        Caller holds the lock."""
        old = self.tickets.get(ticket)
        self.tickets[ticket] = msg
        if old is not None and old is not msg:
            self._release_and_gc(old)

    def _release_and_gc(self, msg: SipcMessage) -> None:
        msg.release()
        for fid in list(msg.files_referenced()):
            f = self.store.files.get(fid)
            if f is not None and f.refcount == 0 \
                    and not f.decache_pinned:
                self.store.delete_file(fid)

    # -- socket loop --------------------------------------------------------
    def _serve_loop(self) -> None:
        while not self._closed:
            try:
                conn, _ = self._listener.accept()
            except OSError:
                return
            threading.Thread(target=self._client_loop, args=(conn,),
                             daemon=True).start()

    def _client_loop(self, conn: socket.socket) -> None:
        try:
            while True:
                try:
                    raw = recv_frame(conn)
                except (ConnectionError, OSError):
                    return
                self.wire_bytes += len(raw) + 8
                reply = self._dispatch(pickle.loads(raw))
                self.wire_bytes += send_frame(conn, pickle.dumps(reply))
        finally:
            conn.close()

    def _dispatch(self, req: dict) -> dict:
        self.requests += 1
        try:
            op = req.get("op")
            with self._lock:
                if op == "put":
                    msg = decode_message(req["msg"], self.store,
                                         adopt_owned=True,
                                         label=f"ticket:{req['ticket']}")
                    self._store_ticket(req["ticket"], msg)
                    return {"ok": True, "new_bytes": msg.new_bytes}
                if op == "get":
                    msg = self.tickets.get(req["ticket"])
                    if msg is None:
                        return {"ok": False,
                                "error": f"no ticket {req['ticket']!r}"}
                    return {"ok": True,
                            "msg": encode_message(msg, self.store)}
                if op == "drop":
                    msg = self.tickets.pop(req["ticket"], None)
                    if msg is not None:
                        self._release_and_gc(msg)
                    return {"ok": True}
                if op == "list":
                    return {"ok": True, "tickets": sorted(self.tickets)}
                if op == "stats":
                    return {"ok": True, "requests": self.requests,
                            "wire_bytes": self.wire_bytes,
                            "tickets": len(self.tickets),
                            **self.store.stats.snapshot()}
            return {"ok": False, "error": f"unknown op {op!r}"}
        except Exception as e:  # noqa: BLE001 — report to the client
            return {"ok": False, "error": repr(e)}

    def close(self) -> None:
        self._closed = True
        try:
            self._listener.close()
        except OSError:
            pass
        try:
            os.unlink(self.sock_path)
        except OSError:
            pass

"""Flight process workers — DAG node ops in separate OS processes.

Each worker is a spawned Python process with its own file-backed
BufferStore.  The parent executor sends it tiny control frames over a
Unix-domain socket:

    {"op": "exec", "label", "mode", "fn": <pickled callable>,
     "inputs": [<SIPC wire frame>, ...]}
    {"op": "load", "label", "mode", "source", "dict_columns", "columns",
     "row_groups"}
    {"op": "exec_chain", "mode", "steps": [<step>, ...],
     "inputs": [<SIPC wire frame>, ...]}
    {"op": "ping"} / {"op": "shutdown"}

and gets back ``{"ok": True, "msg": <SIPC wire frame>}`` (for
``exec_chain``: ``{"ok": True, "chain": [{"i", "msg"}, ...]}`` — one
entry per *echoed* step).  Inputs and
outputs are *references only* — the worker maps the parent's store files,
runs the op inside a normal Sandbox (same share wrapper, same SIPC
writer, so resharing and dictionary sharing work unchanged), writes its
output into its own store files, and hands the parent back paths.  After
the reply the worker forgets its handles; the files stay on disk and the
parent adopts them with ownership (it unlinks them at GC time).

``exec_chain`` runs a whole linear DAG segment in one request: each
step (a loader or a pickled fn) hands its raw in-memory table to the
next step, so a non-echoed intermediate never crosses the socket, is
never SIPC-encoded and never touches a store file at all — the fused
segment does strictly less store work than the same nodes executed
one-by-one.  Only *echoed* steps (tails, keep_output sinks, DeCache /
manifest feeds) are written and handed back as reference frames; any
input file an echoed frame still reshares stays on disk for the
parent to adopt.

Dispatch is *pipelined*: ``WorkerHandle.submit`` sends a frame and
returns a future; a per-handle receiver thread matches replies to
futures in FIFO order (the worker answers strictly in order).  The
parent can therefore encode and send request N+1 while the worker
computes request N — dispatch cost overlaps with worker compute
instead of serializing on a blocking request/reply turn.

Because the compute happens in another process, a Python-heavy op no
longer serializes on the parent's GIL or on the RM critical section —
this is what finally lets compute-bound pipeline stages scale with
``workers`` (the paper's separate-FaaS-processes deployment, which the
thread executor only approximated for GIL-releasing decompression).
"""

from __future__ import annotations

import collections
import multiprocessing as mp
import os
import pickle
import shutil
import socket
import tempfile
import threading
import time
import traceback
import uuid
from typing import Any, Dict, List, Optional, Tuple

from .. import faultplane
from .wire import (WireError, decode_message, encode_message, frame_refs,
                   recv_frame, send_frame)

_SPAWN = mp.get_context("spawn")      # never fork: jax/threads unsafe

# Worker-side fault points (fired in the worker process, armed through the
# inherited environment — ZERROW_FAULTS crosses the spawn boundary):
faultplane.register_hook("worker_kill", "flight worker: die (kill) or "
                         "raise before handling a request")
faultplane.register_hook("worker_slow", "flight worker: delay before "
                         "handling a request (straggler simulation)")
faultplane.register_hook("worker_stall", "flight worker: stall before "
                         "sending the reply (parent timeout path)")
faultplane.register_hook("worker_chain_kill", "flight worker: die between "
                         "exec_chain steps (mid-chain crash)")


# --------------------------------------------------------------------------
# worker side
# --------------------------------------------------------------------------

def worker_main(sock_path: str, data_dir: str) -> None:
    """Entry point of one worker process (spawn target)."""
    # imports deferred so the module object stays spawn-picklable cheaply
    from ..buffers import BufferStore
    from ..dag import Sandbox
    from ..deanon import KernelZero
    from .. import zarquet

    try:
        # batch scheduling: a worker is pure throughput compute — longer
        # timeslices mean fewer cross-address-space switches (each one
        # costs a TLB flush the thread executor's shared-mm switches
        # never pay), which is where process mode loses to threads on
        # core-starved boxes
        os.sched_setscheduler(0, os.SCHED_BATCH, os.sched_param(0))
    except (AttributeError, OSError, PermissionError):
        pass
    sock = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
    sock.connect(sock_path)
    # identify ourselves: accept order is not spawn order, and the parent
    # must pair each socket with the right Process (retiring a dead
    # worker must never terminate a healthy one)
    send_frame(sock, pickle.dumps({"hello": os.getpid()}))
    store = BufferStore(backing="file", data_dir=data_dir)
    kz = KernelZero(store)
    try:
        while True:
            try:
                req: Dict[str, Any] = pickle.loads(recv_frame(sock))
            except (ConnectionError, EOFError):
                return
            op = req.get("op")
            if op == "shutdown":
                send_frame(sock, pickle.dumps({"ok": True}))
                return
            if op == "ping":
                send_frame(sock, pickle.dumps({"ok": True, "pid": os.getpid()}))
                continue
            real_op = op in ("exec", "load", "exec_chain")
            if real_op:
                # injected mid-request faults (never on warm/control ops,
                # so pool startup stays deterministic under injection)
                faultplane.fire("worker_slow")
                faultplane.fire("worker_kill")
            try:
                reply = _handle(req, store, kz, Sandbox, zarquet)
            except BaseException as e:  # noqa: BLE001 — report, don't die
                reply = {"ok": False, "error": repr(e),
                         "traceback": traceback.format_exc()}
            if real_op:
                faultplane.fire("worker_stall")
            send_frame(sock, pickle.dumps(reply))
            if op == "exec_chain" and reply.get("ok"):
                _forget_chain(store, [e["msg"] for e in reply["chain"]])
            else:
                _forget_all(store)
    finally:
        sock.close()
        store.close()


#: worker-side StoreStats counters echoed back per request so the parent
#: executor can aggregate data-plane behaviour that happens entirely
#: inside workers (e.g. a join reshare-hitting on payload dictionaries)
_ECHO_STATS = ("bytes_copied", "bytes_reshared", "reshare_hits",
               "reshare_misses")


def _run_step(step, store, kz, Sandbox, zarquet, mode, inputs):
    """Run one chain step (a loader or a pickled fn over ``inputs``) in a
    fresh Sandbox; returns its output SipcMessage."""
    label = step.get("label", "node")
    sb = Sandbox(store, kz, label, mode=mode)
    if step["kind"] == "load":
        table = zarquet.read_table(step["source"],
                                   dict_columns=tuple(step["dict_columns"]),
                                   columns=step.get("columns"),
                                   row_groups=step.get("row_groups"),
                                   on_buffer=sb.register_anon,
                                   reader_threads=step.get("reader_threads"))
        return sb.write_output(table, label=label)
    fn = pickle.loads(step["fn"])
    return sb.run(fn, inputs, label=label)


def _handle(req, store, kz, Sandbox, zarquet) -> Dict[str, Any]:
    label = req.get("label", "node")
    mode = req.get("mode", "zero")
    before = store.stats.snapshot()
    if req["op"] == "warm":
        # a fresh worker's first real request otherwise pays one-time
        # costs measured in tens of milliseconds — op-module imports,
        # zarquet codec init, store data-file growth + first-touch page
        # faults, numpy kernel caches.  Run a miniature load -> encode ->
        # filter -> write cycle through a real zarquet file now, while
        # the pool is still starting up, so none of it lands on a user
        # request.
        from .. import ops
        from ..arrow import Table
        import numpy as np
        sb = Sandbox(store, kz, "warm", mode=mode)
        t = Table.from_pydict(
            {"k": np.arange(8192, dtype=np.int64),
             "s": ["warm%d" % (i & 7) for i in range(8192)]})
        path = os.path.join(store.data_dir, "warmup.zq")
        zarquet.write_table(path, t)
        t = zarquet.read_table(path, on_buffer=sb.register_anon)
        t = ops.dict_encode(t, ["s"])
        mask = np.arange(t.num_rows) % 3 != 0
        msg = sb.write_output(ops.filter_rows(t, mask), label="warm")
        encode_message(msg, store)
        msg.release()
        for fid in list(store.files):     # nothing to adopt: unlink now
            store.delete_file(fid)
        try:
            os.unlink(path)
        except OSError:
            pass
        return {"ok": True}
    if req["op"] == "exec_chain":
        from ..sipc import SipcReader
        inputs = [decode_message(b, store, charge=False, label=label)
                  for b in req.get("inputs", ())]
        # ONE sandbox for the whole segment: each step hands its raw
        # in-memory table straight to its successor, so a non-echoed
        # intermediate is never SIPC-written, never lands in a store
        # file, never faults a page — the fusion is what makes a shipped
        # chain cheaper than the same nodes run one-by-one (which must
        # materialize every output), not just the saved round trips.
        # The chain-wide input_map lets an echoed tail still reshare
        # buffers it passes through from the chain's real inputs.
        sb = Sandbox(store, kz, label, mode=mode)
        reader = SipcReader(store, mode, record_map=sb.input_map)
        # the values array: chain inputs first, then one slot per step;
        # each exec step picks its inputs by index (``args``), which is
        # how fan-in segments (two loads feeding a join) wire up
        vals = [reader.read_table(m) for m in inputs]
        chain, msgs = [], []
        for i, step in enumerate(req["steps"]):
            faultplane.fire("worker_chain_kill")
            if step["kind"] == "load":
                table = zarquet.read_table(
                    step["source"],
                    dict_columns=tuple(step["dict_columns"]),
                    columns=step.get("columns"),
                    row_groups=step.get("row_groups"),
                    on_buffer=sb.register_anon,
                    reader_threads=step.get("reader_threads"))
            else:
                table = pickle.loads(step["fn"])(
                    [vals[a] for a in step["args"]])
            if step["echo"]:
                # write_output releases the sandbox's anon registry, but
                # only the *accounting* — the arrays stay alive through
                # the table references later steps hold
                msg = sb.write_output(table, label=step.get("label", label))
                msgs.append(msg)
                chain.append({"i": i, "msg": encode_message(msg, store)})
            vals.append(table)
        for m in inputs:
            m.release()
        for m in msgs:
            m.release()
        after = store.stats.snapshot()
        return {"ok": True, "chain": chain,
                "stats": {k: after[k] - before[k] for k in _ECHO_STATS}}
    if req["op"] == "exec":
        inputs = [decode_message(b, store, charge=False, label=label)
                  for b in req["inputs"]]
        msg = _run_step({"kind": "exec", "label": label, "fn": req["fn"]},
                        store, kz, Sandbox, zarquet, mode, inputs)
        for m in inputs:
            m.release()
    elif req["op"] == "load":
        msg = _run_step({"kind": "load", "label": label,
                         "source": req["source"],
                         "dict_columns": req["dict_columns"],
                         "columns": req.get("columns"),
                         "row_groups": req.get("row_groups"),
                         "reader_threads": req.get("reader_threads")},
                        store, kz, Sandbox, zarquet, mode, [])
    else:
        raise ValueError(f"unknown worker op {req['op']!r}")
    out = encode_message(msg, store)
    msg.release()
    after = store.stats.snapshot()
    return {"ok": True, "msg": out, "new_bytes": msg.new_bytes,
            "reshared_bytes": msg.reshared_bytes,
            "stats": {k: after[k] - before[k] for k in _ECHO_STATS}}


def _forget_all(store) -> None:
    """Drop every file handle without unlinking: the bytes now belong to
    the parent (which adopted the paths with ownership)."""
    for fid in list(store.files):
        store.files[fid].owns_path = False
        store.delete_file(fid)


def _forget_chain(store, echoed_frames) -> None:
    """Post-``exec_chain`` cleanup.  Files referenced by an echoed frame
    belong to the parent now (it adopts them with ownership) — drop the
    handle without unlinking.  Everything else the worker owns is a
    chain intermediate nobody will ever map again: unlink it here, in
    the worker, so shipped chains cost the parent zero bytes and zero
    GC work for their intermediates."""
    keep = set()
    for fr in echoed_frames:
        for path, _off, _len in frame_refs(fr):
            keep.add(os.path.abspath(path))
    for fid in list(store.files):
        f = store.files[fid]
        if f.backing_path and os.path.abspath(f.backing_path) in keep:
            f.owns_path = False       # parent takes unlink responsibility
        store.delete_file(fid)        # unlinks only if owns_path held


# --------------------------------------------------------------------------
# parent side
# --------------------------------------------------------------------------

class FlightWorkerError(RuntimeError):
    """A worker process failed or died mid-request."""


class FlightWorkerLost(FlightWorkerError):
    """Transport failure: the worker died (or its socket desynced)
    mid-request.  Unlike an in-op exception, the request itself may be
    perfectly fine — the executor retries it on a surviving worker."""


class _Future:
    """Reply slot for one in-flight request (threading.Event based)."""

    __slots__ = ("_ev", "_result", "_exc", "t0")

    def __init__(self):
        self._ev = threading.Event()
        self._result = None
        self._exc: Optional[BaseException] = None
        self.t0 = 0.0      # submit instant, for service-time health EWMA

    def set_result(self, r) -> None:
        self._result = r
        self._ev.set()

    def set_exception(self, e: BaseException) -> None:
        if not self._ev.is_set():      # first failure wins
            self._exc = e
            self._ev.set()

    def result(self, timeout: Optional[float] = None):
        if not self._ev.wait(timeout):
            raise TimeoutError("flight reply timed out")
        if self._exc is not None:
            raise self._exc
        return self._result


class WorkerHandle:
    """One connected worker process, with pipelined request dispatch.

    ``submit`` sends a frame and returns a :class:`_Future` immediately;
    the per-handle receiver thread matches replies to futures in FIFO
    order (the worker processes requests strictly in order, one reply
    each).  ``request`` is the blocking submit+complete composition.
    Any transport failure — dead worker, truncated frame, reply with no
    matching future, completion timeout — breaks the handle: every
    pending future fails with :class:`FlightWorkerLost` and the socket
    is closed, because a desynced stream can never be re-aligned."""

    def __init__(self, proc, sock: socket.socket):
        self.proc = proc
        self.sock = sock
        self.bytes_sent = 0
        self.bytes_received = 0
        self.broken = False      # socket desynced / worker dead: retire
        self._send_lock = threading.Lock()   # frame sends never interleave
        self._plock = threading.Lock()       # guards _pending
        self._pending: "collections.deque[_Future]" = collections.deque()
        self._on_reply = None    # pool wake-up callback
        self._health = None      # pool's StragglerDetector (keyed by pid)
        self._recv_thread: Optional[threading.Thread] = None

    def start(self) -> None:
        """Start the receiver thread (after the pool wired callbacks)."""
        self._recv_thread = threading.Thread(
            target=self._recv_loop, daemon=True,
            name=f"flight-recv-{getattr(self.proc, 'pid', '?')}")
        self._recv_thread.start()

    @property
    def pending(self) -> int:
        with self._plock:
            return len(self._pending)

    # -- submit / complete --------------------------------------------------
    def submit(self, obj: Dict[str, Any]) -> _Future:
        """Send one request frame; returns the future its reply fills."""
        payload = pickle.dumps(obj)
        fut = _Future()
        fut.t0 = time.monotonic()
        with self._send_lock:
            if self.broken:
                raise FlightWorkerLost(
                    f"worker pid={getattr(self.proc, 'pid', '?')} is "
                    f"broken (cannot submit {obj.get('op')!r})")
            with self._plock:
                self._pending.append(fut)
            try:
                self.bytes_sent += send_frame(self.sock, payload)
            except (ConnectionError, socket.timeout, OSError) as e:
                err = FlightWorkerLost(
                    f"worker pid={getattr(self.proc, 'pid', '?')} failed "
                    f"during {obj.get('op')!r}: {e!r}")
                self._break(err)
                raise err from e
        return fut

    def complete(self, fut: _Future, obj: Dict[str, Any],
                 timeout: float) -> Dict[str, Any]:
        """Wait for ``fut``; a timeout breaks the handle (its late reply
        would otherwise be matched to a request the caller already gave
        up on and retried elsewhere)."""
        try:
            reply = fut.result(timeout)
        except TimeoutError:
            err = FlightWorkerLost(
                f"worker pid={getattr(self.proc, 'pid', '?')} timed out "
                f"after {timeout}s during {obj.get('op')!r}")
            self._break(err)
            raise err from None
        if not reply.get("ok"):
            raise FlightWorkerError(
                f"worker op {obj.get('op')!r} raised {reply.get('error')}\n"
                f"{reply.get('traceback', '')}")
        return reply

    def request(self, obj: Dict[str, Any], timeout: float) -> Dict[str, Any]:
        return self.complete(self.submit(obj), obj, timeout)

    # -- receiver -----------------------------------------------------------
    def _recv_loop(self) -> None:
        while True:
            try:
                raw = recv_frame(self.sock)
            except (ConnectionError, socket.timeout, OSError, WireError) as e:
                self._break(FlightWorkerLost(
                    f"worker pid={getattr(self.proc, 'pid', '?')} "
                    f"connection lost: {e!r}"))
                return
            with self._plock:
                self.bytes_received += len(raw) + 8
                fut = self._pending.popleft() if self._pending else None
            if fut is None:
                self._break(FlightWorkerLost(
                    f"worker pid={getattr(self.proc, 'pid', '?')} sent an "
                    "unsolicited frame"))
                return
            health = self._health
            if health is not None and fut.t0:
                # service time (queue + compute) into the shared
                # straggler detector, keyed by worker pid
                health.update(getattr(self.proc, "pid", 0),
                              time.monotonic() - fut.t0)
            try:
                fut.set_result(pickle.loads(raw))
            except Exception as e:   # noqa: BLE001 — undecodable reply
                fut.set_exception(FlightWorkerLost(
                    f"worker pid={getattr(self.proc, 'pid', '?')} sent an "
                    f"undecodable reply: {e!r}"))
                self._break(FlightWorkerLost("reply stream desynced"))
                return
            cb = self._on_reply
            if cb is not None:
                cb()

    def _break(self, exc: FlightWorkerLost) -> None:
        """Mark broken, fail every pending future, close the socket."""
        self.broken = True
        with self._plock:
            pending, self._pending = list(self._pending), collections.deque()
        for f in pending:
            f.set_exception(exc)
        try:
            self.sock.close()
        except OSError:
            pass
        cb = self._on_reply
        if cb is not None:
            cb()

    def retire(self) -> None:
        self._break(FlightWorkerLost(
            f"worker pid={getattr(self.proc, 'pid', '?')} retired"))
        if self.proc.is_alive():
            self.proc.terminate()


class FlightWorkerPool:
    """N spawned worker processes behind a Unix-domain socket listener.

    Routing is event-driven: a condition variable wakes waiters the
    moment any reply lands or a handle breaks (no polling interval).
    Requests pack onto cache-hot workers first (see ``_pick_locked``),
    bounded by ``pipeline_depth`` — deep enough that the parent encodes
    request N+1 while the worker computes N, shallow enough that a
    worker death re-runs at most ``pipeline_depth`` requests."""

    #: max in-flight requests per worker (1 = strict request/reply).
    #: Scaled up with worker-to-core oversubscription at construction:
    #: when cores are scarce, deeper per-worker queues let the pack
    #: router keep requests on FEWER address spaces (every cross-process
    #: switch costs a TLB flush that thread-mode's shared-mm switches
    #: never pay); with ample cores the queues stay shallow so work
    #: spreads and actually overlaps.
    pipeline_depth = 2

    def __init__(self, workers: int, sipc_mode: str = "zero",
                 data_root: Optional[str] = None,
                 connect_timeout: float = 60.0,
                 request_timeout: float = 600.0):
        self.workers = workers
        self.sipc_mode = sipc_mode
        self.request_timeout = request_timeout
        cores = len(os.sched_getaffinity(0)) if hasattr(
            os, "sched_getaffinity") else (os.cpu_count() or 1)
        self.pipeline_depth = type(self).pipeline_depth * max(
            1, workers // max(cores, 1))
        # worker stores hold chain intermediates and freshly-produced
        # outputs; tmpfs makes their first-touch writes RAM-speed (no
        # block allocation / writeback) while staying path-addressable
        # for the parent's zero-copy mmap adoption — the shared-memory
        # object store arrangement the paper's data plane assumes
        self.data_root = data_root or tempfile.mkdtemp(
            prefix="zerrow-flight-",
            dir="/dev/shm" if os.access("/dev/shm", os.W_OK) else None)
        os.makedirs(self.data_root, exist_ok=True)
        self._sock_path = os.path.join(
            self.data_root, f"uds-{uuid.uuid4().hex[:8]}")
        self._handles: List[WorkerHandle] = []
        self._cv = threading.Condition()
        self._closed = False
        self._connect_timeout = connect_timeout
        # crash-recovery respawn accounting (``ensure_workers``): bounded
        # so a fault that kills every replacement cannot fork-bomb
        self._spawn_lock = threading.Lock()
        self._next_worker = 0
        self.respawns = 0
        self.max_respawns = workers * 8
        # shared per-worker service-time EWMA + straggler flagging — the
        # same detector runtime/fault.py's FleetMonitor uses for
        # training-fleet heartbeats (see core/faultplane.py)
        self.health = faultplane.StragglerDetector()

        # the listener stays open for the pool's lifetime: crash recovery
        # respawns workers through it long after startup
        self._listener = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
        self._listener.bind(self._sock_path)
        self._listener.listen(workers)
        self._listener.settimeout(connect_timeout)
        new = self._spawn_batch(workers, fatal=True)
        self._warm(new)

    def _spawn_batch(self, n: int, fatal: bool = False
                     ) -> List[WorkerHandle]:
        """Spawn ``n`` workers and pair their hello-pid connections.
        ``fatal`` (initial startup): an incomplete batch terminates the
        spawned procs and raises; otherwise (crash-recovery respawn) the
        stragglers are terminated and the paired survivors returned."""
        procs = []
        for _ in range(n):
            i = self._next_worker
            self._next_worker += 1
            p = _SPAWN.Process(
                target=worker_main,
                args=(self._sock_path,
                      os.path.join(self.data_root, f"w{i}")),
                name=f"zerrow-flight-{i}", daemon=True)
            p.start()
            procs.append(p)
        by_pid = {p.pid: p for p in procs}
        new: List[WorkerHandle] = []
        try:
            while by_pid:
                conn, _ = self._listener.accept()
                conn.settimeout(self._connect_timeout)
                hello = pickle.loads(recv_frame(conn))
                conn.settimeout(None)
                p = by_pid.pop(hello.get("hello"), None)
                if p is None:
                    conn.close()      # not from this batch: drop it
                    continue
                h = WorkerHandle(p, conn)
                h._on_reply = self._wake
                h._health = self.health
                new.append(h)
        except (socket.timeout, ConnectionError, WireError, OSError):
            for p in by_pid.values():
                p.terminate()
            if fatal:
                for h in new:
                    h.retire()
                raise FlightWorkerError(
                    f"worker pool: only {len(new)}/{n} workers "
                    "connected before timeout")
        self._handles.extend(new)
        for h in new:
            h.start()
        return new

    def _warm(self, handles: List[WorkerHandle]) -> None:
        """Cold-start amortization: eat each worker's one-time first-
        request costs off the request path, across all workers at once
        (best-effort: a worker that dies warming up is simply retired,
        like any other failure)."""
        warm = []
        for h in handles:
            try:
                warm.append((h, h.submit({"op": "warm",
                                          "mode": self.sipc_mode})))
            except FlightWorkerLost:
                pass
        for h, fut in warm:
            try:
                h.complete(fut, {"op": "warm"},
                           timeout=self._connect_timeout)
            except FlightWorkerError:
                pass

    def ensure_workers(self) -> int:
        """Crash recovery: re-grow the pool back to its configured size.
        Called by the executor's retry path when the pool has thinned.
        Bounded by ``max_respawns`` total replacement workers — a
        poisoned op that kills every replacement must exhaust its node
        retries, not the host's process table.  Returns the live count."""
        with self._spawn_lock:
            if self._closed:
                return self.live_workers
            missing = self.workers - self.live_workers
            if missing <= 0:
                return self.live_workers
            allowed = min(missing, self.max_respawns - self.respawns)
            if allowed <= 0:
                return self.live_workers
            new = self._spawn_batch(allowed)
            self.respawns += len(new)
            self._warm(new)
        self._wake()                  # submit waiters can route again
        return self.live_workers

    def stragglers(self) -> Tuple[List[int], float]:
        """(straggler pids, median service EWMA) over live workers —
        same flagging rule as runtime.fault.FleetMonitor."""
        alive = {getattr(h.proc, "pid", 0)
                 for h in self._handles if not h.broken}
        return self.health.flag(alive)

    def _wake(self) -> None:
        with self._cv:
            self._cv.notify_all()

    # -- request routing ---------------------------------------------------
    def _pick_locked(self) -> Optional[WorkerHandle]:
        """LIFO-style packing: among live handles under ``pipeline_depth``,
        prefer the one with the MOST in-flight requests.  A busy worker is
        a cache-hot worker (the same locality argument behind LIFO slots
        in work-stealing runtimes); idle workers are engaged only when the
        hot ones saturate their pipeline, which also minimizes process
        context-switch pressure when cores are scarce."""
        best, best_n = None, -1
        for h in self._handles:
            if h.broken:
                continue
            n = h.pending
            if n < self.pipeline_depth and n > best_n:
                best, best_n = h, n
        return best

    def submit(self, obj: Dict[str, Any]) -> Tuple[WorkerHandle, _Future]:
        """Send ``obj`` to the least-loaded live worker; returns the
        ``(handle, future)`` pair to complete later.  Blocks (event-
        driven, no polling) while every live handle is at
        ``pipeline_depth``; raises when the whole pool is dead."""
        obj.setdefault("mode", self.sipc_mode)
        while True:
            with self._cv:
                while True:
                    h = self._pick_locked()
                    if h is not None:
                        break
                    if all(x.broken for x in self._handles):
                        raise FlightWorkerError(
                            "no live workers in the pool")
                    # the 1s cap is a safety net against a lost wakeup,
                    # not a polling interval — replies notify _cv
                    self._cv.wait(timeout=1.0)
            try:
                return h, h.submit(obj)
            except FlightWorkerLost:
                h.retire()            # always retire a broken handle
                self._wake()          # re-route to a survivor

    def request(self, obj: Dict[str, Any],
                timeout: Optional[float] = None) -> Dict[str, Any]:
        """Run one request on the least-loaded live worker.

        A handle that fails (dead worker, timeout, desynced stream) is
        always retired — its socket can no longer be trusted to be
        frame-aligned.  The error propagates to the executor's normal
        error path; when every worker has died the pool raises
        immediately."""
        timeout = self.request_timeout if timeout is None else timeout
        h, fut = self.submit(obj)
        try:
            return h.complete(fut, obj, timeout)
        except FlightWorkerLost:
            h.retire()
            raise
        finally:
            self._wake()              # a pipeline slot opened up

    # -- stats / lifecycle --------------------------------------------------
    @property
    def live_workers(self) -> int:
        return sum(1 for h in self._handles if not h.broken)

    @property
    def socket_bytes(self) -> int:
        """Total bytes that crossed the control sockets, both directions —
        the quantity the zero-copy wire claim is asserted on."""
        return sum(h.bytes_sent + h.bytes_received for h in self._handles)

    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        try:
            self._listener.close()
        except OSError:
            pass
        for h in self._handles:
            if not h.broken:      # retired handles have dead/closed sockets
                try:
                    h.request({"op": "shutdown"}, timeout=5.0)
                except (FlightWorkerError, OSError):
                    pass
            try:
                h.sock.close()
            except OSError:
                pass
        for h in self._handles:
            h.proc.join(timeout=5.0)
            if h.proc.is_alive():
                h.proc.terminate()
            if h._recv_thread is not None:
                h._recv_thread.join(timeout=1.0)
        shutil.rmtree(self.data_root, ignore_errors=True)

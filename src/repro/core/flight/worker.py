"""Flight process workers — DAG node ops in separate OS processes.

Each worker is a spawned Python process with its own file-backed
BufferStore.  The parent executor sends it tiny control frames over a
Unix-domain socket:

    {"op": "exec", "label", "mode", "fn": <pickled callable>,
     "inputs": [<SIPC wire frame>, ...]}
    {"op": "load", "label", "mode", "source", "dict_columns"}
    {"op": "ping"} / {"op": "shutdown"}

and gets back ``{"ok": True, "msg": <SIPC wire frame>}``.  Inputs and
outputs are *references only* — the worker maps the parent's store files,
runs the op inside a normal Sandbox (same share wrapper, same SIPC
writer, so resharing and dictionary sharing work unchanged), writes its
output into its own store files, and hands the parent back paths.  After
the reply the worker forgets its handles; the files stay on disk and the
parent adopts them with ownership (it unlinks them at GC time).

Because the compute happens in another process, a Python-heavy op no
longer serializes on the parent's GIL or on the RM critical section —
this is what finally lets compute-bound pipeline stages scale with
``workers`` (the paper's separate-FaaS-processes deployment, which the
thread executor only approximated for GIL-releasing decompression).
"""

from __future__ import annotations

import multiprocessing as mp
import os
import pickle
import queue
import shutil
import socket
import tempfile
import threading
import traceback
import uuid
from typing import Any, Dict, List, Optional

from .wire import decode_message, encode_message, recv_frame, send_frame

_SPAWN = mp.get_context("spawn")      # never fork: jax/threads unsafe


# --------------------------------------------------------------------------
# worker side
# --------------------------------------------------------------------------

def worker_main(sock_path: str, data_dir: str) -> None:
    """Entry point of one worker process (spawn target)."""
    # imports deferred so the module object stays spawn-picklable cheaply
    from ..buffers import BufferStore
    from ..dag import Sandbox
    from ..deanon import KernelZero
    from .. import zarquet

    sock = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
    sock.connect(sock_path)
    # identify ourselves: accept order is not spawn order, and the parent
    # must pair each socket with the right Process (retiring a dead
    # worker must never terminate a healthy one)
    send_frame(sock, pickle.dumps({"hello": os.getpid()}))
    store = BufferStore(backing="file", data_dir=data_dir)
    kz = KernelZero(store)
    try:
        while True:
            try:
                req: Dict[str, Any] = pickle.loads(recv_frame(sock))
            except (ConnectionError, EOFError):
                return
            op = req.get("op")
            if op == "shutdown":
                send_frame(sock, pickle.dumps({"ok": True}))
                return
            if op == "ping":
                send_frame(sock, pickle.dumps({"ok": True, "pid": os.getpid()}))
                continue
            try:
                reply = _handle(req, store, kz, Sandbox, zarquet)
            except BaseException as e:  # noqa: BLE001 — report, don't die
                reply = {"ok": False, "error": repr(e),
                         "traceback": traceback.format_exc()}
            send_frame(sock, pickle.dumps(reply))
            _forget_all(store)
    finally:
        sock.close()
        store.close()


#: worker-side StoreStats counters echoed back per request so the parent
#: executor can aggregate data-plane behaviour that happens entirely
#: inside workers (e.g. a join reshare-hitting on payload dictionaries)
_ECHO_STATS = ("bytes_copied", "bytes_reshared", "reshare_hits",
               "reshare_misses")


def _handle(req, store, kz, Sandbox, zarquet) -> Dict[str, Any]:
    label = req.get("label", "node")
    before = store.stats.snapshot()
    sb = Sandbox(store, kz, label, mode=req.get("mode", "zero"))
    if req["op"] == "exec":
        fn = pickle.loads(req["fn"])
        inputs = [decode_message(b, store, charge=False, label=label)
                  for b in req["inputs"]]
        msg = sb.run(fn, inputs, label=label)
        for m in inputs:
            m.release()
    elif req["op"] == "load":
        table = zarquet.read_table(req["source"],
                                   dict_columns=tuple(req["dict_columns"]),
                                   on_buffer=sb.register_anon,
                                   reader_threads=req.get("reader_threads"))
        msg = sb.write_output(table, label=label)
    else:
        raise ValueError(f"unknown worker op {req['op']!r}")
    out = encode_message(msg, store)
    msg.release()
    after = store.stats.snapshot()
    return {"ok": True, "msg": out, "new_bytes": msg.new_bytes,
            "reshared_bytes": msg.reshared_bytes,
            "stats": {k: after[k] - before[k] for k in _ECHO_STATS}}


def _forget_all(store) -> None:
    """Drop every file handle without unlinking: the bytes now belong to
    the parent (which adopted the paths with ownership)."""
    for fid in list(store.files):
        store.files[fid].owns_path = False
        store.delete_file(fid)


# --------------------------------------------------------------------------
# parent side
# --------------------------------------------------------------------------

class FlightWorkerError(RuntimeError):
    """A worker process failed or died mid-request."""


class FlightWorkerLost(FlightWorkerError):
    """Transport failure: the worker died (or its socket desynced)
    mid-request.  Unlike an in-op exception, the request itself may be
    perfectly fine — the executor retries it on a surviving worker."""


class WorkerHandle:
    """One connected worker process; requests are serialized per handle."""

    def __init__(self, proc, sock: socket.socket):
        self.proc = proc
        self.sock = sock
        self.lock = threading.Lock()
        self.bytes_sent = 0
        self.bytes_received = 0
        self.broken = False      # socket desynced / worker dead: retire

    def request(self, obj: Dict[str, Any], timeout: float) -> Dict[str, Any]:
        with self.lock:
            self.sock.settimeout(timeout)
            try:
                self.bytes_sent += send_frame(self.sock, pickle.dumps(obj))
                raw = recv_frame(self.sock)
            except (ConnectionError, socket.timeout, OSError) as e:
                # a timed-out socket may still deliver THIS op's reply
                # later; never reuse it or the next op would read a stale
                # frame as its own result
                self.broken = True
                raise FlightWorkerLost(
                    f"worker pid={getattr(self.proc, 'pid', '?')} failed "
                    f"during {obj.get('op')!r}: {e!r}") from e
            self.bytes_received += len(raw) + 8
        reply = pickle.loads(raw)
        if not reply.get("ok"):
            raise FlightWorkerError(
                f"worker op {obj.get('op')!r} raised {reply.get('error')}\n"
                f"{reply.get('traceback', '')}")
        return reply

    def retire(self) -> None:
        self.broken = True
        try:
            self.sock.close()
        except OSError:
            pass
        if self.proc.is_alive():
            self.proc.terminate()


class FlightWorkerPool:
    """N spawned worker processes behind a Unix-domain socket listener."""

    def __init__(self, workers: int, sipc_mode: str = "zero",
                 data_root: Optional[str] = None,
                 connect_timeout: float = 60.0):
        self.workers = workers
        self.sipc_mode = sipc_mode
        self.data_root = data_root or tempfile.mkdtemp(
            prefix="zerrow-flight-")
        os.makedirs(self.data_root, exist_ok=True)
        self._sock_path = os.path.join(
            self.data_root, f"uds-{uuid.uuid4().hex[:8]}")
        self._handles: List[WorkerHandle] = []
        self._idle: "queue.Queue[WorkerHandle]" = queue.Queue()
        self._closed = False

        listener = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
        listener.bind(self._sock_path)
        listener.listen(workers)
        listener.settimeout(connect_timeout)
        procs = []
        try:
            for i in range(workers):
                p = _SPAWN.Process(
                    target=worker_main,
                    args=(self._sock_path,
                          os.path.join(self.data_root, f"w{i}")),
                    name=f"zerrow-flight-{i}", daemon=True)
                p.start()
                procs.append(p)
            by_pid = {p.pid: p for p in procs}
            for _ in procs:
                conn, _ = listener.accept()
                conn.settimeout(connect_timeout)
                hello = pickle.loads(recv_frame(conn))
                conn.settimeout(None)
                h = WorkerHandle(by_pid.pop(hello["hello"]), conn)
                self._handles.append(h)
                self._idle.put(h)
        except socket.timeout:
            for p in procs:
                p.terminate()
            raise FlightWorkerError(
                f"worker pool: only {len(self._handles)}/{workers} workers "
                "connected before timeout")
        finally:
            listener.close()

    # -- request routing ---------------------------------------------------
    def request(self, obj: Dict[str, Any],
                timeout: float = 600.0) -> Dict[str, Any]:
        """Run one request on any idle worker (blocks for a free one).

        A handle that fails (dead worker, timeout) is retired, never
        requeued — its socket can no longer be trusted to be frame-
        aligned.  The error propagates to the executor's normal error
        path; when every worker has died the pool raises immediately."""
        obj.setdefault("mode", self.sipc_mode)
        while True:
            try:
                h = self._idle.get(timeout=1.0)
            except queue.Empty:
                if all(x.broken for x in self._handles):
                    raise FlightWorkerError("no live workers in the pool")
                continue
            if h.broken:
                continue
            try:
                reply = h.request(obj, timeout)
            except FlightWorkerError:
                if h.broken:
                    h.retire()       # transport failure: drop the worker
                else:
                    self._idle.put(h)  # op raised in-worker: worker is fine
                raise
            self._idle.put(h)
            return reply

    # -- stats / lifecycle --------------------------------------------------
    @property
    def live_workers(self) -> int:
        return sum(1 for h in self._handles if not h.broken)

    @property
    def socket_bytes(self) -> int:
        """Total bytes that crossed the control sockets, both directions —
        the quantity the zero-copy wire claim is asserted on."""
        return sum(h.bytes_sent + h.bytes_received for h in self._handles)

    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        for h in self._handles:
            if not h.broken:      # retired handles have dead/closed sockets
                try:
                    h.request({"op": "shutdown"}, timeout=5.0)
                except (FlightWorkerError, OSError):
                    pass
            try:
                h.sock.close()
            except OSError:
                pass
        for h in self._handles:
            h.proc.join(timeout=5.0)
            if h.proc.is_alive():
                h.proc.terminate()
        shutil.rmtree(self.data_root, ignore_errors=True)

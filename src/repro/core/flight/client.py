"""FlightClient — the consumer half of the named-ticket SIPC exchange.

Connects to a FlightServer's Unix-domain socket.  ``get`` returns a
SipcMessage decoded into the *caller's* store (mapping the server's
files — zero data bytes moved); ``put`` publishes a local message by
reference.  Counters record exactly how many bytes crossed the socket
so zero-copy claims are checkable.
"""

from __future__ import annotations

import pickle
import socket
from typing import List, Optional

from .. import faultplane
from ..buffers import BufferStore
from ..sipc import SipcMessage
from .wire import decode_message, encode_message, recv_frame, send_frame

faultplane.register_hook("client_call", "flight client: fail/stall a "
                         "ticket-exchange call before it hits the wire")


class FlightError(RuntimeError):
    pass


class FlightClient:
    def __init__(self, sock_path: str, store: Optional[BufferStore] = None,
                 timeout: float = 60.0):
        self.store = store or BufferStore(backing="file")
        if self.store.backing != "file":
            raise ValueError("FlightClient requires a file-backed store")
        self.timeout = timeout
        self.sock = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
        self.sock.settimeout(timeout)
        self.sock.connect(sock_path)
        self.wire_bytes = 0

    def _call(self, req: dict) -> dict:
        faultplane.fire("client_call")
        try:
            self.wire_bytes += send_frame(self.sock, pickle.dumps(req))
            raw = recv_frame(self.sock)
        except socket.timeout:
            # a half-finished exchange leaves the stream unframed; the
            # typed error lets callers retire this client cleanly
            raise FlightError(
                f"flight client timed out after {self.timeout}s during "
                f"{req.get('op')!r}") from None
        self.wire_bytes += len(raw) + 8
        reply = pickle.loads(raw)
        if not reply.get("ok"):
            raise FlightError(reply.get("error", "flight request failed"))
        return reply

    # -- API ----------------------------------------------------------------
    def put(self, ticket: str, msg: SipcMessage) -> None:
        self._call({"op": "put", "ticket": ticket,
                    "msg": encode_message(msg, self.store)})

    def get(self, ticket: str) -> SipcMessage:
        reply = self._call({"op": "get", "ticket": ticket})
        return decode_message(reply["msg"], self.store,
                              label=f"ticket:{ticket}")

    def drop(self, ticket: str) -> None:
        self._call({"op": "drop", "ticket": ticket})

    def list(self) -> List[str]:
        return self._call({"op": "list"})["tickets"]

    def stats(self) -> dict:
        return self._call({"op": "stats"})

    def close(self) -> None:
        try:
            self.sock.close()
        except OSError:
            pass

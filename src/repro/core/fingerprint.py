"""Deterministic node fingerprints for cross-run differential caching.

A node's fingerprint is a sha256 over everything that determines its
output value:

    hash(op code/version, op params, input fingerprints,
         source file content hash)

computed in topo order so a change anywhere in the upstream cone — the op
itself, a captured parameter, a dependency, or the bytes of a zarquet
source file — changes the fingerprints of exactly the downstream nodes
that would produce different data.  A re-run of a DAG over mostly-
unchanged inputs therefore hits the manifest for every node outside the
diff and recomputes only the changed partitions (the Bauplan pre-print's
re-run-DAGs-over-mostly-unchanged-inputs workload).

Op identity is the *code object* — bytecode, consts, names, captured
closure/partial values, and directly-referenced module globals (helper
functions by their code, constants by value) — not the function's name
or address, so an identical lambda re-created next run fingerprints
identically, while editing the op body (or a value it closes over, or a
helper it calls by name) invalidates it.  Attributes reached *through a
module object* (``ops.dict_encode``) are not chased — bump
``FP_VERSION`` after editing shared library code, or have the op
declare its module-attr dependencies in ``__fp_includes__`` (a tuple of
callables folded into the op's identity — how ``ops.join`` binds itself
to the relational vkernels).  Ops that expose
neither code nor stable state (builtins, callables with ``__dict__`` we
cannot canonicalize) fingerprint as None and are simply never cached —
correctness over coverage.
"""

from __future__ import annotations

import functools
import hashlib
import json
import os
import re
import types
from typing import Dict, List, Optional

import numpy as np

#: bump to invalidate every cached output on a format/semantics change
FP_VERSION = 1

#: file content hashes, keyed (path, size, mtime_ns) — cleared by
#: reset_caches() (benchmarks simulate fresh processes with it)
_FILE_HASH_CACHE: Dict[tuple, str] = {}

#: per-row-group content hashes of stream zarquet footers, same key
#: discipline as _FILE_HASH_CACHE (an append changes size/mtime, so the
#: new footer is re-read exactly once)
_GROUP_HASH_CACHE: Dict[tuple, List[Optional[str]]] = {}

_ADDR_REPR = re.compile(r" at 0x[0-9a-fA-F]+")


class _UnstableValue(Exception):
    """A value whose only representation embeds a memory address: it can
    never fingerprint deterministically across processes.  Raised so the
    op becomes uncacheable (None) instead of silently never-hitting —
    which would also append a fresh dead record to the journal per run."""


def _json_default(o) -> str:
    # numpy arrays get a full content hash: their repr truncates large
    # arrays ('...'), which would collide different parameter values
    if isinstance(o, np.ndarray):
        a = np.ascontiguousarray(o)
        return (f"ndarray:{a.dtype}:{a.shape}:"
                f"{hashlib.sha256(a.tobytes()).hexdigest()}")
    if isinstance(o, (np.generic,)):
        return f"npscalar:{o.dtype}:{o.item()!r}"
    r = repr(o)
    if _ADDR_REPR.search(r):
        raise _UnstableValue(r)
    return r


def _stable(obj) -> str:
    """Canonical text for parameter-ish values (sorted keys; ndarrays by
    content hash; repr escape hatch for anything else non-JSON — but an
    address-bearing repr raises, marking the op uncacheable)."""
    try:
        return json.dumps(obj, sort_keys=True, default=_json_default)
    except (TypeError, ValueError):
        return _json_default(obj)


def reset_caches() -> None:
    """Drop the in-memory hash caches (fresh-process simulation)."""
    _FILE_HASH_CACHE.clear()
    _GROUP_HASH_CACHE.clear()


def file_fingerprint(path: str) -> str:
    """Content hash of a source file, cached by (path, size, mtime_ns)."""
    st = os.stat(path)
    key = (os.path.abspath(path), st.st_size, st.st_mtime_ns)
    h = _FILE_HASH_CACHE.get(key)
    if h is not None:
        return h
    d = hashlib.sha256()
    with open(path, "rb") as fh:
        for chunk in iter(lambda: fh.read(1 << 20), b""):
            d.update(chunk)
    h = d.hexdigest()
    _FILE_HASH_CACHE[key] = h
    return h


def source_fingerprint(path: str, row_groups=None) -> str:
    """Content identity of a loader's source.

    Whole-file loads hash the file bytes (``file_fingerprint``).  A
    row-group-scoped load of a *stream* zarquet file instead hashes the
    selected groups' per-group content hashes from the committed footer:
    committed group extents are immutable, so these identities are
    stable across appends — an append leaves every existing loader's
    fingerprint (and its cached output) intact and invalidates only
    consumers of the new tail.  Selection order matters (the output is
    one batch per group, in order).  Group-scoped reads of files without
    per-group hashes (batch files) fall back to whole-file content plus
    the selection."""
    if row_groups is None:
        return file_fingerprint(path)
    st = os.stat(path)
    key = (os.path.abspath(path), st.st_size, st.st_mtime_ns)
    hashes = _GROUP_HASH_CACHE.get(key)
    if hashes is None:
        from . import zarquet
        meta = zarquet.read_footer(path)
        hashes = [g.get("hash") for g in (meta.get("groups") or ())]
        _GROUP_HASH_CACHE[key] = hashes
    sel = []
    for g in row_groups:
        if not 0 <= g < len(hashes) or hashes[g] is None:
            return (f"{file_fingerprint(path)}"
                    f":rg{','.join(str(i) for i in row_groups)}")
        sel.append(hashes[g])
    return "rg:" + hashlib.sha256(";".join(sel).encode()).hexdigest()


def code_fingerprint(fn, _seen=None) -> Optional[str]:
    """Identity of an op: its code object, captured values, and the
    module globals it references directly by name (helper functions by
    *their* code, constants by value — one level; attributes reached
    through a module object, e.g. ``ops.foo``, are not chased: bump
    ``FP_VERSION`` after editing shared library code).  None when the
    callable has no introspectable code or depends on a value whose only
    representation embeds a memory address (never cached)."""
    try:
        return _code_fingerprint(fn, _seen)
    except _UnstableValue:
        return None


def _code_fingerprint(fn, _seen=None) -> Optional[str]:
    if fn is None:
        return f"loader:v{FP_VERSION}"          # the generic loader op
    if _seen is None:
        _seen = set()
    if id(fn) in _seen:                         # mutually-recursive helpers
        return "recursive"
    _seen.add(id(fn))
    if isinstance(fn, functools.partial):
        inner = _code_fingerprint(fn.func, _seen)
        if inner is None:
            return None
        return hashlib.sha256(
            f"partial|{inner}|"
            f"{_stable([_canon_value(a, _seen) for a in fn.args])}|"
            f"{_stable({k: _canon_value(v, _seen) for k, v in (fn.keywords or {}).items()})}"
            .encode()).hexdigest()
    code = getattr(fn, "__code__", None)
    if code is None:
        call = getattr(type(fn), "__call__", None)
        code = getattr(call, "__code__", None)
        if code is None:
            return None
    parts = [_canon_code(code)]
    closure = getattr(fn, "__closure__", None)
    if closure:
        try:
            parts.append(_stable([_canon_value(c.cell_contents, _seen)
                                  for c in closure]))
        except ValueError:              # empty cell: recursion guard
            return None
    defaults = getattr(fn, "__defaults__", None)
    if defaults:
        parts.append(_stable([_canon_value(d, _seen) for d in defaults]))
    g = getattr(fn, "__globals__", None)
    if g:
        for name in sorted(set(code.co_names) & set(g)):
            v = g[name]
            if isinstance(v, types.ModuleType):
                continue                # module-attr chains: FP_VERSION
            parts.append(f"g:{name}={_stable(_canon_value(v, _seen))}")
    # explicit dependency declaration: callables reached through a module
    # attribute (``vkernels.hash_keys``) are invisible to the direct-
    # global scan above; an op can declare them in ``__fp_includes__`` so
    # editing the kernel invalidates the op's cached outputs.  A
    # *callable* __fp_includes__ is invoked at fingerprint time to
    # produce the tuple — how the relational ops bind to whichever
    # kernel backend ``ZERROW_KERNEL_BACKEND`` currently selects, so a
    # backend flip changes the fingerprint (kdispatch.fp_includes_join)
    includes = getattr(fn, "__fp_includes__", ()) or ()
    if callable(includes):
        includes = includes() or ()
    for i, dep in enumerate(includes):
        inner = _code_fingerprint(dep, _seen)
        if inner is None:
            return None
        parts.append(f"inc{i}:{inner}")
    return hashlib.sha256("|".join(parts).encode()).hexdigest()


def _canon_code(code) -> str:
    """Address-free canonical text of a code object (consts may hold
    nested code objects whose repr embeds a memory address)."""
    consts = [_canon_code(c) if isinstance(c, types.CodeType)
              else _stable(c) for c in code.co_consts]
    return "|".join([code.co_code.hex(), _stable(consts),
                     _stable(code.co_names), str(code.co_argcount)])


def _canon_value(v, _seen=None):
    """Captured values: canonicalize nested callables via their code, not
    their (address-bearing) repr, and ndarrays by content hash.  A
    code-less callable falls back to its own repr — stable for classes
    and builtins ('<class ...>', '<built-in ...>'); anything
    address-bearing raises and the op becomes uncacheable."""
    if isinstance(v, types.CodeType):
        return _canon_code(v)
    if isinstance(v, np.ndarray):
        return _json_default(v)
    if callable(v):
        return _code_fingerprint(v, _seen) or _json_default(v)
    return v


def node_fingerprint(spec, input_fps: List[str],
                     salt: str = "") -> Optional[str]:
    """Fingerprint one node from its spec + its inputs' fingerprints.
    None (uncacheable) when the op has no code identity, any input is
    uncacheable, or the source file is unreadable."""
    if any(fp is None for fp in input_fps):
        return None
    op = code_fingerprint(spec.fn)
    if op is None:
        return None
    source_fp = None
    row_groups = getattr(spec, "row_groups", None)
    if spec.source is not None:
        try:
            source_fp = source_fingerprint(spec.source, row_groups)
        except (OSError, ValueError, AssertionError):
            return None
    payload_dict = {
        "v": FP_VERSION, "op": op, "source": source_fp,
        "dict_columns": sorted(spec.dict_columns),
        "inputs": input_fps, "salt": salt}
    # loader column subsets (projection pruning) change the output table,
    # so they change the fingerprint; the key is omitted entirely for
    # full loads so pre-existing manifests keep hitting
    cols = getattr(spec, "columns", None)
    if cols is not None:
        payload_dict["columns"] = sorted(cols)
    # likewise row-group subsets (streaming ingest): selection order is
    # output order, so the key keeps the given order; omitted for
    # whole-file loads so pre-existing manifests keep hitting
    if row_groups is not None:
        payload_dict["row_groups"] = [int(g) for g in row_groups]
    payload = json.dumps(payload_dict, sort_keys=True)
    return hashlib.sha256(payload.encode()).hexdigest()


def fingerprint_dag(dag, salt: str = "") -> Dict[str, Optional[str]]:
    """Assign ``NodeState.fingerprint`` for every node, topo order."""
    out: Dict[str, Optional[str]] = {}
    for name in dag.topo_order():
        st = dag.nodes[name]
        st.fingerprint = node_fingerprint(
            st.spec, [out[d] for d in st.spec.deps], salt=salt)
        out[name] = st.fingerprint
    return out

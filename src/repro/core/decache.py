"""DeCache — the shared deserialization service (paper §3.1, §4.2.4).

When multiple DAGs (possibly submitted at different times) deserialize the
same source with the same dictionary configuration, the load runs once and
every consumer maps the same physical Arrow data.  Entries are keyed by
``(source_path, dict_columns)`` — the same source deserialized with
different ``read_dictionary`` settings gets distinct loader nodes, as in
the paper's Figure 3a (shows.parquet with and without dictionaries).

Entries are pinned in the store (``decache_pinned``) and survive DAG
completion until the RM uncaches them under memory pressure.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Dict, Optional, Tuple

from .buffers import BufferStore
from .sipc import SipcMessage

Key = Tuple[Optional[str], tuple]


@dataclass
class DeCacheEntry:
    key: Key
    msg: SipcMessage
    load_latency: float
    bytes: int
    refcount: int = 0          # active attachments (running/queued consumers)
    last_use: float = field(default_factory=time.monotonic)
    hits: int = 0


class DeCache:
    def __init__(self, store: BufferStore, enabled: bool = True,
                 manifest=None):
        self.store = store
        self.enabled = enabled
        self.manifest = manifest       # persistent cross-run cache (may be
        #                              # None): misses on fingerprint keys
        #                              # warm from it instead of reloading
        self.entries: Dict[Key, DeCacheEntry] = {}
        self.loads = 0
        self.hits = 0
        self.warmed = 0                # entries materialized from manifest
        self._cgroup = None            # owner of warmed adoptions

    # -- lookup/attach --------------------------------------------------------
    def lookup(self, key: Key) -> Optional[DeCacheEntry]:
        if not self.enabled:
            return None
        e = self.entries.get(key)
        if e is None:
            e = self._warm(key)
        if e is not None:
            e.last_use = time.monotonic()
        return e

    def _warm(self, key: Key) -> Optional[DeCacheEntry]:
        """Materialize a fingerprint key from the persistent manifest: the
        published output is adopted (mmap'd, zero bytes copied) and pinned
        like any loader output — deserialization survives the process."""
        if self.manifest is None or not isinstance(key, str):
            return None
        if key not in self.manifest:
            return None
        if self._cgroup is None:
            self._cgroup = self.store.new_cgroup("decache")
        msg = self.manifest.decode(key, self.store, owner=self._cgroup,
                                   label="decache")
        if msg is None:
            return None
        self.warmed += 1
        e = self.insert(key, msg, load_latency=0.0)
        self.loads -= 1         # a warm adoption is not a deserialization
        return e

    def attach(self, e: DeCacheEntry) -> SipcMessage:
        e.refcount += 1
        e.hits += 1
        self.hits += 1
        e.last_use = time.monotonic()
        return e.msg

    def detach(self, e: DeCacheEntry) -> None:
        e.refcount -= 1
        assert e.refcount >= 0

    # -- insert (after a loader node ran) --------------------------------------
    def insert(self, key: Key, msg: SipcMessage, load_latency: float) -> DeCacheEntry:
        self.loads += 1
        if not self.enabled:
            return DeCacheEntry(key, msg, load_latency, msg.new_bytes)
        for fid in msg.files_referenced():
            f = self.store.files.get(fid)
            if f is not None:
                f.decache_pinned = True
        e = DeCacheEntry(key, msg, load_latency, msg.new_bytes)
        self.entries[key] = e
        return e

    # -- eviction ('RM:uncache') ------------------------------------------------
    def uncache_candidates(self):
        """Zero-reference entries, least recently used first."""
        free = [e for e in self.entries.values() if e.refcount == 0]
        free.sort(key=lambda e: e.last_use)
        return free

    def uncache(self, e: DeCacheEntry) -> int:
        del self.entries[e.key]
        freed = 0
        for fid in e.msg.files_referenced():
            f = self.store.files.get(fid)
            if f is not None:
                f.decache_pinned = False
                freed += f.resident_bytes()
        e.msg.release()
        # files with no other references are garbage collected now
        for fid in list(e.msg.files_referenced()):
            f = self.store.files.get(fid)
            if f is not None and f.refcount == 0:
                self.store.delete_file(fid)
        return freed

    def resident_bytes(self) -> int:
        total = 0
        for e in self.entries.values():
            for fid in e.msg.files_referenced():
                f = self.store.files.get(fid)
                if f is not None:
                    total += f.resident_bytes()
        return total

"""Event-driven worker-pool DAG executor (the RM run loop, paper §3.1/§3.3).

``WorkerPoolExecutor`` replaces the seed's sequential ``Executor.run``
monolith with four pluggable layers:

  scheduling  — a :class:`~..sched.policy.SchedulePolicy` orders runnable
                nodes (``RMConfig.schedule``);
  admission   — the :class:`~.admission.AdmissionController` budget check;
  eviction    — an :class:`~.eviction.EvictionPolicy` frees memory for the
                chosen node (``RMConfig.policy``);
  execution   — N workers pull admitted nodes and run them concurrently.

Concurrency model (``workers > 1``):

  * One re-entrant lock — the *RM critical section* — protects all
    scheduler, RM, DeCache and BufferStore state.  Node *claims*
    (WAITING/EVICTED -> RUNNING transitions), completion bookkeeping,
    eviction, and SIPC reads/writes all happen under it.
  * Loader nodes release the lock around zarquet decompression, which
    drops the GIL (zstd/zlib), so deserialization overlaps across workers
    — the parallelism the paper notes in Fig 2.  Each decompressed buffer
    re-enters the lock briefly to register as sandbox anonymous memory.
  * Compute nodes release the lock around the user function: SIPC reads
    the inputs under the lock, the fn runs outside it (the vkernels bulk
    numpy ops release the GIL), and the SIPC output write re-enters it.
    A LazyBuf the fn faults mid-compute re-acquires the lock for the
    store-mutating read (``LazyBuf.fault_lock``).
  * Loads are single-flight per DeCache key: a worker that finds another
    worker already deserializing the same ``(source, dict_columns)`` waits
    and attaches to the cached entry instead of duplicating the load.
  * Eviction only runs when the pool is drained (no in-flight nodes):
    evicting an output a running node is reading would be a use-after-free.
    Workers that cannot admit anything while peers run simply wait for a
    completion event.

``workers=1`` executes inline on the calling thread with the exact
scheduling semantics of the seed's sequential loop (same node order, same
``node_runs`` / ``load_runs`` / eviction counts).
"""

from __future__ import annotations

import pickle
import threading
import time
from typing import Dict, List, Optional, Set, Tuple

from ..dag import (CACHED, COMPLETE, DAG, DONE, EVICTED, NodeState, RUNNING,
                   Sandbox, WAITING)
from ..fingerprint import fingerprint_dag
from .. import zarquet

#: sentinel: nothing runnable now, but in-flight nodes may unblock us
_WAIT = object()


class NodePoisonedError(RuntimeError):
    """A node op killed its worker ``max_node_retries`` times in a row.

    The op is treated as permanently poisonous: its code fingerprint is
    quarantined on the RM (subsequent DAGs carrying it are shed with
    ``"shed:quarantined"``) and *its own DAG* fails with outcome
    ``"poisoned"`` — the pool is healed and every other DAG keeps
    running.  ``fns`` carries the candidate culprit callables (the whole
    segment for a chain dispatch, where the killer step is unknowable —
    the worker died before saying which)."""

    def __init__(self, msg: str):
        super().__init__(msg)
        self.fns: list = []


class Ticket:
    """Serving-plane handle for one submitted DAG (``Executor.submit``).

    Resolves when the DAG reaches a terminal outcome — "completed",
    "shed:<reason>" (never ran), "deadline_miss", "poisoned", or
    "failed:<exc>".  ``wait`` never raises: overload is data, not an
    exception, in a serving loop."""

    def __init__(self, dag: DAG):
        self.dag = dag
        self.submitted_at = time.monotonic()
        self.finished_at: Optional[float] = None
        self._ev = threading.Event()

    def _resolve(self) -> None:
        self.finished_at = time.monotonic()
        self._ev.set()

    def done(self) -> bool:
        return self._ev.is_set()

    def wait(self, timeout: Optional[float] = None) -> Optional[str]:
        """Block until terminal; returns the outcome (None on timeout)."""
        self._ev.wait(timeout)
        return self.dag.outcome if self._ev.is_set() else None

    @property
    def outcome(self) -> Optional[str]:
        return self.dag.outcome

    @property
    def latency(self) -> Optional[float]:
        """Submit-to-terminal seconds (sheds resolve in microseconds)."""
        if self.finished_at is None:
            return None
        return self.finished_at - self.submitted_at


class WorkerPoolExecutor:
    """Pull-based executor: workers repeatedly (schedule -> execute ->
    complete) until every submitted DAG is done.

    ``workers`` defaults to ``rm.cfg.workers``.  The instance is reusable
    across ``run`` calls (counters accumulate, as before) but a single
    instance must not run concurrently with itself.
    """

    def __init__(self, store, rm, workers: Optional[int] = None,
                 force_threads: bool = False):
        self.store = store
        self.rm = rm
        if workers is None:
            workers = getattr(rm.cfg, "workers", 1)
        self.workers = max(int(workers or 1), 1)
        # run workers=1 through the thread pool instead of inline (used by
        # tests to prove pool(1) ≡ sequential)
        self.force_threads = force_threads
        # scheduler threads pulling nodes; the process executor runs more
        # of them than workers so that while every worker computes, spare
        # threads encode and submit the next requests (pipelined dispatch)
        self.sched_threads = self.workers
        self.node_runs = 0
        self.load_runs = 0
        self.cache_hits = 0     # nodes satisfied from the persistent
        #                       # manifest (marked CACHED, never executed)
        self._lock = threading.RLock()
        self._cond = threading.Condition(self._lock)
        # run() resets per-run state (_active/_attach/_inflight), so one
        # instance must never run two batches concurrently; the gate
        # serializes concurrent submitters (e.g. a streaming-ingest
        # refresh thread and serving threads sharing one executor)
        # instead of corrupting each other's run
        self._run_gate = threading.Lock()
        self._active: Dict[int, DAG] = {}
        self._attach: Dict[int, list] = {}
        self._inflight: Dict[Tuple[int, str], NodeState] = {}
        self._loading: Set[tuple] = set()
        self._error: Optional[BaseException] = None
        # serving-plane submission (submit/Ticket): offered DAGs queue
        # here and a lazily started dispatcher thread runs them in waves
        # through the normal run gate
        self._submit_cv = threading.Condition(threading.Lock())
        self._pending_tickets: List[Ticket] = []
        self._dispatcher: Optional[threading.Thread] = None
        self._shutdown = False

    # -- entry point -------------------------------------------------------
    def run(self, dags: List[DAG], deadline_s: float = 3600.0) -> float:
        with self._run_gate:
            return self._run_gated(dags, deadline_s)

    # -- serving-plane submission ------------------------------------------
    def submit(self, dag: DAG, now: Optional[float] = None) -> Ticket:
        """Offer one DAG to the bounded admission queue.  Returns a
        :class:`Ticket` immediately: shed DAGs resolve on the spot with a
        typed ``"shed:<reason>"`` outcome (no exception, no execution);
        admitted DAGs run in dispatcher waves and resolve when terminal.
        Safe to call concurrently from many request threads."""
        ticket = Ticket(dag)
        if self.rm.admission.offer(dag, now) is not None:
            ticket._resolve()
            return ticket
        with self._submit_cv:
            self._pending_tickets.append(ticket)
            if self._dispatcher is None:
                self._dispatcher = threading.Thread(
                    target=self._dispatch_loop, name="zerrow-dispatch",
                    daemon=True)
                self._dispatcher.start()
            self._submit_cv.notify_all()
        return ticket

    def _dispatch_loop(self) -> None:
        """Run admitted DAGs in waves: everything queued when the run
        gate frees executes as one batch (sharing loads/cache hits), so
        burst arrivals amortize while the queue bound caps the wave."""
        while True:
            with self._submit_cv:
                while not self._pending_tickets and not self._shutdown:
                    self._submit_cv.wait(timeout=0.1)
                if not self._pending_tickets and self._shutdown:
                    return
                wave, self._pending_tickets = self._pending_tickets, []
            adm = self.rm.admission
            enforcing = getattr(self.rm.cfg, "enforce_deadlines", False)
            now, live = time.monotonic(), []
            for t in wave:
                d = t.dag
                if enforcing and d.deadline is not None \
                        and now >= d.deadline and not d.cancelled:
                    # expired while queued: already admitted, so this is
                    # a miss (not a shed) — cancel without running
                    d.outcome = "deadline_miss"
                    d.cancelled = True
                    adm.count("deadline_misses")
                    adm.finished(d)
                    t._resolve()
                    continue
                live.append(t)
            if not live:
                continue
            try:
                self.run([t.dag for t in live])
            except BaseException as e:
                # a run-level failure (scheduler error, pool loss past
                # recovery) fails the DAGs that did not finish; the
                # serving loop keeps accepting
                for t in live:
                    d = t.dag
                    if d.outcome is None:
                        d.outcome = f"failed:{type(e).__name__}"
                        d.error = e
                        d.cancelled = True
                        adm.finished(d)
            for t in live:
                t._resolve()

    def drain(self, timeout: Optional[float] = None) -> None:
        """Block until every submitted DAG has resolved and stop the
        dispatcher (the executor remains usable; a later ``submit``
        restarts it)."""
        with self._submit_cv:
            self._shutdown = True
            self._submit_cv.notify_all()
            d = self._dispatcher
        if d is not None:
            d.join(timeout)
        with self._submit_cv:
            self._dispatcher = None
            self._shutdown = False

    def _run_gated(self, dags: List[DAG], deadline_s: float) -> float:
        t0 = time.perf_counter()
        self._t0 = t0
        self._deadline = deadline_s
        self._active = {d.id: d for d in dags}
        self._attach = {d.id: [] for d in dags}
        self._inflight = {}
        self._loading = set()
        self._error = None
        if self.rm.manifest is not None:
            # differential caching: fingerprint every node (topo order),
            # then satisfy fingerprint hits straight from the manifest —
            # whole unchanged sub-DAGs are skipped before any scheduling
            for d in dags:
                fingerprint_dag(d)
                with self._cond:
                    self._apply_cache_hits(d)
        if self.workers == 1 and not self.force_threads:
            self._run_sequential()
        else:
            self._run_threaded()
        if self._error is not None:
            raise self._error
        return time.perf_counter() - t0

    def _run_sequential(self) -> None:
        while True:
            with self._cond:
                st = self._schedule_locked()
            if st is None:
                return
            assert st is not _WAIT, "sequential run cannot have in-flight"
            try:
                self._execute(st)
            except BaseException:
                self._release_claim_locked(st)
                raise
            self._publish_output(st)
            with self._cond:
                self._complete_locked(st)

    def _run_threaded(self) -> None:
        threads = [threading.Thread(target=self._worker_loop,
                                    name=f"zerrow-worker-{i}", daemon=True)
                   for i in range(self.sched_threads)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()

    def _worker_loop(self) -> None:
        while True:
            with self._cond:
                st = None
                while st is None:
                    if self._error is not None:
                        return
                    try:
                        st = self._schedule_locked()
                    except BaseException as e:
                        self._error = e
                        self._cond.notify_all()
                        return
                    if st is None:
                        self._cond.notify_all()   # wake idle peers: done
                        return
                    if st is _WAIT:
                        st = None
                        self._cond.wait(timeout=0.1)
            try:
                self._execute(st)
            except NodePoisonedError as e:
                # permanent op failure: quarantine the op and fail ONLY
                # this DAG — peers and the pool keep serving
                with self._cond:
                    self._release_claim_locked(st)
                    if st.status == RUNNING:
                        st.transition(WAITING)
                    self._poison_locked(st, e)
                    self._cond.notify_all()
                continue
            except BaseException as e:
                with self._cond:
                    if self._error is None:
                        self._error = e
                    self._release_claim_locked(st)
                    self._cond.notify_all()
                return
            self._publish_output(st)
            with self._cond:
                self._complete_locked(st)
                self._cond.notify_all()

    # -- scheduling (RM critical section) ----------------------------------
    def _schedule_locked(self):
        """Pick, admit and claim the next node.  Returns the claimed
        NodeState, ``_WAIT`` (only possible with in-flight nodes), or
        ``None`` when every DAG is finished.  Caller holds the lock."""
        while True:
            if time.perf_counter() - self._t0 > self._deadline:
                raise TimeoutError("executor deadline exceeded")
            if getattr(self.rm.cfg, "enforce_deadlines", False):
                now = time.monotonic()
                for d in self._active.values():
                    if not d.cancelled and d.deadline is not None \
                            and now >= d.deadline:
                        self._cancel_dag_locked(d, "deadline_miss")
                self._finish_done_dags()
            if not self._active:
                return None
            cands = self._collect()
            if not cands:
                self._finish_done_dags()
                if not self._active:
                    return None
                if self._inflight:
                    return _WAIT
                # a rolled-back CACHED node can be blocked on skipped
                # (output-less) CACHED deps that normal candidate repair
                # never visits; repair every blocked node before
                # declaring a stall
                for d in self._active.values():
                    for st in d.nodes.values():
                        if st.status in (WAITING, EVICTED):
                            self._ensure_deps(st)
                if self._collect():
                    continue
                raise RuntimeError("scheduler stall: no runnable node")
            # repair evicted dependencies (cascading rollback) in priority
            # order, then re-plan: the cascade changes runnability
            for st in cands:
                self._ensure_deps(st)
            cands = self._collect()
            if not cands:
                continue
            # fast path: highest-priority node that already fits
            picked = None
            for st in cands:
                if self.rm.admit(st):
                    picked = st
                    break
            if picked is None:
                if self._inflight:
                    # memory may free when a running node completes, and
                    # evicting under a concurrent reader is unsafe — wait
                    return _WAIT
                # nothing fits: evict for the highest-priority node only
                # (paper: 'outputs are evicted one by one until the
                # available memory is larger than the requirement of the
                # node scheduled to run next'); kswap/no-admission runs it
                # anyway and lets kernel swap / OOM handle the overflow
                picked = cands[0]
                self.rm.make_room_for(picked,
                                      extra_protect=self._inflight_deps())
                if any(picked.dag.nodes[d].output is None or
                       picked.dag.nodes[d].output.released
                       for d in picked.spec.deps):
                    continue  # an eviction broke a dep; re-plan
            picked.claim()
            self._inflight[(picked.dag.id, picked.name)] = picked
            self.rm.admission.reserve(picked)
            self.node_runs += 1
            return picked

    def _collect(self) -> List[NodeState]:
        policy = self.rm.schedule
        policy.prepare(self._active.values())
        cands = [st for d in self._active.values() for st in d.runnable()]
        cands.sort(key=lambda st: (*policy.key(st), st.dag.id))
        return cands

    def _inflight_deps(self) -> Set[Tuple[int, str]]:
        prot: Set[Tuple[int, str]] = set()
        for st in self._inflight.values():
            for d in st.spec.deps:
                prot.add((st.dag.id, d))
        return prot

    # -- cross-run differential cache hits ---------------------------------
    def _apply_cache_hits(self, dag: DAG) -> None:
        """Mark fingerprint-hit nodes CACHED before scheduling starts.

        Children first (reverse topo): a hit node's output is *adopted*
        from the manifest only when someone will read it — it has an
        executing (non-hit) child or is a keep_output sink; hit nodes in
        the interior of a fully-hit cone are skipped outright (CACHED with
        no output, zero bytes touched).  An entry whose objects vanished
        demotes to a miss and the node executes normally."""
        man = self.rm.manifest
        order = dag.topo_order()
        hit = {name: (dag.nodes[name].fingerprint is not None
                      and dag.nodes[name].status == WAITING
                      and man.get(dag.nodes[name].fingerprint) is not None)
               for name in order}
        for name in reversed(order):
            if not hit[name]:
                continue
            st = dag.nodes[name]
            kids = dag.children[name]
            wanted = st.spec.keep_output or any(not hit[k] for k in kids)
            if wanted:
                if self.rm.adopt_cached(st) is None:
                    hit[name] = False    # vanished underneath us: execute
                    continue
                # adopted bytes honor the admission budget like executed
                # ones: past it, spill earlier adoptions back to disk
                over = -self.rm.available()
                if over > 0:
                    self.rm.free_memory(over, protect=st)
            st.transition(CACHED)
            self.cache_hits += 1

    # -- cascading rollback repair ----------------------------------------
    def _ensure_deps(self, st: NodeState) -> None:
        for dep_name in st.spec.deps:
            dep = st.dag.nodes[dep_name]
            if dep.status in COMPLETE and (dep.output is None or
                                           dep.output.released):
                if dep.is_loader and self.rm.decache.enabled:
                    e = self.rm.decache.lookup(dep.decache_key())
                    if e is not None:
                        dep.output = self.rm.decache.attach(e)
                        self._attach[dep.dag.id].append(e)
                        continue
                # a skipped/evicted durable output can be re-adopted from
                # the manifest instead of re-executing the dependency;
                # its own (possibly skipped) ancestors stay untouched —
                # the adopted output makes them unnecessary
                if self.rm.adopt_cached(dep) is not None:
                    if dep.status != CACHED:
                        dep.transition(EVICTED)
                        dep.transition(CACHED)
                    continue
                dep.transition(EVICTED)
                dep.output = None
                self._ensure_deps(dep)

    # -- node execution (outside the lock where safe) ----------------------
    def _execute(self, st: NodeState) -> None:
        t0 = time.perf_counter()
        if st.is_loader:
            self._run_loader(st)
        else:
            self._run_compute(st)
        st.exec_latency = time.perf_counter() - t0

    def _make_sandbox(self, st: NodeState) -> Sandbox:
        return Sandbox(self.store, self.rm.kz,
                       f"{st.dag.name}.{st.name}#{st.runs}",
                       mode=self.rm.cfg.sipc_mode)

    def _run_compute(self, st: NodeState) -> None:
        with self._lock:
            sb = self._make_sandbox(st)
            st.sandbox = sb
            inputs = [st.dag.nodes[d].output for d in st.spec.deps]
        msg = self._compute_output(st, sb, inputs)
        with self._lock:
            st.output = msg
            st.output_bytes = msg.new_bytes

    def _compute_output(self, st: NodeState, sb: Sandbox, inputs):
        """Run the node's user function; override point for process-mode
        execution.  Thread mode: the SIPC input reads, the output write
        and any LazyBuf fault user code triggers are store-mutating and
        run inside the critical section (``lock=``), but the user
        function itself runs outside it — vectorized kernels release the
        GIL, so computes overlap across workers alongside loader
        decompression."""
        return sb.run(st.spec.fn, inputs, label=st.name, lock=self._lock)

    def _run_loader(self, st: NodeState) -> None:
        key = st.decache_key()
        with self._cond:
            # single-flight: if a peer is deserializing this key, wait for
            # its DeCache insert instead of duplicating the load
            while key in self._loading:
                self._cond.wait(timeout=0.1)
            e = self.rm.decache.lookup(key)
            if e is not None:
                st.output = self.rm.decache.attach(e)
                self._attach[st.dag.id].append(e)
                st.output_bytes = 0
                return
            self._loading.add(key)
            self.load_runs += 1
            sb = self._make_sandbox(st)
            st.sandbox = sb
        try:
            msg = self._load_output(st, sb)
            with self._cond:
                st.output = msg
                st.output_bytes = msg.new_bytes
                if self.rm.decache.enabled:
                    e = self.rm.decache.insert(key, st.output,
                                               time.perf_counter())
                    self.rm.decache.attach(e)
                    self._attach[st.dag.id].append(e)
        finally:
            with self._cond:
                self._loading.discard(key)
                self._cond.notify_all()

    def _load_output(self, st: NodeState, sb: Sandbox):
        """Deserialize the node's zarquet source and SIPC-write the table;
        override point for process-mode execution.

        Generic loader 'user code' (paper §4.2.4): deserialize zarquet
        OUTSIDE the lock — decompression releases the GIL and overlaps
        across workers; each fresh buffer re-enters the lock to register
        as sandbox anonymous memory."""
        lock = self._lock

        def on_buffer(a):
            with lock:
                sb.register_anon(a)

        table = zarquet.read_table(
            st.spec.source, dict_columns=st.spec.dict_columns,
            columns=st.spec.columns, row_groups=st.spec.row_groups,
            on_buffer=on_buffer,
            reader_threads=getattr(self.rm.cfg, "reader_threads", None))
        with self._lock:
            return sb.write_output(table, label=st.name)

    # -- durable publication (outside the RM critical section) -------------
    def _publish_output(self, st: NodeState) -> None:
        """Publish a just-executed output under its fingerprint so the
        next run adopts it instead of re-executing.  Hashing + the four
        fsyncs are slow, so they run *off* the executor lock: the node is
        still in-flight (not yet DONE/in completed_nodes), so eviction
        cannot touch the message, and once its extents are landed in the
        backing files (done under the lock) the file bytes are immutable
        — a concurrent swap-out only drops mappings."""
        rm = self.rm
        if rm.manifest is None or st.fingerprint is None or \
                st.output is None:
            return
        try:
            with self._lock:
                for fid in st.output.files_referenced():
                    if fid in self.store.files:
                        self.store.ensure_file_backed(fid)
            rm.publish_output(st)
        except Exception:
            # publication is strictly best-effort: a full disk or a
            # vanished file must cost a future cache miss, never the run
            # (an escaped exception here would strand the in-flight node
            # and hang the pool until the deadline)
            pass

    # -- completion bookkeeping (RM critical section) ----------------------
    def _release_claim_locked(self, st: NodeState) -> None:
        """Release a claimed node's inflight entry + admission reservation
        exactly once.  Pop-guarded: completion and error paths can both
        reach a claim, and a double unreserve would corrupt the books
        (``unreserve`` now raises on imbalance rather than asserting)."""
        if self._inflight.pop((st.dag.id, st.name), None) is not None:
            self.rm.admission.unreserve(st)

    def _cancel_dag_locked(self, dag: DAG, outcome: str) -> None:
        """Cooperative cancellation: no new claims (``runnable`` goes
        empty), in-flight nodes drain through their normal completion
        path, and the DAG finishes with the given outcome once drained."""
        dag.cancelled = True
        if dag.outcome is None:
            dag.outcome = outcome
        if outcome == "deadline_miss":
            self.rm.admission.count("deadline_misses")

    def _poison_locked(self, st: NodeState, e: NodePoisonedError) -> None:
        """Quarantine a permanently failing op and cancel its DAG."""
        fns = getattr(e, "fns", None) or [st.spec.fn]
        keys = {self.rm.poison_key(fn) for fn in fns}
        keys.discard(None)          # loaders are never quarantined
        self.rm.quarantined.update(keys)
        self.rm.admission.count("poisoned")
        st.dag.error = e
        self._cancel_dag_locked(st.dag, "poisoned")
        self._finish_done_dags()

    def _complete_locked(self, st: NodeState) -> None:
        st.transition(DONE)
        st.runs += 1
        if st not in self.rm.completed_nodes:
            self.rm.completed_nodes.append(st)
        self._release_claim_locked(st)
        self.rm.admission.note_latency(st.exec_latency)
        # NOTE: outputs are retained until DAG completion (paper §3.1) —
        # freeing earlier would defeat rollback and share-aware eviction.
        self._finish_done_dags()

    def _owned_by_decache(self, st: NodeState) -> bool:
        if not self.rm.decache.enabled or st.output is None:
            return False
        e = self.rm.decache.entries.get(st.decache_key())
        return e is not None and e.msg is st.output

    def _finish_done_dags(self) -> None:
        for did in [i for i, d in self._active.items() if d.all_done()]:
            self._finish_dag(self._active.pop(did), self._attach.pop(did))

    def _finish_dag(self, dag: DAG, attachments: list) -> None:
        dag.done = True
        if dag.outcome is None:
            dag.outcome = "completed"
        for st in dag.nodes.values():
            if st in self.rm.completed_nodes:
                self.rm.completed_nodes.remove(st)
            # external consumers own keep_output sinks (they release the
            # msg) — unless the DAG was cancelled, in which case no
            # consumer will ever read the partial result
            if st.spec.keep_output and not dag.cancelled:
                continue
            # release everything except messages the DeCache owns (the
            # entry's own msg — shared by every DAG keyed on it; this
            # includes CACHED loaders repaired via a decache attach,
            # while manifest-adopted CACHED outputs are ours to release)
            if not self._owned_by_decache(st):
                self.rm.release_output(st)
            if st.sandbox is not None:
                st.sandbox.destroy()
        for e in attachments:
            self.rm.decache.detach(e)
        self.rm.admission.finished(dag)

    def reshare_stats(self) -> Dict[str, int]:
        """Writer-side copy-avoidance counters for every SIPC write this
        executor's nodes performed: reshare hits (buffers emitted as
        references) vs misses (buffers de-anonymized), bytes reshared,
        and real copied bytes.  ``ProcessWorkerExecutor`` folds in the
        counters echoed back from its worker processes, so the view is
        uniform across ``workers_mode`` (what ``benchmarks/bench_join.py``
        records as the reshare hit-rate)."""
        s = self.store.stats
        return {"reshare_hits": s.reshare_hits,
                "reshare_misses": s.reshare_misses,
                "bytes_reshared": s.bytes_reshared,
                "bytes_copied": s.bytes_copied}

    def close(self) -> None:
        """Release executor resources (the thread pool only has the
        dispatcher thread to stop)."""
        self.drain(timeout=30.0)


class ProcessWorkerExecutor(WorkerPoolExecutor):
    """Executor whose node ops run in spawned OS worker processes.

    Same scheduling, admission, eviction and DeCache behaviour as the
    thread executor — the N scheduler threads still claim nodes under the
    RM critical section — but ``_compute_output`` / ``_load_output``
    dispatch to a :class:`~..flight.worker.FlightWorkerPool` instead of
    running user code inline.  Inputs go out and outputs come back as
    SIPC wire references (never data), the worker maps the parent's
    store files, and the parent adopts the worker's output files with
    ownership, so the RM keeps full accounting, admission and eviction
    authority over every byte (paper §3.1: the RM owns memory; nodes are
    untrusted tenants).

    Requires a file-backed store (``BufferStore(backing="file")``) —
    references must name real files other processes can map.  Node
    functions must be picklable (module-level functions or
    ``functools.partial`` over them); unpicklable ops fall back to
    inline execution (counted in ``fallback_inline``).
    """

    def __init__(self, store, rm, workers: Optional[int] = None,
                 data_root: Optional[str] = None):
        super().__init__(store, rm, workers, force_threads=True)
        if store.backing != "file":
            raise ValueError(
                "ProcessWorkerExecutor needs BufferStore(backing='file'): "
                "worker processes can only map file-backed extents")
        self._pool = None
        self._data_root = data_root
        self.fallback_inline = 0   # unpicklable fns executed in-parent
        self.worker_retries = 0    # requests re-run after a worker died
        # data-plane counters from inside the workers (each reply echoes
        # its store-stats delta): without these, a reshare hit on a join
        # payload dictionary that happens wholly in a worker process
        # would be invisible to the parent's accounting
        self.worker_stats: Dict[str, int] = {}
        # pipelined dispatch: with one scheduler thread per worker, every
        # thread is parked in a blocking reply wait while its worker
        # computes — nobody is left to encode/submit the next request.
        # 2x threads keeps each worker's submission slot (the pool's
        # pipeline_depth) full.
        self.sched_threads = self.workers * 2
        # chain shipping (ISSUE 6): linear picklable segments dispatch as
        # one exec_chain request; intermediates stay worker-local
        self._chain_enabled = bool(getattr(rm.cfg, "chain_dispatch", True))
        self._chain_next: Dict[Tuple[int, str], str] = {}
        self._chain_claims: Dict[Tuple[int, str], List[NodeState]] = {}
        self.chains_shipped = 0        # exec_chain requests sent
        self.chain_nodes_shipped = 0   # nodes covered by those requests
        # fn identity -> (fn, pickled bytes | None): shard DAGs reuse the
        # same callable across hundreds of nodes — pickle it once, and
        # remember unpicklable fns so the fallback probe is paid once too
        self._fn_pickle: Dict[int, Tuple[object, Optional[bytes]]] = {}

    # -- pool lifecycle -----------------------------------------------------
    def _ensure_pool(self):
        if self._pool is None:
            from ..flight.worker import FlightWorkerPool
            self._pool = FlightWorkerPool(
                self.workers, sipc_mode=self.rm.cfg.sipc_mode,
                data_root=self._data_root,
                request_timeout=getattr(self.rm.cfg, "flight_timeout_s",
                                        600.0))
        return self._pool

    @property
    def socket_bytes(self) -> int:
        return self._pool.socket_bytes if self._pool is not None else 0

    def _run_gated(self, dags: List[DAG], deadline_s: float = 3600.0
                   ) -> float:
        # under the base class's run gate: chain planning resets
        # per-run state, so it must not race an in-progress run
        self._ensure_pool()
        self._chain_next = {}
        self._chain_claims = {}
        if self._chain_enabled:
            for d in dags:
                self._plan_chains(d)
        return super()._run_gated(dags, deadline_s)

    def close(self) -> None:
        super().close()             # stop the dispatcher first: a late
        if self._pool is not None:  # wave must not hit a closed pool
            self._pool.close()
            self._pool = None

    # -- chain shipping (subgraph dispatch) ---------------------------------
    def _fn_bytes(self, fn) -> Optional[bytes]:
        """Pickled bytes for ``fn`` (memoized by identity), or ``None``
        when it cannot cross the process boundary."""
        hit = self._fn_pickle.get(id(fn))
        if hit is not None and hit[0] is fn:
            return hit[1]
        try:
            b: Optional[bytes] = pickle.dumps(fn)
        except (pickle.PicklingError, TypeError, AttributeError):
            # closures/bound methods can't cross the process boundary
            b = None
        self._fn_pickle[id(fn)] = (fn, b)
        return b

    def _plan_chains(self, dag: DAG) -> None:
        """Structure-only planning pass: record every link ``a -> b``
        (a's only child is b) whose nodes can execute in a worker — a
        loader or picklable compute feeding a picklable compute.  ``b``
        may have further deps when each one is a *co-shippable loader
        root* (a dep-less loader whose only child is b): two loads
        feeding a join ship with it as one segment, so neither load
        output ever materializes.  Maximal runs of links form the
        shippable chains; node *status* is checked at claim time, so
        CACHED / evicted boundaries simply truncate what actually
        ships."""
        for name in dag.topo_order():
            st = dag.nodes[name]
            kids = dag.children[name]
            if len(kids) != 1:
                continue
            nxt = dag.nodes[kids[0]]
            if any(not (d == name or self._loader_root(dag, d, kids[0]))
                   for d in nxt.spec.deps):
                continue
            if not st.is_loader and self._fn_bytes(st.spec.fn) is None:
                continue
            if nxt.is_loader or self._fn_bytes(nxt.spec.fn) is None:
                continue
            self._chain_next[(dag.id, name)] = kids[0]

    @staticmethod
    def _loader_root(dag: DAG, name: str, child: str) -> bool:
        st = dag.nodes[name]
        return (st.is_loader and not st.spec.deps
                and list(dag.children[name]) == [child])

    def _schedule_locked(self):
        st = super()._schedule_locked()
        if st is None or st is _WAIT:
            return st
        self._claim_chain_rest(st)
        return st

    def _claim_chain_rest(self, st: NodeState) -> None:
        """Extend the picked node's claim down its chain (caller holds
        the lock).  Each suffix node gets the full claim protocol —
        RUNNING transition, inflight entry, admission reservation — so
        peers, eviction protection and rollback treat it exactly like an
        individually dispatched node."""
        chain = [st]
        cur = st
        while True:
            nxt = self._chain_next.get((st.dag.id, cur.name))
            if nxt is None:
                break
            n = st.dag.nodes[nxt]
            # WAITING only: a CACHED/EVICTED/DONE downstream truncates
            # the shipped segment; admission keeps the memory budget
            # honest even though intermediates stay worker-local
            if n.status != WAITING:
                break
            # co-shippable loader roots ride along (see _plan_chains);
            # an already-complete side dep travels as an input frame, a
            # RUNNING one (another scheduler thread owns it) blocks the
            # extension.  The DeCache would need its single-flight /
            # insert protocol per side loader, so claim only when it is
            # off — with it on, loads are cheap cache attaches anyway.
            side, ok = [], True
            for d in n.spec.deps:
                if d == cur.name:
                    continue
                ds = st.dag.nodes[d]
                if ds.status in (DONE, CACHED) and ds.output is not None:
                    continue
                if ds.status != WAITING or not ds.is_loader \
                        or self.rm.decache.enabled:
                    ok = False
                    break
                side.append(ds)
            if not ok:
                break
            group, claimed = side + [n], []
            for s in group:
                if not self.rm.admit(s):
                    break
                s.claim()
                self._inflight[(st.dag.id, s.name)] = s
                self.rm.admission.reserve(s)
                self.node_runs += 1
                claimed.append(s)
            if len(claimed) != len(group):
                # partial admission: roll the group back, truncate here
                for s in claimed:
                    self._release_claim_locked(s)
                    s.transition(WAITING)
                    self.node_runs -= 1
                break
            chain.extend(group)
            cur = n
        if len(chain) > 1:
            self._chain_claims[(st.dag.id, st.name)] = chain

    def _execute(self, st: NodeState) -> None:
        chain = self._chain_claims.pop((st.dag.id, st.name), None)
        if chain is None:
            return super()._execute(st)
        t0 = time.perf_counter()
        try:
            self._execute_chain(chain)
        except BaseException as e:
            # a chain's worker dies without saying which step killed it,
            # so quarantine must cover the whole shipped segment
            if isinstance(e, NodePoisonedError) and not e.fns:
                e.fns = [n.spec.fn for n in chain
                         if n.spec.fn is not None]
            # revert the suffix claims so a later run can redo them
            # node-by-node; the head's cleanup is the caller's normal
            # error path
            with self._cond:
                for n in chain[1:]:
                    self._release_claim_locked(n)
                    if n.status == RUNNING:
                        n.transition(WAITING)
                self._cond.notify_all()
            raise
        dt = (time.perf_counter() - t0) / len(chain)
        for n in chain:
            n.exec_latency = dt

    def _chain_echo(self, n: NodeState, is_tail: bool) -> bool:
        """Should this chain step's output cross the wire back?  Tails
        and keep_output sinks have consumers; loader outputs feed the
        DeCache; fingerprinted outputs feed the manifest (preserving the
        PR 3 hit cones).  Everything else stays worker-local."""
        if is_tail or n.spec.keep_output:
            return True
        if n.is_loader and self.rm.decache.enabled:
            return True
        return (self.rm.manifest is not None and n.fingerprint is not None
                and getattr(self.rm.cfg, "publish_outputs", True))

    def _chain_step(self, n: NodeState, is_tail: bool,
                    pos: Dict[str, int]) -> dict:
        if n.is_loader:
            return {"kind": "load", "label": n.name,
                    "source": n.spec.source,
                    "dict_columns": tuple(n.spec.dict_columns),
                    "columns": n.spec.columns,
                    "row_groups": n.spec.row_groups,
                    "reader_threads": getattr(self.rm.cfg,
                                              "reader_threads", None),
                    "echo": self._chain_echo(n, is_tail)}
        return {"kind": "exec", "label": n.name,
                "fn": self._fn_bytes(n.spec.fn),
                # value indices of this step's inputs: chain inputs
                # first, then one slot per prior step (fan-in wiring)
                "args": [pos[d] for d in n.spec.deps],
                "echo": self._chain_echo(n, is_tail)}

    def _execute_chain(self, chain: List[NodeState]) -> None:
        """Ship one claimed chain as a single exec_chain request.

        The head resolves exactly like ``_run_loader`` would (DeCache
        single-flight: wait on a peer's in-progress load, attach on hit —
        a hit strips the head from the shipped segment).  Store mutation
        (sandboxes, input export, output adoption, DeCache insert) stays
        under the RM critical section; frame encode/decode and the
        socket round-trip run outside it.  Suffix nodes complete here;
        the head completes through the caller's normal path."""
        from ..flight import wire
        head = chain[0]
        key = None
        shipped = chain
        with self._cond:
            if head.is_loader:
                k = head.decache_key()
                while k in self._loading:
                    self._cond.wait(timeout=0.1)
                e = self.rm.decache.lookup(k)
                if e is not None:
                    head.output = self.rm.decache.attach(e)
                    self._attach[head.dag.id].append(e)
                    head.output_bytes = 0
                    shipped = chain[1:]
                    ext = [(head.name, head.output)]
                else:
                    self._loading.add(k)
                    key = k
                    self.load_runs += 1
                    ext = []
            else:
                ext = [(d, head.dag.nodes[d].output)
                       for d in head.spec.deps]
            # a shipped step's dep that is not itself shipped (upstream
            # DONE/CACHED boundary) travels as an exported input frame
            names = {n.name for n in shipped}
            have = {d for d, _ in ext}
            for n in shipped:
                if n.is_loader:
                    if n is not head:
                        self.load_runs += 1
                    continue
                for d in n.spec.deps:
                    if d not in names and d not in have:
                        ext.append((d, n.dag.nodes[d].output))
                        have.add(d)
        inputs = [m for _, m in ext]
        pos = {d: i for i, (d, _) in enumerate(ext)}
        for i, n in enumerate(shipped):
            pos[n.name] = len(ext) + i
        try:
            with self._lock:
                for n in shipped:
                    n.sandbox = self._make_sandbox(n)
                fid_paths = [wire.export_paths(m, self.store)
                             for m in inputs]
            enc = [wire.encode_message(m, fid_paths=fp)
                   for m, fp in zip(inputs, fid_paths)]
            steps = [self._chain_step(n, i == len(shipped) - 1, pos)
                     for i, n in enumerate(shipped)]
            reply = self._request({"op": "exec_chain",
                                   "label": shipped[-1].name,
                                   "steps": steps, "inputs": enc})
            self._accumulate_stats(reply)
            parsed = {e["i"]: wire.parse_frame(e["msg"])
                      for e in reply["chain"]}
            with self._cond:
                for i, n in enumerate(shipped):
                    p = parsed.get(i)
                    if p is None:
                        # worker-local intermediate: never adopted, never
                        # charged — its bytes lived and died in the worker
                        n.output = None
                        n.output_bytes = 0
                        continue
                    msg = self._materialize_frame(p, n, n.sandbox)
                    n.output = msg
                    n.output_bytes = msg.new_bytes
                if key is not None and self.rm.decache.enabled and \
                        head.output is not None:
                    e = self.rm.decache.insert(key, head.output,
                                               time.perf_counter())
                    self.rm.decache.attach(e)
                    self._attach[head.dag.id].append(e)
            self.chains_shipped += 1
            self.chain_nodes_shipped += len(shipped)
        finally:
            if key is not None:
                with self._cond:
                    self._loading.discard(key)
                    self._cond.notify_all()
        for n in chain[1:]:
            self._publish_output(n)
            with self._cond:
                self._complete_locked(n)
                self._cond.notify_all()

    # -- remote execution ---------------------------------------------------
    def _request(self, obj: dict) -> dict:
        """Pool request with crash recovery: a request that dies with its
        worker (SIGKILL, OOM, socket desync) is retried with capped
        exponential backoff — requests carry only references, so a
        replay is free and side-effect-safe.  A single death retries on
        the surviving peers; once the pool thins below half strength it
        is re-grown first, so repeated deaths cannot drain it.  Past
        ``max_node_retries`` the op is declared poisoned: the pool is
        healed and :class:`NodePoisonedError` fails *this DAG only* (the
        worker loop quarantines the op's fingerprint).  In-op exceptions
        are never retried — they are deterministic."""
        from ..flight.worker import FlightWorkerLost
        cfg = self.rm.cfg
        max_retries = max(int(getattr(cfg, "max_node_retries", 3)), 1)
        backoff = float(getattr(cfg, "retry_backoff_s", 0.05))
        attempts = 0
        while True:
            try:
                return self._pool.request(obj)
            except FlightWorkerLost as e:
                attempts += 1
                if attempts > max_retries:
                    self._heal_pool()
                    raise NodePoisonedError(
                        f"op {obj.get('label', '?')!r} lost its worker "
                        f"{attempts} times ({e}); quarantining") from e
                if self._pool.live_workers < max(1, self.workers // 2):
                    self._heal_pool()
                if self._pool.live_workers == 0:
                    # respawn failed outright: nothing left to retry on
                    raise NodePoisonedError(
                        f"op {obj.get('label', '?')!r} lost its worker "
                        f"and the pool could not be re-grown") from e
                self.worker_retries += 1
                time.sleep(min(backoff * (2 ** (attempts - 1)), 1.0))

    def _heal_pool(self) -> None:
        """Re-grow the worker pool back to strength (best-effort: spawn
        failure surfaces as an empty pool at the call site, never as an
        exception replacing the one being handled)."""
        try:
            self._pool.ensure_workers()
        except Exception:
            pass

    def _accumulate_stats(self, reply: dict) -> None:
        for k, v in (reply.get("stats") or {}).items():
            self.worker_stats[k] = self.worker_stats.get(k, 0) + v

    def _materialize_frame(self, parsed, st: NodeState, sb: Sandbox):
        """Adopt a parsed worker output frame under the lock: newly
        created files are adopted with ownership and charged to the
        node's cgroup (exactly where thread-mode output bytes land), so
        admission, limitdrop and rollback treat process outputs like any
        other node output.  The byte-level parse already happened
        outside the lock (``wire.parse_frame``)."""
        from ..flight.wire import materialize_message
        msg = materialize_message(parsed, self.store, owner=sb.cgroup,
                                  adopt_owned=True, label=st.name)
        sb.owned_files.extend(
            fid for fid in msg.files_referenced()
            if fid in self.store.files and
            self.store.files[fid].owner is sb.cgroup)
        return msg

    def _adopt_reply(self, reply: dict, st: NodeState, sb: Sandbox):
        from ..flight.wire import parse_frame
        self._accumulate_stats(reply)
        parsed = parse_frame(reply["msg"])      # pure: outside the lock
        with self._lock:
            return self._materialize_frame(parsed, st, sb)

    def _compute_output(self, st: NodeState, sb: Sandbox, inputs):
        fn_bytes = self._fn_bytes(st.spec.fn)
        if fn_bytes is None:
            # run unpicklable fns in-parent (correct, just not parallel)
            self.fallback_inline += 1
            return super()._compute_output(st, sb, inputs)
        from ..flight import wire
        # store-mutating export prepass under the lock; the byte-level
        # frame encode runs outside it (pipelined dispatch: another
        # scheduler thread can hold the critical section meanwhile)
        with self._lock:
            fid_paths = [wire.export_paths(m, self.store) for m in inputs]
        enc = [wire.encode_message(m, fid_paths=fp)
               for m, fp in zip(inputs, fid_paths)]
        reply = self._request(
            {"op": "exec", "label": st.name, "fn": fn_bytes, "inputs": enc})
        return self._adopt_reply(reply, st, sb)

    def _load_output(self, st: NodeState, sb: Sandbox):
        reply = self._request(
            {"op": "load", "label": st.name, "source": st.spec.source,
             "dict_columns": tuple(st.spec.dict_columns),
             "columns": st.spec.columns,
             "row_groups": st.spec.row_groups,
             "reader_threads": getattr(self.rm.cfg, "reader_threads",
                                       None)})
        return self._adopt_reply(reply, st, sb)

    def reshare_stats(self) -> Dict[str, int]:
        out = super().reshare_stats()
        for k in out:
            out[k] += self.worker_stats.get(k, 0)
        return out

"""Scheduling priority policies (paper §3.1: 'closest-to-finishing first').

A :class:`SchedulePolicy` turns a runnable node into a sort key; the
executor runs candidates in ascending key order, tie-broken by DAG id
(older DAG first), then by node insertion order (the sort is stable).

Built-in policies:

  depth     — deepest node first: drives one DAG to completion before the
              next starts, minimizing the live intermediate set (the
              paper's default).
  breadth   — shallowest first: models concurrently-started DAGs.
  fair      — least-progressed tenant first (multi-tenant fair share):
              no tenant's DAGs run ahead while another's starve.  The
              per-tenant memory ceilings (``RMConfig.tenant_budgets``,
              enforced by the admission layer) are the other half of the
              isolation story: fair ordering shares the workers, budgets
              share the memory.
  deadline  — earliest-deadline-first over ``DAG.deadline``, depth-first
              within a DAG; deadline-less DAGs run last.

Ordering vs enforcement: a policy only *orders* candidates.  With
``RMConfig.enforce_deadlines`` on, ``DAG.deadline`` is additionally
interpreted against ``time.monotonic()`` — the executor cancels DAGs
past it and the admission layer sheds offers that are already hopeless
(see ``core/sched/admission.py``); with it off (default), deadlines
remain a pure ordering hint with no clock semantics, the seed behaviour.

Register a custom policy with :func:`register_schedule`; select it by name
via ``RMConfig(schedule=...)``.
"""

from __future__ import annotations

import math
from typing import Dict, Iterable, Tuple, Type

from ..dag import COMPLETE, DAG, NodeState

SCHEDULES: Dict[str, Type["SchedulePolicy"]] = {}


def register_schedule(cls: Type["SchedulePolicy"]) -> Type["SchedulePolicy"]:
    SCHEDULES[cls.name] = cls
    return cls


def get_schedule(name: str) -> "SchedulePolicy":
    try:
        return SCHEDULES[name]()
    except KeyError:
        raise KeyError(f"unknown schedule policy {name!r}; "
                       f"choose from {sorted(SCHEDULES)}") from None


class SchedulePolicy:
    """Priority protocol: ``prepare`` once per scheduling round, then
    ``key`` per candidate.  Lower key = higher priority."""

    name = ""

    def prepare(self, dags: Iterable[DAG]) -> None:
        """Hook to precompute per-round state (e.g. per-DAG progress)."""

    def key(self, st: NodeState) -> Tuple:
        raise NotImplementedError


@register_schedule
class DepthFirst(SchedulePolicy):
    name = "depth"

    def key(self, st: NodeState) -> Tuple:
        return (-st.depth,)


@register_schedule
class BreadthFirst(SchedulePolicy):
    name = "breadth"

    def key(self, st: NodeState) -> Tuple:
        return (st.depth,)


@register_schedule
class FairShare(SchedulePolicy):
    """Least-progressed tenant first (progress = completed-node fraction
    across the tenant's active DAGs), then deepest node.  With one DAG per
    tenant this round-robins DAGs instead of finishing them serially."""

    name = "fair"

    def __init__(self) -> None:
        self._progress: Dict[str, float] = {}

    def prepare(self, dags: Iterable[DAG]) -> None:
        done: Dict[str, int] = {}
        total: Dict[str, int] = {}
        for d in dags:
            t = d.tenant
            done[t] = done.get(t, 0) + sum(
                1 for n in d.nodes.values() if n.status in COMPLETE)
            total[t] = total.get(t, 0) + len(d.nodes)
        self._progress = {t: done[t] / max(total[t], 1) for t in total}

    def key(self, st: NodeState) -> Tuple:
        return (self._progress.get(st.dag.tenant, 0.0), -st.depth)


@register_schedule
class DeadlineAware(SchedulePolicy):
    """Earliest-deadline-first over ``DAG.deadline``, depth-first within
    a DAG.  For ordering, any monotone clock works; only when
    ``RMConfig.enforce_deadlines`` is set must deadlines be
    ``time.monotonic()`` instants, since the executor then compares them
    against that clock to cancel overdue DAGs."""

    name = "deadline"

    def key(self, st: NodeState) -> Tuple:
        dl = st.dag.deadline
        return (dl if dl is not None else math.inf, -st.depth)

"""Layered scheduler subsystem: the RM run loop split into four pluggable
components (see docs/ARCHITECTURE.md).

  policy     — scheduling priority (depth/breadth/fair/deadline, SCHEDULES)
  admission  — budget check + make-room sequencing
  eviction   — victim selection + rollback/limitdrop/adaptive (POLICIES)
  executor   — concurrent worker-pool executor (workers=1: sequential)
"""

from .admission import AdmissionController
from .eviction import (AdaptiveEviction, EvictionPolicy, KswapEviction,
                       LimitDropEviction, NoEviction, POLICIES,
                       RollbackEviction, get_eviction, register_eviction)
from .executor import (NodePoisonedError, ProcessWorkerExecutor, Ticket,
                       WorkerPoolExecutor)
from .policy import (BreadthFirst, DeadlineAware, DepthFirst, FairShare,
                     SCHEDULES, SchedulePolicy, get_schedule,
                     register_schedule)

__all__ = [
    "AdmissionController",
    "AdaptiveEviction", "EvictionPolicy", "KswapEviction",
    "LimitDropEviction", "NoEviction", "POLICIES", "RollbackEviction",
    "get_eviction", "register_eviction",
    "NodePoisonedError", "ProcessWorkerExecutor", "Ticket",
    "WorkerPoolExecutor",
    "BreadthFirst", "DeadlineAware", "DepthFirst", "FairShare",
    "SCHEDULES", "SchedulePolicy", "get_schedule", "register_schedule",
]

"""Eviction: victim selection + the rollback/limitdrop/adaptive mechanisms
(paper §3.3, Fig 3a RM:uncache / RM:rollback / RM:limitdrop).

An :class:`EvictionPolicy` owns

  * the *memory-freeing sequence*: first uncache zero-reference DeCache
    entries, then evict completed-node outputs one by one;
  * the *victim order* (shared by all mechanisms): least-progressed DAG
    first, ties broken by DAG id descending (the next DAG the scheduler
    will pick is needed soonest), deepest output first within a DAG
    ('rollback the pipeline');
  * the *mechanism* applied to a victim (``evict``), which is what the
    subclasses differ in.

Policies register themselves in :data:`POLICIES` and are selected by name
via ``RMConfig(policy=...)``.  Share-awareness: mechanisms operate on
virtual Arrow artifacts; underlying files are freed only when refcounts
hit zero (the RM's ``_gc``), so resharing never causes use-after-free.
"""

from __future__ import annotations

from typing import Dict, FrozenSet, Iterable, List, Optional, Tuple, Type

from ..dag import COMPLETE, DONE, EVICTED, NodeState

POLICIES: Dict[str, Type["EvictionPolicy"]] = {}


def register_eviction(cls: Type["EvictionPolicy"]) -> Type["EvictionPolicy"]:
    POLICIES[cls.name] = cls
    return cls


def get_eviction(name: str, rm) -> "EvictionPolicy":
    try:
        return POLICIES[name](rm)
    except KeyError:
        raise KeyError(f"unknown eviction policy {name!r}; "
                       f"choose from {sorted(POLICIES)}") from None


class EvictionPolicy:
    """Base: uncache sequencing + victim ordering.  ``rm`` is the owning
    ResourceManager (accounting: counters, completed set, refcount GC)."""

    name = ""
    evicts_outputs = True     # False: only kernel swap / nothing (baselines)

    MAX_EVICTIONS_PER_ALLOC = 8   # bound eviction storms: past this the
    #                             # node runs over budget instead of the RM
    #                             # rolling back half the fleet's progress

    def __init__(self, rm):
        self.rm = rm

    # -- the memory-freeing sequence (paper §3.3) -------------------------
    def free_memory(self, need: int, protect: Optional[NodeState] = None,
                    extra_protect: FrozenSet[Tuple[int, str]] = frozenset(),
                    ) -> int:
        rm = self.rm
        freed = 0
        # 1) uncache DeCache entries with no active references
        for e in rm.decache.uncache_candidates():
            if freed >= need:
                return freed
            freed += rm.decache.uncache(e)
            rm.evictions["uncache"] += 1
        # 2) evict outputs of the lowest-priority completed nodes.  Only
        # *productive* evictions count toward the storm bound: a victim
        # that frees nothing (already-spilled durable output, sandbox-
        # less limitdrop target) must not eat the budget and starve the
        # reclaimable victims behind it.
        if not self.evicts_outputs:
            return freed
        n_evicted = 0
        for st in self.victims(protect, extra_protect):
            if freed >= need:
                break
            if n_evicted >= self.MAX_EVICTIONS_PER_ALLOC:
                # storm bound tripped: the node runs over budget rather
                # than the RM rolling back half the fleet's progress.
                # Counted so the overload bench can prove the eviction
                # loop degraded (bounded) instead of livelocking.
                rm.evictions["storm_breaks"] += 1
                break
            got = self.evict(st)
            freed += got
            if got > 0:
                n_evicted += 1
        return freed

    # -- victim selection --------------------------------------------------
    def victims(self, protect: Optional[NodeState] = None,
                extra_protect: Iterable[Tuple[int, str]] = (),
                ) -> List[NodeState]:
        """Eviction candidates in victim order.  ``protect`` shields the
        dependencies of the node about to run; ``extra_protect`` shields
        (dag_id, node_name) pairs depended on by in-flight nodes."""
        rm = self.rm
        protected = set(extra_protect)
        if protect is not None:
            protected |= {(protect.dag.id, d) for d in protect.spec.deps}
        # keep_output nodes are excluded: their message is promised to an
        # external consumer after the run, and a rolled-back sink with no
        # un-run children would never be re-executed (data loss) — newly
        # reachable now that consumers submit multi-DAG groups per run
        cands = [st for st in rm.completed_nodes
                 if st.status in COMPLETE and st.output is not None
                 and not st.output.released
                 and not st.spec.keep_output
                 and (st.dag.id, st.name) not in protected
                 and not (st.is_loader and rm.decache.enabled)]
        # Victim order: lowest-priority = scheduled LAST.  Least-progressed
        # DAG first; ties broken by dag id DESCENDING (the scheduler picks
        # ascending ids, so the highest id is needed latest — evicting the
        # next-to-run DAG's frontier would thrash).  Within a DAG, deepest
        # output first — releasing the pipeline frontier is what actually
        # frees exclusively-owned files ('rollback the pipeline', §3.3).
        progress = {}
        for st in cands:
            d = st.dag
            if d.id not in progress:
                done = sum(1 for n in d.nodes.values()
                           if n.status in COMPLETE)
                progress[d.id] = done / max(len(d.nodes), 1)
        cands.sort(key=lambda st: (progress[st.dag.id], -st.dag.id,
                                   -st.depth))
        return cands

    # -- the mechanism -----------------------------------------------------
    def evict(self, st: NodeState) -> int:
        """Apply this policy's mechanism to one victim; return bytes freed."""
        raise NotImplementedError

    def spill(self, st: NodeState) -> int:
        """Durable outputs (published in / adopted from the manifest) are
        *spilled*: resident mappings dropped, bytes kept in the content-
        addressed objects.  The node stays in ``completed_nodes`` — a
        reader may fault the output back to resident, after which it
        must be spillable again."""
        rm = self.rm
        freed = 0
        for fid in st.output.files_referenced():
            freed += rm.store.swap_out_file(fid)
        if freed > 0:
            rm.evictions["spill"] += 1
        return freed


@register_eviction
class NoEviction(EvictionPolicy):
    """Admission only — no RM-driven eviction at all."""

    name = "none"
    evicts_outputs = False

    def evict(self, st: NodeState) -> int:
        return 0


@register_eviction
class KswapEviction(EvictionPolicy):
    """Baseline: leave memory pressure to the store's global LRU kswap."""

    name = "kswap"
    evicts_outputs = False

    def evict(self, st: NodeState) -> int:
        return 0


@register_eviction
class RollbackEviction(EvictionPolicy):
    """RM:rollback — delete a completed node's outputs; re-execute the node
    later if un-run children still need them (cascading up the pipeline if
    its own inputs were GC'd).

    Durable outputs (published in — or adopted from — the persistent
    manifest) are *spilled* instead of discarded: the resident mappings
    are dropped and the bytes stay in the content-addressed object files,
    so reclaiming memory never costs a recompute that a disk read can
    serve (counted separately in ``rm.evictions['spill']``)."""

    name = "rollback"

    def evict(self, st: NodeState) -> int:
        rm = self.rm
        if rm.is_durable(st):
            return self.spill(st)
        freed = rm._resident_of(st.output)
        msg = st.output
        st.output = None
        msg.release()
        rm._gc(msg)
        # re-execution is only scheduled if un-run children still need the
        # output (otherwise the release is pure GC; a later cascading
        # rollback can still resurrect it via the executor's dep repair)
        kids = [st.dag.nodes[c] for c in st.dag.children[st.name]]
        if any(k.status != DONE for k in kids):
            st.transition(EVICTED)
        rm.evictions["rollback"] += 1
        if st in rm.completed_nodes:
            rm.completed_nodes.remove(st)
        return freed


@register_eviction
class LimitDropEviction(EvictionPolicy):
    """RM:limitdrop — drop the node sandbox's cgroup limit so its tmpfs
    output swaps to disk; restore the limit afterwards."""

    name = "limitdrop"

    def evict(self, st: NodeState) -> int:
        rm = self.rm
        if st.sandbox is None:
            # adopted (CACHED) outputs have no sandbox; durable ones can
            # still be spilled.  Anything else is unevictable by this
            # mechanism: drop it from the candidate set so it cannot
            # clog the victim list forever.
            if rm.is_durable(st):
                return self.spill(st)
            if st in rm.completed_nodes:
                rm.completed_nodes.remove(st)
            return 0
        swapped = st.sandbox.drop_limit_and_swap()
        rm.evictions["limitdrop"] += 1
        if st in rm.completed_nodes:
            rm.completed_nodes.remove(st)   # only evict once
        return swapped


@register_eviction
class AdaptiveEviction(EvictionPolicy):
    """Pick rollback vs limit-dropping per node from the ratio of its
    execution latency to its output size (threshold ≈ 1/swap bandwidth,
    tuned offline — paper §3.3)."""

    name = "adaptive"

    def __init__(self, rm):
        super().__init__(rm)
        self._rollback = RollbackEviction(rm)
        self._limitdrop = LimitDropEviction(rm)

    def evict(self, st: NodeState) -> int:
        ratio = st.exec_latency / max(st.output_bytes, 1)
        if ratio > self.rm.cfg.adaptive_threshold:
            return self._limitdrop.evict(st)
        return self._rollback.evict(st)

"""Admission control: budget check, make-room sequencing, and the
serving-plane backpressure layer (paper §3.3, Fig 3a RM:alloc).

Admission is *non-destructive* (``admit`` only answers "does it fit right
now?"); making room is only performed for the definitively chosen node —
'outputs are evicted one by one until the available memory is larger than
the requirement of the node scheduled to run next'.  kswap/no-admission
configurations run the node anyway and let kernel swap / OOM handle the
overflow.

The serving extensions (all opt-in via ``RMConfig``) turn the controller
from a pure budget check into a backpressure valve:

  * **per-tenant budgets** (``tenant_budgets``) — reservations are also
    accounted per ``DAG.tenant``; a claim that would push a tenant past
    its ceiling is refused even when global memory is free, so one burst
    tenant cannot occupy the whole budget;
  * **bounded admission queue** (``max_queue_depth``) — ``offer`` sheds a
    DAG outright once the queue is full, with a typed
    ``"shed:overloaded"`` outcome instead of OOM-churning the eviction
    loop;
  * **overload-aware deadline shedding** — under combined queue +
    reservation pressure, a DAG whose latency ETA (node-latency EWMA x
    backlog / workers) already overshoots its deadline is shed as
    ``"shed:deadline"`` rather than admitted to miss;
  * **quarantine shedding** — DAGs carrying an op the RM has poisoned
    (see ``ProcessWorkerExecutor._request``) are shed as
    ``"shed:quarantined"``.

Shedding decision order (first match wins; see ARCHITECTURE.md for the
full table): quarantined op -> tenant budget impossible -> deadline
already expired -> queue full -> deadline hopeless under overload.
"""

from __future__ import annotations

import threading
import time
from typing import Dict, FrozenSet, Optional, Tuple

from ..dag import DAG, NodeState


class AdmissionController:
    """Budget accounting against ``RMConfig.memory_limit``; ``rm`` is the
    owning ResourceManager (store + config + eviction engine).

    In-flight nodes hold a *reservation* of their ``est_mem`` from claim
    to completion, so concurrent workers cannot co-admit nodes whose
    combined estimates exceed the budget (a reservation is conservative:
    while a node runs, its real charges and its estimate both count).
    With one worker the reservation set is always empty at admission
    time, preserving the sequential semantics exactly.

    Reservation balance is an invariant, not an assumption: ``unreserve``
    raises ``RuntimeError`` when a release would drive the global or
    per-tenant balance negative (a bare assert would vanish under
    ``python -O`` and let the books corrupt silently).
    """

    #: EWMA smoothing for the node-latency estimate behind ``eta``
    LATENCY_ALPHA = 0.3

    def __init__(self, rm):
        self.rm = rm
        self.reserved = 0            # sum of in-flight nodes' est_mem
        self.tenant_reserved: Dict[str, int] = {}
        # serving-plane state: queued DAG ids -> node count, plus the
        # node-latency EWMA feeding the shedding ETA.  Guarded by its own
        # lock — offer/finished are called from submitter threads while
        # reserve/unreserve run under the executor's RM lock.
        self._stats_lock = threading.Lock()
        self.queued: Dict[int, int] = {}
        self._latency_ewma = 0.0

    # -- reservations ------------------------------------------------------
    def reserve(self, node: NodeState) -> None:
        self.reserved += node.spec.est_mem
        t = node.dag.tenant
        self.tenant_reserved[t] = \
            self.tenant_reserved.get(t, 0) + node.spec.est_mem

    def unreserve(self, node: NodeState) -> None:
        self.reserved -= node.spec.est_mem
        if self.reserved < 0:
            raise RuntimeError("unbalanced admission reservation "
                               f"(global {self.reserved} after releasing "
                               f"{node.dag.name}.{node.name})")
        t = node.dag.tenant
        left = self.tenant_reserved.get(t, 0) - node.spec.est_mem
        if left < 0:
            raise RuntimeError("unbalanced admission reservation "
                               f"(tenant {t!r} {left} after releasing "
                               f"{node.dag.name}.{node.name})")
        if left:
            self.tenant_reserved[t] = left
        else:
            self.tenant_reserved.pop(t, None)

    def available(self) -> int:
        cfg = self.rm.cfg
        if cfg.memory_limit is None:
            return 1 << 62
        return cfg.memory_limit - self.rm.store.global_charged \
            - self.reserved

    # -- per-tenant budgets ------------------------------------------------
    def tenant_budget(self, tenant: str) -> Optional[int]:
        budgets = self.rm.cfg.tenant_budgets
        return None if budgets is None else budgets.get(tenant)

    def tenant_fits(self, node: NodeState) -> bool:
        """Would claiming ``node`` keep its tenant within budget?"""
        budget = self.tenant_budget(node.dag.tenant)
        if budget is None:
            return True
        used = self.tenant_reserved.get(node.dag.tenant, 0)
        return used + node.spec.est_mem <= budget

    def admit(self, node: NodeState) -> bool:
        """Non-destructive admission check: does the node fit right now?"""
        if not self.rm.cfg.admission:
            return True
        if not self.tenant_fits(node):
            return False
        return node.spec.est_mem <= self.available()

    def make_room_for(self, node: NodeState,
                      extra_protect: FrozenSet[Tuple[int, str]] = frozenset(),
                      ) -> None:
        """Evict outputs one by one until the chosen node fits (§3.3).
        ``extra_protect`` shields the dependencies of in-flight nodes when
        the worker pool runs concurrently."""
        cfg = self.rm.cfg
        if cfg.policy in ("none", "kswap") or not cfg.admission:
            return
        need = node.spec.est_mem - self.available()
        if need > 0:
            self.rm.eviction.free_memory(need, protect=node,
                                         extra_protect=extra_protect)

    # -- serving-plane backpressure ----------------------------------------
    def count(self, key: str, n: int = 1) -> None:
        """Bump one ``rm.serve_stats`` counter (thread-safe)."""
        with self._stats_lock:
            self.rm.serve_stats[key] = self.rm.serve_stats.get(key, 0) + n

    def note_latency(self, dt: float) -> None:
        """Fold one completed node's exec latency into the ETA EWMA."""
        if dt <= 0:
            return
        with self._stats_lock:
            a = self.LATENCY_ALPHA
            self._latency_ewma = dt if self._latency_ewma == 0 \
                else a * dt + (1 - a) * self._latency_ewma

    def pressure(self) -> float:
        """Reservation pressure: fraction of the memory budget already
        charged or reserved (0.0 with no limit configured)."""
        limit = self.rm.cfg.memory_limit
        if not limit:
            return 0.0
        return (self.rm.store.global_charged + self.reserved) / limit

    def overloaded(self) -> bool:
        """Queue depth x reservation pressure against the threshold —
        deep queue alone (work drains fine) or high memory alone (few
        big DAGs) is not overload; both together is."""
        depth = self.rm.cfg.max_queue_depth
        if depth is None:
            return False
        with self._stats_lock:
            fill = len(self.queued) / depth
        return fill * self.pressure() >= self.rm.cfg.overload_threshold

    def eta(self, dag: DAG, now: float) -> float:
        """Crude completion-time estimate for an offered DAG: backlog
        plus its own nodes, each costing the node-latency EWMA, divided
        across the worker pool.  Deliberately optimistic — shedding on an
        optimistic ETA only sheds the truly hopeless."""
        with self._stats_lock:
            backlog = sum(self.queued.values())
            ewma = self._latency_ewma
        workers = max(self.rm.cfg.workers, 1)
        return now + ewma * (backlog + len(dag.nodes)) / workers

    def offer(self, dag: DAG, now: Optional[float] = None) -> Optional[str]:
        """Serving-plane admission: accept ``dag`` into the bounded queue
        or shed it.  Returns None when admitted, else the shed reason
        (also recorded as ``dag.outcome = "shed:<reason>"``)."""
        cfg = self.rm.cfg
        if now is None:
            now = time.monotonic()
        self.count("offered")
        reason = None
        if self.rm.quarantined:
            for st in dag.nodes.values():
                if self.rm.poison_key(st.spec.fn) in self.rm.quarantined:
                    reason = "quarantined"
                    break
        if reason is None and cfg.tenant_budgets is not None:
            budget = self.tenant_budget(dag.tenant)
            if budget is not None and any(
                    st.spec.est_mem > budget for st in dag.nodes.values()):
                reason = "tenant_budget"   # can never fit, not even alone
        if reason is None and cfg.enforce_deadlines and \
                dag.deadline is not None and now >= dag.deadline:
            reason = "deadline"            # dead on arrival
        if reason is None and cfg.max_queue_depth is not None:
            with self._stats_lock:
                full = len(self.queued) >= cfg.max_queue_depth
            if full:
                reason = "overloaded"
        if reason is None and cfg.enforce_deadlines and \
                dag.deadline is not None and self.overloaded() and \
                self.eta(dag, now) > dag.deadline:
            reason = "deadline"            # hopeless under overload
        if reason is not None:
            dag.outcome = f"shed:{reason}"
            dag.cancelled = True
            self.count("shed")
            self.count(f"shed_{reason}")
            return reason
        with self._stats_lock:
            self.queued[dag.id] = len(dag.nodes)
        self.count("admitted")
        return None

    def finished(self, dag: DAG) -> None:
        """Retire a previously offered DAG from the queue accounting and
        settle its outcome counter."""
        with self._stats_lock:
            was_queued = self.queued.pop(dag.id, None) is not None
        if not was_queued:
            return
        if dag.outcome is None or dag.outcome == "completed":
            self.count("completed")
        elif dag.outcome == "deadline_miss":
            pass    # counted at cancellation time (deadline_misses)
        elif dag.outcome == "poisoned":
            pass    # counted at quarantine time (poisoned)
        elif dag.outcome.startswith("failed"):
            self.count("failed")

"""Admission control: the budget check + make-room sequencing (paper §3.3,
Fig 3a RM:alloc).

Admission is *non-destructive* (``admit`` only answers "does it fit right
now?"); making room is only performed for the definitively chosen node —
'outputs are evicted one by one until the available memory is larger than
the requirement of the node scheduled to run next'.  kswap/no-admission
configurations run the node anyway and let kernel swap / OOM handle the
overflow.
"""

from __future__ import annotations

from typing import FrozenSet, Tuple

from ..dag import NodeState


class AdmissionController:
    """Budget accounting against ``RMConfig.memory_limit``; ``rm`` is the
    owning ResourceManager (store + config + eviction engine).

    In-flight nodes hold a *reservation* of their ``est_mem`` from claim
    to completion, so concurrent workers cannot co-admit nodes whose
    combined estimates exceed the budget (a reservation is conservative:
    while a node runs, its real charges and its estimate both count).
    With one worker the reservation set is always empty at admission
    time, preserving the sequential semantics exactly.
    """

    def __init__(self, rm):
        self.rm = rm
        self.reserved = 0            # sum of in-flight nodes' est_mem

    def reserve(self, node: NodeState) -> None:
        self.reserved += node.spec.est_mem

    def unreserve(self, node: NodeState) -> None:
        self.reserved -= node.spec.est_mem
        assert self.reserved >= 0, "unbalanced admission reservation"

    def available(self) -> int:
        cfg = self.rm.cfg
        if cfg.memory_limit is None:
            return 1 << 62
        return cfg.memory_limit - self.rm.store.global_charged \
            - self.reserved

    def admit(self, node: NodeState) -> bool:
        """Non-destructive admission check: does the node fit right now?"""
        if not self.rm.cfg.admission:
            return True
        return node.spec.est_mem <= self.available()

    def make_room_for(self, node: NodeState,
                      extra_protect: FrozenSet[Tuple[int, str]] = frozenset(),
                      ) -> None:
        """Evict outputs one by one until the chosen node fits (§3.3).
        ``extra_protect`` shields the dependencies of in-flight nodes when
        the worker pool runs concurrently."""
        cfg = self.rm.cfg
        if cfg.policy in ("none", "kswap") or not cfg.admission:
            return
        need = node.spec.est_mem - self.available()
        if need > 0:
            self.rm.eviction.free_memory(need, protect=node,
                                         extra_protect=extra_protect)

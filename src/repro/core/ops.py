"""Table transformations — the 'user code' run inside DAG nodes.

Each op is written the way an Arrow-ecosystem library would write it:
it computes over input buffers and returns a Table whose buffers are,
wherever the semantics allow, *views* of the input buffers.  Whether those
views are reshared (references) or copied is decided downstream by SIPC's
IPC inspection — the op itself is unmodified, ordinary code (Goal G5).

Op classes, matching paper Fig 6:
  subtractive: drop_columns / select_columns, slice_rows  -> pure views
  additive:    add_column, concat_tables                  -> new data only
  fine-grained: filter_rows, sort_by                      -> copies, except
               dictionaries (dictionary sharing) and reshare-friendly cases
  rewriting:   upper (UTF-8 changes byte lengths; ASCII fast path can
               reshare offsets — the paper's UTF-16 observation, applied)
"""

from __future__ import annotations

from typing import Callable, List, Optional, Sequence, Union

import numpy as np

from . import vkernels
from .arrow import (Column, Field, RecordBatch, Schema, Table, UTF8,
                    pack_validity, type_for_np)

# --------------------------------------------------------------------------
# subtractive ops (pure views)
# --------------------------------------------------------------------------

def select_columns(table: Table, names: Sequence[str]) -> Table:
    idx = [table.schema.index(n) for n in names]
    schema = Schema([table.schema.fields[i] for i in idx])
    return Table([RecordBatch(schema, [b.columns[i] for i in idx])
                  for b in table.batches])


def drop_columns(table: Table, names: Sequence[str]) -> Table:
    keep = [n for n in table.schema.names() if n not in set(names)]
    return Table(select_columns(table, keep).batches)


def slice_rows(table: Table, start: int, stop: int) -> Table:
    """Row-slice across batches: every buffer is a view."""
    out = []
    pos = 0
    for b in table.batches:
        lo = max(start - pos, 0)
        hi = min(stop - pos, b.num_rows)
        if lo < hi:
            out.append(RecordBatch(b.schema,
                                   [c.slice(lo, hi) for c in b.columns]))
        pos += b.num_rows
    if not out:
        out = [RecordBatch(table.schema,
                           [c.slice(0, 0) for c in table.batches[0].columns])]
    return Table(out)


# --------------------------------------------------------------------------
# additive ops (new data only)
# --------------------------------------------------------------------------

def add_column(table: Table, name: str, column: Union[Column, np.ndarray]
               ) -> Table:
    """Append a column; existing columns pass through by reference."""
    if isinstance(column, np.ndarray):
        column = Column.primitive(column)
    assert column.length == table.num_rows
    schema = Schema(list(table.schema.fields) + [Field(name, column.type)])
    out, pos = [], 0
    for b in table.batches:
        piece = column.slice(pos, pos + b.num_rows)
        out.append(RecordBatch(schema, list(b.columns) + [piece]))
        pos += b.num_rows
    return Table(out)


def concat_tables(tables: Sequence[Table]) -> Table:
    """Row-concatenation = batch concatenation: zero new data."""
    schema = tables[0].schema
    batches: List[RecordBatch] = []
    for t in tables:
        assert t.schema.equals(schema)
        batches.extend(t.batches)
    return Table(batches)


# --------------------------------------------------------------------------
# fine-grained-overlap ops (row granularity)
# --------------------------------------------------------------------------

def take(table: Table, indices: np.ndarray) -> Table:
    """Global row gather; materializes (except dictionaries)."""
    t = table.combine()
    b = t.batches[0]
    return Table.from_batch(t.schema, [c.take(indices) for c in b.columns])


def filter_rows(table: Table, mask: Union[np.ndarray, Callable[[RecordBatch], np.ndarray]]
                ) -> Table:
    """Keep rows where mask is True.  Per-batch: codes/values copied,
    dictionaries ride through by reference (dictionary sharing)."""
    out, pos = [], 0
    for b in table.batches:
        m = mask(b) if callable(mask) else np.asarray(mask[pos:pos + b.num_rows])
        idx = np.nonzero(m)[0]
        out.append(RecordBatch(b.schema, [c.take(idx) for c in b.columns]))
        pos += b.num_rows
    return Table(out)


def sort_by(table: Table, name: str, descending: bool = False) -> Table:
    t = table.combine()
    col = t.batches[0].column(name)
    if col.type.is_utf8:
        # direct stable bytes sort replaces the per-row bytes-object keys
        order = vkernels.sort_order_var(col.offsets, col.values)
    elif col.type.is_dict and col.dictionary.type.is_utf8:
        d = col.dictionary
        rank = vkernels.sort_keys_var(d.offsets, d.values)
        order = np.argsort(rank[col.values], kind="stable")
    else:
        order = np.argsort(col._logical(), kind="stable")
    if descending:
        order = order[::-1].copy()
    return take(t, order)


# --------------------------------------------------------------------------
# rewriting op: upper-case (paper §5.3's counter-example)
# --------------------------------------------------------------------------

def upper(table: Table, name: str, assume_ascii: Optional[bool] = None) -> Table:
    """Upper-case a utf8 column.

    General UTF-8 path: byte lengths may change ('ß' -> 'SS'), so both the
    values *and offsets* buffers are new — no resharing possible (paper).
    ASCII fast path (beyond-paper): if all bytes < 0x80, lengths are
    preserved; the offsets buffer passes through as a view and becomes
    reshareable — the paper's UTF-16 observation realized for ASCII UTF-8.
    """
    j = table.schema.index(name)
    out = []
    for b in table.batches:
        col = b.column(name)
        assert col.type.is_utf8
        lo, hi = int(col.offsets[0]), int(col.offsets[-1])
        window = col.values[lo:hi]
        ascii_ok = assume_ascii if assume_ascii is not None \
            else (window.size == 0 or int(window.max()) < 0x80)
        if ascii_ok:
            vals = window.copy()
            lower = (vals >= 0x61) & (vals <= 0x7A)
            vals[lower] -= 0x20
            if lo == 0 and hi == col.values.nbytes:
                new = Column(UTF8, col.length, vals, offsets=col.offsets,
                             validity=col.validity)   # offsets reshared!
            else:
                new = Column(UTF8, col.length, vals,
                             offsets=col.offsets - lo, validity=col.validity)
        else:
            new_off, vals = vkernels.upper_var(col.offsets, col.values)
            new = Column.utf8(new_off, vals, validity=col.validity)
        cols = list(b.columns)
        cols[j] = new
        out.append(RecordBatch(b.schema, cols))
    return Table(out)


# --------------------------------------------------------------------------
# compute helpers used by the paper's workloads
# --------------------------------------------------------------------------

def sum_all_ints(table: Table) -> int:
    """Reader-node workload of paper Fig 2."""
    total = 0
    for b in table.batches:
        for c in b.columns:
            if c.type.is_primitive and np.issubdtype(np.dtype(c.type.np_dtype),
                                                     np.integer):
                total += int(c.values.sum())
    return total


def add_columns_compute(table: Table, a: str, b: str, out_name: str,
                        repeat: int = 1) -> Table:
    """The Fig 7/10 'column-adding function': out = f(col_a, col_b) with a
    tunable amount of compute (``repeat`` additions)."""
    t0 = table.combine()
    ca = t0.batches[0].column(a).to_numpy()
    cb = t0.batches[0].column(b).to_numpy()
    acc = ca + cb
    for _ in range(repeat - 1):
        acc = acc + cb
    return add_column(table, out_name, Column.primitive(acc))


def dict_encode(table: Table, names: Sequence[str]) -> Table:
    """Dictionary-encode utf8 columns (what read_dictionary does at load)."""
    name_set = set(names)
    out = []
    for b in table.batches:
        cols = []
        for f, c in zip(b.schema.fields, b.columns):
            if f.name in name_set and c.type.is_utf8:
                codes, uoff, uvals = vkernels.dict_encode_var(c.offsets,
                                                              c.values)
                dic = Column.utf8(uoff, uvals)
                c = Column.dictionary_encoded(codes, dic,
                                              validity=c.validity)
            cols.append(c)
        schema = Schema([Field(f.name, c.type)
                         for f, c in zip(b.schema.fields, cols)])
        out.append(RecordBatch(schema, cols))
    return Table(out)

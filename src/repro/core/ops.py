"""Table transformations — the 'user code' run inside DAG nodes.

Each op is written the way an Arrow-ecosystem library would write it:
it computes over input buffers and returns a Table whose buffers are,
wherever the semantics allow, *views* of the input buffers.  Whether those
views are reshared (references) or copied is decided downstream by SIPC's
IPC inspection — the op itself is unmodified, ordinary code (Goal G5).

Every public op, with its one-line contract (classes match paper Fig 6):

Subtractive (pure views — zero new bytes):
  ``select_columns(t, names)``   keep the named columns, by reference.
  ``drop_columns(t, names)``     drop the named columns; rest by reference.
  ``slice_rows(t, start, stop)`` row-slice across batches; every buffer a
      view (utf8 offsets need not start at zero).

Additive (new data only — inputs ride through by reference):
  ``add_column(t, name, col)``   append one column.
  ``concat_tables(ts)``          row concat == batch concat; no new bytes.

Fine-grained (row granularity — codes/values copy, dictionaries reshare):
  ``take(t, idx)``               global row gather (dictionary sharing).
  ``filter_rows(t, mask)``       keep mask-true rows, per batch.
  ``sort_by(t, name, descending=False)``  stable sort by one column
      (vectorized bytes sort for utf8; dict ranks for dict-of-utf8).

Rewriting:
  ``upper(t, name)``             utf8 upper-case; the ASCII fast path
      reshares the offsets buffer (the paper's UTF-16 observation).
  ``dict_encode(t, names)``      dictionary-encode utf8 columns.

Relational (reshuffle rows across tables — hash-join engine, PR 5):
  ``join(left, right, on, how='inner'|'left')``  multi-key hash
      equi-join; null keys never match (SQL); output is left-major with
      build matches ascending; payload dictionaries reshare by reference.
  ``group_by(t, keys, aggs)``    hash-free exact group-by (dense key
      codes + segment reducers): one row per distinct key tuple (nulls
      form one group, sorted last), aggs from sum/min/max/count/mean.
  ``filter_join(left, right, on, how, left_mask=, right_mask=)``  fused
      filter->join: per-side row masks compose into the join's
      take-gather (one gather over the original columns, no
      materialized filtered table); bit-identical to the unfused pair.

Compute helpers (paper workloads):
  ``sum_all_ints(t)``            Fig 2 reader-node reduction.
  ``add_columns_compute(t, a, b, out, repeat=1)``  Fig 7/10 column math.

These ops are also the lowering targets of the declarative query
frontend (``core/plan/``): its compiler emits ``select_columns`` /
``filter_rows`` / ``sort_by`` / ``slice_rows`` / ``join_node`` /
``group_by_node`` nodes, and its filter->join fusion rule rewrites
filter-under-join trees onto ``filter_join`` — so a plan-built DAG and a
hand-wired one exercise the identical op (and fingerprint) surface.
"""

from __future__ import annotations

from typing import Callable, Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from . import kdispatch as kd
from . import vkernels
from .arrow import (Column, Field, RecordBatch, Schema, Table, UTF8,
                    pack_validity, type_for_np)

# --------------------------------------------------------------------------
# subtractive ops (pure views)
# --------------------------------------------------------------------------

def select_columns(table: Table, names: Sequence[str]) -> Table:
    idx = [table.schema.index(n) for n in names]
    schema = Schema([table.schema.fields[i] for i in idx])
    return Table([RecordBatch(schema, [b.columns[i] for i in idx])
                  for b in table.batches])


def drop_columns(table: Table, names: Sequence[str]) -> Table:
    keep = [n for n in table.schema.names() if n not in set(names)]
    return Table(select_columns(table, keep).batches)


def slice_rows(table: Table, start: int, stop: int) -> Table:
    """Row-slice across batches: every buffer is a view."""
    out = []
    pos = 0
    for b in table.batches:
        lo = max(start - pos, 0)
        hi = min(stop - pos, b.num_rows)
        if lo < hi:
            out.append(RecordBatch(b.schema,
                                   [c.slice(lo, hi) for c in b.columns]))
        pos += b.num_rows
    if not out:
        out = [RecordBatch(table.schema,
                           [c.slice(0, 0) for c in table.batches[0].columns])]
    return Table(out)


# --------------------------------------------------------------------------
# additive ops (new data only)
# --------------------------------------------------------------------------

def add_column(table: Table, name: str, column: Union[Column, np.ndarray]
               ) -> Table:
    """Append a column; existing columns pass through by reference."""
    if isinstance(column, np.ndarray):
        column = Column.primitive(column)
    assert column.length == table.num_rows
    schema = Schema(list(table.schema.fields) + [Field(name, column.type)])
    out, pos = [], 0
    for b in table.batches:
        piece = column.slice(pos, pos + b.num_rows)
        out.append(RecordBatch(schema, list(b.columns) + [piece]))
        pos += b.num_rows
    return Table(out)


def concat_tables(tables: Sequence[Table]) -> Table:
    """Row-concatenation = batch concatenation: zero new data."""
    schema = tables[0].schema
    batches: List[RecordBatch] = []
    for t in tables:
        assert t.schema.equals(schema)
        batches.extend(t.batches)
    return Table(batches)


# --------------------------------------------------------------------------
# fine-grained-overlap ops (row granularity)
# --------------------------------------------------------------------------

def take(table: Table, indices: np.ndarray) -> Table:
    """Global row gather; materializes (except dictionaries)."""
    t = table.combine()
    b = t.batches[0]
    return Table.from_batch(t.schema, [c.take(indices) for c in b.columns])


def filter_rows(table: Table, mask: Union[np.ndarray, Callable[[RecordBatch], np.ndarray]]
                ) -> Table:
    """Keep rows where mask is True.  Per-batch: codes/values copied,
    dictionaries ride through by reference (dictionary sharing)."""
    out, pos = [], 0
    for b in table.batches:
        m = mask(b) if callable(mask) else np.asarray(mask[pos:pos + b.num_rows])
        idx = np.nonzero(m)[0]
        out.append(RecordBatch(b.schema, [c.take(idx) for c in b.columns]))
        pos += b.num_rows
    return Table(out)


def sort_by(table: Table, name: str, descending: bool = False) -> Table:
    t = table.combine()
    col = t.batches[0].column(name)
    if col.type.is_utf8:
        # direct stable bytes sort replaces the per-row bytes-object keys
        order = vkernels.sort_order_var(col.offsets, col.values)
    elif col.type.is_dict and col.dictionary.type.is_utf8:
        d = col.dictionary
        rank = vkernels.sort_keys_var(d.offsets, d.values)
        order = np.argsort(rank[col.values], kind="stable")
    else:
        order = np.argsort(col._logical(), kind="stable")
    if descending:
        order = order[::-1].copy()
    return take(t, order)


# --------------------------------------------------------------------------
# rewriting op: upper-case (paper §5.3's counter-example)
# --------------------------------------------------------------------------

def upper(table: Table, name: str, assume_ascii: Optional[bool] = None) -> Table:
    """Upper-case a utf8 column.

    General UTF-8 path: byte lengths may change ('ß' -> 'SS'), so both the
    values *and offsets* buffers are new — no resharing possible (paper).
    ASCII fast path (beyond-paper): if all bytes < 0x80, lengths are
    preserved; the offsets buffer passes through as a view and becomes
    reshareable — the paper's UTF-16 observation realized for ASCII UTF-8.
    """
    j = table.schema.index(name)
    out = []
    for b in table.batches:
        col = b.column(name)
        assert col.type.is_utf8
        lo, hi = int(col.offsets[0]), int(col.offsets[-1])
        window = col.values[lo:hi]
        ascii_ok = assume_ascii if assume_ascii is not None \
            else (window.size == 0 or int(window.max()) < 0x80)
        if ascii_ok:
            vals = window.copy()
            lower = (vals >= 0x61) & (vals <= 0x7A)
            vals[lower] -= 0x20
            if lo == 0 and hi == col.values.nbytes:
                new = Column(UTF8, col.length, vals, offsets=col.offsets,
                             validity=col.validity)   # offsets reshared!
            else:
                new = Column(UTF8, col.length, vals,
                             offsets=col.offsets - lo, validity=col.validity)
        else:
            new_off, vals = vkernels.upper_var(col.offsets, col.values)
            new = Column.utf8(new_off, vals, validity=col.validity)
        cols = list(b.columns)
        cols[j] = new
        out.append(RecordBatch(b.schema, cols))
    return Table(out)


# --------------------------------------------------------------------------
# relational ops: hash join + group-by (reshuffle rows across tables)
# --------------------------------------------------------------------------

def _key_hashes(batch: RecordBatch, keys: Sequence[str],
                cast: Dict[str, np.dtype]):
    """(uint64 row hashes, all-keys-valid mask) for one table's key
    columns.  Hashes depend only on logical values, never representation:
    a dict-of-utf8 key hashes its dictionary once and scatters through
    the codes, landing on exactly ``hash_var`` of the decoded rows, so
    it matches a plain utf8 key on the other side; primitive keys hash
    through the two sides' common dtype (``cast``), so an int64 -1
    matches an int32 -1; float zeros are canonicalized inside the
    kernels."""
    n = batch.num_rows
    parts: List[np.ndarray] = []
    valid = np.ones(n, dtype=bool)
    for name in keys:
        c = batch.column(name)
        valid &= c.valid_mask()
        if c.type.is_utf8:
            parts.append(vkernels.hash_var(c.offsets, c.values))
        elif c.type.is_dict:
            d = c.dictionary
            hd = vkernels.hash_var(d.offsets, d.values) \
                if d.type.is_utf8 else kd.hash_fixed(
                    d.values.astype(cast[name], copy=False))
            parts.append(hd[c.values])
        else:
            parts.append(kd.hash_fixed(
                c.values.astype(cast[name], copy=False)))
    return kd.combine_hashes(parts, n), valid


def _key_cast_map(lb: RecordBatch, rb: RecordBatch,
                  keys: Sequence[str]) -> Dict[str, np.dtype]:
    """Common hash dtype per primitive-kind key column: both sides hash
    through ``np.result_type`` of their logical dtypes, so bit patterns
    agree whenever ``==`` would.  Mixed int64/uint64 hashes through
    float64 — complete for candidate generation (equal integers cast to
    the same float), with float-rounding collisions filtered by the
    exact-integer confirm in ``_key_pairs_equal``.  Joining a utf8-kind
    key against a primitive-kind key is a type error, not an empty
    result."""
    def prim_dtype(c: Column) -> np.dtype:
        t = c.type.value_type if c.type.is_dict else c.type
        return np.dtype(t.np_dtype)

    cast: Dict[str, np.dtype] = {}
    for name in keys:
        lc, rc = lb.column(name), rb.column(name)
        if lc._kindof() != rc._kindof():
            raise TypeError(f"join key {name!r}: {lc._kindof()} vs "
                            f"{rc._kindof()} columns")
        if lc._kindof() == "prim":
            cast[name] = np.result_type(prim_dtype(lc), prim_dtype(rc))
    return cast


def _exact_int_equal(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """Exact elementwise == for integer arrays numpy would promote to
    float64 (int64 vs uint64): a negative signed value never equals any
    unsigned value; the rest compare as uint64 with no precision loss."""
    ok = np.ones(len(a), dtype=bool)
    if np.issubdtype(a.dtype, np.signedinteger):
        ok &= a >= 0
    if np.issubdtype(b.dtype, np.signedinteger):
        ok &= b >= 0
    return ok & (a.astype(np.uint64) == b.astype(np.uint64))


def _key_pairs_equal(lcol: Column, li: np.ndarray,
                     rcol: Column, ri: np.ndarray) -> np.ndarray:
    """Confirm candidate pairs: bool per pair, left row li[p] == right
    row ri[p] on this key column (the hash-collision filter)."""
    if lcol._kindof() == "utf8":
        off_a, val_a = lcol._logical_var(li)
        off_b, val_b = rcol._logical_var(ri)
        return vkernels.bytes_rows_equal(off_a, val_a, off_b, val_b)
    a, b = lcol._logical()[li], rcol._logical()[ri]
    if (np.issubdtype(a.dtype, np.integer)
            and np.issubdtype(b.dtype, np.integer)
            and not np.issubdtype(np.result_type(a, b), np.integer)):
        return _exact_int_equal(a, b)
    return a == b


def _join_gather_indices(lb: RecordBatch, rb: RecordBatch,
                         keys: Sequence[str], how: str,
                         lmask: Optional[np.ndarray] = None,
                         rmask: Optional[np.ndarray] = None
                         ) -> Tuple[np.ndarray, np.ndarray]:
    """(left, right) original-domain gather index arrays for the join
    output rows (``-1`` right index = left-join miss).  ``lmask`` /
    ``rmask`` restrict each side to mask-true rows — the fused
    filter->join path: the selection composes into the probe/build
    subsets (via ``vkernels.filter_join_gather``), so the caller gathers
    payload columns exactly once from the *unfiltered* batches."""
    cast = _key_cast_map(lb, rb, keys)
    lh, lvalid = _key_hashes(lb, keys, cast)
    rh, rvalid = _key_hashes(rb, keys, cast)
    if lmask is not None:
        lvalid &= lmask
    if rmask is not None:
        rvalid &= rmask
    # null keys never match: probe/build over the valid-key subsets only
    pidx = np.nonzero(lvalid)[0]
    bidx = np.nonzero(rvalid)[0]
    pi, bi = vkernels.hash_join_probe(rh[bidx], lh[pidx])
    li = kd.filter_join_gather(pidx, pi)
    ri = kd.filter_join_gather(bidx, bi)
    keep = np.ones(len(li), dtype=bool)
    for k in keys:
        keep &= _key_pairs_equal(lb.column(k), li, rb.column(k), ri)
    li, ri = li[keep], ri[keep]
    if how == "left":
        matched = np.zeros(lb.num_rows, dtype=bool)
        matched[li] = True
        cand = ~matched if lmask is None else lmask & ~matched
        miss = np.nonzero(cand)[0]
        li = np.concatenate([li, miss])
        ri = np.concatenate([ri, np.full(len(miss), -1, dtype=np.int64)])
        order = np.argsort(li, kind="stable")   # restore left-major order
        li, ri = li[order], ri[order]
    return li, ri


def _join_output(lb: RecordBatch, rb: RecordBatch, keys: Sequence[str],
                 li: np.ndarray, ri: np.ndarray, suffix: str) -> Table:
    """Assemble the join output: one gather per left column, one
    nullable gather per right payload column."""
    fields: List[Field] = []
    cols: List[Column] = []
    rkeys = set(keys)
    lnames = set(lb.schema.names())
    for f, c in zip(lb.schema.fields, lb.columns):
        fields.append(f)
        cols.append(c.take(li))
    used = set(lnames)
    for f, c in zip(rb.schema.fields, rb.columns):
        if f.name in rkeys:
            continue                 # equal to the left key by definition
        name = f.name + suffix if f.name in lnames else f.name
        if name in used:
            raise ValueError(
                f"join output column {name!r} is ambiguous (suffixed "
                f"right column collides with an existing column); rename "
                f"it or pass a different suffix")
        used.add(name)
        fields.append(Field(name, c.type))
        cols.append(c.take_nullable(ri))
    return Table.from_batch(Schema(fields), cols)


def join(left: Table, right: Table, on: Union[str, Sequence[str]],
         how: str = "inner", suffix: str = "_right") -> Table:
    """Multi-key hash equi-join (probe = left, build = right).

    ``on`` names key columns present in both tables (same logical kind:
    utf8 and dict-of-utf8 mix freely; primitives must compare with
    ``==``, except that mixed signed/unsigned 64-bit integer keys are
    compared *exactly* — numpy's float64 promotion would conflate
    distinct integers beyond 2**53).  Null keys never match (SQL
    semantics): inner drops them,
    left preserves the row with all-null right payloads.  Output rows
    are left-major (left row order preserved) with matching right rows
    ascending; columns are the left table's, then right's non-key
    columns (name collisions get ``suffix``; a name that still collides
    after suffixing raises ``ValueError`` rather than emitting a
    duplicate field).  Left payloads are
    take-gathers, right payloads nullable take-gathers — dictionary
    buffers of dict-encoded payloads pass through by reference, so SIPC
    reshares them on the output (no re-deanonymization).
    """
    assert how in ("inner", "left"), how
    keys = [on] if isinstance(on, str) else list(on)
    lb = left.combine().batches[0]
    rb = right.combine().batches[0]
    li, ri = _join_gather_indices(lb, rb, keys, how)
    return _join_output(lb, rb, keys, li, ri, suffix)


#: a mask for one side of a fused filter->join: a bool/int array over the
#: (combined) batch rows, or a callable evaluated on the combined batch
MaskLike = Union[np.ndarray, Callable[[RecordBatch], np.ndarray]]


def _resolve_mask(mask: MaskLike, batch: RecordBatch) -> np.ndarray:
    m = np.asarray(mask(batch) if callable(mask) else mask)
    if m.dtype != np.bool_:
        m = m != 0
    assert len(m) == batch.num_rows, \
        f"mask length {len(m)} != batch rows {batch.num_rows}"
    return m


def filter_join(left: Table, right: Table, on: Union[str, Sequence[str]],
                how: str = "inner", suffix: str = "_right",
                left_mask: Optional[MaskLike] = None,
                right_mask: Optional[MaskLike] = None) -> Table:
    """Fused filter->join: ``join(filter_rows(left, left_mask),
    filter_rows(right, right_mask), on, how)`` without materializing
    either filtered intermediate table.

    The masks compose into the join's probe/build row selection
    (``vkernels.filter_join_gather``), so payload columns are gathered
    exactly *once* from the original batches — the unfused pair gathers
    the filtered side twice (filter take + join take) and pays the
    intermediate's allocation, deanonymization and (in process mode)
    wire hop.  Output is bit-identical to the unfused pair: same rows,
    same left-major order, same buffers.  A mask may be an array over
    the side's combined rows or a picklable callable evaluated on the
    combined batch (so a ``functools.partial`` of this op crosses the
    Flight process boundary)."""
    assert how in ("inner", "left"), how
    keys = [on] if isinstance(on, str) else list(on)
    lb = left.combine().batches[0]
    rb = right.combine().batches[0]
    lm = None if left_mask is None else _resolve_mask(left_mask, lb)
    rm = None if right_mask is None else _resolve_mask(right_mask, rb)
    li, ri = _join_gather_indices(lb, rb, keys, how, lmask=lm, rmask=rm)
    return _join_output(lb, rb, keys, li, ri, suffix)


def _group_codes(col: Column) -> np.ndarray:
    """Dense int64 group codes for one key column: equal logical rows
    share a code, codes ascend in value order (bytes order for utf8),
    float NaNs collapse into one group after the real values, and null
    rows share the single largest code (SQL: nulls group together)."""
    valid = col.valid_mask()
    if col._kindof() == "utf8":
        if col.type.is_dict:
            d = col.dictionary
            ranks = vkernels.sort_keys_var(d.offsets,
                                           d.values).astype(np.int64)
            codes = ranks[col.values.astype(np.int64)] \
                if col.length else np.empty(0, np.int64)
            ncodes = int(ranks.max(initial=-1)) + 1
        else:
            c32, uoff, _ = vkernels.dict_encode_var(col.offsets, col.values)
            codes, ncodes = c32.astype(np.int64), len(uoff) - 1
    else:
        v = col._logical()
        nan = None
        if np.issubdtype(v.dtype, np.floating):
            nan = np.isnan(v)
            v = np.where(nan | (v == 0), 0, v)   # -0.0 == +0.0; NaN later
        uniq, inv = np.unique(v, return_inverse=True)
        codes, ncodes = inv.astype(np.int64).reshape(-1), len(uniq)
        if nan is not None and nan.any():
            codes = np.where(nan, ncodes, codes)
            ncodes += 1
    if not valid.all():
        codes = np.where(valid, codes, ncodes)
    return codes


#: agg spec: {out_name: (column_name, how)} with how one of
#: vkernels.GROUPED_REDUCERS — 'sum', 'min', 'max', 'count', 'mean'
AggSpec = Dict[str, Tuple[str, str]]


def group_by(table: Table, keys: Union[str, Sequence[str]],
             aggs: AggSpec) -> Table:
    """Group by key columns and reduce payload columns.

    Exact (no hashing): per-key dense codes + one lexsort find the
    groups, segment reducers aggregate.  One output row per distinct key
    tuple, sorted by key values ascending (float NaNs after real values,
    the null group last); key columns come first (dictionary-encoded
    keys keep their dictionary by reference), then one column per agg in
    ``aggs`` order.  Nulls are excluded from every aggregate; a group
    whose payload is all-null aggregates to null (count: 0).
    """
    keys = [keys] if isinstance(keys, str) else list(keys)
    clash = [n for n in aggs if n in keys]
    if clash:
        raise ValueError(f"agg output name(s) {clash} collide with key "
                         f"column(s); pick a different out_name")
    b = table.combine().batches[0]
    order, starts = vkernels.group_ranges(
        [_group_codes(b.column(k)) for k in keys])
    reps = order[starts]
    fields: List[Field] = []
    cols: List[Column] = []
    for k in keys:
        c = b.column(k).take(reps)
        fields.append(Field(k, c.type))
        cols.append(c)
    for out_name, (col_name, how) in aggs.items():
        reducer = kd.GROUPED_REDUCERS[how]
        c = b.column(col_name)
        if how == "count":
            v = np.empty(c.length, dtype=np.int64)    # values unused
        else:
            assert c._kindof() == "prim", \
                f"{how}({col_name}): non-numeric column"
            v = c._logical()
        valid = None if c.validity is None else c.valid_mask()
        vals, counts = reducer(v, order, starts, valid)
        if how in ("min", "max") and v.dtype == np.bool_:
            vals = vals.astype(bool)
        validity = None
        if how != "count" and (counts == 0).any():
            validity = pack_validity(counts > 0)      # all-null group
        fields.append(Field(out_name, type_for_np(vals.dtype)))
        cols.append(Column.primitive(vals, validity=validity))
    return Table.from_batch(Schema(fields), cols)


def join_node(tables: Sequence[Table], on, how: str = "inner",
              suffix: str = "_right") -> Table:
    """DAG-node form of ``join``: ``tables == [left, right]``.  Module-
    level so a ``functools.partial`` over it pickles across the Flight
    process boundary and fingerprints deterministically."""
    return join(tables[0], tables[1], on=on, how=how, suffix=suffix)


def group_by_node(tables: Sequence[Table], keys, aggs: AggSpec) -> Table:
    """DAG-node form of ``group_by`` (see ``join_node``)."""
    return group_by(tables[0], keys, aggs)


def filter_join_node(tables: Sequence[Table], on, how: str = "inner",
                     suffix: str = "_right",
                     left_mask: Optional[MaskLike] = None,
                     right_mask: Optional[MaskLike] = None) -> Table:
    """DAG-node form of ``filter_join`` (see ``join_node``)."""
    return filter_join(tables[0], tables[1], on=on, how=how, suffix=suffix,
                       left_mask=left_mask, right_mask=right_mask)


#: the relational ops reach their kernels through module attributes,
#: which the fingerprint's direct-global scan does not chase; declaring
#: them here makes a kernel edit invalidate every cached join/group-by
#: output (differential reruns recompute the affected side).  The
#: declarations are *callables* resolved at fingerprint time by
#: ``kdispatch``: they always fold in the numpy vkernels and, when
#: ``ZERROW_KERNEL_BACKEND=pallas`` is active, additionally the backend
#: tag + live Pallas kernels — so flipping the backend or editing a
#: Pallas kernel invalidates exactly these cones too
join.__fp_includes__ = kd.fp_includes_join
group_by.__fp_includes__ = kd.fp_includes_group_by
join_node.__fp_includes__ = join.__fp_includes__
group_by_node.__fp_includes__ = group_by.__fp_includes__
#: fused and unfused plans fingerprint distinctly: filter_join's own code
#: object differs from join's, and both fold in filter_join_gather so a
#: fusion-kernel edit invalidates fused *and* unfused cached outputs
filter_join.__fp_includes__ = join.__fp_includes__
filter_join_node.__fp_includes__ = join.__fp_includes__


# --------------------------------------------------------------------------
# compute helpers used by the paper's workloads
# --------------------------------------------------------------------------

def sum_all_ints(table: Table) -> int:
    """Reader-node workload of paper Fig 2."""
    total = 0
    for b in table.batches:
        for c in b.columns:
            if c.type.is_primitive and np.issubdtype(np.dtype(c.type.np_dtype),
                                                     np.integer):
                total += int(c.values.sum())
    return total


def add_columns_compute(table: Table, a: str, b: str, out_name: str,
                        repeat: int = 1) -> Table:
    """The Fig 7/10 'column-adding function': out = f(col_a, col_b) with a
    tunable amount of compute (``repeat`` additions)."""
    t0 = table.combine()
    ca = t0.batches[0].column(a).to_numpy()
    cb = t0.batches[0].column(b).to_numpy()
    acc = ca + cb
    for _ in range(repeat - 1):
        acc = acc + cb
    return add_column(table, out_name, Column.primitive(acc))


def dict_encode(table: Table, names: Sequence[str]) -> Table:
    """Dictionary-encode utf8 columns (what read_dictionary does at load)."""
    name_set = set(names)
    out = []
    for b in table.batches:
        cols = []
        for f, c in zip(b.schema.fields, b.columns):
            if f.name in name_set and c.type.is_utf8:
                codes, uoff, uvals = vkernels.dict_encode_var(c.offsets,
                                                              c.values)
                dic = Column.utf8(uoff, uvals)
                c = Column.dictionary_encoded(codes, dic,
                                              validity=c.validity)
            cols.append(c)
        schema = Schema([Field(f.name, c.type)
                         for f, c in zip(b.schema.fields, cols)])
        out.append(RecordBatch(schema, cols))
    return Table(out)

"""BufferStore: the tmpfs / kernel-memory analogue for Zerrow.

The paper's KernelZero operates on Linux physical pages, page tables and
tmpfs files.  This module provides the user-space equivalent with *real*
memory semantics:

  * ``StoreFile``    — an in-memory "tmpfs file": an append-only sequence of
                       extents.  Extents either hold a (read-only) numpy view
                       of user memory that was *transferred* (zero copy), or
                       bytes that were *copied* in (partial pages / baseline
                       writer-copy mode), or a swap handle on disk.
  * ``Cgroup``       — per-sandbox memory accounting with a limit; charging
                       past the limit triggers reclaim (swap-out) exactly like
                       cgroup-integrated kernel swap ("limit dropping").
  * ``BufferStore``  — the registry: file ids, refcounts, global kswap, LRU,
                       and the stats counters every benchmark reads.

Simulation notes (see DESIGN.md §2a):
  - Sharing is byte-granular here (user-space object sharing can do better
    than the kernel's page tables); the *cost model* of partial-page copies
    is retained: ``deanon`` really copies head/tail partial pages and the
    stats account for them.
  - Swap performs real disk I/O and virtual accounting.  RSS of the original
    arrays is not reclaimed (a user-space process cannot free memory that
    user code may still reference) — experiments are sized to fit RAM, and
    the *time* cost of swap (the quantity that drives the paper's Figures 4
    and 10) is real.

Backing modes (the Flight data plane, docs/ARCHITECTURE.md):
  - ``backing="ram"`` (default) — extents hold numpy views of user memory;
    zero-copy within one process, invisible to other processes.  This is
    the mode every pre-Flight benchmark and test runs in.
  - ``backing="file"`` — every StoreFile is a real file under ``data_dir``
    and resident extents are MAP_SHARED mmaps of it, so *any* process can
    map the same physical page-cache pages by ``(path, offset, length)``.
    De-anonymization writes the anonymous bytes into the backing file once
    (``bytes_file_ingest`` — the user-space tax for cross-process reality;
    the kernel module moves the pages instead) and the anonymous source is
    freed immediately after, so peak memory matches the transfer
    semantics.  ``adopt_file`` maps a file another process produced into
    this store without touching a single data byte — that is how Flight
    readers and the parent RM pick up worker outputs.  Swap-out of a
    file-backed extent just drops the mapping (the bytes stay durable in
    the backing file); swap-in re-maps it.
"""

from __future__ import annotations

import os
import threading
import uuid
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

import numpy as np

PAGE = 4096


# --------------------------------------------------------------------------
# aligned allocation (our jemalloc-with-page-alignment stand-in)
# --------------------------------------------------------------------------

def alloc_aligned(nbytes: int, align: int = PAGE) -> np.ndarray:
    """Allocate ``nbytes`` of uint8 page-aligned memory.

    Arrow allocators align to at least 64B; we align to PAGE so that
    de-anonymization transfers whole pages (the fast path).  The unaligned
    path is still supported (and tested) — it costs two partial-page copies,
    as in the paper.
    """
    if nbytes == 0:
        return np.empty(0, dtype=np.uint8)
    raw = np.empty(nbytes + align, dtype=np.uint8)
    addr = raw.__array_interface__["data"][0]
    off = (-addr) % align
    return raw[off : off + nbytes]  # .base keeps `raw` alive


def addr_range(arr: np.ndarray) -> tuple[int, int]:
    """(virtual address, nbytes) of a contiguous array's memory."""
    if not arr.flags["C_CONTIGUOUS"]:
        raise ValueError("addr_range requires a C-contiguous array")
    a = arr.__array_interface__["data"][0]
    return a, arr.nbytes


def pages_of(nbytes: int) -> int:
    return -(-nbytes // PAGE)


def _file_extend(path: str, length: int) -> None:
    """Grow ``path`` to at least ``length`` bytes (sparse, no data I/O)."""
    fd = os.open(path, os.O_RDWR | os.O_CREAT, 0o600)
    try:
        if os.fstat(fd).st_size < length:
            os.ftruncate(fd, length)
    finally:
        os.close(fd)


def map_extent(path: str, offset: int, length: int) -> np.ndarray:
    """Read-only MAP_SHARED view of ``path[offset:offset+length]``.

    This is the reader half of the Flight data plane: any process that
    knows a ``(path, offset, length)`` reference maps the same physical
    page-cache pages — no data bytes move.
    """
    if length == 0:
        return np.empty(0, dtype=np.uint8)
    return np.memmap(path, dtype=np.uint8, mode="r", offset=offset,
                     shape=(length,))


# --------------------------------------------------------------------------
# stats
# --------------------------------------------------------------------------

@dataclass
class StoreStats:
    bytes_copied: int = 0            # real memcpys into store files
    bytes_deanon: int = 0            # zero-copy ownership transfers
    bytes_reshared: int = 0          # output refs that reused input files
    reshare_hits: int = 0            # output buffers emitted as references
    #                                # (lazy pass-through or AddressMap hit)
    reshare_misses: int = 0          # output buffers that had to be
    #                                # de-anonymized/copied in zero mode
    bytes_file_ingest: int = 0       # anon bytes written into backing files
    #                                # (file mode's deanon tax; not a SIPC
    #                                # wire/reader/writer copy)
    bytes_adopted: int = 0           # bytes mapped in from foreign files
    partial_page_bytes: int = 0      # head/tail partial-page copies
    swapout_bytes: int = 0
    swapin_bytes: int = 0
    swapout_events: int = 0          # extent-granular
    swapin_events: int = 0
    fg_swapin_pages: int = 0         # page-granular foreground swapins (Fig 4b/5b)
    direct_swap_bytes: int = 0       # swap entries moved without I/O
    files_created: int = 0
    files_deleted: int = 0
    oom_kills: int = 0

    def snapshot(self) -> dict:
        return dict(self.__dict__)


# --------------------------------------------------------------------------
# cgroup accounting
# --------------------------------------------------------------------------

class OOMError(MemoryError):
    """Simulated cgroup / system OOM kill."""


class Cgroup:
    """Memory accounting for one sandbox (or the DeCache, or 'system').

    tmpfs memory for a file is charged to the cgroup that created it, as on
    Linux (§4.1); the cgroup survives process exit so outputs can be evicted
    later ("we modify SOCK so that cgroups are retained").
    """

    def __init__(self, name: str, store: "BufferStore", limit: Optional[int] = None):
        self.name = name
        self.store = store
        self.limit = limit
        self.charged = 0
        self.swap_charged = 0
        self.alive = True

    def charge(self, nbytes: int) -> None:
        self.charged += nbytes
        self.store._global_charge(nbytes)
        if self.limit is not None and self.charged > self.limit:
            self.store.reclaim_cgroup(self, self.charged - self.limit)

    def uncharge(self, nbytes: int) -> None:
        self.charged -= nbytes
        self.store._global_charge(-nbytes)

    def set_limit(self, limit: Optional[int]) -> None:
        """Dynamic limit adjustment — the 'limit dropping' mechanism."""
        self.limit = limit
        if limit is not None and self.charged > limit:
            self.store.reclaim_cgroup(self, self.charged - limit)


# --------------------------------------------------------------------------
# extents and files
# --------------------------------------------------------------------------

class Extent:
    """A contiguous range of a StoreFile's logical address space.

    state: resident (holds a read-only ndarray) or swapped (holds a path).
    """

    __slots__ = ("file", "logical_off", "length", "array", "swap_path",
                 "swap_off", "last_access", "pinned_resident", "file_backed")

    def __init__(self, file: "StoreFile", logical_off: int, length: int,
                 array: Optional[np.ndarray], swap_path: Optional[str] = None,
                 swap_off: int = 0):
        self.file = file
        self.logical_off = logical_off
        self.length = length
        self.array = array           # None when swapped out
        self.swap_path = swap_path   # set when a swap copy exists / is live
        self.swap_off = swap_off
        self.last_access = 0
        self.pinned_resident = False
        self.file_backed = False     # array is a MAP_SHARED mmap of the
        #                            # StoreFile's backing file (file mode)

    @property
    def resident(self) -> bool:
        return self.array is not None


class StoreFile:
    """A 'tmpfs file' assembled from de-anonymized extents.

    In-memory in RAM mode; a real file under the store's ``data_dir`` in
    file mode (``backing_path``), where resident extents are MAP_SHARED
    mmaps and the byte layout is exactly the logical layout (extent
    ``logical_off`` == file offset), so ``(backing_path, offset, length)``
    is a complete cross-process buffer reference.
    """

    def __init__(self, store: "BufferStore", file_id: int, owner: Cgroup,
                 label: str = "", backing_path: Optional[str] = None):
        self.store = store
        self.file_id = file_id
        self.owner = owner
        self.label = label
        self.extents: List[Extent] = []
        self.length = 0
        self.refcount = 0
        self.deleted = False
        self.decache_pinned = False
        self.backing_path = backing_path   # file mode only
        self.owns_path = backing_path is not None  # unlink on delete?

    # -- building ---------------------------------------------------------
    def append_extent(self, array: Optional[np.ndarray],
                      swap_path: Optional[str] = None,
                      length: Optional[int] = None,
                      charge: bool = True) -> int:
        if self.deleted:
            raise ValueError(f"append to deleted file {self.file_id}")
        n = array.nbytes if array is not None else int(length)  # type: ignore[arg-type]
        ext = Extent(self, self.length, n, array, swap_path)
        off = self.length
        self.extents.append(ext)
        self.length += n
        if self.backing_path is not None and n > 0:
            # the backing file always spans the logical length, so swapped
            # direct-swap entries have their region reserved up front
            _file_extend(self.backing_path, self.length)
        if array is not None:
            array = np.ascontiguousarray(array).view(np.uint8).reshape(-1)
            if self.backing_path is not None and n > 0:
                # file mode: the anonymous bytes are written into the
                # backing file once (the user-space stand-in for the kernel
                # module's page move) and the resident view becomes a
                # MAP_SHARED mmap any process can re-open
                mm = np.memmap(self.backing_path, dtype=np.uint8,
                               mode="r+", offset=off, shape=(n,))
                mm[:] = array
                mm.flags.writeable = False
                ext.array = mm
                ext.file_backed = True
                self.store.stats.bytes_file_ingest += n
            else:
                ext.array = array
                ext.array.flags.writeable = False  # post-deanon immutability
            if charge:
                self.owner.charge(n)
            self.store._lru_touch(ext)
        else:
            self.owner.swap_charged += n
        return off

    # -- reading ----------------------------------------------------------
    def read(self, offset: int, length: int, foreground: bool = True) -> np.ndarray:
        """Zero-copy view when the range sits in one resident extent;
        swaps in (real disk read) when needed; stitches across extents
        (copy, counted) otherwise."""
        if self.deleted:
            raise ValueError(f"read from deleted file {self.file_id} ({self.label})")
        end = offset + length
        if end > self.length:
            raise ValueError("read past end of store file")
        pieces: List[np.ndarray] = []
        for ext in self.extents:
            e0, e1 = ext.logical_off, ext.logical_off + ext.length
            if e1 <= offset or e0 >= end:
                continue
            # pin while faulting: the swap-in's charge may trigger reclaim,
            # which must not evict the very extent being accessed
            prev_pin = ext.pinned_resident
            ext.pinned_resident = True
            try:
                if not ext.resident:
                    self.store.swap_in(ext, foreground=foreground)
                self.store._lru_touch(ext)
                lo = max(offset, e0) - e0
                hi = min(end, e1) - e0
                pieces.append(ext.array[lo:hi])  # type: ignore[index]
            finally:
                ext.pinned_resident = prev_pin
        if len(pieces) == 1:
            return pieces[0]
        out = np.concatenate(pieces) if pieces else np.empty(0, np.uint8)
        self.store.stats.bytes_copied += out.nbytes  # stitch copy is a real copy
        return out

    # -- lifecycle --------------------------------------------------------
    def incref(self) -> None:
        self.refcount += 1

    def decref(self) -> None:
        self.refcount -= 1
        assert self.refcount >= 0, f"negative refcount on file {self.file_id}"

    def resident_bytes(self) -> int:
        return sum(e.length for e in self.extents if e.resident)


# --------------------------------------------------------------------------
# the store
# --------------------------------------------------------------------------

class BufferStore:
    """Registry of StoreFiles + swap machinery + kswap + stats."""

    def __init__(self, swap_dir: Optional[str] = None,
                 system_limit: Optional[int] = None,
                 backing: str = "ram", data_dir: Optional[str] = None,
                 root: Optional[str] = None):
        assert backing in ("ram", "file"), backing
        if root is not None:
            backing = "file"      # durable mode implies real backing files
        self.files: Dict[int, StoreFile] = {}
        self._next_id = 1
        self.stats = StoreStats()
        self.swap_dir = swap_dir or os.path.join(
            os.environ.get("TMPDIR", "/tmp"), f"zerrow-swap-{uuid.uuid4().hex[:8]}")
        os.makedirs(self.swap_dir, exist_ok=True)
        self.backing = backing
        self.data_dir: Optional[str] = None
        self.root: Optional[str] = None
        self.manifest = None                   # set by attach_manifest
        self.path_index: Dict[str, int] = {}   # abs backing path -> file_id
        if backing == "file":
            if root is not None and data_dir is None:
                # live (unpublished) files go in a per-instance dir under
                # the root; publish hard-links them into <root>/objects
                data_dir = os.path.join(
                    os.path.abspath(root),
                    f"live-{os.getpid()}-{uuid.uuid4().hex[:8]}")
            self.data_dir = os.path.abspath(data_dir or os.path.join(
                os.environ.get("TMPDIR", "/tmp"),
                f"zerrow-store-{uuid.uuid4().hex[:8]}"))
            os.makedirs(self.data_dir, exist_ok=True)
        if root is not None:
            self.attach_manifest(root)
        self.system = Cgroup("system", self, limit=None)
        self.system_limit = system_limit
        self.global_charged = 0
        self._lru_clock = 0
        self._lock = threading.RLock()
        self.kswap_enabled = True
        self.anon_regions: List["AnonRegion"] = []
        self.on_oom: Optional[Callable[[int], bool]] = None  # returns True if it freed memory

    # -- durability (persistent content-addressed cache) -------------------
    def attach_manifest(self, root: str) -> None:
        """Turn this (file-backed) store durable: content-addressed
        objects + fsync'd publish journal under ``root``."""
        if self.backing != "file":
            raise ValueError(
                "a durable store needs real backing files: construct with "
                "BufferStore(backing='file', root=...)")
        from .manifest import Manifest       # deferred: avoids import cycle
        self.root = os.path.abspath(root)
        os.makedirs(self.root, exist_ok=True)
        self.manifest = Manifest(self.root)

    @classmethod
    def reopen(cls, root: str, **kw) -> "BufferStore":
        """Remap a durable store root left by earlier runs.

        Zero bytes are copied: the manifest journal is replayed (torn
        tails discarded, entries with missing objects dropped) and each
        surviving output is lazily re-mmap'd through ``adopt_file`` the
        first time a fingerprint hit decodes it."""
        return cls(backing="file", root=root, **kw)

    def publish(self, fingerprint: str, msg, label: str = "",
                meta: Optional[dict] = None):
        """Durably publish a SipcMessage under a node fingerprint."""
        if self.manifest is None:
            raise ValueError("publish requires a durable store "
                             "(BufferStore(root=...))")
        return self.manifest.publish(self, fingerprint, msg, label=label,
                                     meta=meta)

    @property
    def copied_bytes(self) -> int:
        """Real data-byte memcpys through the SIPC read/write paths — the
        quantity the zero-copy claims are asserted on.  File-mode ingest
        and adoption are accounted separately (``bytes_file_ingest`` /
        ``bytes_adopted``)."""
        return self.stats.bytes_copied

    # -- cgroups ----------------------------------------------------------
    def new_cgroup(self, name: str, limit: Optional[int] = None) -> Cgroup:
        return Cgroup(name, self, limit)

    def _global_charge(self, nbytes: int) -> None:
        self.global_charged += nbytes
        if nbytes > 0 and self.system_limit is not None and \
                self.global_charged > self.system_limit:
            need = self.global_charged - self.system_limit
            if self.kswap_enabled:
                freed = self.kswap(need)
                need -= freed
            if need > 0:
                if self.on_oom is not None and self.on_oom(need):
                    return
                self.stats.oom_kills += 1
                raise OOMError(
                    f"simulated OOM: charged {self.global_charged} > "
                    f"limit {self.system_limit}")

    # -- files ------------------------------------------------------------
    def new_file(self, owner: Cgroup, label: str = "",
                 backing_path: Optional[str] = None) -> StoreFile:
        with self._lock:
            fid = self._next_id
            self._next_id += 1
            path = None
            if self.backing == "file":
                path = os.path.abspath(backing_path or os.path.join(
                    self.data_dir, f"f{fid:06d}"))
                if backing_path is None:
                    _file_extend(path, 0)       # create empty backing file
                self.path_index[path] = fid
            f = StoreFile(self, fid, owner, label, backing_path=path)
            self.files[fid] = f
            self.stats.files_created += 1
            return f

    def get(self, file_id: int) -> StoreFile:
        return self.files[file_id]

    def backing_path(self, file_id: int) -> str:
        """The cross-process name of a StoreFile (file mode only)."""
        f = self.files[file_id]
        if f.backing_path is None:
            raise ValueError(
                f"store file {file_id} ({f.label!r}) has no backing file: "
                "construct the BufferStore with backing='file' to export "
                "wire references")
        return f.backing_path

    def ensure_file_backed(self, file_id: int) -> None:
        """Materialize every extent of a StoreFile in its backing file.

        A direct-swap entry (anon region swapped out *before* deanon, then
        transferred without I/O) lives in a separate swap file; its
        backing-file region is only reserved.  Exporting a wire reference
        to it would hand readers a sparse hole, so the wire encoder calls
        this before naming the path — the swap-in lands the bytes in the
        backing file and the extent becomes file-backed for good."""
        f = self.files[file_id]
        if f.backing_path is None:
            raise ValueError(f"store file {file_id} is not file-backed")
        for ext in f.extents:
            if not ext.file_backed and not ext.resident:
                self.swap_in(ext, foreground=False)

    def adopt_file(self, path: str, owner: Optional[Cgroup] = None,
                   label: str = "", charge: bool = True,
                   owns_path: bool = False) -> StoreFile:
        """Map a file produced by another process (or store) into this
        registry without touching any data bytes.

        Returns the existing StoreFile when ``path`` is already registered
        (e.g. a worker output that reshares one of our own input files).
        ``owns_path=True`` transfers unlink responsibility to this store —
        the ownership handover the parent RM performs on worker outputs.
        """
        if self.backing != "file":
            raise ValueError("adopt_file requires a file-backed store")
        path = os.path.abspath(path)
        with self._lock:
            fid = self.path_index.get(path)
            if fid is not None:
                return self.files[fid]
            length = os.path.getsize(path)
            f = self.new_file(owner or self.system, label,
                              backing_path=path)
            f.owns_path = owns_path
            if length:
                ext = Extent(f, 0, length, map_extent(path, 0, length))
                ext.file_backed = True
                f.extents.append(ext)
                f.length = length
                if charge:
                    f.owner.charge(length)
                self._lru_touch(ext)
            self.stats.bytes_adopted += length
            return f

    def delete_file(self, file_id: int) -> None:
        f = self.files.pop(file_id, None)
        if f is None or f.deleted:
            return
        f.deleted = True
        for ext in f.extents:
            if ext.resident:
                f.owner.uncharge(ext.length)
                ext.array = None
            elif ext.swap_path:
                f.owner.swap_charged -= ext.length
                if not ext.file_backed:
                    try:
                        os.unlink(ext.swap_path)
                    except OSError:
                        pass
        if f.backing_path is not None:
            self.path_index.pop(f.backing_path, None)
            if f.owns_path:
                try:
                    os.unlink(f.backing_path)
                except OSError:
                    pass
        self.stats.files_deleted += 1

    # -- swap -------------------------------------------------------------
    def _swap_path(self) -> str:
        return os.path.join(self.swap_dir, uuid.uuid4().hex)

    def swap_out(self, ext: Extent) -> None:
        if not ext.resident or ext.pinned_resident:
            return
        if ext.file_backed:
            # the bytes are durable in the backing file: dropping the
            # mapping is the page-cache-eviction analogue (no write I/O)
            ext.swap_path = ext.file.backing_path
            ext.array = None
        else:
            path = self._swap_path()
            ext.array.tofile(path)  # real disk write  # type: ignore[union-attr]
            ext.swap_path = path
            ext.array = None
        ext.file.owner.uncharge(ext.length)
        ext.file.owner.swap_charged += ext.length
        self.stats.swapout_bytes += ext.length
        self.stats.swapout_events += 1

    def swap_in(self, ext: Extent, foreground: bool = True) -> None:
        if ext.resident:
            return
        assert ext.swap_path is not None
        if ext.file_backed:
            ext.array = map_extent(ext.file.backing_path, ext.logical_off,
                                   ext.length)
            ext.swap_path = None
        else:
            data = np.fromfile(ext.swap_path, dtype=np.uint8,
                               count=ext.length)  # real read
            try:
                os.unlink(ext.swap_path)
            except OSError:
                pass
            ext.swap_path = None
            if ext.file.backing_path is not None:
                # file-mode store: land the faulted bytes in the backing
                # file (region was reserved at append) so the file stays a
                # complete cross-process image; future swaps are drops
                mm = np.memmap(ext.file.backing_path, dtype=np.uint8,
                               mode="r+", offset=ext.logical_off,
                               shape=(ext.length,))
                mm[:] = data
                mm.flags.writeable = False
                ext.array = mm
                ext.file_backed = True
            else:
                data.flags.writeable = False
                ext.array = data
        ext.file.owner.swap_charged -= ext.length
        ext.file.owner.charge(ext.length)  # may recursively reclaim elsewhere
        self.stats.swapin_bytes += ext.length
        self.stats.swapin_events += 1
        if foreground:
            self.stats.fg_swapin_pages += pages_of(ext.length)
        self._lru_touch(ext)

    def _lru_touch(self, ext: Extent) -> None:
        self._lru_clock += 1
        ext.last_access = self._lru_clock

    def _candidates(self, owner: Optional[Cgroup]) -> List:
        exts = [e for f in self.files.values() if not f.deleted
                for e in f.extents
                if e.resident and not e.pinned_resident
                and (owner is None or f.owner is owner)]
        # anonymous working-set pages are reclaimable too (per-cgroup LRU
        # over anon + file pages, as on Linux §4.1)
        anons = [r for r in self.anon_regions
                 if not r.swapped and r.array is not None
                 and (owner is None or r.cgroup is owner)]
        cands = [(e.last_access, e) for e in exts] + \
                [(r.last_access, r) for r in anons]
        cands.sort(key=lambda t: t[0])
        return [c for _, c in cands]

    def _reclaim_one(self, c) -> int:
        if isinstance(c, AnonRegion):
            n = c.nbytes
            c.swap_out(self)
            return n
        self.swap_out(c)
        return c.length

    def reclaim_cgroup(self, cg: Cgroup, need: int) -> int:
        """Swap out this cgroup's LRU pages until ``need`` bytes are freed.
        This is the mechanism 'limit dropping' manipulates."""
        freed = 0
        for c in self._candidates(cg):
            if freed >= need:
                break
            freed += self._reclaim_one(c)
        return freed

    def kswap(self, need: int) -> int:
        """Global LRU reclaim — the baseline 'kswap' behaviour."""
        freed = 0
        for c in self._candidates(None):
            if freed >= need:
                break
            freed += self._reclaim_one(c)
        return freed

    # -- file-level eviction helpers (RM mechanisms) -----------------------
    def swap_out_file(self, file_id: int) -> int:
        f = self.files.get(file_id)
        if f is None:
            return 0
        n = 0
        for ext in f.extents:
            if ext.resident and not ext.pinned_resident:
                n += ext.length
                self.swap_out(ext)
        return n

    def resident_total(self) -> int:
        return self.global_charged

    def close(self) -> None:
        for fid in list(self.files):
            self.delete_file(fid)
        if self.manifest is not None:
            # published objects + journal outlive the store: that is the
            # point of the durable mode.  Only the live dir is removed.
            self.manifest.close()
        try:
            for p in os.listdir(self.swap_dir):
                os.unlink(os.path.join(self.swap_dir, p))
            os.rmdir(self.swap_dir)
        except OSError:
            pass
        if self.data_dir is not None:
            try:
                for p in os.listdir(self.data_dir):
                    os.unlink(os.path.join(self.data_dir, p))
                os.rmdir(self.data_dir)
            except OSError:
                pass


# --------------------------------------------------------------------------
# lazy buffer views (the mmap-fault analogue)
# --------------------------------------------------------------------------

class LazyBuf:
    """A not-yet-faulted mapping of a store-file range.

    The paper's readers mmap tmpfs files: data is faulted in per page only
    when touched, so a swapped-out column that a node merely passes through
    never costs swap-in I/O.  We reproduce that at buffer granularity: a
    LazyBuf knows its (file, offset, length) provenance and materializes a
    numpy view only when compute actually accesses it.  SIPC's writer can
    reshare an *unforced* LazyBuf directly from provenance — passing a
    column through a node touches no data at all.
    """

    __slots__ = ("store", "file_id", "offset", "length", "np_dtype",
                 "_arr", "on_force", "fault_lock")

    def __init__(self, store: "BufferStore", file_id: int, offset: int,
                 length: int, np_dtype: str = "uint8", on_force=None,
                 fault_lock=None):
        self.store = store
        self.file_id = file_id
        self.offset = offset
        self.length = length
        self.np_dtype = np_dtype
        self._arr: Optional[np.ndarray] = None
        self.on_force = on_force
        # executor critical-section guard: when user code running *outside*
        # the RM lock faults this mapping, the store-mutating read re-enters
        # the lock (see sched/executor.py "Concurrency model")
        self.fault_lock = fault_lock

    @property
    def forced(self) -> bool:
        return self._arr is not None

    @property
    def nbytes(self) -> int:
        return self.length

    def force(self) -> np.ndarray:
        if self._arr is None:
            if self.fault_lock is not None:
                with self.fault_lock:
                    self._force_locked()
            else:
                self._force_locked()
        return self._arr

    def _force_locked(self) -> None:
        if self._arr is not None:
            return
        raw = self.store.get(self.file_id).read(self.offset, self.length)
        arr = raw.view(np.dtype(self.np_dtype))
        if self.on_force is not None:
            self.on_force(raw, self.file_id, self.offset)
        self._arr = arr

    def subrange(self, byte_off: int, byte_len: int,
                 np_dtype: Optional[str] = None) -> "LazyBuf":
        """Lazy slice: adjust provenance, no fault."""
        assert byte_off + byte_len <= self.length
        return LazyBuf(self.store, self.file_id, self.offset + byte_off,
                       byte_len, np_dtype or self.np_dtype, self.on_force,
                       fault_lock=self.fault_lock)


def force_buf(b) -> np.ndarray:
    return b.force() if isinstance(b, LazyBuf) else b


# --------------------------------------------------------------------------
# anonymous memory regions (pre-deanon working memory of a sandbox)
# --------------------------------------------------------------------------

class AnonRegion:
    """A sandbox-owned anonymous allocation (malloc'd Arrow memory).

    Registered by the share wrapper so that (a) it is charged to the
    sandbox's cgroup and (b) it can be swapped under pressure *before*
    de-anonymization — the situation KernelZero's direct-swap optimizes.
    """

    __slots__ = ("array", "cgroup", "swap_path", "swapped", "nbytes",
                 "last_access")

    def __init__(self, array: np.ndarray, cgroup: Cgroup):
        self.array = np.ascontiguousarray(array).view(np.uint8).reshape(-1)
        self.nbytes = self.array.nbytes
        self.cgroup = cgroup
        self.swap_path: Optional[str] = None
        self.swapped = False
        store = cgroup.store
        store._lru_clock += 1
        self.last_access = store._lru_clock
        store.anon_regions.append(self)
        cgroup.charge(self.nbytes)  # may trigger reclaim (incl. of us)

    def swap_out(self, store: BufferStore) -> None:
        if self.swapped:
            return
        path = store._swap_path()
        self.array.tofile(path)
        self.swap_path = path
        self.swapped = True
        self.cgroup.uncharge(self.nbytes)
        self.cgroup.swap_charged += self.nbytes
        store.stats.swapout_bytes += self.nbytes
        store.stats.swapout_events += 1

    def swap_in(self, store: BufferStore) -> None:
        if not self.swapped:
            return
        data = np.fromfile(self.swap_path, dtype=np.uint8, count=self.nbytes)
        try:
            os.unlink(self.swap_path)
        except OSError:
            pass
        arr = self.array
        writeable = arr.flags.writeable
        arr.flags.writeable = True
        arr[:] = data
        arr.flags.writeable = writeable
        self.swap_path = None
        self.swapped = False
        self.cgroup.swap_charged -= self.nbytes
        self.cgroup.charge(self.nbytes)
        store.stats.swapin_bytes += self.nbytes
        store.stats.swapin_events += 1
        store.stats.fg_swapin_pages += pages_of(self.nbytes)

    def release(self) -> None:
        if self.swapped:
            self.cgroup.swap_charged -= self.nbytes
            if self.swap_path:
                try:
                    os.unlink(self.swap_path)
                except OSError:
                    pass
            self.swapped = False
            self.swap_path = None
        elif self.array is not None:
            self.cgroup.uncharge(self.nbytes)
        self.array = None  # type: ignore[assignment]
        try:
            self.cgroup.store.anon_regions.remove(self)
        except ValueError:
            pass

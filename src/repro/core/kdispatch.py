"""Kernel backend dispatch: numpy vkernels vs Pallas relational kernels.

``ZERROW_KERNEL_BACKEND={numpy,pallas}`` selects where the relational
hot path (key hashing, join gathers, segment reducers) runs.  The
default is ``numpy`` (``core/vkernels.py``); ``pallas`` routes each
kernel call through ``repro.kernels.relational`` — accelerator-resident
on TPU, interpret-mode on CI runners — **but only for kernels the
eligibility registry admits**.  Admission is a bit-identity contract:
a kernel enters ``REGISTRY`` as eligible only once the differential
harness (``tests/test_pallas_relational.py``) proves pallas-interpret
bits equal the numpy reference bits equal a per-row naive reference,
across dtypes, nulls, duplicates, and empties.  Kernels that *cannot*
meet the contract are documented ineligible with their reason and stay
on numpy regardless of the env knob — the first entries are the float
segment reductions, whose PR 5 contract fixes a sequential accumulation
order (``np.bincount`` original-row-order for sums; left-to-right
``reduceat`` ties for min/max over -0.0/NaN) that a block-parallel
reduction cannot reproduce bit-for-bit.  Position-dependent float
accumulation must never silently change results.

Fallback is loud-but-safe: requesting ``pallas`` without a working
jax/Pallas install warns once (naming this knob and the import error)
and serves numpy; an unknown backend name raises.  ``self_check()``
re-runs a compact differential in-process and *demotes* any kernel
whose bits diverge (runtime numpy fallback + reported) — the
``bench_pallas_join`` smoke gate asserts no demotions, so an eligibility
regression fails CI.

Fingerprint integration: ``fp_includes_join`` / ``fp_includes_group_by``
are *callables* assigned to the relational ops' ``__fp_includes__``
(``core/fingerprint.py`` calls them at fingerprint time).  They always
return the numpy kernel deps; when the pallas backend is active they
additionally fold in a backend tag plus the live Pallas kernel
functions.  Flipping ``ZERROW_KERNEL_BACKEND``, editing a Pallas kernel
body, or a runtime demotion therefore invalidates exactly the cached
join/group-by cones — switching backends can never serve a stale cone
computed by the other engine.
"""

from __future__ import annotations

import functools
import os
import warnings
from dataclasses import dataclass
from typing import Dict, Optional, Sequence, Tuple

import numpy as np

from . import vkernels

__all__ = [
    "BACKENDS", "REGISTRY", "Eligibility", "requested_backend",
    "active_backend", "pallas_import_error", "eligible", "demoted",
    "demote", "reset_demotions", "self_check",
    "hash_fixed", "combine_hashes", "hash_keys", "filter_join_gather",
    "GROUPED_REDUCERS", "fp_includes_join", "fp_includes_group_by",
]

BACKENDS = ("numpy", "pallas")

_ENV = "ZERROW_KERNEL_BACKEND"


# --------------------------------------------------------------------------
# backend resolution (env-driven, per call — no import-time sniffing)
# --------------------------------------------------------------------------

def requested_backend() -> str:
    """The backend named by ``ZERROW_KERNEL_BACKEND`` (default numpy).
    Read per call so tests and benchmarks can flip it without reloading
    modules; an unknown name raises rather than silently serving the
    default."""
    v = os.environ.get(_ENV, "numpy").strip().lower()
    if v not in BACKENDS:
        raise ValueError(f"{_ENV}={v!r}: choose one of {BACKENDS}")
    return v


_pallas_mod = None
_pallas_error: Optional[BaseException] = None
_warned_fallback = False


def _pallas():
    """Import ``repro.kernels.relational`` lazily, once; the failure (if
    any) is kept for the loud fallback message."""
    global _pallas_mod, _pallas_error
    if _pallas_mod is None and _pallas_error is None:
        try:
            from repro.kernels import relational
            _pallas_mod = relational
        except Exception as e:   # ImportError or jax init failure
            _pallas_error = e
    return _pallas_mod


def pallas_import_error() -> Optional[BaseException]:
    """The exception that made the pallas backend unavailable, or None."""
    _pallas()
    return _pallas_error


def active_backend() -> str:
    """The backend actually serving calls: the requested one, demoted to
    numpy — with a one-time warning naming the knob and the import
    failure — when pallas was requested but jax/Pallas cannot load."""
    global _warned_fallback
    b = requested_backend()
    if b == "pallas" and _pallas() is None:
        if not _warned_fallback:
            warnings.warn(
                f"{_ENV}=pallas requested but the Pallas kernels are "
                f"unavailable ({_pallas_error!r}); falling back to the "
                "numpy vkernels. Results are identical; unset "
                f"{_ENV} to silence this.", RuntimeWarning, stacklevel=2)
            _warned_fallback = True
        return "numpy"
    return b


# --------------------------------------------------------------------------
# eligibility registry: bit-identity admission, documented refusals
# --------------------------------------------------------------------------

@dataclass(frozen=True)
class Eligibility:
    eligible: bool
    reason: str


#: keyed ``kernel`` or ``kernel:dtypeclass`` (``int`` covers bool and all
#: integer widths, ``float`` all floats).  A kernel/dtype pair absent
#: from the registry is NOT admitted — numpy serves it.
REGISTRY: Dict[str, Eligibility] = {
    "hash_fixed": Eligibility(True,
        "splitmix64 over bit patterns: wrapping uint64 multiply and "
        "xor-shift are exact on every backend"),
    "combine_hashes": Eligibility(True,
        "ordered uint64 fold, same per-element exactness as hash_fixed"),
    "hash_keys": Eligibility(True,
        "fused per-column mix + ordered combine over fixed-width key "
        "buffers; var-length (offsets, values) keys route to numpy "
        "structurally (not expressible as a dense block kernel)"),
    "filter_join_gather": Eligibility(True,
        "index gather with -1 sentinel passthrough: no arithmetic"),
    "gather_payload": Eligibility(True,
        "payload-column gather with -1 fill: no arithmetic"),
    "grouped_count": Eligibility(True,
        "integer segment count: exact in any accumulation order"),
    "grouped_sum:int": Eligibility(True,
        "integer segment sum: associative and exact, any block order "
        "reproduces reduceat bits"),
    "grouped_sum:float": Eligibility(False,
        "PR 5 contract: float sums accumulate sequentially in original "
        "row order (np.bincount); block-parallel reduction reorders the "
        "additions and changes low-order bits — position-dependent "
        "accumulation must not silently change results"),
    "grouped_min:int": Eligibility(True,
        "integer extremes are order-free"),
    "grouped_min:float": Eligibility(False,
        "-0.0/+0.0 ties and NaN propagation resolve by reduction order; "
        "the contract is reduceat's left-to-right result"),
    "grouped_max:int": Eligibility(True,
        "integer extremes are order-free"),
    "grouped_max:float": Eligibility(False,
        "-0.0/+0.0 ties and NaN propagation resolve by reduction order; "
        "the contract is reduceat's left-to-right result"),
    "grouped_mean": Eligibility(False,
        "composes the float segment sum, inheriting its sequential-"
        "accumulation contract"),
}

#: registry keys demoted at runtime by ``self_check`` bit mismatches;
#: demoted kernels serve numpy and fail the bench smoke gate
_demoted: Dict[str, str] = {}


def _dtype_class(dt) -> str:
    dt = np.dtype(dt)
    return "float" if np.issubdtype(dt, np.floating) else "int"


def _registry_key(kernel: str, dtype=None) -> str:
    if dtype is not None and f"{kernel}:{_dtype_class(dtype)}" in REGISTRY:
        return f"{kernel}:{_dtype_class(dtype)}"
    return kernel


def eligible(kernel: str, dtype=None) -> bool:
    """Is this kernel (for this value dtype, if reductions) admitted to
    the pallas path right now?  False for documented-ineligible entries,
    unknown kernels, and runtime demotions alike."""
    key = _registry_key(kernel, dtype)
    e = REGISTRY.get(key)
    return bool(e and e.eligible) and key not in _demoted


def demoted() -> Dict[str, str]:
    """Registry keys demoted at runtime, with the mismatch description."""
    return dict(_demoted)


def demote(kernel_key: str, why: str) -> None:
    """Force a kernel onto the numpy path (bit mismatch observed)."""
    _demoted[kernel_key] = why


def reset_demotions() -> None:
    _demoted.clear()


# --------------------------------------------------------------------------
# dispatchers (numpy arrays in and out; signatures mirror vkernels)
# --------------------------------------------------------------------------

def _use_pallas(kernel: str, dtype=None) -> bool:
    return active_backend() == "pallas" and eligible(kernel, dtype)


def hash_fixed(values: np.ndarray) -> np.ndarray:
    if _use_pallas("hash_fixed", values.dtype):
        return _pallas_mod.hash_fixed(values)
    return vkernels.hash_fixed(values)


def combine_hashes(col_hashes: Sequence[np.ndarray], n: int) -> np.ndarray:
    if _use_pallas("combine_hashes"):
        return _pallas_mod.combine_hashes(col_hashes, n)
    return vkernels.combine_hashes(col_hashes, n)


def hash_keys(keys: Sequence, n: int) -> np.ndarray:
    # var-length (offsets, values) keys are structurally numpy-only
    if (_use_pallas("hash_keys")
            and not any(isinstance(k, tuple) for k in keys)):
        return _pallas_mod.hash_keys(keys, n)
    return vkernels.hash_keys(list(keys), n)


def filter_join_gather(sel: np.ndarray, idx: np.ndarray) -> np.ndarray:
    if _use_pallas("filter_join_gather"):
        return _pallas_mod.filter_join_gather(sel, idx)
    return vkernels.filter_join_gather(sel, idx)


def gather_payload(values: np.ndarray, idx: np.ndarray,
                   fill=0) -> np.ndarray:
    idx = np.ascontiguousarray(idx, dtype=np.int64)
    if _use_pallas("gather_payload", values.dtype):
        return _pallas_mod.gather_payload(values, idx, fill)
    out = np.full(len(idx), fill, dtype=values.dtype)
    hit = idx >= 0
    out[hit] = values[idx[hit]]
    return out


def _r_count(values, order, starts, valid=None):
    if _use_pallas("grouped_count"):
        return _pallas_mod.grouped_count(values, order, starts, valid)
    return vkernels.grouped_count(values, order, starts, valid)


def _r_sum(values, order, starts, valid=None):
    if _use_pallas("grouped_sum", values.dtype):
        return _pallas_mod.grouped_sum(values, order, starts, valid)
    return vkernels.grouped_sum(values, order, starts, valid)


def _r_min(values, order, starts, valid=None):
    if _use_pallas("grouped_min", values.dtype):
        return _pallas_mod.grouped_min(values, order, starts, valid)
    return vkernels.grouped_min(values, order, starts, valid)


def _r_max(values, order, starts, valid=None):
    if _use_pallas("grouped_max", values.dtype):
        return _pallas_mod.grouped_max(values, order, starts, valid)
    return vkernels.grouped_max(values, order, starts, valid)


def _r_mean(values, order, starts, valid=None):
    # documented ineligible: composes the sequential float sum
    return vkernels.grouped_mean(values, order, starts, valid)


#: drop-in for ``vkernels.GROUPED_REDUCERS`` with per-dtype dispatch
GROUPED_REDUCERS = {
    "count": _r_count, "sum": _r_sum, "min": _r_min, "max": _r_max,
    "mean": _r_mean,
}


# --------------------------------------------------------------------------
# fingerprint integration: callable __fp_includes__ for the relational ops
# --------------------------------------------------------------------------

def _backend_tag(name: str, demotions: Tuple[str, ...]) -> None:
    """Inert marker folded into op fingerprints via
    ``functools.partial(_backend_tag, <backend>, <demotions>)`` — the
    partial's args make backends (and runtime demotion states) produce
    distinct cone fingerprints."""


def _tag():
    return functools.partial(_backend_tag, "pallas",
                             tuple(sorted(_demoted)))


_JOIN_NUMPY = (vkernels.combine_hashes, vkernels.hash_fixed,
               vkernels.hash_var, vkernels.hash_join_probe,
               vkernels.filter_join_gather, vkernels.bytes_rows_equal)
_GROUP_BY_NUMPY = (vkernels.group_ranges, vkernels.grouped_count,
                   vkernels.grouped_sum, vkernels.grouped_min,
                   vkernels.grouped_max, vkernels.grouped_mean,
                   vkernels.dict_encode_var, vkernels.sort_keys_var)


def fp_includes_join():
    """Kernel deps of the join/filter_join ops, evaluated at fingerprint
    time: numpy always; plus the backend tag and live Pallas kernels
    when the pallas backend is active (so backend flips and kernel edits
    invalidate exactly the join cones)."""
    deps = _JOIN_NUMPY
    if active_backend() == "pallas":
        rel = _pallas_mod
        deps = deps + (_tag(), rel.hash_fixed, rel.combine_hashes,
                       rel.filter_join_gather)
    return deps


def fp_includes_group_by():
    """Kernel deps of the group_by op (see ``fp_includes_join``)."""
    deps = _GROUP_BY_NUMPY
    if active_backend() == "pallas":
        rel = _pallas_mod
        deps = deps + (_tag(), rel.grouped_count, rel.grouped_sum,
                       rel.grouped_min, rel.grouped_max)
    return deps


# --------------------------------------------------------------------------
# in-process differential: demote anything whose bits diverge
# --------------------------------------------------------------------------

def self_check(n: int = 4096, n_groups: int = 97) -> Dict[str, str]:
    """Compact differential over adversarial seeded inputs: every
    *eligible* registry entry runs pallas-vs-numpy and must match bit
    for bit (values and dtypes).  A mismatch demotes the kernel — calls
    fall back to numpy — and is reported; ineligible entries report
    their documented reason.  The ``bench_pallas_join`` smoke gate
    asserts no demotions, so a regression fails CI even before the full
    harness runs."""
    results: Dict[str, str] = {}
    if _pallas() is None:
        raise RuntimeError(
            f"self_check needs the Pallas kernels; import failed with "
            f"{_pallas_error!r} (see {_ENV})")
    rel = _pallas_mod
    rng = np.random.default_rng(0)
    f64 = rng.standard_normal(n)
    f64[rng.random(n) < 0.1] = -0.0
    f64[rng.random(n) < 0.05] = np.nan
    cols = {
        "int32": rng.integers(-50, 50, n).astype(np.int32),
        "int64": rng.integers(-(1 << 62), 1 << 62, n, dtype=np.int64),
        "uint64": rng.integers(0, 1 << 64, n, dtype=np.uint64),
        "float64": f64,
        "bool": rng.random(n) < 0.5,
    }
    valid = rng.random(n) < 0.8
    codes = rng.integers(0, n_groups, n)
    order, starts = vkernels.group_ranges([codes])
    sel = np.nonzero(rng.random(n // 2) < 0.5)[0]
    idx = rng.integers(-1, len(sel), n).astype(np.int64)
    pidx = rng.integers(-1, n, n).astype(np.int64)

    def check(key: str, got, want) -> None:
        gv, gc = got if isinstance(got, tuple) else (got, None)
        wv, wc = want if isinstance(want, tuple) else (want, None)
        same = (gv.dtype == wv.dtype
                and np.array_equal(gv, wv, equal_nan=True)
                and (gc is None or (gc.dtype == wc.dtype
                                    and np.array_equal(gc, wc))))
        if same:
            results.setdefault(key, "ok")
        else:
            why = f"bit mismatch vs numpy ({gv.dtype} vs {wv.dtype})"
            demote(key, why)
            results[key] = f"demoted: {why}"

    for v in cols.values():
        check("hash_fixed", rel.hash_fixed(v), vkernels.hash_fixed(v))
    hs = [vkernels.hash_fixed(cols["int64"]),
          vkernels.hash_fixed(cols["float64"]),
          vkernels.hash_fixed(cols["uint64"])]
    check("combine_hashes", rel.combine_hashes(hs, n),
          vkernels.combine_hashes(hs, n))
    ks = [cols["int64"], cols["float64"], cols["int32"]]
    check("hash_keys", rel.hash_keys(ks, n), vkernels.hash_keys(ks, n))
    check("filter_join_gather", rel.filter_join_gather(sel, idx),
          vkernels.filter_join_gather(sel, idx))
    check("gather_payload", rel.gather_payload(cols["int64"], pidx, 0),
          np.where(pidx >= 0, cols["int64"][np.where(pidx >= 0, pidx, 0)],
                   0))
    check("grouped_count",
          rel.grouped_count(cols["int64"], order, starts, valid),
          vkernels.grouped_count(cols["int64"], order, starts, valid))
    for name in ("int32", "int64", "uint64", "bool"):
        v = cols[name]
        check("grouped_sum:int", rel.grouped_sum(v, order, starts, valid),
              vkernels.grouped_sum(v, order, starts, valid))
        check("grouped_min:int", rel.grouped_min(v, order, starts, valid),
              vkernels.grouped_min(v, order, starts, valid))
        check("grouped_max:int", rel.grouped_max(v, order, starts, valid),
              vkernels.grouped_max(v, order, starts, valid))
    for key, e in REGISTRY.items():
        if not e.eligible:
            results[key] = f"ineligible: {e.reason}"
    return results

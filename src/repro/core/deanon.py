"""KernelZero — de-anonymization of Arrow memory (paper §4.2.1).

The kernel module exposes two calls:

    new_file()                      -> tmpfs file to receive anonymous memory
    deanon(file_id, addr, len)      -> append [addr, addr+len) to the file
                                       *without copying* (ownership transfer)

This user-space implementation preserves the contract and the cost model:

  * whole pages are transferred by reference (zero copy) — here, the store
    file's extent holds a read-only view of the caller's numpy memory;
  * partial head/tail pages are *really copied* (``partial_page_bytes``);
  * memory that has been de-anonymized is made immutable
    (``writeable = False``) — the paper requires the producing process to
    never modify transferred data (§4.2.1, last ¶);
  * ``direct_swap``: when the source region was already swapped out, the
    swap entry is moved into the tmpfs file without any disk I/O.  Without
    this optimization the pages must first be swapped in (real read).
"""

from __future__ import annotations

from typing import Optional, Tuple, Union

import numpy as np

from .buffers import (PAGE, AnonRegion, BufferStore, Cgroup, StoreFile,
                      alloc_aligned)


class KernelZero:
    """User-space stand-in for the KernelZero Linux module."""

    def __init__(self, store: BufferStore):
        self.store = store

    # -- interface 1 -------------------------------------------------------
    def new_file(self, owner: Cgroup, label: str = "") -> StoreFile:
        return self.store.new_file(owner, label)

    # -- interface 2 -------------------------------------------------------
    def deanon(self, file: StoreFile,
               src: Union[np.ndarray, AnonRegion],
               direct_swap: bool = True) -> Tuple[int, int]:
        """Move ``src`` into ``file`` (append) without copying.

        Returns (offset, length) of the appended range.
        """
        region: Optional[AnonRegion] = None
        if isinstance(src, AnonRegion):
            region = src
            if region.swapped:
                return self._deanon_swapped(file, region, direct_swap)
            arr = region.array
        else:
            arr = src
        if isinstance(arr, np.ndarray) and arr.flags["C_CONTIGUOUS"]:
            # best-effort enforcement of the §4.2.1 contract on the caller's
            # own handle (other pre-existing views cannot be frozen from
            # user space; the kernel version freezes the physical pages)
            arr.flags.writeable = False
        arr = np.ascontiguousarray(arr).view(np.uint8).reshape(-1)
        n = arr.nbytes
        if n == 0:
            return file.length, 0

        # page-granularity cost model: copy partial head/tail pages for real
        addr = arr.__array_interface__["data"][0]
        head = (-addr) % PAGE
        head = min(head, n)
        tail = (addr + n) % PAGE if n > head else 0
        tail = min(tail, n - head)
        partial = head + tail
        if partial:
            # the kernel would memcpy these bytes into fresh pages; do it
            if head:
                _ = arr[:head].copy()
            if tail:
                _ = arr[n - tail:].copy()
            self.store.stats.partial_page_bytes += partial
            self.store.stats.bytes_copied += partial

        # ownership-of-charge transfer: the sandbox's anonymous charge moves
        # to the file owner's cgroup (tmpfs charging rules, paper §4.1)
        if region is not None:
            keep = region.array
            region.release()
            arr = keep.view(np.uint8).reshape(-1)

        off = file.append_extent(arr)
        self.store.stats.bytes_deanon += n - partial
        return off, n

    def _deanon_swapped(self, file: StoreFile, region: AnonRegion,
                        direct_swap: bool) -> Tuple[int, int]:
        n = region.nbytes
        if direct_swap:
            # move the swap entry into the tmpfs file: zero disk I/O
            off = file.append_extent(None, swap_path=region.swap_path,
                                     length=n)
            self.store.stats.direct_swap_bytes += n
            region.swap_path = None
            region.swapped = False
            region.cgroup.swap_charged -= n
            region.array = None  # type: ignore[assignment]
            try:
                self.store.anon_regions.remove(region)
            except ValueError:
                pass
            return off, n
        # naive path: swap in first (real disk read), then transfer
        region.swap_in(self.store)
        return self.deanon(file, region, direct_swap=False)

    # -- baseline path (no KernelZero): writer-side memcpy ------------------
    def writer_copy(self, file: StoreFile, src: np.ndarray) -> Tuple[int, int]:
        """What Arrow IPC without Zerrow does: copy the buffer into the
        shared-memory file (Figure 1, degree C)."""
        arr = np.ascontiguousarray(src).view(np.uint8).reshape(-1)
        n = arr.nbytes
        if n == 0:
            return file.length, 0
        dst = alloc_aligned(n)
        np.copyto(dst, arr)  # the real write-side memcpy Zerrow eliminates
        off = file.append_extent(dst)
        self.store.stats.bytes_copied += n
        return off, n

"""Resource Manager: accounting + back-compat facade over ``core/sched``
(paper §3.1, §3.3, §4.2.5).

The RM's four actions (Fig 3a) now live in separate layers:

  RM:alloc     — admission control (sched/admission.py) + priority
                 scheduling (sched/policy.py)
  RM:uncache   — drop zero-reference DeCache entries (sched/eviction.py,
                 step 1 of the memory-freeing sequence)
  RM:rollback  — delete a completed node's outputs; re-execute it later
                 (sched/eviction.py RollbackEviction)
  RM:limitdrop — drop the node sandbox's cgroup limit so its tmpfs output
                 swaps to disk (sched/eviction.py LimitDropEviction)

What remains here is what the RM actually *owns*: configuration, the
eviction counters, the completed-node set, and refcount-safe GC — the
share-awareness invariant that underlying files are freed only when
IPC-inspection-derived refcounts hit zero, so resharing never causes
use-after-free (Challenge 6).

``Executor`` is the worker-pool executor from sched/executor.py, re-
exported under its historical name; ``Executor(store, rm)`` with the
default ``workers=1`` reproduces the seed's sequential semantics exactly.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, FrozenSet, List, Optional, Set, Tuple

from .buffers import BufferStore, OOMError
from .dag import NodeState
from .deanon import KernelZero
from .decache import DeCache
from .sipc import SipcMessage
from .sched.admission import AdmissionController
from .sched.eviction import (EvictionPolicy, POLICIES, get_eviction)
from .sched.executor import ProcessWorkerExecutor, WorkerPoolExecutor
from .sched.policy import SCHEDULES, get_schedule

# historical name: benchmarks/tests/examples construct `Executor(store, rm)`
Executor = WorkerPoolExecutor

#: executor selection by name (``RMConfig.workers_mode``)
WORKERS_MODES = ("thread", "process")


@dataclass
class RMConfig:
    memory_limit: Optional[int] = None       # admission-control budget (bytes)
    admission: bool = True
    policy: str = "adaptive"                 # eviction mechanism (POLICIES)
    decache: bool = True
    sipc_mode: str = "zero"                  # full_copy|writer_copy|zero|...
    adaptive_threshold: float = 1.0 / (1.5e9)  # s/byte ≈ 1/swap-bandwidth
    direct_swap: bool = True
    schedule: str = "depth"   # scheduling priority (SCHEDULES):
    #                         # 'depth' (paper: closest-to-finishing first),
    #                         # 'breadth', 'fair', 'deadline'
    workers: int = 1          # executor worker-pool size (1 = sequential)
    reader_threads: Optional[int] = None   # per-loader zarquet reader pool:
    #                                      # fan column-chunk decompression
    #                                      # across this many threads inside
    #                                      # one read_table (None = auto:
    #                                      # ZERROW_READER_THREADS env, else
    #                                      # min(4, cpu count); 1 = serial)
    workers_mode: str = "thread"   # 'thread' (in-process pool) or 'process'
    #                              # (Flight: ops in spawned OS processes;
    #                              # needs BufferStore(backing='file'))
    cache_root: Optional[str] = None   # persistent content-addressed cache
    #                                  # directory: node outputs are
    #                                  # published under deterministic
    #                                  # fingerprints and re-runs adopt
    #                                  # unchanged nodes' outputs (CACHED)
    #                                  # instead of executing them
    publish_outputs: bool = True   # durable mode: publish every completed
    #                              # node output (False: adopt-only reader)
    flight_timeout_s: float = 600.0   # process mode: per-request reply
    #                                 # deadline on the flight data plane
    #                                 # (a worker past it is presumed hung
    #                                 # and retired; the request retries
    #                                 # on a surviving worker)
    chain_dispatch: bool = True    # process mode: ship linear picklable
    #                              # DAG segments as one exec_chain
    #                              # request (intermediates stay worker-
    #                              # local); False = per-node dispatch
    # -- overload & fault model (multi-tenant serving) ---------------------
    tenant_budgets: Optional[Dict[str, int]] = None   # per-tenant memory
    #                              # reservation ceilings (bytes); a DAG
    #                              # whose largest node cannot ever fit its
    #                              # tenant's budget is shed at offer time,
    #                              # and admission refuses claims that
    #                              # would push a tenant past its ceiling
    max_queue_depth: Optional[int] = None   # bounded admission queue: at
    #                              # most this many DAGs queued+active per
    #                              # executor; excess offers are shed with
    #                              # outcome "shed:overloaded" (None = no
    #                              # bound: batch mode, never sheds)
    overload_threshold: float = 1.0   # shed deadline-hopeless DAGs when
    #                              # (queue depth fraction) x (reservation
    #                              # pressure) reaches this
    enforce_deadlines: bool = False   # interpret DAG.deadline against
    #                              # time.monotonic() and cancel DAGs past
    #                              # it (False: deadline is an ordering
    #                              # hint only — the seed semantics)
    max_node_retries: int = 3      # process mode: transport-failure
    #                              # (FlightWorkerLost) retries per request
    #                              # before the op is quarantined as
    #                              # poisoned and fails its DAG
    retry_backoff_s: float = 0.05  # base of the capped exponential backoff
    #                              # between those retries
    verify_objects: bool = False   # manifest verify-on-adopt: re-hash
    #                              # objects against their content address
    #                              # before serving them (catches at-rest
    #                              # corruption at full-read cost)


def make_executor(store: BufferStore, rm: "ResourceManager",
                  workers: Optional[int] = None,
                  mode: Optional[str] = None) -> WorkerPoolExecutor:
    """Build the executor selected by ``mode`` (or ``rm.cfg.workers_mode``)."""
    mode = mode or getattr(rm.cfg, "workers_mode", "thread")
    assert mode in WORKERS_MODES, f"unknown workers_mode {mode!r}"
    if mode == "process":
        return ProcessWorkerExecutor(store, rm, workers)
    return WorkerPoolExecutor(store, rm, workers)


class ResourceManager:
    """Accounting + component wiring.  The admission/eviction/schedule
    components hold a back-reference here for counters and GC."""

    def __init__(self, store: BufferStore, config: RMConfig):
        assert config.policy in POLICIES, \
            f"unknown eviction policy {config.policy!r}"
        self.store = store
        self.cfg = config
        self.kz = KernelZero(store)
        self.manifest = getattr(store, "manifest", None)
        if config.cache_root and self.manifest is None:
            if store.backing != "file":
                raise ValueError(
                    "RMConfig.cache_root needs a file-backed store: "
                    "construct BufferStore(backing='file', "
                    "root=cache_root)")
            store.attach_manifest(config.cache_root)
            self.manifest = store.manifest
        self.decache = DeCache(store, enabled=config.decache,
                               manifest=self.manifest)
        if self.manifest is not None and config.verify_objects:
            self.manifest.verify_objects = True
        self.evictions = {"uncache": 0, "rollback": 0, "limitdrop": 0,
                          "spill": 0, "storm_breaks": 0}
        self.cache_stats = {"hits": 0, "published": 0, "adopted_bytes": 0}
        # serving-plane outcome counters (admission layer writes them);
        # the invariant the bench gates on: offered == admitted + shed,
        # and admitted == completed + deadline_misses + poisoned + failed
        self.serve_stats = {"offered": 0, "admitted": 0, "shed": 0,
                            "shed_overloaded": 0, "shed_deadline": 0,
                            "shed_tenant_budget": 0, "shed_quarantined": 0,
                            "deadline_misses": 0, "poisoned": 0,
                            "failed": 0, "completed": 0}
        #: poison keys (code fingerprints) of ops that repeatedly killed
        #: their worker — DAGs containing one are shed at offer time
        self.quarantined: Set[str] = set()
        self.completed_nodes: List[NodeState] = []   # eviction candidates
        self.schedule = get_schedule(config.schedule)
        self.admission = AdmissionController(self)
        self.eviction: EvictionPolicy = get_eviction(config.policy, self)
        # direct mechanism handles for the back-compat methods below
        self._rollback = get_eviction("rollback", self)
        self._limitdrop = get_eviction("limitdrop", self)

    # -- accounting (delegated to the admission layer) ---------------------
    def available(self) -> int:
        return self.admission.available()

    def admit(self, node: NodeState) -> bool:
        return self.admission.admit(node)

    def make_room_for(self, node: NodeState,
                      extra_protect: FrozenSet[Tuple[int, str]] = frozenset(),
                      ) -> None:
        self.admission.make_room_for(node, extra_protect)

    # -- poison quarantine (process-mode permanent failures) ---------------
    @staticmethod
    def poison_key(fn) -> Optional[str]:
        """Stable identity for a user op across DAG instances, so a
        quarantine entered by one request also sheds the next request
        carrying the same op.  Loader nodes (fn=None) are never
        quarantined — worker death while loading is environmental, not
        the op's fault."""
        if fn is None:
            return None
        from .fingerprint import code_fingerprint
        fp = code_fingerprint(fn)
        if fp is not None:
            return fp
        return f"{getattr(fn, '__module__', '?')}:" \
               f"{getattr(fn, '__qualname__', repr(fn))}"

    # -- memory-freeing sequence (delegated to the eviction layer) ---------
    MAX_EVICTIONS_PER_ALLOC = EvictionPolicy.MAX_EVICTIONS_PER_ALLOC

    def free_memory(self, need: int,
                    protect: Optional[NodeState] = None) -> int:
        return self.eviction.free_memory(need, protect)

    def eviction_candidates(self, protect: Optional[NodeState]
                            ) -> List[NodeState]:
        return self.eviction.victims(protect)

    def evict_node_output(self, st: NodeState) -> int:
        return self.eviction.evict(st)

    def rollback(self, st: NodeState) -> int:
        return self._rollback.evict(st)

    def limitdrop(self, st: NodeState) -> int:
        return self._limitdrop.evict(st)

    # -- cross-run differential cache (manifest adoption/publication) ------
    def adopt_cached(self, st: NodeState) -> Optional[SipcMessage]:
        """Adopt a node's published output from the manifest (zero bytes
        copied: the objects are mmap'd).  The adopted bytes are charged to
        a per-node consumer cgroup, exactly where executed-output bytes
        would land, so admission/eviction govern them.  Returns None on a
        miss (or when the entry's objects vanished)."""
        if self.manifest is None or st.fingerprint is None:
            return None
        cg = self.store.new_cgroup(f"{st.dag.name}.{st.name}.cached")
        msg = self.manifest.decode(st.fingerprint, self.store, owner=cg,
                                   label=st.name)
        if msg is None:
            return None
        st.output = msg
        st.output_bytes = msg.new_bytes
        self.cache_stats["hits"] += 1
        self.cache_stats["adopted_bytes"] += msg.new_bytes
        if st not in self.completed_nodes:
            self.completed_nodes.append(st)   # adopted bytes are evictable
        return msg

    def publish_output(self, st: NodeState) -> None:
        """Durably publish a completed node's output under its
        fingerprint (best-effort: a full disk must not fail the run)."""
        if (self.manifest is None or not self.cfg.publish_outputs
                or st.fingerprint is None or st.output is None
                or st.output.released):
            return
        try:
            self.manifest.publish(self.store, st.fingerprint, st.output,
                                  label=f"{st.dag.name}.{st.name}")
            self.cache_stats["published"] += 1
        except OSError:
            pass

    def is_durable(self, st: NodeState) -> bool:
        """True when this node's output is recoverable from the manifest,
        so eviction may spill (drop mappings) instead of discarding."""
        return self.manifest is not None and st.fingerprint in self.manifest

    # -- refcount GC (the share-awareness invariant) -----------------------
    def _resident_of(self, msg: SipcMessage) -> int:
        total = 0
        for fid in msg.files_referenced():
            f = self.store.files.get(fid)
            if f is not None and f.refcount == 1 and not f.decache_pinned:
                total += f.resident_bytes()
        return total

    def _gc(self, msg: SipcMessage) -> None:
        for fid in msg.files_referenced():
            f = self.store.files.get(fid)
            if f is not None and f.refcount == 0 and not f.decache_pinned:
                self.store.delete_file(fid)

    def release_output(self, st: NodeState) -> None:
        if st.output is not None and not st.output.released:
            msg = st.output
            msg.release()
            self._gc(msg)

"""Resource Manager + DAG executor (paper §3.1, §3.3, §4.2.5).

The RM owns four actions (Fig 3a):
  RM:alloc     — admission control + depth-first priority scheduling
  RM:uncache   — drop zero-reference DeCache entries
  RM:rollback  — delete a completed node's outputs; re-execute it later
                 (cascading up the pipeline if its own inputs were GC'd)
  RM:limitdrop — drop the node sandbox's cgroup limit so its tmpfs output
                 swaps to disk; restore the limit afterwards

*Adaptive eviction* picks rollback vs limit-dropping per node from the
ratio of its execution latency to its output size (threshold ≈ 1/swap
bandwidth, tuned offline — paper §3.3).

Share-awareness: eviction operates on *virtual* Arrow artifacts; the
underlying files are freed only when IPC-inspection-derived refcounts hit
zero, so resharing never causes use-after-free (Challenge 6).
"""

from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

from .buffers import BufferStore, OOMError
from .dag import (DAG, DONE, EVICTED, NodeState, RUNNING, Sandbox, WAITING)
from .deanon import KernelZero
from .decache import DeCache
from .sipc import SipcMessage
from . import zarquet

POLICIES = ("none", "kswap", "rollback", "limitdrop", "adaptive")


@dataclass
class RMConfig:
    memory_limit: Optional[int] = None       # admission-control budget (bytes)
    admission: bool = True
    policy: str = "adaptive"                 # eviction mechanism
    decache: bool = True
    sipc_mode: str = "zero"                  # full_copy|writer_copy|zero|...
    adaptive_threshold: float = 1.0 / (1.5e9)  # s/byte ≈ 1/swap-bandwidth
    direct_swap: bool = True
    schedule: str = "depth"   # 'depth' (paper: closest-to-finishing first)
    #                         # or 'breadth' (models concurrent DAG starts)


class ResourceManager:
    def __init__(self, store: BufferStore, config: RMConfig):
        assert config.policy in POLICIES
        self.store = store
        self.cfg = config
        self.kz = KernelZero(store)
        self.decache = DeCache(store, enabled=config.decache)
        self.evictions: Dict[str, int] = {"uncache": 0, "rollback": 0,
                                          "limitdrop": 0}
        self.completed_nodes: List[NodeState] = []   # eviction candidates

    # -- accounting -------------------------------------------------------
    def available(self) -> int:
        if self.cfg.memory_limit is None:
            return 1 << 62
        return self.cfg.memory_limit - self.store.global_charged

    # -- RM:alloc ----------------------------------------------------------
    def admit(self, node: NodeState) -> bool:
        """Non-destructive admission check: does the node fit right now?"""
        if not self.cfg.admission:
            return True
        return node.spec.est_mem <= self.available()

    def make_room_for(self, node: NodeState) -> None:
        """Evict outputs one by one until the next scheduled node fits
        (paper §3.3).  Called only for the definitively chosen node."""
        if self.cfg.policy in ("none", "kswap") or not self.cfg.admission:
            return
        need = node.spec.est_mem - self.available()
        if need > 0:
            self.free_memory(need, protect=node)

    # -- RM memory-freeing sequence (paper §3.3) ----------------------------
    MAX_EVICTIONS_PER_ALLOC = 8   # bound eviction storms: past this the
    #                             # node runs over budget instead of the RM
    #                             # rolling back half the fleet's progress

    def free_memory(self, need: int,
                    protect: Optional[NodeState] = None) -> int:
        freed = 0
        # 1) uncache DeCache entries with no active references
        for e in self.decache.uncache_candidates():
            if freed >= need:
                return freed
            freed += self.decache.uncache(e)
            self.evictions["uncache"] += 1
        # 2) evict outputs of the lowest-priority completed nodes
        for n_evicted, st in enumerate(self.eviction_candidates(protect)):
            if freed >= need or n_evicted >= self.MAX_EVICTIONS_PER_ALLOC:
                break
            freed += self.evict_node_output(st)
        return freed

    def eviction_candidates(self, protect: Optional[NodeState]
                            ) -> List[NodeState]:
        protected = set()
        if protect is not None:
            protected = {(protect.dag.id, d) for d in protect.spec.deps}
        cands = [st for st in self.completed_nodes
                 if st.status == DONE and st.output is not None
                 and not st.output.released
                 and (st.dag.id, st.name) not in protected
                 and not (st.is_loader and self.decache.enabled)]
        # Victim order: lowest-priority = scheduled LAST.  Least-progressed
        # DAG first; ties broken by dag id DESCENDING (the scheduler picks
        # ascending ids, so the highest id is needed latest — evicting the
        # next-to-run DAG's frontier would thrash).  Within a DAG, deepest
        # output first — releasing the pipeline frontier is what actually
        # frees exclusively-owned files ('rollback the pipeline', §3.3).
        progress = {}
        for st in cands:
            d = st.dag
            if d.id not in progress:
                done = sum(1 for n in d.nodes.values() if n.status == DONE)
                progress[d.id] = done / max(len(d.nodes), 1)
        cands.sort(key=lambda st: (progress[st.dag.id], -st.dag.id,
                                   -st.depth))
        return cands

    def evict_node_output(self, st: NodeState) -> int:
        mech = self.cfg.policy
        if mech == "adaptive":
            ratio = st.exec_latency / max(st.output_bytes, 1)
            mech = "limitdrop" if ratio > self.cfg.adaptive_threshold \
                else "rollback"
        if mech == "rollback":
            return self.rollback(st)
        return self.limitdrop(st)

    # -- RM:rollback --------------------------------------------------------
    def rollback(self, st: NodeState) -> int:
        freed = self._resident_of(st.output)
        msg = st.output
        st.output = None
        msg.release()
        self._gc(msg)
        # re-execution is only scheduled if un-run children still need the
        # output (otherwise the release is pure GC; a later cascading
        # rollback can still resurrect it via _ensure_deps)
        kids = [st.dag.nodes[c] for c in st.dag.children[st.name]]
        if any(k.status != DONE for k in kids):
            st.status = EVICTED
        self.evictions["rollback"] += 1
        if st in self.completed_nodes:
            self.completed_nodes.remove(st)
        return freed

    # -- RM:limitdrop ---------------------------------------------------------
    def limitdrop(self, st: NodeState) -> int:
        if st.sandbox is None:
            return 0
        swapped = st.sandbox.drop_limit_and_swap()
        self.evictions["limitdrop"] += 1
        if st in self.completed_nodes:
            self.completed_nodes.remove(st)   # only evict once
        return swapped

    # -- refcount GC -----------------------------------------------------------
    def _resident_of(self, msg: SipcMessage) -> int:
        total = 0
        for fid in msg.files_referenced():
            f = self.store.files.get(fid)
            if f is not None and f.refcount == 1 and not f.decache_pinned:
                total += f.resident_bytes()
        return total

    def _gc(self, msg: SipcMessage) -> None:
        for fid in msg.files_referenced():
            f = self.store.files.get(fid)
            if f is not None and f.refcount == 0 and not f.decache_pinned:
                self.store.delete_file(fid)

    def release_output(self, st: NodeState) -> None:
        if st.output is not None and not st.output.released:
            msg = st.output
            msg.release()
            self._gc(msg)


class Executor:
    """Sequential scheduler driven by RM admission + priorities.

    One node runs at a time (single-core container); 'parallelism' is the
    interleaving the scheduler chooses, and all memory effects (resident
    growth, swap I/O, recompute) are real.
    """

    def __init__(self, store: BufferStore, rm: ResourceManager):
        self.store = store
        self.rm = rm
        self.node_runs = 0
        self.load_runs = 0

    # -- main loop -----------------------------------------------------------
    def run(self, dags: List[DAG], deadline_s: float = 3600.0) -> float:
        t0 = time.perf_counter()
        active: Dict[int, DAG] = {d.id: d for d in dags}
        # DeCache attachments per dag: key -> entry
        attach: Dict[int, list] = {d.id: [] for d in dags}
        while active:
            if time.perf_counter() - t0 > deadline_s:
                raise TimeoutError("executor deadline exceeded")
            cands: List[Tuple[int, int, NodeState]] = []
            for d in active.values():
                for st in d.runnable():
                    # depth-first: deepest (closest to finishing) first;
                    # 'breadth' models concurrently-started DAGs
                    key = -st.depth if self.rm.cfg.schedule == "depth" \
                        else st.depth
                    cands.append((key, d.id, st))
            if not cands:
                # nothing runnable: either all running deps broken or done
                for did in [i for i, d in active.items() if d.all_done()]:
                    self._finish_dag(active.pop(did), attach[did])
                if not active:
                    break
                raise RuntimeError("scheduler stall: no runnable node")
            cands.sort(key=lambda t: (t[0], t[1]))
            picked = None
            for _, _, st in cands:
                self._ensure_deps(st)
            # re-collect after cascading rollbacks
            cands = []
            for d in active.values():
                for st in d.runnable():
                    key = -st.depth if self.rm.cfg.schedule == "depth" \
                        else st.depth
                    cands.append((key, d.id, st))
            cands.sort(key=lambda t: (t[0], t[1]))
            # fast path: highest-priority node that already fits
            for _, _, st in cands:
                if self.rm.admit(st):
                    picked = st
                    break
            if picked is None:
                # nothing fits: evict for the highest-priority node only
                # (paper: 'outputs are evicted one by one until the available
                # memory is larger than the requirement of the node scheduled
                # to run next'); kswap/no-admission runs it anyway and lets
                # kernel swap / OOM handle the overflow
                picked = cands[0][2]
                self.rm.make_room_for(picked)
            if any(picked.dag.nodes[d].output is None or
                   picked.dag.nodes[d].output.released
                   for d in picked.spec.deps):
                continue  # an eviction broke a dep; re-plan
            self._run_node(picked, attach[picked.dag.id])
            for did in [i for i, d in active.items() if d.all_done()]:
                self._finish_dag(active.pop(did), attach[did])
        return time.perf_counter() - t0

    # -- cascading rollback repair ---------------------------------------------
    def _ensure_deps(self, st: NodeState) -> None:
        for dep_name in st.spec.deps:
            dep = st.dag.nodes[dep_name]
            if dep.status == DONE and (dep.output is None or
                                       dep.output.released):
                if dep.is_loader and self.rm.decache.enabled:
                    e = self.rm.decache.lookup(dep.decache_key())
                    if e is not None:
                        dep.output = self.rm.decache.attach(e)
                        continue
                dep.status = EVICTED
                dep.output = None
                self._ensure_deps(dep)

    # -- node execution -----------------------------------------------------------
    def _run_node(self, st: NodeState, attachments: list) -> None:
        st.status = RUNNING
        self.node_runs += 1
        t0 = time.perf_counter()
        if st.is_loader:
            self._run_loader(st, attachments)
        else:
            sb = Sandbox(self.store, self.rm.kz,
                         f"{st.dag.name}.{st.name}#{st.runs}",
                         mode=self.rm.cfg.sipc_mode)
            st.sandbox = sb
            inputs = [st.dag.nodes[d].output for d in st.spec.deps]
            st.output = sb.run(st.spec.fn, inputs, label=st.name)
            st.output_bytes = st.output.new_bytes
        st.exec_latency = time.perf_counter() - t0
        st.status = DONE
        st.runs += 1
        if st not in self.rm.completed_nodes:
            self.rm.completed_nodes.append(st)
        # NOTE: outputs are retained until DAG completion (paper §3.1) —
        # freeing earlier would defeat rollback and share-aware eviction.

    def _run_loader(self, st: NodeState, attachments: list) -> None:
        key = st.decache_key()
        e = self.rm.decache.lookup(key)
        if e is not None:
            st.output = self.rm.decache.attach(e)
            attachments.append(e)
            st.output_bytes = 0
            return
        self.load_runs += 1
        sb = Sandbox(self.store, self.rm.kz,
                     f"{st.dag.name}.{st.name}#{st.runs}",
                     mode=self.rm.cfg.sipc_mode)
        st.sandbox = sb
        # generic loader 'user code' (paper §4.2.4): deserialize zarquet,
        # registering every fresh buffer as sandbox anonymous memory
        table = zarquet.read_table(
            st.spec.source, dict_columns=st.spec.dict_columns,
            on_buffer=lambda a: sb.register_anon(a))
        st.output = sb.write_output(table, label=st.name)
        st.output_bytes = st.output.new_bytes
        if self.rm.decache.enabled:
            e = self.rm.decache.insert(key, st.output,
                                       time.perf_counter())
            self.rm.decache.attach(e)
            attachments.append(e)

    def _finish_dag(self, dag: DAG, attachments: list) -> None:
        dag.done = True
        for st in dag.nodes.values():
            if st.spec.keep_output:
                continue   # external consumer owns it (releases the msg)
            if not (st.is_loader and self.rm.decache.enabled):
                self.rm.release_output(st)
            if st.sandbox is not None:
                st.sandbox.destroy()
            if st in self.rm.completed_nodes:
                self.rm.completed_nodes.remove(st)
        for e in attachments:
            self.rm.decache.detach(e)

"""Declarative query frontend + zero-copy-aware logical optimizer.

Users of the op surface (select/filter/sort/dict-encode/join/group_by/
filter_join) no longer hand-wire DAG nodes: a dataframe-style builder
(:mod:`builder`) constructs a logical plan, a rule-based optimizer
(:mod:`rules`) rewrites it, and the compiler (:mod:`compiler`) lowers it
to the existing fingerprinted ``dag.py`` nodes — so plans flow unchanged
through the thread/process executors, chain shipping, and the
differential cache (the Bauplan framing: pipelines are declaratively
specified function DAGs the platform is free to re-plan).

    from repro.core.plan import scan, col, compile_plans

    orders = scan("orders.zq")
    cust   = scan("customers.zq", dict_columns=("country",))
    mart   = (orders.filter(col("amount") > 0)
                    .join(cust, on="cust")
                    .group_by("country", {"rev": ("amount", "sum")}))
    cp = compile_plans({"mart": mart})       # one optimized DAG
    executor.run([cp.dag]); table = cp.read(store, "mart")

Optimizer passes (all unusually profitable under zero-copy):
  * projection pruning — loaders narrow to the referenced column subset
    (``NodeSpec.columns`` -> ``zarquet.read_table(columns=)``): unused
    columns are never read, decompressed, charged, or deanonymized;
  * filter pushdown — filters sink below joins/projects/sorts, shrinking
    every downstream gather;
  * filter->join fusion — a filter directly under a join rewrites to the
    fused ``ops.filter_join`` gather (one gather, no materialized
    filtered intermediate);
  * common-subplan dedup — structurally identical subtrees across sink
    plans compile to ONE DAG node cone; node fingerprints then make the
    shared cone DeCache/manifest-shared across runs too.

``explain_plans``/``Plan.explain`` dump the pre/post-optimization trees
with per-pass annotations.
"""

from .expr import Col, Expr, Lit, col, eval_predicate, lit
from .builder import (Filter, FilterJoin, GroupBy, Join, Limit, LNode,
                      Plan, Project, Scan, Sort, scan)
from .rules import Trace, optimize_plans
from .compiler import CompiledPlan, compile_plans, explain_plans

__all__ = [
    "Col", "Expr", "Lit", "col", "eval_predicate", "lit",
    "Filter", "FilterJoin", "GroupBy", "Join", "Limit", "LNode", "Plan",
    "Project", "Scan", "Sort", "scan",
    "Trace", "optimize_plans",
    "CompiledPlan", "compile_plans", "explain_plans",
]

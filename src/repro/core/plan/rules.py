"""Rule-based logical optimizer: rewrites that are cheap to prove and
unusually profitable under zero-copy execution.

Pass order matters and is fixed:

1. ``pushdown_filters`` (per sink, to fixpoint) — filters sink through
   projects/sorts and below joins.  Conjuncts of a top-level ``&`` route
   independently: a conjunct reading only left-side columns pushes left
   under *inner and left* joins (left values are copied verbatim to the
   output, one-or-more output rows per surviving left row); a conjunct
   reading only right-side columns pushes right under *inner* joins
   only — under a left join it would resurrect unmatched rows as
   null-padded output that the original plan filtered out.  A conjunct
   naming a suffixed collision column (``x_right``) or columns from both
   sides stays above the join.
2. ``fuse_filter_join`` (per sink) — a ``Filter`` directly under either
   join input lifts into the fused ``FilterJoin`` node
   (``ops.filter_join``: masks compose into the join gather, the
   filtered intermediate is never materialized).  This is a literal
   rewrite — ``filter_join(l, r, left_mask=p)`` is *defined* as
   ``join(filter(l, p), r)`` for both join types — so it needs no
   side conditions.
3. ``prune_projections`` (global, across all sinks) — walks every sink
   top-down accumulating the demanded column set per *structural* scan
   key, then narrows each ``Scan`` to the union of demands.  Only scans
   are rewritten: narrowing interior nodes could change join collision
   naming, and per-sink narrowing would split structurally shared
   subtrees into per-sink variants (defeating pass 4).  The union keeps
   a scan shared by two marts identical in both — one loader node, one
   DeCache entry, one manifest row.  Collision naming is preserved
   explicitly: when a plan demands ``x_right``, the pruner keeps ``x``
   on *both* sides so the right column still collides and still gets
   the suffix.
4. ``dedup_subplans`` (global) — annotation pass: counts structurally
   identical subtrees (equal ``LNode.key()``) across sinks.  The
   compiler realizes the sharing by memoizing lowered nodes on the same
   key, so two marts over one staging subtree compile to a single
   shared node cone (and, via node fingerprints, to one cached cone
   across runs).

Every rewrite appends a human-readable note to the ``Trace``;
``explain()`` replays them between the pre- and post-optimization trees.
"""

from __future__ import annotations

import os
from typing import Dict, List, Optional, Tuple

from .builder import (Filter, FilterJoin, GroupBy, Join, Limit, LNode,
                      Project, Scan, Sort)
from .expr import and_all, split_conjuncts

__all__ = ["Trace", "pushdown_filters", "fuse_filter_join",
           "prune_projections", "dedup_subplans", "optimize_plans"]

#: safety valve for the pushdown fixpoint loop (plans are tiny; a
#: correct pass converges in O(depth) iterations)
_MAX_PUSHDOWN_ROUNDS = 10


class Trace:
    """Ordered per-pass annotations accumulated during optimization."""

    def __init__(self):
        self.notes: List[Tuple[str, str]] = []

    def add(self, rule: str, note: str) -> None:
        self.notes.append((rule, note))

    def lines(self) -> List[str]:
        return [f"[{rule}] {note}" for rule, note in self.notes]

    def __repr__(self):
        return f"Trace({len(self.notes)} notes)"


# --------------------------------------------------------------------------
# pass 1: filter pushdown
# --------------------------------------------------------------------------

def pushdown_filters(root: LNode, trace: Trace, sink: str = "plan") -> LNode:
    """Sink filters toward the scans (see module docstring for the join
    side conditions).  Runs bottom-up passes to fixpoint."""
    for _ in range(_MAX_PUSHDOWN_ROUNDS):
        new = _push(root, trace, sink)
        if new.key() == root.key():
            return new
        root = new
    return root


def _push(node: LNode, trace: Trace, sink: str) -> LNode:
    node = node.with_children([_push(c, trace, sink)
                               for c in node.children])
    if not isinstance(node, Filter):
        return node
    child = node.children[0]
    pred = node.predicate

    if isinstance(child, Filter):
        # merge adjacent filters so conjunct routing sees all pieces
        return Filter(child.children[0],
                      and_all([child.predicate, pred]))

    if isinstance(child, (Project, Sort)):
        # predicate columns are a subset of the child's output, which
        # project/sort pass through unchanged -> commute
        trace.add("pushdown_filter",
                  f"{sink}: pushed {pred!r} below {child.kind}")
        return child.with_children(
            [Filter(child.children[0], pred)])

    if isinstance(child, Join):
        left, right = child.children
        lnames, rnames = left.schema(), right.schema()
        lset, rset = set(lnames), set(rnames)
        keys = set(child.on)
        to_left, to_right, keep = [], [], []
        for c in split_conjuncts(pred):
            cols = c.columns()
            if cols and cols <= lset:
                to_left.append(c)
            elif (child.how == "inner" and cols and cols <= rset
                  and not (cols & (lset - keys))):
                # non-key left/right name collisions resolve to the LEFT
                # column in the join output, so only push right when no
                # referenced column collides; key columns are equal on
                # matched rows (inner join ⇒ no null keys in output)
                to_right.append(c)
            else:
                keep.append(c)
        if not to_left and not to_right:
            return node
        if to_left:
            left = Filter(left, and_all(to_left))
            trace.add("pushdown_filter",
                      f"{sink}: pushed {and_all(to_left)!r} below join "
                      f"(left side)")
        if to_right:
            right = Filter(right, and_all(to_right))
            trace.add("pushdown_filter",
                      f"{sink}: pushed {and_all(to_right)!r} below join "
                      f"(right side)")
        out: LNode = child.with_children([left, right])
        if keep:
            out = Filter(out, and_all(keep))
        return out

    # Limit: NOT safe (filter-then-limit != limit-then-filter);
    # GroupBy/FilterJoin/Scan: stop
    return node


# --------------------------------------------------------------------------
# pass 2: filter -> join fusion
# --------------------------------------------------------------------------

def fuse_filter_join(root: LNode, trace: Trace, sink: str = "plan") -> LNode:
    """Rewrite ``Join`` whose input(s) are ``Filter``s into the fused
    ``FilterJoin`` node (lowered to ``ops.filter_join``)."""
    def rec(node: LNode) -> LNode:
        node = node.with_children([rec(c) for c in node.children])
        if not isinstance(node, Join):
            return node
        left, right = node.children
        lp = rp = None
        if isinstance(left, Filter):
            lp, left = left.predicate, left.children[0]
        if isinstance(right, Filter):
            rp, right = right.predicate, right.children[0]
        if lp is None and rp is None:
            return node
        sides = "+".join(s for s, p in
                         (("left", lp), ("right", rp)) if p is not None)
        trace.add("fuse_filter_join",
                  f"{sink}: fused {sides} filter(s) into filter_join "
                  f"on={node.on!r}")
        return FilterJoin(left, right, node.on, node.how, node.suffix,
                          lp, rp)
    return rec(root)


# --------------------------------------------------------------------------
# pass 3: projection pruning (global)
# --------------------------------------------------------------------------

def _child_demands(node: LNode, needed: set) -> List[set]:
    """Column sets each child must provide so that ``node`` can emit
    ``needed`` (needed ⊆ node.schema())."""
    if isinstance(node, Scan):
        return []
    if isinstance(node, Project):
        # the project is a demand anchor: its output IS the user's ask
        return [set(node.columns)]
    if isinstance(node, Filter):
        return [needed | node.predicate.columns()]
    if isinstance(node, Sort):
        return [needed | {node.by}]
    if isinstance(node, Limit):
        return [set(needed)]
    if isinstance(node, GroupBy):
        return [set(node.keys) |
                {src for src, _how in node.aggs.values()}]
    if isinstance(node, (Join, FilterJoin)):
        left, right = node.children
        lnames, rnames = left.schema(), right.schema()
        lset, rset = set(lnames), set(rnames)
        keys = set(node.on)
        ln, rn = set(keys), set(keys)
        sfx = node.suffix
        for n in needed:
            if n in lset:
                ln.add(n)
            if n in rset and n not in lset:
                rn.add(n)
            if n.endswith(sfx):
                base = n[:-len(sfx)]
                if base in lset and base in rset and base not in keys:
                    # keep the collision on BOTH sides so the right
                    # column still gets its suffix
                    ln.add(base)
                    rn.add(base)
        if isinstance(node, FilterJoin):
            if node.left_pred is not None:
                ln |= node.left_pred.columns()
            if node.right_pred is not None:
                rn |= node.right_pred.columns()
        return [ln & lset, rn & rset]
    raise TypeError(f"unknown node kind: {node.kind}")


def prune_projections(roots: Dict[str, LNode], trace: Trace
                      ) -> Dict[str, LNode]:
    """Narrow every ``Scan`` to the union of columns demanded across ALL
    sink plans (union keeps shared subtrees structurally identical)."""
    demand: Dict[str, set] = {}

    def walk(node: LNode, needed: set) -> None:
        if isinstance(node, Scan):
            demand.setdefault(node.key(), set()).update(needed)
            return
        for child, cn in zip(node.children, _child_demands(node, needed)):
            walk(child, cn)

    for root in roots.values():
        walk(root, set(root.schema()))

    rewritten: Dict[str, LNode] = {}

    def rewrite(node: LNode) -> LNode:
        k = node.key()
        if k in rewritten:
            return rewritten[k]
        if isinstance(node, Scan):
            footer = node.schema()
            want = demand.get(k, set(footer))
            cols = [n for n in footer if n in want] or footer[:1]
            if set(cols) == set(footer) and node.columns is None:
                out: LNode = node
            elif node.columns is not None and set(cols) == set(node.columns):
                out = node
            else:
                out = Scan(node.path, tuple(cols),
                           tuple(d for d in node.dict_columns
                                 if d in set(cols)))
                trace.add("prune_projection",
                          f"scan {os.path.basename(node.path)}: load "
                          f"{len(cols)}/{len(footer)} columns "
                          f"{tuple(cols)!r}")
        else:
            out = node.with_children([rewrite(c) for c in node.children])
        rewritten[k] = out
        return out

    return {sink: rewrite(root) for sink, root in roots.items()}


# --------------------------------------------------------------------------
# pass 4: common-subplan dedup (annotation; the compiler's key-memo
# realizes the sharing)
# --------------------------------------------------------------------------

def subplan_counts(roots: Dict[str, LNode]) -> Dict[str, int]:
    """Occurrences of each structural key across the sink forest (each
    distinct parent edge counts once)."""
    counts: Dict[str, int] = {}

    def walk(node: LNode) -> None:
        counts[node.key()] = counts.get(node.key(), 0) + 1
        for c in node.children:
            walk(c)

    for root in roots.values():
        walk(root)
    return counts


def dedup_subplans(roots: Dict[str, LNode], trace: Trace) -> None:
    counts = subplan_counts(roots)
    shared = {k: n for k, n in counts.items() if n > 1}
    if shared:
        saved = sum(n - 1 for n in shared.values())
        trace.add("dedup_subplan",
                  f"{len(shared)} shared subtree(s) across "
                  f"{len(roots)} sink(s): {saved} duplicate node "
                  f"cone(s) elided at compile")


# --------------------------------------------------------------------------
# driver
# --------------------------------------------------------------------------

def optimize_plans(roots: Dict[str, LNode],
                   trace: Optional[Trace] = None
                   ) -> Tuple[Dict[str, LNode], Trace]:
    """Run all passes in order; returns (optimized roots, trace)."""
    if trace is None:
        trace = Trace()
    out = {}
    for sink, root in roots.items():
        root = pushdown_filters(root, trace, sink)
        root = fuse_filter_join(root, trace, sink)
        out[sink] = root
    out = prune_projections(out, trace)
    dedup_subplans(out, trace)
    return out, trace

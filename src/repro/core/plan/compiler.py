"""Lower logical plans to fingerprinted DAG nodes.

The compiler emits plain ``dag.NodeSpec``s whose ``fn``s are
``functools.partial``s over the module-level executors below — exactly
the shape every other layer already understands, so a compiled plan
flows unchanged through:

* the thread/process executors (partials pickle across the Flight
  boundary; no new wire ops);
* chain shipping (linear filter/select/sort segments fuse into
  ``exec_chain`` like any hand-built DAG);
* differential caching (the partial's keywords canonicalize via the
  expression trees' stable reprs, so the same plan built next run
  fingerprints identically, and the executor fns declare
  ``__fp_includes__`` for the kernels *and the optimizer rules* they
  embody — editing a rewrite rule invalidates the outputs it shaped).

Sharing: with ``optimize=True`` the lowering memoizes on ``LNode.key()``
— structurally identical subtrees across sinks become ONE node cone
(one loader, one DeCache entry, one manifest row).  With
``optimize=False`` (the naive baseline) every occurrence lowers to its
own nodes, which is what a hand-wired per-mart pipeline would build.
"""

from __future__ import annotations

import functools
import os
from typing import Dict, List, Optional, Sequence, Union

from .. import ops
from ..dag import DAG, NodeSpec
from .builder import (Filter, FilterJoin, GroupBy, Join, Limit, LNode,
                      Plan, Project, Scan, Sort)
from .expr import EVAL_FP, eval_predicate
from .rules import (Trace, fuse_filter_join, optimize_plans,
                    prune_projections, pushdown_filters, subplan_counts)

__all__ = ["CompiledPlan", "compile_plans", "explain_plans",
           "filter_exec", "project_exec", "sort_exec", "limit_exec",
           "filter_join_exec"]

_MIN_EST = 1 << 20


# --------------------------------------------------------------------------
# node executors (module-level: picklable + fingerprintable as partials)
# --------------------------------------------------------------------------

def filter_exec(tables, predicate):
    return ops.filter_rows(
        tables[0], functools.partial(eval_predicate, expr=predicate))


def project_exec(tables, columns):
    return ops.select_columns(tables[0], list(columns))


def sort_exec(tables, by, descending=False):
    return ops.sort_by(tables[0], by, descending)


def limit_exec(tables, n):
    return ops.slice_rows(tables[0], 0, n)


def filter_join_exec(tables, on, how="inner", suffix="_right",
                     left_pred=None, right_pred=None):
    lm = None if left_pred is None else \
        functools.partial(eval_predicate, expr=left_pred)
    rm = None if right_pred is None else \
        functools.partial(eval_predicate, expr=right_pred)
    return ops.filter_join(tables[0], tables[1], on=on, how=how,
                           suffix=suffix, left_mask=lm, right_mask=rm)


#: fingerprint pinning (same contract as ops.join pinning the relational
#: vkernels): each executor folds in (a) the op + predicate-evaluation
#: code it runs and (b) the REWRITE RULES that may have shaped the node —
#: editing pushdown/fusion/pruning invalidates the cached outputs those
#: rules produced, even when the lowered structure happens to coincide
filter_exec.__fp_includes__ = \
    (ops.filter_rows, pushdown_filters) + EVAL_FP
project_exec.__fp_includes__ = (ops.select_columns, prune_projections)
sort_exec.__fp_includes__ = (ops.sort_by,)
limit_exec.__fp_includes__ = (ops.slice_rows,)
filter_join_exec.__fp_includes__ = \
    (ops.filter_join, fuse_filter_join, pushdown_filters) + EVAL_FP


# --------------------------------------------------------------------------
# lowering
# --------------------------------------------------------------------------

def _normalize(plans) -> Dict[str, LNode]:
    if isinstance(plans, (Plan, LNode)):
        plans = {"plan": plans}
    elif isinstance(plans, (list, tuple)):
        plans = {f"sink{i}": p for i, p in enumerate(plans)}
    return {sink: (p.root if isinstance(p, Plan) else p)
            for sink, p in plans.items()}


class CompiledPlan:
    """A lowered plan: one DAG plus the sink-name -> node-name map."""

    def __init__(self, dag: DAG, sinks: Dict[str, str], trace: Trace,
                 roots: Dict[str, LNode]):
        self.dag = dag
        self.sinks = sinks
        self.trace = trace
        self.roots = roots          # post-optimization logical roots

    def read(self, store, sink: Optional[str] = None):
        """Materialize a sink's output table (after ``executor.run``)."""
        from ..sipc import SipcReader
        if sink is None:
            assert len(self.sinks) == 1, \
                f"multiple sinks {sorted(self.sinks)}: name one"
            sink = next(iter(self.sinks))
        node = self.dag.nodes[self.sinks[sink]]
        assert node.output is not None, f"sink {sink!r} has no output " \
            f"(run the DAG first, and keep_output must hold it)"
        return SipcReader(store).read_table(node.output)

    def __repr__(self):
        return (f"CompiledPlan<{self.dag.name}: {len(self.dag.nodes)} "
                f"nodes, sinks={sorted(self.sinks)}>")


def _scan_est(node: Scan) -> int:
    try:
        size = os.path.getsize(node.path)
    except OSError:
        return _MIN_EST
    sel = node.schema()                 # also caches the footer names
    full_n = len(node._footer_names)
    frac = len(sel) / full_n if full_n else 1.0
    return max(int(size * 8 * frac), _MIN_EST)


def compile_plans(plans, *, optimize: bool = True, name: str = "query",
                  deadline: Optional[float] = None,
                  tenant: Optional[str] = None) -> CompiledPlan:
    """Compile one or more sink plans into a single DAG.

    ``plans``: a ``Plan``, a list of plans, or ``{sink_name: Plan}``.
    ``optimize=False`` lowers the trees verbatim, one node per
    occurrence — the naive baseline benchmarked in bench_query.
    ``deadline``/``tenant`` plumb through to the DAG for the
    deadline-aware and fair-share scheduling policies."""
    roots = _normalize(plans)
    trace = Trace()
    if optimize:
        roots, trace = optimize_plans(roots, trace)

    specs: List[NodeSpec] = []
    by_name: Dict[str, NodeSpec] = {}
    used = set()
    memo: Dict[str, str] = {}

    def fresh(base: str) -> str:
        nm, i = base, 1
        while nm in used:
            nm = f"{base}~{i}"
            i += 1
        used.add(nm)
        return nm

    def lower(node: LNode, prefer: Optional[str] = None) -> str:
        if optimize and prefer is None and node.key() in memo:
            return memo[node.key()]
        deps = [lower(c) for c in node.children]
        if isinstance(node, Scan):
            stem = os.path.splitext(os.path.basename(node.path))[0]
            spec = NodeSpec(
                fresh(prefer or f"scan_{stem}"), source=node.path,
                dict_columns=tuple(node.dict_columns),
                columns=node.columns, est_mem=_scan_est(node))
        else:
            dep_est = [by_name[d].est_mem for d in deps]
            if isinstance(node, Filter):
                fn = functools.partial(filter_exec,
                                       predicate=node.predicate)
                est = dep_est[0]
            elif isinstance(node, Project):
                fn = functools.partial(project_exec,
                                       columns=tuple(node.columns))
                est = dep_est[0]
            elif isinstance(node, Sort):
                fn = functools.partial(sort_exec, by=node.by,
                                       descending=node.descending)
                est = dep_est[0]
            elif isinstance(node, Limit):
                fn = functools.partial(limit_exec, n=node.n)
                est = max(dep_est[0] // 2, _MIN_EST)
            elif isinstance(node, Join):
                fn = functools.partial(ops.join_node, on=list(node.on),
                                       how=node.how, suffix=node.suffix)
                est = sum(dep_est)
            elif isinstance(node, FilterJoin):
                fn = functools.partial(
                    filter_join_exec, on=list(node.on), how=node.how,
                    suffix=node.suffix, left_pred=node.left_pred,
                    right_pred=node.right_pred)
                est = sum(dep_est)
            elif isinstance(node, GroupBy):
                fn = functools.partial(ops.group_by_node,
                                       keys=list(node.keys),
                                       aggs=dict(node.aggs))
                est = max(dep_est[0] // 2, _MIN_EST)
            else:
                raise TypeError(f"unknown node kind: {node.kind}")
            spec = NodeSpec(fresh(prefer or node.kind), fn=fn, deps=deps,
                            est_mem=max(est, _MIN_EST))
        specs.append(spec)
        by_name[spec.name] = spec
        if optimize:
            memo[node.key()] = spec.name
        return spec.name

    sinks: Dict[str, str] = {}
    for sink, root in roots.items():
        if optimize and root.key() in memo:
            sinks[sink] = memo[root.key()]       # two identical sinks
        else:
            sinks[sink] = lower(root, prefer=sink)
    for nm in set(sinks.values()):
        by_name[nm].keep_output = True

    dag = DAG(specs, name=name, deadline=deadline, tenant=tenant)
    return CompiledPlan(dag, sinks, trace, roots)


# --------------------------------------------------------------------------
# explain
# --------------------------------------------------------------------------

def _render(node: LNode, depth: int, lines: List[str],
            counts: Dict[str, int]) -> None:
    mark = " [shared]" if counts.get(node.key(), 0) > 1 else ""
    lines.append("  " * depth + node.describe() + mark)
    for c in node.children:
        _render(c, depth + 1, lines, counts)


def explain_plans(plans, optimize: bool = True) -> str:
    """Pre/post-optimization tree dump with per-pass annotations.
    Structurally shared subtrees in the optimized forest are marked
    ``[shared]`` (they compile to one node cone)."""
    roots = _normalize(plans)
    lines = ["== logical plan" +
             (" (pre-optimization)" if optimize else "") + " =="]
    for sink, root in roots.items():
        lines.append(f"{sink}:")
        _render(root, 1, lines, {})
    if optimize:
        opt, trace = optimize_plans(roots)
        lines += ["", "== optimizer passes =="]
        lines += trace.lines() or ["(no rewrites)"]
        lines += ["", "== optimized plan =="]
        counts = subplan_counts(opt)
        for sink, root in opt.items():
            lines.append(f"{sink}:")
            _render(root, 1, lines, counts)
    return "\n".join(lines)

"""Picklable expression trees for plan predicates.

``col("amount") > 0`` builds a tiny AST (module-level classes, plain
attributes) with three properties the rest of the stack depends on:

* **picklable** — a predicate crosses the Flight process boundary inside
  a ``functools.partial(eval_predicate, expr=...)`` op, so every node is
  a module-level class holding only plain-Python values;
* **stable repr** — ``repr`` is deterministic and address-free
  (``(col('amount') > lit(0.0))``), because node fingerprints
  canonicalize partial keywords via ``repr``: the same predicate built
  next run must fingerprint identically, and an edited predicate must
  not;
* **null semantics fixed per comparison** — a comparison involving a
  null row is ``False`` (SQL WHERE semantics), and ``&``/``|``/``~``
  are plain boolean algebra over those masks.  Because each conjunct
  evaluates independently of its siblings, the optimizer may split a
  top-level ``&`` and push the pieces to different join sides without
  changing the result.  Nulls are *tested* with
  ``col(...).is_null()``/``is_not_null()`` — ``lit(None)`` is rejected
  at construction because every comparison against it would silently be
  False.  Arithmetic is IEEE with warnings contained (``np.errstate``):
  ``x/0 → ±inf``, ``0/0 → NaN``, and NaN fails every comparison, so
  undefined rows drop out of the mask like null rows do.

Evaluation is vectorized per record batch: primitive and dict-encoded
numeric columns compare via numpy on the logical values; utf8 equality
against a literal compares lengths first, then gathers only the
length-matching rows' bytes (dict-encoded utf8 compares once per
dictionary entry, then projects through the codes — dictionary sharing
makes this O(unique)).
"""

from __future__ import annotations

from typing import Optional, Set, Tuple

import numpy as np

__all__ = ["Expr", "Col", "Lit", "Cmp", "BoolOp", "Not", "Arith",
           "IsNull", "col", "lit", "eval_predicate", "split_conjuncts",
           "and_all", "EVAL_FP"]


def _wrap(v) -> "Expr":
    return v if isinstance(v, Expr) else Lit(v)


class Expr:
    """Base node: operator overloads build the tree."""

    # comparisons ----------------------------------------------------------
    def __eq__(self, other):            # noqa: D105 — builds an AST node
        return Cmp("==", self, _wrap(other))

    def __ne__(self, other):
        return Cmp("!=", self, _wrap(other))

    def __lt__(self, other):
        return Cmp("<", self, _wrap(other))

    def __le__(self, other):
        return Cmp("<=", self, _wrap(other))

    def __gt__(self, other):
        return Cmp(">", self, _wrap(other))

    def __ge__(self, other):
        return Cmp(">=", self, _wrap(other))

    __hash__ = object.__hash__          # __eq__ override would drop it

    # boolean algebra ------------------------------------------------------
    def __and__(self, other):
        return BoolOp("&", self, _wrap(other))

    def __or__(self, other):
        return BoolOp("|", self, _wrap(other))

    def __invert__(self):
        return Not(self)

    # null tests -----------------------------------------------------------
    def is_null(self) -> "IsNull":
        """True exactly on null rows — the *only* way to test for nulls
        (a comparison against a null is always False, and ``lit(None)``
        is rejected for that reason)."""
        return IsNull(self)

    def is_not_null(self) -> "IsNull":
        return IsNull(self, negate=True)

    # arithmetic -----------------------------------------------------------
    def __add__(self, other):
        return Arith("+", self, _wrap(other))

    def __sub__(self, other):
        return Arith("-", self, _wrap(other))

    def __mul__(self, other):
        return Arith("*", self, _wrap(other))

    def __truediv__(self, other):
        return Arith("/", self, _wrap(other))

    # analysis -------------------------------------------------------------
    def columns(self) -> Set[str]:
        """Column names this expression reads."""
        out: Set[str] = set()
        self._collect(out)
        return out

    def _collect(self, out: Set[str]) -> None:
        raise NotImplementedError

    # evaluation -----------------------------------------------------------
    def mask(self, batch) -> np.ndarray:
        raise TypeError(f"{self!r} is not a boolean predicate")

    def _value(self, batch) -> Tuple[np.ndarray, Optional[np.ndarray]]:
        """(values, valid) for numeric evaluation; valid None == all."""
        raise TypeError(f"{self!r} is not a value expression")


class Col(Expr):
    def __init__(self, name: str):
        assert isinstance(name, str) and name, name
        self.name = name

    def __repr__(self):
        return f"col({self.name!r})"

    def _collect(self, out):
        out.add(self.name)

    def _value(self, batch):
        c = batch.column(self.name)
        if c._kindof() == "utf8":
            raise TypeError(f"col({self.name!r}): utf8 columns support "
                            f"only ==/!= against another column or a "
                            f"string literal")
        vals = c._logical()
        valid = None if c.validity is None else c.valid_mask()
        return vals, valid

    def mask(self, batch):
        # a bare column used as predicate: truthy and non-null
        vals, valid = self._value(batch)
        m = vals != 0
        return m if valid is None else (m & valid)


class Lit(Expr):
    def __init__(self, value):
        if isinstance(value, np.generic):    # np.float64(3) reprs unstably
            value = value.item()
        if value is None:
            # SQL three-valued logic is deliberately not implemented: a
            # null literal would make every comparison silently False
            raise TypeError(
                "lit(None)/literal None has no comparison semantics "
                "(a null row is never ==, !=, < or > anything); test "
                "for nulls with col(...).is_null() / "
                "col(...).is_not_null() instead")
        assert isinstance(
            value, (bool, int, float, str, bytes)), type(value)
        self.value = value

    def __repr__(self):
        return f"lit({self.value!r})"

    def _collect(self, out):
        pass

    def _value(self, batch):
        if isinstance(self.value, (str, bytes)):
            raise TypeError(f"{self!r} is not numeric")
        return self.value, None


class Cmp(Expr):
    OPS = ("==", "!=", "<", "<=", ">", ">=")

    def __init__(self, op: str, left: Expr, right: Expr):
        assert op in self.OPS, op
        self.op, self.left, self.right = op, left, right

    def __repr__(self):
        return f"({self.left!r} {self.op} {self.right!r})"

    def _collect(self, out):
        self.left._collect(out)
        self.right._collect(out)

    def _is_utf8_side(self, e: Expr, batch) -> bool:
        if isinstance(e, Lit):
            return isinstance(e.value, (str, bytes))
        if isinstance(e, Col):
            return batch.column(e.name)._kindof() == "utf8"
        return False

    def mask(self, batch):
        if self._is_utf8_side(self.left, batch) or \
           self._is_utf8_side(self.right, batch):
            return self._utf8_mask(batch)
        av, avalid = self.left._value(batch)
        bv, bvalid = self.right._value(batch)
        if self.op == "==":
            m = av == bv
        elif self.op == "!=":
            m = av != bv
        elif self.op == "<":
            m = av < bv
        elif self.op == "<=":
            m = av <= bv
        elif self.op == ">":
            m = av > bv
        else:
            m = av >= bv
        m = np.asarray(m)
        if m.ndim == 0:                     # scalar-vs-scalar literal
            m = np.full(batch.num_rows, bool(m))
        if avalid is not None:
            m = m & avalid
        if bvalid is not None:
            m = m & bvalid
        return m

    def _utf8_mask(self, batch):
        if self.op not in ("==", "!="):
            raise TypeError(f"utf8 comparison supports ==/!=, not "
                            f"{self.op!r}: {self!r}")
        a, b = self.left, self.right
        if isinstance(a, Lit):              # canonical: column on the left
            a, b = b, a
        if not isinstance(a, Col):
            raise TypeError(f"unsupported utf8 comparison: {self!r}")
        ca = batch.column(a.name)
        # kinds must match on both sides (same contract as join keys,
        # ops.hash_keys) — named error instead of a bare assert deep in
        # the byte-compare path
        if isinstance(b, Col):
            cb = batch.column(b.name)
            if ca._kindof() != "utf8" or cb._kindof() != "utf8":
                raise TypeError(
                    f"comparison {self!r}: {a.name!r} vs {b.name!r}: "
                    f"{ca._kindof()} vs {cb._kindof()} columns (utf8 "
                    f"compares only against utf8)")
            eq = _utf8_eq_pair(ca, cb)
        elif isinstance(b, Lit):
            if not isinstance(b.value, (str, bytes)):
                raise TypeError(
                    f"comparison {self!r}: column {a.name!r} is utf8 but "
                    f"{b!r} is {type(b.value).__name__} (utf8 compares "
                    f"only against utf8)")
            if ca._kindof() != "utf8":
                raise TypeError(
                    f"comparison {self!r}: column {a.name!r} is "
                    f"{ca._kindof()} but {b!r} is utf8 (utf8 compares "
                    f"only against utf8)")
            needle = b.value.encode() if isinstance(b.value, str) else b.value
            eq = _utf8_eq_scalar(ca, needle)
        else:
            raise TypeError(f"unsupported utf8 comparison: {self!r}")
        valid = ca.valid_mask()
        if isinstance(b, Col):
            valid = valid & batch.column(b.name).valid_mask()
        # null rows are False for BOTH == and != (SQL WHERE semantics)
        return (eq if self.op == "==" else ~eq) & valid


def _utf8_eq_scalar(c, needle: bytes) -> np.ndarray:
    """Per-row equality of a utf8/dict-utf8 column against one bytes
    value.  Length-filter first, then one vectorized gather+compare of
    just the candidate rows; dict columns compare per dictionary entry
    and project through the codes."""
    if c.type.is_dict:
        return _utf8_eq_scalar(c.dictionary, needle)[c.values]
    off = np.asarray(c.offsets, dtype=np.int64)
    eq = (off[1:] - off[:-1]) == len(needle)
    if len(needle):
        idx = np.nonzero(eq)[0]
        if len(idx):
            gathered = np.asarray(c.values)[
                off[idx][:, None] + np.arange(len(needle))]
            pat = np.frombuffer(needle, dtype=np.uint8)
            eq = eq.copy()
            eq[idx] = (gathered == pat).all(axis=1)
    return eq


def _utf8_eq_pair(ca, cb) -> np.ndarray:
    """Row-wise equality of two utf8-kind columns (slow path: per-row
    bytes compare on the length-matching candidates)."""
    n = ca.length
    assert cb.length == n
    la = np.asarray([len(ca._get_logical_bytes(i)) for i in range(n)])
    lb = np.asarray([len(cb._get_logical_bytes(i)) for i in range(n)])
    eq = la == lb
    for i in np.nonzero(eq)[0]:
        eq[i] = ca._get_logical_bytes(int(i)) == cb._get_logical_bytes(int(i))
    return eq


class BoolOp(Expr):
    def __init__(self, op: str, left: Expr, right: Expr):
        assert op in ("&", "|"), op
        self.op, self.left, self.right = op, left, right

    def __repr__(self):
        return f"({self.left!r} {self.op} {self.right!r})"

    def _collect(self, out):
        self.left._collect(out)
        self.right._collect(out)

    def mask(self, batch):
        a = self.left.mask(batch)
        b = self.right.mask(batch)
        return (a & b) if self.op == "&" else (a | b)


class Not(Expr):
    def __init__(self, expr: Expr):
        self.expr = expr

    def __repr__(self):
        return f"(~{self.expr!r})"

    def _collect(self, out):
        self.expr._collect(out)

    def mask(self, batch):
        return ~self.expr.mask(batch)


class IsNull(Expr):
    """Null test — works on every column kind (including utf8, which has
    no numeric ``_value``) and on computed expressions (null iff any
    input column is null on that row)."""

    def __init__(self, expr: Expr, negate: bool = False):
        self.expr = expr
        self.negate = bool(negate)

    def __repr__(self):
        return f"{self.expr!r}.is_{'not_' if self.negate else ''}null()"

    def _collect(self, out):
        self.expr._collect(out)

    def mask(self, batch):
        if isinstance(self.expr, Col):
            valid = batch.column(self.expr.name).valid_mask()
        else:
            _, valid = self.expr._value(batch)
            if valid is None:
                valid = np.ones(batch.num_rows, dtype=bool)
            else:
                valid = np.asarray(valid)
                if valid.ndim == 0:         # literal-only expression
                    valid = np.full(batch.num_rows, bool(valid))
        return valid if self.negate else ~valid


class Arith(Expr):
    def __init__(self, op: str, left: Expr, right: Expr):
        assert op in ("+", "-", "*", "/"), op
        self.op, self.left, self.right = op, left, right

    def __repr__(self):
        return f"({self.left!r} {self.op} {self.right!r})"

    def _collect(self, out):
        self.left._collect(out)
        self.right._collect(out)

    def _value(self, batch):
        av, avalid = self.left._value(batch)
        bv, bvalid = self.right._value(batch)
        # IEEE semantics, warnings suppressed (they must not escape
        # eval_predicate — callers running under `-W error` would crash
        # on data-dependent values): x/0 -> ±inf, 0/0 -> NaN, float
        # overflow -> inf.  NaN then fails every comparison, so rows
        # whose expression is undefined drop out of the mask exactly
        # like null rows do.  Note `/` is numpy true division: int/int
        # promotes to float64
        with np.errstate(divide="ignore", invalid="ignore", over="ignore"):
            if self.op == "+":
                v = av + bv
            elif self.op == "-":
                v = av - bv
            elif self.op == "*":
                v = av * bv
            else:
                v = av / bv
        if avalid is None:
            valid = bvalid
        elif bvalid is None:
            valid = avalid
        else:
            valid = avalid & bvalid
        return v, valid


# --------------------------------------------------------------------------
# module-level entry points (picklable partial targets)
# --------------------------------------------------------------------------

def col(name: str) -> Col:
    return Col(name)


def lit(value) -> Lit:
    return Lit(value)


def eval_predicate(batch, expr: Expr) -> np.ndarray:
    """Evaluate a predicate to a bool mask over one record batch.  The
    compiler ships ``functools.partial(eval_predicate, expr=pred)`` as
    the mask callable of ``ops.filter_rows``/``ops.filter_join`` — the
    partial pickles across the Flight boundary and fingerprints via the
    expression's stable repr."""
    m = np.asarray(expr.mask(batch))
    if m.dtype != np.bool_:
        m = m != 0
    return m


def split_conjuncts(expr: Expr):
    """Top-level ``&`` split: [a, b, c] for ``a & b & c``.  Safe because
    each conjunct's mask is independent of its siblings (nulls resolve
    per comparison, not per WHERE clause)."""
    if isinstance(expr, BoolOp) and expr.op == "&":
        return split_conjuncts(expr.left) + split_conjuncts(expr.right)
    return [expr]


def and_all(exprs) -> Expr:
    """Re-combine conjuncts: inverse of split_conjuncts."""
    exprs = list(exprs)
    assert exprs
    out = exprs[0]
    for e in exprs[1:]:
        out = BoolOp("&", out, e)
    return out


#: evaluation-semantics dependency set: any op that ships an expression
#: (``partial(eval_predicate, ...)``) folds these into its code identity
#: via ``__fp_includes__`` — editing how predicates evaluate invalidates
#: every cached filtered/fused-join output (same contract as ops.join
#: pinning the relational vkernels)
EVAL_FP = (Cmp.mask, Cmp._utf8_mask, BoolOp.mask, Not.mask, Col.mask,
           Col._value, Lit._value, Arith._value, IsNull.mask,
           _utf8_eq_scalar, _utf8_eq_pair)

eval_predicate.__fp_includes__ = EVAL_FP

"""Logical plan nodes + the dataframe-style ``Plan`` builder.

A logical plan is an immutable tree of ``LNode``s.  Each node knows:

* ``key()`` — a canonical structural string (kind + args + child keys).
  Two nodes with equal keys compute the same table from the same
  sources; the optimizer's common-subplan dedup and the compiler's
  node-sharing memo both hang off it.  It is built from stable reprs
  only (paths absolutized, exprs via their address-free repr), so it is
  deterministic across processes.
* ``schema()`` — output column names in order, derived without touching
  data (scans read only the zarquet footer).  The optimizer's
  projection pruning and join-side routing are schema-driven.
* ``describe()`` — one ``explain()`` line.

``Plan`` wraps a root ``LNode`` and exposes the builder surface
(``scan/select/filter/join/group_by/sort/limit``).  Plans are cheap
values: every builder call returns a new ``Plan`` sharing existing
nodes, so ``staging = scan(...).filter(...); a = staging.group_by(...);
b = staging.group_by(...)`` naturally shares the staging subtree — the
dedup pass turns that sharing (or any *structurally equal* rebuild of
it) into a single compiled node cone.
"""

from __future__ import annotations

import os
from typing import Dict, List, Optional, Sequence, Tuple, Union

from .. import zarquet
from .expr import Expr

__all__ = ["LNode", "Scan", "Project", "Filter", "Join", "FilterJoin",
           "GroupBy", "Sort", "Limit", "Plan", "scan"]


class LNode:
    kind = "?"
    children: Tuple["LNode", ...] = ()

    def args_key(self) -> str:
        """Canonical text of the node-local arguments."""
        raise NotImplementedError

    def key(self) -> str:
        k = getattr(self, "_key", None)
        if k is None:
            k = f"{self.kind}[{self.args_key()}]" + \
                "".join(f"({c.key()})" for c in self.children)
            self._key = k
        return k

    def schema(self) -> List[str]:
        raise NotImplementedError

    def describe(self) -> str:
        raise NotImplementedError

    def with_children(self, children: Sequence["LNode"]) -> "LNode":
        raise NotImplementedError


class Scan(LNode):
    kind = "scan"

    def __init__(self, path: str, columns: Optional[Tuple[str, ...]] = None,
                 dict_columns: Tuple[str, ...] = ()):
        self.path = os.path.abspath(path)
        self.columns = None if columns is None else tuple(columns)
        self.dict_columns = tuple(dict_columns)

    def args_key(self):
        # canonicalize the column subset to footer order (via schema()):
        # a scan's identity is *which* columns it loads, never the order
        # the user spelled them — read_table assembles in footer order
        # regardless — so scan(p, ["a","b"]) and scan(p, ["b","a"]) must
        # be one loader for subplan dedup, the compiler memo and the
        # cross-run manifest, not two loaders with double loaded bytes
        cols = None if self.columns is None else tuple(self.schema())
        return f"{self.path!r},cols={cols!r}," \
               f"dict={tuple(sorted(self.dict_columns))!r}"

    def schema(self):
        """Output column names — deliberately in *footer order* (the
        order ``zarquet.read_table`` assembles), restricted to
        ``columns`` when set; the order the user passed to ``scan()`` is
        irrelevant.  Unknown names raise KeyError."""
        names = getattr(self, "_footer_names", None)
        if names is None:
            names = [cm["name"] for cm in
                     zarquet.read_footer(self.path)["columns"]]
            self._footer_names = names
        if self.columns is None:
            return list(names)
        want = set(self.columns)
        missing = want - set(names)
        if missing:
            raise KeyError(f"scan {self.path}: no such column(s) "
                           f"{sorted(missing)}")
        return [n for n in names if n in want]

    def describe(self):
        s = f"scan {os.path.basename(self.path)}"
        if self.columns is not None:
            s += f" columns={tuple(self.schema())!r}"
        if self.dict_columns:
            s += f" dict={self.dict_columns!r}"
        return s

    def with_children(self, children):
        assert not children
        return self


class Project(LNode):
    kind = "project"

    def __init__(self, child: LNode, columns: Tuple[str, ...]):
        self.children = (child,)
        self.columns = tuple(columns)

    def args_key(self):
        return repr(self.columns)

    def schema(self):
        have = set(self.children[0].schema())
        missing = set(self.columns) - have
        if missing:
            raise KeyError(f"select: no such column(s) {sorted(missing)}")
        return list(self.columns)

    def describe(self):
        return f"select columns={self.columns!r}"

    def with_children(self, children):
        (c,) = children
        return Project(c, self.columns)


class Filter(LNode):
    kind = "filter"

    def __init__(self, child: LNode, predicate: Expr):
        # validate at construction, not at runtime: a predicate over
        # columns the child does not produce must fail in BOTH modes.
        # Without this, pushdown_filters would commute such a filter
        # below the Project that dropped the column and the plan would
        # "work" under optimize=True while crashing under optimize=False
        # — the optimizer must never repair an invalid plan
        have = child.schema()
        missing = predicate.columns() - set(have)
        if missing:
            raise KeyError(
                f"filter {predicate!r}: no such column(s) "
                f"{sorted(missing)} in the input schema {have}")
        self.children = (child,)
        self.predicate = predicate

    def args_key(self):
        return repr(self.predicate)

    def schema(self):
        return self.children[0].schema()

    def describe(self):
        return f"filter {self.predicate!r}"

    def with_children(self, children):
        (c,) = children
        return Filter(c, self.predicate)


def _join_schema(left: List[str], right: List[str], on: Tuple[str, ...],
                 suffix: str) -> List[str]:
    """Mirror of ops._join_output naming: all left columns, then right
    non-key columns, suffixed on collision with a left name."""
    keys = set(on)
    out = list(left)
    for n in right:
        if n in keys:
            continue
        out.append(n + suffix if n in left else n)
    return out


class Join(LNode):
    kind = "join"

    def __init__(self, left: LNode, right: LNode, on: Tuple[str, ...],
                 how: str = "inner", suffix: str = "_right"):
        assert how in ("inner", "left"), how
        self.children = (left, right)
        self.on = tuple(on)
        self.how = how
        self.suffix = suffix

    def args_key(self):
        return f"on={self.on!r},how={self.how!r},suffix={self.suffix!r}"

    def schema(self):
        l, r = self.children
        return _join_schema(l.schema(), r.schema(), self.on, self.suffix)

    def describe(self):
        return f"join on={self.on!r} how={self.how!r}"

    def with_children(self, children):
        l, r = children
        return Join(l, r, self.on, self.how, self.suffix)


class FilterJoin(LNode):
    """Optimizer-emitted fused node: ``join(filter(l), filter(r))``
    lowered to one ``ops.filter_join`` gather.  Never built directly by
    the Plan surface — the fuse_filter_join rule rewrites to it."""
    kind = "filter_join"

    def __init__(self, left: LNode, right: LNode, on: Tuple[str, ...],
                 how: str, suffix: str, left_pred: Optional[Expr],
                 right_pred: Optional[Expr]):
        assert how in ("inner", "left"), how
        self.children = (left, right)
        self.on = tuple(on)
        self.how = how
        self.suffix = suffix
        self.left_pred = left_pred
        self.right_pred = right_pred

    def args_key(self):
        return (f"on={self.on!r},how={self.how!r},suffix={self.suffix!r},"
                f"lp={self.left_pred!r},rp={self.right_pred!r}")

    def schema(self):
        l, r = self.children
        return _join_schema(l.schema(), r.schema(), self.on, self.suffix)

    def describe(self):
        s = f"filter_join on={self.on!r} how={self.how!r}"
        if self.left_pred is not None:
            s += f" left_pred={self.left_pred!r}"
        if self.right_pred is not None:
            s += f" right_pred={self.right_pred!r}"
        return s

    def with_children(self, children):
        l, r = children
        return FilterJoin(l, r, self.on, self.how, self.suffix,
                          self.left_pred, self.right_pred)


class GroupBy(LNode):
    kind = "group_by"

    def __init__(self, child: LNode, keys: Tuple[str, ...],
                 aggs: Dict[str, Tuple[str, str]]):
        self.children = (child,)
        self.keys = tuple(keys)
        # insertion order is output order — keep it in the key
        self.aggs = dict(aggs)

    def args_key(self):
        return f"keys={self.keys!r},aggs={list(self.aggs.items())!r}"

    def schema(self):
        return list(self.keys) + list(self.aggs)

    def describe(self):
        return f"group_by keys={self.keys!r} aggs={self.aggs!r}"

    def with_children(self, children):
        (c,) = children
        return GroupBy(c, self.keys, self.aggs)


class Sort(LNode):
    kind = "sort"

    def __init__(self, child: LNode, by: str, descending: bool = False):
        self.children = (child,)
        self.by = by
        self.descending = bool(descending)

    def args_key(self):
        return f"by={self.by!r},desc={self.descending!r}"

    def schema(self):
        return self.children[0].schema()

    def describe(self):
        return f"sort by={self.by!r}" + \
               (" descending" if self.descending else "")

    def with_children(self, children):
        (c,) = children
        return Sort(c, self.by, self.descending)


class Limit(LNode):
    kind = "limit"

    def __init__(self, child: LNode, n: int):
        assert n >= 0, n
        self.children = (child,)
        self.n = int(n)

    def args_key(self):
        return repr(self.n)

    def schema(self):
        return self.children[0].schema()

    def describe(self):
        return f"limit {self.n}"

    def with_children(self, children):
        (c,) = children
        return Limit(c, self.n)


# --------------------------------------------------------------------------
# builder surface
# --------------------------------------------------------------------------

class Plan:
    """Immutable dataframe-style handle over a logical-plan root."""

    def __init__(self, root: LNode):
        self.root = root

    # builders -------------------------------------------------------------
    def select(self, *columns: str) -> "Plan":
        return Plan(Project(self.root, tuple(columns)))

    def filter(self, predicate: Expr) -> "Plan":
        assert isinstance(predicate, Expr), predicate
        return Plan(Filter(self.root, predicate))

    def join(self, other: "Plan", on: Union[str, Sequence[str]],
             how: str = "inner", suffix: str = "_right") -> "Plan":
        on = (on,) if isinstance(on, str) else tuple(on)
        return Plan(Join(self.root, other.root, on, how, suffix))

    def group_by(self, keys: Union[str, Sequence[str]],
                 aggs: Dict[str, Tuple[str, str]]) -> "Plan":
        keys = (keys,) if isinstance(keys, str) else tuple(keys)
        return Plan(GroupBy(self.root, keys, aggs))

    def sort(self, by: str, descending: bool = False) -> "Plan":
        return Plan(Sort(self.root, by, descending))

    def limit(self, n: int) -> "Plan":
        return Plan(Limit(self.root, n))

    # introspection --------------------------------------------------------
    def schema(self) -> List[str]:
        return self.root.schema()

    def explain(self, optimize: bool = True) -> str:
        from .compiler import explain_plans
        return explain_plans({"plan": self.root}, optimize=optimize)

    def __repr__(self):
        return f"Plan<{self.root.key()}>"


def scan(path: str, columns: Optional[Sequence[str]] = None,
         dict_columns: Sequence[str] = ()) -> Plan:
    """Start a plan from a zarquet file.  ``columns`` narrows the load
    up front (the pruning pass narrows it further to what the plan
    actually references); ``dict_columns`` dictionary-encode at load."""
    return Plan(Scan(path, None if columns is None else tuple(columns),
                     tuple(dict_columns)))

"""DAGs, node sandboxes and the share wrapper (paper §3.1, §4.2.3).

A DAG node runs arbitrary user code over Arrow tables.  Each node executes
inside a ``Sandbox`` — the container analogue: it owns a cgroup (memory
charging + dynamic limit for limit-dropping), an anonymous-memory registry
(the pre-deanon working set), and the *share wrapper* that (1) SIPC-reads
the inputs (recording mapped address ranges), (2) invokes the user
function, and (3) SIPC-writes the returned table, de-anonymizing or
resharing each output buffer.  User code never touches SIPC (Goals G4/G5).

The sandbox (cgroup) is retained after the node completes so the RM can
evict its outputs later (limit dropping) — exactly the SOCK modification
described in §4.2.3.
"""

from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence

import numpy as np

from .arrow import Table
from .buffers import AnonRegion, BufferStore, Cgroup
from .deanon import KernelZero
from .sipc import AddressMap, SipcMessage, SipcReader, SipcWriter

UserFn = Callable[[List[Table]], Table]


@dataclass
class NodeSpec:
    name: str
    fn: Optional[UserFn] = None          # None for loader nodes
    deps: List[str] = field(default_factory=list)
    est_mem: int = 0                     # bytes the RM reserves at admission
    # loader-node parameters (generic loader code — paper §3.1):
    source: Optional[str] = None         # zarquet path
    dict_columns: tuple = ()
    columns: Optional[tuple] = None      # column subset to load (None =
    #                                    # all) — projection pruning: the
    #                                    # plan optimizer narrows loaders
    #                                    # so unused columns are never
    #                                    # read, decompressed or charged
    row_groups: Optional[tuple] = None   # row-group subset of a stream
    #                                    # zarquet source (None = whole
    #                                    # file) — the incremental-
    #                                    # recompute driver pins loaders
    #                                    # to committed groups, whose
    #                                    # content hashes are immutable,
    #                                    # so an append invalidates only
    #                                    # the new tail's consumers
    keep_output: bool = False            # survive DAG completion (sinks
    #                                    # consumed by an external reader)


# node lifecycle — an explicit state machine so the scheduler's claims are
# verifiable under concurrency (all transitions happen inside the
# executor's RM critical section; see core/sched/executor.py)
WAITING, READY, RUNNING, DONE, EVICTED, CACHED = \
    "waiting", "ready", "running", "done", "evicted", "cached"

#: node states that count as complete (outputs available / not needed)
COMPLETE = (DONE, CACHED)

#: legal lifecycle transitions.  WAITING/EVICTED -> RUNNING is the
#: scheduler's *claim* (exclusive: only one worker can perform it because
#: it happens under the executor lock); DONE -> EVICTED is a rollback.
#: WAITING/EVICTED -> CACHED is a cross-run differential-cache hit: the
#: output is adopted from the persistent manifest instead of executing;
#: CACHED -> EVICTED is a rollback of the adopted output (the durable
#: entry stays on disk, so the node can be re-adopted or re-executed).
VALID_TRANSITIONS = {
    WAITING: (READY, RUNNING, CACHED),
    READY: (WAITING, RUNNING, CACHED),
    RUNNING: (DONE, WAITING),
    DONE: (EVICTED,),
    CACHED: (EVICTED,),
    EVICTED: (RUNNING, CACHED),
}


class InvalidTransition(RuntimeError):
    """An illegal node-lifecycle transition (scheduler logic error)."""


class NodeState:
    def __init__(self, spec: NodeSpec, dag: "DAG"):
        self.spec = spec
        self.dag = dag
        self.status = WAITING
        self.output: Optional[SipcMessage] = None
        self.sandbox: Optional[Sandbox] = None
        self.exec_latency = 0.0          # for adaptive eviction
        self.output_bytes = 0
        self.depth = 0
        self.runs = 0                    # re-executions due to rollback
        self.fingerprint: Optional[str] = None   # cross-run cache identity

    @property
    def name(self) -> str:
        return self.spec.name

    @property
    def is_loader(self) -> bool:
        return self.spec.source is not None

    def decache_key(self):
        # fingerprints are content-addressed (source bytes, not path), so
        # keying the DeCache on them lets manifest warming serve re-runs;
        # fall back to the path key when fingerprinting is off/uncacheable
        if self.fingerprint is not None:
            return self.fingerprint
        return (self.spec.source, tuple(sorted(self.spec.dict_columns)),
                None if self.spec.columns is None
                else tuple(sorted(self.spec.columns)),
                None if self.spec.row_groups is None
                else tuple(self.spec.row_groups))

    def transition(self, new_status: str) -> None:
        """Move through the lifecycle, validating against
        ``VALID_TRANSITIONS``.  Callers must hold the executor lock when
        the DAG is being executed concurrently."""
        if new_status not in VALID_TRANSITIONS[self.status]:
            raise InvalidTransition(
                f"node {self.dag.name}.{self.name}: "
                f"{self.status} -> {new_status}")
        self.status = new_status

    def claim(self) -> None:
        """Scheduler claim: WAITING/EVICTED -> RUNNING."""
        self.transition(RUNNING)


class DAG:
    _next_id = 0
    _id_lock = threading.Lock()

    def __init__(self, nodes: Sequence[NodeSpec], name: str = "",
                 deadline: Optional[float] = None,
                 tenant: Optional[str] = None):
        with DAG._id_lock:
            DAG._next_id += 1
            self.id = DAG._next_id
        self.name = name or f"dag{self.id}"
        self.deadline = deadline        # for the deadline-aware policy;
        #                               # enforced against time.monotonic()
        #                               # when RMConfig.enforce_deadlines
        self.tenant = tenant or self.name   # fair-share grouping key
        # serving-plane outcome channel (core/sched/executor.py):
        # cancelled stops further claims (in-flight nodes drain); outcome
        # is a typed terminal string — "completed", "shed:<reason>",
        # "deadline_miss", "poisoned", "failed:<exc>" — or None while the
        # DAG is still live; error carries the poisoning/failing exception
        self.cancelled = False
        self.outcome: Optional[str] = None
        self.error: Optional[BaseException] = None
        self.nodes: Dict[str, NodeState] = {s.name: NodeState(s, self)
                                            for s in nodes}
        self.children: Dict[str, List[str]] = {n: [] for n in self.nodes}
        for s in nodes:
            for d in s.deps:
                self.children[d].append(s.name)
        # depth = longest distance from a root; priority = deeper first
        order = self.topo_order()
        for n in order:
            st = self.nodes[n]
            st.depth = max([self.nodes[d].depth + 1
                            for d in st.spec.deps], default=0)
        self.done = False

    def topo_order(self) -> List[str]:
        seen, out = set(), []
        def visit(n: str) -> None:
            if n in seen:
                return
            seen.add(n)
            for d in self.nodes[n].spec.deps:
                visit(d)
            out.append(n)
        for n in self.nodes:
            visit(n)
        return out

    def runnable(self) -> List[NodeState]:
        if self.cancelled:
            return []               # cooperative cancel: no new claims
        out = []
        for st in self.nodes.values():
            if st.status in (WAITING, EVICTED):
                deps = [self.nodes[d] for d in st.spec.deps]
                if all(d.status in COMPLETE and d.output is not None
                       and not d.output.released for d in deps):
                    out.append(st)
        return out

    def all_done(self) -> bool:
        if self.cancelled:
            # a cancelled DAG is finished once its in-flight nodes drain
            return not any(st.status == RUNNING
                           for st in self.nodes.values())
        return all(st.status in COMPLETE for st in self.nodes.values())


class Sandbox:
    """Container analogue: cgroup + anon registry + share wrapper."""

    def __init__(self, store: BufferStore, kz: KernelZero, name: str,
                 mode: str = "zero", mem_limit: Optional[int] = None):
        self.store = store
        self.kz = kz
        self.name = name
        self.mode = mode
        self.cgroup = store.new_cgroup(name, mem_limit)
        self.anon: List[AnonRegion] = []
        self.input_map = AddressMap()
        self.owned_files: List[int] = []

    # -- anonymous-memory management (the malloc'd working set) -------------
    def register_anon(self, arr: np.ndarray) -> AnonRegion:
        r = AnonRegion(arr, self.cgroup)
        self.anon.append(r)
        return r

    def anon_region_for(self, arr: np.ndarray) -> Optional[AnonRegion]:
        for r in self.anon:
            if r.array is arr or (r.array is not None and
                                  r.array.base is getattr(arr, "base", None)
                                  and arr.base is not None):
                return r
        return None

    # -- the share wrapper ---------------------------------------------------
    def run(self, fn: UserFn, inputs: List[SipcMessage],
            label: str = "", lock=None) -> SipcMessage:
        """SIPC-read inputs, invoke the user function, SIPC-write the
        result.  With ``lock`` (the executor's RM critical section), the
        store-mutating phases — input reads, output write, and any
        LazyBuf fault the user code triggers — run *inside* the lock
        while the user function itself runs outside it, so vectorized
        (GIL-releasing) compute overlaps across executor workers."""
        reader = SipcReader(self.store, self.mode, record_map=self.input_map,
                            fault_lock=lock)
        if lock is None:
            tables = [reader.read_table(m) for m in inputs]
            out_table = fn(tables)
            return self.write_output(out_table, label)
        with lock:
            tables = [reader.read_table(m) for m in inputs]
        out_table = fn(tables)
        with lock:
            return self.write_output(out_table, label)

    def write_output(self, table: Table, label: str = "") -> SipcMessage:
        writer = SipcWriter(self.store, self.kz, self.cgroup, self.mode,
                            input_map=self.input_map,
                            label=label or self.name)
        msg = writer.write_table(table)
        # anon regions whose memory was transferred are now file-owned;
        # release the remainder (the wrapper is the last code that runs and
        # never frees data already sent via SIPC — §4.2.3)
        for r in self.anon:
            if r.array is not None or r.swapped:
                r.release()
        self.anon = []
        self.owned_files.extend(
            fid for fid in msg.files_referenced()
            if fid in self.store.files and
            self.store.files[fid].owner is self.cgroup)
        return msg

    # -- eviction interface ---------------------------------------------------
    def drop_limit_and_swap(self) -> int:
        """Limit dropping: set the cgroup limit to 0, forcing its tmpfs
        pages out to swap, then restore (paper §3.1/§4.2.5)."""
        prev = self.cgroup.limit
        swapped = 0
        for fid in self.owned_files:
            swapped += self.store.swap_out_file(fid)
        self.cgroup.set_limit(prev)
        return swapped

    def destroy(self) -> None:
        for r in self.anon:
            r.release()
        self.anon = []
        self.cgroup.alive = False

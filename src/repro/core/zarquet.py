"""zarquet — the on-disk columnar source format (Parquet stand-in).

pyarrow is unavailable offline, so Zerrow's sources are 'zarquet' files:
compressed column chunks with a JSON footer, keeping the Parquet
properties the paper relies on:
  * compressed on disk, uncompressed Arrow in memory (deserialization is
    real decompression work, parallelizable per column — paper Fig 2);
  * ``read_table(..., dict_columns=...)`` mirrors PyArrow's
    ``read_dictionary=`` argument: chosen utf8 columns are deserialized
    straight into dictionary encoding (paper §4.2.4);
  * ``read_table(..., columns=...)`` mirrors Parquet readers' column
    selection: unselected columns are never read or decompressed — the
    hook the plan optimizer's projection pruning bottoms out on
    (``core/plan/``: unused columns never get deanonymized because they
    are never loaded at all).

zstd is preferred when the ``zstandard`` package is installed; otherwise
stdlib ``zlib`` is used.  The codec is recorded in the footer, so files
written with either codec read back on any environment that has the
matching decompressor.  Both codecs release the GIL while (de)compressing,
which is what lets the worker-pool executor overlap deserialization
across loader nodes — and, within one ``read_table``, lets the reader
pool fan column-chunk decompression out across threads (the paper's
"real deserialization work, parallelizable per column", Fig 2).

Decompression is copy-free: each blob is decompressed *directly into*
the allocator-provided page-aligned destination buffer (zstd stream
``readinto``; chunked ``zlib.decompressobj`` writes into the destination
memoryview) instead of materializing an intermediate full-size ``bytes``
and memcpy-ing it over.

Layout:  [MAGIC][buffer blob .... ][footer json][footer_len u64][MAGIC]
"""

from __future__ import annotations

import io
import json
import os
import struct
import threading
import zlib
from concurrent.futures import ThreadPoolExecutor
from typing import Callable, Dict, List, Optional, Sequence

import numpy as np

try:
    import zstandard
except ImportError:                       # clean environment: stdlib only
    zstandard = None

from . import vkernels
from .arrow import (ArrowType, Column, Field, RecordBatch, Schema, Table,
                    UTF8)
from .buffers import alloc_aligned

MAGIC = b"ZQ01"
DEFAULT_CODEC = "zstd" if zstandard is not None else "zlib"

#: zlib copy-free path decompresses in bounded chunks into the destination
_ZLIB_CHUNK = 1 << 20

#: below this much uncompressed data a reader pool costs more than it
#: saves (thread spin-up vs sub-ms decompression) — stay serial
_PARALLEL_MIN_BYTES = 1 << 20


def _default_readers() -> int:
    """Reader-pool width for one read_table call (per-column-chunk
    decompression fan-out): env ZERROW_READER_THREADS, else
    min(4, cpu count).  The codecs release the GIL, so this is real
    parallelism even under the thread executor."""
    env = os.environ.get("ZERROW_READER_THREADS")
    if env:
        return max(1, int(env))
    return max(1, min(4, os.cpu_count() or 1))


def _comp(data: np.ndarray, level: int, codec: str = DEFAULT_CODEC) -> bytes:
    raw = np.ascontiguousarray(data).view(np.uint8).reshape(-1).tobytes()
    if codec == "zstd":
        return zstandard.ZstdCompressor(level=level).compress(raw)
    return zlib.compress(raw, level)


def _require_zstd() -> None:
    if zstandard is None:
        raise RuntimeError(
            "zarquet file was written with zstd but the 'zstandard' "
            "package is not installed; rewrite the source with the "
            "zlib codec or install zstandard")


def _decomp_into(blob: bytes, dest: np.ndarray, codec: str) -> None:
    """Decompress ``blob`` directly into ``dest`` (uint8, exactly the
    uncompressed length) — no intermediate full-size ``bytes`` object."""
    mv = memoryview(dest)
    rlen = len(mv)
    if codec == "zstd":
        _require_zstd()
        reader = zstandard.ZstdDecompressor().stream_reader(io.BytesIO(blob))
        if not hasattr(reader, "readinto"):
            # very old zstandard without stream readinto: one-shot with
            # the intermediate copy (correctness over copy-freedom)
            raw = zstandard.ZstdDecompressor().decompress(
                blob, max_output_size=rlen)
            if len(raw) != rlen:
                raise ValueError(
                    f"zarquet: zstd buffer decompressed to an unexpected "
                    f"size (got {len(raw)}, want {rlen})")
            mv[:] = raw
            return
        pos = 0
        while pos < rlen:
            k = reader.readinto(mv[pos:])
            if k == 0:
                break
            pos += k
        if pos != rlen or reader.read(1):
            raise ValueError(
                f"zarquet: zstd buffer decompressed to an unexpected size "
                f"(got {pos}, want {rlen})")
        return
    # feed the input in bounded slices: decompressobj returns the
    # not-yet-consumed input as a fresh bytes object on every capped
    # call, so capping output against the *whole* blob would re-copy an
    # O(blob) tail per chunk — bounding the fed slice bounds that re-copy
    d = zlib.decompressobj()
    src = memoryview(blob)
    pos = 0
    for a in range(0, max(len(src), 1), _ZLIB_CHUNK):
        data = src[a:a + _ZLIB_CHUNK]
        while True:
            remaining = rlen - pos
            chunk = d.decompress(data, min(remaining, _ZLIB_CHUNK)
                                 if remaining else 1)
            if chunk:
                if len(chunk) > remaining:
                    raise ValueError("zarquet: zlib buffer decompressed "
                                     "past the destination size")
                mv[pos:pos + len(chunk)] = chunk
                pos += len(chunk)
            data = d.unconsumed_tail
            if not data:
                break
        if d.eof:
            break
    tail = d.flush()
    if tail:
        if len(tail) > rlen - pos:
            raise ValueError("zarquet: zlib buffer decompressed past the "
                             "destination size")
        mv[pos:pos + len(tail)] = tail
        pos += len(tail)
    if pos != rlen:
        raise ValueError(
            f"zarquet: zlib buffer decompressed to an unexpected size "
            f"(got {pos}, want {rlen})")


def write_table(path: str, table: Table, level: int = 1,
                codec: str = DEFAULT_CODEC) -> None:
    if codec == "zstd" and zstandard is None:
        raise RuntimeError("zstd codec requested but 'zstandard' is not "
                           "installed")
    t = table.combine()
    b = t.batches[0]
    blobs: List[bytes] = []
    cols_meta = []
    off = len(MAGIC)
    for f, c in zip(b.schema.fields, b.columns):
        if c.type.is_dict:
            c = c.decode_dictionary()       # store plain; re-encode at read
        bufs_meta = []
        for bname, arr in c.buffers():
            raw = np.ascontiguousarray(arr)
            blob = _comp(raw, level, codec)
            bufs_meta.append({"name": bname, "off": off, "clen": len(blob),
                              "rlen": raw.nbytes, "np": str(raw.dtype)})
            blobs.append(blob)
            off += len(blob)
        cols_meta.append({"name": f.name,
                          "type": (c.type.to_json()),
                          "nrows": c.length,
                          "buffers": bufs_meta})
    footer = json.dumps({"columns": cols_meta, "nrows": b.num_rows,
                         "codec": codec}).encode()
    with open(path, "wb") as fh:
        fh.write(MAGIC)
        for blob in blobs:
            fh.write(blob)
        fh.write(footer)
        fh.write(struct.pack("<Q", len(footer)))
        fh.write(MAGIC)


def read_footer(path: str) -> dict:
    with open(path, "rb") as fh:
        fh.seek(-12, os.SEEK_END)
        tail = fh.read(12)
        assert tail[-4:] == MAGIC, "not a zarquet file"
        (flen,) = struct.unpack("<Q", tail[:8])
        fh.seek(-(12 + flen), os.SEEK_END)
        return json.loads(fh.read(flen).decode())


def read_table(path: str, dict_columns: Sequence[str] = (),
               allocator: Callable[[int], np.ndarray] = alloc_aligned,
               on_buffer: Optional[Callable[[np.ndarray], None]] = None,
               reader_threads: Optional[int] = None,
               columns: Optional[Sequence[str]] = None) -> Table:
    """Deserialize to Arrow.  ``allocator`` controls where uncompressed
    buffers land (page-aligned by default: the de-anonymization fast path).
    ``on_buffer`` lets the share wrapper register each fresh buffer as
    sandbox-charged anonymous memory.

    ``reader_threads`` fans per-buffer decompression out across a small
    thread pool (the codecs release the GIL) — per-column parallel
    deserialization within a single source; ``None`` = auto, ``<= 1`` =
    serial.  Allocation, ``on_buffer`` callbacks and column assembly all
    stay on the calling thread, in footer order, so the
    allocator/on_buffer contract is unchanged; only the GIL-free
    decompress-into step runs on pool threads.

    ``columns`` restricts the read to a subset of columns (projection
    pushdown, mirroring Parquet readers' ``columns=``): unselected
    columns are never read, decompressed or allocated — their bytes stay
    on disk.  Output column order is footer order restricted to the
    selection (order of the ``columns`` sequence itself is irrelevant);
    unknown names raise ``KeyError``."""
    meta = read_footer(path)
    codec = meta.get("codec", "zstd")   # pre-codec files were always zstd
    dict_set = set(dict_columns)
    cols_meta = meta["columns"]
    if columns is not None:
        want = set(columns)
        missing = want - {cm["name"] for cm in cols_meta}
        if missing:
            raise KeyError(f"zarquet {path}: no such column(s) "
                           f"{sorted(missing)}")
        cols_meta = [cm for cm in cols_meta if cm["name"] in want]
        if not cols_meta:
            raise KeyError(f"zarquet {path}: empty column selection")
    # 1) allocate destinations + record blob extents (footer order)
    spans: List[tuple] = []             # (file_off, clen) per buffer
    dests: List[np.ndarray] = []
    for cm in cols_meta:
        for bm in cm["buffers"]:
            spans.append((bm["off"], bm["clen"]))
            dests.append(allocator(bm["rlen"]))
    # 2) decompress directly into the destinations, in parallel.  Blobs
    # are read per job and dropped as soon as they are consumed, so peak
    # memory is destinations + the in-flight blobs, never + the whole
    # compressed file
    n_threads = reader_threads if reader_threads is not None \
        else _default_readers()
    jobs = [i for i, d in enumerate(dests) if d.nbytes]
    total = sum(dests[i].nbytes for i in jobs)
    with open(path, "rb") as fh:
        io_lock = threading.Lock()

        def _decomp_job(i: int) -> None:
            off, clen = spans[i]
            with io_lock:
                fh.seek(off)
                blob = fh.read(clen)
            _decomp_into(blob, dests[i], codec)

        if n_threads > 1 and len(jobs) > 1 and total >= _PARALLEL_MIN_BYTES:
            with ThreadPoolExecutor(min(n_threads, len(jobs))) as pool:
                list(pool.map(_decomp_job, jobs))
        else:
            for i in jobs:
                _decomp_job(i)
    # 3) register buffers + assemble columns (calling thread, footer order)
    fields, cols = [], []
    it = iter(dests)
    for cm in cols_meta:
        bufs: Dict[str, np.ndarray] = {}
        for bm in cm["buffers"]:
            out = next(it)
            if on_buffer is not None:
                on_buffer(out)
            bufs[bm["name"]] = out.view(np.dtype(bm["np"]))
        t = ArrowType.from_json(cm["type"])
        validity = bufs.get("validity")
        if t.is_utf8:
            col = Column.utf8(bufs["offsets"].view(np.int64),
                              bufs["values"].view(np.uint8), validity)
            if cm["name"] in dict_set:
                col = _dict_encode_col(col, allocator, on_buffer)
        else:
            col = Column(t, cm["nrows"],
                         bufs["values"].view(np.dtype(t.np_dtype)),
                         validity=validity)
        fields.append(Field(cm["name"], col.type))
        cols.append(col)
    return Table.from_batch(Schema(fields), cols)


def _dict_encode_col(col: Column, allocator, on_buffer) -> Column:
    """Deserialize-with-dictionary: unique strings -> dictionary column
    (vectorized: vkernels.dict_encode_var, no per-row Python)."""
    codes, uoff, uvals = vkernels.dict_encode_var(col.offsets, col.values)
    # build dictionary buffers through the allocator (they are outputs too)
    values = allocator(uvals.nbytes)
    values[:] = uvals
    offsets = allocator(uoff.nbytes).view(np.int64)
    offsets[:] = uoff
    codes_buf = allocator(codes.size * 4).view(np.int32)
    codes_buf[:] = codes
    for a in (values, offsets, codes_buf):
        if on_buffer is not None:
            on_buffer(a)
    dic = Column.utf8(offsets, values)
    return Column.dictionary_encoded(codes_buf, dic, validity=col.validity)


# --------------------------------------------------------------------------
# synthetic dataset generators (shared by benchmarks and tests)
# --------------------------------------------------------------------------

def gen_int_table(num_cols: int, bytes_per_col: int, seed: int = 0) -> Table:
    rng = np.random.default_rng(seed)
    n = bytes_per_col // 8
    return Table.from_pydict({
        f"i{j}": rng.integers(0, 1 << 40, size=n, dtype=np.int64)
        for j in range(num_cols)})


def gen_str_table(num_cols: int, bytes_per_col: int, str_len: int = 100,
                  repeats: int = 1, seed: int = 0) -> Table:
    """num_cols string columns; each unique value occurs ``repeats`` times."""
    rng = np.random.default_rng(seed)
    n = bytes_per_col // str_len
    uniq = n // repeats
    cols = {}
    for j in range(num_cols):
        letters = rng.integers(97, 123, size=(uniq, str_len), dtype=np.uint8)
        vals = np.repeat(letters, repeats, axis=0)[:n]
        perm = rng.permutation(len(vals))
        vals = vals[perm]
        offsets = np.arange(0, (len(vals) + 1) * str_len, str_len,
                            dtype=np.int64)
        cols[f"s{j}"] = Column.utf8(offsets, vals.reshape(-1).copy())
    return Table.from_pydict(cols)

"""zarquet — the on-disk columnar source format (Parquet stand-in).

pyarrow is unavailable offline, so Zerrow's sources are 'zarquet' files:
compressed column chunks with a JSON footer, keeping the Parquet
properties the paper relies on:
  * compressed on disk, uncompressed Arrow in memory (deserialization is
    real decompression work, parallelizable per column — paper Fig 2);
  * ``read_table(..., dict_columns=...)`` mirrors PyArrow's
    ``read_dictionary=`` argument: chosen utf8 columns are deserialized
    straight into dictionary encoding (paper §4.2.4).

zstd is preferred when the ``zstandard`` package is installed; otherwise
stdlib ``zlib`` is used.  The codec is recorded in the footer, so files
written with either codec read back on any environment that has the
matching decompressor.  Both codecs release the GIL while (de)compressing,
which is what lets the worker-pool executor overlap deserialization
across loader nodes.

Layout:  [MAGIC][buffer blob .... ][footer json][footer_len u64][MAGIC]
"""

from __future__ import annotations

import json
import os
import struct
import zlib
from typing import Callable, Dict, List, Optional, Sequence

import numpy as np

try:
    import zstandard
except ImportError:                       # clean environment: stdlib only
    zstandard = None

from .arrow import (ArrowType, Column, Field, RecordBatch, Schema, Table,
                    UTF8)
from .buffers import alloc_aligned

MAGIC = b"ZQ01"
DEFAULT_CODEC = "zstd" if zstandard is not None else "zlib"


def _comp(data: np.ndarray, level: int, codec: str = DEFAULT_CODEC) -> bytes:
    raw = np.ascontiguousarray(data).view(np.uint8).reshape(-1).tobytes()
    if codec == "zstd":
        return zstandard.ZstdCompressor(level=level).compress(raw)
    return zlib.compress(raw, level)


def _decomp(blob: bytes, rlen: int, codec: str) -> bytes:
    if codec == "zstd":
        if zstandard is None:
            raise RuntimeError(
                "zarquet file was written with zstd but the 'zstandard' "
                "package is not installed; rewrite the source with the "
                "zlib codec or install zstandard")
        return zstandard.ZstdDecompressor().decompress(
            blob, max_output_size=rlen)
    return zlib.decompress(blob)


def write_table(path: str, table: Table, level: int = 1,
                codec: str = DEFAULT_CODEC) -> None:
    if codec == "zstd" and zstandard is None:
        raise RuntimeError("zstd codec requested but 'zstandard' is not "
                           "installed")
    t = table.combine()
    b = t.batches[0]
    blobs: List[bytes] = []
    cols_meta = []
    off = len(MAGIC)
    for f, c in zip(b.schema.fields, b.columns):
        if c.type.is_dict:
            c = c.decode_dictionary()       # store plain; re-encode at read
        bufs_meta = []
        for bname, arr in c.buffers():
            raw = np.ascontiguousarray(arr)
            blob = _comp(raw, level, codec)
            bufs_meta.append({"name": bname, "off": off, "clen": len(blob),
                              "rlen": raw.nbytes, "np": str(raw.dtype)})
            blobs.append(blob)
            off += len(blob)
        cols_meta.append({"name": f.name,
                          "type": (c.type.to_json()),
                          "nrows": c.length,
                          "buffers": bufs_meta})
    footer = json.dumps({"columns": cols_meta, "nrows": b.num_rows,
                         "codec": codec}).encode()
    with open(path, "wb") as fh:
        fh.write(MAGIC)
        for blob in blobs:
            fh.write(blob)
        fh.write(footer)
        fh.write(struct.pack("<Q", len(footer)))
        fh.write(MAGIC)


def read_footer(path: str) -> dict:
    with open(path, "rb") as fh:
        fh.seek(-12, os.SEEK_END)
        tail = fh.read(12)
        assert tail[-4:] == MAGIC, "not a zarquet file"
        (flen,) = struct.unpack("<Q", tail[:8])
        fh.seek(-(12 + flen), os.SEEK_END)
        return json.loads(fh.read(flen).decode())


def read_table(path: str, dict_columns: Sequence[str] = (),
               allocator: Callable[[int], np.ndarray] = alloc_aligned,
               on_buffer: Optional[Callable[[np.ndarray], None]] = None
               ) -> Table:
    """Deserialize to Arrow.  ``allocator`` controls where uncompressed
    buffers land (page-aligned by default: the de-anonymization fast path).
    ``on_buffer`` lets the share wrapper register each fresh buffer as
    sandbox-charged anonymous memory."""
    meta = read_footer(path)
    codec = meta.get("codec", "zstd")   # pre-codec files were always zstd
    dict_set = set(dict_columns)
    fields, cols = [], []
    with open(path, "rb") as fh:
        for cm in meta["columns"]:
            bufs: Dict[str, np.ndarray] = {}
            for bm in cm["buffers"]:
                fh.seek(bm["off"])
                blob = fh.read(bm["clen"])
                out = allocator(bm["rlen"])
                raw = _decomp(blob, bm["rlen"], codec)
                out[:] = np.frombuffer(raw, dtype=np.uint8)
                if on_buffer is not None:
                    on_buffer(out)
                bufs[bm["name"]] = out.view(np.dtype(bm["np"]))
            t = ArrowType.from_json(cm["type"])
            validity = bufs.get("validity")
            if t.is_utf8:
                col = Column.utf8(bufs["offsets"].view(np.int64),
                                  bufs["values"].view(np.uint8), validity)
                if cm["name"] in dict_set:
                    col = _dict_encode_col(col, allocator, on_buffer)
            else:
                col = Column(t, cm["nrows"],
                             bufs["values"].view(np.dtype(t.np_dtype)),
                             validity=validity)
            fields.append(Field(cm["name"], col.type))
            cols.append(col)
    return Table.from_batch(Schema(fields), cols)


def _dict_encode_col(col: Column, allocator, on_buffer) -> Column:
    """Deserialize-with-dictionary: unique strings -> dictionary column."""
    arr = np.array([col.get_bytes(i) for i in range(col.length)])
    uniq, codes = np.unique(arr, return_inverse=True)
    # build dictionary buffers through the allocator (they are outputs too)
    lens = np.fromiter((len(u) for u in uniq), dtype=np.int64,
                       count=len(uniq))
    offsets_src = np.zeros(len(uniq) + 1, dtype=np.int64)
    np.cumsum(lens, out=offsets_src[1:])
    joined = b"".join(uniq.tolist())
    values = allocator(len(joined))
    values[:] = np.frombuffer(joined, dtype=np.uint8)
    offsets = allocator(offsets_src.nbytes).view(np.int64)
    offsets[:] = offsets_src
    codes_buf = allocator(codes.size * 4).view(np.int32)
    codes_buf[:] = codes
    for a in (values, offsets, codes_buf):
        if on_buffer is not None:
            on_buffer(a)
    dic = Column.utf8(offsets, values)
    return Column.dictionary_encoded(codes_buf, dic, validity=col.validity)


# --------------------------------------------------------------------------
# synthetic dataset generators (shared by benchmarks and tests)
# --------------------------------------------------------------------------

def gen_int_table(num_cols: int, bytes_per_col: int, seed: int = 0) -> Table:
    rng = np.random.default_rng(seed)
    n = bytes_per_col // 8
    return Table.from_pydict({
        f"i{j}": rng.integers(0, 1 << 40, size=n, dtype=np.int64)
        for j in range(num_cols)})


def gen_str_table(num_cols: int, bytes_per_col: int, str_len: int = 100,
                  repeats: int = 1, seed: int = 0) -> Table:
    """num_cols string columns; each unique value occurs ``repeats`` times."""
    rng = np.random.default_rng(seed)
    n = bytes_per_col // str_len
    uniq = n // repeats
    cols = {}
    for j in range(num_cols):
        letters = rng.integers(97, 123, size=(uniq, str_len), dtype=np.uint8)
        vals = np.repeat(letters, repeats, axis=0)[:n]
        perm = rng.permutation(len(vals))
        vals = vals[perm]
        offsets = np.arange(0, (len(vals) + 1) * str_len, str_len,
                            dtype=np.int64)
        cols[f"s{j}"] = Column.utf8(offsets, vals.reshape(-1).copy())
    return Table.from_pydict(cols)

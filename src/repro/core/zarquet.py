"""zarquet — the on-disk columnar source format (Parquet stand-in).

pyarrow is unavailable offline, so Zerrow's sources are 'zarquet' files:
compressed column chunks with a JSON footer, keeping the Parquet
properties the paper relies on:
  * compressed on disk, uncompressed Arrow in memory (deserialization is
    real decompression work, parallelizable per column — paper Fig 2);
  * ``read_table(..., dict_columns=...)`` mirrors PyArrow's
    ``read_dictionary=`` argument: chosen utf8 columns are deserialized
    straight into dictionary encoding (paper §4.2.4);
  * ``read_table(..., columns=...)`` mirrors Parquet readers' column
    selection: unselected columns are never read or decompressed — the
    hook the plan optimizer's projection pruning bottoms out on
    (``core/plan/``: unused columns never get deanonymized because they
    are never loaded at all).

zstd is preferred when the ``zstandard`` package is installed; otherwise
stdlib ``zlib`` is used.  The codec is recorded in the footer, so files
written with either codec read back on any environment that has the
matching decompressor.  Both codecs release the GIL while (de)compressing,
which is what lets the worker-pool executor overlap deserialization
across loader nodes — and, within one ``read_table``, lets the reader
pool fan column-chunk decompression out across threads (the paper's
"real deserialization work, parallelizable per column", Fig 2).

Decompression is copy-free: each blob is decompressed *directly into*
the allocator-provided page-aligned destination buffer (zstd stream
``readinto``; chunked ``zlib.decompressobj`` writes into the destination
memoryview) instead of materializing an intermediate full-size ``bytes``
and memcpy-ing it over.

Batch layout:  [MAGIC][buffer blob .... ][footer json][footer_len u64][MAGIC]

Stream layout (``StreamWriter``) generalizes this to append-only *row
groups*: every commit appends the new groups' blobs plus a fresh footer
describing **all** groups, then atomically advances a sidecar commit
pointer (``<path>.commit``).  Superseded footers stay behind as dead
bytes — committed blob extents are immutable, so readers that mapped an
older version keep working, and a crash mid-append is invisible (the
pointer still names the previous durable footer; reopening truncates
the torn tail and the producer re-sends unACKed batches —
at-least-once).  Stream footers carry ``version`` (monotonic) and
``groups`` (per-group column metadata + a content hash that
``core/fingerprint.py`` uses so an append invalidates only consumers of
the new tail).
"""

from __future__ import annotations

import binascii
import hashlib
import io
import json
import os
import struct
import threading
import zlib
from concurrent.futures import ThreadPoolExecutor
from typing import Callable, Dict, List, Optional, Sequence

import numpy as np

try:
    import zstandard
except ImportError:                       # clean environment: stdlib only
    zstandard = None

from . import faultplane, vkernels
from .arrow import (ArrowType, Column, Field, RecordBatch, Schema, Table,
                    UTF8)
from .buffers import alloc_aligned

MAGIC = b"ZQ01"
DEFAULT_CODEC = "zstd" if zstandard is not None else "zlib"

#: zlib copy-free path decompresses in bounded chunks into the destination
_ZLIB_CHUNK = 1 << 20

#: below this much uncompressed data a reader pool costs more than it
#: saves (thread spin-up vs sub-ms decompression) — stay serial
_PARALLEL_MIN_BYTES = 1 << 20


def _default_readers() -> int:
    """Reader-pool width for one read_table call (per-column-chunk
    decompression fan-out): env ZERROW_READER_THREADS, else
    min(4, cpu count).  The codecs release the GIL, so this is real
    parallelism even under the thread executor."""
    env = os.environ.get("ZERROW_READER_THREADS")
    if env:
        return max(1, int(env))
    return max(1, min(4, os.cpu_count() or 1))


def _comp(data: np.ndarray, level: int, codec: str = DEFAULT_CODEC) -> bytes:
    raw = np.ascontiguousarray(data).view(np.uint8).reshape(-1).tobytes()
    if codec == "zstd":
        return zstandard.ZstdCompressor(level=level).compress(raw)
    return zlib.compress(raw, level)


def _require_zstd() -> None:
    if zstandard is None:
        raise RuntimeError(
            "zarquet file was written with zstd but the 'zstandard' "
            "package is not installed; rewrite the source with the "
            "zlib codec or install zstandard")


def _decomp_into(blob: bytes, dest: np.ndarray, codec: str) -> None:
    """Decompress ``blob`` directly into ``dest`` (uint8, exactly the
    uncompressed length) — no intermediate full-size ``bytes`` object."""
    mv = memoryview(dest)
    rlen = len(mv)
    if codec == "zstd":
        _require_zstd()
        reader = zstandard.ZstdDecompressor().stream_reader(io.BytesIO(blob))
        if not hasattr(reader, "readinto"):
            # very old zstandard without stream readinto: one-shot with
            # the intermediate copy (correctness over copy-freedom)
            raw = zstandard.ZstdDecompressor().decompress(
                blob, max_output_size=rlen)
            if len(raw) != rlen:
                raise ValueError(
                    f"zarquet: zstd buffer decompressed to an unexpected "
                    f"size (got {len(raw)}, want {rlen})")
            mv[:] = raw
            return
        pos = 0
        while pos < rlen:
            k = reader.readinto(mv[pos:])
            if k == 0:
                break
            pos += k
        if pos != rlen or reader.read(1):
            raise ValueError(
                f"zarquet: zstd buffer decompressed to an unexpected size "
                f"(got {pos}, want {rlen})")
        return
    # feed the input in bounded slices: decompressobj returns the
    # not-yet-consumed input as a fresh bytes object on every capped
    # call, so capping output against the *whole* blob would re-copy an
    # O(blob) tail per chunk — bounding the fed slice bounds that re-copy
    d = zlib.decompressobj()
    src = memoryview(blob)
    pos = 0
    for a in range(0, max(len(src), 1), _ZLIB_CHUNK):
        data = src[a:a + _ZLIB_CHUNK]
        while True:
            remaining = rlen - pos
            chunk = d.decompress(data, min(remaining, _ZLIB_CHUNK)
                                 if remaining else 1)
            if chunk:
                if len(chunk) > remaining:
                    raise ValueError("zarquet: zlib buffer decompressed "
                                     "past the destination size")
                mv[pos:pos + len(chunk)] = chunk
                pos += len(chunk)
            data = d.unconsumed_tail
            if not data:
                break
        if d.eof:
            break
    tail = d.flush()
    if tail:
        if len(tail) > rlen - pos:
            raise ValueError("zarquet: zlib buffer decompressed past the "
                             "destination size")
        mv[pos:pos + len(tail)] = tail
        pos += len(tail)
    if pos != rlen:
        raise ValueError(
            f"zarquet: zlib buffer decompressed to an unexpected size "
            f"(got {pos}, want {rlen})")


def _encode_group(b, level: int, codec: str, off: int,
                  with_hash: bool = False):
    """Compress one RecordBatch into blobs + column metadata starting at
    file offset ``off``.  ``with_hash`` additionally computes the group's
    content hash: sha256 over the *uncompressed* stored representation
    (column names, types, buffer names and raw bytes), so group identity
    is codec- and placement-independent."""
    blobs: List[bytes] = []
    cols_meta = []
    d = hashlib.sha256() if with_hash else None
    for f, c in zip(b.schema.fields, b.columns):
        if c.type.is_dict:
            c = c.decode_dictionary()       # store plain; re-encode at read
        bufs_meta = []
        if d is not None:
            d.update(json.dumps([f.name, c.type.to_json(),
                                 c.length]).encode())
        for bname, arr in c.buffers():
            raw = np.ascontiguousarray(arr)
            if d is not None:
                d.update(bname.encode())
                d.update(raw.view(np.uint8).reshape(-1).data)
            blob = _comp(raw, level, codec)
            bufs_meta.append({"name": bname, "off": off, "clen": len(blob),
                              "rlen": raw.nbytes, "np": str(raw.dtype)})
            blobs.append(blob)
            off += len(blob)
        cols_meta.append({"name": f.name,
                          "type": (c.type.to_json()),
                          "nrows": c.length,
                          "buffers": bufs_meta})
    return blobs, cols_meta, (d.hexdigest() if d is not None else None), off


def write_table(path: str, table: Table, level: int = 1,
                codec: str = DEFAULT_CODEC) -> None:
    if codec == "zstd" and zstandard is None:
        raise RuntimeError("zstd codec requested but 'zstandard' is not "
                           "installed")
    t = table.combine()
    b = t.batches[0]
    blobs, cols_meta, _, _ = _encode_group(b, level, codec, len(MAGIC))
    footer = json.dumps({"columns": cols_meta, "nrows": b.num_rows,
                         "codec": codec}).encode()
    with open(path, "wb") as fh:
        fh.write(MAGIC)
        for blob in blobs:
            fh.write(blob)
        fh.write(footer)
        fh.write(struct.pack("<Q", len(footer)))
        fh.write(MAGIC)


def _commit_path(path: str) -> str:
    return path + ".commit"


def _read_commit_pointer(path: str) -> Optional[dict]:
    """Parse the sidecar commit pointer; ``None`` when absent/corrupt
    (fall back to the physical tail — correct for quiescent files)."""
    try:
        with open(_commit_path(path), "rb") as fh:
            ptr = json.loads(fh.read().decode())
        body = f"{ptr['end']}:{ptr['version']}".encode()
        if ptr.get("crc") != (binascii.crc32(body) & 0xFFFFFFFF):
            return None
        return ptr
    except (OSError, ValueError, KeyError):
        return None


#: StreamWriter commit fault points, in execution order (the stream
#: analog of manifest.CRASH_POINTS; see tests/test_faultplane.py)
STREAM_CRASH_POINTS = ("stream_pre_blob", "stream_torn_blob",
                       "stream_pre_footer", "stream_torn_footer",
                       "stream_pre_sidecar", "stream_torn_sidecar",
                       "stream_post_commit")

faultplane.register_hook("stream_pre_blob", "StreamWriter flush: before "
                         "the first row-group blob write")
faultplane.register_hook("stream_torn_blob", "StreamWriter flush: half a "
                         "row-group blob written, then SIGKILL")
faultplane.register_hook("stream_pre_footer", "StreamWriter commit: blobs "
                         "written, before the footer")
faultplane.register_hook("stream_torn_footer", "StreamWriter commit: half "
                         "the footer written, then SIGKILL")
faultplane.register_hook("stream_pre_sidecar", "StreamWriter commit: "
                         "footer fsync'd, before the sidecar pointer")
faultplane.register_hook("stream_torn_sidecar", "StreamWriter commit: "
                         "sidecar tmp fsync'd, SIGKILL before os.replace")
faultplane.register_hook("stream_post_commit", "StreamWriter commit: "
                         "pointer replaced (batch durably committed)")


def _write_commit_pointer(path: str, end: int, version: int,
                          sync: bool = True) -> None:
    """Atomically advance the commit pointer (tmp + ``os.replace``):
    readers see either the previous durable footer or the new one,
    never a torn tail."""
    body = f"{end}:{version}".encode()
    ptr = json.dumps({"end": end, "version": version,
                      "crc": binascii.crc32(body) & 0xFFFFFFFF}).encode()
    tmp = _commit_path(path) + ".tmp"
    with open(tmp, "wb") as fh:
        fh.write(ptr)
        fh.flush()
        if sync:
            os.fsync(fh.fileno())
    if faultplane.fire("stream_torn_sidecar") == "torn":
        faultplane.kill()       # tmp durable, pointer not replaced: the
                                # reader must still see the OLD commit
    os.replace(tmp, _commit_path(path))
    faultplane.fire("stream_post_commit")


def committed_end(path: str) -> int:
    """Byte offset just past the last durably committed footer: the
    commit pointer when a valid sidecar exists (stream files), else the
    physical file size (batch files / quiescent streams)."""
    ptr = _read_commit_pointer(path)
    if ptr is not None:
        return int(ptr["end"])
    return os.path.getsize(path)


def read_footer(path: str) -> dict:
    """Read the footer of a batch or stream zarquet file.

    Stream footers (``"groups"`` present) are normalized so batch-era
    consumers keep working: ``meta["columns"]`` mirrors the schema of the
    first group (all groups share it) and ``meta["nrows"]`` is the total
    across groups.  For stream files the *committed* footer is read (the
    sidecar pointer names it), so an in-progress or torn append is never
    observed."""
    end = committed_end(path)
    with open(path, "rb") as fh:
        fh.seek(end - 12)
        tail = fh.read(12)
        assert tail[-4:] == MAGIC, "not a zarquet file"
        (flen,) = struct.unpack("<Q", tail[:8])
        fh.seek(end - 12 - flen)
        meta = json.loads(fh.read(flen).decode())
    groups = meta.get("groups")
    if groups is not None and "columns" not in meta:
        meta["columns"] = groups[0]["columns"] if groups else []
    return meta


def read_table(path: str, dict_columns: Sequence[str] = (),
               allocator: Callable[[int], np.ndarray] = alloc_aligned,
               on_buffer: Optional[Callable[[np.ndarray], None]] = None,
               reader_threads: Optional[int] = None,
               columns: Optional[Sequence[str]] = None,
               row_groups: Optional[Sequence[int]] = None) -> Table:
    """Deserialize to Arrow.  ``allocator`` controls where uncompressed
    buffers land (page-aligned by default: the de-anonymization fast path).
    ``on_buffer`` lets the share wrapper register each fresh buffer as
    sandbox-charged anonymous memory.

    ``reader_threads`` fans per-buffer decompression out across a small
    thread pool (the codecs release the GIL) — per-column parallel
    deserialization within a single source; ``None`` = auto, ``<= 1`` =
    serial.  Allocation, ``on_buffer`` callbacks and column assembly all
    stay on the calling thread, in footer order, so the
    allocator/on_buffer contract is unchanged; only the GIL-free
    decompress-into step runs on pool threads.

    ``columns`` restricts the read to a subset of columns (projection
    pushdown, mirroring Parquet readers' ``columns=``): unselected
    columns are never read, decompressed or allocated — their bytes stay
    on disk.  Output column order is footer order restricted to the
    selection (order of the ``columns`` sequence itself is irrelevant);
    unknown names raise ``KeyError``.

    ``row_groups`` restricts a *stream* file read to a subset of its
    committed row groups (indices into the footer's ``groups`` list, in
    the given order); unselected groups' bytes stay on disk.  The result
    is one RecordBatch per selected group — callers that need a single
    contiguous batch ``combine()``.  Batch files are a single group 0."""
    meta = read_footer(path)
    codec = meta.get("codec", "zstd")   # pre-codec files were always zstd
    dict_set = set(dict_columns)
    groups = meta.get("groups")
    if groups is None:
        groups = [{"columns": meta["columns"], "nrows": meta["nrows"]}]
    if row_groups is not None:
        bad = [g for g in row_groups if not 0 <= g < len(groups)]
        if bad:
            raise IndexError(
                f"zarquet {path}: no such row group(s) {bad} "
                f"(file has {len(groups)})")
        groups = [groups[g] for g in row_groups]
    if not groups:
        raise ValueError(f"zarquet {path}: stream has no committed row "
                         f"groups yet")
    per_group: List[list] = []          # selected cols_meta per group
    for gi, grp in enumerate(groups):
        cols_meta = grp["columns"]
        if columns is not None:
            want = set(columns)
            missing = want - {cm["name"] for cm in cols_meta}
            if missing:
                raise KeyError(f"zarquet {path}: no such column(s) "
                               f"{sorted(missing)}")
            cols_meta = [cm for cm in cols_meta if cm["name"] in want]
            if not cols_meta:
                raise KeyError(f"zarquet {path}: empty column selection")
        per_group.append(cols_meta)
    # 1) allocate destinations + record blob extents (group, footer order)
    spans: List[tuple] = []             # (file_off, clen) per buffer
    dests: List[np.ndarray] = []
    for cols_meta in per_group:
        for cm in cols_meta:
            for bm in cm["buffers"]:
                spans.append((bm["off"], bm["clen"]))
                dests.append(allocator(bm["rlen"]))
    # 2) decompress directly into the destinations, in parallel — one
    # pool across every selected group.  Blobs are read per job and
    # dropped as soon as they are consumed, so peak memory is
    # destinations + the in-flight blobs, never + the whole compressed
    # file
    n_threads = reader_threads if reader_threads is not None \
        else _default_readers()
    jobs = [i for i, d in enumerate(dests) if d.nbytes]
    total = sum(dests[i].nbytes for i in jobs)
    with open(path, "rb") as fh:
        io_lock = threading.Lock()

        def _decomp_job(i: int) -> None:
            off, clen = spans[i]
            with io_lock:
                fh.seek(off)
                blob = fh.read(clen)
            _decomp_into(blob, dests[i], codec)

        if n_threads > 1 and len(jobs) > 1 and total >= _PARALLEL_MIN_BYTES:
            with ThreadPoolExecutor(min(n_threads, len(jobs))) as pool:
                list(pool.map(_decomp_job, jobs))
        else:
            for i in jobs:
                _decomp_job(i)
    # 3) register buffers + assemble columns (calling thread, footer
    # order within each group; one RecordBatch per group)
    batches: List[RecordBatch] = []
    it = iter(dests)
    for cols_meta in per_group:
        fields, cols = [], []
        for cm in cols_meta:
            bufs: Dict[str, np.ndarray] = {}
            for bm in cm["buffers"]:
                out = next(it)
                if on_buffer is not None:
                    on_buffer(out)
                bufs[bm["name"]] = out.view(np.dtype(bm["np"]))
            t = ArrowType.from_json(cm["type"])
            validity = bufs.get("validity")
            if t.is_utf8:
                col = Column.utf8(bufs["offsets"].view(np.int64),
                                  bufs["values"].view(np.uint8), validity)
                if cm["name"] in dict_set:
                    col = _dict_encode_col(col, allocator, on_buffer)
            else:
                col = Column(t, cm["nrows"],
                             bufs["values"].view(np.dtype(t.np_dtype)),
                             validity=validity)
            fields.append(Field(cm["name"], col.type))
            cols.append(col)
        batches.append(RecordBatch(Schema(fields), cols))
    return Table(batches)


def _dict_encode_col(col: Column, allocator, on_buffer) -> Column:
    """Deserialize-with-dictionary: unique strings -> dictionary column
    (vectorized: vkernels.dict_encode_var, no per-row Python)."""
    codes, uoff, uvals = vkernels.dict_encode_var(col.offsets, col.values)
    # build dictionary buffers through the allocator (they are outputs too)
    values = allocator(uvals.nbytes)
    values[:] = uvals
    offsets = allocator(uoff.nbytes).view(np.int64)
    offsets[:] = uoff
    codes_buf = allocator(codes.size * 4).view(np.int32)
    codes_buf[:] = codes
    for a in (values, offsets, codes_buf):
        if on_buffer is not None:
            on_buffer(a)
    dic = Column.utf8(offsets, values)
    return Column.dictionary_encoded(codes_buf, dic, validity=col.validity)


# --------------------------------------------------------------------------
# streaming ingest (append-oriented micro-batch writer)
# --------------------------------------------------------------------------

class StreamWriter:
    """Append-oriented zarquet writer: micro-batches in, row groups out.

    Lifecycle (the Zerobus ingest shape): open/create the stream →
    ``ingest()`` micro-batches (buffered; a bounded in-flight window of
    ``max_inflight`` unACKed batches triggers an automatic commit) →
    ``flush()`` to force a commit → ``close()``.  A commit appends one
    row group per pending micro-batch, writes a fresh footer describing
    all groups with a monotonically increasing ``version``, fsyncs, and
    only then advances the sidecar commit pointer — the durability
    point.  Every micro-batch in the commit is then ACKed (sequence
    numbers via ``poll_acks()`` and/or the ``on_ack`` callback).

    Delivery is at-least-once: a batch whose ACK was observed is durable
    and will never be lost; a crash before the pointer advanced leaves a
    torn tail that reopening truncates, and the producer re-sends
    whatever it never saw ACKed.  Committed group extents are immutable,
    so concurrent readers of older versions are undisturbed and
    per-group content hashes stay valid forever.
    """

    def __init__(self, path: str, level: int = 1,
                 codec: str = DEFAULT_CODEC, max_inflight: int = 8,
                 sync: bool = True,
                 on_ack: Optional[Callable[[List[int], int], None]] = None):
        if codec == "zstd" and zstandard is None:
            raise RuntimeError("zstd codec requested but 'zstandard' is "
                               "not installed")
        self.path = path
        self.level = level
        self.codec = codec
        self.max_inflight = max(1, int(max_inflight))
        self.sync = sync
        self.on_ack = on_ack
        self._lock = threading.Lock()
        self._pending: List[tuple] = []         # (seq, combined batch)
        self._acked: List[int] = []
        self._next_seq = 0
        self._names: Optional[List[str]] = None
        self._groups: List[dict] = []
        self._nrows = 0
        if os.path.exists(path) and os.path.getsize(path) > len(MAGIC):
            self._recover()
        else:
            self.version = 0
            self._fh = open(path, "wb")
            self._fh.write(MAGIC)
            self._end = len(MAGIC)
            self._commit_footer()               # v0: readable when empty

    def _recover(self) -> None:
        """Reopen an existing stream for append: honor the commit
        pointer, drop any torn tail past it, resume at the committed
        version."""
        try:
            meta = read_footer(self.path)
        except (AssertionError, ValueError, KeyError, struct.error):
            if _read_commit_pointer(self.path) is not None:
                raise
            # torn during the *first* footer write, before any sidecar
            # pointer existed: no commit ever completed, so no batch can
            # have been ACKed — recover to an empty stream
            self.version = 0
            self._fh = open(self.path, "r+b")
            self._fh.truncate(len(MAGIC))
            self._end = len(MAGIC)
            self._commit_footer()
            return
        if meta.get("groups") is None:
            raise ValueError(
                f"zarquet {self.path}: batch file, not a stream "
                f"(write_table output cannot be appended to)")
        if meta.get("codec", "zstd") != self.codec:
            self.codec = meta["codec"]          # stick with the file's codec
        self.version = meta["version"]
        self._groups = list(meta["groups"])
        self._nrows = meta["nrows"]
        if self._groups:
            self._names = [cm["name"] for cm in self._groups[0]["columns"]]
        end = committed_end(self.path)
        self._fh = open(self.path, "r+b")
        if os.path.getsize(self.path) > end:
            self._fh.truncate(end)              # torn (uncommitted) tail
        self._end = end

    # -- ingest side -------------------------------------------------------

    @property
    def inflight(self) -> int:
        with self._lock:
            return len(self._pending)

    def ingest(self, table: Table) -> int:
        """Buffer one micro-batch; returns its sequence number.  The
        batch is *not* durable until a commit ACKs it.  When the
        in-flight window is full the call commits synchronously
        (bounded-window backpressure)."""
        b = table.combine().batches[0]
        names = [f.name for f in b.schema.fields]
        with self._lock:
            if self._names is None:
                self._names = names
            elif names != self._names:
                raise ValueError(
                    f"stream {self.path}: micro-batch schema {names} does "
                    f"not match the stream schema {self._names}")
            seq = self._next_seq
            self._next_seq += 1
            self._pending.append((seq, b))
            full = len(self._pending) >= self.max_inflight
        if full:
            self.flush()
        return seq

    def flush(self) -> int:
        """Commit all pending micro-batches (one row group each, one
        footer+pointer write total), fsync, advance the pointer, ACK.
        Returns the new footer version (unchanged if nothing pending)."""
        with self._lock:
            if not self._pending:
                return self.version
            pending, self._pending = self._pending, []
            off = self._end
            self._fh.seek(off)
            faultplane.fire("stream_pre_blob")
            new_groups = []
            for _seq, b in pending:
                blobs, cols_meta, ghash, off = _encode_group(
                    b, self.level, self.codec, off, with_hash=True)
                for blob in blobs:
                    if faultplane.fire("stream_torn_blob") == "torn":
                        # half a blob past the committed footer: reopen
                        # must truncate it (nothing was ACKed)
                        self._fh.write(blob[:max(len(blob) // 2, 1)])
                        self._fh.flush()
                        faultplane.kill()
                    self._fh.write(blob)
                new_groups.append({"columns": cols_meta,
                                   "nrows": b.num_rows, "hash": ghash})
            self._groups.extend(new_groups)
            self._nrows += sum(g["nrows"] for g in new_groups)
            self.version += 1
            self._write_footer_locked(off)
            for seq, _b in pending:
                self._acked.append(seq)
            acked = [seq for seq, _b in pending]
            version = self.version
        if self.on_ack is not None:
            self.on_ack(acked, version)
        return version

    def _write_footer_locked(self, off: int) -> None:
        footer = json.dumps({"groups": self._groups, "nrows": self._nrows,
                             "version": self.version,
                             "codec": self.codec}).encode()
        faultplane.fire("stream_pre_footer")
        if faultplane.fire("stream_torn_footer") == "torn":
            # half the footer, no pointer advance: the sidecar still
            # names the previous footer, so reopen recovers to it
            self._fh.write(footer[:max(len(footer) // 2, 1)])
            self._fh.flush()
            faultplane.kill()
        self._fh.write(footer)
        self._fh.write(struct.pack("<Q", len(footer)))
        self._fh.write(MAGIC)
        self._fh.flush()
        if self.sync:
            os.fsync(self._fh.fileno())
        faultplane.fire("stream_pre_sidecar")
        self._end = off + len(footer) + 8 + len(MAGIC)
        _write_commit_pointer(self.path, self._end, self.version,
                              sync=self.sync)

    def _commit_footer(self) -> None:
        with self._lock:
            self._fh.seek(self._end)
            self._write_footer_locked(self._end)

    def poll_acks(self) -> List[int]:
        """Drain and return the sequence numbers ACKed since the last
        poll (commit order)."""
        with self._lock:
            out, self._acked = self._acked, []
            return out

    def close(self) -> None:
        """Flush pending batches and release the file handle."""
        self.flush()
        with self._lock:
            if not self._fh.closed:
                self._fh.close()

    def __enter__(self) -> "StreamWriter":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


# --------------------------------------------------------------------------
# synthetic dataset generators (shared by benchmarks and tests)
# --------------------------------------------------------------------------

def gen_int_table(num_cols: int, bytes_per_col: int, seed: int = 0) -> Table:
    rng = np.random.default_rng(seed)
    n = bytes_per_col // 8
    return Table.from_pydict({
        f"i{j}": rng.integers(0, 1 << 40, size=n, dtype=np.int64)
        for j in range(num_cols)})


def gen_str_table(num_cols: int, bytes_per_col: int, str_len: int = 100,
                  repeats: int = 1, seed: int = 0) -> Table:
    """num_cols string columns; each unique value occurs ``repeats`` times."""
    rng = np.random.default_rng(seed)
    n = bytes_per_col // str_len
    uniq = n // repeats
    cols = {}
    for j in range(num_cols):
        letters = rng.integers(97, 123, size=(uniq, str_len), dtype=np.uint8)
        vals = np.repeat(letters, repeats, axis=0)[:n]
        perm = rng.permutation(len(vals))
        vals = vals[perm]
        offsets = np.arange(0, (len(vals) + 1) * str_len, str_len,
                            dtype=np.int64)
        cols[f"s{j}"] = Column.utf8(offsets, vals.reshape(-1).copy())
    return Table.from_pydict(cols)

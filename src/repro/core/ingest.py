"""Continuous differential recompute over streaming zarquet ingest.

``zarquet.StreamWriter`` commits micro-batches as immutable row groups;
this module closes the loop: ``IncrementalRecompute`` watches a stream
table and, after each ACKed commit, re-runs its consumer DAG — one
loader per committed row group (``NodeSpec.row_groups=(g,)``), an
optional per-group map stage, and a reduce over all groups — through
the ordinary executor.  Because committed group extents (and therefore
their footer content hashes) are immutable, every loader and map node
over the *stable prefix* re-fingerprints to exactly the value it had
last refresh: the PR 3 manifest marks those cones ``CACHED`` before any
scheduling and the executor recomputes only the new tail's load→map
cone plus the reduce (whose input set changed).  Nodes recomputed per
micro-batch is O(tail), not O(table) — the one-shard-diff result made
continuous.

Serving stays concurrent with recompute: the latest reduce output is
held as a refcounted snapshot.  Readers pin it (``with
driver.snapshot() as (table, version)``) for zero-copy reads while the
next refresh runs; a superseded snapshot is released only when its last
reader lets go, so a swap never invalidates an in-progress query.

The reduce stage defaults to ``ops.concat_tables`` — row concatenation
is batch-list concatenation, zero new bytes — so "the whole table as of
version v" is itself a zero-copy view over per-group cached outputs.
Order-dependent aggregations (float sums) belong *after* the concat,
over the combined table, so incremental results stay bit-identical to a
from-scratch run over the same groups.
"""

from __future__ import annotations

import os
import threading
import time
from contextlib import contextmanager
from dataclasses import dataclass
from typing import Callable, List, Optional

from . import faultplane, ops, zarquet
from .arrow import Table
from .dag import DAG, NodeSpec
from .sipc import SipcReader

UserFn = Callable[[List[Table]], Table]

faultplane.register_hook("refresh_pre_swap", "ingest: fail a refresh "
                         "after the DAG ran but before the served "
                         "snapshot swaps (readers must keep the old "
                         "version)")


@dataclass
class RefreshStats:
    """What one ``refresh()`` did — the differential-recompute receipt."""
    version: int            # stream footer version this refresh is of
    groups: int             # committed row groups at that version
    nodes_total: int        # DAG size (cold run executes all of these)
    nodes_executed: int     # nodes actually run (the affected cone)
    cache_hits: int         # nodes satisfied CACHED from the manifest
    wall_s: float


def _release_msg(msg, store) -> None:
    """Release a kept output and GC its store files once unreferenced
    (same ownership discipline as the data pipeline's batch loop)."""
    msg.release()
    for fid in list(msg.files_referenced()):
        f = store.files.get(fid)
        if f is not None and f.refcount == 0 and not f.decache_pinned:
            store.delete_file(fid)


class _Snapshot:
    """Refcounted served table version.  The driver holds one reference;
    each in-progress reader holds one more.  The backing message is
    released when the last reference drops — after the driver swapped in
    a newer version AND every reader of this one finished."""

    def __init__(self, msg, version: int, store):
        self.msg = msg
        self.version = version
        self._store = store
        self._lock = threading.Lock()
        self._refs = 1

    def acquire(self) -> None:
        with self._lock:
            self._refs += 1

    def release(self) -> None:
        with self._lock:
            self._refs -= 1
            free = self._refs == 0
        if free:
            _release_msg(self.msg, self._store)


class IncrementalRecompute:
    """Differential rerun driver for one stream zarquet table.

    ``refresh()`` re-fingerprints the consumer DAG against the stream's
    committed footer and runs it; untouched group cones adopt from the
    manifest (``CACHED``), so only the new tail executes.  Requires a
    persistent manifest (``RMConfig(cache_root=...)``) — without it
    every refresh would re-execute the whole DAG, which defeats the
    point loudly rather than silently.

    ``map_fn`` (optional) runs once per row group — per-micro-batch
    transform work that is never repeated for old groups.  ``reduce_fn``
    combines the per-group outputs in group order (default
    ``ops.concat_tables``: zero-copy).  Both must be picklable
    module-level callables for process-mode workers and deterministic
    for fingerprinting (see ``core/fingerprint.py``).
    """

    def __init__(self, path: str, *, store, rm, executor,
                 map_fn: Optional[UserFn] = None,
                 reduce_fn: Optional[UserFn] = None,
                 dict_columns: tuple = (),
                 columns: Optional[tuple] = None,
                 name: Optional[str] = None,
                 tenant: str = "default",
                 deadline_s: Optional[float] = None):
        if rm.manifest is None:
            raise ValueError(
                "IncrementalRecompute needs cross-run fingerprint caching "
                "to be differential: construct the ResourceManager with "
                "RMConfig(cache_root=...)")
        self.path = path
        self.store = store
        self.rm = rm
        self.ex = executor
        self.map_fn = map_fn
        self.reduce_fn = reduce_fn if reduce_fn is not None \
            else ops.concat_tables
        self.dict_columns = tuple(dict_columns)
        self.columns = None if columns is None else tuple(columns)
        self._name = name or f"ingest-{os.path.basename(path)}"
        # admission identity of refresh DAGs: the per-tenant memory
        # budget and (when enforcement is on) the per-refresh deadline
        # apply to recompute work like to any other submitted DAG
        self.tenant = tenant
        self.deadline_s = deadline_s
        self._lock = threading.Lock()
        self._snap: Optional[_Snapshot] = None
        self.last = RefreshStats(0, 0, 0, 0, 0, 0.0)

    @property
    def version(self) -> int:
        """Stream footer version of the currently served snapshot."""
        with self._lock:
            return self._snap.version if self._snap is not None else 0

    def refresh(self) -> RefreshStats:
        """Rebuild + rerun the consumer DAG over the stream's committed
        groups, swap the served snapshot, and report the cone that
        actually executed.  Safe to call from an ingest/ACK thread while
        other threads serve queries (the executor's run gate serializes
        DAG batches; readers never block on a refresh)."""
        meta = zarquet.read_footer(self.path)
        groups = meta.get("groups")
        if groups is None:
            raise ValueError(f"{self.path}: not a stream zarquet table")
        k = len(groups)
        version = meta.get("version", 0)
        if k == 0:
            self.last = RefreshStats(version, 0, 0, 0, 0, 0.0)
            return self.last
        est = max(os.path.getsize(self.path) * 8 // k, 1 << 18)
        nodes: List[NodeSpec] = []
        reduce_deps: List[str] = []
        for g in range(k):
            lname = f"load_g{g}"
            nodes.append(NodeSpec(lname, source=self.path, est_mem=est,
                                  dict_columns=self.dict_columns,
                                  columns=self.columns, row_groups=(g,)))
            dep = lname
            if self.map_fn is not None:
                mname = f"map_g{g}"
                nodes.append(NodeSpec(mname, fn=self.map_fn, deps=[lname],
                                      est_mem=est))
                dep = mname
            reduce_deps.append(dep)
        nodes.append(NodeSpec("reduce", fn=self.reduce_fn,
                              deps=reduce_deps, est_mem=est * k,
                              keep_output=True))
        deadline = None if self.deadline_s is None \
            else time.monotonic() + self.deadline_s
        dag = DAG(nodes, name=f"{self._name}-v{version}",
                  tenant=self.tenant, deadline=deadline)
        runs0, hits0 = self.ex.node_runs, self.ex.cache_hits
        wall = self.ex.run([dag])
        out = dag.nodes["reduce"].output
        if dag.cancelled or out is None:
            # shed / deadline-missed / poisoned refresh: the served
            # snapshot is untouched — readers keep the previous version
            raise RuntimeError(
                f"{self._name}: refresh v{version} did not complete "
                f"(outcome={dag.outcome!r})")
        try:
            injected = faultplane.fire("refresh_pre_swap")
        except BaseException:
            _release_msg(out, self.store)
            raise
        if injected in ("torn", "corrupt"):
            # kill/raise execute inside fire(); delay/stall just slow the
            # swap down — only the write-mangling actions abort it here
            _release_msg(out, self.store)
            raise RuntimeError(
                f"{self._name}: refresh v{version} aborted pre-swap "
                "(injected)")
        new = _Snapshot(out, version, self.store)
        with self._lock:
            old, self._snap = self._snap, new
        if old is not None:
            old.release()           # readers of the old version keep it
        self.last = RefreshStats(
            version=version, groups=k, nodes_total=len(nodes),
            nodes_executed=self.ex.node_runs - runs0,
            cache_hits=self.ex.cache_hits - hits0, wall_s=wall)
        return self.last

    @contextmanager
    def snapshot(self):
        """Pin + yield ``(table, version)`` of the latest refresh.  The
        table is a zero-copy SIPC view of the reduce output; it stays
        mapped for the whole ``with`` block even if newer versions land
        meanwhile."""
        with self._lock:
            snap = self._snap
            if snap is None:
                raise RuntimeError(
                    f"{self._name}: no refresh has completed yet")
            snap.acquire()
        try:
            yield SipcReader(self.store).read_table(snap.msg), snap.version
        finally:
            snap.release()

    def close(self) -> None:
        """Drop the driver's reference to the served snapshot."""
        with self._lock:
            snap, self._snap = self._snap, None
        if snap is not None:
            snap.release()

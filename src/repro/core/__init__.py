"""Zerrow core: true zero-copy Arrow pipelines (the paper's contribution).

Data-plane subsystems (paper §4.2):
  arrow    — Arrow computational format (columns, batches, chunked tables)
  buffers  — BufferStore: tmpfs analogue, cgroup charging, swap
  deanon   — KernelZero: de-anonymization (ownership transfer, direct swap)
  sipc     — Shared IPC: reference-passing streams, IPC inspection,
             resharing, dictionary sharing
  zarquet  — on-disk compressed columnar source format (Parquet stand-in;
             zstd with stdlib-zlib fallback, codec recorded per file;
             reader-pool parallel, copy-free decompression into
             allocator-provided buffers)
  vkernels — vectorized columnar kernels: GIL-releasing numpy bulk ops
             over raw (offsets, values, validity) buffers (var-length
             gather, dictionary encode, utf8 sort keys, bulk upper)
  decache  — shared deserialization cache
  dag      — DAGs, node lifecycle state machine, sandboxes, share wrapper
  ingest   — streaming ingest loop: zarquet.StreamWriter micro-batch
             commits (ACK/at-least-once, bounded in-flight window)
             driving IncrementalRecompute — per-row-group fingerprinted
             DAGs whose stable prefix stays CACHED, so each ACKed
             micro-batch recomputes only its own cone while refcounted
             snapshots serve queries concurrently

Control-plane subsystems (paper §3.1/§3.3, layered — docs/ARCHITECTURE.md):
  sched.policy     — scheduling priority protocol + registry (SCHEDULES):
                     depth-first, breadth-first, fair-share, deadline-aware
  sched.admission  — AdmissionController: budget check + make-room
  sched.eviction   — EvictionPolicy classes + registry (POLICIES):
                     none/kswap/rollback/limitdrop/adaptive
  sched.executor   — WorkerPoolExecutor: N workers pull admitted nodes
                     concurrently (loader decompression overlaps across
                     workers); workers=1 is the exact sequential semantics.
                     ProcessWorkerExecutor: same scheduling, but node ops
                     run in spawned OS processes over the Flight data
                     plane (select with RMConfig.workers_mode='process')
  flight   — the cross-process zero-copy data plane: SIPC wire protocol
             (schema bytes + (file_path, offset, length) references over a
             Unix-domain socket), FlightServer/FlightClient named-ticket
             exchange, and the process-worker pool.  Pairs with
             BufferStore(backing='file'), whose store files are real
             mmap'd files any process can map
  rm       — ResourceManager: accounting, counters, refcount-safe GC, and
             the wiring of the three sched components; re-exports the
             executor under its historical ``Executor`` name and the
             ``make_executor`` factory (workers_mode -> class)

Frontend (post-paper, docs/ARCHITECTURE.md "Query frontend"):
  plan     — declarative query layer: picklable expression trees,
             dataframe-style plan builder, rule-based logical optimizer
             (projection pruning, filter pushdown, filter->join fusion,
             common-subplan dedup), compiled to ordinary fingerprinted
             DAG nodes (``plan.scan(...).filter(...)`` ->
             ``plan.compile_plans``)

Register a new policy by subclassing ``EvictionPolicy`` (decorate with
``sched.register_eviction``) or ``SchedulePolicy`` (``register_schedule``)
and selecting it by name in ``RMConfig``.
"""

from . import faultplane, vkernels
from .faultplane import (FaultInjected, FaultPlane, StragglerDetector,
                         PLANE)
from .arrow import (ArrowType, Column, Field, RecordBatch, Schema, Table,
                    BOOL, FLOAT32, FLOAT64, INT8, INT16, INT32, INT64,
                    UINT8, UINT64, UTF8, dict_of, pack_validity,
                    unpack_validity)
from .buffers import (PAGE, AnonRegion, BufferStore, Cgroup, OOMError,
                      StoreFile, StoreStats, alloc_aligned)
from .dag import (CACHED, DAG, InvalidTransition, NodeSpec, NodeState,
                  Sandbox, VALID_TRANSITIONS)
from .deanon import KernelZero
from .decache import DeCache
from .fingerprint import (code_fingerprint, file_fingerprint,
                          fingerprint_dag, node_fingerprint,
                          source_fingerprint)
from .ingest import IncrementalRecompute, RefreshStats
from .zarquet import StreamWriter
from .flight import (FlightClient, FlightError, FlightServer,
                     FlightWorkerError, FlightWorkerLost, FlightWorkerPool,
                     WireError, decode_message, encode_message, frame_refs)
from .manifest import Manifest, ManifestEntry
from .rm import (Executor, POLICIES, RMConfig, ResourceManager,
                 WORKERS_MODES, make_executor)
from .sched import (AdmissionController, EvictionPolicy,
                    NodePoisonedError, ProcessWorkerExecutor, SCHEDULES,
                    SchedulePolicy, Ticket, WorkerPoolExecutor,
                    get_eviction, get_schedule, register_eviction,
                    register_schedule)
from .sipc import (AddressMap, BufRef, SipcMessage, SipcReader, SipcWriter)
from . import plan

__all__ = [
    "ArrowType", "Column", "Field", "RecordBatch", "Schema", "Table",
    "BOOL", "FLOAT32", "FLOAT64", "INT8", "INT16", "INT32", "INT64",
    "UINT8", "UINT64", "UTF8", "dict_of", "pack_validity",
    "unpack_validity",
    "PAGE", "AnonRegion", "BufferStore", "Cgroup", "OOMError", "StoreFile",
    "StoreStats", "alloc_aligned", "CACHED", "DAG", "InvalidTransition",
    "NodeSpec", "NodeState", "Sandbox", "VALID_TRANSITIONS",
    "Manifest", "ManifestEntry", "code_fingerprint", "file_fingerprint",
    "fingerprint_dag", "node_fingerprint", "source_fingerprint",
    "IncrementalRecompute", "RefreshStats", "StreamWriter",
    "KernelZero", "DeCache", "Executor", "POLICIES", "RMConfig",
    "ResourceManager", "WORKERS_MODES", "make_executor",
    "AdmissionController", "EvictionPolicy", "SCHEDULES",
    "SchedulePolicy", "NodePoisonedError", "ProcessWorkerExecutor",
    "Ticket", "WorkerPoolExecutor", "get_eviction", "get_schedule",
    "register_eviction", "register_schedule",
    "FaultInjected", "FaultPlane", "PLANE", "StragglerDetector",
    "faultplane",
    "AddressMap", "BufRef", "SipcMessage", "SipcReader", "SipcWriter",
    "FlightClient", "FlightError", "FlightServer", "FlightWorkerError",
    "FlightWorkerLost", "FlightWorkerPool", "WireError", "decode_message",
    "encode_message", "frame_refs", "vkernels", "plan",
]

"""Zerrow core: true zero-copy Arrow pipelines (the paper's contribution).

Subsystems (paper §4.2):
  arrow    — Arrow computational format (columns, batches, chunked tables)
  buffers  — BufferStore: tmpfs analogue, cgroup charging, swap
  deanon   — KernelZero: de-anonymization (ownership transfer, direct swap)
  sipc     — Shared IPC: reference-passing streams, IPC inspection,
             resharing, dictionary sharing
  zarquet  — on-disk compressed columnar source format (Parquet stand-in)
  decache  — shared deserialization cache
  dag      — DAGs, node sandboxes, share wrapper
  rm       — Resource Manager: admission, uncache/rollback/limitdrop/adaptive
"""

from .arrow import (ArrowType, Column, Field, RecordBatch, Schema, Table,
                    BOOL, FLOAT32, FLOAT64, INT8, INT16, INT32, INT64,
                    UINT8, UTF8, dict_of, pack_validity, unpack_validity)
from .buffers import (PAGE, AnonRegion, BufferStore, Cgroup, OOMError,
                      StoreFile, StoreStats, alloc_aligned)
from .dag import DAG, NodeSpec, Sandbox
from .deanon import KernelZero
from .decache import DeCache
from .rm import Executor, POLICIES, RMConfig, ResourceManager
from .sipc import (AddressMap, BufRef, SipcMessage, SipcReader, SipcWriter)

__all__ = [
    "ArrowType", "Column", "Field", "RecordBatch", "Schema", "Table",
    "BOOL", "FLOAT32", "FLOAT64", "INT8", "INT16", "INT32", "INT64",
    "UINT8", "UTF8", "dict_of", "pack_validity", "unpack_validity",
    "PAGE", "AnonRegion", "BufferStore", "Cgroup", "OOMError", "StoreFile",
    "StoreStats", "alloc_aligned", "DAG", "NodeSpec", "Sandbox",
    "KernelZero", "DeCache", "Executor", "POLICIES", "RMConfig",
    "ResourceManager", "AddressMap", "BufRef", "SipcMessage", "SipcReader",
    "SipcWriter",
]

"""A minimal-but-real Apache Arrow columnar format (computational layout).

Implements the subset of the Arrow spec Zerrow exercises (paper §2.1):
  * fixed-width primitive arrays (contiguous, indexable values buffer)
  * variable-length utf8 arrays (offsets buffer + values buffer)
  * validity ("null") bitmaps — packed bits, like Arrow
  * dictionary encoding (int32 codes + shared dictionary array)
  * record batches and (chunked) tables

Buffers are plain C-contiguous numpy arrays so that views are zero-copy and
the SIPC layer can track physical identity via virtual addresses.  Unlike
pyarrow, a utf8 array here is allowed to have offsets that do not start at
zero: a row-slice is then pure views (offsets sub-view + the *same* values
buffer), which is what makes slice resharing free (paper Fig 6).
"""

from __future__ import annotations

import json
from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Optional, Sequence, Union

import numpy as np

from . import vkernels
from .vkernels import ranges as _ranges  # noqa: F401  (back-compat export)

# --------------------------------------------------------------------------
# types
# --------------------------------------------------------------------------


@dataclass(frozen=True)
class ArrowType:
    name: str                                  # 'int64', 'float32', ..., 'utf8', 'dict'
    np_dtype: Optional[str] = None             # numpy dtype str for primitives / codes
    value_type: Optional["ArrowType"] = None   # for dictionary

    @property
    def is_primitive(self) -> bool:
        return self.name not in ("utf8", "dict")

    @property
    def is_utf8(self) -> bool:
        return self.name == "utf8"

    @property
    def is_dict(self) -> bool:
        return self.name == "dict"

    def to_json(self) -> dict:
        d = {"name": self.name}
        if self.np_dtype:
            d["np"] = self.np_dtype
        if self.value_type:
            d["value"] = self.value_type.to_json()
        return d

    @staticmethod
    def from_json(d: dict) -> "ArrowType":
        return ArrowType(d["name"], d.get("np"),
                         ArrowType.from_json(d["value"]) if "value" in d else None)


INT8 = ArrowType("int8", "int8")
INT16 = ArrowType("int16", "int16")
INT32 = ArrowType("int32", "int32")
INT64 = ArrowType("int64", "int64")
UINT8 = ArrowType("uint8", "uint8")
UINT64 = ArrowType("uint64", "uint64")
FLOAT32 = ArrowType("float32", "float32")
FLOAT64 = ArrowType("float64", "float64")
BOOL = ArrowType("bool", "bool")
UTF8 = ArrowType("utf8")


def dict_of(value_type: ArrowType = UTF8) -> ArrowType:
    return ArrowType("dict", "int32", value_type)


_PRIMITIVES = {t.name: t for t in
               (INT8, INT16, INT32, INT64, UINT8, UINT64, FLOAT32, FLOAT64,
                BOOL)}


def type_for_np(dt: np.dtype) -> ArrowType:
    t = _PRIMITIVES.get(np.dtype(dt).name)
    if t is None:
        raise TypeError(f"unsupported numpy dtype {dt}")
    return t


# --------------------------------------------------------------------------
# validity bitmaps
# --------------------------------------------------------------------------

def pack_validity(mask: np.ndarray) -> np.ndarray:
    """bool mask (True = valid) -> packed little-endian bitmap (Arrow rule)."""
    return np.packbits(mask.astype(bool), bitorder="little")


def unpack_validity(bitmap: np.ndarray, length: int) -> np.ndarray:
    return np.unpackbits(bitmap, count=length, bitorder="little").astype(bool)


# --------------------------------------------------------------------------
# columns
# --------------------------------------------------------------------------

class Column:
    """One Arrow array: type + buffers (+ optional dictionary column).

    Buffers may be numpy arrays or unforced ``LazyBuf`` mappings (the
    mmap-fault analogue); accessing ``.values``/``.offsets``/``.validity``
    forces them.  ``raw_*`` accessors expose the unforced handles so SIPC
    can reshare pass-through buffers without touching the data.
    """

    __slots__ = ("type", "length", "_validity", "_values", "_offsets",
                 "dictionary")

    def __init__(self, type: ArrowType, length: int,
                 values,
                 offsets=None,
                 validity=None,
                 dictionary: Optional["Column"] = None):
        self.type = type
        self.length = length
        self._values = values         # primitive values / utf8 bytes / dict codes
        self._offsets = offsets       # utf8 only (int64 offsets, length+1)
        self._validity = validity     # packed bitmap or None (= all valid)
        self.dictionary = dictionary  # dict only

    # -- buffer access (forces lazy mappings) --------------------------------
    @property
    def values(self) -> np.ndarray:
        from .buffers import LazyBuf
        if isinstance(self._values, LazyBuf):
            self._values = self._values.force()
        return self._values

    @property
    def offsets(self) -> Optional[np.ndarray]:
        from .buffers import LazyBuf
        if isinstance(self._offsets, LazyBuf):
            self._offsets = self._offsets.force()
        return self._offsets

    @property
    def validity(self) -> Optional[np.ndarray]:
        from .buffers import LazyBuf
        if isinstance(self._validity, LazyBuf):
            self._validity = self._validity.force()
        return self._validity

    # -- constructors -------------------------------------------------------
    @staticmethod
    def primitive(values: np.ndarray,
                  validity: Optional[np.ndarray] = None) -> "Column":
        values = np.ascontiguousarray(values)
        return Column(type_for_np(values.dtype), len(values), values,
                      validity=validity)

    @staticmethod
    def utf8(offsets, values, validity=None) -> "Column":
        if isinstance(offsets, np.ndarray):
            assert offsets.dtype == np.int64
            n = len(offsets) - 1
        else:
            n = offsets.length // 8 - 1
        return Column(UTF8, n, values, offsets=offsets, validity=validity)

    @staticmethod
    def from_strings(strings: Sequence[Union[str, bytes]],
                     validity: Optional[np.ndarray] = None) -> "Column":
        bs = [s.encode() if isinstance(s, str) else s for s in strings]
        lens = np.fromiter((len(b) for b in bs), dtype=np.int64, count=len(bs))
        offsets = np.zeros(len(bs) + 1, dtype=np.int64)
        np.cumsum(lens, out=offsets[1:])
        values = np.frombuffer(b"".join(bs), dtype=np.uint8).copy() \
            if bs else np.empty(0, np.uint8)
        return Column.utf8(offsets, values, validity)

    @staticmethod
    def dictionary_encoded(codes: np.ndarray, dictionary: "Column",
                           validity: Optional[np.ndarray] = None) -> "Column":
        codes = np.ascontiguousarray(codes.astype(np.int32, copy=False))
        return Column(dict_of(dictionary.type), len(codes), codes,
                      validity=validity, dictionary=dictionary)

    # -- buffer enumeration (for SIPC; returns raw, possibly-lazy handles) ---
    def buffers(self) -> List[tuple]:
        """[(buffer_name, ndarray-or-LazyBuf)] in IPC order, unforced."""
        out = []
        if self._validity is not None:
            out.append(("validity", self._validity))
        if self.type.is_utf8:
            out.append(("offsets", self._offsets))
        out.append(("values", self._values))
        return out

    @property
    def nbytes(self) -> int:
        n = self._values.nbytes
        if self._offsets is not None:
            n += self._offsets.nbytes
        if self._validity is not None:
            n += self._validity.nbytes
        if self.dictionary is not None:
            n += self.dictionary.nbytes
        return n

    # -- access --------------------------------------------------------------
    def valid_mask(self) -> np.ndarray:
        if self.validity is None:
            return np.ones(self.length, dtype=bool)
        return unpack_validity(self.validity, self.length)

    def get_bytes(self, i: int) -> bytes:
        assert self.type.is_utf8
        return self.values[self.offsets[i]:self.offsets[i + 1]].tobytes()

    def to_numpy(self) -> np.ndarray:
        if self.type.is_primitive:
            return self.values
        if self.type.is_dict and self.dictionary.type.is_primitive:
            return self.dictionary.values[self.values]
        raise TypeError("to_numpy on non-primitive column")

    def decode_dictionary(self) -> "Column":
        """Materialize a dict column back to its plain representation."""
        assert self.type.is_dict
        d = self.dictionary
        if d.type.is_primitive:
            return Column.primitive(d.values[self.values], self.validity)
        # utf8 dictionary: vectorized var-length gather (vkernels.take_var)
        new_off, out = vkernels.take_var(d.offsets, d.values, self.values)
        return Column.utf8(new_off, out, self.validity)

    # -- slicing (pure views / lazy subranges; the reshare-friendly path) ---
    def slice(self, start: int, stop: int) -> "Column":
        from .buffers import LazyBuf
        start = max(0, min(start, self.length))
        stop = max(start, min(stop, self.length))
        validity = None
        if self._validity is not None:
            validity = pack_validity(self.valid_mask()[start:stop])
        if self.type.is_utf8:
            # offsets sub-view + the SAME (possibly unfaulted) values buffer
            if isinstance(self._offsets, LazyBuf):
                offs = self._offsets.subrange(start * 8,
                                              (stop - start + 1) * 8, "int64")
            else:
                offs = self._offsets[start:stop + 1]
            return Column(UTF8, stop - start, self._values,
                          offsets=offs, validity=validity)
        isz = np.dtype(self.type.np_dtype).itemsize
        if isinstance(self._values, LazyBuf):
            vals = self._values.subrange(start * isz, (stop - start) * isz,
                                         self.type.np_dtype)
        else:
            vals = self._values[start:stop]
        return Column(self.type, stop - start, vals,
                      validity=validity, dictionary=self.dictionary)

    def take(self, indices: np.ndarray) -> "Column":
        """Row gather — the materializing op (filter/sort fall back to this)."""
        validity = None
        if self.validity is not None:
            validity = pack_validity(self.valid_mask()[indices])
        if self.type.is_dict:
            # dictionary sharing: codes copied, dictionary passed by reference
            return Column(self.type, len(indices), self.values[indices],
                          validity=validity, dictionary=self.dictionary)
        if self.type.is_utf8:
            # vectorized gather of variable-length rows
            new_off, out = vkernels.take_var(self.offsets, self.values,
                                             indices)
            return Column.utf8(new_off, out, validity)
        return Column(self.type, len(indices), self.values[indices],
                      validity=validity)

    def take_nullable(self, indices: np.ndarray) -> "Column":
        """Row gather where ``indices`` may contain -1: those output rows
        are null (the left-join miss gather).  Dictionary buffers still
        pass through by reference."""
        indices = np.asarray(indices, dtype=np.int64)
        miss = indices < 0
        if not miss.any():
            return self.take(indices)
        if self.length == 0:
            return _null_column(self.type, len(indices), self.dictionary)
        safe = np.where(miss, 0, indices)
        if self.type.is_utf8:
            # miss rows gather zero bytes — clamping the index alone
            # would copy row 0's payload once per miss
            off = self.offsets
            starts = off[:-1][safe]
            lens = np.where(miss, 0, off[1:][safe] - starts)
            new_off, vals = vkernels.gather_var(self.values, starts, lens)
            vm = self.valid_mask()[safe]
            vm[miss] = False
            return Column.utf8(new_off, vals, validity=pack_validity(vm))
        out = self.take(safe)
        vm = out.valid_mask()
        vm[miss] = False
        return Column(out.type, out.length, out._values,
                      offsets=out._offsets, validity=pack_validity(vm),
                      dictionary=out.dictionary)

    # -- equality (logical, for tests) --------------------------------------
    def equals(self, other: "Column") -> bool:
        if self.length != other.length:
            return False
        ms, mo = self.valid_mask(), other.valid_mask()
        if not np.array_equal(ms, mo):
            return False
        a, b = self._logical(), other._logical()
        if a.dtype != b.dtype or a.shape != b.shape:
            # utf8 compare elementwise below
            pass
        if self._kindof() != other._kindof():
            return False
        if self._kindof() == "utf8":
            idx = np.nonzero(ms)[0]
            off_a, val_a = self._logical_var(idx)
            off_b, val_b = other._logical_var(idx)
            return bool(np.array_equal(off_a, off_b) and
                        np.array_equal(val_a, val_b))
        return bool(np.array_equal(a[ms], b[mo]))

    def _kindof(self) -> str:
        t = self.type.value_type if self.type.is_dict else self.type
        return "utf8" if t.is_utf8 else "prim"

    def _logical(self) -> np.ndarray:
        if self.type.is_primitive:
            return self.values
        if self.type.is_dict and self.dictionary.type.is_primitive:
            return self.dictionary.values[self.values]
        return self.values  # utf8: compared via _get_logical_bytes

    def _get_logical_bytes(self, i: int) -> bytes:
        if self.type.is_utf8:
            return self.get_bytes(i)
        assert self.type.is_dict and self.dictionary.type.is_utf8
        return self.dictionary.get_bytes(int(self.values[i]))

    def _logical_var(self, indices: np.ndarray):
        """(offsets, flat bytes) of the selected rows' logical byte
        strings — one var-gather, no per-row Python."""
        if self.type.is_utf8:
            return vkernels.take_var(self.offsets, self.values, indices)
        assert self.type.is_dict and self.dictionary.type.is_utf8
        d = self.dictionary
        return vkernels.take_var(d.offsets, d.values, self.values[indices])


def _null_column(t: ArrowType, n: int,
                 dictionary: Optional[Column] = None) -> Column:
    """An all-null column of ``n`` rows (every left-join miss against an
    empty build side).  Values are zeros; the validity bitmap is all 0."""
    validity = pack_validity(np.zeros(n, dtype=bool))
    if t.is_utf8:
        return Column.utf8(np.zeros(n + 1, np.int64),
                           np.empty(0, np.uint8), validity)
    values = np.zeros(n, dtype=np.dtype(t.np_dtype))
    if t.is_dict:
        if dictionary is None or dictionary.length == 0:
            # codes must index a real dictionary row even when never read
            dictionary = Column.from_strings([b""]) \
                if t.value_type.is_utf8 else \
                Column.primitive(np.zeros(1, np.dtype(t.value_type.np_dtype)))
        return Column(t, n, values, validity=validity,
                      dictionary=dictionary)
    return Column(t, n, values, validity=validity)


# --------------------------------------------------------------------------
# schema / record batch / table
# --------------------------------------------------------------------------

@dataclass(frozen=True)
class Field:
    name: str
    type: ArrowType


class Schema:
    def __init__(self, fields: Sequence[Field]):
        self.fields = list(fields)
        self._index = {f.name: i for i, f in enumerate(self.fields)}

    def __len__(self) -> int:
        return len(self.fields)

    def index(self, name: str) -> int:
        return self._index[name]

    def names(self) -> List[str]:
        return [f.name for f in self.fields]

    def to_json_bytes(self) -> bytes:
        return json.dumps([{"name": f.name, "type": f.type.to_json()}
                           for f in self.fields]).encode()

    @staticmethod
    def from_json_bytes(b: bytes) -> "Schema":
        return Schema([Field(d["name"], ArrowType.from_json(d["type"]))
                       for d in json.loads(b.decode())])

    def equals(self, other: "Schema") -> bool:
        return [(f.name, f.type) for f in self.fields] == \
               [(f.name, f.type) for f in other.fields]


class RecordBatch:
    def __init__(self, schema: Schema, columns: Sequence[Column]):
        assert len(schema) == len(columns)
        ns = {c.length for c in columns}
        assert len(ns) <= 1, f"ragged batch: {ns}"
        self.schema = schema
        self.columns = list(columns)
        self.num_rows = columns[0].length if columns else 0

    def column(self, name: str) -> Column:
        return self.columns[self.schema.index(name)]

    @property
    def nbytes(self) -> int:
        return sum(c.nbytes for c in self.columns)


class Table:
    """A chunked table: list of record batches with a common schema.

    Chunking is what makes ``concat`` zero-copy (multiple record batches in
    one IPC stream — paper Fig 6 'concat costs only the additional data')."""

    def __init__(self, batches: Sequence[RecordBatch]):
        assert batches, "Table needs >= 1 batch (may be 0-row)"
        self.batches = list(batches)
        self.schema = batches[0].schema
        for b in batches[1:]:
            assert b.schema.equals(self.schema), "schema mismatch across batches"

    # -- constructors --------------------------------------------------------
    @staticmethod
    def from_batch(schema: Schema, columns: Sequence[Column]) -> "Table":
        return Table([RecordBatch(schema, columns)])

    @staticmethod
    def from_pydict(d: Dict[str, object]) -> "Table":
        fields, cols = [], []
        for name, v in d.items():
            if isinstance(v, Column):
                col = v
            elif isinstance(v, np.ndarray):
                col = Column.primitive(v)
            else:
                v = list(v)
                if v and isinstance(v[0], (str, bytes)):
                    col = Column.from_strings(v)
                else:
                    col = Column.primitive(np.asarray(v))
            fields.append(Field(name, col.type))
            cols.append(col)
        return Table.from_batch(Schema(fields), cols)

    # -- info ----------------------------------------------------------------
    @property
    def num_rows(self) -> int:
        return sum(b.num_rows for b in self.batches)

    @property
    def num_columns(self) -> int:
        return len(self.schema)

    @property
    def nbytes(self) -> int:
        return sum(b.nbytes for b in self.batches)

    def column_chunks(self, name: str) -> List[Column]:
        return [b.column(name) for b in self.batches]

    # -- materialization -------------------------------------------------------
    def combine(self) -> "Table":
        """Concatenate batches into one (materializes: real copies)."""
        if len(self.batches) == 1:
            return self
        cols = []
        for j, f in enumerate(self.schema.fields):
            chunks = [b.columns[j] for b in self.batches]
            cols.append(_concat_columns(chunks))
        return Table.from_batch(self.schema, cols)

    def to_pydict(self) -> Dict[str, list]:
        t = self.combine()
        out: Dict[str, list] = {}
        for f, c in zip(t.schema.fields, t.batches[0].columns):
            mask = c.valid_mask()
            if c._kindof() == "utf8":
                vals = [c._get_logical_bytes(i).decode() if mask[i] else None
                        for i in range(c.length)]
            else:
                lv = c._logical()
                vals = [lv[i].item() if mask[i] else None
                        for i in range(c.length)]
            out[f.name] = vals
        return out

    def equals(self, other: "Table") -> bool:
        if not self.schema.equals(other.schema):
            return False
        if self.num_rows != other.num_rows:
            return False
        a, b = self.combine(), other.combine()
        return all(ca.equals(cb) for ca, cb in
                   zip(a.batches[0].columns, b.batches[0].columns))


def _concat_columns(chunks: List[Column]) -> Column:
    t = chunks[0].type
    validity = None
    if any(c.validity is not None for c in chunks):
        validity = pack_validity(
            np.concatenate([c.valid_mask() for c in chunks]))
    if t.is_utf8:
        vals, offs, base = [], [np.zeros(1, np.int64)], 0
        for c in chunks:
            lo, hi = int(c.offsets[0]), int(c.offsets[-1])
            vals.append(c.values[lo:hi])
            offs.append(c.offsets[1:] - lo + base)
            base += hi - lo
        return Column.utf8(np.concatenate(offs),
                           np.concatenate(vals) if vals else np.empty(0, np.uint8),
                           validity)
    if t.is_dict:
        # re-encode against the first dictionary if they are identical objects,
        # else decode+concat (correctness first)
        d0 = chunks[0].dictionary
        if all(c.dictionary is d0 for c in chunks):
            return Column(t, sum(c.length for c in chunks),
                          np.concatenate([c.values for c in chunks]),
                          validity=validity, dictionary=d0)
        return _concat_columns([c.decode_dictionary() for c in chunks])
    return Column(t, sum(c.length for c in chunks),
                  np.concatenate([c.values for c in chunks]),
                  validity=validity)

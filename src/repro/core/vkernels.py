"""Vectorized columnar kernels — GIL-releasing bulk ops over Arrow buffers.

Every kernel here works directly on the raw (offsets, values, validity)
buffers of the Arrow computational format and replaces a per-row Python
loop somewhere in the compute path:

  ``ranges`` / ``gather_var`` / ``take_var``
      variable-length row materializer: gathers N byte-ranges in three
      numpy bulk ops (repeat / arange / take).  Used by ``Column.take``,
      ``Column.decode_dictionary`` and the utf8 ``Column.equals`` branch.
  ``dict_encode_var``
      vectorized dictionary-encode of a variable-length byte column.
      Fixed-width fast path (all rows the same length, as produced by
      ``zarquet.gen_str_table``): rows are viewed as an ``np.void``
      record array and deduplicated with one ``np.unique`` (memcmp order
      == bytes-lexicographic order for equal-width rows).  General path:
      rows are zero-padded into an (n, max_len) byte matrix and sorted
      lexicographically with ``np.lexsort`` using the true length as the
      final tiebreaker — zero-padding plus a length tiebreak reproduces
      bytes comparison exactly (a prefix sorts before its extensions).
      Replaces the object-array loops in ``ops.dict_encode``,
      ``ops.sort_by`` and ``zarquet._dict_encode_col``.  Unlike the old
      ``np.array([... bytes ...])`` path (numpy 'S' dtype), trailing NUL
      bytes are significant, matching real bytes equality.
  ``sort_keys_var``
      utf8 sort-key builder: dense int32 lexicographic ranks (equal
      strings share a rank), so ``np.argsort(keys, kind='stable')``
      reproduces a stable per-row bytes sort.
  ``upper_var``
      bulk non-ASCII utf8 upper-case.  One whole-buffer decode, a
      per-*alphabet* (not per-row) uppercase table, then the var-gather
      kernel re-assembles the output bytes; row boundaries are carried
      through as character offsets.  Handles length-changing mappings
      ('ß' -> 'SS') without touching Python per row.

Kernels take and return plain numpy arrays (no Column/Table types), so
this module sits below ``arrow.py`` with no import cycle, and the big
array ops release the GIL — which is what lets the worker-pool executor
actually overlap compute-adjacent work across threads (see
docs/ARCHITECTURE.md "Compute kernels & the GIL").
"""

from __future__ import annotations

from typing import Tuple

import numpy as np

__all__ = [
    "ranges", "gather_var", "take_var", "dict_encode_var",
    "sort_keys_var", "sort_order_var", "upper_var",
]


# --------------------------------------------------------------------------
# variable-length gather
# --------------------------------------------------------------------------

def ranges(lens: np.ndarray) -> np.ndarray:
    """[0..lens[0]), [0..lens[1]), ... concatenated."""
    total = int(lens.sum())
    if total == 0:
        return np.empty(0, dtype=np.int64)
    excl = np.cumsum(lens) - lens           # exclusive prefix sums
    return np.arange(total, dtype=np.int64) - np.repeat(excl, lens)


def gather_var(values: np.ndarray, starts: np.ndarray, lens: np.ndarray
               ) -> Tuple[np.ndarray, np.ndarray]:
    """Gather ``len(starts)`` byte-ranges out of ``values``.

    Returns ``(new_offsets, out)`` with
    ``out[new_offsets[i]:new_offsets[i+1]] == values[starts[i]:starts[i]+lens[i]]``.
    """
    new_off = np.zeros(len(starts) + 1, dtype=np.int64)
    np.cumsum(lens, out=new_off[1:])
    out = np.empty(int(new_off[-1]), dtype=np.uint8)
    if len(starts) and out.nbytes:
        idx = np.repeat(starts, lens) + ranges(lens)
        np.take(values, idx, out=out)
    return new_off, out


def take_var(offsets: np.ndarray, values: np.ndarray, indices: np.ndarray
             ) -> Tuple[np.ndarray, np.ndarray]:
    """Row-gather on a var-length column: select rows ``indices`` from
    ``(offsets, values)``.  Returns ``(new_offsets, new_values)``."""
    lens = (offsets[1:] - offsets[:-1])[indices]
    starts = offsets[:-1][indices]
    return gather_var(values, starts, lens)


# --------------------------------------------------------------------------
# dictionary encode
# --------------------------------------------------------------------------

def _empty_encode(n: int) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    if n == 0:
        return (np.empty(0, np.int32), np.zeros(1, np.int64),
                np.empty(0, np.uint8))
    # n rows, all of them the empty string: one dictionary entry
    return (np.zeros(n, np.int32), np.zeros(2, np.int64),
            np.empty(0, np.uint8))


#: the padded matrix costs ~2 * n_rows * pad(max_len) bytes; on a
#: length-skewed column (many short rows, one huge outlier) that can
#: dwarf the actual data.  Past BOTH limits the kernels fall back to the
#: per-row path rather than OOM: padded bytes > _SKEW_RATIO x data bytes
#: and > _SKEW_FLOOR absolute.
_SKEW_RATIO = 32
_SKEW_FLOOR = 64 << 20


def _skewed(n: int, lens: np.ndarray) -> bool:
    padded = n * (-(-int(lens.max()) // 8) * 8)
    return padded > _SKEW_FLOOR and \
        padded > _SKEW_RATIO * max(int(lens.sum()), 1)


def _row_bytes(offsets: np.ndarray, values: np.ndarray) -> list:
    """Per-row bytes objects — the skew-fallback reader."""
    return [values[offsets[i]:offsets[i + 1]].tobytes()
            for i in range(len(offsets) - 1)]


def _padded_chunks(offsets: np.ndarray, values: np.ndarray,
                   lens: np.ndarray) -> np.ndarray:
    """Rows zero-padded to a multiple of 8 bytes and packed into
    big-endian uint64 chunks: chunk-tuple comparison == memcmp of the
    padded bytes == bytes-lexicographic order, except that rows
    differing only in trailing NUL padding tie (the caller breaks ties
    with the true length)."""
    n = len(offsets) - 1
    lo, hi = int(offsets[0]), int(offsets[-1])
    window = np.ascontiguousarray(values[lo:hi])
    w = int(lens.max())
    w8 = -(-w // 8) * 8
    mat = np.zeros((n, w8), dtype=np.uint8)
    # rows are adjacent in the values window (offsets are cumulative), so
    # the window itself is already the concatenated row bytes
    if int(lens.min()) == w:
        mat[:, :w] = window.reshape(n, w)
    else:
        mat[np.repeat(np.arange(n, dtype=np.int64), lens),
            ranges(lens)] = window
    return mat.view(">u8").astype(np.uint64)    # native ints, same order


def _lex_order(chunks: np.ndarray, lens: np.ndarray,
               tiebreak: bool) -> np.ndarray:
    """Stable bytes-lexicographic sort permutation from padded chunks;
    ~w/8 integer sort keys instead of w byte keys."""
    keys = [chunks[:, j] for j in range(chunks.shape[1] - 1, -1, -1)]
    if tiebreak:
        keys = [lens] + keys
    return np.lexsort(keys)


def dict_encode_var(offsets: np.ndarray, values: np.ndarray
                    ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Dictionary-encode a var-length byte column.

    Returns ``(codes int32, uniq_offsets int64, uniq_values uint8)`` where
    the unique values are in bytes-lexicographic order and
    ``uniq[codes[i]] == row i`` — exactly ``np.unique(rows,
    return_inverse=True)`` over the row byte-strings, without building a
    Python object per row.
    """
    offsets = np.asarray(offsets)
    n = len(offsets) - 1
    lens = offsets[1:] - offsets[:-1]
    if n == 0 or int(lens.max(initial=0)) == 0:
        return _empty_encode(n)
    lo, hi = int(offsets[0]), int(offsets[-1])
    window = values[lo:hi]

    if int(lens.min()) == int(lens.max()):
        # fixed-width fast path: rows as an np.void record array; memcmp
        # order == lexicographic order at equal width — one np.unique
        w = int(lens[0])
        mat = np.ascontiguousarray(window).reshape(n, w)
        rows = mat.view(np.dtype((np.void, w))).ravel()
        uniq, codes = np.unique(rows, return_inverse=True)
        uvals = uniq.view(np.uint8).reshape(len(uniq), w).reshape(-1).copy()
        uoff = np.arange(0, (len(uniq) + 1) * w, w, dtype=np.int64)
        return codes.astype(np.int32), uoff, uvals

    if _skewed(n, lens):
        # length-skewed column: the padded matrix would dwarf the data
        rows = _row_bytes(offsets, values)
        uniq = sorted(set(rows))
        index = {s: i for i, s in enumerate(uniq)}
        codes = np.fromiter((index[r] for r in rows), dtype=np.int32,
                            count=n)
        ulens = np.fromiter((len(u) for u in uniq), dtype=np.int64,
                            count=len(uniq))
        uoff = np.zeros(len(uniq) + 1, dtype=np.int64)
        np.cumsum(ulens, out=uoff[1:])
        uvals = np.frombuffer(b"".join(uniq), dtype=np.uint8)
        return codes, uoff, uvals
    # general path: padded big-endian chunks, stable lexicographic sort
    # (prefixes sort before extensions; true length breaks pad ties)
    chunks = _padded_chunks(offsets, values, lens)
    order = _lex_order(chunks, lens, tiebreak=True)
    schunks, slens = chunks[order], lens[order]
    new_group = np.empty(n, dtype=bool)
    new_group[0] = True
    new_group[1:] = (schunks[1:] != schunks[:-1]).any(axis=1) | \
                    (slens[1:] != slens[:-1])
    group = np.cumsum(new_group) - 1
    codes = np.empty(n, dtype=np.int32)
    codes[order] = group.astype(np.int32)
    firsts = order[new_group]           # representative row per unique
    uoff, uvals = gather_var(values, offsets[:-1][firsts], lens[firsts])
    return codes, uoff, uvals


def sort_keys_var(offsets: np.ndarray, values: np.ndarray) -> np.ndarray:
    """Dense int32 lexicographic ranks of a var-length byte column:
    ``np.argsort(sort_keys_var(...), kind='stable')`` == a stable sort by
    row bytes.  Use for *rank* lookups (e.g. dictionary-rank sorting);
    for a direct row sort, ``sort_order_var`` skips the second argsort."""
    codes, _, _ = dict_encode_var(offsets, values)
    return codes


def sort_order_var(offsets: np.ndarray, values: np.ndarray) -> np.ndarray:
    """Stable bytes-lexicographic sort permutation of a var-length byte
    column — one lexsort over packed chunks, no per-row keys and no
    second argsort over ranks."""
    offsets = np.asarray(offsets)
    n = len(offsets) - 1
    lens = offsets[1:] - offsets[:-1]
    if n == 0 or int(lens.max(initial=0)) == 0:
        return np.arange(n, dtype=np.int64)
    if _skewed(n, lens):
        return np.argsort(np.array(_row_bytes(offsets, values),
                                   dtype=object), kind="stable")
    chunks = _padded_chunks(offsets, values, lens)
    fixed = int(lens.min()) == int(lens.max())
    return _lex_order(chunks, lens, tiebreak=not fixed)


# --------------------------------------------------------------------------
# bulk utf8 upper-case
# --------------------------------------------------------------------------

def upper_var(offsets: np.ndarray, values: np.ndarray
              ) -> Tuple[np.ndarray, np.ndarray]:
    """Upper-case every row of a utf8 column in bulk.

    Handles the general (non-ASCII) case where byte lengths change
    ('ß' -> 'SS'): the whole values window is decoded once, the uppercase
    mapping is computed per *unique code point* (alphabet-sized, not
    row-sized), and the output bytes are re-assembled with the var-gather
    kernel.  Returns ``(new_offsets, new_values)`` with zero-based
    offsets.  Raises ``UnicodeDecodeError`` on invalid utf8, like the
    per-row decode it replaces.
    """
    offsets = np.asarray(offsets)
    n = len(offsets) - 1
    lo, hi = int(offsets[0]), int(offsets[-1])
    window = np.ascontiguousarray(values[lo:hi])
    if window.size == 0:
        return offsets - lo, np.empty(0, np.uint8)
    text = window.tobytes().decode("utf-8")
    cps = np.frombuffer(text.encode("utf-32-le"), dtype=np.uint32)
    # character index of every byte -> row boundaries in character space
    is_start = (window & 0xC0) != 0x80
    nchars = np.zeros(len(window) + 1, dtype=np.int64)
    np.cumsum(is_start, out=nchars[1:])
    char_off = nchars[offsets - lo]               # (n+1,) char boundaries
    # per-unique-codepoint uppercase expansion (alphabet-sized loop)
    uniq_cp, inv = np.unique(cps, return_inverse=True)
    upper_bytes = [chr(int(c)).upper().encode("utf-8") for c in uniq_cp]
    ulens = np.fromiter((len(b) for b in upper_bytes), dtype=np.int64,
                        count=len(upper_bytes))
    uoff = np.zeros(len(upper_bytes) + 1, dtype=np.int64)
    np.cumsum(ulens, out=uoff[1:])
    uvals = np.frombuffer(b"".join(upper_bytes), dtype=np.uint8) \
        if upper_bytes else np.empty(0, np.uint8)
    # per-input-character output lengths -> new row offsets + one gather
    clens = ulens[inv]
    ccum = np.zeros(len(cps) + 1, dtype=np.int64)
    np.cumsum(clens, out=ccum[1:])
    new_off = ccum[char_off]
    _, out = gather_var(uvals, uoff[:-1][inv], clens)
    return new_off, out

"""Vectorized columnar kernels — GIL-releasing bulk ops over Arrow buffers.

Every kernel works directly on raw (offsets, values, validity) buffers of
the Arrow computational format and replaces a per-row Python loop on the
compute path.  One-line contracts for every public kernel:

Var-length gather:
  ``ranges(lens)``            [0..lens[0]) .. [0..lens[n-1]) concatenated.
  ``gather_var(v, starts, lens)``  gather N byte-ranges out of ``v`` ->
      (new_offsets, out) in three bulk ops (repeat / arange / take).
  ``take_var(off, v, idx)``   row-gather on a var-length column: select
      rows ``idx`` -> (new_offsets, new_values).  Used by ``Column.take``,
      ``Column.decode_dictionary`` and the utf8 ``Column.equals`` branch.

Dictionary encode / sort:
  ``dict_encode_var(off, v)`` -> (codes i32, uniq_offsets, uniq_values):
      exactly ``np.unique`` over the row byte-strings (uniques in
      bytes-lexicographic order) without a Python object per row.
      Fixed-width fast path: rows viewed as an ``np.void`` record array,
      one ``np.unique`` (memcmp order == bytes order at equal width).
      General path: rows zero-padded into big-endian uint64 chunks +
      ``np.lexsort`` with the true length as final tiebreaker (a prefix
      sorts before its extensions; trailing NULs are significant).
      Length-skewed columns (padded matrix > 32x data and > 64 MiB) fall
      back to a per-row path instead of OOMing.
  ``sort_keys_var(off, v)``   dense int32 lexicographic ranks (equal rows
      share a rank): ``np.argsort(keys, kind='stable')`` == stable bytes
      sort.  Also the var-length group-code builder for ``group_ranges``.
  ``sort_order_var(off, v)``  direct stable bytes-sort permutation (one
      lexsort over packed chunks, no second argsort over ranks).

Rewriting:
  ``upper_var(off, v)``       bulk non-ASCII utf8 upper-case: one
      whole-window decode, a per-*alphabet* (not per-row) uppercase
      table, one var-gather.  Handles length changes ('ß' -> 'SS').

Relational (hash join + group-by, the zero-copy relational engine):
  ``hash_fixed(v)``           uint64 splitmix64 hash of a fixed-width
      array's bit patterns (float -0.0 canonicalized to +0.0).
  ``hash_var(off, v)``        uint64 hash of each var-length row: XOR of
      position-salted mixed chunks over the row's own ceil(len/8)
      big-endian uint64 chunks, length-seeded — a pure function of the
      row bytes (identical across column widths, slices, and the
      per-row skew fallback), so equal bytes always hash equal.
  ``hash_keys(keys, n)``      combine raw key buffers (ndarray = fixed
      width, (offsets, values) tuple = var-length) into one order-
      sensitive uint64 row hash per table row.
  ``combine_hashes(hs, n)``   the representation-free combiner under
      ``hash_keys``: fold precomputed per-column uint64 hashes (how a
      dict key hashes its dictionary once yet matches a plain utf8 key).
  ``hash_join_probe(bh, ph)`` hash-equality candidate pairs: sort the
      build hashes once, searchsorted every probe hash -> (probe_idx,
      build_idx) index arrays, probe-major, build ascending within a
      probe row.  Collisions survive; the caller confirms key equality.
  ``filter_join_gather(sel, idx)``  compose a filter's selection with a
      join's gather indices in one step (-1 miss sentinels preserved) —
      the fused filter->join never materializes the filtered table.
  ``bytes_rows_equal(off_a, v_a, off_b, v_b)``  per-row bool: row i of A
      == row i of B (length compare + one flat gather-and-compare).
  ``group_ranges(codes)``     group boundary detection over per-column
      dense codes: (order, starts) with ``order`` a stable lexsort
      permutation and ``starts`` each group's first sorted position.
  ``grouped_count / grouped_sum / grouped_min / grouped_max /
  grouped_mean(values, order, starts, valid=None)``  segment reducers
      over ``group_ranges`` boundaries; nulls are excluded and each
      returns per-group ``(values, counts)`` (count of non-null rows) so
      the caller can null out empty (all-null) groups.

Kernels take and return plain numpy arrays (no Column/Table types), so
this module sits below ``arrow.py`` with no import cycle, and the big
array ops release the GIL — which is what lets the worker-pool executor
actually overlap compute-adjacent work across threads (see
docs/ARCHITECTURE.md "Compute kernels & the GIL").

Ops reached from the query frontend (``core/plan/``) pin their kernels
via ``__fp_includes__`` (``ops.filter_join`` chains down to
``filter_join_gather``), so editing a kernel here invalidates cached
plan outputs exactly as it invalidates hand-wired ones.

This module is also the *reference semantics* for the accelerator
backend: ``core/kdispatch.py`` (``ZERROW_KERNEL_BACKEND=pallas``) may
route hashing, join gathers, and the integer segment reducers to the
Pallas ports in ``repro.kernels.relational`` — but only kernels the
differential harness proves bit-identical to the functions here are
admitted, and order-sensitive float reductions (``grouped_sum``'s
sequential ``np.bincount`` accumulation, ``reduceat`` extreme ties)
stay on this code path by registry.  Behavior changes here are contract
changes for both backends.
"""

from __future__ import annotations

from typing import Sequence, Tuple, Union

import numpy as np

__all__ = [
    "ranges", "gather_var", "take_var", "dict_encode_var",
    "sort_keys_var", "sort_order_var", "upper_var",
    "hash_fixed", "hash_var", "hash_keys", "combine_hashes",
    "hash_join_probe",
    "bytes_rows_equal", "group_ranges", "grouped_count", "grouped_sum",
    "grouped_min", "grouped_max", "grouped_mean",
]


# --------------------------------------------------------------------------
# variable-length gather
# --------------------------------------------------------------------------

def ranges(lens: np.ndarray) -> np.ndarray:
    """[0..lens[0]), [0..lens[1]), ... concatenated."""
    total = int(lens.sum())
    if total == 0:
        return np.empty(0, dtype=np.int64)
    excl = np.cumsum(lens) - lens           # exclusive prefix sums
    return np.arange(total, dtype=np.int64) - np.repeat(excl, lens)


def gather_var(values: np.ndarray, starts: np.ndarray, lens: np.ndarray
               ) -> Tuple[np.ndarray, np.ndarray]:
    """Gather ``len(starts)`` byte-ranges out of ``values``.

    Returns ``(new_offsets, out)`` with
    ``out[new_offsets[i]:new_offsets[i+1]] == values[starts[i]:starts[i]+lens[i]]``.
    """
    new_off = np.zeros(len(starts) + 1, dtype=np.int64)
    np.cumsum(lens, out=new_off[1:])
    out = np.empty(int(new_off[-1]), dtype=np.uint8)
    if len(starts) and out.nbytes:
        idx = np.repeat(starts, lens) + ranges(lens)
        np.take(values, idx, out=out)
    return new_off, out


def take_var(offsets: np.ndarray, values: np.ndarray, indices: np.ndarray
             ) -> Tuple[np.ndarray, np.ndarray]:
    """Row-gather on a var-length column: select rows ``indices`` from
    ``(offsets, values)``.  Returns ``(new_offsets, new_values)``."""
    lens = (offsets[1:] - offsets[:-1])[indices]
    starts = offsets[:-1][indices]
    return gather_var(values, starts, lens)


# --------------------------------------------------------------------------
# dictionary encode
# --------------------------------------------------------------------------

def _empty_encode(n: int) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    if n == 0:
        return (np.empty(0, np.int32), np.zeros(1, np.int64),
                np.empty(0, np.uint8))
    # n rows, all of them the empty string: one dictionary entry
    return (np.zeros(n, np.int32), np.zeros(2, np.int64),
            np.empty(0, np.uint8))


#: the padded matrix costs ~2 * n_rows * pad(max_len) bytes; on a
#: length-skewed column (many short rows, one huge outlier) that can
#: dwarf the actual data.  Past BOTH limits the kernels fall back to the
#: per-row path rather than OOM: padded bytes > _SKEW_RATIO x data bytes
#: and > _SKEW_FLOOR absolute.
_SKEW_RATIO = 32
_SKEW_FLOOR = 64 << 20


def _skewed(n: int, lens: np.ndarray) -> bool:
    padded = n * (-(-int(lens.max()) // 8) * 8)
    return padded > _SKEW_FLOOR and \
        padded > _SKEW_RATIO * max(int(lens.sum()), 1)


def _row_bytes(offsets: np.ndarray, values: np.ndarray) -> list:
    """Per-row bytes objects — the skew-fallback reader."""
    return [values[offsets[i]:offsets[i + 1]].tobytes()
            for i in range(len(offsets) - 1)]


def _padded_chunks(offsets: np.ndarray, values: np.ndarray,
                   lens: np.ndarray) -> np.ndarray:
    """Rows zero-padded to a multiple of 8 bytes and packed into
    big-endian uint64 chunks: chunk-tuple comparison == memcmp of the
    padded bytes == bytes-lexicographic order, except that rows
    differing only in trailing NUL padding tie (the caller breaks ties
    with the true length)."""
    n = len(offsets) - 1
    lo, hi = int(offsets[0]), int(offsets[-1])
    window = np.ascontiguousarray(values[lo:hi])
    w = int(lens.max())
    w8 = -(-w // 8) * 8
    mat = np.zeros((n, w8), dtype=np.uint8)
    # rows are adjacent in the values window (offsets are cumulative), so
    # the window itself is already the concatenated row bytes
    if int(lens.min()) == w:
        mat[:, :w] = window.reshape(n, w)
    else:
        mat[np.repeat(np.arange(n, dtype=np.int64), lens),
            ranges(lens)] = window
    return mat.view(">u8").astype(np.uint64)    # native ints, same order


def _lex_order(chunks: np.ndarray, lens: np.ndarray,
               tiebreak: bool) -> np.ndarray:
    """Stable bytes-lexicographic sort permutation from padded chunks;
    ~w/8 integer sort keys instead of w byte keys."""
    keys = [chunks[:, j] for j in range(chunks.shape[1] - 1, -1, -1)]
    if tiebreak:
        keys = [lens] + keys
    return np.lexsort(keys)


def dict_encode_var(offsets: np.ndarray, values: np.ndarray
                    ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Dictionary-encode a var-length byte column.

    Returns ``(codes int32, uniq_offsets int64, uniq_values uint8)`` where
    the unique values are in bytes-lexicographic order and
    ``uniq[codes[i]] == row i`` — exactly ``np.unique(rows,
    return_inverse=True)`` over the row byte-strings, without building a
    Python object per row.
    """
    offsets = np.asarray(offsets)
    n = len(offsets) - 1
    lens = offsets[1:] - offsets[:-1]
    if n == 0 or int(lens.max(initial=0)) == 0:
        return _empty_encode(n)
    lo, hi = int(offsets[0]), int(offsets[-1])
    window = values[lo:hi]

    if int(lens.min()) == int(lens.max()):
        # fixed-width fast path: rows as an np.void record array; memcmp
        # order == lexicographic order at equal width — one np.unique
        w = int(lens[0])
        mat = np.ascontiguousarray(window).reshape(n, w)
        rows = mat.view(np.dtype((np.void, w))).ravel()
        uniq, codes = np.unique(rows, return_inverse=True)
        uvals = uniq.view(np.uint8).reshape(len(uniq), w).reshape(-1).copy()
        uoff = np.arange(0, (len(uniq) + 1) * w, w, dtype=np.int64)
        return codes.astype(np.int32), uoff, uvals

    if _skewed(n, lens):
        # length-skewed column: the padded matrix would dwarf the data
        rows = _row_bytes(offsets, values)
        uniq = sorted(set(rows))
        index = {s: i for i, s in enumerate(uniq)}
        codes = np.fromiter((index[r] for r in rows), dtype=np.int32,
                            count=n)
        ulens = np.fromiter((len(u) for u in uniq), dtype=np.int64,
                            count=len(uniq))
        uoff = np.zeros(len(uniq) + 1, dtype=np.int64)
        np.cumsum(ulens, out=uoff[1:])
        uvals = np.frombuffer(b"".join(uniq), dtype=np.uint8)
        return codes, uoff, uvals
    # general path: padded big-endian chunks, stable lexicographic sort
    # (prefixes sort before extensions; true length breaks pad ties)
    chunks = _padded_chunks(offsets, values, lens)
    order = _lex_order(chunks, lens, tiebreak=True)
    schunks, slens = chunks[order], lens[order]
    new_group = np.empty(n, dtype=bool)
    new_group[0] = True
    new_group[1:] = (schunks[1:] != schunks[:-1]).any(axis=1) | \
                    (slens[1:] != slens[:-1])
    group = np.cumsum(new_group) - 1
    codes = np.empty(n, dtype=np.int32)
    codes[order] = group.astype(np.int32)
    firsts = order[new_group]           # representative row per unique
    uoff, uvals = gather_var(values, offsets[:-1][firsts], lens[firsts])
    return codes, uoff, uvals


def sort_keys_var(offsets: np.ndarray, values: np.ndarray) -> np.ndarray:
    """Dense int32 lexicographic ranks of a var-length byte column:
    ``np.argsort(sort_keys_var(...), kind='stable')`` == a stable sort by
    row bytes.  Use for *rank* lookups (e.g. dictionary-rank sorting);
    for a direct row sort, ``sort_order_var`` skips the second argsort."""
    codes, _, _ = dict_encode_var(offsets, values)
    return codes


def sort_order_var(offsets: np.ndarray, values: np.ndarray) -> np.ndarray:
    """Stable bytes-lexicographic sort permutation of a var-length byte
    column — one lexsort over packed chunks, no per-row keys and no
    second argsort over ranks."""
    offsets = np.asarray(offsets)
    n = len(offsets) - 1
    lens = offsets[1:] - offsets[:-1]
    if n == 0 or int(lens.max(initial=0)) == 0:
        return np.arange(n, dtype=np.int64)
    if _skewed(n, lens):
        return np.argsort(np.array(_row_bytes(offsets, values),
                                   dtype=object), kind="stable")
    chunks = _padded_chunks(offsets, values, lens)
    fixed = int(lens.min()) == int(lens.max())
    return _lex_order(chunks, lens, tiebreak=not fixed)


# --------------------------------------------------------------------------
# bulk utf8 upper-case
# --------------------------------------------------------------------------

def upper_var(offsets: np.ndarray, values: np.ndarray
              ) -> Tuple[np.ndarray, np.ndarray]:
    """Upper-case every row of a utf8 column in bulk.

    Handles the general (non-ASCII) case where byte lengths change
    ('ß' -> 'SS'): the whole values window is decoded once, the uppercase
    mapping is computed per *unique code point* (alphabet-sized, not
    row-sized), and the output bytes are re-assembled with the var-gather
    kernel.  Returns ``(new_offsets, new_values)`` with zero-based
    offsets.  Raises ``UnicodeDecodeError`` on invalid utf8, like the
    per-row decode it replaces.
    """
    offsets = np.asarray(offsets)
    n = len(offsets) - 1
    lo, hi = int(offsets[0]), int(offsets[-1])
    window = np.ascontiguousarray(values[lo:hi])
    if window.size == 0:
        return offsets - lo, np.empty(0, np.uint8)
    text = window.tobytes().decode("utf-8")
    cps = np.frombuffer(text.encode("utf-32-le"), dtype=np.uint32)
    # character index of every byte -> row boundaries in character space
    is_start = (window & 0xC0) != 0x80
    nchars = np.zeros(len(window) + 1, dtype=np.int64)
    np.cumsum(is_start, out=nchars[1:])
    char_off = nchars[offsets - lo]               # (n+1,) char boundaries
    # per-unique-codepoint uppercase expansion (alphabet-sized loop)
    uniq_cp, inv = np.unique(cps, return_inverse=True)
    upper_bytes = [chr(int(c)).upper().encode("utf-8") for c in uniq_cp]
    ulens = np.fromiter((len(b) for b in upper_bytes), dtype=np.int64,
                        count=len(upper_bytes))
    uoff = np.zeros(len(upper_bytes) + 1, dtype=np.int64)
    np.cumsum(ulens, out=uoff[1:])
    uvals = np.frombuffer(b"".join(upper_bytes), dtype=np.uint8) \
        if upper_bytes else np.empty(0, np.uint8)
    # per-input-character output lengths -> new row offsets + one gather
    clens = ulens[inv]
    ccum = np.zeros(len(cps) + 1, dtype=np.int64)
    np.cumsum(clens, out=ccum[1:])
    new_off = ccum[char_off]
    _, out = gather_var(uvals, uoff[:-1][inv], clens)
    return new_off, out


# --------------------------------------------------------------------------
# bulk hashing (the hash-join key path)
# --------------------------------------------------------------------------

#: one key spec for ``hash_keys``: a fixed-width array, or the
#: (offsets, values) buffer pair of a var-length column
KeyBuf = Union[np.ndarray, Tuple[np.ndarray, np.ndarray]]

_GOLDEN = np.uint64(0x9E3779B97F4A7C15)


def _mix64(h: np.ndarray) -> np.ndarray:
    """splitmix64 finalizer, elementwise over a uint64 array."""
    with np.errstate(over="ignore"):
        h = h ^ (h >> np.uint64(30))
        h = h * np.uint64(0xBF58476D1CE4E5B9)
        h = h ^ (h >> np.uint64(27))
        h = h * np.uint64(0x94D049BB133111EB)
        return h ^ (h >> np.uint64(31))


def hash_fixed(values: np.ndarray) -> np.ndarray:
    """uint64 hash per element of a fixed-width array, from the bit
    pattern.  Float ``-0.0`` is canonicalized to ``+0.0`` first so equal
    values (under ``==``) always hash equal; NaNs hash by bit pattern,
    which is fine because NaN never equals anything."""
    values = np.ascontiguousarray(values)
    if np.issubdtype(values.dtype, np.floating):
        values = np.where(values == 0, 0, values)
    w = values.dtype.itemsize
    bits = np.ascontiguousarray(values).view(f"u{w}").astype(np.uint64) \
        if w < 8 else np.ascontiguousarray(values).view(np.uint64)
    return _mix64(bits ^ _GOLDEN)


def _chunk_salts(m: int) -> np.ndarray:
    """Per-position uint64 salts for the chunk hash (position-keyed, so
    'ab'+'cd' cannot collide with 'cd'+'ab')."""
    with np.errstate(over="ignore"):
        return _mix64((np.arange(m, dtype=np.uint64) + np.uint64(1))
                      * _GOLDEN)


def hash_var(offsets: np.ndarray, values: np.ndarray) -> np.ndarray:
    """uint64 hash per row of a var-length byte column.

    A pure function of the row's bytes: ``mix(mix(len) ^ XOR_j
    mix(chunk_j ^ salt_j))`` over the row's *own* zero-padded big-endian
    uint64 chunks — the XOR runs over exactly ``ceil(len/8)`` positions,
    so the hash is identical across columns of different widths, across
    slices, and across the length-skewed fallback (which computes the
    same formula row by row).  The length seed keeps strings that differ
    only in trailing NULs distinct."""
    offsets = np.asarray(offsets)
    n = len(offsets) - 1
    lens = offsets[1:] - offsets[:-1]
    h = _mix64(lens.astype(np.uint64) ^ _GOLDEN)
    if n == 0 or int(lens.max(initial=0)) == 0:
        # all rows empty: acc is 0 for every row, but the final mix must
        # still run or an empty row here would hash differently from an
        # empty row in a mixed column
        return _mix64(h)
    if _skewed(n, lens):
        acc = np.fromiter(
            (_row_chunk_acc(r) for r in _row_bytes(offsets, values)),
            dtype=np.uint64, count=n)
        return _mix64(h ^ acc)
    chunks = _padded_chunks(offsets, values, lens)
    salts = _chunk_salts(chunks.shape[1])
    nchunks = (lens + 7) // 8
    acc = np.zeros(n, dtype=np.uint64)
    for j in range(chunks.shape[1]):
        term = _mix64(chunks[:, j] ^ salts[j])
        acc ^= np.where(j < nchunks, term, np.uint64(0))
    return _mix64(h ^ acc)


def _row_chunk_acc(row: bytes) -> np.uint64:
    """One row's chunk accumulator (the skew fallback), same formula as
    the vectorized path but over a single row's chunk array."""
    m = -(-len(row) // 8)
    if m == 0:
        return np.uint64(0)
    arr = np.frombuffer(row.ljust(m * 8, b"\0"), dtype=">u8") \
        .astype(np.uint64)
    return np.bitwise_xor.reduce(_mix64(arr ^ _chunk_salts(m)))


def combine_hashes(col_hashes: Sequence[np.ndarray], n: int) -> np.ndarray:
    """Fold per-column uint64 hash arrays into one row hash.
    Order-sensitive: the same columns in a different order hash
    differently.  This is the representation-free half of ``hash_keys``:
    a dict-encoded key column can hash its dictionary once, scatter
    through its codes, and still combine identically to the plain utf8
    column it decodes to."""
    h = np.full(n, _GOLDEN, dtype=np.uint64)
    with np.errstate(over="ignore"):
        for hk in col_hashes:
            h = _mix64(h * _GOLDEN ^ hk)
    return h


def hash_keys(keys: Sequence[KeyBuf], n: int) -> np.ndarray:
    """Combine raw key buffers into one uint64 row hash.  Each key is a
    fixed-width ndarray or an ``(offsets, values)`` pair; ``n`` is the
    row count (needed for the zero-key edge).  ``ops._key_hashes``
    composes the same primitives directly (a dict-encoded key needs a
    hash-the-dictionary-then-scatter step a raw KeyBuf cannot express)
    and must stay hash-identical to this on plain columns."""
    return combine_hashes(
        [hash_var(*k) if isinstance(k, tuple) else hash_fixed(k)
         for k in keys], n)


def hash_join_probe(build_hash: np.ndarray, probe_hash: np.ndarray
                    ) -> Tuple[np.ndarray, np.ndarray]:
    """Hash-equality candidate pairs between a build and a probe side.

    Sorts the build hashes once (the 'build' phase), then binary-searches
    every probe hash into the sorted order and expands the equal-hash
    runs: returns ``(probe_idx, build_idx)`` int64 index arrays, one
    entry per candidate pair, probe-major with build indices ascending
    within each probe row.  Distinct keys that collide on the 64-bit
    hash survive as candidates — the caller confirms real key equality.
    """
    order = np.argsort(build_hash, kind="stable")
    sh = build_hash[order]
    lo = np.searchsorted(sh, probe_hash, side="left")
    hi = np.searchsorted(sh, probe_hash, side="right")
    counts = hi - lo
    probe_idx = np.repeat(np.arange(len(probe_hash), dtype=np.int64),
                          counts)
    build_pos = np.repeat(lo, counts) + ranges(counts)
    return probe_idx, order[build_pos]


def filter_join_gather(sel: np.ndarray, idx: np.ndarray) -> np.ndarray:
    """Compose a filter's row selection with a join's gather indices.

    ``sel`` maps a filtered (or valid-key) domain back to original row
    ids; ``idx`` gathers within that domain, with ``-1`` the left-join
    miss sentinel.  Returns original-domain gather indices with every
    ``-1`` preserved — the fusion step that lets a filter feeding a join
    run as *one* gather over the original columns instead of
    materializing the filtered intermediate table first."""
    sel = np.ascontiguousarray(sel, dtype=np.int64)
    idx = np.ascontiguousarray(idx, dtype=np.int64)
    if (idx >= 0).all():
        return sel[idx]                    # inner join: no sentinels
    out = np.full(len(idx), -1, dtype=np.int64)
    hit = idx >= 0
    out[hit] = sel[idx[hit]]
    return out


def bytes_rows_equal(off_a: np.ndarray, val_a: np.ndarray,
                     off_b: np.ndarray, val_b: np.ndarray) -> np.ndarray:
    """Per-row equality of two equally-long var-length columns: bool[i]
    == (row i of A == row i of B).  Lengths first, then one flat
    gather-and-compare of the equal-length rows (cumulative-sum segment
    reduction, so zero-length rows are handled exactly)."""
    off_a, off_b = np.asarray(off_a), np.asarray(off_b)
    lens_a = off_a[1:] - off_a[:-1]
    eq = lens_a == (off_b[1:] - off_b[:-1])
    idx = np.nonzero(eq)[0]
    if len(idx) == 0:
        return eq
    ga_off, ga = take_var(off_a, val_a, idx)
    _, gb = take_var(off_b, val_b, idx)
    diff = ga != gb
    if diff.any():
        cs = np.zeros(len(diff) + 1, dtype=np.int64)
        np.cumsum(diff, out=cs[1:])
        eq[idx] &= (cs[ga_off[1:]] - cs[ga_off[:-1]]) == 0
    return eq


# --------------------------------------------------------------------------
# group-by: boundary detection + segment reducers
# --------------------------------------------------------------------------

def group_ranges(codes: Sequence[np.ndarray]
                 ) -> Tuple[np.ndarray, np.ndarray]:
    """Group boundary detection over per-column dense codes.

    ``codes`` is one int array per key column; rows with equal code
    tuples form a group.  Returns ``(order, starts)``: ``order`` is a
    stable sort permutation that makes groups contiguous (primary key =
    ``codes[0]``, so groups come out in ascending code order), and
    ``starts`` marks each group's first position in the sorted order
    (``starts[0] == 0``; group g spans ``order[starts[g]:starts[g+1]]``).
    """
    n = len(codes[0])
    if n == 0:
        return np.empty(0, np.int64), np.empty(0, np.int64)
    order = np.lexsort(tuple(reversed([np.asarray(c) for c in codes])))
    new_group = np.zeros(n, dtype=bool)
    new_group[0] = True
    for c in codes:
        sc = np.asarray(c)[order]
        new_group[1:] |= sc[1:] != sc[:-1]
    return order, np.nonzero(new_group)[0]


def _group_ends(starts: np.ndarray, n: int) -> np.ndarray:
    return np.append(starts[1:], n)


def grouped_count(values: np.ndarray, order: np.ndarray,
                  starts: np.ndarray, valid=None
                  ) -> Tuple[np.ndarray, np.ndarray]:
    """Per-group count of non-null rows (``values`` is ignored — the
    signature matches the other reducers for uniform dispatch)."""
    ends = _group_ends(starts, len(order))
    if valid is None:
        counts = (ends - starts).astype(np.int64)
        return counts, counts
    cs = np.zeros(len(order) + 1, dtype=np.int64)
    np.cumsum(valid[order].astype(np.int64), out=cs[1:])
    counts = cs[ends] - cs[starts]
    return counts, counts


def grouped_sum(values: np.ndarray, order: np.ndarray,
                starts: np.ndarray, valid=None
                ) -> Tuple[np.ndarray, np.ndarray]:
    """Per-group sum over non-null rows -> (sums, counts).  Integer and
    bool inputs widen to int64 (SQL-style, no narrow-dtype wraparound;
    uint64 stays uint64 — widening to int64 would wrap values >= 2**63)
    and reduce with ``reduceat`` (integer addition is exact in any
    order).  The 64-bit accumulator itself wraps silently — numpy
    semantics — if a group's total exceeds int64/uint64 range; callers
    needing totals beyond 2**63 should aggregate in float.  Float
    inputs widen to float64 and accumulate with
    ``np.bincount``, whose C loop adds row by row in *original row
    order* — bit-identical to a naive left-to-right per-row loop, unlike
    ``reduceat``'s position-dependent SIMD accumulation.  A zero-count
    (all-null) group's sum is meaningless and should be nulled by the
    caller."""
    _, counts = grouped_count(values, order, starts, valid)
    n_groups = len(starts)
    if values.dtype == np.bool_ or np.issubdtype(values.dtype, np.integer):
        acc = np.uint64 if values.dtype == np.uint64 else np.int64
        if n_groups == 0:
            return np.empty(0, acc), counts
        v = values[order].astype(acc)
        if valid is not None:
            v = np.where(valid[order], v, v.dtype.type(0))
        return np.add.reduceat(v, starts), counts
    gid = np.empty(len(order), dtype=np.int64)
    gid[order] = np.repeat(np.arange(n_groups, dtype=np.int64),
                           _group_ends(starts, len(order)) - starts)
    w = values.astype(np.float64, copy=False)
    if valid is not None:
        w = np.where(valid, w, 0.0)
    return np.bincount(gid, weights=w, minlength=n_groups), counts


def _grouped_extreme(values, order, starts, valid, ufunc, sentinel):
    v = values[order]
    if v.dtype == np.bool_:
        v = v.astype(np.uint8)
    if valid is not None:
        v = np.where(valid[order], v, sentinel(v.dtype))
    _, counts = grouped_count(values, order, starts, valid)
    return ufunc.reduceat(v, starts), counts


def _dtype_max(dt):
    return np.inf if np.issubdtype(dt, np.floating) else np.iinfo(dt).max


def _dtype_min(dt):
    return -np.inf if np.issubdtype(dt, np.floating) else np.iinfo(dt).min


def grouped_min(values, order, starts, valid=None):
    """Per-group min over non-null rows -> (mins, counts)."""
    return _grouped_extreme(values, order, starts, valid,
                            np.minimum, _dtype_max)


def grouped_max(values, order, starts, valid=None):
    """Per-group max over non-null rows -> (maxs, counts)."""
    return _grouped_extreme(values, order, starts, valid,
                            np.maximum, _dtype_min)


def grouped_mean(values, order, starts, valid=None):
    """Per-group float64 mean over non-null rows -> (means, counts);
    zero-count groups produce NaN (the caller nulls them).  64-bit
    integer inputs accumulate in float64 (the result is float64 anyway,
    and an exact 64-bit sum could wrap the accumulator)."""
    if np.issubdtype(values.dtype, np.integer) and values.dtype.itemsize == 8:
        values = values.astype(np.float64)
    sums, counts = grouped_sum(values, order, starts, valid)
    with np.errstate(invalid="ignore", divide="ignore"):
        return sums.astype(np.float64) / counts, counts


#: reducer dispatch for ``ops.group_by`` (all share one signature)
GROUPED_REDUCERS = {
    "count": grouped_count, "sum": grouped_sum, "min": grouped_min,
    "max": grouped_max, "mean": grouped_mean,
}

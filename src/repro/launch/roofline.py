"""Roofline analysis from compiled dry-run artifacts (TPU v5e target).

Three terms per (arch × shape × mesh), in seconds:

    compute    = HLO_FLOPs_per_chip    / PEAK_FLOPS      (197 TFLOP/s bf16)
    memory     = HLO_bytes_per_chip    / HBM_BW          (819 GB/s)
    collective = collective_bytes/chip / ICI_BW          (~50 GB/s/link)

FLOPs and collective bytes come from walking the optimized HLO text —
including *while-loop bodies multiplied by their trip counts* (XLA's own
cost analysis counts a scanned layer once; we scan over layers, so this
correction is what makes 96-layer models report honest numbers).  Ring
accounting for collectives: all-reduce moves 2·(n-1)/n bytes/chip,
all-gather/reduce-scatter/all-to-all (n-1)/n, permute 1.

An independent analytic model (6·N·D dense / 6·N_active·D MoE + exact
param/KV-cache byte counts) cross-checks every cell; both are reported.
"""

from __future__ import annotations

import dataclasses
import json
import re
from typing import Dict, List, Optional, Tuple

# -- hardware constants (TPU v5e) -------------------------------------------
PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link (used: 1 link per axis hop)
DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16, "s4": 1, "u4": 1,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$")
_CALLED_RE = re.compile(
    r"(?:to_apply|body|condition|branch_computations|called_computations)="
    r"[{]?%?([\w.\-, %]+)[}]?")


def _parse_shape(s: str) -> Tuple[str, List[int]]:
    m = _SHAPE_RE.search(s)
    if not m:
        return "f32", []
    dims = [int(d) for d in m.group(2).split(",") if d] if m.group(2) else []
    return m.group(1), dims


def _nbytes(dtype: str, dims: List[int]) -> int:
    n = DTYPE_BYTES.get(dtype, 4)
    for d in dims:
        n *= d
    return n


def _all_shapes(expr: str) -> List[Tuple[str, List[int]]]:
    out = []
    for m in _SHAPE_RE.finditer(expr):
        dims = [int(d) for d in m.group(2).split(",") if d] \
            if m.group(2) else []
        out.append((m.group(1), dims))
    return out


@dataclasses.dataclass
class CompStats:
    flops: float = 0.0
    coll_bytes: float = 0.0
    coll_by_kind: Dict[str, float] = dataclasses.field(default_factory=dict)
    hbm_bytes: float = 0.0
    calls: List[Tuple[str, float]] = dataclasses.field(default_factory=list)
    # (computation_name, multiplier)


class HloAnalyzer:
    """Walks optimized HLO text computation-by-computation."""

    def __init__(self, hlo_text: str):
        self.comps: Dict[str, List[str]] = {}
        self.entry: Optional[str] = None
        self._split(hlo_text)
        # symbol table: instruction name -> list of (dtype, dims) of its
        # result (tuples give several entries).  Operands are printed
        # without shapes in optimized HLO, so dot contraction sizes must be
        # resolved through this table.
        self.shape_of: Dict[str, List[Tuple[str, List[int]]]] = {}
        self.defs: Dict[str, str] = {}
        for lines in self.comps.values():
            for line in lines:
                mi = _INSTR_RE.match(line)
                if not mi:
                    continue
                expr = mi.group(2)
                lhs = expr.split("(")[0] if "(" in expr else expr
                self.shape_of[mi.group(1)] = _all_shapes(lhs)
                self.defs[mi.group(1)] = line.strip()
        self.stats: Dict[str, CompStats] = {}
        for name in self.comps:
            self.stats[name] = self._analyze(name)
        self._total_cache: Dict[str, CompStats] = {}

    # -- parsing ------------------------------------------------------------
    def _split(self, text: str) -> None:
        """HLO text: computation headers start at column 0 (optionally
        'ENTRY '), instructions are indented."""
        cur = None
        for line in text.splitlines():
            if not line:
                continue
            if not line[0].isspace():
                m = re.match(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(", line)
                if m and "{" in line:
                    cur = m.group(1)
                    self.comps[cur] = []
                    if line.startswith("ENTRY"):
                        self.entry = cur
                continue
            if cur is not None:
                if line.strip() == "}":
                    cur = None
                else:
                    self.comps[cur].append(line)

    def _trip_count(self, cond_name: str) -> int:
        """Largest integer constant in the loop condition (heuristic; scan
        conditions compare the induction variable with the trip count)."""
        best = 1
        for line in self.comps.get(cond_name, []):
            for m in re.finditer(r"constant\((\d+)\)", line):
                best = max(best, int(m.group(1)))
        return best

    def _analyze(self, name: str) -> CompStats:
        st = CompStats()
        for line in self.comps[name]:
            mi = _INSTR_RE.match(line)
            if not mi:
                continue
            expr = mi.group(2)
            opm = re.match(r"(?:\w+\[[\d,]*\]\s*|\([^=]*\)\s*)?(\w[\w\-]*)\(",
                           expr)
            shapes = _all_shapes(expr)
            if not shapes:
                continue
            out_dtype, out_dims = shapes[0]
            op = None
            for cand in ("dot", "convolution", "while", "fusion", "call",
                         "conditional", "custom-call") + COLLECTIVES:
                if re.search(rf"\b{re.escape(cand)}\(", expr):
                    op = cand
                    break
            if op is None:
                continue
            # shapes to the LEFT of the op token are the result type(s)
            opm2 = re.search(rf"\b{re.escape(op)}\(", expr)
            out_shapes = _all_shapes(expr[:opm2.start()]) if opm2 else \
                shapes[:1]
            if op == "dot":
                st.flops += self._dot_flops(expr, out_shapes)
                st.hbm_bytes += self._dot_bytes(expr, out_shapes)
            elif op == "convolution":
                st.flops += 2 * _nbytes("s8", out_dims)  # rough lower bound
            elif op in COLLECTIVES:
                payload = self._coll_payload(op, expr, out_shapes)
                if payload and self._is_promoted(op, expr):
                    payload /= 2
                st.coll_bytes += payload
                st.coll_by_kind[op] = st.coll_by_kind.get(op, 0) + payload
                st.hbm_bytes += 2 * sum(_nbytes(dt, dm)
                                        for dt, dm in out_shapes)
            elif op == "while":
                mb = re.search(r"body=%?([\w.\-]+)", expr)
                mc = re.search(r"condition=%?([\w.\-]+)", expr)
                if mb:
                    trips = self._trip_count(mc.group(1)) if mc else 1
                    st.calls.append((mb.group(1), float(trips)))
            elif op in ("fusion", "call", "conditional", "custom-call"):
                mto = re.search(r"(?:to_apply|calls)=%?([\w.\-]+)", expr)
                if mto and mto.group(1) in self.comps:
                    st.calls.append((mto.group(1), 1.0))
                if op == "fusion":
                    st.hbm_bytes += sum(_nbytes(dt, dm)
                                        for dt, dm in shapes)
        return st

    def _dot_operands(self, expr: str) -> List[List[Tuple[str, List[int]]]]:
        mo = re.search(r"\bdot\(([^)]*)\)", expr)
        if not mo:
            return []
        names = [a.strip().lstrip("%") for a in mo.group(1).split(",")]
        return [self.shape_of.get(n, []) for n in names]

    def _dot_flops(self, expr: str, out_shapes) -> float:
        # 2 × output elements × contraction size (from the lhs operand's
        # shape, resolved through the symbol table)
        out_dims = out_shapes[0][1] if out_shapes else []
        ops = self._dot_operands(expr)
        lhs_dims = ops[0][0][1] if ops and ops[0] else []
        mc = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", expr)
        contr = 1
        if mc and lhs_dims:
            for idx in (int(i) for i in mc.group(1).split(",") if i):
                if idx < len(lhs_dims):
                    contr *= lhs_dims[idx]
        out_elems = 1
        for d in out_dims:
            out_elems *= d
        return 2.0 * out_elems * contr

    def _dot_bytes(self, expr: str, out_shapes) -> float:
        n = sum(_nbytes(dt, dm) for dt, dm in out_shapes)
        for op_shapes in self._dot_operands(expr):
            n += sum(_nbytes(dt, dm) for dt, dm in op_shapes)
        return float(n)

    def _is_promoted(self, op: str, expr: str) -> bool:
        """XLA's CPU backend has no bf16 collectives: it wraps them in
        f32 converts (reducers named '*promoted'; gather/permute operands
        fed by bf16->f32 convert fusions).  On the TPU target these run
        natively at bf16, so the roofline counts the pre-promotion width.
        """
        if "promoted" in expr:
            return True
        mo = re.search(rf"\b{re.escape(op)}\(%?([\w.\-]+)", expr)
        if not mo:
            return False
        src = self.defs.get(mo.group(1), "")
        if "convert" not in src and "fusion" not in src:
            return False
        if "bf16" in src:
            return True
        mc = re.search(r"calls=%?([\w.\-]+)", src)
        if mc:
            body = "\n".join(self.comps.get(mc.group(1), []))
            return "bf16" in body and "convert" in body
        return "convert" in src

    @staticmethod
    def _coll_payload(op: str, expr: str, out_shapes) -> float:
        size = sum(_nbytes(dt, dm) for dt, dm in out_shapes)
        n = 1
        mg = re.search(r"replica_groups=\{\{([\d,]+)\}", expr)
        if mg:
            n = len(mg.group(1).split(","))
        else:
            mi = re.search(r"replica_groups=\[(\d+),(\d+)\]", expr)
            if mi:
                n = int(mi.group(2))
        if n <= 1:
            return 0.0
        frac = (n - 1) / n
        if op == "all-reduce":
            return 2.0 * size * frac
        if op == "collective-permute":
            return float(size)
        return size * frac

    # -- rollup ------------------------------------------------------------------
    def total(self, comp: Optional[str] = None) -> CompStats:
        comp = comp or self.entry or next(iter(self.comps))
        if comp in self._total_cache:
            return self._total_cache[comp]
        st = self.stats[comp]
        agg = CompStats(st.flops, st.coll_bytes, dict(st.coll_by_kind),
                        st.hbm_bytes)
        for callee, mult in st.calls:
            if callee not in self.comps or callee == comp:
                continue
            sub = self.total(callee)
            agg.flops += mult * sub.flops
            agg.coll_bytes += mult * sub.coll_bytes
            agg.hbm_bytes += mult * sub.hbm_bytes
            for k, v in sub.coll_by_kind.items():
                agg.coll_by_kind[k] = agg.coll_by_kind.get(k, 0) + mult * v
        self._total_cache[comp] = agg
        return agg


def cpu_promotion_bytes(hlo_text: str, min_bytes: int = 1 << 28) -> float:
    """Bytes of f32 staging copies the CPU backend creates because it has
    no native bf16 FMA/collectives: hoisted convert(bf16->f32) of large
    stacked weights/caches.  The TPU target consumes bf16 directly, so the
    dry-run's temp memory is corrected by this amount when judging
    fits-in-HBM (reported as both raw and corrected in EXPERIMENTS.md)."""
    total = 0.0
    seen = set()
    for m in re.finditer(
            r"= f32\[([\d,]+)\][^=]*(?:convert|wrapped_convert)", hlo_text):
        dims = [int(d) for d in m.group(1).split(",") if d]
        n = 4
        for d in dims:
            n *= d
        if n >= min_bytes and m.group(1) not in seen:
            seen.add(m.group(1))
            total += n
    return total


# --------------------------------------------------------------------------
# analytic cross-check model
# --------------------------------------------------------------------------

def analytic_model(arch, shape, n_params: int, n_active: int) -> Dict:
    """MODEL_FLOPS and exact byte counts from the config."""
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        tokens = B * S
        flops = 6.0 * n_active * tokens
        # + attention quadratic term 12·L·d·S²·B (dense archs)
        if arch.family not in ("ssm",):
            n_attn = arch.n_layers
            if arch.family == "hybrid" and arch.block_pattern:
                n_attn = arch.n_layers // len(arch.block_pattern) * \
                    arch.block_pattern.count("attn")
            eff_S = min(S, arch.local_window) if arch.family == "hybrid" \
                else S
            flops += 12.0 * n_attn * arch.n_heads * arch.hd * eff_S * B * S
        bytes_ = 2 * n_params * 4        # rough: read+write fp32 grads/opt
    elif shape.kind == "prefill":
        tokens = B * S
        flops = 2.0 * n_active * tokens
        if arch.family not in ("ssm",):
            flops += 4.0 * arch.n_layers * arch.n_heads * arch.hd * S * S * B
        bytes_ = n_params * 2
    else:  # decode: one token per sequence
        tokens = B
        flops = 2.0 * n_active * tokens
        bytes_ = n_params * 2 + kv_cache_bytes(arch, shape)
    return {"model_flops": flops, "model_bytes": float(bytes_),
            "tokens": tokens}


def analytic_hbm_bytes(arch, shape, *, chips: int, mp: int, dp: int,
                       accum: int, n_params: int) -> float:
    """Per-chip HBM traffic estimate for one step.

    train: per microbatch each chip reads its model-shard of the gathered
    f32 weights twice (fwd + bwd), plus optimizer state r/w, the remat
    carry write+read+recompute, and the (transient) logits.
    serve:  bf16 weight read per step + KV cache read(+write).
    """
    P = float(n_params)
    L = arch.n_layers + arch.encoder_layers
    if shape.kind == "train":
        w = accum * 2 * (P / mp) * 4
        opt = 10 * (P / chips) * 4
        tokens_chip = shape.global_batch * shape.seq_len / dp
        act = 3 * L * tokens_chip * arch.d_model * 2
        logits = 3 * (tokens_chip / accum) * (arch.vocab / mp) * 4
        return w + opt + act + logits
    if shape.kind == "prefill":
        w = 2 * (P / mp) * 2
        kv = 2 * kv_cache_bytes(arch, shape) / chips
        # flash tiles re-read KV once per q-block column pass (~S/bq)
        return w + kv * 2
    # decode: one token; weights + cache dominate utterly
    w = (P / mp) * 2
    kv = 2 * kv_cache_bytes(arch, shape) / chips
    return w + kv


def kv_cache_bytes(arch, shape) -> float:
    B, S = shape.global_batch, shape.seq_len
    if arch.family == "ssm":
        N = arch.rwkv_head_dim
        H = arch.d_model // N
        return arch.n_layers * B * (H * N * N * 4 + 2 * arch.d_model * 2)
    eff = S
    n_attn = arch.n_layers
    extra = 0.0
    if arch.family == "hybrid" and arch.block_pattern:
        k = len(arch.block_pattern)
        n_attn = arch.n_layers // k * arch.block_pattern.count("attn")
        n_rec = arch.n_layers - n_attn
        eff = min(S, arch.local_window or S)
        w = arch.lru_width or arch.d_model
        extra = n_rec * B * (w * 4 + (arch.conv_width - 1) * w * 2)
    per_layer = 2 * B * eff * arch.n_kv_heads * arch.hd * 2
    total = n_attn * per_layer + extra
    if arch.is_encdec:
        total += 2 * arch.n_layers * B * S * arch.n_kv_heads * arch.hd * 2
    return float(total)


# --------------------------------------------------------------------------
# report
# --------------------------------------------------------------------------

def roofline_report(hlo_text: str, *, chips: int, arch, shape,
                    n_params: int, n_active: int,
                    cost_analysis: Optional[Dict] = None,
                    mp: int = 16, dp: Optional[int] = None,
                    accum: int = 1) -> Dict:
    an = HloAnalyzer(hlo_text)
    tot = an.total()
    dp = dp if dp is not None else max(chips // mp, 1)
    # HLO here is the per-device (SPMD) program: FLOPs/bytes are per chip
    compute_s = tot.flops / PEAK_FLOPS
    hbm_bytes = analytic_hbm_bytes(arch, shape, chips=chips, mp=mp, dp=dp,
                                   accum=accum, n_params=n_params)
    memory_s = hbm_bytes / HBM_BW
    coll_s = tot.coll_bytes / ICI_BW
    model = analytic_model(arch, shape, n_params, n_active)
    model_flops_per_chip = model["model_flops"] / chips
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": coll_s}
    dominant = max(terms, key=terms.get)
    useful = model_flops_per_chip / tot.flops if tot.flops else 0.0
    bound = max(terms.values())
    out = {
        "chips": chips,
        "hlo_flops_per_chip": tot.flops,
        "hbm_bytes_per_chip": hbm_bytes,
        "hlo_bytes_upper_bound": tot.hbm_bytes,  # per-op sum (no reuse)
        "collective_bytes_per_chip": tot.coll_bytes,
        "collective_by_kind": tot.coll_by_kind,
        **terms,
        "dominant": dominant.replace("_s", ""),
        "model_flops_total": model["model_flops"],
        "model_bytes_total": model["model_bytes"],
        "useful_flop_fraction": useful,
        "step_time_bound_s": bound,
        "roofline_fraction": (model_flops_per_chip / PEAK_FLOPS) / bound
        if bound else 0.0,           # useful-compute time / bound (≈ MFU cap)
        "tokens": model["tokens"],
    }
    if cost_analysis:
        out["xla_cost_flops"] = cost_analysis.get("flops", 0.0)
        out["xla_cost_bytes"] = cost_analysis.get("bytes accessed", 0.0)
    return out

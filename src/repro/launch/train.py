"""Training driver: Zerrow data pipeline -> jit'd train step -> async
Zerrow-backed checkpoints, with fleet monitoring hooks.

    PYTHONPATH=src python -m repro.launch.train --arch smollm-135m \
        --smoke --steps 50
"""

from __future__ import annotations

import argparse
import dataclasses
import math
import os
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_arch, smoke_variant
from ..data.pipeline import (PipelineConfig, ZerrowDataPipeline,
                             make_text_shards)
from ..models.api import ModelAPI
from ..runtime.checkpoint import CheckpointStore
from ..runtime.fault import FaultConfig, FleetMonitor, RestartPolicy
from ..train.trainstep import init_state, make_train_step


def train_loop(arch_name: str, *, steps: int = 100, batch: int = 8,
               seq_len: int = 256, smoke: bool = True,
               ckpt_dir: str = None, ckpt_every: int = 50,
               data_dir: str = None, lr: float = 1e-3,
               log_every: int = 10, resume: bool = False,
               data_workers: int = 1, workers_mode: str = "thread",
               reader_threads: int = None, cache_root: str = None):
    arch = get_arch(arch_name)
    if smoke:
        arch = smoke_variant(arch)
    arch = dataclasses.replace(arch, vocab=max(arch.vocab, 257))
    api = ModelAPI(arch)

    data_dir = data_dir or os.path.join(tempfile.gettempdir(),
                                        "zerrow-corpus")
    if not os.path.isdir(data_dir) or not os.listdir(data_dir):
        make_text_shards(data_dir, n_shards=2, rows_per_shard=4000)
    shards = sorted(os.path.join(data_dir, f)
                    for f in os.listdir(data_dir) if f.endswith(".zq"))
    pipe = ZerrowDataPipeline(shards, PipelineConfig(
        batch=batch, seq_len=seq_len, workers=data_workers,
        workers_mode=workers_mode, reader_threads=reader_threads,
        cache_root=cache_root))

    state = init_state(api, jax.random.key(0))
    store = None
    if ckpt_dir:
        store = CheckpointStore(ckpt_dir)
        if resume and store.latest_step() is not None:
            state, mani = store.restore(like=state)
            print(f"resumed from step {mani['step']}")
    step_fn = jax.jit(make_train_step(api, peak_lr=lr, total_steps=steps),
                      donate_argnums=(0,))

    monitor = FleetMonitor(n_workers=1)
    t_start = time.perf_counter()
    it = pipe.batches(epochs=10_000)
    losses = []
    for i in range(steps):
        batch_np = next(it)
        t0 = time.perf_counter()
        state, metrics = step_fn(state, {
            "tokens": jnp.asarray(batch_np["tokens"]),
            "labels": jnp.asarray(batch_np["labels"])})
        dt = time.perf_counter() - t0
        monitor.heartbeat(0, i, dt)
        losses.append(float(metrics["loss"]))
        if i % log_every == 0 or i == steps - 1:
            print(f"step {i:5d} loss {losses[-1]:.4f} "
                  f"lr {float(metrics['lr']):.2e} {dt*1e3:.0f}ms")
        if store and (i + 1) % ckpt_every == 0:
            store.save(i + 1, jax.tree.map(np.asarray, state))
    if store:
        store.close()
    print("pipeline stats:", {k: v for k, v in pipe.stats().items()
                              if k in ("decache_hits", "loads",
                                       "bytes_reshared", "bytes_deanon",
                                       "bytes_copied")})
    pipe.close()
    wall = time.perf_counter() - t_start
    print(f"done: {steps} steps in {wall:.1f}s; "
          f"loss {losses[0]:.3f} -> {losses[-1]:.3f}")
    return losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-friendly)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--data-workers", type=int, default=1,
                    help="data-pipeline worker-pool size (overlaps shard "
                         "decompression across loader nodes)")
    ap.add_argument("--workers-mode", default="thread",
                    choices=("thread", "process"),
                    help="run pipeline DAG nodes in threads or in spawned "
                         "Flight worker processes (tokenize/pack scale "
                         "past the GIL)")
    ap.add_argument("--reader-threads", type=int, default=None,
                    help="zarquet reader-pool width: fan column-chunk "
                         "decompression across this many threads inside "
                         "each shard load (default auto; 1 = serial)")
    ap.add_argument("--cache-root", default=None,
                    help="persistent content-addressed cache dir: packed "
                         "shards publish under node fingerprints and "
                         "restarts adopt unchanged shards instead of "
                         "re-tokenizing (differential caching)")
    a = ap.parse_args()
    train_loop(a.arch, steps=a.steps, batch=a.batch, seq_len=a.seq_len,
               smoke=a.smoke, ckpt_dir=a.ckpt_dir, resume=a.resume,
               lr=a.lr, data_workers=a.data_workers,
               workers_mode=a.workers_mode,
               reader_threads=a.reader_threads, cache_root=a.cache_root)


if __name__ == "__main__":
    main()

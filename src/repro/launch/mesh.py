"""Production mesh construction.

Importing this module never touches jax device state; call the functions.
  single-pod: (16, 16)        ("data", "model")   = 256 chips (one v5e pod)
  multi-pod:  (2, 16, 16)     ("pod", "data", "model") = 512 chips
"""

from __future__ import annotations

from ..compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_mesh_for(devices: int, *, model_parallel: int = 0):
    """Best-effort mesh for an arbitrary device count (tests, smoke)."""
    mp = model_parallel or max(1, min(4, devices))
    while devices % mp:
        mp -= 1
    return make_mesh((devices // mp, mp), ("data", "model"))


MESH_NAMES = ("single", "multi")


def make_named_mesh(name: str):
    assert name in MESH_NAMES, name
    return make_production_mesh(multi_pod=(name == "multi"))

"""Recompute roofline reports from saved dry-run HLO artifacts (no
recompilation — used when the analyzer itself improves).

    PYTHONPATH=src python -m repro.launch.reanalyze [dir ...]
"""

import gzip
import json
import sys
from pathlib import Path

from ..configs import SHAPES_BY_NAME, get_arch
from . import roofline as rl


def reanalyze_dir(root: Path) -> None:
    for d in sorted(root.iterdir()):
        meta_p = d / "meta.json"
        hlo_p = d / "hlo.txt.gz"
        if not (meta_p.exists() and hlo_p.exists()):
            continue
        info = json.loads(meta_p.read_text())
        arch = get_arch(info["arch"])
        shape = SHAPES_BY_NAME[info["shape"]]
        chips = info["chips"]
        mp = 16
        dp = chips // mp
        with gzip.open(hlo_p, "rt") as f:
            hlo = f.read()
        roof = rl.roofline_report(
            hlo, chips=chips, arch=arch, shape=shape,
            n_params=info["params"], n_active=info["active_params"],
            mp=mp, dp=dp, accum=info.get("accum", 1))
        info["roofline"] = roof
        promo = rl.cpu_promotion_bytes(hlo)
        info["cpu_promotion_bytes"] = promo
        info["temp_tpu_estimate"] = max(
            info["temp_bytes_per_device"] - promo, 0)
        meta_p.write_text(json.dumps(info, indent=1, default=float))
        print(f"{d.name}: {roof['dominant']}-bound "
              f"c={roof['compute_s']*1e3:.0f}ms "
              f"m={roof['memory_s']*1e3:.0f}ms "
              f"x={roof['collective_s']*1e3:.0f}ms "
              f"frac={roof['roofline_fraction']:.3f}")


if __name__ == "__main__":
    dirs = [Path(p) for p in sys.argv[1:]] or \
        [Path(__file__).resolve().parents[3] / "runs" / "dryrun"]
    for d in dirs:
        reanalyze_dir(d)

"""Serving driver: batched prefill + decode with donated KV caches.

    PYTHONPATH=src python -m repro.launch.serve --arch smollm-135m \
        --smoke --batch 4 --max-new 32
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import numpy as np

from ..configs import get_arch, smoke_variant
from ..models.api import ModelAPI
from ..serve.engine import Request, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--kv-int8", action="store_true",
                    help="int8-quantized KV cache (halves cache HBM)")
    ap.add_argument("--rounds", type=int, default=3)
    a = ap.parse_args()

    arch = get_arch(a.arch)
    if a.smoke:
        arch = smoke_variant(arch)
    if a.kv_int8:
        arch = dataclasses.replace(arch, kv_cache_dtype="int8")
    api = ModelAPI(arch)
    params = api.model.init(jax.random.key(0))
    engine = ServeEngine(api, params, batch=a.batch, max_seq=a.max_seq)

    rng = np.random.default_rng(0)
    for r in range(a.rounds):
        reqs = [Request(prompt=rng.integers(
            1, arch.vocab, size=int(rng.integers(8, a.max_seq // 2))
        ).astype(np.int32), max_new=a.max_new) for _ in range(a.batch)]
        t0 = time.perf_counter()
        outs = engine.run_batch(reqs)
        dt = time.perf_counter() - t0
        toks = sum(len(o) for o in outs)
        print(f"round {r}: {toks} tokens in {dt:.2f}s "
              f"({toks / dt:.1f} tok/s)")
    s = engine.stats
    print(f"totals: prefill {s['prefill_tokens']} tok / "
          f"{s['prefill_s']:.2f}s | decode {s['decode_steps']} steps / "
          f"{s['decode_s']:.2f}s "
          f"({s['decode_s'] / max(s['decode_steps'], 1) * 1e3:.1f} ms/step)")


if __name__ == "__main__":
    main()

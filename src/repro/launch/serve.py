"""Serving driver: batched prefill + decode with donated KV caches.

    PYTHONPATH=src python -m repro.launch.serve --arch smollm-135m \
        --smoke --batch 4 --max-new 32

With ``--prompt-shards N`` the requests come from zarquet prompt shards
through the core/sched worker-pool executor (``--workers`` overlaps shard
decompression) instead of being drawn randomly.  ``--workers-mode
process`` runs the shard loads in spawned OS processes over the Flight
data plane (file-backed store + SIPC wire references): compute-bound
stages scale past the GIL, and only tiny reference frames cross the
worker sockets.
"""

from __future__ import annotations

import argparse
import dataclasses
import os
import tempfile
import time

import jax
import numpy as np

from ..configs import get_arch, smoke_variant
from ..models.api import ModelAPI
from ..serve.engine import (Request, ServeEngine, ZerrowPromptSource,
                            make_prompt_shards)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--kv-int8", action="store_true",
                    help="int8-quantized KV cache (halves cache HBM)")
    ap.add_argument("--rounds", type=int, default=3)
    ap.add_argument("--prompt-shards", type=int, default=0,
                    help="serve prompts from N zarquet shards via the "
                         "sched executor (0 = random prompts)")
    ap.add_argument("--prompts-per-shard", type=int, default=32)
    ap.add_argument("--workers", type=int, default=1,
                    help="prompt-source worker-pool size")
    ap.add_argument("--workers-mode", default="thread",
                    choices=("thread", "process"),
                    help="run prompt-shard DAG nodes in threads or in "
                         "spawned Flight worker processes")
    ap.add_argument("--reader-threads", type=int, default=None,
                    help="zarquet reader-pool width inside each shard "
                         "load (default auto; 1 = serial)")
    ap.add_argument("--cache-root", default=None,
                    help="persistent content-addressed cache dir: prompt-"
                         "shard loads publish under node fingerprints and "
                         "re-launches adopt unchanged shards (CACHED) "
                         "instead of re-deserializing them")
    a = ap.parse_args()

    arch = get_arch(a.arch)
    if a.smoke:
        arch = smoke_variant(arch)
    if a.kv_int8:
        arch = dataclasses.replace(arch, kv_cache_dtype="int8")
    api = ModelAPI(arch)
    params = api.model.init(jax.random.key(0))
    engine = ServeEngine(api, params, batch=a.batch, max_seq=a.max_seq)

    source = None
    if a.prompt_shards > 0:
        shard_dir = os.path.join(tempfile.gettempdir(), "zerrow-prompts")
        paths = make_prompt_shards(shard_dir, a.prompt_shards,
                                   a.prompts_per_shard)
        source = ZerrowPromptSource(paths, batch=a.batch,
                                    max_new=a.max_new, workers=a.workers,
                                    workers_mode=a.workers_mode,
                                    reader_threads=a.reader_threads,
                                    cache_root=a.cache_root,
                                    max_prompt_len=a.max_seq // 2)
        batches = source.batches()
    else:
        rng = np.random.default_rng(0)
        batches = ([Request(prompt=rng.integers(
            1, arch.vocab, size=int(rng.integers(8, a.max_seq // 2))
        ).astype(np.int32), max_new=a.max_new) for _ in range(a.batch)]
            for _ in range(a.rounds))

    for r, reqs in enumerate(batches):
        if r >= a.rounds:
            break
        t0 = time.perf_counter()
        outs = engine.run_batch(reqs)
        dt = time.perf_counter() - t0
        toks = sum(len(o) for o in outs)
        print(f"round {r}: {toks} tokens in {dt:.2f}s "
              f"({toks / dt:.1f} tok/s)")
    if source is not None:
        source.close()
    s = engine.stats
    print(f"totals: prefill {s['prefill_tokens']} tok / "
          f"{s['prefill_s']:.2f}s | decode {s['decode_steps']} steps / "
          f"{s['decode_s']:.2f}s "
          f"({s['decode_s'] / max(s['decode_steps'], 1) * 1e3:.1f} ms/step)")


if __name__ == "__main__":
    main()

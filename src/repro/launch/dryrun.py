import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede any jax import: jax locks the device count on first init.
# 512 host devices let jax.make_mesh build the production meshes (16x16
# single-pod, 2x16x16 multi-pod) on this CPU-only container.

"""Multi-pod dry-run: lower + compile every (architecture × input shape ×
mesh) cell and record memory/cost/collective artifacts for the roofline.

    PYTHONPATH=src python -m repro.launch.dryrun --arch granite-8b \
        --shape train_4k --mesh single
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both

A cell SUCCEEDS when ``.lower().compile()`` completes; its
``memory_analysis()`` (bytes/device) and ``cost_analysis()`` are printed
and saved under runs/dryrun/<arch>--<shape>--<mesh>/ together with the
optimized HLO (gzipped) that launch/roofline.py consumes.
"""

import argparse
import gzip
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp

from .. import compat
from ..configs import ARCHS, SHAPES_BY_NAME, get_arch, shape_applicable
from ..models.api import ModelAPI
from ..sharding.partition import (DEFAULT_RULES, ShardingRules,
                                  logical_to_spec, shardings_for_tree,
                                  use_mesh)
from ..train.trainstep import init_state, make_train_step, state_axes
from .mesh import make_named_mesh
from . import roofline as rl

RUNS = Path(os.environ.get(
    "REPRO_DRYRUN_DIR",
    Path(__file__).resolve().parents[3] / "runs" / "dryrun"))


def count_params(api) -> int:
    import math
    shapes = jax.eval_shape(lambda: api.model.init(jax.random.key(0)))
    return sum(math.prod(s.shape) for s in jax.tree.leaves(shapes))


def active_params(api, total: int) -> int:
    cfg = api.cfg
    if not cfg.n_experts:
        return total
    per_expert = 3 * cfg.d_model * cfg.d_ff
    inactive = cfg.n_layers * (cfg.n_experts - cfg.top_k) * per_expert
    return total - inactive


def auto_plan(arch, shape, mesh):
    """Pick grad-accumulation count + activation sharding so the saved
    remat working set (~6 B/elem: bf16 stack + the f32 backward copy)
    stays under ~4 GB/chip.  Serving shapes use SERVE_RULES (weights
    replicated over 'data', KV cache length-sharded over 'model')."""
    if shape.kind != "train":
        from ..sharding.partition import SERVE_BIG_RULES, SERVE_RULES
        mp = dict(zip(mesh.axis_names, mesh.devices.shape)).get("model", 1)
        if arch.param_count() * 2 / mp > 8e9:     # bf16 weights vs 16G HBM
            return 1, SERVE_BIG_RULES
        return 1, SERVE_RULES
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    dp = sizes.get("data", 1) * sizes.get("pod", 1)
    mp = sizes.get("model", 1)
    budget = 4e9
    L = arch.n_layers + arch.encoder_layers
    max_accum = max(shape.global_batch // dp, 1)
    accum = 1
    def carry(acc, sp):
        tokens_dev = shape.global_batch * shape.seq_len / dp / acc
        return L * tokens_dev * arch.d_model * 6 / (mp if sp else 1)
    while carry(accum, False) > budget and accum < max_accum:
        accum *= 2
    if carry(accum, False) <= budget:
        return accum, DEFAULT_RULES
    from ..sharding.partition import ACT_SP_RULES
    return accum, ACT_SP_RULES


def lower_cell(arch_name: str, shape_name: str, mesh_name: str,
               *, rules: ShardingRules = None,
               compress_grads: bool = False, save: bool = True,
               attention_impl: str = None, accum: int = None,
               cast_bf16: bool = False, kv_int8: bool = False):
    """Returns (ok, info-dict)."""
    import dataclasses
    arch = get_arch(arch_name)
    if attention_impl:
        arch = dataclasses.replace(arch, attention_impl=attention_impl)
    if kv_int8:
        arch = dataclasses.replace(arch, kv_cache_dtype="int8")
    shape = SHAPES_BY_NAME[shape_name]
    ok, why = shape_applicable(arch, shape)
    if not ok:
        return True, {"skipped": why}
    mesh = make_named_mesh(mesh_name)
    auto_acc, auto_rules = auto_plan(arch, shape, mesh)
    if accum is None:
        accum = auto_acc
    if rules is None:
        rules = auto_rules
    api = ModelAPI(arch)
    t0 = time.time()
    with use_mesh(mesh, rules):
        if shape.kind == "train":
            lowered = _lower_train(api, shape, mesh, rules, compress_grads,
                                   accum, cast_bf16)
        elif shape.kind == "prefill":
            lowered = _lower_prefill(api, shape, mesh, rules)
        else:
            lowered = _lower_decode(api, shape, mesh, rules)
        t_lower = time.time() - t0
        compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compat.cost_analysis(compiled)
    print(f"[{arch_name} × {shape_name} × {mesh_name}] "
          f"lower {t_lower:.1f}s compile {t_compile:.1f}s")
    print(" ", mem)
    print("  cost:", {k: cost[k] for k in ("flops", "bytes accessed")
                      if k in cost})

    total = count_params(api)
    info = {
        "arch": arch_name, "shape": shape_name, "mesh": mesh_name,
        "chips": mesh.devices.size, "accum": accum,
        "bf16_params": cast_bf16,
        "act_sp": dict(rules.rules).get("act_seq") is not None,
        "lower_s": t_lower, "compile_s": t_compile,
        "params": total, "active_params": active_params(api, total),
        "arg_bytes_per_device": mem.argument_size_in_bytes,
        "temp_bytes_per_device": mem.temp_size_in_bytes,
        "output_bytes_per_device": mem.output_size_in_bytes,
        "alias_bytes_per_device": mem.alias_size_in_bytes,
        "cost_flops": cost.get("flops", 0.0),
        "cost_bytes": cost.get("bytes accessed", 0.0),
    }
    hlo = compiled.as_text()
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    roof = rl.roofline_report(
        hlo, chips=mesh.devices.size, arch=arch, shape=shape,
        n_params=total, n_active=info["active_params"],
        cost_analysis=cost, mp=sizes.get("model", 1),
        dp=sizes.get("data", 1) * sizes.get("pod", 1), accum=accum)
    info["roofline"] = roof
    print(f"  roofline: compute {roof['compute_s']*1e3:.2f}ms "
          f"memory {roof['memory_s']*1e3:.2f}ms "
          f"collective {roof['collective_s']*1e3:.2f}ms "
          f"-> {roof['dominant']}-bound "
          f"(useful-FLOP {roof['useful_flop_fraction']:.2f}, "
          f"roofline-frac {roof['roofline_fraction']:.3f})")

    if save:
        d = RUNS / f"{arch_name}--{shape_name}--{mesh_name}"
        d.mkdir(parents=True, exist_ok=True)
        with gzip.open(d / "hlo.txt.gz", "wt") as f:
            f.write(hlo)
        (d / "meta.json").write_text(json.dumps(info, indent=1,
                                                default=float))
        (d / "memory.txt").write_text(str(mem))
        (d / "cost.json").write_text(json.dumps(dict(cost), default=float))
    return True, info


def _lower_train(api, shape, mesh, rules, compress_grads, accum=1,
                 cast_bf16=False):
    step = make_train_step(api, mesh=mesh, grad_compression=compress_grads,
                           accum=accum, cast_bf16=cast_bf16)
    st_axes = state_axes(api, grad_compression=compress_grads)
    state_spec = jax.eval_shape(
        lambda: init_state(api, jax.random.key(0),
                           grad_compression=compress_grads))
    st_sh = shardings_for_tree(st_axes, mesh, rules, state_spec)
    batch_spec = api.input_specs(shape)
    b_sh = shardings_for_tree(api.input_axes(shape), mesh, rules, batch_spec)
    jf = jax.jit(step, in_shardings=(st_sh, b_sh), donate_argnums=(0,))
    return jf.lower(state_spec, batch_spec)


def _serve_param_specs(api):
    """Serving runs bf16 weights (model code casts at use anyway)."""
    spec = jax.eval_shape(lambda: api.model.init(jax.random.key(0)))
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(
            s.shape, jnp.bfloat16 if jnp.issubdtype(s.dtype, jnp.floating)
            else s.dtype), spec)


def _lower_prefill(api, shape, mesh, rules):
    params_spec = _serve_param_specs(api)
    p_sh = shardings_for_tree(api.model.param_axes(), mesh, rules,
                              params_spec)
    batch_spec = api.input_specs(shape)
    b_sh = shardings_for_tree(api.input_axes(shape), mesh, rules, batch_spec)
    fn = lambda p, b: api.prefill(p, b, shape)
    jf = jax.jit(fn, in_shardings=(p_sh, b_sh))
    return jf.lower(params_spec, batch_spec)


def _lower_decode(api, shape, mesh, rules):
    params_spec = _serve_param_specs(api)
    p_sh = shardings_for_tree(api.model.param_axes(), mesh, rules,
                              params_spec)
    batch_spec = api.input_specs(shape)
    b_sh = shardings_for_tree(api.input_axes(shape), mesh, rules, batch_spec)
    cache_spec = api.cache_specs(shape)
    c_sh = shardings_for_tree(api.cache_axes(), mesh, rules, cache_spec)
    jf = jax.jit(api.serve_step, in_shardings=(p_sh, b_sh, c_sh),
                 donate_argnums=(2,))   # donated in-place cache update
    return jf.lower(params_spec, batch_spec, cache_spec)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--attention-impl", default=None)
    ap.add_argument("--seq-parallel", action="store_true")
    ap.add_argument("--accum", type=int, default=None)
    ap.add_argument("--bf16-params", action="store_true")
    ap.add_argument("--act-sp", action="store_true")
    ap.add_argument("--fsdp-only", action="store_true")
    ap.add_argument("--kv-int8", action="store_true")
    args = ap.parse_args()

    rules = None          # auto (per-cell plan)
    if args.seq_parallel:
        from ..sharding.partition import SP_RULES
        rules = SP_RULES
    if args.act_sp:
        from ..sharding.partition import ACT_SP_RULES
        rules = ACT_SP_RULES
    if args.fsdp_only:
        from ..sharding.partition import FSDP_RULES
        rules = FSDP_RULES

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    archs = sorted(ARCHS) if (args.all or not args.arch) else [args.arch]
    shapes = sorted(SHAPES_BY_NAME) if (args.all or not args.shape) \
        else [args.shape]

    results, failures = [], []
    for a in archs:
        for s in shapes:
            for m in meshes:
                try:
                    ok, info = lower_cell(
                        a, s, m, rules=rules,
                        compress_grads=args.compress_grads,
                        attention_impl=args.attention_impl,
                        accum=args.accum, cast_bf16=args.bf16_params,
                        kv_int8=args.kv_int8)
                    if "skipped" in info:
                        print(f"[{a} × {s} × {m}] {info['skipped']}")
                    results.append(info)
                except Exception as e:
                    traceback.print_exc()
                    failures.append((a, s, m, repr(e)))
    print(f"\n== dry-run: {len(results)} cells ok, "
          f"{len(failures)} failed ==")
    for f in failures:
        print("  FAIL:", f)
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()

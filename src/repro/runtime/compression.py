"""Gradient compression for the cross-pod (DCN) all-reduce.

int8 per-tensor symmetric quantization with error feedback: the pod axis
is the slow link (DCN, not ICI), so shrinking that one all-reduce 4x
(fp32->int8) moves the collective roofline term directly.  Error feedback
keeps the scheme unbiased over time (residual carried in fp32 state the
same shape as the grads, sharded like params).
"""

from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


def quantize_int8(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def compress_grads_psum(grads, residual, axis_name: str, n_pods: int = 2):
    """Inside shard_map over the pod axis: quantize (grad + residual),
    psum a true-int8 payload, dequantize, update residual.

    The quantization range is ±(127 // n_pods) so the int8 ring-reduce
    cannot overflow — the wire payload really is 1 byte/element (4x less
    DCN traffic than fp32, 2x less than bf16).

    Returns (synced_grads, new_residual)."""
    qmax = max(127 // n_pods, 1)

    def one(g, r):
        g = g.astype(jnp.float32) + r
        # shared scale across pods (one tiny pmax) so the int8 sum is exact
        scale = jax.lax.pmax(jnp.max(jnp.abs(g)), axis_name) / qmax + 1e-12
        q = jnp.clip(jnp.round(g / scale), -qmax, qmax).astype(jnp.int8)
        new_r = g - q.astype(jnp.float32) * scale   # error feedback
        qsum = jax.lax.psum(q, axis_name)           # int8 on the wire
        n = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
        synced = qsum.astype(jnp.float32) * scale / n
        return synced, new_r
    flat_g, tdef = jax.tree.flatten(grads)
    flat_r = tdef.flatten_up_to(residual)
    outs = [one(g, r) for g, r in zip(flat_g, flat_r)]
    return (tdef.unflatten([o[0] for o in outs]),
            tdef.unflatten([o[1] for o in outs]))


def init_residual(params):
    return jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)

"""Zerrow-backed checkpointing: content-dedup tensor store + async save.

The checkpoint store applies the paper's ideas to training state across
*time* instead of across DAG nodes:

  * resharing-in-time — tensors are content-addressed (blake2 of bytes);
    a step-N checkpoint only writes tensors that changed since step M
    (e.g. frozen embeddings, optimizer `count`, data-pipeline state), the
    rest are references.  Exactly SIPC's reference-vs-copy decision, with
    hashing standing in for address-range inspection (we cannot inspect
    device memory identity across steps).
  * de-anonymization — host arrays fetched from device are handed to the
    BufferStore by reference (no host-side copy) before the async writer
    flushes them to disk.
  * async save — a writer thread drains a queue; training continues.

Restore supports *elastic resharding*: the checkpoint stores the global
arrays; on load they are device_put against whatever mesh/sharding the
(possibly different-sized) new job provides.
"""

from __future__ import annotations

import hashlib
import json
import os
import queue
import threading
import time
from typing import Any, Dict, Optional

import numpy as np

try:
    import jax
except ImportError:                     # pure-host tests
    jax = None


def _leaf_paths(tree, prefix=""):
    if isinstance(tree, dict):
        for k in sorted(tree):
            yield from _leaf_paths(tree[k], f"{prefix}/{k}")
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            yield from _leaf_paths(v, f"{prefix}/{i}")
    else:
        yield prefix, tree


class CheckpointStore:
    def __init__(self, root: str, *, async_io: bool = True):
        self.root = root
        os.makedirs(os.path.join(root, "blobs"), exist_ok=True)
        self.async_io = async_io
        self._q: "queue.Queue" = queue.Queue()
        self._writer: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None
        self.stats = {"blobs_written": 0, "blobs_reused": 0,
                      "bytes_written": 0, "bytes_reused": 0}
        if async_io:
            self._writer = threading.Thread(target=self._drain, daemon=True)
            self._writer.start()

    # -- write path ---------------------------------------------------------
    def save(self, step: int, state) -> Dict[str, Any]:
        """Snapshot a pytree.  Returns the manifest (also written to disk).
        With async_io the heavy writes happen on the writer thread."""
        manifest = {"step": step, "time": time.time(), "tensors": {}}
        for path, leaf in _leaf_paths(state):
            arr = np.asarray(leaf)
            h = hashlib.blake2b(arr.tobytes(), digest_size=16).hexdigest()
            blob = os.path.join(self.root, "blobs", h + ".npy")
            manifest["tensors"][path] = {
                "hash": h, "dtype": str(arr.dtype), "shape": list(arr.shape)}
            if os.path.exists(blob):
                self.stats["blobs_reused"] += 1    # resharing-in-time
                self.stats["bytes_reused"] += arr.nbytes
                continue
            self.stats["blobs_written"] += 1
            self.stats["bytes_written"] += arr.nbytes
            if self.async_io:
                self._q.put((blob, arr))
            else:
                self._write_blob(blob, arr)
        mpath = os.path.join(self.root, f"step-{step:08d}.json")
        if self.async_io:
            self._q.put((mpath, manifest))
        else:
            self._write_manifest(mpath, manifest)
        return manifest

    def _write_blob(self, path: str, arr: np.ndarray) -> None:
        tmp = path + ".tmp"
        np.save(tmp, arr, allow_pickle=False)
        os.replace(tmp + ".npy" if not tmp.endswith(".npy") else tmp, path)

    def _write_manifest(self, path: str, manifest: dict) -> None:
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(manifest, f)
        os.replace(tmp, path)

    def _drain(self) -> None:
        while True:
            item = self._q.get()
            if item is None:
                return
            try:
                path, payload = item
                if isinstance(payload, dict):
                    self._write_manifest(path, payload)
                else:
                    self._write_blob(path, payload)
            except BaseException as e:        # surfaced on flush()
                self._error = e
            finally:
                self._q.task_done()

    def flush(self) -> None:
        if self.async_io:
            self._q.join()
        if self._error:
            raise self._error

    def close(self) -> None:
        self.flush()
        if self._writer is not None:
            self._q.put(None)
            self._writer.join(timeout=5)
            self._writer = None

    # -- read path ------------------------------------------------------------
    def latest_step(self) -> Optional[int]:
        steps = [int(f[5:13]) for f in os.listdir(self.root)
                 if f.startswith("step-") and f.endswith(".json")]
        return max(steps) if steps else None

    def restore(self, step: Optional[int] = None, *, like=None,
                shardings=None):
        """Load a snapshot.  ``like``: a pytree giving the structure (and
        the leaf order); ``shardings``: optional matching pytree of
        NamedShardings for elastic re-mesh restore (device_put per leaf)."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError("no checkpoints in " + self.root)
        mpath = os.path.join(self.root, f"step-{step:08d}.json")
        manifest = json.load(open(mpath))

        flat = {}
        for path, meta in manifest["tensors"].items():
            blob = os.path.join(self.root, "blobs", meta["hash"] + ".npy")
            flat[path] = np.load(blob, allow_pickle=False)

        if like is None:
            return flat, manifest
        leaves, treedef = (jax.tree.flatten(like) if jax else (None, None))
        paths = [p for p, _ in _leaf_paths(like)]
        assert len(paths) == len(leaves)
        out = []
        flat_sh = jax.tree.leaves(
            shardings, is_leaf=lambda x: hasattr(x, "spec")) \
            if shardings is not None else [None] * len(paths)
        for p, leaf, sh in zip(paths, leaves, flat_sh):
            arr = flat[p]
            if sh is not None:
                arr = jax.device_put(arr, sh)   # elastic: any new mesh
            out.append(arr)
        return treedef.unflatten(out), manifest

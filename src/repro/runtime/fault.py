"""Fault tolerance for the multi-pod training loop.

Designed for 1000+ nodes; implemented against this container's simulated
failure hooks so the policies are testable:

  * heartbeats + failure detection  — every worker publishes a step-stamped
    heartbeat; a worker silent for ``grace`` steps is declared failed.
  * checkpoint/restart              — on failure the coordinator rolls the
    job back to the last durable CheckpointStore snapshot (DAG rollback
    applied to training-in-time: recompute beats babysitting a sick node).
  * straggler mitigation           — per-worker step-time EWMA; a worker
    slower than ``straggler_factor`` x the fleet median is marked for
    replacement *between* checkpoint intervals (no global desync).  The
    EWMA + median-factor rule itself lives in
    ``core.faultplane.StragglerDetector`` — the same detector the flight
    worker pool uses for serving-plane request health, so the training
    and serving planes share one definition of "straggler".
  * elastic re-mesh                — a new mesh (e.g. 512 -> 448 chips)
    restores the same checkpoint with new shardings (restore(..,
    shardings=...)): data-parallel size changes, model state is intact.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

from ..core.faultplane import StragglerDetector
from .checkpoint import CheckpointStore


@dataclass
class WorkerState:
    worker_id: int
    last_step: int = -1
    last_beat: float = 0.0
    step_ewma: float = 0.0    # mirror of the shared detector's EWMA
    failed: bool = False
    straggler: bool = False


@dataclass
class FaultConfig:
    grace_steps: int = 3
    straggler_factor: float = 1.7
    ewma: float = 0.3
    checkpoint_every: int = 50


class FleetMonitor:
    """Coordinator-side view of the fleet (one per job)."""

    def __init__(self, n_workers: int, cfg: FaultConfig = FaultConfig()):
        self.cfg = cfg
        self.workers = {i: WorkerState(i) for i in range(n_workers)}
        self.global_step = 0
        self.events: List[dict] = []
        self.health = StragglerDetector(alpha=cfg.ewma,
                                        factor=cfg.straggler_factor,
                                        min_peers=3)

    # -- heartbeats ----------------------------------------------------------
    def heartbeat(self, worker_id: int, step: int, step_time: float,
                  now: Optional[float] = None) -> None:
        w = self.workers[worker_id]
        w.last_step = step
        w.last_beat = now if now is not None else time.monotonic()
        w.step_ewma = self.health.update(worker_id, step_time)
        self.global_step = max(self.global_step, step)

    # -- failure detection -----------------------------------------------------
    def detect_failures(self) -> List[int]:
        out = []
        for w in self.workers.values():
            if w.failed:
                continue
            if self.global_step - w.last_step > self.cfg.grace_steps:
                w.failed = True
                self.events.append({"kind": "failure", "worker": w.worker_id,
                                    "step": self.global_step})
                out.append(w.worker_id)
        return out

    # -- stragglers --------------------------------------------------------------
    def detect_stragglers(self) -> List[int]:
        alive = {w.worker_id for w in self.workers.values()
                 if not w.failed}
        slow_ids, median = self.health.flag(alive)
        if median == 0.0:               # fewer than min_peers populated
            return []
        slow = set(slow_ids)
        out = []
        for wid in alive:
            w = self.workers[wid]
            if w.step_ewma <= 0:
                continue
            if wid in slow and not w.straggler:
                w.straggler = True
                self.events.append({"kind": "straggler", "worker": wid,
                                    "ewma": w.step_ewma, "median": median})
                out.append(wid)
            elif wid not in slow:
                w.straggler = False
        return out

    def healthy(self) -> int:
        return sum(1 for w in self.workers.values() if not w.failed)


class RestartPolicy:
    """Decides how the job resumes after failures."""

    def __init__(self, store: CheckpointStore, monitor: FleetMonitor,
                 *, min_workers: int):
        self.store = store
        self.monitor = monitor
        self.min_workers = min_workers

    def plan(self) -> dict:
        """Returns an action plan:
        - 'continue'          no failures
        - 'restart'           reload last checkpoint on replacement nodes
        - 'elastic_shrink'    not enough spares: shrink the data axis and
                              restore with new shardings
        """
        failed = self.monitor.detect_failures()
        healthy = self.monitor.healthy()
        step = self.store.latest_step()
        if healthy < self.min_workers:
            return {"action": "elastic_shrink", "from_step": step,
                    "new_size": healthy}
        if failed:
            return {"action": "restart", "from_step": step,
                    "replace": failed}
        return {"action": "continue",
                "stragglers": self.monitor.detect_stragglers()}

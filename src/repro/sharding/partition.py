"""Logical-axis partitioning (MaxText-style) for the production mesh.

Mesh axes:
  single-pod:  ("data", "model")            = (16, 16)   -> 256 chips
  multi-pod:   ("pod", "data", "model")     = (2, 16, 16) -> 512 chips

Logical axes used by the model code:
  "batch"        -> ("pod", "data")   activations' batch dim
  "fsdp"         -> "data"            param dim sharded ZeRO-style
  "tensor"       -> "model"           TP dim (heads / ffn / vocab / experts)
  "expert"       -> "model"           expert parallelism for MoE stacks
  "seq"          -> None by default; "model" under sequence parallelism
  "layers"/None  -> replicated

The "pod" axis is *pure data parallelism*: params are replicated across
pods (only the gradient all-reduce crosses the DCN once per step), while
FSDP stays inside a pod — deliberate: cross-DCN per-layer all-gathers
would dominate the collective roofline term (DESIGN.md §5).
"""

from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclass(frozen=True)
class ShardingRules:
    """logical axis name -> mesh axis (or tuple of mesh axes)."""
    rules: Tuple[Tuple[str, Any], ...] = (
        ("batch", ("pod", "data")),
        ("fsdp", "data"),
        ("tensor", "model"),
        ("expert", "model"),
        ("heads", "model"),
        ("kv_heads", "model"),
        ("vocab", "model"),
        ("ffn", "model"),
        ("seq", None),
        ("act_seq", None),      # residual-stream seq dim between blocks
        ("kv_seq", None),       # decode KV-cache length dim (serving)
        ("lru", "model"),
        ("layers", None),
    )

    def to_dict(self) -> Dict[str, Any]:
        return dict(self.rules)

    def with_overrides(self, **kw) -> "ShardingRules":
        d = self.to_dict()
        d.update(kw)
        return ShardingRules(tuple(d.items()))


DEFAULT_RULES = ShardingRules()

# sequence-parallel variant for very long sequences (beyond-paper perf knob)
SP_RULES = DEFAULT_RULES.with_overrides(seq="model")

# activation sequence-sharding: the residual stream saved between scanned
# blocks (the remat working set) lives seq-sharded over 'model'; XLA
# re-gathers it at each block entry.  Trades one all-gather/layer for a
# 16x smaller saved-activation footprint — required to fit the 340B-class
# train cells on 16 GB chips.
ACT_SP_RULES = DEFAULT_RULES.with_overrides(act_seq="model")

# serving topology (decode/prefill): weights replicated across 'data'
# (FSDP gathers per token would swamp the ICI), TP over 'model', and the
# KV cache *length*-sharded over 'model' (flash-decoding style split-K:
# per-chip partial softmax + a tiny cross-chip combine).
SERVE_RULES = DEFAULT_RULES.with_overrides(fsdp=None, kv_seq="model")

# big-model serving (weights at TP-16 exceed a 16 GB chip): keep the fsdp
# dim sharded over 'data' as well — weights live 2D-sharded (256-way) and
# XLA resolves each use as row-parallel partial sums or per-layer gathers,
# whichever is cheaper.  Trades collective time for fitting at all; the
# production alternative is pipeline parallelism (DESIGN.md §5).
SERVE_BIG_RULES = DEFAULT_RULES.with_overrides(kv_seq="model")

# pure-FSDP layout (no tensor parallelism): params fully sharded over all
# 256 chips, weights gathered per layer, ZERO activation all-reduces.
# For dense archs at large batch this trades the Megatron partial-sum
# reductions (∝ tokens·d per layer) for weight gathers (∝ params·accum) —
# a huge win when tokens >> params/accum (§Perf iteration 3).
FSDP_RULES = DEFAULT_RULES.with_overrides(
    batch=("pod", "data", "model"),   # batch over ALL chips (no TP)
    fsdp=("data", "model"), tensor=None, heads=None, kv_heads=None,
    vocab=None, ffn=None, lru=None, expert=None)


def logical_to_spec(axes: Tuple[Optional[str], ...], mesh: Mesh,
                    rules: ShardingRules = DEFAULT_RULES,
                    shape: Optional[Tuple[int, ...]] = None) -> P:
    """Map logical axis names to a PartitionSpec valid for this mesh.

    If ``shape`` is given, mesh axes whose size does not divide the
    corresponding dimension are dropped (dim replicated) — e.g. smollm's
    9 attention heads cannot be TP-sharded 16 ways; multi-axis entries
    keep the longest dividing prefix (("pod","data") on a batch divisible
    by 2 but not 32 keeps just "pod").
    """
    table = rules.to_dict()
    mesh_axes = set(mesh.axis_names)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    used = set()
    out = []
    for i, ax in enumerate(axes):
        if ax is None:
            out.append(None)
            continue
        m = table.get(ax, None)
        if m is None:
            out.append(None)
            continue
        cand = tuple(a for a in ((m,) if isinstance(m, str) else m)
                     if a in mesh_axes and a not in used)
        if shape is not None and i < len(shape):
            kept, prod = [], 1
            for a in cand:
                if shape[i] % (prod * sizes[a]) == 0:
                    kept.append(a)
                    prod *= sizes[a]
                else:
                    break
            cand = tuple(kept)
        for a in cand:
            used.add(a)
        out.append(cand if len(cand) > 1 else (cand[0] if cand else None))
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def _is_axes_leaf(x) -> bool:
    return isinstance(x, tuple) and \
        all(a is None or isinstance(a, str) for a in x)


def shardings_for_tree(logical_tree, mesh: Mesh,
                       rules: ShardingRules = DEFAULT_RULES,
                       specs_tree=None):
    """Map a pytree of logical-axis tuples to NamedShardings.

    ``specs_tree`` (matching pytree of arrays/ShapeDtypeStructs) enables
    divisibility-aware axis dropping.
    """
    if specs_tree is None:
        return jax.tree.map(
            lambda axes: NamedSharding(mesh,
                                       logical_to_spec(axes, mesh, rules)),
            logical_tree, is_leaf=_is_axes_leaf)

    flat_axes = jax.tree.flatten(logical_tree, is_leaf=_is_axes_leaf)[0]
    flat_specs, treedef = jax.tree.flatten(specs_tree)
    assert len(flat_axes) == len(flat_specs), \
        (len(flat_axes), len(flat_specs))
    out = [NamedSharding(mesh, logical_to_spec(a, mesh, rules,
                                               tuple(s.shape)))
           for a, s in zip(flat_axes, flat_specs)]
    return treedef.unflatten(out)


# module-level mesh/rules context so model code can constrain activations
# without threading a mesh handle through every layer
_CURRENT: Dict[str, Any] = {"mesh": None, "rules": DEFAULT_RULES}


def set_mesh(mesh: Optional[Mesh],
             rules: ShardingRules = DEFAULT_RULES) -> None:
    _CURRENT["mesh"] = mesh
    _CURRENT["rules"] = rules


class use_mesh:
    """Context manager: activate a mesh (+rules) for logical constraints."""

    def __init__(self, mesh: Optional[Mesh],
                 rules: ShardingRules = DEFAULT_RULES):
        self.new = (mesh, rules)

    def __enter__(self):
        self.old = (_CURRENT["mesh"], _CURRENT["rules"])
        set_mesh(*self.new)
        return self.new[0]

    def __exit__(self, *exc):
        set_mesh(*self.old)
        return False


def current_mesh() -> Optional[Mesh]:
    return _CURRENT["mesh"]


def current_rules() -> ShardingRules:
    return _CURRENT["rules"]


def constrain_tree(tree, axes_tree, rules: Optional["ShardingRules"] = None):
    """constrain() every leaf of ``tree`` with the matching logical axes
    from ``axes_tree`` (same structure, tuple-of-names leaves)."""
    flat, treedef = jax.tree.flatten(tree)
    flat_axes = jax.tree.flatten(axes_tree, is_leaf=_is_axes_leaf)[0]
    assert len(flat) == len(flat_axes), (len(flat), len(flat_axes))
    return treedef.unflatten([constrain(x, a, rules)
                              for x, a in zip(flat, flat_axes)])


def constrain(x, axes: Tuple[Optional[str], ...],
              rules: Optional[ShardingRules] = None):
    """with_sharding_constraint by logical axes (no-op outside a mesh)."""
    mesh = _CURRENT["mesh"]
    if mesh is None:
        return x
    rules = rules or _CURRENT["rules"]
    axes = tuple(axes)[:x.ndim]
    spec = logical_to_spec(axes, mesh, rules, tuple(x.shape))
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def divisible_pad(n: int, k: int) -> int:
    return -(-n // k) * k

"""Batched serving engine: continuous prefill + decode with donated caches.

The KV cache is updated in place via buffer donation — the device-side
analogue of Zerrow's resharing (appending one token never rewrites the
cache, exactly as SIPC's slice/concat never rewrites input buffers).

``ZerrowPromptSource`` feeds the engine from zarquet prompt shards through
the ``core/sched`` worker-pool executor: shard decompression overlaps
across workers, and the DeCache shares decoded shards between engine
replicas reading the same corpus.
"""

from __future__ import annotations

import dataclasses
import os
import time
from typing import Dict, Iterator, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ArchConfig, ShapeConfig
from ..core import (BufferStore, DAG, NodeSpec, RMConfig, ResourceManager,
                    SipcReader, Table, make_executor)
from ..core import zarquet
from ..models.api import ModelAPI


def passthrough_fn(tables: List[Table]) -> Table:
    """Identity node: every output buffer is reshared (zero-copy).
    Module-level so it pickles into Flight worker processes."""
    return tables[0]


@dataclasses.dataclass
class Request:
    prompt: np.ndarray           # (S,) int32
    max_new: int = 16
    out: Optional[List[int]] = None


def make_prompt_shards(root: str, n_shards: int, prompts_per_shard: int,
                       seed: int = 0) -> List[str]:
    """Synthetic prompt corpus: zarquet shards with a utf8 'text' column."""
    rng = np.random.default_rng(seed)
    words = ["describe", "the", "zero", "copy", "arrow", "pipeline",
             "memory", "kernel", "shared", "data", "cache", "batch",
             "serve", "model", "token", "fast"]
    os.makedirs(root, exist_ok=True)
    paths = []
    for s in range(n_shards):
        texts = [" ".join(rng.choice(words, size=rng.integers(4, 16)))
                 for _ in range(prompts_per_shard)]
        p = os.path.join(root, f"prompts-{s:04d}.zq")
        zarquet.write_table(p, Table.from_pydict({"text": texts}))
        paths.append(p)
    return paths


class ZerrowPromptSource:
    """Streams ``Request`` batches out of zarquet prompt shards via the
    sched executor.  All shard DAGs are submitted in one ``run`` so loader
    decompression overlaps across the worker pool; prompts are
    byte-tokenized (ids 1..256, 0 stays PAD) so any vocab ≥ 257 works.
    With ``workers_mode='process'`` the shard loads run in spawned OS
    processes over the Flight data plane (file-backed store, SIPC wire
    references) and scale past the GIL."""

    def __init__(self, shard_paths: List[str], *, batch: int,
                 max_new: int = 16, workers: int = 1,
                 workers_mode: str = "thread",
                 reader_threads: Optional[int] = None,
                 max_prompt_len: Optional[int] = None,
                 memory_limit: Optional[int] = None,
                 cache_root: Optional[str] = None,
                 store: Optional[BufferStore] = None,
                 rm: Optional[ResourceManager] = None,
                 tenant: str = "default",
                 deadline_s: Optional[float] = None):
        self.paths = list(shard_paths)
        self.batch = batch
        self.max_new = max_new
        self.max_prompt_len = max_prompt_len
        # admission identity: shard DAGs carry the source's tenant (for
        # budget isolation) and an optional per-run deadline; when the RM
        # enforces deadlines, shard loads past it are cancelled and
        # surface as ``missed_shards`` instead of stalling the engine
        self.tenant = tenant
        self.deadline_s = deadline_s
        self.missed_shards = 0
        backing = ("file" if workers_mode == "process" or cache_root
                   else "ram")
        self.store = store or BufferStore(backing=backing, root=cache_root)
        self.rm = rm or ResourceManager(
            self.store, RMConfig(memory_limit=memory_limit,
                                 workers=workers,
                                 workers_mode=workers_mode,
                                 reader_threads=reader_threads,
                                 cache_root=cache_root))
        self.ex = make_executor(self.store, self.rm, workers=workers)

    def batches(self) -> Iterator[List[Request]]:
        dags = []
        deadline = None if self.deadline_s is None \
            else time.monotonic() + self.deadline_s
        for p in self.paths:
            est = max(os.path.getsize(p) * 8, 1 << 20)
            dags.append(DAG([
                NodeSpec("load", source=p, est_mem=est),
                NodeSpec("prompts", fn=passthrough_fn, deps=["load"],
                         est_mem=est // 4, keep_output=True),
            ], name=f"prompts-{os.path.basename(p)}",
                tenant=self.tenant, deadline=deadline))
        self.ex.run(dags)
        pending: List[Request] = []
        for dag in dags:
            msg = dag.nodes["prompts"].output
            if dag.cancelled or msg is None:
                # shed / deadline-missed shard: the engine degrades to
                # fewer prompts rather than crashing on a None output
                self.missed_shards += 1
                continue
            table = SipcReader(self.store).read_table(msg)
            col = table.combine().batches[0].column("text")
            for i in range(col.length):
                ids = np.frombuffer(col.get_bytes(i),
                                    dtype=np.uint8).astype(np.int32) + 1
                if self.max_prompt_len is not None:
                    ids = ids[:self.max_prompt_len]
                if len(ids) == 0:
                    continue
                pending.append(Request(prompt=ids, max_new=self.max_new))
                if len(pending) == self.batch:
                    yield pending
                    pending = []
            msg.release()
        if pending:
            yield pending

    def close(self) -> None:
        self.ex.close()
        self.store.close()


class ServeEngine:
    def __init__(self, api: ModelAPI, params, *, batch: int,
                 max_seq: int, greedy: bool = True):
        self.api = api
        self.params = params
        self.batch = batch
        self.max_seq = max_seq
        self.greedy = greedy
        shape = ShapeConfig("serve", "prefill", max_seq, batch)
        self._prefill = jax.jit(
            lambda p, b: api.prefill(p, b, shape))
        self._decode = jax.jit(api.serve_step, donate_argnums=(2,))
        self.stats = {"prefill_tokens": 0, "decode_steps": 0,
                      "prefill_s": 0.0, "decode_s": 0.0}

    def run_batch(self, requests: List[Request]) -> List[List[int]]:
        assert len(requests) <= self.batch
        B = self.batch
        S = max(len(r.prompt) for r in requests)
        toks = np.zeros((B, S), np.int32)
        for i, r in enumerate(requests):
            toks[i, S - len(r.prompt):] = r.prompt     # left-pad
        t0 = time.perf_counter()
        logits, caches = self._prefill(self.params,
                                       {"tokens": jnp.asarray(toks)})
        self.stats["prefill_s"] += time.perf_counter() - t0
        self.stats["prefill_tokens"] += B * S

        outs = [[] for _ in range(B)]
        cur = np.asarray(jnp.argmax(logits[:, -1], axis=-1),
                         np.int32).reshape(B, 1)
        max_new = max(r.max_new for r in requests)
        pos = S
        t0 = time.perf_counter()
        for step in range(max_new):
            for i in range(B):
                outs[i].append(int(cur[i, 0]))
            logits, caches = self._decode(
                self.params,
                {"tokens": jnp.asarray(cur),
                 "positions": jnp.full((B, 1), pos, jnp.int32)},
                caches)
            cur = np.asarray(jnp.argmax(logits[:, -1], axis=-1),
                             np.int32).reshape(B, 1)
            pos += 1
            self.stats["decode_steps"] += 1
        self.stats["decode_s"] += time.perf_counter() - t0
        return [outs[i][:r.max_new] for i, r in enumerate(requests)]

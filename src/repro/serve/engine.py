"""Batched serving engine: continuous prefill + decode with donated caches.

The KV cache is updated in place via buffer donation — the device-side
analogue of Zerrow's resharing (appending one token never rewrites the
cache, exactly as SIPC's slice/concat never rewrites input buffers).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ArchConfig, ShapeConfig
from ..models.api import ModelAPI


@dataclasses.dataclass
class Request:
    prompt: np.ndarray           # (S,) int32
    max_new: int = 16
    out: Optional[List[int]] = None


class ServeEngine:
    def __init__(self, api: ModelAPI, params, *, batch: int,
                 max_seq: int, greedy: bool = True):
        self.api = api
        self.params = params
        self.batch = batch
        self.max_seq = max_seq
        self.greedy = greedy
        shape = ShapeConfig("serve", "prefill", max_seq, batch)
        self._prefill = jax.jit(
            lambda p, b: api.prefill(p, b, shape))
        self._decode = jax.jit(api.serve_step, donate_argnums=(2,))
        self.stats = {"prefill_tokens": 0, "decode_steps": 0,
                      "prefill_s": 0.0, "decode_s": 0.0}

    def run_batch(self, requests: List[Request]) -> List[List[int]]:
        assert len(requests) <= self.batch
        B = self.batch
        S = max(len(r.prompt) for r in requests)
        toks = np.zeros((B, S), np.int32)
        for i, r in enumerate(requests):
            toks[i, S - len(r.prompt):] = r.prompt     # left-pad
        t0 = time.perf_counter()
        logits, caches = self._prefill(self.params,
                                       {"tokens": jnp.asarray(toks)})
        self.stats["prefill_s"] += time.perf_counter() - t0
        self.stats["prefill_tokens"] += B * S

        outs = [[] for _ in range(B)]
        cur = np.asarray(jnp.argmax(logits[:, -1], axis=-1),
                         np.int32).reshape(B, 1)
        max_new = max(r.max_new for r in requests)
        pos = S
        t0 = time.perf_counter()
        for step in range(max_new):
            for i in range(B):
                outs[i].append(int(cur[i, 0]))
            logits, caches = self._decode(
                self.params,
                {"tokens": jnp.asarray(cur),
                 "positions": jnp.full((B, 1), pos, jnp.int32)},
                caches)
            cur = np.asarray(jnp.argmax(logits[:, -1], axis=-1),
                             np.int32).reshape(B, 1)
            pos += 1
            self.stats["decode_steps"] += 1
        self.stats["decode_s"] += time.perf_counter() - t0
        return [outs[i][:r.max_new] for i, r in enumerate(requests)]

"""Training step: grad + optimizer update, donation, optional compressed
cross-pod gradient sync.

Buffer donation here is the device-side de-anonymization analogue
(DESIGN.md §2b): ``params`` and ``opt_state`` HBM buffers transfer
ownership to the step outputs instead of being copied.
"""

from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..compat import optimization_barrier, shard_map
from ..runtime.compression import compress_grads_psum, init_residual
from .optimizer import make_optimizer
from .schedule import warmup_cosine


def make_train_step(api, *, peak_lr: float = 3e-4, warmup: int = 100,
                    total_steps: int = 10000,
                    grad_compression: bool = False,
                    accum: int = 1,
                    cast_bf16: bool = False,
                    mesh=None) -> Callable:
    """Returns train_step(state, batch) -> (state, metrics).

    state = {"params", "opt": opt_state, "step", ["residual"]}.
    ``accum`` > 1 splits the global batch into microbatches and accumulates
    gradients in fp32 — the saved-activation working set (one residual per
    scanned layer) shrinks by the same factor, which is what lets the big
    assigned configs fit a 16 GB v5e chip at 1M-token batches.
    ``cast_bf16``: mixed precision with fp32 master weights — matrices are
    cast to bf16 *once, before the microbatch loop*, so every FSDP
    all-gather inside the scan moves half the bytes (§Perf iteration).
    """
    opt = make_optimizer(api.cfg.optimizer)
    model = api.model
    do_compress = bool(grad_compression and mesh is not None
                       and "pod" in mesh.axis_names)

    def grads_of(params, batch):
        grad_fn = jax.value_and_grad(
            lambda p: model.loss_fn(p, batch), has_aux=True)
        (_, metrics), grads = grad_fn(params)
        return grads, metrics

    def accum_grads(params, batch):
        if accum <= 1:
            return grads_of(params, batch)
        from ..sharding.partition import constrain_tree
        p_axes = model.param_axes()
        micro = jax.tree.map(
            lambda x: x.reshape((accum, x.shape[0] // accum) + x.shape[1:]),
            batch)

        def body(carry, mb):
            g_acc, m_acc = carry
            # loop-varying view of the params: keeps per-layer weight
            # gathers inside the microbatch loop (no LICM hoisting)
            p_local = optimization_barrier(params)
            g, m = grads_of(p_local, mb)
            g_acc = jax.tree.map(
                lambda a, b: a + b.astype(jnp.float32), g_acc, g)
            # keep the fp32 accumulator sharded exactly like the params —
            # otherwise XLA replicates the carry (params-sized!) and
            # all-reduces every microbatch
            g_acc = constrain_tree(g_acc, p_axes)
            m_acc = jax.tree.map(lambda a, b: a + b.astype(jnp.float32),
                                 m_acc, m)
            return (g_acc, m_acc), None

        g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        g0 = constrain_tree(g0, p_axes)
        m0 = jax.eval_shape(lambda: grads_of(params,
                                             jax.tree.map(lambda x: x[0],
                                                          micro))[1])
        m0 = jax.tree.map(lambda s: jnp.zeros(s.shape, jnp.float32), m0)
        (grads, metrics), _ = jax.lax.scan(body, (g0, m0), micro)
        inv = 1.0 / accum
        return (jax.tree.map(lambda g: g * inv, grads),
                jax.tree.map(lambda m: m * inv, metrics))

    def step_fn(state, batch):
        params = state["params"]
        compute_params = params
        if cast_bf16:
            # local elementwise cast on the fp32 shards; downstream
            # gathers then move bf16 (norm vectors stay fp32)
            compute_params = jax.tree.map(
                lambda p: p.astype(jnp.bfloat16)
                if p.dtype == jnp.float32 and p.ndim >= 2 else p, params)
        lr = warmup_cosine(state["step"], peak_lr=peak_lr, warmup=warmup,
                           total=total_steps)
        grads, metrics = accum_grads(compute_params, batch)
        residual = state.get("residual")
        if do_compress and residual is not None:
            grads, residual = compress_grads_psum(grads, residual, "pod")
        new_params, new_opt = opt.update(grads, state["opt"], params, lr)
        # NB: per-leaf square+reduce, NOT vdot — vdot ravels the leaf and
        # flattening a 2-D-sharded tensor forces a full all-gather
        gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                          for g in jax.tree.leaves(grads)))
        metrics = dict(metrics, grad_norm=gn, lr=lr)
        new_state = {"params": new_params, "opt": new_opt,
                     "step": state["step"] + 1}
        if residual is not None:
            new_state["residual"] = residual
        return new_state, metrics

    if do_compress:
        # manual control of the cross-pod sync only; data/model stay auto
        from jax.sharding import PartitionSpec as P
        step_fn_inner = step_fn

        def step_fn(state, batch):  # noqa: F811
            f = shard_map(
                step_fn_inner, mesh=mesh,
                in_specs=(P(), P("pod")), out_specs=(P(), P()),
                check_vma=False, axis_names={"pod"})
            return f(state, batch)

    return step_fn


def init_state(api, key, *, grad_compression: bool = False) -> Dict[str, Any]:
    params = api.model.init(key)
    opt = make_optimizer(api.cfg.optimizer)
    state = {"params": params, "opt": opt.init(params),
             "step": jnp.zeros((), jnp.int32)}
    if grad_compression:
        state["residual"] = init_residual(params)
    return state


def state_axes(api, *, grad_compression: bool = False) -> Dict[str, Any]:
    param_axes = api.model.param_axes()
    opt = make_optimizer(api.cfg.optimizer)
    axes = {"params": param_axes, "opt": opt.state_axes(param_axes),
            "step": (None,)}
    if grad_compression:
        axes["residual"] = param_axes
    return axes

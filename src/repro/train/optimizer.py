"""Optimizers in pure JAX: AdamW and Adafactor (sub-linear memory).

States are pytrees shaped like params (AdamW) or factored (Adafactor), so
they shard with the same logical-axis machinery as the params themselves —
ZeRO-style: optimizer state lives on the 'fsdp' shards.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp


def _tree_axes_like(param_axes, fn):
    return jax.tree.map(fn, param_axes,
                        is_leaf=lambda x: isinstance(x, tuple) and
                        all(e is None or isinstance(e, str) for e in x))


@dataclass(frozen=True)
class AdamW:
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1

    def init(self, params):
        z = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
        return {"mu": jax.tree.map(z, params),
                "nu": jax.tree.map(z, params),
                "count": jnp.zeros((), jnp.int32)}

    def state_axes(self, param_axes):
        return {"mu": param_axes, "nu": param_axes, "count": (None,)}

    def update(self, grads, state, params, lr):
        c = state["count"] + 1
        b1, b2 = self.b1, self.b2

        def upd(g, m, v, p):
            g = g.astype(jnp.float32)
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * jnp.square(g)
            mh = m / (1 - b1 ** c.astype(jnp.float32))
            vh = v / (1 - b2 ** c.astype(jnp.float32))
            step = mh / (jnp.sqrt(vh) + self.eps)
            if p.ndim >= 2:
                step = step + self.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * step).astype(p.dtype), m, v

        out = jax.tree.map(upd, grads, state["mu"], state["nu"], params)
        new_p = jax.tree.map(lambda t: t[0], out,
                             is_leaf=lambda x: isinstance(x, tuple))
        mu = jax.tree.map(lambda t: t[1], out,
                          is_leaf=lambda x: isinstance(x, tuple))
        nu = jax.tree.map(lambda t: t[2], out,
                          is_leaf=lambda x: isinstance(x, tuple))
        return new_p, {"mu": mu, "nu": nu, "count": c}


@dataclass(frozen=True)
class Adafactor:
    """Factored second moments over the trailing two dims (leading stacked
    layer axes kept) — the memory fix that lets nemotron-4-340b train on a
    256-chip pod (EXPERIMENTS.md §Dry-run)."""
    decay: float = 0.8
    eps: float = 1e-30
    clip_threshold: float = 1.0

    def _factored(self, p) -> bool:
        return p.ndim >= 2 and p.shape[-1] >= 2 and p.shape[-2] >= 2

    def init(self, params):
        def st(p):
            if self._factored(p):
                return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:],
                                        jnp.float32)}
            return {"v": jnp.zeros_like(p, dtype=jnp.float32)}
        return {"v": jax.tree.map(st, params),
                "count": jnp.zeros((), jnp.int32)}

    def state_axes(self, param_axes):
        def ax(a):
            a = tuple(a)
            if len(a) >= 2:
                return {"vr": a[:-1], "vc": a[:-2] + a[-1:]}
            return {"v": a}
        return {"v": _tree_axes_like(param_axes, ax), "count": (None,)}

    def update(self, grads, state, params, lr):
        c = state["count"] + 1
        beta = 1.0 - c.astype(jnp.float32) ** -self.decay

        def upd(g, v, p):
            g = g.astype(jnp.float32)
            g2 = jnp.square(g) + self.eps
            if self._factored(p):
                vr = beta * v["vr"] + (1 - beta) * g2.mean(-1)
                vc = beta * v["vc"] + (1 - beta) * g2.mean(-2)
                denom = (vr / jnp.maximum(
                    vr.mean(-1, keepdims=True), self.eps))[..., None] \
                    * vc[..., None, :]
                step = g * jax.lax.rsqrt(jnp.maximum(denom, self.eps))
                nv = {"vr": vr, "vc": vc}
            else:
                nv = {"v": beta * v["v"] + (1 - beta) * g2}
                step = g * jax.lax.rsqrt(jnp.maximum(nv["v"], self.eps))
            # update clipping (RMS)
            rms = jnp.sqrt(jnp.mean(jnp.square(step)) + 1e-12)
            step = step / jnp.maximum(1.0, rms / self.clip_threshold)
            return (p.astype(jnp.float32) - lr * step).astype(p.dtype), nv

        leaves_p, treedef = jax.tree.flatten(params)
        leaves_g = treedef.flatten_up_to(grads)
        leaves_v = treedef.flatten_up_to(state["v"])
        out = [upd(g, v, p) for g, v, p in zip(leaves_g, leaves_v, leaves_p)]
        new_p = treedef.unflatten([o[0] for o in out])
        new_v = treedef.unflatten([o[1] for o in out])
        return new_p, {"v": new_v, "count": c}


def make_optimizer(name: str):
    return {"adamw": AdamW(), "adafactor": Adafactor()}[name]

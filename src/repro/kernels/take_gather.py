"""Row-gather Pallas TPU kernel — the Zerrow data-plane hot spot.

The copies Zerrow *cannot* avoid are row-granular materializations
(filter/sort on regular encodings, dictionary-code lookup).  On TPU those
are gathers; this kernel streams them through VMEM:

  * ``take_rows``: out[i] = values[indices[i]] with the indices as a
    scalar-prefetch operand — the index map itself selects which source
    row block DMA'd into VMEM (no gather instruction at all: the gather
    *is* the DMA schedule).
  * ``dict_decode``: same contract, tuned for small dictionaries — the
    whole dictionary is pinned in VMEM and rows are selected with a
    vectorized one-hot matmul (MXU does the gather).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


# --------------------------------------------------------------------------
# streaming row gather (large tables): scalar-prefetch index map
# --------------------------------------------------------------------------

def _take_kernel(idx_ref, vals_ref, out_ref):
    out_ref[...] = vals_ref[...]


def take_rows(values, indices, *, interpret: bool = True):
    """values: (R, W); indices: (M,) int32 -> (M, W).

    One source row block per output row: the scalar-prefetched index map
    turns the gather into a DMA schedule (BlockSpec index_map reads
    indices[i]).
    """
    R, W = values.shape
    M = indices.shape[0]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(M,),
        in_specs=[
            pl.BlockSpec((1, W), lambda i, idx_ref: (idx_ref[i], 0)),
        ],
        out_specs=pl.BlockSpec((1, W), lambda i, idx_ref: (i, 0)),
    )
    return pl.pallas_call(
        _take_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((M, W), values.dtype),
        interpret=interpret,
    )(indices.astype(jnp.int32), values)


# --------------------------------------------------------------------------
# dictionary decode (small dictionary resident in VMEM)
# --------------------------------------------------------------------------

def _dict_kernel(codes_ref, dict_ref, out_ref, *, bm: int):
    codes = codes_ref[...].reshape(bm)                    # (bm,)
    d = dict_ref[...]                                     # (R, W)
    R = d.shape[0]
    onehot = (codes[:, None] ==
              jax.lax.broadcasted_iota(jnp.int32, (bm, R), 1))
    out_ref[...] = jax.lax.dot_general(
        onehot.astype(d.dtype), d, (((1,), (0,)), ((), ())),
        preferred_element_type=d.dtype)


def dict_decode(codes, dictionary, *, bm: int = 256,
                interpret: bool = True):
    """codes: (M,) int32; dictionary: (R, W) -> (M, W).

    The dictionary stays pinned in VMEM across the whole grid; each block
    of bm codes becomes a one-hot (bm, R) @ (R, W) MXU matmul — for
    dictionary-encoded Arrow columns (paper §5.3) R is small, so this is
    compute-cheap and strictly sequential-DMA-free.
    """
    M = codes.shape[0]
    R, W = dictionary.shape
    bm = min(bm, M)
    assert M % bm == 0, (M, bm)
    kernel = functools.partial(_dict_kernel, bm=bm)
    return pl.pallas_call(
        kernel,
        grid=(M // bm,),
        in_specs=[
            pl.BlockSpec((bm,), lambda i: (i,)),
            pl.BlockSpec((R, W), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, W), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((M, W), dictionary.dtype),
        interpret=interpret,
    )(codes.astype(jnp.int32), dictionary)

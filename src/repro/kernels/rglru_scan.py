"""RG-LRU linear-recurrence Pallas TPU kernel.

h_t = a_t ⊙ h_{t-1} + b_t over the sequence, tiled (B, W/bw, S/chunk) with
the chunk dimension innermost: the carry h lives in VMEM scratch and flows
across sequential grid steps (the TPU grid is sequential), so HBM traffic
is exactly one read of (a, b) and one write of h — the memory roofline for
this op.  Within a chunk the recurrence is a fori_loop over rows of the
(chunk, bw) VMEM tile: vector ops on 8x128 VREG tiles.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _rglru_kernel(h0_ref, a_ref, b_ref, h_ref, hlast_ref, carry, *,
                  chunk: int, nchunks: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        carry[...] = h0_ref[...].astype(jnp.float32)

    a = a_ref[0].astype(jnp.float32)        # (chunk, bw)
    b = b_ref[0].astype(jnp.float32)

    def step(t, h):
        h = a[t] * h + b[t]
        h_ref[0, t, :] = h.astype(h_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, chunk, step, carry[0])
    carry[...] = h[None]

    @pl.when(ci == nchunks - 1)
    def _fin():
        hlast_ref[...] = h[None].astype(hlast_ref.dtype)


def rglru_pallas(a, b, h0=None, *, chunk: int = 256, bw: int = 512,
                 interpret: bool = True):
    """a, b: (B, S, W) -> (h (B,S,W) float32, h_last (B,W) float32)."""
    B, S, W = a.shape
    if h0 is None:
        h0 = jnp.zeros((B, W), jnp.float32)
    chunk = min(chunk, S)
    bw = min(bw, W)
    assert S % chunk == 0 and W % bw == 0, (S, chunk, W, bw)
    nchunks = S // chunk

    kernel = functools.partial(_rglru_kernel, chunk=chunk, nchunks=nchunks)
    h, h_last = pl.pallas_call(
        kernel,
        grid=(B, W // bw, nchunks),
        in_specs=[
            pl.BlockSpec((1, bw), lambda bi, wi, ci: (bi, wi)),
            pl.BlockSpec((1, chunk, bw), lambda bi, wi, ci: (bi, ci, wi)),
            pl.BlockSpec((1, chunk, bw), lambda bi, wi, ci: (bi, ci, wi)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, bw), lambda bi, wi, ci: (bi, ci, wi)),
            pl.BlockSpec((1, bw), lambda bi, wi, ci: (bi, wi)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S, W), jnp.float32),
            jax.ShapeDtypeStruct((B, W), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((1, bw), jnp.float32)],
        interpret=interpret,
    )(h0, a, b)
    return h, h_last

"""Flash attention Pallas TPU kernel (causal / local-window, GQA).

Tiling: grid (B, H, S/bq, T/bk) with the KV dimension innermost —
sequential on TPU, so the online-softmax accumulators (m, l, acc) live in
VMEM scratch and persist across KV steps.  Out-of-range blocks for
causal/local masks are skipped with ``pl.when`` (the compute-roofline win
over the XLA chunked path, which multiplies every block).

GQA is handled in the index map: query head h reads KV head h // G — the
KV tensors are never materialized per-query-head in HBM.

Block shapes default to (128, 128): MXU-aligned, and the working set
(q: bq×hd, k/v: bk×hd, scores: bq×bk, acc: bq×hd in f32) stays well under
VMEM for hd ≤ 256.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_sc, l_sc, acc_sc, *,
                  causal: bool, window: int, bq: int, bk: int, nk: int,
                  scale: float):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_sc[...] = jnp.full_like(m_sc, NEG_INF)
        l_sc[...] = jnp.zeros_like(l_sc)
        acc_sc[...] = jnp.zeros_like(acc_sc)

    q_start = qi * bq
    k_start = ki * bk

    def _body():
        q = q_ref[0, 0].astype(jnp.float32)          # (bq, hd)
        k = k_ref[0, 0].astype(jnp.float32)          # (bk, hd)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale   # (bq, bk)
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = jnp.ones((bq, bk), jnp.bool_)
        if causal:
            mask &= kpos <= qpos
        if window:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_sc[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_sc[...] = l_sc[...] * alpha + p.sum(axis=1, keepdims=True)
        acc_sc[...] = acc_sc[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_sc[...] = m_new

    # block-level skip: tiles that the causal/local mask fully excludes do
    # no compute at all (the roofline win over XLA chunked attention)
    if causal or window:
        run = jnp.asarray(True)
        if causal:
            run &= k_start <= q_start + bq - 1
        if window:
            run &= k_start + bk - 1 > q_start - window
        pl.when(run)(_body)
    else:
        _body()

    @pl.when(ki == nk - 1)
    def _finish():
        o_ref[0, 0] = (acc_sc[...] /
                       jnp.maximum(l_sc[...], 1e-30)).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    bq: int = 128, bk: int = 128,
                    interpret: bool = True):
    """q: (B, S, H, hd); k, v: (B, T, KV, hd) -> (B, S, H, hd)."""
    B, S, H, hd = q.shape
    T, KV = k.shape[1], k.shape[2]
    G = H // KV
    bq = min(bq, S)
    bk = min(bk, T)
    assert S % bq == 0 and T % bk == 0, (S, bq, T, bk)
    nq, nk = S // bq, T // bk
    scale = hd ** -0.5

    # layout: (B, H, S, hd) blocks
    qt = q.swapaxes(1, 2)
    kt = k.swapaxes(1, 2)
    vt = v.swapaxes(1, 2)

    kernel = functools.partial(_flash_kernel, causal=causal, window=window,
                               bq=bq, bk=bk, nk=nk, scale=scale)
    out = pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda b, h, qi, ki: (b, h, qi, 0)),
            pl.BlockSpec((1, 1, bk, hd),
                         lambda b, h, qi, ki, G=G: (b, h // G, ki, 0)),
            pl.BlockSpec((1, 1, bk, hd),
                         lambda b, h, qi, ki, G=G: (b, h // G, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, hd),
                               lambda b, h, qi, ki: (b, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, S, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),      # running max
            pltpu.VMEM((bq, 1), jnp.float32),      # running denom
            pltpu.VMEM((bq, hd), jnp.float32),     # running accumulator
        ],
        interpret=interpret,
    )(qt, kt, vt)
    return out.swapaxes(1, 2)

"""Accelerator-resident relational kernels — Pallas ports of the
eligible ``core/vkernels.py`` hot paths.

The Zerrow data plane keeps relational data adjacent to the model
kernels in this package (flash_attention, wkv6, take_gather); these
kernels let the join/group-by hot path run *there* instead of
round-tripping through host numpy.  Every kernel has an interpret-mode
path (``ops.default_interpret()``, overridable with
``ZERROW_PALLAS_INTERPRET``) so the whole surface runs in CI on
accelerator-less runners, and every kernel is held to a **bit-identity**
contract against its numpy reference — admission is decided by the
differential harness in ``tests/test_pallas_relational.py`` and recorded
in ``core.kdispatch.REGISTRY``; anything that cannot reproduce the numpy
bits exactly (float segment sums: PR 5's sequential-accumulation
contract) stays on numpy *by registry*, never silently.

Ported kernels (signatures mirror ``core/vkernels.py``; inputs and
outputs are plain numpy arrays, conversion happens at the edge):

  ``hash_fixed(v)``        splitmix64 over the bit patterns of a
      fixed-width array (float -0.0 canonicalized, same prep as numpy);
      the mix itself runs as a blocked elementwise Pallas kernel.
  ``combine_hashes(hs,n)`` order-sensitive fold of per-column uint64
      hashes into one row hash (the representation-free combiner).
  ``hash_keys(keys, n)``   fused multi-key mixing: per-column splitmix64
      + the ordered combine in ONE kernel over the stacked key bits.
      Fixed-width key buffers only — var-length (offsets, values) keys
      are structurally routed to numpy by the dispatch layer.
  ``filter_join_gather(sel, idx)``  compose a filter selection with join
      gather indices, ``-1`` miss sentinels preserved, as a blocked
      in-VMEM gather.
  ``gather_payload(values, idx, fill=0)``  the fused payload-column
      gather behind the join output: out[i] = values[idx[i]] with ``-1``
      rows filled — ``take_rows``'s 1-D sibling with sentinel handling.
  ``grouped_count / grouped_sum / grouped_min / grouped_max(values,
  order, starts, valid=None)``  segment reducers over precomputed
      ``group_ranges`` boundaries: a (row-block x group-block) one-hot
      mask reduction with the group axis outermost, so each group
      block's accumulator stays resident across the whole row sweep
      (the flash-attention revisit pattern).  Integer/bool reductions
      are exact in any order and hold bit-identity; float sum/min/max
      are order- or tie-sensitive and are registry-ineligible.

64-bit lanes: the hash kernels work in uint64, so calls run under
``jax.experimental.enable_x64`` (scoped — the global default-dtype
behavior of the model kernels is untouched).  TPU note: 64-bit integer
lanes are emulated on current TPUs; the interpret path is the CI
contract, the compiled path is gated by the same differential harness
before it may be admitted on real hardware.

The kernel *bodies* are plain module functions referenced directly by
the public wrappers (no module-level jit objects), so
``core.fingerprint``'s direct-global scan sees them: editing a kernel
body here invalidates every cached join/group-by cone that was computed
with ``ZERROW_KERNEL_BACKEND=pallas``.
"""

from __future__ import annotations

import functools
from typing import Optional, Sequence, Tuple

import numpy as np

import jax
import jax.numpy as jnp
from jax.experimental import enable_x64
from jax.experimental import pallas as pl

from repro.core import vkernels

from .ops import default_interpret

__all__ = [
    "hash_fixed", "combine_hashes", "hash_keys",
    "filter_join_gather", "gather_payload",
    "grouped_count", "grouped_sum", "grouped_min", "grouped_max",
]

_GOLDEN = np.uint64(0x9E3779B97F4A7C15)

#: elementwise/gather block rows and (row, group) reduction tile —
#: VPU-lane friendly multiples; interpret mode only cares that the tile
#: bounds the (block x group-block) one-hot materialization
_BN = 2048
_BG = 512


def _resolve_interpret(interpret: Optional[bool]) -> bool:
    return default_interpret() if interpret is None else bool(interpret)


def _pad1(a: np.ndarray, bn: int, fill) -> Tuple[np.ndarray, int]:
    """Pad a 1-D array up to a block multiple (at least one block)."""
    n = len(a)
    npad = -(-max(n, 1) // bn) * bn
    if npad == n:
        return a, n
    out = np.full(npad, fill, dtype=a.dtype)
    out[:n] = a
    return out, n


# --------------------------------------------------------------------------
# splitmix64: hash_fixed / combine_hashes / fused hash_keys
# --------------------------------------------------------------------------

def _mix64(h):
    """splitmix64 finalizer over a uint64 jnp array — the same constants
    and operation order as ``vkernels._mix64`` (wrapping uint64 multiply
    and xor-shift are exact on any backend, so bits agree)."""
    h = h ^ (h >> jnp.uint64(30))
    h = h * jnp.uint64(0xBF58476D1CE4E5B9)
    h = h ^ (h >> jnp.uint64(27))
    h = h * jnp.uint64(0x94D049BB133111EB)
    return h ^ (h >> jnp.uint64(31))


def _prep_bits(values: np.ndarray) -> np.ndarray:
    """Bit-pattern prep, shared contract with ``vkernels.hash_fixed``:
    canonicalize float -0.0 to +0.0, then widen the raw bits to uint64.
    Pure representation work (views + one elementwise where) — the
    mixing is what runs on the accelerator."""
    values = np.ascontiguousarray(values)
    if np.issubdtype(values.dtype, np.floating):
        values = np.where(values == 0, 0, values)
    w = values.dtype.itemsize
    return np.ascontiguousarray(values).view(f"u{w}").astype(np.uint64) \
        if w < 8 else np.ascontiguousarray(values).view(np.uint64)


def _hash_fixed_kernel(bits_ref, out_ref):
    out_ref[...] = _mix64(bits_ref[...] ^ jnp.uint64(_GOLDEN))


def hash_fixed(values: np.ndarray, *,
               interpret: Optional[bool] = None) -> np.ndarray:
    """uint64 splitmix64 hash per element of a fixed-width array —
    bit-identical to ``vkernels.hash_fixed``."""
    bits = _prep_bits(values)
    n = len(bits)
    if n == 0:
        return np.empty(0, dtype=np.uint64)
    padded, _ = _pad1(bits, _BN, 0)
    with enable_x64():
        out = pl.pallas_call(
            _hash_fixed_kernel,
            grid=(len(padded) // _BN,),
            in_specs=[pl.BlockSpec((_BN,), lambda i: (i,))],
            out_specs=pl.BlockSpec((_BN,), lambda i: (i,)),
            out_shape=jax.ShapeDtypeStruct((len(padded),), jnp.uint64),
            interpret=_resolve_interpret(interpret),
        )(padded)
        return np.asarray(out)[:n]


def _combine_kernel(hs_ref, out_ref, *, ncols, bn):
    h = jnp.full((bn,), jnp.uint64(_GOLDEN))
    for j in range(ncols):
        h = _mix64(h * jnp.uint64(_GOLDEN) ^ hs_ref[j, :])
    out_ref[...] = h


def _run_combine(stacked: np.ndarray, n: int, mix_first: bool,
                 interpret: Optional[bool]) -> np.ndarray:
    """Shared driver for ``combine_hashes`` (pre-hashed columns) and the
    fused ``hash_keys`` (raw bits, ``mix_first=True`` hashes each column
    in-kernel before the ordered combine)."""
    ncols = stacked.shape[0]
    npad = -(-max(n, 1) // _BN) * _BN
    if npad != n:
        padded = np.zeros((ncols, npad), dtype=np.uint64)
        padded[:, :n] = stacked
    else:
        padded = stacked
    kernel = functools.partial(
        _hash_keys_kernel if mix_first else _combine_kernel,
        ncols=ncols, bn=_BN)
    with enable_x64():
        out = pl.pallas_call(
            kernel,
            grid=(npad // _BN,),
            in_specs=[pl.BlockSpec((ncols, _BN), lambda i: (0, i))],
            out_specs=pl.BlockSpec((_BN,), lambda i: (i,)),
            out_shape=jax.ShapeDtypeStruct((npad,), jnp.uint64),
            interpret=_resolve_interpret(interpret),
        )(padded)
        return np.asarray(out)[:n]


def combine_hashes(col_hashes: Sequence[np.ndarray], n: int, *,
                   interpret: Optional[bool] = None) -> np.ndarray:
    """Fold per-column uint64 hash arrays into one row hash —
    bit-identical to ``vkernels.combine_hashes`` (order-sensitive)."""
    if not col_hashes or n == 0:
        return vkernels.combine_hashes(col_hashes, n)
    stacked = np.ascontiguousarray(
        np.stack([np.asarray(h, dtype=np.uint64) for h in col_hashes]))
    return _run_combine(stacked, n, mix_first=False, interpret=interpret)


def _hash_keys_kernel(bits_ref, out_ref, *, ncols, bn):
    h = jnp.full((bn,), jnp.uint64(_GOLDEN))
    for j in range(ncols):
        hk = _mix64(bits_ref[j, :] ^ jnp.uint64(_GOLDEN))
        h = _mix64(h * jnp.uint64(_GOLDEN) ^ hk)
    out_ref[...] = h


def hash_keys(keys: Sequence[np.ndarray], n: int, *,
              interpret: Optional[bool] = None) -> np.ndarray:
    """Fused multi-key mixing over *fixed-width* key buffers: per-column
    splitmix64 and the ordered combine in one kernel pass —
    bit-identical to ``vkernels.hash_keys`` on ndarray keys.  Var-length
    ``(offsets, values)`` keys are not expressible here; the dispatch
    layer routes any mix containing one to numpy."""
    if any(isinstance(k, tuple) for k in keys):
        raise TypeError("hash_keys (pallas) takes fixed-width ndarray "
                        "keys only; var-length keys stay on numpy")
    if not keys or n == 0:
        return vkernels.hash_keys(list(keys), n)
    stacked = np.ascontiguousarray(
        np.stack([_prep_bits(k) for k in keys]))
    return _run_combine(stacked, n, mix_first=True, interpret=interpret)


# --------------------------------------------------------------------------
# gathers: filter->join index composition + fused payload gather
# --------------------------------------------------------------------------

def _gather_kernel(idx_ref, src_ref, out_ref, *, fill):
    idx = idx_ref[...]
    hit = idx >= 0
    g = jnp.take(src_ref[...], jnp.where(hit, idx, 0), axis=0)
    out_ref[...] = jnp.where(hit, g, jnp.asarray(fill, g.dtype))


def _sentinel_gather(src: np.ndarray, idx: np.ndarray, fill, *,
                     interpret: Optional[bool]) -> np.ndarray:
    """Blocked gather with the whole source resident per block and ``-1``
    indices mapped to ``fill`` — the shared core of
    ``filter_join_gather`` and ``gather_payload``."""
    m = len(idx)
    if m == 0:
        return np.empty(0, dtype=src.dtype)
    if len(src) == 0:
        # every index must be a -1 miss sentinel; nothing to gather
        return np.full(m, fill, dtype=src.dtype)
    ii, _ = _pad1(np.ascontiguousarray(idx, dtype=np.int64), _BN, -1)
    r = len(src)
    with enable_x64():
        out = pl.pallas_call(
            functools.partial(_gather_kernel, fill=fill),
            grid=(len(ii) // _BN,),
            in_specs=[pl.BlockSpec((_BN,), lambda i: (i,)),
                      pl.BlockSpec((r,), lambda i: (0,))],
            out_specs=pl.BlockSpec((_BN,), lambda i: (i,)),
            out_shape=jax.ShapeDtypeStruct((len(ii),), src.dtype),
            interpret=_resolve_interpret(interpret),
        )(ii, np.ascontiguousarray(src))
        return np.asarray(out)[:m]


def filter_join_gather(sel: np.ndarray, idx: np.ndarray, *,
                       interpret: Optional[bool] = None) -> np.ndarray:
    """Compose a filter's row selection with a join's gather indices,
    ``-1`` left-join miss sentinels preserved — bit-identical to
    ``vkernels.filter_join_gather``."""
    sel = np.ascontiguousarray(sel, dtype=np.int64)
    return _sentinel_gather(sel, idx, -1, interpret=interpret)


def gather_payload(values: np.ndarray, idx: np.ndarray, fill=0, *,
                   interpret: Optional[bool] = None) -> np.ndarray:
    """Fused join-payload gather: ``out[i] = values[idx[i]]`` with
    ``idx[i] == -1`` rows set to ``fill`` — the 1-D, sentinel-aware
    sibling of ``take_rows`` used to materialize join output columns
    without a host round-trip."""
    values = np.ascontiguousarray(values)
    return _sentinel_gather(values, np.asarray(idx, dtype=np.int64), fill,
                            interpret=interpret)


# --------------------------------------------------------------------------
# segment reducers over group_ranges boundaries
# --------------------------------------------------------------------------

def _segreduce_kernel(seg_ref, v_ref, w_ref, out_ref, cnt_ref, *,
                      op, bn, bg, sentinel):
    j = pl.program_id(0)                       # group block (outermost)
    i = pl.program_id(1)                       # row block (innermost)

    @pl.when(i == 0)
    def _():
        out_ref[...] = jnp.full((bg,), jnp.asarray(sentinel,
                                                   out_ref.dtype))
        cnt_ref[...] = jnp.zeros((bg,), cnt_ref.dtype)

    seg = seg_ref[...]
    gids = jax.lax.broadcasted_iota(jnp.int32, (bn, bg), 1) + j * bg
    oh = seg[:, None] == gids
    v = v_ref[...][:, None]
    w = w_ref[...][:, None]
    cnt_ref[...] += jnp.sum(jnp.where(oh, w, 0), axis=0)
    if op == "sum":
        out_ref[...] += jnp.sum(jnp.where(oh, v, 0), axis=0)
    elif op == "min":
        out_ref[...] = jnp.minimum(
            out_ref[...],
            jnp.min(jnp.where(oh, v, jnp.asarray(sentinel, v.dtype)),
                    axis=0))
    else:
        out_ref[...] = jnp.maximum(
            out_ref[...],
            jnp.max(jnp.where(oh, v, jnp.asarray(sentinel, v.dtype)),
                    axis=0))


def _segreduce(op: str, seg: np.ndarray, v: np.ndarray, w: np.ndarray,
               n_groups: int, sentinel, interpret: Optional[bool]
               ) -> Tuple[np.ndarray, np.ndarray]:
    """(reduced, counts) over dense sorted-domain segment ids.  Grid is
    (group blocks, row blocks) with the group axis outermost, so each
    group block's accumulator is revisited on consecutive steps only —
    resident across the whole row sweep, no partial spills."""
    seg_p, _ = _pad1(np.ascontiguousarray(seg, np.int32), _BN, -1)
    v_p, _ = _pad1(np.ascontiguousarray(v), _BN, 0)
    w_p, _ = _pad1(np.ascontiguousarray(w, np.int64), _BN, 0)
    gpad = -(-n_groups // _BG) * _BG
    kernel = functools.partial(_segreduce_kernel, op=op, bn=_BN, bg=_BG,
                               sentinel=sentinel)
    with enable_x64():
        out, cnt = pl.pallas_call(
            kernel,
            grid=(gpad // _BG, len(seg_p) // _BN),
            in_specs=[pl.BlockSpec((_BN,), lambda j, i: (i,)),
                      pl.BlockSpec((_BN,), lambda j, i: (i,)),
                      pl.BlockSpec((_BN,), lambda j, i: (i,))],
            out_specs=[pl.BlockSpec((_BG,), lambda j, i: (j,)),
                       pl.BlockSpec((_BG,), lambda j, i: (j,))],
            out_shape=[jax.ShapeDtypeStruct((gpad,), v_p.dtype),
                       jax.ShapeDtypeStruct((gpad,), jnp.int64)],
            interpret=_resolve_interpret(interpret),
        )(seg_p, v_p, w_p)
        return (np.asarray(out)[:n_groups], np.asarray(cnt)[:n_groups])


def _sorted_segments(order: np.ndarray, starts: np.ndarray
                     ) -> np.ndarray:
    """Dense int32 group ids in the sorted domain (boundary metadata,
    linear-time host prep — the reduction itself is the kernel)."""
    n = len(order)
    counts = np.diff(np.append(starts, n))
    return np.repeat(np.arange(len(starts), dtype=np.int32), counts)


def _weights(order: np.ndarray, valid) -> np.ndarray:
    if valid is None:
        return np.ones(len(order), dtype=np.int64)
    return valid[order].astype(np.int64)


def grouped_count(values: np.ndarray, order: np.ndarray,
                  starts: np.ndarray, valid=None, *,
                  interpret: Optional[bool] = None
                  ) -> Tuple[np.ndarray, np.ndarray]:
    """Per-group count of non-null rows — bit-identical to
    ``vkernels.grouped_count`` (``values`` ignored, uniform reducer
    signature)."""
    if len(starts) == 0:
        counts = np.empty(0, np.int64)
        return counts, counts
    seg = _sorted_segments(order, starts)
    w = _weights(order, valid)
    _, counts = _segreduce("sum", seg, w, w, len(starts), 0, interpret)
    return counts, counts


def grouped_sum(values: np.ndarray, order: np.ndarray,
                starts: np.ndarray, valid=None, *,
                interpret: Optional[bool] = None
                ) -> Tuple[np.ndarray, np.ndarray]:
    """Per-group sum over non-null rows, integer/bool inputs only —
    exact in any accumulation order, so blocked reduction holds
    bit-identity with ``vkernels.grouped_sum``'s ``reduceat``.  Float
    inputs are rejected: PR 5's contract accumulates float sums
    sequentially in original row order (``np.bincount``), which a
    parallel reduction cannot reproduce bit-for-bit — the registry keeps
    them on numpy (the documented-ineligible entry)."""
    if not (values.dtype == np.bool_
            or np.issubdtype(values.dtype, np.integer)):
        raise TypeError(
            f"grouped_sum (pallas) is integer/bool only; {values.dtype} "
            "sums are order-sensitive and registry-ineligible")
    acc = np.uint64 if values.dtype == np.uint64 else np.int64
    n_groups = len(starts)
    if n_groups == 0:
        return np.empty(0, acc), np.empty(0, np.int64)
    seg = _sorted_segments(order, starts)
    v = values[order].astype(acc)
    if valid is not None:
        v = np.where(valid[order], v, v.dtype.type(0))
    sums, counts = _segreduce("sum", seg, v, _weights(order, valid),
                              n_groups, 0, interpret)
    return sums, counts


def _grouped_extreme(op, values, order, starts, valid, sentinel_of,
                     interpret):
    if np.issubdtype(values.dtype, np.floating):
        raise TypeError(
            f"grouped_{op} (pallas) is integer/bool only; float "
            "-0.0/NaN tie-breaking is order-sensitive and "
            "registry-ineligible")
    n_groups = len(starts)
    v = values[order]
    if v.dtype == np.bool_:
        v = v.astype(np.uint8)
    if n_groups == 0:
        return np.empty(0, v.dtype), np.empty(0, np.int64)
    sentinel = sentinel_of(v.dtype)
    if valid is not None:
        v = np.where(valid[order], v, v.dtype.type(sentinel))
    seg = _sorted_segments(order, starts)
    return _segreduce(op, seg, v, _weights(order, valid), n_groups,
                      sentinel, interpret)


def grouped_min(values, order, starts, valid=None, *,
                interpret: Optional[bool] = None):
    """Per-group min over non-null rows (integer/bool) — bit-identical
    to ``vkernels.grouped_min`` including the all-null sentinel."""
    return _grouped_extreme("min", values, order, starts, valid,
                            vkernels._dtype_max, interpret)


def grouped_max(values, order, starts, valid=None, *,
                interpret: Optional[bool] = None):
    """Per-group max over non-null rows (integer/bool) — bit-identical
    to ``vkernels.grouped_max`` including the all-null sentinel."""
    return _grouped_extreme("max", values, order, starts, valid,
                            vkernels._dtype_min, interpret)

"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def attention_ref(q, k, v, *, causal: bool = True, window: int = 0):
    """Naive full-matrix attention.  q: (B,S,H,hd); k,v: (B,T,KV,hd)."""
    B, S, H, hd = q.shape
    T, KV = k.shape[1], k.shape[2]
    G = H // KV
    qg = q.reshape(B, S, KV, G, hd).astype(jnp.float32)
    s = jnp.einsum("bskgh,btkh->bskgt", qg, k.astype(jnp.float32))
    s = s * (hd ** -0.5)
    qpos = jnp.arange(S)[:, None]
    kpos = jnp.arange(T)[None, :]
    mask = jnp.ones((S, T), bool)
    if causal:
        mask &= kpos <= qpos
    if window:
        mask &= kpos > qpos - window
    s = jnp.where(mask[None, :, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bskgt,btkh->bskgh", p, v.astype(jnp.float32))
    return o.reshape(B, S, H, hd).astype(q.dtype)


def rglru_ref(a, b, h0=None):
    """Sequential linear recurrence h_t = a_t*h_{t-1} + b_t.
    a, b: (B,S,W) float32.  Returns (h (B,S,W), h_last (B,W))."""
    B, S, W = a.shape
    h = jnp.zeros((B, W), jnp.float32) if h0 is None else h0

    def step(h, ab):
        at, bt = ab
        h = at * h + bt
        return h, h
    h_last, hs = jax.lax.scan(step, h, (a.swapaxes(0, 1), b.swapaxes(0, 1)))
    return hs.swapaxes(0, 1), h_last


def wkv6_ref(r, k, v, w, u, state=None):
    """Sequential WKV6: o_t = r·(diag(u) k v^T + S);  S' = diag(w) S + k v^T.
    r,k,v,w: (B,S,H,N); u: (H,N); state: (B,H,N,N)."""
    B, S, H, N = r.shape
    f32 = jnp.float32
    if state is None:
        state = jnp.zeros((B, H, N, N), f32)

    def step(st, inp):
        rt, kt, vt, wt = inp                       # (B,H,N)
        kv = jnp.einsum("bhn,bhm->bhnm", kt, vt)
        o = jnp.einsum("bhn,bhnm->bhm", rt, st + u[None, :, :, None] * kv)
        st = st * wt[..., None] + kv
        return st, o
    seq = tuple(a.astype(f32).swapaxes(0, 1) for a in (r, k, v, w))
    state, outs = jax.lax.scan(step, state, seq)
    return outs.swapaxes(0, 1).astype(r.dtype), state


def take_rows_ref(values, indices):
    """Row gather: out[i] = values[indices[i]].  values: (R, W)."""
    return jnp.take(values, indices, axis=0)


def dict_decode_ref(codes, dictionary):
    """Dictionary decode: out[i] = dictionary[codes[i]]."""
    return jnp.take(dictionary, codes, axis=0)

"""WKV-6 (RWKV 'Finch') chunked Pallas TPU kernel.

State S ∈ R^{N×N} per (batch, head); grid (B, H, S/chunk) with chunks
innermost so the state lives in VMEM scratch across sequential steps.
Within a chunk the three contributions are MXU matmuls:

    out = (r ⊙ d_in) @ S              carried state
        + tril((r ⊙ d_in) @ (k ⊙ d_k⁻¹)ᵀ, -1) @ v      intra-chunk
        + diag(rᵀ(u ⊙ k)) @ v                          bonus term
    S'  = d_total ⊙ S + (k ⊙ d_tail)ᵀ @ v

d_* are cumulative-decay factors; the decay floor (log w ≥ -4, enforced in
the model) bounds every exponent by 4·chunk so chunk ≤ 16 stays in f32
range — same scheme as the jnp chunked path in models/rwkv6.py.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _wkv6_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref,
                 o_ref, sout_ref, state, *, chunk: int, nchunks: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state[...] = s0_ref[0, 0].astype(jnp.float32)

    r = r_ref[0, 0].astype(jnp.float32)     # (C, N)
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)
    w = w_ref[0, 0].astype(jnp.float32)
    u = u_ref[0].astype(jnp.float32)        # (1, N) -> broadcast
    C, N = r.shape

    logw = jnp.log(jnp.maximum(w, 1e-8))
    cum = jnp.cumsum(logw, axis=0)
    cum_excl = cum - logw
    d_in = jnp.exp(cum_excl)                                 # (C, N)
    st = state[...]

    dot = functools.partial(jax.lax.dot_general,
                            preferred_element_type=jnp.float32)
    out_state = dot(r * d_in, st, (((1,), (0,)), ((), ())))
    k_scaled = k * jnp.exp(-cum)
    A = dot(r * d_in, k_scaled, (((1,), (1,)), ((), ())))    # (C, C)
    tri = jax.lax.broadcasted_iota(jnp.int32, (C, C), 0) > \
        jax.lax.broadcasted_iota(jnp.int32, (C, C), 1)
    A = jnp.where(tri, A, 0.0)
    out_intra = dot(A, v, (((1,), (0,)), ((), ())))
    diag = jnp.sum(r * (u * k), axis=1, keepdims=True)       # (C, 1)
    o_ref[0, 0] = (out_state + out_intra + diag * v).astype(o_ref.dtype)

    d_total = jnp.exp(cum[-1])                               # (N,)
    k_tail = k * jnp.exp(cum[-1][None, :] - cum)
    state[...] = st * d_total[:, None] + \
        dot(k_tail, v, (((0,), (0,)), ((), ())))

    @pl.when(ci == nchunks - 1)
    def _fin():
        sout_ref[0, 0] = state[...]


def wkv6_pallas(r, k, v, w, u, state=None, *, chunk: int = 16,
                interpret: bool = True):
    """r,k,v,w: (B,S,H,N); u: (H,N); state: (B,H,N,N) f32.
    Returns (out (B,S,H,N), final_state (B,H,N,N))."""
    B, S, H, N = r.shape
    if state is None:
        state = jnp.zeros((B, H, N, N), jnp.float32)
    chunk = min(chunk, S)
    assert S % chunk == 0
    nchunks = S // chunk

    # layout: (B, H, S, N)
    rt, kt, vt, wt = (x.swapaxes(1, 2) for x in (r, k, v, w))
    kernel = functools.partial(_wkv6_kernel, chunk=chunk, nchunks=nchunks)
    out, s_out = pl.pallas_call(
        kernel,
        grid=(B, H, nchunks),
        in_specs=[
            pl.BlockSpec((1, 1, chunk, N), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, chunk, N), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, chunk, N), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, chunk, N), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, N), lambda b, h, c: (h, 0)),
            pl.BlockSpec((1, 1, N, N), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, chunk, N), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, N, N), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, S, N), r.dtype),
            jax.ShapeDtypeStruct((B, H, N, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((N, N), jnp.float32)],
        interpret=interpret,
    )(rt, kt, vt, wt, u, state)
    return out.swapaxes(1, 2), s_out

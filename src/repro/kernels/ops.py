"""Public wrappers for the Pallas kernels.

``interpret`` resolves *per call* (not at trace time):
``ZERROW_PALLAS_INTERPRET=0/1`` is the explicit, tested override;
without it, kernels interpret everywhere except on a real TPU backend.
The wrappers here are plain functions that resolve the mode and pass it
as a static argument into the jitted implementations, so flipping the
env var between calls takes effect immediately (a mode baked into a jit
trace would be stale).  Every wrapper has a pure-jnp oracle in ref.py;
tests sweep shapes/dtypes and assert allclose against it.

The gather wrappers additionally validate on the host: out-of-range
indices/codes raise ``IndexError`` (a gather must never silently wrap
or clamp — that is how a wrong row ships), and zero-row inputs return
empty outputs without launching a kernel.
"""

from __future__ import annotations

import functools
import os

try:
    import jax
    import jax.numpy as jnp
except ImportError as e:                                # pragma: no cover
    raise ImportError(
        "repro.kernels requires jax (with Pallas), which is not "
        "importable here. The relational pipeline does not need it: "
        "leave ZERROW_KERNEL_BACKEND unset (or set it to 'numpy') to "
        "run on the numpy vkernels; set ZERROW_KERNEL_BACKEND=pallas "
        "only where jax is installed."
    ) from e

import numpy as np

from .flash_attention import flash_attention as _flash
from .rglru_scan import rglru_pallas as _rglru
from .take_gather import dict_decode as _dict_decode
from .take_gather import take_rows as _take_rows
from .wkv6 import wkv6_pallas as _wkv6

_INTERPRET_ENV = "ZERROW_PALLAS_INTERPRET"


def on_tpu() -> bool:
    """True when jax's default backend is a real TPU."""
    return jax.default_backend() == "tpu"


def default_interpret() -> bool:
    """Should Pallas kernels run in interpret mode?  The explicit env
    override ``ZERROW_PALLAS_INTERPRET=1`` (force interpret) / ``0``
    (force compiled) wins and is read per call, so tests and CI lanes
    can flip it without reimporting; any other value raises.  Without
    the override: interpret everywhere except on a TPU backend."""
    v = os.environ.get(_INTERPRET_ENV)
    if v:
        if v not in ("0", "1"):
            raise ValueError(
                f"{_INTERPRET_ENV}={v!r}: use '1' (force interpret "
                "mode) or '0' (force compiled)")
        return v == "1"
    return not on_tpu()


@functools.partial(jax.jit, static_argnames=("causal", "window", "bq",
                                             "bk", "interpret"))
def _flash_jit(q, k, v, *, causal, window, bq, bk, interpret):
    return _flash(q, k, v, causal=causal, window=window, bq=bq, bk=bk,
                  interpret=interpret)


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    bq: int = 128, bk: int = 128):
    return _flash_jit(q, k, v, causal=causal, window=window, bq=bq,
                      bk=bk, interpret=default_interpret())


@functools.partial(jax.jit, static_argnames=("chunk", "bw", "interpret"))
def _rglru_jit(a, b, h0, *, chunk, bw, interpret):
    return _rglru(a, b, h0, chunk=chunk, bw=bw, interpret=interpret)


def rglru_scan(a, b, h0=None, *, chunk: int = 256, bw: int = 512):
    return _rglru_jit(a, b, h0, chunk=chunk, bw=bw,
                      interpret=default_interpret())


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def _wkv6_jit(r, k, v, w, u, state, *, chunk, interpret):
    return _wkv6(r, k, v, w, u, state, chunk=chunk, interpret=interpret)


def wkv6(r, k, v, w, u, state=None, *, chunk: int = 16):
    return _wkv6_jit(r, k, v, w, u, state, chunk=chunk,
                     interpret=default_interpret())


def _check_gather_domain(what: str, idx, n_rows: int) -> None:
    """Host-side gather validation: a Pallas index map silently wraps or
    clamps out-of-range block indices, so reject them *before* launch."""
    idx = np.asarray(idx)
    if len(idx) == 0:
        return
    lo, hi = int(idx.min()), int(idx.max())
    if lo < 0 or hi >= n_rows:
        bad = lo if lo < 0 else hi
        raise IndexError(
            f"{what}: index {bad} out of range for {n_rows} rows")


@functools.partial(jax.jit, static_argnames=("interpret",))
def _take_rows_jit(values, indices, *, interpret):
    return _take_rows(values, indices, interpret=interpret)


def take_rows(values, indices):
    """out[i] = values[indices[i]] — (R, W) x (M,) -> (M, W); empty
    index arrays return an empty gather, out-of-range indices raise."""
    _check_gather_domain("take_rows", indices, values.shape[0])
    if np.asarray(indices).shape[0] == 0:
        return jnp.empty((0, values.shape[1]), dtype=values.dtype)
    return _take_rows_jit(values, indices,
                          interpret=default_interpret())


@functools.partial(jax.jit, static_argnames=("bm", "interpret"))
def _dict_decode_jit(codes, dictionary, *, bm, interpret):
    return _dict_decode(codes, dictionary, bm=bm, interpret=interpret)


def dict_decode(codes, dictionary, *, bm: int = 256):
    """out[i] = dictionary[codes[i]] — (M,) x (R, W) -> (M, W).  Codes
    are validated on the host (out-of-range raises ``IndexError``; the
    one-hot matmul would silently decode garbage otherwise), zero codes
    return an empty decode, and M is padded up to the block size so any
    length works (the kernel requires bm | M; the pad rows decode row 0
    and are sliced off)."""
    _check_gather_domain("dict_decode", codes, dictionary.shape[0])
    M = np.asarray(codes).shape[0]
    if M == 0:
        return jnp.empty((0, dictionary.shape[1]),
                         dtype=dictionary.dtype)
    bm = min(bm, M)
    pad = -M % bm
    if pad:
        codes = jnp.concatenate(
            [jnp.asarray(codes),
             jnp.zeros(pad, dtype=jnp.asarray(codes).dtype)])
    out = _dict_decode_jit(codes, dictionary, bm=bm,
                           interpret=default_interpret())
    return out[:M] if pad else out

"""jit'd public wrappers for the Pallas kernels.

``interpret`` defaults to True off-TPU (the kernel body runs in Python for
validation) and False on TPU.  Every wrapper has a pure-jnp oracle in
ref.py; tests sweep shapes/dtypes and assert allclose against it.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .flash_attention import flash_attention as _flash
from .rglru_scan import rglru_pallas as _rglru
from .take_gather import dict_decode as _dict_decode
from .take_gather import take_rows as _take_rows
from .wkv6 import wkv6_pallas as _wkv6


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def default_interpret() -> bool:
    return not on_tpu()


@functools.partial(jax.jit, static_argnames=("causal", "window", "bq", "bk"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    bq: int = 128, bk: int = 128):
    return _flash(q, k, v, causal=causal, window=window, bq=bq, bk=bk,
                  interpret=default_interpret())


@functools.partial(jax.jit, static_argnames=("chunk", "bw"))
def rglru_scan(a, b, h0=None, *, chunk: int = 256, bw: int = 512):
    return _rglru(a, b, h0, chunk=chunk, bw=bw,
                  interpret=default_interpret())


@functools.partial(jax.jit, static_argnames=("chunk",))
def wkv6(r, k, v, w, u, state=None, *, chunk: int = 16):
    return _wkv6(r, k, v, w, u, state, chunk=chunk,
                 interpret=default_interpret())


@jax.jit
def take_rows(values, indices):
    return _take_rows(values, indices, interpret=default_interpret())


@functools.partial(jax.jit, static_argnames=("bm",))
def dict_decode(codes, dictionary, *, bm: int = 256):
    return _dict_decode(codes, dictionary, bm=bm,
                        interpret=default_interpret())

"""Whisper-style encoder-decoder (audio family).

The conv1d×2 mel frontend is a STUB per the assignment: ``input_specs``
provides precomputed frame embeddings (B, S_frames, d_model).  Backbone is
exact: 24 bidirectional encoder layers + 24 causal decoder layers with
cross-attention, gelu MLP, sinusoidal (encoder) / learned (decoder)
positions — all scan-stacked.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..compat import optimization_barrier
from ..configs.base import ArchConfig
from ..sharding.partition import constrain
from .attention import attn_apply, attn_axes, attn_init
from .layers import (dense_init, embed_init, mlp_apply, mlp_axes, mlp_init,
                     rms_norm, softmax_xent)

MAX_DEC_POS = 1 << 20


def sinusoid_pos(S: int, d: int) -> np.ndarray:
    pos = np.arange(S)[:, None]
    dim = np.arange(d // 2)[None, :]
    ang = pos / (10000 ** (dim / (d // 2 - 1)))
    return np.concatenate([np.sin(ang), np.cos(ang)], axis=-1
                          ).astype(np.float32)


class EncDecLM:
    def __init__(self, cfg: ArchConfig):
        self.cfg = cfg
        self.pdtype = jnp.dtype(cfg.param_dtype)
        self.cdtype = jnp.dtype(cfg.dtype)

    # -- init -------------------------------------------------------------
    def _enc_block_init(self, k):
        cfg = self.cfg
        ks = jax.random.split(k, 2)
        return {"ln1": jnp.zeros((cfg.d_model,), jnp.float32),
                "attn": attn_init(ks[0], cfg.d_model, cfg.n_heads,
                                  cfg.n_kv_heads, cfg.hd, self.pdtype),
                "ln2": jnp.zeros((cfg.d_model,), jnp.float32),
                "mlp": mlp_init(ks[1], cfg.d_model, cfg.d_ff, cfg.mlp,
                                self.pdtype)}

    def _dec_block_init(self, k):
        cfg = self.cfg
        ks = jax.random.split(k, 3)
        return {"ln1": jnp.zeros((cfg.d_model,), jnp.float32),
                "self": attn_init(ks[0], cfg.d_model, cfg.n_heads,
                                  cfg.n_kv_heads, cfg.hd, self.pdtype),
                "lnx": jnp.zeros((cfg.d_model,), jnp.float32),
                "cross": attn_init(ks[1], cfg.d_model, cfg.n_heads,
                                   cfg.n_kv_heads, cfg.hd, self.pdtype),
                "ln2": jnp.zeros((cfg.d_model,), jnp.float32),
                "mlp": mlp_init(ks[2], cfg.d_model, cfg.d_ff, cfg.mlp,
                                self.pdtype)}

    def init(self, key) -> Dict[str, Any]:
        cfg = self.cfg
        k1, k2, k3, k4 = jax.random.split(key, 4)
        enc = jax.vmap(self._enc_block_init)(
            jax.random.split(k1, cfg.encoder_layers))
        dec = jax.vmap(self._dec_block_init)(
            jax.random.split(k2, cfg.n_layers))
        return {"enc_blocks": enc, "dec_blocks": dec,
                "embed": embed_init(k3, cfg.vocab, cfg.d_model, self.pdtype),
                "dec_pos": (jax.random.normal(
                    k4, (4096, cfg.d_model)) * 0.01).astype(self.pdtype),
                "enc_norm": jnp.zeros((cfg.d_model,), jnp.float32),
                "final_norm": jnp.zeros((cfg.d_model,), jnp.float32)}

    def param_axes(self) -> Dict[str, Any]:
        a = attn_axes()
        m = mlp_axes(self.cfg.mlp)
        lift = lambda tree: jax.tree.map(
            lambda t: ("layers",) + t, tree,
            is_leaf=lambda x: isinstance(x, tuple) and
            all(e is None or isinstance(e, str) for e in x))
        return {
            "enc_blocks": lift({"ln1": (None,), "attn": a,
                                "ln2": (None,), "mlp": m}),
            "dec_blocks": lift({"ln1": (None,), "self": a, "lnx": (None,),
                                "cross": a, "ln2": (None,), "mlp": m}),
            "embed": ("vocab", "fsdp"), "dec_pos": (None, "fsdp"),
            "enc_norm": (None,), "final_norm": (None,)}

    # -- encoder --------------------------------------------------------------
    def encode(self, params, frames):
        """frames: (B, S, d) precomputed embeddings (frontend stub)."""
        cfg = self.cfg
        x = frames.astype(self.cdtype)
        S = x.shape[1]
        x = x + jnp.asarray(sinusoid_pos(S, cfg.d_model),
                            self.cdtype)[None]
        x = constrain(x, ("batch", "seq", None))

        def body(x, bp):
            bp = optimization_barrier(bp)  # keep gathers in-loop
            h = rms_norm(x, bp["ln1"], cfg.norm_eps)
            o, _ = attn_apply(bp["attn"], h, cfg=cfg, mode="train",
                              causal=False)
            x = x + o
            h = rms_norm(x, bp["ln2"], cfg.norm_eps)
            return x + mlp_apply(bp["mlp"], h, cfg.mlp), None
        if cfg.remat:
            body = jax.checkpoint(
                body, policy=jax.checkpoint_policies.nothing_saveable)
        x, _ = jax.lax.scan(body, x, params["enc_blocks"])
        return rms_norm(x, params["enc_norm"], cfg.norm_eps)

    # -- cross-attention KV, computed once per request -------------------------
    def cross_kv(self, params, enc_out):
        cfg = self.cfg

        def one(bp):
            k = jnp.einsum("bsd,dhk->bshk", enc_out,
                           bp["cross"]["wk"].astype(enc_out.dtype))
            v = jnp.einsum("bsd,dhk->bshk", enc_out,
                           bp["cross"]["wv"].astype(enc_out.dtype))
            return k, v
        return jax.lax.map(one, params["dec_blocks"])

    # -- decoder ------------------------------------------------------------------
    def _decoder(self, params, tokens, mode, enc_out=None, cross=None,
                 caches=None, positions=None):
        cfg = self.cfg
        B, S = tokens.shape
        x = jnp.take(params["embed"], tokens, axis=0).astype(self.cdtype)
        if positions is None:
            positions = jnp.arange(S)[None, :]
        # (1|B, S, d) learned positions broadcast over the batch
        x = x + jnp.take(params["dec_pos"], positions % 4096,
                         axis=0).astype(self.cdtype)
        x = constrain(x, ("batch", "seq", None))

        def body(carry, scanned):
            x = carry
            bp, cr, cache = scanned
            bp = optimization_barrier(bp)  # keep gathers in-loop
            h = rms_norm(x, bp["ln1"], cfg.norm_eps)
            nc = None
            o, nc = attn_apply(bp["self"], h, cfg=cfg, mode=mode,
                               cache=cache, pos=positions)
            x = x + o
            h = rms_norm(x, bp["lnx"], cfg.norm_eps)
            o, _ = attn_apply(bp["cross"], h, cfg=cfg, mode=mode,
                              kv_override=cr)
            x = x + o
            h = rms_norm(x, bp["ln2"], cfg.norm_eps)
            x = x + mlp_apply(bp["mlp"], h, cfg.mlp)
            return x, nc

        if cross is None:
            assert enc_out is not None
            cross = self.cross_kv(params, enc_out)
        body_fn = body
        if cfg.remat and mode == "train":
            body_fn = jax.checkpoint(
                body, policy=jax.checkpoint_policies.nothing_saveable)
        x, new_caches = jax.lax.scan(body_fn, x,
                                     (params["dec_blocks"], cross, caches))
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = x @ params["embed"].T.astype(x.dtype)
        return constrain(logits, ("batch", "seq", "vocab")), new_caches

    # -- public API ---------------------------------------------------------------
    def loss_fn(self, params, batch):
        enc_out = self.encode(params, batch["frames"])
        logits, _ = self._decoder(params, batch["tokens"], "train",
                                  enc_out=enc_out)
        loss = softmax_xent(logits, batch["labels"]).mean()
        return loss, {"loss": loss, "total_loss": loss}

    def init_cache(self, B: int, cache_len: int):
        cfg = self.cfg

        def one(_):
            return {"k": jnp.zeros((B, cache_len, cfg.n_kv_heads, cfg.hd),
                                   self.cdtype),
                    "v": jnp.zeros((B, cache_len, cfg.n_kv_heads, cfg.hd),
                                   self.cdtype),
                    "len": jnp.zeros((), jnp.int32)}
        return jax.vmap(one)(jnp.arange(cfg.n_layers))

    def cache_axes(self):
        return {"k": (None, "batch", "kv_seq", "kv_heads", None),
                "v": (None, "batch", "kv_seq", "kv_heads", None),
                "len": (None,)}

    def prefill(self, params, batch, cache_len: int):
        """Encode frames + run decoder prompt (BOS) -> caches."""
        enc_out = self.encode(params, batch["frames"])
        cross = self.cross_kv(params, enc_out)
        caches = self.init_cache(batch["tokens"].shape[0], cache_len)
        logits, caches = self._decoder(params, batch["tokens"], "prefill",
                                       cross=cross, caches=caches)
        return logits[:, -1:], (caches, cross)

    def decode_step(self, params, tokens, caches, positions):
        self_caches, cross = caches
        logits, self_caches = self._decoder(params, tokens, "decode",
                                            cross=cross, caches=self_caches,
                                            positions=positions)
        return logits, (self_caches, cross)

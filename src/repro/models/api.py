"""Unified model API: build any assigned architecture, get its train /
prefill / decode entry points, input specs (ShapeDtypeStruct stand-ins for
the dry-run) and logical sharding axes for every input and state."""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ArchConfig, ShapeConfig
from .encdec import EncDecLM
from .lm import DecoderLM


class ModelAPI:
    def __init__(self, cfg: ArchConfig):
        self.cfg = cfg
        self.model = EncDecLM(cfg) if cfg.is_encdec else DecoderLM(cfg)

    # -- inputs -------------------------------------------------------------
    def input_specs(self, shape: ShapeConfig) -> Dict[str, Any]:
        """ShapeDtypeStruct stand-ins for every model input (no allocation).

        train:   {tokens, labels, [patches|frames]}
        prefill: {tokens, [patches|frames]}
        decode:  {tokens (B,1), positions (B,1)} (+ cache specs separately)
        """
        cfg = self.cfg
        B, S = shape.global_batch, shape.seq_len
        f32 = jnp.dtype(cfg.dtype)
        i32 = jnp.int32
        sds = jax.ShapeDtypeStruct
        if shape.kind == "train":
            d: Dict[str, Any] = {}
            if cfg.is_encdec:
                d["frames"] = sds((B, S, cfg.d_model), f32)
                d["tokens"] = sds((B, S), i32)
                d["labels"] = sds((B, S), i32)
            elif cfg.vision_tokens:
                d["patches"] = sds((B, cfg.vision_tokens, cfg.d_model), f32)
                d["tokens"] = sds((B, S - cfg.vision_tokens), i32)
                d["labels"] = sds((B, S - cfg.vision_tokens), i32)
            else:
                d["tokens"] = sds((B, S), i32)
                d["labels"] = sds((B, S), i32)
            return d
        if shape.kind == "prefill":
            d = {}
            if cfg.is_encdec:
                d["frames"] = sds((B, S, cfg.d_model), f32)
                d["tokens"] = sds((B, 1), i32)       # BOS
            elif cfg.vision_tokens:
                d["patches"] = sds((B, cfg.vision_tokens, cfg.d_model), f32)
                d["tokens"] = sds((B, S - cfg.vision_tokens), i32)
            else:
                d["tokens"] = sds((B, S), i32)
            return d
        # decode: one new token against a cache of seq_len
        return {"tokens": sds((B, 1), i32), "positions": sds((B, 1), i32)}

    def input_axes(self, shape: ShapeConfig) -> Dict[str, Tuple]:
        cfg = self.cfg
        ax: Dict[str, Tuple] = {}
        for k in self.input_specs(shape):
            if k in ("frames", "patches"):
                ax[k] = ("batch", "seq", None) if k == "frames" \
                    else ("batch", None, None)
            else:
                ax[k] = ("batch", "seq") if shape.kind != "decode" \
                    else ("batch", None)
        return ax

    # -- caches ------------------------------------------------------------------
    def cache_specs(self, shape: ShapeConfig) -> Any:
        """Abstract cache pytree for decode shapes (eval_shape: no alloc)."""
        B, S = shape.global_batch, shape.seq_len
        if self.cfg.is_encdec:
            def mk():
                caches = self.model.init_cache(B, S)
                cross = jax.eval_shape(
                    lambda: self._abstract_cross(B, S))
                return caches, cross
            # build both under eval_shape
            return jax.eval_shape(lambda: (self.model.init_cache(B, S),
                                           self._abstract_cross(B, S)))
        return jax.eval_shape(lambda: self.model.init_cache(B, S))

    def _abstract_cross(self, B, S_enc):
        cfg = self.cfg
        z = jnp.zeros((cfg.n_layers, B, S_enc, cfg.n_kv_heads, cfg.hd),
                      jnp.dtype(cfg.dtype))
        return (z, z)

    def cache_axes(self) -> Any:
        base = self.model.cache_axes()
        if self.cfg.is_encdec:
            cross = ((None, "batch", "kv_seq", "kv_heads", None),) * 2
            return (base, cross)
        return base

    # -- entry points ----------------------------------------------------------
    def train_loss(self, params, batch):
        return self.model.loss_fn(params, batch)

    def prefill(self, params, batch, shape: ShapeConfig):
        return self.model.prefill(params, batch, cache_len=shape.seq_len)

    def serve_step(self, params, batch, caches):
        """decode: one new token for every sequence in the batch."""
        return self.model.decode_step(params, batch["tokens"], caches,
                                      batch["positions"])


@functools.lru_cache(maxsize=None)
def build_model(cfg: ArchConfig) -> ModelAPI:
    return ModelAPI(cfg)
